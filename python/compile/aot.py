"""AOT lowering: JAX/Pallas -> HLO text artifacts + manifest.json.

This is the only place Python runs in the system: `make artifacts`
invokes it once; the Rust runtime then loads the HLO text via
`HloModuleProto::from_text_file` (PJRT). HLO *text* — not serialized
protos — is the interchange format: jax >= 0.5 emits 64-bit instruction
ids that xla_extension 0.5.1 rejects; the text parser reassigns ids.

Exported modules (see ArtifactStore on the Rust side):
  * forward      — DeepCAM-lite inference: (params..., x) -> (logits,)
  * train_step   — full fwd+bwd+SGD: (params..., momentum..., x, labels)
                   -> (new_params..., new_momentum..., loss)
  * gemm_<M>     — standalone Pallas GEMM probes for runtime tests and
                   the Fig. 2 small-size empirical anchors
  * ert_fma      — the Pallas ERT micro-kernel probe
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ert, gemm


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def tensor_spec(x) -> dict:
    dt = jnp.result_type(x)
    name = {"float32": "f32", "int32": "s32", "bfloat16": "bf16"}.get(str(dt), str(dt))
    return {"dims": list(x.shape), "dtype": name}


def flops_estimate(lowered) -> float | None:
    """Analytic FLOPs from XLA's cost analysis, when available."""
    try:
        compiled = lowered.compile()
        cost = compiled.cost_analysis()
        if isinstance(cost, list):
            cost = cost[0]
        return float(cost.get("flops", 0.0)) or None
    except Exception:
        return None


def lower_module(name, fn, example_args, out_dir, manifest, meta=None, with_flops=True):
    print(f"[aot] lowering {name} ...", flush=True)
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(text)
    outputs = jax.eval_shape(fn, *example_args)
    flat_out, _ = jax.tree_util.tree_flatten(outputs)
    flat_in, _ = jax.tree_util.tree_flatten(example_args)
    manifest["modules"][name] = {
        "hlo_file": hlo_file,
        "inputs": [tensor_spec(a) for a in flat_in],
        "outputs": [tensor_spec(o) for o in flat_out],
        "flops_per_run": flops_estimate(lowered) if with_flops else None,
        "meta": meta or {},
    }
    print(f"[aot]   wrote {hlo_file} ({len(text)} chars)", flush=True)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="artifact output dir")
    parser.add_argument("--height", type=int, default=32)
    parser.add_argument("--width", type=int, default=32)
    parser.add_argument("--batch", type=int, default=2)
    args = parser.parse_args()
    out_dir = args.out
    os.makedirs(out_dir, exist_ok=True)

    cfg = model.DeepCamConfig.lite(height=args.height, width=args.width, batch=args.batch)
    params = model.init_params(cfg, seed=0)
    momentum = model.zero_momentum(params)
    x, labels = model.synthetic_batch(cfg, seed=0)

    flat_params, params_def = jax.tree_util.tree_flatten(params)
    flat_mom, _ = jax.tree_util.tree_flatten(momentum)
    n_p = len(flat_params)

    manifest = {"modules": {}, "config": {
        "height": cfg.height, "width": cfg.width, "batch": cfg.batch,
        "in_channels": cfg.in_channels, "classes": cfg.classes,
        "n_param_tensors": n_p, "n_params": model.n_params(params),
    }}

    # ---- forward ----
    def forward_flat(*args_):
        p = jax.tree_util.tree_unflatten(params_def, args_[:n_p])
        return (model.forward(p, args_[n_p], cfg),)

    lower_module(
        "forward",
        forward_flat,
        (*flat_params, x),
        out_dir,
        manifest,
        meta={"params": str(model.n_params(params))},
    )

    # ---- train_step ----
    def train_step_flat(*args_):
        p = jax.tree_util.tree_unflatten(params_def, args_[:n_p])
        m = jax.tree_util.tree_unflatten(params_def, args_[n_p : 2 * n_p])
        xb, lb = args_[2 * n_p], args_[2 * n_p + 1]
        new_p, new_m, loss = model.train_step(p, m, xb, lb, cfg)
        fp, _ = jax.tree_util.tree_flatten(new_p)
        fm, _ = jax.tree_util.tree_flatten(new_m)
        return (*fp, *fm, loss)

    lower_module(
        "train_step",
        train_step_flat,
        (*flat_params, *flat_mom, x, labels),
        out_dir,
        manifest,
        meta={"params": str(model.n_params(params))},
    )

    # ---- standalone GEMM probes ----
    for m_size in (128, 256):
        a = jnp.ones((m_size, m_size), jnp.float32)

        def gemm_fn(x_, w_):
            return (gemm.matmul_nocustom(x_, w_),)

        lower_module(
            f"gemm_{m_size}",
            gemm_fn,
            (a, a),
            out_dir,
            manifest,
            meta={"flops_analytic": str(2 * m_size**3)},
        )

    # ---- ERT probe ----
    buf = jnp.ones((4096, 64), jnp.float32)

    def ert_fn(x_):
        return (ert.ert_fma(x_, iters=64),)

    lower_module(
        "ert_fma",
        ert_fn,
        (buf,),
        out_dir,
        manifest,
        meta={"flops_analytic": str(ert.ert_flops(buf.shape, 64))},
    )

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] manifest with {len(manifest['modules'])} modules -> {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
