"""Layer-1 ERT micro-kernel in Pallas: the chained-FMA probe of §II-A,
as a real kernel artifact.

The Rust ERT's *empirical* mode measures native host loops; this Pallas
variant is additionally AOT-lowered so the runtime integration tests can
execute an ERT probe through the exact PJRT path the model artifacts
use (machine characterization and application characterization sharing
one execution substrate).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ert_kernel(x_ref, o_ref, *, iters: int, alpha: float, beta: float):
    v = x_ref[...]
    def body(_, acc):
        return acc * alpha + beta
    v = jax.lax.fori_loop(0, iters, body, v.astype(jnp.float32))
    o_ref[...] = v.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("iters",))
def ert_fma(x, *, iters: int = 64, alpha: float = 1.000001, beta: float = 0.999999):
    """Run the FMA chain over a 2-D buffer, blocked over rows.

    FLOPs = 2 * iters * x.size (one FMA per element per iteration).
    """
    rows, cols = x.shape
    br = min(256, rows)
    pad = -rows % br
    xp = jnp.pad(x, ((0, pad), (0, 0))) if pad else x
    y = pl.pallas_call(
        functools.partial(_ert_kernel, iters=iters, alpha=alpha, beta=beta),
        grid=((rows + pad) // br,),
        in_specs=[pl.BlockSpec((br, cols), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + pad, cols), jnp.float32),
        interpret=True,
    )(xp)
    return y[:rows] if pad else y


def ert_flops(shape, iters: int) -> int:
    """Analytic FLOP count for the manifest."""
    n = 1
    for d in shape:
        n *= d
    return 2 * iters * n
