"""Layer-1 fused scale-shift + ReLU Pallas kernel (batch-norm apply).

DeepCAM interleaves batch norm + ReLU after nearly every conv; in both
frameworks those lower to *streaming* elementwise kernels — the
overlapping L1/L2/HBM triplets near the bandwidth ceilings in Figs 3-6.
The normalization statistics (mean/var over N,H,W) are computed with
jnp reductions; the per-element normalize+affine+ReLU — the bandwidth-
bound part — is a fused Pallas kernel with a Pallas backward.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scale_shift_relu_kernel(x_ref, scale_ref, shift_ref, y_ref):
    x = x_ref[...]
    y = x * scale_ref[...] + shift_ref[...]
    y_ref[...] = jnp.maximum(y, 0.0).astype(y_ref.dtype)


def _scale_shift_relu_bwd_kernel(x_ref, scale_ref, shift_ref, g_ref, dx_ref):
    x = x_ref[...]
    pre = x * scale_ref[...] + shift_ref[...]
    mask = (pre > 0.0).astype(g_ref.dtype)
    dx_ref[...] = (g_ref[...] * mask * scale_ref[...]).astype(dx_ref.dtype)


def _row_blocks(rows: int, block: int = 256) -> int:
    return min(block, rows)


def _call_elementwise(kernel, args, out_dtype, rows, cols):
    """Run an elementwise (rows, cols)-shaped kernel blocked over rows.

    VMEM per cell: block_rows * cols * 4B per operand — a streaming
    BlockSpec schedule (each block touched once, no reuse), matching the
    kernel's roofline signature.
    """
    br = _row_blocks(rows)
    pad = -rows % br
    if pad:
        args = [jnp.pad(a, ((0, pad), (0, 0))) if a.shape[0] == rows else a for a in args]
    rp = rows + pad
    specs = []
    for a in args:
        if a.shape[0] == rp:
            specs.append(pl.BlockSpec((br, cols), lambda i: (i, 0)))
        else:  # broadcast row (scale/shift): (1, cols) block for all i
            specs.append(pl.BlockSpec((1, cols), lambda i: (0, 0)))
    y = pl.pallas_call(
        kernel,
        grid=(rp // br,),
        in_specs=specs,
        out_specs=pl.BlockSpec((br, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rp, cols), out_dtype),
        interpret=True,
    )(*args)
    return y[:rows] if pad else y


@jax.custom_vjp
def scale_shift_relu(x2d, scale, shift):
    """Fused y = relu(x * scale + shift) over (rows, C) with (1, C)
    broadcast scale/shift. Forward and dx-backward are Pallas kernels."""
    rows, cols = x2d.shape
    return _call_elementwise(
        _scale_shift_relu_kernel, [x2d, scale, shift], x2d.dtype, rows, cols
    )


def _ssr_fwd(x2d, scale, shift):
    return scale_shift_relu(x2d, scale, shift), (x2d, scale, shift)


def _ssr_bwd(res, g):
    x2d, scale, shift = res
    rows, cols = x2d.shape
    dx = _call_elementwise(
        _scale_shift_relu_bwd_kernel, [x2d, scale, shift, g], x2d.dtype, rows, cols
    )
    pre = x2d * scale + shift
    mask = (pre > 0.0).astype(g.dtype)
    gm = g * mask
    dscale = jnp.sum(gm * x2d, axis=0, keepdims=True)
    dshift = jnp.sum(gm, axis=0, keepdims=True)
    return dx, dscale.astype(scale.dtype), dshift.astype(shift.dtype)


scale_shift_relu.defvjp(_ssr_fwd, _ssr_bwd)


def batch_norm_relu(x, gamma, beta, *, eps: float = 1e-5):
    """Training-mode BN + ReLU over NHWC, fused apply via Pallas.

    Statistics are batch statistics (differentiable through jnp); the
    elementwise apply is the Pallas kernel above.
    """
    n, h, w, c = x.shape
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    inv = gamma * jax.lax.rsqrt(var + eps)
    scale = inv.reshape(1, c)
    shift = (beta - mean * inv).reshape(1, c)
    y = scale_shift_relu(x.reshape(n * h * w, c), scale, shift)
    return y.reshape(n, h, w, c)
