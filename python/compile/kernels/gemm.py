"""Layer-1 Pallas tiled-GEMM kernel — the compute hot-spot of DeepCAM-lite.

This is the TPU re-expression of the paper's tensor-core GEMM study
(§II-A2): instead of WMMA fragments + shared-memory staging, the kernel
tiles the output into (block_m, block_n) MXU-friendly blocks via
``BlockSpec`` (the HBM->VMEM schedule) and lets the MXU-shaped ``jnp.dot``
with ``preferred_element_type=float32`` express the systolic matmul
(bf16 inputs are the TPU analog of FP16 tensor-core inputs).

VMEM footprint per grid cell (see DESIGN.md §8):
    (block_m*K + K*block_n + block_m*block_n) * dtype_bytes
e.g. 64x1152 + 1152x64 + 64x64 f32 = ~608 KiB << 16 MiB VMEM.

``interpret=True`` everywhere: the CPU PJRT backend cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the artifact runs
from the Rust runtime.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, w_ref, o_ref):
    """One (block_m, block_n) output tile: full-K panel contraction."""
    o_ref[...] = jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _round_up(v: int, to: int) -> int:
    return -(-v // to) * to


def _pad_to(x, rows: int, cols: int):
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@partial(jax.jit, static_argnames=("block_m", "block_n"))
def matmul_nocustom(x, w, *, block_m: int = 64, block_n: int = 64):
    """Pallas GEMM without a custom VJP (building block; padded/tiled).

    x: (M, K), w: (K, N) -> (M, N) in float32 accumulation.
    """
    if x.ndim != 2 or w.ndim != 2 or x.shape[1] != w.shape[0]:
        raise ValueError(f"matmul shapes {x.shape} @ {w.shape}")
    m, k = x.shape
    _, n = w.shape
    bm = min(block_m, _round_up(m, 8))
    bn = min(block_n, _round_up(n, 8))
    mp, np_ = _round_up(m, bm), _round_up(n, bn)
    xp = _pad_to(x, mp, k)
    wp = _pad_to(w, k, np_)
    out = pl.pallas_call(
        _matmul_kernel,
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x, w):
    """Differentiable Pallas GEMM: ``x @ w`` with fp32 accumulation.

    The backward pass is two more Pallas GEMMs (dx = g w^T, dw = x^T g),
    so the L1 kernel carries the training hot path end to end.
    """
    return matmul_nocustom(x, w)


def _matmul_fwd(x, w):
    return matmul_nocustom(x, w), (x, w)


def _matmul_bwd(res, g):
    x, w = res
    g = g.astype(jnp.float32)
    dx = matmul_nocustom(g, w.T).astype(x.dtype)
    dw = matmul_nocustom(x.T, g).astype(w.dtype)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_bf16(x, w):
    """Mixed-precision GEMM: bf16 inputs, fp32 accumulate (the TPU analog
    of FP16 tensor-core GEMM; used by the AMP-enabled model variants)."""
    return matmul(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16))
