"""Layer-1 convolution kernels: im2col + Pallas GEMM.

The paper's dominant DeepCAM kernels are cuDNN implicit-GEMM
convolutions; the TPU re-expression lowers every conv to an explicit
patch extraction (pure data movement, differentiable) followed by the
Pallas tiled GEMM of :mod:`gemm` — so the network's FLOP hot path runs
through the L1 kernel in both the forward and backward pass (the GEMM
carries a custom VJP built from more Pallas GEMMs).

Layout: NHWC activations, HWIO weights (JAX convention).
"""

import jax
import jax.numpy as jnp
from jax import lax

from . import gemm


def _same_pads(size: int, stride: int, kernel: int) -> tuple[int, int]:
    """TF-style SAME padding for one spatial dim."""
    out = -(-size // stride)
    pad = max(0, (out - 1) * stride + kernel - size)
    return pad // 2, pad - pad // 2


def im2col(x, kh: int, kw: int, stride: int, dilation: int = 1):
    """Extract conv patches: (N,H,W,C) -> (N*OH*OW, KH*KW*C).

    Pure data movement (lax.conv_general_dilated_patches), fully
    differentiable; all FLOPs happen in the Pallas GEMM that follows.
    """
    n, h, w, _c = x.shape
    pads = (
        _same_pads(h, stride, (kh - 1) * dilation + 1),
        _same_pads(w, stride, (kw - 1) * dilation + 1),
    )
    patches = lax.conv_general_dilated_patches(
        x,
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding=pads,
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    # patches: (N, OH, OW, C*KH*KW) with channel-major patch layout.
    oh, ow = patches.shape[1], patches.shape[2]
    return patches.reshape(n * oh * ow, patches.shape[3]), (n, oh, ow)


def conv2d(x, w, b=None, *, stride: int = 1, dilation: int = 1):
    """2-D convolution with SAME padding via im2col + Pallas GEMM.

    x: (N, H, W, C); w: (KH, KW, C, OC); b: (OC,) or None.
    """
    kh, kw, c, oc = w.shape
    if x.shape[3] != c:
        raise ValueError(f"conv2d channels: x {x.shape} vs w {w.shape}")
    cols, (n, oh, ow) = im2col(x, kh, kw, stride, dilation)
    # Patch layout is (C, KH, KW)-major: transpose weights to match.
    w2 = jnp.transpose(w, (2, 0, 1, 3)).reshape(c * kh * kw, oc)
    y = gemm.matmul(cols, w2)
    y = y.reshape(n, oh, ow, oc)
    if b is not None:
        y = y + b
    return y


def conv2d_transpose(x, w, b=None, *, stride: int = 2):
    """Transposed convolution (decoder upsampling), built as input
    dilation (zero insertion — pure movement) + stride-1 Pallas conv.

    x: (N, H, W, C); w: (KH, KW, C, OC). Output spatial = H*stride.
    """
    if stride > 1:
        n, h, w_, c = x.shape
        # Interior padding inserts stride-1 zeros between elements.
        x = lax.pad(
            x,
            jnp.zeros((), x.dtype),
            ((0, 0, 0), (0, stride - 1, stride - 1), (0, stride - 1, stride - 1), (0, 0, 0)),
        )
        # lax.pad with interior puts zeros *between* and after; trim the
        # trailing zeros to get exactly H*stride.
        x = x[:, : h * stride, : w_ * stride, :]
    # Spatially flip the kernel (transposed conv = correlation with
    # flipped kernel over the dilated input).
    w_flipped = w[::-1, ::-1, :, :]
    return conv2d(x, w_flipped, b, stride=1)


def avg_pool_global(x):
    """Global average pool (ASPP image-level feature): (N,H,W,C)->(N,1,1,C)."""
    return jnp.mean(x, axis=(1, 2), keepdims=True)


def conv_flops(x_shape, w_shape, stride: int = 1) -> int:
    """Analytic FLOPs of conv2d (2 * N*OH*OW * KH*KW*C * OC), used by the
    AOT manifest and cross-checked against the Rust dl/ lowering."""
    n, h, w_, _ = x_shape
    kh, kw, c, oc = w_shape
    oh, ow = -(-h // stride), -(-w_ // stride)
    return 2 * n * oh * ow * kh * kw * c * oc
