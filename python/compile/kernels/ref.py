"""Pure-jnp correctness oracles for every Pallas kernel.

These are the ground truth against which pytest (and hypothesis sweeps)
check the L1 kernels — the build-time equivalent of the paper's concern
that profiled kernels be deterministic and correct before measurement.
"""

import jax
import jax.numpy as jnp
from jax import lax


def matmul_ref(x, w):
    """Reference GEMM with fp32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32)


def conv2d_ref(x, w, b=None, *, stride: int = 1, dilation: int = 1):
    """Reference conv (SAME padding) via lax.conv_general_dilated."""
    kh, kw = w.shape[0], w.shape[1]
    h, wd = x.shape[1], x.shape[2]

    def same_pads(size, k):
        out = -(-size // stride)
        pad = max(0, (out - 1) * stride + (k - 1) * dilation + 1 - size)
        return pad // 2, pad - pad // 2

    y = lax.conv_general_dilated(
        x.astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=(same_pads(h, kh), same_pads(wd, kw)),
        rhs_dilation=(dilation, dilation),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return y


def conv2d_transpose_ref(x, w, b=None, *, stride: int = 2):
    """Reference transposed conv: zero-dilate input, flip kernel, conv."""
    if stride > 1:
        n, h, wd, c = x.shape
        x = lax.pad(
            x,
            jnp.zeros((), x.dtype),
            ((0, 0, 0), (0, stride - 1, stride - 1), (0, stride - 1, stride - 1), (0, 0, 0)),
        )
        x = x[:, : h * stride, : wd * stride, :]
    return conv2d_ref(x, w[::-1, ::-1, :, :], b, stride=1)


def batch_norm_relu_ref(x, gamma, beta, *, eps: float = 1e-5):
    """Reference train-mode batch norm + ReLU over NHWC."""
    mean = jnp.mean(x, axis=(0, 1, 2))
    var = jnp.var(x, axis=(0, 1, 2))
    y = (x - mean) * gamma * lax.rsqrt(var + eps) + beta
    return jnp.maximum(y, 0.0)


def scale_shift_relu_ref(x2d, scale, shift):
    """Reference fused scale-shift-relu over (rows, C)."""
    return jnp.maximum(x2d * scale + shift, 0.0)


def ert_fma_ref(x, iters: int, alpha: float = 1.000001, beta: float = 0.999999):
    """Reference ERT FMA chain: x <- alpha*x + beta, `iters` times."""
    def body(_, v):
        return alpha * v + beta
    return lax.fori_loop(0, iters, body, x.astype(jnp.float32))
