"""Layer-2 JAX model: DeepCAM-lite — a DeepLabv3+-style encoder-decoder
for climate-pattern segmentation (the paper's profiling subject, §III-B),
built entirely on the Layer-1 Pallas kernels.

Architecture (scaled-down but structurally faithful to DeepCAM):
  * encoder — conv stem + residual blocks with strided downsampling
    (ResNet-style, the paper's encoder is ResNet-50);
  * ASPP — atrous spatial pyramid pooling: parallel 3x3 convs at
    dilations {1, 2, 4}, a 1x1 branch and an image-level branch, fused
    by a 1x1 conv;
  * decoder — nine layers: two transposed-conv upsampling stages with
    skip connections from the stem and mid-encoder, interleaved with
    3x3 convs, and a final 1x1 classifier (3 classes: background /
    tropical cyclone / atmospheric river).

Every conv goes through the Pallas im2col GEMM; every BN+ReLU through
the fused Pallas scale-shift kernel; their custom VJPs keep the backward
pass on Pallas GEMMs too. All functions are pure and jit/lower-able —
`compile/aot.py` exports `forward` and `train_step` to HLO text.
"""

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import bn, conv, gemm


@dataclass(frozen=True)
class DeepCamConfig:
    """Model hyper-parameters. `lite()` is the AOT/e2e configuration;
    `paper()` mirrors DeepCAM's published scale for the Rust-side trace
    generator (never compiled here — too large for interpret mode)."""

    height: int = 64
    width: int = 64
    in_channels: int = 4
    classes: int = 3
    stem_channels: int = 16
    encoder_channels: tuple = (16, 32, 64)
    blocks_per_stage: int = 1
    aspp_channels: int = 32
    decoder_channels: int = 32
    batch: int = 2
    amp: bool = False  # bf16 GEMM inputs (the TPU analog of AMP FP16)

    @staticmethod
    def lite(**kw):
        return DeepCamConfig(**kw)

    @staticmethod
    def tiny(**kw):
        """Unit-test scale."""
        base = dict(
            height=16,
            width=16,
            stem_channels=4,
            encoder_channels=(4, 8),
            aspp_channels=8,
            decoder_channels=8,
            batch=1,
        )
        base.update(kw)
        return DeepCamConfig(**base)


# --------------------------------------------------------------------
# Parameter initialization
# --------------------------------------------------------------------


def _conv_init(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    w = jax.random.normal(key, (kh, kw, cin, cout), jnp.float32)
    return w * (2.0 / fan_in) ** 0.5


def _bn_init(c):
    return {"gamma": jnp.ones((c,), jnp.float32), "beta": jnp.zeros((c,), jnp.float32)}


def init_params(cfg: DeepCamConfig, seed: int = 0):
    """Build the parameter pytree (nested dicts keyed by layer name)."""
    key = jax.random.PRNGKey(seed)
    keys = iter(jax.random.split(key, 64))
    p = {}

    # Stem: 3x3 stride-2.
    p["stem"] = {
        "w": _conv_init(next(keys), 3, 3, cfg.in_channels, cfg.stem_channels),
        "bn": _bn_init(cfg.stem_channels),
    }

    # Encoder stages: each downsamples 2x then runs residual blocks.
    cin = cfg.stem_channels
    p["encoder"] = []
    for ch in cfg.encoder_channels:
        stage = {
            "down": {
                "w": _conv_init(next(keys), 3, 3, cin, ch),
                "bn": _bn_init(ch),
            },
            "blocks": [],
        }
        for _ in range(cfg.blocks_per_stage):
            stage["blocks"].append(
                {
                    "w1": _conv_init(next(keys), 3, 3, ch, ch),
                    "bn1": _bn_init(ch),
                    "w2": _conv_init(next(keys), 3, 3, ch, ch),
                    "bn2": _bn_init(ch),
                }
            )
        p["encoder"].append(stage)
        cin = ch

    # ASPP: dilations 1/2/4 + 1x1 + image pooling, fused by 1x1.
    ac = cfg.aspp_channels
    p["aspp"] = {
        "b0": {"w": _conv_init(next(keys), 1, 1, cin, ac), "bn": _bn_init(ac)},
        "b1": {"w": _conv_init(next(keys), 3, 3, cin, ac), "bn": _bn_init(ac)},
        "b2": {"w": _conv_init(next(keys), 3, 3, cin, ac), "bn": _bn_init(ac)},
        "b3": {"w": _conv_init(next(keys), 3, 3, cin, ac), "bn": _bn_init(ac)},
        "pool": {"w": _conv_init(next(keys), 1, 1, cin, ac)},
        "fuse": {"w": _conv_init(next(keys), 1, 1, 5 * ac, ac), "bn": _bn_init(ac)},
    }

    # Decoder (nine layers, two skips).
    dc = cfg.decoder_channels
    mid_ch = cfg.encoder_channels[0]
    p["decoder"] = {
        # layer 1: deconv x2
        "up1": {"w": _conv_init(next(keys), 3, 3, ac, dc)},
        # layer 2: fuse skip from encoder stage 0
        "skip1": {"w": _conv_init(next(keys), 1, 1, dc + mid_ch, dc), "bn": _bn_init(dc)},
        # layers 3-4: convs
        "c1": {"w": _conv_init(next(keys), 3, 3, dc, dc), "bn": _bn_init(dc)},
        "c2": {"w": _conv_init(next(keys), 3, 3, dc, dc), "bn": _bn_init(dc)},
        # layer 5: deconv x2
        "up2": {"w": _conv_init(next(keys), 3, 3, dc, dc)},
        # layer 6: fuse skip from stem
        "skip2": {"w": _conv_init(next(keys), 1, 1, dc + cfg.stem_channels, dc), "bn": _bn_init(dc)},
        # layers 7-8: convs
        "c3": {"w": _conv_init(next(keys), 3, 3, dc, dc), "bn": _bn_init(dc)},
        "c4": {"w": _conv_init(next(keys), 3, 3, dc, dc), "bn": _bn_init(dc)},
        # layer 9: the 1x1 per-pixel classifier
        "cls": {"w": _conv_init(next(keys), 1, 1, dc, cfg.classes)},
    }
    return p


def n_params(params) -> int:
    """Total scalar parameter count."""
    return sum(x.size for x in jax.tree_util.tree_leaves(params))


# --------------------------------------------------------------------
# Forward pass
# --------------------------------------------------------------------


def _maybe_amp(x, cfg: DeepCamConfig):
    return x.astype(jnp.bfloat16) if cfg.amp else x


def _conv_bn_relu(x, layer, cfg, *, stride=1, dilation=1):
    y = conv.conv2d(_maybe_amp(x, cfg), _maybe_amp(layer["w"], cfg), stride=stride, dilation=dilation)
    return bn.batch_norm_relu(y, layer["bn"]["gamma"], layer["bn"]["beta"])


def _res_block(x, blk, cfg):
    y = _conv_bn_relu(x, {"w": blk["w1"], "bn": blk["bn1"]}, cfg)
    y = conv.conv2d(_maybe_amp(y, cfg), _maybe_amp(blk["w2"], cfg))
    # BN without ReLU before the residual add, ReLU after (ResNet order,
    # folded: scale-shift then add then relu).
    g, b = blk["bn2"]["gamma"], blk["bn2"]["beta"]
    mean = jnp.mean(y, axis=(0, 1, 2))
    var = jnp.var(y, axis=(0, 1, 2))
    y = (y - mean) * g * jax.lax.rsqrt(var + 1e-5) + b
    return jnp.maximum(y + x, 0.0)


def forward(params, x, cfg: DeepCamConfig):
    """DeepCAM-lite forward: (N, H, W, C) -> per-pixel logits
    (N, H, W, classes)."""
    # Stem (keeps a full-res skip).
    stem = _conv_bn_relu(x, params["stem"], cfg, stride=1)

    # Encoder.
    feats = stem
    skips = [stem]
    for stage in params["encoder"]:
        feats = _conv_bn_relu(feats, stage["down"], cfg, stride=2)
        for blk in stage["blocks"]:
            feats = _res_block(feats, blk, cfg)
        skips.append(feats)
    mid = skips[1]  # after first stage: the decoder's mid-level skip

    # ASPP.
    a = params["aspp"]
    b0 = _conv_bn_relu(feats, a["b0"], cfg)
    b1 = _conv_bn_relu(feats, a["b1"], cfg, dilation=1)
    b2 = _conv_bn_relu(feats, a["b2"], cfg, dilation=2)
    b3 = _conv_bn_relu(feats, a["b3"], cfg, dilation=4)
    pooled = conv.avg_pool_global(feats)
    pooled = conv.conv2d(_maybe_amp(pooled, cfg), _maybe_amp(a["pool"]["w"], cfg))
    pooled = jnp.broadcast_to(pooled, b0.shape)
    y = jnp.concatenate([b0, b1, b2, b3, pooled], axis=-1)
    y = _conv_bn_relu(y, a["fuse"], cfg)

    # Decoder: 9 layers, 2 skips, 3 upsampling stages (total 2^3 = the
    # encoder's downsampling factor: stem(1) * stages(2^n)).
    d = params["decoder"]
    y = conv.conv2d_transpose(_maybe_amp(y, cfg), _maybe_amp(d["up1"]["w"], cfg), stride=2)
    if y.shape[1] != mid.shape[1]:
        # Resize by nearest-neighbour to the skip resolution (covers
        # encoder depths > 2).
        fy = mid.shape[1] // y.shape[1]
        y = jnp.repeat(jnp.repeat(y, fy, axis=1), fy, axis=2)
    y = jnp.concatenate([y, mid], axis=-1)
    y = _conv_bn_relu(y, d["skip1"], cfg)
    y = _conv_bn_relu(y, d["c1"], cfg)
    y = _conv_bn_relu(y, d["c2"], cfg)
    y = conv.conv2d_transpose(_maybe_amp(y, cfg), _maybe_amp(d["up2"]["w"], cfg), stride=2)
    if y.shape[1] != stem.shape[1]:
        fy = stem.shape[1] // y.shape[1]
        y = jnp.repeat(jnp.repeat(y, fy, axis=1), fy, axis=2)
    y = jnp.concatenate([y, stem], axis=-1)
    y = _conv_bn_relu(y, d["skip2"], cfg)
    y = _conv_bn_relu(y, d["c3"], cfg)
    y = _conv_bn_relu(y, d["c4"], cfg)
    logits = conv.conv2d(_maybe_amp(y, cfg), _maybe_amp(d["cls"]["w"], cfg))
    return logits.astype(jnp.float32)


# --------------------------------------------------------------------
# Loss + training step
# --------------------------------------------------------------------


def loss_fn(params, x, labels, cfg: DeepCamConfig):
    """Class-weighted softmax cross-entropy over pixels (climate events
    are rare: background dominates, as in DeepCAM)."""
    logits = forward(params, x, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, cfg.classes, dtype=jnp.float32)
    weights = jnp.asarray([0.2, 1.0, 1.0][: cfg.classes], jnp.float32)
    pixel_w = jnp.take(weights, labels)
    ce = -(onehot * logp).sum(-1)
    return (ce * pixel_w).mean()


def sgd_momentum_step(params, momentum, grads, lr=0.02, mu=0.9):
    """The PyTorch-DeepCAM 'optimizer' step (the memory-bound streaming
    phase of Fig. 7): v <- mu v + g ; p <- p - lr v."""
    new_m = jax.tree_util.tree_map(lambda m, g: mu * m + g, momentum, grads)
    new_p = jax.tree_util.tree_map(lambda p, m: p - lr * m, params, new_m)
    return new_p, new_m


def train_step(params, momentum, x, labels, cfg: DeepCamConfig):
    """One full training step: fwd + bwd + update. Returns
    (new_params, new_momentum, loss)."""
    loss, grads = jax.value_and_grad(loss_fn)(params, x, labels, cfg)
    new_p, new_m = sgd_momentum_step(params, momentum, grads)
    return new_p, new_m, loss


def zero_momentum(params):
    return jax.tree_util.tree_map(jnp.zeros_like, params)


def synthetic_batch(cfg: DeepCamConfig, seed: int = 0):
    """Synthetic climate tiles: smooth random fields (data values never
    matter to the paper's analysis; shapes/dtypes do)."""
    key = jax.random.PRNGKey(seed)
    kx, kl = jax.random.split(key)
    x = jax.random.normal(kx, (cfg.batch, cfg.height, cfg.width, cfg.in_channels), jnp.float32)
    # Smooth with a cheap box blur to get weather-ish structure.
    x = (x + jnp.roll(x, 1, 1) + jnp.roll(x, 1, 2) + jnp.roll(x, -1, 1) + jnp.roll(x, -1, 2)) / 5.0
    labels = (jax.random.uniform(kl, (cfg.batch, cfg.height, cfg.width)) * cfg.classes).astype(
        jnp.int32
    )
    return x, labels
