"""Extra L1 kernel coverage: numerical edge cases, determinism, VMEM
block-shape documentation checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import bn, conv, gemm, ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestNumericalEdges:
    def test_gemm_zeros(self):
        z = jnp.zeros((16, 16))
        np.testing.assert_array_equal(gemm.matmul(z, z), z)

    def test_gemm_large_magnitudes_no_overflow(self):
        x = rand(0, (32, 32)) * 1e4
        w = rand(1, (32, 32)) * 1e4
        got = gemm.matmul(x, w)
        want = ref.matmul_ref(x, w)
        np.testing.assert_allclose(got, want, rtol=1e-4)
        assert bool(jnp.all(jnp.isfinite(got)))

    def test_gemm_identity(self):
        x = rand(0, (24, 24))
        eye = jnp.eye(24)
        np.testing.assert_allclose(gemm.matmul(x, eye), x, rtol=1e-6, atol=1e-6)

    def test_bn_constant_channel_stable(self):
        # Zero-variance channel must not produce NaN (eps guards rsqrt).
        x = jnp.ones((1, 4, 4, 2))
        y = bn.batch_norm_relu(x, jnp.ones((2,)), jnp.zeros((2,)))
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_conv_single_pixel(self):
        x = rand(0, (1, 1, 1, 3))
        w = rand(1, (1, 1, 3, 4))
        np.testing.assert_allclose(
            conv.conv2d(x, w), ref.conv2d_ref(x, w), rtol=1e-5, atol=1e-5
        )


class TestDeterminism:
    """The §III-B requirement: profiled executions must be deterministic
    (the paper needed tensorflow-determinism to get this)."""

    def test_gemm_bitwise_deterministic(self):
        x, w = rand(0, (64, 48)), rand(1, (48, 32))
        a = np.asarray(gemm.matmul(x, w))
        b = np.asarray(gemm.matmul(x, w))
        np.testing.assert_array_equal(a, b)

    def test_conv_bitwise_deterministic(self):
        x, w = rand(0, (2, 8, 8, 3)), rand(1, (3, 3, 3, 8))
        a = np.asarray(conv.conv2d(x, w))
        b = np.asarray(conv.conv2d(x, w))
        np.testing.assert_array_equal(a, b)


class TestVmemBudget:
    """DESIGN.md §8: the GEMM BlockSpec working set must fit TPU VMEM
    (16 MiB). We verify the documented footprint formula for the shapes
    the model actually emits."""

    @pytest.mark.parametrize("m,k,n", [(2048, 1152, 64), (8192, 144, 16), (512, 512, 512)])
    def test_footprint_under_budget(self, m, k, n):
        bm = bn_ = 64
        footprint = (bm * k + k * bn_ + bm * bn_) * 4  # f32 bytes
        assert footprint < 16 * 1024 * 1024, f"{footprint} bytes exceeds VMEM"

    def test_conv_flops_helper(self):
        f = conv.conv_flops((2, 16, 16, 8), (3, 3, 8, 4), stride=1)
        assert f == 2 * 2 * 16 * 16 * 9 * 8 * 4
