"""AOT pipeline tests: manifest structure, HLO text sanity, ERT kernel."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ert, ref

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestErtKernel:
    def test_matches_reference(self):
        x = jnp.linspace(0.0, 1.0, 64 * 8).reshape(64, 8).astype(jnp.float32)
        got = ert.ert_fma(x, iters=16)
        want = ref.ert_fma_ref(x, 16)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_flop_accounting(self):
        assert ert.ert_flops((64, 8), 16) == 2 * 16 * 64 * 8

    @settings(max_examples=10, deadline=None)
    @given(rows=st.integers(1, 300), iters=st.sampled_from([1, 4, 32]))
    def test_property_sweep(self, rows, iters):
        x = jnp.ones((rows, 4), jnp.float32) * 0.5
        got = ert.ert_fma(x, iters=iters)
        want = ref.ert_fma_ref(x, iters)
        np.testing.assert_allclose(got, want, rtol=1e-6)


needs_artifacts = pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="run `make artifacts` first",
)


@needs_artifacts
class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_all_modules_present(self, manifest):
        mods = set(manifest["modules"])
        assert {"forward", "train_step", "gemm_128", "gemm_256", "ert_fma"} <= mods

    def test_hlo_files_exist_and_are_text(self, manifest):
        for name, entry in manifest["modules"].items():
            path = os.path.join(ARTIFACTS, entry["hlo_file"])
            assert os.path.exists(path), name
            head = open(path).read(200)
            assert "HloModule" in head, f"{name}: not HLO text"

    def test_train_step_io_arity(self, manifest):
        ts = manifest["modules"]["train_step"]
        n_p = manifest["config"]["n_param_tensors"]
        # inputs: params + momentum + x + labels
        assert len(ts["inputs"]) == 2 * n_p + 2
        # outputs: params + momentum + loss
        assert len(ts["outputs"]) == 2 * n_p + 1
        assert ts["outputs"][-1]["dims"] == []

    def test_input_shapes_match_config(self, manifest):
        cfg = manifest["config"]
        fwd = manifest["modules"]["forward"]
        x_spec = fwd["inputs"][-1]
        assert x_spec["dims"] == [cfg["batch"], cfg["height"], cfg["width"], cfg["in_channels"]]

    def test_gemm_flops_meta(self, manifest):
        g = manifest["modules"]["gemm_128"]
        assert int(g["meta"]["flops_analytic"]) == 2 * 128**3
