"""Conv (im2col + Pallas GEMM), transposed conv, and fused BN+ReLU vs
their jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import bn, conv, ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


class TestConv2d:
    @pytest.mark.parametrize("stride", [1, 2])
    @pytest.mark.parametrize("k", [1, 3])
    def test_stride_kernel_grid(self, stride, k):
        x = rand(0, (2, 11, 13, 3))
        w = rand(1, (k, k, 3, 5))
        got = conv.conv2d(x, w, stride=stride)
        want = ref.conv2d_ref(x, w, stride=stride)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dilation", [1, 2, 4])
    def test_dilation_atrous(self, dilation):
        # The ASPP branches: dilated 3x3 convs.
        x = rand(0, (1, 16, 16, 4))
        w = rand(1, (3, 3, 4, 6))
        got = conv.conv2d(x, w, dilation=dilation)
        want = ref.conv2d_ref(x, w, dilation=dilation)
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    def test_bias(self):
        x = rand(0, (1, 6, 6, 2))
        w = rand(1, (3, 3, 2, 4))
        b = rand(2, (4,))
        np.testing.assert_allclose(
            conv.conv2d(x, w, b), ref.conv2d_ref(x, w, b), rtol=1e-4, atol=1e-4
        )

    def test_channel_mismatch_raises(self):
        with pytest.raises(ValueError):
            conv.conv2d(rand(0, (1, 4, 4, 3)), rand(1, (3, 3, 5, 2)))

    def test_grad_matches_reference(self):
        x = rand(0, (1, 8, 8, 3))
        w = rand(1, (3, 3, 3, 4))

        gp = jax.grad(lambda w: jnp.sum(conv.conv2d(x, w) ** 2))(w)
        gr = jax.grad(lambda w: jnp.sum(ref.conv2d_ref(x, w) ** 2))(w)
        np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-3)


class TestConvTranspose:
    @pytest.mark.parametrize("stride", [1, 2])
    def test_upsampling(self, stride):
        x = rand(0, (1, 5, 5, 4))
        w = rand(1, (3, 3, 4, 2))
        got = conv.conv2d_transpose(x, w, stride=stride)
        want = ref.conv2d_transpose_ref(x, w, stride=stride)
        assert got.shape[1] == 5 * stride
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


class TestBatchNormRelu:
    def test_matches_reference(self):
        x = rand(0, (2, 8, 8, 5))
        gamma, beta = rand(1, (5,)) * 0.1 + 1.0, rand(2, (5,)) * 0.1
        np.testing.assert_allclose(
            bn.batch_norm_relu(x, gamma, beta),
            ref.batch_norm_relu_ref(x, gamma, beta),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_output_nonnegative(self):
        x = rand(0, (1, 4, 4, 3))
        y = bn.batch_norm_relu(x, jnp.ones((3,)), jnp.zeros((3,)))
        assert float(y.min()) >= 0.0

    def test_grad_finite_and_matches(self):
        x = rand(0, (1, 6, 6, 4))
        gamma, beta = jnp.ones((4,)), jnp.zeros((4,))

        gp = jax.grad(lambda x: jnp.sum(bn.batch_norm_relu(x, gamma, beta) ** 2))(x)
        gr = jax.grad(lambda x: jnp.sum(ref.batch_norm_relu_ref(x, gamma, beta) ** 2))(x)
        np.testing.assert_allclose(gp, gr, rtol=1e-3, atol=1e-3)

    def test_scale_shift_relu_kernel_direct(self):
        x2d = rand(0, (100, 7))
        scale = rand(1, (1, 7))
        shift = rand(2, (1, 7))
        np.testing.assert_allclose(
            bn.scale_shift_relu(x2d, scale, shift),
            ref.scale_shift_relu_ref(x2d, scale, shift),
            rtol=1e-5,
            atol=1e-5,
        )


@settings(max_examples=10, deadline=None)
@given(
    h=st.integers(4, 20),
    w=st.integers(4, 20),
    cin=st.integers(1, 6),
    cout=st.integers(1, 6),
    stride=st.sampled_from([1, 2]),
)
def test_conv_property_sweep(h, w, cin, cout, stride):
    x = rand(h * 31 + w, (1, h, w, cin))
    wt = rand(cin * 7 + cout, (3, 3, cin, cout))
    np.testing.assert_allclose(
        conv.conv2d(x, wt, stride=stride),
        ref.conv2d_ref(x, wt, stride=stride),
        rtol=1e-3,
        atol=1e-3,
    )
