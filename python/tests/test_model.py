"""DeepCAM-lite model tests: shapes, loss behaviour, gradient flow, AMP."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def tiny():
    cfg = model.DeepCamConfig.tiny()
    params = model.init_params(cfg, seed=0)
    x, labels = model.synthetic_batch(cfg, seed=0)
    return cfg, params, x, labels


class TestForward:
    def test_logits_shape(self, tiny):
        cfg, params, x, _ = tiny
        logits = model.forward(params, x, cfg)
        assert logits.shape == (cfg.batch, cfg.height, cfg.width, cfg.classes)
        assert logits.dtype == jnp.float32

    def test_forward_finite(self, tiny):
        cfg, params, x, _ = tiny
        assert bool(jnp.all(jnp.isfinite(model.forward(params, x, cfg))))

    def test_deterministic(self, tiny):
        cfg, params, x, _ = tiny
        a = model.forward(params, x, cfg)
        b = model.forward(params, x, cfg)
        np.testing.assert_array_equal(a, b)

    def test_amp_variant_close(self, tiny):
        cfg, params, x, _ = tiny
        import dataclasses
        amp_cfg = dataclasses.replace(cfg, amp=True)
        y32 = model.forward(params, x, cfg)
        y16 = model.forward(params, x, amp_cfg)
        # bf16 mantissa: loose agreement.
        np.testing.assert_allclose(y16, y32, rtol=0.15, atol=0.15)


class TestTraining:
    def test_loss_positive_scalar(self, tiny):
        cfg, params, x, labels = tiny
        loss = model.loss_fn(params, x, labels, cfg)
        assert loss.shape == ()
        assert float(loss) > 0.0

    def test_loss_decreases_over_steps(self, tiny):
        cfg, params, x, labels = tiny
        m = model.zero_momentum(params)
        losses = []
        p = params
        for _ in range(5):
            p, m, loss = model.train_step(p, m, x, labels, cfg)
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses

    def test_grads_nonzero_everywhere(self, tiny):
        cfg, params, x, labels = tiny
        grads = jax.grad(model.loss_fn)(params, x, labels, cfg)
        flat, _ = jax.tree_util.tree_flatten(grads)
        n_zero = sum(int(jnp.all(g == 0)) for g in flat)
        # Every parameter tensor should receive gradient signal.
        assert n_zero == 0, f"{n_zero}/{len(flat)} grads identically zero"

    def test_momentum_accumulates(self, tiny):
        cfg, params, x, labels = tiny
        m = model.zero_momentum(params)
        _, m1, _ = model.train_step(params, m, x, labels, cfg)
        flat, _ = jax.tree_util.tree_flatten(m1)
        assert any(float(jnp.abs(g).max()) > 0 for g in flat)


class TestParams:
    def test_param_count_scales_with_channels(self):
        small = model.init_params(model.DeepCamConfig.tiny(), 0)
        big = model.init_params(
            model.DeepCamConfig.tiny(stem_channels=8, encoder_channels=(8, 16)), 0
        )
        assert model.n_params(big) > model.n_params(small)

    def test_init_deterministic_by_seed(self):
        cfg = model.DeepCamConfig.tiny()
        a = model.init_params(cfg, seed=3)
        b = model.init_params(cfg, seed=3)
        fa, _ = jax.tree_util.tree_flatten(a)
        fb, _ = jax.tree_util.tree_flatten(b)
        for x, y in zip(fa, fb):
            np.testing.assert_array_equal(x, y)

    def test_synthetic_batch_shapes(self):
        cfg = model.DeepCamConfig.tiny()
        x, labels = model.synthetic_batch(cfg, 0)
        assert x.shape == (cfg.batch, cfg.height, cfg.width, cfg.in_channels)
        assert labels.shape == (cfg.batch, cfg.height, cfg.width)
        assert labels.dtype == jnp.int32
        assert int(labels.max()) < cfg.classes
