"""Pallas GEMM kernel vs the pure-jnp oracle — the core L1 correctness
signal, swept over shapes/dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm, ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


class TestMatmulBasic:
    def test_square(self):
        x, w = rand(0, (64, 64)), rand(1, (64, 64))
        np.testing.assert_allclose(gemm.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_rectangular(self):
        x, w = rand(0, (37, 19)), rand(1, (19, 53))
        np.testing.assert_allclose(gemm.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_non_tile_aligned(self):
        # Shapes that force padding in every dimension.
        x, w = rand(0, (65, 77)), rand(1, (77, 129))
        np.testing.assert_allclose(gemm.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_vector_edge(self):
        x, w = rand(0, (1, 8)), rand(1, (8, 1))
        np.testing.assert_allclose(gemm.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_shape_mismatch_raises(self):
        with pytest.raises(ValueError):
            gemm.matmul_nocustom(jnp.ones((4, 5)), jnp.ones((6, 4)))

    def test_block_sizes_dont_change_result(self):
        # Different BlockSpec tilings change XLA fusion shapes and hence
        # float summation micro-order; results agree to normal f32 slack.
        x, w = rand(0, (100, 60)), rand(1, (60, 90))
        a = gemm.matmul_nocustom(x, w, block_m=32, block_n=32)
        b = gemm.matmul_nocustom(x, w, block_m=64, block_n=128)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


class TestMatmulGrad:
    def test_custom_vjp_matches_jnp_grad(self):
        x, w = rand(0, (24, 16)), rand(1, (16, 8))

        def loss_pallas(x, w):
            return jnp.sum(gemm.matmul(x, w) ** 2)

        def loss_ref(x, w):
            return jnp.sum(ref.matmul_ref(x, w) ** 2)

        gx_p, gw_p = jax.grad(loss_pallas, argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(loss_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx_p, gx_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(gw_p, gw_r, rtol=1e-4, atol=1e-4)

    def test_grad_through_chain(self):
        x = rand(0, (8, 8))
        w1, w2 = rand(1, (8, 8)), rand(2, (8, 8))

        def f(w1, w2):
            return jnp.mean(gemm.matmul(gemm.matmul(x, w1), w2))

        g1, g2 = jax.grad(f, argnums=(0, 1))(w1, w2)
        assert np.all(np.isfinite(g1)) and np.all(np.isfinite(g2))


class TestMatmulBf16:
    def test_bf16_close_to_f32(self):
        x, w = rand(0, (32, 32)), rand(1, (32, 32))
        y16 = gemm.matmul_bf16(x, w)
        y32 = ref.matmul_ref(x, w)
        assert y16.dtype == jnp.float32  # fp32 accumulate
        np.testing.assert_allclose(y16, y32, rtol=3e-2, atol=3e-2)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_property_sweep(m, k, n, seed):
    """Hypothesis sweep: arbitrary small shapes match the oracle."""
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    np.testing.assert_allclose(
        gemm.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=8, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]), m=st.integers(8, 40))
def test_matmul_dtype_sweep(dtype, m):
    x = rand(0, (m, m)).astype(dtype)
    w = rand(1, (m, m)).astype(dtype)
    out = gemm.matmul(x, w)
    expect = ref.matmul_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(out, expect, rtol=tol, atol=tol)
