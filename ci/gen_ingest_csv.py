#!/usr/bin/env python3
"""Generate a synthetic Nsight-Compute-style counter CSV for the CI
ingest smoke (`.github/workflows/ci.yml`, job `ingest-smoke`).

Shape: KERNELS distinct kernels x METRICS rows each, repeated REPEATS
times — repeated launches re-state the same per-kernel aggregates, the
way consecutive `--csv` exports of a steady-state training loop do. The
defaults produce 120,000 data rows over 300 unique kernels, so a correct
streaming ingest reports exactly:

    rows            = KERNELS * len(METRICS) * REPEATS   (120000)
    unique_kernels  = KERNELS                            (300)
    dedup ratio     = len(METRICS) * REPEATS             (400.0)
    peak resident accumulators = unique_kernels          (300)

Usage: gen_ingest_csv.py OUT.csv [KERNELS] [REPEATS]
"""

import sys

# The paper's Table II time/FLOP/byte counters plus two fallback-lane
# extras, exercising both CounterSet storage lanes.
METRICS = [
    "sm__cycles_elapsed.avg",
    "sm__cycles_elapsed.avg.per_second",
    "sm__inst_executed_pipe_tensor.sum",
    "l1tex__t_bytes.sum",
    "lts__t_bytes.sum",
    "dram__bytes.sum",
    "smsp__warps_active.avg",
    "launch__occupancy_limit_blocks",
]


def main() -> None:
    out = sys.argv[1] if len(sys.argv) > 1 else "ingest-smoke.csv"
    kernels = int(sys.argv[2]) if len(sys.argv) > 2 else 300
    repeats = int(sys.argv[3]) if len(sys.argv) > 3 else 50
    with open(out, "w", newline="") as f:
        f.write("# device=V100-SXM2-16GB\n")
        f.write('"Kernel Name","Metric Name","Metric Value","Invocations"\n')
        for _ in range(repeats):
            for k in range(kernels):
                # Commas in every name: the quoted-field parser is part
                # of what the smoke exercises. Values and invocations
                # are functions of (kernel, metric) only, so repeats
                # restate identical aggregates (no conflicts).
                name = f"void deepcam_kernel_{k}<float, {k % 7}>(float*, int)"
                inv = 1 + k % 9
                for m, metric in enumerate(METRICS):
                    value = (k + 1) * 1000 + m
                    f.write(f'"{name}","{metric}",{value},{inv}\n')
    rows = kernels * len(METRICS) * repeats
    print(f"wrote {out}: {rows} rows, {kernels} unique kernels")


if __name__ == "__main__":
    main()
