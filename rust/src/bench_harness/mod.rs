//! Benchmark harness substrate (no `criterion` offline). Benches are
//! `harness = false` binaries that build a [`Bench`] set, register
//! closures, and call [`Bench::run`], which prints criterion-style
//! lines:
//!
//! ```text
//! fig3_tf_forward/profile   time: [1.234 ms 1.250 ms 1.271 ms]  n=50
//! ```
//!
//! Timings are wall-clock medians over warmup + measured iterations.
//! Two machine-readable artifacts are written per group:
//!
//! * `out/bench/<group>.json` — the full stats (median/mean/p05/p95),
//!   for the §Perf iteration log in EXPERIMENTS.md;
//! * `BENCH_<group>.json` — the perf-trajectory summary (case name →
//!   `ns_per_iter` and `items_per_sec`), written to the working
//!   directory (override with `HROOFLINE_BENCH_DIR`) so CI can archive
//!   one small file per run and diff regressions across PRs.

pub mod diff;

use crate::util::{fmt, Json, Summary};
use std::time::Instant;

/// One registered benchmark case.
struct Case {
    name: String,
    f: Box<dyn FnMut() -> u64>, // returns a "work units" count for throughput lines (0 = none)
}

/// A named group of benchmark cases with shared iteration policy.
pub struct Bench {
    group: String,
    warmup_iters: u32,
    iters: u32,
    cases: Vec<Case>,
}

/// Result of one case.
#[derive(Clone, Debug)]
pub struct CaseResult {
    pub name: String,
    pub secs: Summary,
    pub work_units: u64,
}

impl Bench {
    /// New bench group. Iteration counts can be overridden by the env
    /// vars `HROOFLINE_BENCH_ITERS` / `HROOFLINE_BENCH_WARMUP` (used by
    /// `make bench` smoke configs).
    pub fn new(group: &str) -> Bench {
        let iters = env_u32("HROOFLINE_BENCH_ITERS", 30);
        let warmup_iters = env_u32("HROOFLINE_BENCH_WARMUP", 3);
        Bench {
            group: group.to_string(),
            warmup_iters,
            iters,
            cases: Vec::new(),
        }
    }

    /// Override the per-case measured iteration count.
    pub fn iters(mut self, n: u32) -> Bench {
        self.iters = env_u32("HROOFLINE_BENCH_ITERS", n);
        self
    }

    /// Register a case. The closure runs once per iteration; its return
    /// value is a work-unit count (e.g. kernels profiled) for throughput
    /// reporting — return 0 if not meaningful.
    pub fn case(&mut self, name: &str, f: impl FnMut() -> u64 + 'static) -> &mut Bench {
        self.cases.push(Case {
            name: name.to_string(),
            f: Box::new(f),
        });
        self
    }

    /// Run all cases, print report lines, persist JSON, return results.
    pub fn run(&mut self) -> Vec<CaseResult> {
        println!("== bench group: {} (iters={}) ==", self.group, self.iters);
        let mut results = Vec::new();
        for case in &mut self.cases {
            for _ in 0..self.warmup_iters {
                let _ = (case.f)();
            }
            let mut times = Vec::with_capacity(self.iters as usize);
            let mut work = 0u64;
            for _ in 0..self.iters {
                let t0 = Instant::now();
                work = (case.f)();
                times.push(t0.elapsed().as_secs_f64());
            }
            let secs = Summary::of(&times);
            let mut line = format!(
                "{}/{:<28} time: [{} {} {}]  n={}",
                self.group,
                case.name,
                fmt::duration(secs.p05),
                fmt::duration(secs.median),
                fmt::duration(secs.p95),
                secs.n,
            );
            if work > 0 {
                let rate = work as f64 / secs.median;
                line.push_str(&format!("  thrpt: {}", fmt::si(rate, "elem/s")));
            }
            println!("{line}");
            results.push(CaseResult {
                name: case.name.clone(),
                secs,
                work_units: work,
            });
        }
        self.persist(&results);
        results
    }

    fn persist(&self, results: &[CaseResult]) {
        let doc = Json::obj(vec![
            ("group", Json::str(&self.group)),
            ("iters", Json::num(self.iters as f64)),
            (
                "cases",
                Json::arr(results.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(&r.name)),
                        ("median_s", Json::num(r.secs.median)),
                        ("mean_s", Json::num(r.secs.mean)),
                        ("p05_s", Json::num(r.secs.p05)),
                        ("p95_s", Json::num(r.secs.p95)),
                        ("work_units", Json::num(r.work_units as f64)),
                    ])
                })),
            ),
        ]);
        let dir = std::path::Path::new("out/bench");
        if std::fs::create_dir_all(dir).is_ok() {
            let path = dir.join(format!("{}.json", self.group));
            let _ = std::fs::write(path, doc.to_string_pretty());
        }

        // Perf-trajectory summary: BENCH_<group>.json, flat and stable
        // so successive runs diff cleanly (case → ns/iter + items/sec).
        let summary = Json::Obj(
            [
                ("schema".to_string(), Json::str("hroofline-bench-v1")),
                ("group".to_string(), Json::str(&self.group)),
                ("iters".to_string(), Json::num(self.iters as f64)),
                (
                    "cases".to_string(),
                    Json::Obj(
                        results
                            .iter()
                            .map(|r| {
                                let items_per_sec = if r.secs.median > 0.0 {
                                    r.work_units as f64 / r.secs.median
                                } else {
                                    0.0
                                };
                                (
                                    r.name.clone(),
                                    Json::obj(vec![
                                        ("ns_per_iter", Json::num(r.secs.median * 1e9)),
                                        ("items_per_sec", Json::num(items_per_sec)),
                                    ]),
                                )
                            })
                            .collect(),
                    ),
                ),
            ]
            .into_iter()
            .collect(),
        );
        let bench_dir = std::env::var("HROOFLINE_BENCH_DIR").unwrap_or_else(|_| ".".into());
        let path = std::path::Path::new(&bench_dir).join(format!("BENCH_{}.json", self.group));
        let _ = std::fs::write(path, summary.to_string_pretty());
    }
}

fn env_u32(name: &str, default: u32) -> u32 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Prevent the optimizer from discarding a computed value (stable-Rust
/// black_box replacement good enough for our coarse-grained benches).
pub fn black_box<T>(x: T) -> T {
    // std::hint::black_box is stable since 1.66; use it directly.
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let dir = std::env::temp_dir().join(format!("hroofline-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::env::set_var("HROOFLINE_BENCH_ITERS", "5");
        std::env::set_var("HROOFLINE_BENCH_WARMUP", "1");
        std::env::set_var("HROOFLINE_BENCH_DIR", &dir);
        let mut b = Bench::new("selftest");
        b.case("spin", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(black_box(i));
            }
            black_box(acc);
            1000
        });
        let results = b.run();
        std::env::remove_var("HROOFLINE_BENCH_ITERS");
        std::env::remove_var("HROOFLINE_BENCH_WARMUP");
        std::env::remove_var("HROOFLINE_BENCH_DIR");
        assert_eq!(results.len(), 1);
        assert_eq!(results[0].secs.n, 5);
        assert!(results[0].secs.median >= 0.0);
        assert_eq!(results[0].work_units, 1000);

        // The perf-trajectory summary is valid JSON with the promised
        // shape: case name → {ns_per_iter, items_per_sec}.
        let text = std::fs::read_to_string(dir.join("BENCH_selftest.json")).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(doc.get("group").unwrap().as_str().unwrap(), "selftest");
        let spin = doc.get("cases").unwrap().get("spin").unwrap();
        assert!(spin.get("ns_per_iter").unwrap().as_f64().unwrap() >= 0.0);
        assert!(spin.get("items_per_sec").unwrap().as_f64().unwrap() >= 0.0);
        let _ = std::fs::remove_dir_all(dir);
    }
}
