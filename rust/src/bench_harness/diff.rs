//! Bench-trajectory comparator: diff two `BENCH_<group>.json`
//! summaries (schema `hroofline-bench-v1`, written by
//! [`crate::bench_harness::Bench::run`]) and flag per-case `ns_per_iter`
//! regressions beyond a threshold.
//!
//! CI commits a baseline under `ci/` and runs `repro bench-diff`
//! against the fresh quick-mode run on every PR: any case regressing
//! past the threshold fails the job. Cases present on only one side
//! are reported but never fail (benches come and go across PRs).

use crate::util::error::{ensure, Context, Result};
use crate::util::table::Align;
use crate::util::{fmt, Json, Table};

/// One case present in both summaries.
#[derive(Clone, Debug)]
pub struct CaseDiff {
    pub name: String,
    pub base_ns: f64,
    pub fresh_ns: f64,
}

impl CaseDiff {
    /// fresh/baseline time ratio (> 1 is slower than baseline).
    pub fn ratio(&self) -> f64 {
        self.fresh_ns / self.base_ns
    }
}

/// The full comparison.
#[derive(Clone, Debug)]
pub struct DiffReport {
    pub group: String,
    pub compared: Vec<CaseDiff>,
    /// Cases only in the fresh run (new benches).
    pub added: Vec<String>,
    /// Cases only in the baseline (removed benches).
    pub removed: Vec<String>,
    /// Allowed fractional slowdown (0.25 = +25% ns/iter).
    pub max_regress: f64,
}

impl DiffReport {
    /// Cases slower than `baseline * (1 + max_regress)`.
    pub fn regressions(&self) -> Vec<&CaseDiff> {
        self.compared.iter().filter(|c| c.ratio() > 1.0 + self.max_regress).collect()
    }

    /// Text rendering: one row per compared case plus added/removed
    /// footnotes.
    pub fn render(&self) -> String {
        let mut t = Table::new(&["case", "baseline", "fresh", "ratio", "verdict"]).aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Left,
        ]);
        for c in &self.compared {
            let verdict = if c.ratio() > 1.0 + self.max_regress {
                "REGRESSED"
            } else if c.ratio() < 1.0 {
                "improved"
            } else {
                "ok"
            };
            t.row(&[
                c.name.clone(),
                fmt::duration(c.base_ns * 1e-9),
                fmt::duration(c.fresh_ns * 1e-9),
                format!("{:.3}", c.ratio()),
                verdict.to_string(),
            ]);
        }
        let mut out = format!(
            "bench group '{}' vs baseline (threshold +{:.0}%):\n{}",
            self.group,
            self.max_regress * 100.0,
            t.render()
        );
        if !self.added.is_empty() {
            out.push_str(&format!("new cases (no baseline): {}\n", self.added.join(", ")));
        }
        if !self.removed.is_empty() {
            out.push_str(&format!("removed cases (baseline only): {}\n", self.removed.join(", ")));
        }
        out
    }
}

/// Compare a fresh bench summary against a baseline. Cases are matched
/// by name; baseline entries with non-positive `ns_per_iter` are
/// skipped (placeholder rows). Errors on schema/shape mismatches, never
/// on perf — regression policy is the caller's call via
/// [`DiffReport::regressions`].
pub fn diff(baseline: &Json, fresh: &Json, max_regress: f64) -> Result<DiffReport> {
    for (doc, which) in [(baseline, "baseline"), (fresh, "fresh")] {
        let schema = doc
            .get("schema")
            .with_context(|| format!("{which}: missing schema"))?
            .as_str()?;
        ensure!(
            schema == "hroofline-bench-v1",
            "{which}: unsupported bench schema '{schema}' (want hroofline-bench-v1)"
        );
    }
    let group = baseline.get("group")?.as_str()?.to_string();
    let base_cases = baseline.get("cases")?.as_obj()?;
    let fresh_cases = fresh.get("cases")?.as_obj()?;

    let mut compared = Vec::new();
    let mut removed = Vec::new();
    for (name, base) in base_cases {
        let base_ns = base
            .get("ns_per_iter")
            .with_context(|| format!("baseline case '{name}'"))?
            .as_f64()?;
        match fresh_cases.get(name) {
            None => removed.push(name.clone()),
            Some(_) if base_ns <= 0.0 => {} // placeholder baseline row
            Some(f) => {
                let fresh_ns = f
                    .get("ns_per_iter")
                    .with_context(|| format!("fresh case '{name}'"))?
                    .as_f64()?;
                compared.push(CaseDiff { name: name.clone(), base_ns, fresh_ns });
            }
        }
    }
    let added = fresh_cases.keys().filter(|k| !base_cases.contains_key(*k)).cloned().collect();
    Ok(DiffReport { group, compared, added, removed, max_regress })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn summary(cases: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("schema", Json::str("hroofline-bench-v1")),
            ("group", Json::str("hotpath")),
            ("iters", Json::num(3.0)),
            (
                "cases",
                Json::Obj(
                    cases
                        .iter()
                        .map(|(name, ns)| {
                            let case = Json::obj(vec![
                                ("ns_per_iter", Json::num(*ns)),
                                ("items_per_sec", Json::num(0.0)),
                            ]);
                            (name.to_string(), case)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    #[test]
    fn within_threshold_passes() {
        let base = summary(&[("a", 1000.0), ("b", 2000.0)]);
        let fresh = summary(&[("a", 1200.0), ("b", 1500.0)]);
        let report = diff(&base, &fresh, 0.25).unwrap();
        assert_eq!(report.compared.len(), 2);
        assert!(report.regressions().is_empty());
        assert!(report.render().contains("improved"), "{}", report.render());
    }

    #[test]
    fn regression_beyond_threshold_flagged() {
        let base = summary(&[("a", 1000.0), ("b", 2000.0)]);
        let fresh = summary(&[("a", 1251.0), ("b", 2000.0)]);
        let report = diff(&base, &fresh, 0.25).unwrap();
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "a");
        assert!(report.render().contains("REGRESSED"));
    }

    #[test]
    fn added_and_removed_cases_reported_not_failed() {
        let base = summary(&[("a", 1000.0), ("gone", 500.0)]);
        let fresh = summary(&[("a", 1000.0), ("new", 700.0)]);
        let report = diff(&base, &fresh, 0.25).unwrap();
        assert_eq!(report.added, vec!["new".to_string()]);
        assert_eq!(report.removed, vec!["gone".to_string()]);
        assert!(report.regressions().is_empty());
    }

    #[test]
    fn placeholder_baseline_rows_skipped() {
        let base = summary(&[("a", 0.0)]);
        let fresh = summary(&[("a", 99999.0)]);
        let report = diff(&base, &fresh, 0.25).unwrap();
        assert!(report.compared.is_empty());
        assert!(report.regressions().is_empty());
    }

    #[test]
    fn schema_mismatch_rejected() {
        let mut bad = summary(&[("a", 1.0)]);
        if let Json::Obj(m) = &mut bad {
            m.insert("schema".into(), Json::str("v0"));
        }
        let good = summary(&[("a", 1.0)]);
        assert!(diff(&bad, &good, 0.25).is_err());
        assert!(diff(&good, &Json::Null, 0.25).is_err());
    }
}
