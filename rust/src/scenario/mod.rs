//! Scenario-matrix subsystem: cross the workload registry with
//! framework personalities, training phases and AMP policies, profile
//! every cell, and compare the results on one hierarchical Roofline.
//!
//! The paper's figures are hand-picked cells of exactly this matrix
//! (Figs 3–9 are DeepCAM × {TF, PyTorch} × {forward, backward,
//! optimizer} × {O0, O1, manual-fp16}); this module makes the whole
//! cross product a first-class sweep:
//!
//! * [`ScenarioMatrix`] enumerates a deterministic, duplicate-free
//!   scenario list (workload-major order) across a **device axis**
//!   ([`crate::device::registry`]) as well as the workload, framework,
//!   phase and AMP axes — the quick matrix stays single-device (the
//!   registry default V100) so the CI gate's cost is flat, while the
//!   full matrix crosses every registered device;
//! * [`ScenarioMatrix::run`] builds each workload graph once, lowers
//!   each (workload, device, framework, policy) combination once, then
//!   fans per-scenario profiling through the supervised
//!   [`crate::exec::parallel_try_map`] with one [`SharedSimCache`]
//!   *per device* — duplicate kernels *across* scenarios simulate once
//!   for the whole sweep, and a cell that panics / times out / errors
//!   degrades into a structured [`CellFailure`] instead of aborting
//!   its siblings ([`ScenarioMatrix::run_with`] exposes the
//!   supervision policy and deterministic fault injection;
//!   [`errors_manifest`] is the `matrix.errors.json` payload);
//! * [`ScenarioResult`] exposes per-scenario hierarchical Roofline
//!   data for every [`MemLevel`] and renders per-scenario artifacts
//!   (kernel-table text, summary JSON, paper-style SVG, Nsight-style
//!   counter CSV);
//! * [`comparison_artifact`] renders the cross-scenario report: a
//!   summary table plus one combined Roofline chart overlaying every
//!   scenario as a labelled aggregate point
//!   ([`RooflineChart::overlay`]); multi-device runs additionally get
//!   the cross-device pivot table and merged per-device ceilings, and
//!   [`device_comparison_artifact`] renders one overlay per device;
//! * the matrix is **incremental** ([`store`]): every cell has a
//!   content-addressed [`Scenario::cell_key`] over (lowered trace ×
//!   [`GpuSpec`] × AMP policy × workload spec × store format), and
//!   [`MatrixRunOptions::incremental`] serves clean cells from the
//!   on-disk [`store::CellStore`] with zero simulations while dirty
//!   cells re-run and are written back; [`MatrixRunOptions::shard`]
//!   deterministically partitions the cell list across N processes and
//!   merge runs union shard stores back into the single artifact set.
//!   Store traffic is instrumented by [`CacheStats`] and surfaced via
//!   [`cache_manifest`] (`matrix.cache.json`) — deliberately *outside*
//!   the comparison artifact, which stays byte-identical across cold,
//!   warm, sharded and merged runs.
//!
//! `repro matrix` is the CLI front-end; its `--quick` mode doubles as
//! the CI smoke for the whole stack.

use std::collections::{BTreeSet, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};

pub mod store;

use crate::cli::CliError;
use crate::device::registry::{self as devices, DeviceEntry};
use crate::device::{GpuSpec, MemLevel};
use crate::dl::lower::{lower, Framework, FrameworkTrace, Phase};
use crate::dl::workloads::{self, Scale, WorkloadSpec};
use crate::dl::{Graph, Policy};
use crate::profiler::{export, Profile, ProfileRequest, Session, SessionConfig, StepTimeline};
use crate::report::Artifact;
use crate::roofline::chart::RooflineChart;
use crate::roofline::model::{Ceilings, KernelPoint, RooflineModel};
use crate::roofline::time as rtime;
use crate::sim::kernel::KernelInvocation;
use crate::sim::SharedSimCache;
use crate::util::digest::StableHasher;
use crate::util::table::Align;
use crate::util::{fmt, Json, Table};

/// One cell of the matrix.
#[derive(Clone, Copy, Debug)]
pub struct Scenario {
    pub workload: &'static WorkloadSpec,
    pub device: &'static DeviceEntry,
    pub framework: Framework,
    pub phase: Phase,
    pub policy: Policy,
    pub scale: Scale,
}

impl Scenario {
    /// The device-less id stem shared by the same cell on every device:
    /// `resnet-pt-forward-O1` (the cross-device pivot key).
    pub fn base_id(&self) -> String {
        format!(
            "{}-{}-{}-{}",
            self.workload.name,
            self.framework.short(),
            self.phase.name(),
            self.policy.name()
        )
    }

    /// Stable id, safe as a file stem. On the default device this is
    /// the historical `resnet-pt-forward-O1` form (golden catalogs and
    /// CI artifact layouts are pinned to it); other devices append
    /// their short tag: `resnet-pt-forward-O1@a100`.
    pub fn id(&self) -> String {
        if self.device.name == devices::default_entry().name {
            self.base_id()
        } else {
            format!("{}@{}", self.base_id(), self.device.short)
        }
    }

    /// The content-address of this cell: a process-stable digest over
    /// *everything its profile is a function of* — the store format
    /// version ([`store::CELL_SCHEMA`]), the workload spec (name +
    /// scale; the graph is a pure function of those, and any structural
    /// change shows up in the trace anyway), framework, phase, AMP
    /// policy, every field of the device spec, and the full lowered
    /// kernel trace (every descriptor field, invocation count and
    /// stream). Equal keys therefore mean bit-identical profiles, which
    /// is what lets [`MatrixRunOptions::incremental`] serve a hit with
    /// zero simulations and byte-identical artifacts.
    pub fn cell_key(&self, trace: &[KernelInvocation], spec: &GpuSpec) -> store::CellKey {
        let mut h = StableHasher::new();
        h.write_str(store::CELL_SCHEMA);
        h.write_str(self.workload.name);
        h.write_str(self.scale.name());
        h.write_str(self.framework.short());
        h.write_str(self.phase.name());
        h.write_str(self.policy.name());
        spec.digest_into(&mut h);
        h.write_u64(trace.len() as u64);
        for inv in trace {
            inv.kernel.digest_into(&mut h);
            h.write_u64(inv.invocations);
            h.write_u32(inv.stream);
        }
        store::CellKey::new(h.finish_hex())
    }

    /// Human title for charts and report headers.
    pub fn title(&self) -> String {
        format!(
            "{} · {} {} (AMP {}) on {}",
            self.workload.name,
            self.framework.name(),
            self.phase.name(),
            self.policy.name(),
            self.device.display,
        )
    }
}

/// The sweep specification: the axes to cross.
#[derive(Debug)]
pub struct ScenarioMatrix {
    pub workloads: Vec<&'static WorkloadSpec>,
    pub devices: Vec<&'static DeviceEntry>,
    pub frameworks: Vec<Framework>,
    pub phases: Vec<Phase>,
    pub policies: Vec<Policy>,
    pub scale: Scale,
}

impl ScenarioMatrix {
    /// The full sweep: every workload × **every registered device** ×
    /// both frameworks × all three phases × {O0, O1, O2}, at
    /// paper-style scale.
    pub fn full() -> ScenarioMatrix {
        ScenarioMatrix {
            workloads: workloads::registry().iter().collect(),
            devices: devices::entries().iter().collect(),
            frameworks: Framework::ALL.to_vec(),
            phases: Phase::ALL.to_vec(),
            policies: vec![Policy::O0, Policy::O1, Policy::O2],
            scale: Scale::Full,
        }
    }

    /// The CI smoke sweep: every workload at quick scale, forward +
    /// backward, {O0, O1} — 32 scenarios covering the whole stack.
    /// Deliberately single-device (the registry default V100) so the
    /// required CI gate's cost stays flat as devices are added.
    pub fn quick() -> ScenarioMatrix {
        ScenarioMatrix {
            workloads: workloads::registry().iter().collect(),
            devices: vec![devices::default_entry()],
            frameworks: Framework::ALL.to_vec(),
            phases: vec![Phase::Forward, Phase::Backward],
            policies: vec![Policy::O0, Policy::O1],
            scale: Scale::Quick,
        }
    }

    /// Restrict the workload axis to a comma-separated name list
    /// (`"all"` keeps the registry order); unknown names are a clean
    /// [`CliError`] with a did-you-mean hint.
    pub fn with_workloads(mut self, list: &str) -> Result<ScenarioMatrix, CliError> {
        if list == "all" {
            return Ok(self);
        }
        let mut selected: Vec<&'static WorkloadSpec> = Vec::new();
        for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
            let w = workloads::lookup(name)?;
            if !selected.iter().any(|s| s.name == w.name) {
                selected.push(w);
            }
        }
        if selected.is_empty() {
            return Err(CliError("--workloads selected nothing (try --help)".into()));
        }
        self.workloads = selected;
        Ok(self)
    }

    /// Restrict the device axis via the unified `--device` list syntax
    /// ([`crate::cli::parse_device_list`]: comma lists, `all`,
    /// `default`); unknown names are a clean [`CliError`] with the
    /// registry's did-you-mean hint.
    pub fn with_devices(mut self, list: &str) -> Result<ScenarioMatrix, CliError> {
        self.devices = crate::cli::parse_device_list(list)?;
        Ok(self)
    }

    /// Flatten the axes into a scenario list: workload-major, then
    /// device, framework, phase, policy. Deterministic (same spec →
    /// same order) and duplicate-free (repeated axis values collapse).
    pub fn enumerate(&self) -> Vec<Scenario> {
        let mut out = Vec::new();
        let mut seen = BTreeSet::new();
        for &workload in &self.workloads {
            for &device in &self.devices {
                for &framework in &self.frameworks {
                    for &phase in &self.phases {
                        for &policy in &self.policies {
                            let sc = Scenario {
                                workload,
                                device,
                                framework,
                                phase,
                                policy,
                                scale: self.scale,
                            };
                            if seen.insert(sc.id()) {
                                out.push(sc);
                            }
                        }
                    }
                }
            }
        }
        out
    }

    /// The scenario catalog as a text table (golden-tested; timing-free
    /// so it is stable across cost-model changes).
    pub fn catalog_table(&self) -> Table {
        let mut t = Table::new(&[
            "scenario", "workload", "device", "framework", "phase", "amp", "scale",
        ]);
        for sc in self.enumerate() {
            t.row(&[
                sc.id(),
                sc.workload.name.to_string(),
                sc.device.name.to_string(),
                sc.framework.name().to_string(),
                sc.phase.name().to_string(),
                sc.policy.name().to_string(),
                sc.scale.name().to_string(),
            ]);
        }
        t
    }

    /// Run the sweep:
    ///
    /// 1. build each workload graph once (parallel across workloads;
    ///    graphs are device-independent);
    /// 2. lower each (workload, device, framework, policy) combination
    ///    once — the three phases of a combination share one lowering,
    ///    and lowering is device-aware (tile selection, HMMA width);
    /// 3. profile every scenario through [`Session::run`] with a
    ///    [`ProfileRequest`] carrying one [`SharedSimCache`] *per
    ///    device* (the cache is keyed by descriptor, so each device
    ///    needs its own), fanned out with the supervised
    ///    [`crate::exec::parallel_try_map`] (results in enumeration
    ///    order).
    ///
    /// Equivalent to [`ScenarioMatrix::run_with`] with default options:
    /// no fault injection, no retries, no failure budget. A default
    /// supervised run over healthy cells produces byte-identical
    /// artifacts to the historical unsupervised pipeline
    /// (test-asserted).
    pub fn run(&self) -> MatrixRun {
        self.run_with(&MatrixRunOptions::default())
    }

    /// [`ScenarioMatrix::run`] with explicit supervision options: a
    /// [`crate::exec::SupervisePolicy`] (retries, soft deadline,
    /// fail-fast budget) and an optional deterministic
    /// [`crate::exec::FaultInjector`].
    ///
    /// Cells degrade gracefully: a cell that panics, times out, or
    /// errors becomes a [`CellFailure`] in [`MatrixRun::failures`]
    /// while every other cell keeps profiling. Cell labels for fault
    /// targeting are `cell#<index>:<scenario-id>`; the injector is
    /// also threaded into each cell's session, where kernels apply it
    /// under `kernel:<name>` labels.
    ///
    /// Panic isolation across cells is sound because the shared
    /// per-device [`SharedSimCache`] simulates *outside* its lock — an
    /// unwinding cell never poisons state its siblings need.
    ///
    /// When [`MatrixRunOptions::span`] / [`MatrixRunOptions::metrics`]
    /// are set, the run additionally emits one `cell` child span per
    /// attempted cell (fields: `label` = `cell#<index>:<id>`,
    /// `attempt`, and the `outcome` — `replayed` / `ran` / `failed`)
    /// with `store.load` / `store.save` children around store traffic,
    /// and counts the [`crate::obs::metrics`] catalog into a run-local
    /// registry merged into the sink afterwards. Telemetry is strictly
    /// additive: profiles and artifacts are byte-identical with or
    /// without it (test-asserted).
    pub fn run_with(&self, options: &MatrixRunOptions<'_>) -> MatrixRun {
        let prep = {
            let _prep_span = child_span(options.span, "prepare");
            self.prepare()
        };

        let caches: Vec<SharedSimCache> =
            self.devices.iter().map(|_| SharedSimCache::new()).collect();
        // Shard selection partitions on the *global* enumeration index,
        // so the union over shards 0..N of `--shard i/N` runs is exactly
        // the unsharded cell list (test-asserted). Fault labels keep the
        // global index too, so a fault plan targets the same cell no
        // matter how the matrix is sharded.
        let cells: Vec<(usize, Scenario)> = prep
            .scenarios
            .iter()
            .copied()
            .enumerate()
            .filter(|&(i, _)| match options.shard {
                Some(s) => s.owns(i),
                None => true,
            })
            .collect();
        let prof_workers = crate::exec::default_workers(cells.len());
        // Split the worker budget between the two fan-out levels: the
        // outer scenario map already uses up to `prof_workers` cores,
        // so each session gets the remaining share (1 when the sweep
        // alone saturates the machine) instead of spawning its own
        // machine-sized pools per scenario. Thread count cannot change
        // the profile (bit-identity is test-asserted by the session).
        let inner_threads =
            (crate::exec::default_workers(usize::MAX) / prof_workers.max(1)).max(1);
        // The cell-level retry budget also applies inside each session,
        // so a transient per-kernel fault is retried at the kernel
        // grain instead of re-profiling the whole cell.
        let session_cfg = SessionConfig {
            threads: Some(inner_threads),
            retry: options.policy.retry,
            ..Default::default()
        };
        let sessions: Vec<Session> =
            prep.specs.iter().map(|spec| Session::new(spec, session_cfg.clone())).collect();

        // Run-local telemetry: counters accumulate here (so parallel
        // callers never cross-pollinate a shared registry) and merge
        // into `options.metrics` after the sweep. CacheStats is derived
        // from this registry — the single source of truth.
        let local = crate::obs::MetricsRegistry::new();
        // Per-cell attempt counters (keyed by global enumeration index),
        // so a retried cell's spans are tellable apart.
        let attempt_counts: HashMap<usize, AtomicU64> =
            cells.iter().map(|&(i, _)| (i, AtomicU64::new(0))).collect();
        let outcomes = crate::exec::parallel_try_map_observed(
            cells.clone(),
            prof_workers,
            &options.policy,
            Some(&local),
            |&(index, sc)| {
                let mut cell_span = child_span(options.span, "cell");
                cell_span.set("label", format!("cell#{index}:{}", sc.id()));
                let attempt = attempt_counts[&index].fetch_add(1, Ordering::Relaxed) + 1;
                cell_span.set("attempt", attempt.to_string());
                if let Some(inj) = options.fault {
                    if let Err(e) = inj.apply(&format!("cell#{index}:{}", sc.id())) {
                        cell_span.set("outcome", "failed");
                        return Err(e);
                    }
                }
                let di = prep.didx[sc.device.name];
                let trace = prep.trace_for(&sc);
                // Fault-armed runs bypass the store entirely (no reads,
                // no writes): a profile built under injection must never
                // be served to — or persisted for — a clean run.
                let store_key = if options.fault.is_none()
                    && (options.incremental || options.merge_only)
                {
                    options.store.map(|st| (st, sc.cell_key(trace, &prep.specs[di])))
                } else {
                    None
                };
                if let Some((st, key)) = &store_key {
                    let lookup = {
                        let _load_span = cell_span.child("store.load");
                        st.load(key)
                    };
                    match lookup {
                        store::Lookup::Hit(profile) => {
                            local.add("store.hits", 1);
                            local.add("matrix.cells.replayed", 1);
                            cell_span.set("outcome", "replayed");
                            return Ok(profile);
                        }
                        // A corrupt entry is a miss that also counts as
                        // an eviction — the re-run overwrites it below.
                        store::Lookup::Corrupt => {
                            local.add("store.evictions", 1);
                            local.add("store.misses", 1);
                        }
                        store::Lookup::Miss => {
                            local.add("store.misses", 1);
                        }
                    }
                    if options.merge_only {
                        // A merge run has no simulation budget: every
                        // cell must come out of the shard-store union.
                        cell_span.set("outcome", "failed");
                        return Err(crate::exec::TaskError::fatal(format!(
                            "cell {} missing from the merged store union",
                            sc.id()
                        )));
                    }
                }
                let mut req = ProfileRequest::new(trace)
                    .shared_cache(&caches[di])
                    .with_span(&cell_span)
                    .with_metrics(&local);
                if let Some(inj) = options.fault {
                    req = req.fault_injector(inj);
                }
                // Session-level errors already exhausted the kernel-
                // grain retry budget — at the cell grain they are final.
                let profile = match sessions[di].run(&req) {
                    Ok(p) => p,
                    Err(e) => {
                        cell_span.set("outcome", "failed");
                        return Err(crate::exec::TaskError::fatal(e.to_string()));
                    }
                };
                local.add("matrix.cells.ran", 1);
                if let Some((st, key)) = &store_key {
                    let mut save_span = cell_span.child("store.save");
                    // Best-effort write-back: a full disk degrades the
                    // store to pass-through, never the run to a failure.
                    match st.save(key, &sc.id(), &profile) {
                        Ok(bytes) => {
                            local.add("store.bytes_written", bytes);
                            save_span.set("bytes", bytes.to_string());
                        }
                        Err(e) => crate::obs::log::warn(format!(
                            "warning: cell store write failed for {}: {e:#}",
                            sc.id()
                        )),
                    }
                    drop(save_span);
                }
                cell_span.set("outcome", "ran");
                Ok(profile)
            },
        );

        let mut results = Vec::with_capacity(cells.len());
        let mut failures = Vec::new();
        for ((index, (_, scenario)), outcome) in cells.into_iter().enumerate().zip(outcomes) {
            match outcome {
                Ok(profile) => results.push(ScenarioResult { scenario, profile }),
                Err(error) => failures.push(CellFailure { index, scenario, error }),
            }
        }
        let sim_stats = caches.iter().fold((0, 0), |(h, s), c| {
            let (hits, sims) = c.stats();
            (h + hits, s + sims)
        });
        if !failures.is_empty() {
            local.add("matrix.cells.failed", failures.len() as u64);
        }
        let metrics = local.snapshot();
        let cache_stats = CacheStats {
            hits: metrics.counter("store.hits"),
            misses: metrics.counter("store.misses"),
            evictions: metrics.counter("store.evictions"),
        };
        if let Some(sink) = options.metrics {
            local.merge_into(sink);
        }
        MatrixRun { results, failures, sim_stats, cache_stats, metrics }
    }

    /// The content-address of every enumerated cell, in enumeration
    /// order, paired with its scenario id. Builds graphs and lowers
    /// traces (keys cover the lowered kernels) but simulates nothing.
    /// `repro matrix --print-keys` exposes this, which is how the
    /// integration tests pin key stability **across processes**.
    pub fn cell_keys(&self) -> Vec<(store::CellKey, String)> {
        let prep = self.prepare();
        prep.scenarios
            .iter()
            .map(|sc| (sc.cell_key(prep.trace_for(sc), prep.spec_for(sc)), sc.id()))
            .collect()
    }

    /// Steps 1 and 2 of the sweep (graph builds + lowering), shared by
    /// [`ScenarioMatrix::run_with`] and [`ScenarioMatrix::cell_keys`].
    fn prepare(&self) -> Prepared {
        let scenarios = self.enumerate();
        let widx: HashMap<&'static str, usize> =
            self.workloads.iter().enumerate().map(|(i, w)| (w.name, i)).collect();
        let didx: HashMap<&'static str, usize> =
            self.devices.iter().enumerate().map(|(i, d)| (d.name, i)).collect();
        let build_workers = crate::exec::default_workers(self.workloads.len());
        let graphs: Vec<Graph> =
            crate::exec::parallel_map(self.workloads.clone(), build_workers, |w| {
                w.build(self.scale)
            });
        let specs: Vec<GpuSpec> = self.devices.iter().map(|d| d.spec()).collect();

        let mut combo_of: HashMap<(usize, usize, Framework, Policy), usize> = HashMap::new();
        let mut combos: Vec<(usize, usize, Framework, Policy)> = Vec::new();
        for sc in &scenarios {
            let key = (widx[sc.workload.name], didx[sc.device.name], sc.framework, sc.policy);
            if !combo_of.contains_key(&key) {
                combo_of.insert(key, combos.len());
                combos.push(key);
            }
        }
        let lower_workers = crate::exec::default_workers(combos.len());
        let traces: Vec<FrameworkTrace> =
            crate::exec::parallel_map(combos, lower_workers, |(wi, di, fw, policy)| {
                lower(&graphs[wi], fw, policy, &specs[di])
            });
        Prepared { scenarios, specs, widx, didx, combo_of, traces }
    }
}

/// The prepared (built + lowered, not yet simulated) sweep state.
struct Prepared {
    scenarios: Vec<Scenario>,
    specs: Vec<GpuSpec>,
    widx: HashMap<&'static str, usize>,
    didx: HashMap<&'static str, usize>,
    combo_of: HashMap<(usize, usize, Framework, Policy), usize>,
    traces: Vec<FrameworkTrace>,
}

impl Prepared {
    fn trace_for(&self, sc: &Scenario) -> &[KernelInvocation] {
        let key = (
            self.widx[sc.workload.name],
            self.didx[sc.device.name],
            sc.framework,
            sc.policy,
        );
        self.traces[self.combo_of[&key]].phase(sc.phase)
    }

    fn spec_for(&self, sc: &Scenario) -> &GpuSpec {
        &self.specs[self.didx[sc.device.name]]
    }
}

/// A deterministic 1-of-N partition of the enumerated cell list
/// (`--shard i/N`): shard `index` owns every cell whose **global**
/// enumeration index is congruent to `index` mod `count`. Round-robin
/// (rather than contiguous ranges) keeps shard wall-times balanced even
/// though cost varies along the enumeration order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Shard {
    /// 0-based shard index, `< count`.
    pub index: usize,
    /// Total number of shards, ≥ 1.
    pub count: usize,
}

impl Shard {
    pub fn owns(&self, cell_index: usize) -> bool {
        self.count != 0 && cell_index % self.count == self.index
    }
}

/// Cell-store traffic counters for one matrix run, surfaced through
/// [`cache_manifest`] (`matrix.cache.json`). A fully warm incremental
/// run reports `misses == 0 && evictions == 0` — together with zero
/// simulations in [`MatrixRun::sim_stats`], the CI warm-store gate.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Cells served from the store without profiling.
    pub hits: u64,
    /// Cells that had to profile (absent or corrupt entries).
    pub misses: u64,
    /// Corrupt or version-mismatched entries discarded (each eviction
    /// is also counted as a miss).
    pub evictions: u64,
}

/// Supervision options for [`ScenarioMatrix::run_with`]. The default
/// is the historical behaviour: every cell runs, nothing is injected,
/// failures are still isolated per cell.
#[derive(Clone, Copy, Default)]
pub struct MatrixRunOptions<'a> {
    pub policy: crate::exec::SupervisePolicy,
    pub fault: Option<&'a crate::exec::FaultInjector>,
    /// The cell store probed (and, for incremental runs, filled) by
    /// this run. Ignored unless `incremental` or `merge_only` is set.
    pub store: Option<&'a store::CellStore>,
    /// `--incremental`: serve clean cells from the store (zero
    /// simulations, byte-identical artifacts), re-run dirty cells and
    /// write them back. Fault-armed runs bypass the store entirely.
    pub incremental: bool,
    /// `repro matrix --merge`: every cell must come out of the store
    /// union — a miss is a cell failure, and nothing is written back.
    pub merge_only: bool,
    /// `--shard i/N`: run only the cells this shard owns.
    pub shard: Option<Shard>,
    /// Parent span for run telemetry (`--trace`): the run hangs one
    /// `cell` child per attempted cell off it. `None` records nothing.
    pub span: Option<&'a crate::obs::Span>,
    /// Metrics sink the run-local counters merge into after the sweep
    /// (the CLI passes [`crate::obs::MetricsRegistry::global`]).
    pub metrics: Option<&'a crate::obs::MetricsRegistry>,
}

/// `parent.child(name)` when telemetry is on, a no-op span otherwise.
fn child_span(parent: Option<&crate::obs::Span>, name: &str) -> crate::obs::Span {
    match parent {
        Some(s) => s.child(name),
        None => crate::obs::Span::disabled(),
    }
}

/// One cell that failed to profile: which cell (attempt-order index +
/// scenario) and the structured [`crate::exec::ExecError`] (kind,
/// attempts, elapsed) describing how.
pub struct CellFailure {
    /// Index into the *attempted* cell list — equal to the global
    /// enumeration index for unsharded runs (sharded runs attempt a
    /// subsequence, and [`MatrixRun::outcomes`] interleaves over it).
    pub index: usize,
    pub scenario: Scenario,
    pub error: crate::exec::ExecError,
}

impl CellFailure {
    pub fn id(&self) -> String {
        self.scenario.id()
    }
}

/// A cell's outcome in enumeration order — the view over
/// [`MatrixRun::outcomes`] that interleaves survivors and failures
/// back into one sequence.
pub enum CellOutcome<'a> {
    Success(&'a ScenarioResult),
    Failed(&'a CellFailure),
}

/// The sweep output: surviving per-scenario results in enumeration
/// order, per-cell failures (also enumeration-ordered), and
/// shared-cache statistics. A fault-free run has `failures.is_empty()`
/// and is byte-identical to the pre-supervision pipeline.
pub struct MatrixRun {
    pub results: Vec<ScenarioResult>,
    /// Cells that failed to profile (panicked / timed out / errored /
    /// skipped by fail-fast), with structured errors.
    pub failures: Vec<CellFailure>,
    /// (cache hits, distinct simulations) across the whole sweep,
    /// summed over the per-device caches.
    pub sim_stats: (u64, u64),
    /// Cell-store traffic (all zeros for non-incremental runs). Derived
    /// from [`MatrixRun::metrics`] — the run-local
    /// [`crate::obs::MetricsRegistry`] is the single source of truth
    /// for store counters.
    pub cache_stats: CacheStats,
    /// Frozen run-local telemetry: the store counters behind
    /// [`MatrixRun::cache_stats`], the per-outcome cell counts
    /// (`matrix.cells.{replayed,ran,failed}`), dedup counters, and the
    /// exec queue-wait / run-time histograms.
    pub metrics: crate::obs::MetricsSnapshot,
}

impl MatrixRun {
    /// Total cells attempted (survivors + failures).
    pub fn n_cells(&self) -> usize {
        self.results.len() + self.failures.len()
    }

    /// Every cell's outcome, re-interleaved into enumeration order
    /// (failures carry their enumeration index; survivors fill the
    /// gaps in order).
    pub fn outcomes(&self) -> Vec<CellOutcome<'_>> {
        let mut out = Vec::with_capacity(self.n_cells());
        let mut ok = self.results.iter();
        let mut failed = self.failures.iter().peekable();
        for index in 0..self.n_cells() {
            match failed.peek() {
                Some(f) if f.index == index => {
                    out.push(CellOutcome::Failed(failed.next().unwrap()));
                }
                _ => {
                    if let Some(r) = ok.next() {
                        out.push(CellOutcome::Success(r));
                    }
                }
            }
        }
        out
    }

    /// The distinct devices this run covered, in first-seen order.
    pub fn device_entries(&self) -> Vec<&'static DeviceEntry> {
        let mut out: Vec<&'static DeviceEntry> = Vec::new();
        for r in &self.results {
            if !out.iter().any(|d| d.name == r.scenario.device.name) {
                out.push(r.scenario.device);
            }
        }
        out
    }

    /// Results restricted to one device, in enumeration order.
    pub fn results_for(&self, device: &DeviceEntry) -> Vec<&ScenarioResult> {
        self.results.iter().filter(|r| r.scenario.device.name == device.name).collect()
    }
}

/// One profiled scenario.
pub struct ScenarioResult {
    pub scenario: Scenario,
    pub profile: Profile,
}

impl ScenarioResult {
    pub fn id(&self) -> String {
        self.scenario.id()
    }

    /// A phase with no kernels (TF folds the optimizer into backward).
    pub fn is_empty(&self) -> bool {
        self.profile.n_kernels() == 0
    }

    /// Aggregate FLOPs across all kernels.
    pub fn total_flops(&self) -> f64 {
        self.profile.kernels().map(|k| k.flops()).sum()
    }

    fn tensor_flops(&self) -> f64 {
        self.profile.kernels().map(|k| k.tensor_flops()).sum()
    }

    /// Aggregate sustained performance.
    pub fn flops_per_sec(&self) -> f64 {
        let s = self.profile.total_seconds();
        if s == 0.0 {
            0.0
        } else {
            self.total_flops() / s
        }
    }

    /// Aggregate arithmetic intensity at one memory level (total FLOPs
    /// over total bytes at that level).
    pub fn ai(&self, level: MemLevel) -> Option<f64> {
        let bytes: f64 = self.profile.kernels().map(|k| k.counters.bytes(level) as f64).sum();
        if bytes > 0.0 {
            Some(self.total_flops() / bytes)
        } else {
            None
        }
    }

    /// Zero-AI invocation fraction (the Table III quantity).
    pub fn zero_ai_fraction(&self) -> f64 {
        let (zero, total) = self.profile.zero_ai_census();
        if total == 0 {
            0.0
        } else {
            zero as f64 / total as f64
        }
    }

    /// Tensor-pipe share of aggregate FLOPs.
    pub fn tc_fraction(&self) -> f64 {
        let total = self.total_flops();
        if total == 0.0 {
            0.0
        } else {
            self.tensor_flops() / total
        }
    }

    /// Full per-kernel hierarchical Roofline dataset for this scenario,
    /// with ceilings from the scenario's own device.
    pub fn roofline_model(&self) -> RooflineModel {
        RooflineModel::from_profile(&self.scenario.device.spec(), &self.profile)
    }

    /// The whole scenario as one chart point (triplet of per-level AI
    /// at the aggregate performance) — the unit of the overlay chart.
    pub fn aggregate_point(&self) -> Option<KernelPoint> {
        let flops = self.total_flops();
        if self.is_empty() || flops <= 0.0 {
            return None;
        }
        let ai: Vec<(MemLevel, f64)> =
            MemLevel::ALL.iter().filter_map(|&l| self.ai(l).map(|a| (l, a))).collect();
        if ai.is_empty() {
            return None;
        }
        Some(KernelPoint {
            name: self.id(),
            seconds: self.profile.total_seconds(),
            flops_per_sec: self.flops_per_sec(),
            ai,
            tensor_dominated: self.tensor_flops() > 0.5 * flops,
            invocations: self.profile.total_invocations(),
        })
    }

    /// This scenario's step timeline: one phase slice (a scenario
    /// profiles exactly one phase of the step).
    pub fn timeline(&self) -> StepTimeline {
        let mut t = StepTimeline::new(self.scenario.device.display);
        t.push_phase(self.scenario.phase.name(), &self.profile);
        t
    }

    /// Per-scenario artifact: kernel-table text, summary JSON,
    /// paper-style SVG chart, and the Nsight-style counter CSV. The
    /// scenario's device supplies the ceilings and is recorded in the
    /// JSON payload (and the CSV's `# device=` stamp). The time-based
    /// Roofline rides in extra lanes (`timeline.txt` — step-time
    /// breakdown + per-kernel timing — and `timeline.svg`, the
    /// time-weighted chart), keeping the four core lanes byte-identical
    /// to the counter-only pipeline.
    pub fn to_artifact(&self) -> Artifact {
        let model = self.roofline_model();
        let bound_violation = model.validate_bounds().err();
        let title = self.scenario.title();
        let chart = RooflineChart::hierarchical(&model, &title);
        let text = if self.is_empty() {
            format!(
                "{title}\n\n(no kernels in this phase — TF folds the optimizer into backward)\n"
            )
        } else {
            format!(
                "{title}\n\ntotal {} | kernels {} | invocations {} | \
                 zero-AI {} | tensor-core FLOP share {}\n\n{}",
                fmt::duration(self.profile.total_seconds()),
                self.profile.n_kernels(),
                self.profile.total_invocations(),
                fmt::pct(self.zero_ai_fraction()),
                fmt::pct(self.tc_fraction()),
                chart.to_table().render()
            )
        };
        let ai_json = Json::obj(
            MemLevel::ALL
                .iter()
                .map(|&l| {
                    (l.name(), self.ai(l).map(Json::num).unwrap_or(Json::Null))
                })
                .collect(),
        );
        let timeline_lane = rtime::timeline_text(&title, &self.timeline(), &self.profile);
        let timeline_svg = rtime::time_weighted_svg(
            &self.scenario.device.spec(),
            &self.profile,
            &format!("{title} — time-weighted"),
        );
        let artifact = Artifact {
            id: self.id(),
            title,
            text,
            json: Json::obj(vec![
                ("workload", Json::str(self.scenario.workload.name)),
                ("device", Json::str(self.scenario.device.name)),
                ("device_spec", Json::str(self.scenario.device.display)),
                ("framework", Json::str(self.scenario.framework.name())),
                ("phase", Json::str(self.scenario.phase.name())),
                ("amp", Json::str(self.scenario.policy.name())),
                ("scale", Json::str(self.scenario.scale.name())),
                ("total_seconds", Json::num(self.profile.total_seconds())),
                ("n_kernels", Json::num(self.profile.n_kernels() as f64)),
                ("invocations", Json::num(self.profile.total_invocations() as f64)),
                ("gflops_per_sec", Json::num(self.flops_per_sec() / 1e9)),
                ("zero_ai_fraction", Json::num(self.zero_ai_fraction())),
                ("tc_flop_fraction", Json::num(self.tc_fraction())),
                ("ai", ai_json),
                (
                    "roofline_bound_violation",
                    bound_violation.map(Json::str).unwrap_or(Json::Null),
                ),
            ]),
            svg: if self.is_empty() { None } else { Some(chart.to_svg()) },
            csv: if self.is_empty() { None } else { Some(export::to_csv(&self.profile)) },
            lanes: Vec::new(),
        };
        let artifact = artifact.with_lane("timeline.txt", timeline_lane);
        match timeline_svg {
            Some(svg) => artifact.with_lane("timeline.svg", svg),
            None => artifact,
        }
    }
}

/// The cross-scenario comparison table (one row per scenario, in
/// enumeration order).
pub fn comparison_table(results: &[ScenarioResult]) -> Table {
    let mut t = Table::new(&[
        "scenario", "time", "GFLOP/s", "AI(L1)", "AI(L2)", "AI(HBM)", "zero-AI", "TC", "kernels",
        "inv",
    ])
    .aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for r in results {
        if r.is_empty() {
            t.row(&[
                r.id(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "-".into(),
                "0".into(),
                "0".into(),
            ]);
            continue;
        }
        let ai_of = |l: MemLevel| {
            r.ai(l).map(|a| format!("{a:.2}")).unwrap_or_else(|| "-".into())
        };
        t.row(&[
            r.id(),
            fmt::duration(r.profile.total_seconds()),
            format!("{:.1}", r.flops_per_sec() / 1e9),
            ai_of(MemLevel::L1),
            ai_of(MemLevel::L2),
            ai_of(MemLevel::Hbm),
            fmt::pct(r.zero_ai_fraction()),
            fmt::pct(r.tc_fraction()),
            r.profile.n_kernels().to_string(),
            r.profile.total_invocations().to_string(),
        ]);
    }
    t
}

/// Comparison CSV: one summary row per scenario (the `device` column is
/// the registry name, so cross-device sweeps pivot cleanly).
pub fn comparison_csv(results: &[ScenarioResult]) -> String {
    use std::fmt::Write as _;
    let mut out = String::with_capacity(128 + results.len() * 176);
    out.push_str(
        "scenario,workload,device,framework,phase,amp,seconds,gflops_per_sec,\
         ai_l1,ai_l2,ai_hbm,zero_ai_fraction,tc_flop_fraction,kernels,invocations\n",
    );
    for r in results {
        let ai = |l: MemLevel| r.ai(l).unwrap_or(0.0);
        let _ = writeln!(
            out,
            "{},{},{},{},{},{},{:.6e},{:.3},{:.4},{:.4},{:.4},{:.4},{:.4},{},{}",
            r.id(),
            r.scenario.workload.name,
            r.scenario.device.name,
            r.scenario.framework.name(),
            r.scenario.phase.name(),
            r.scenario.policy.name(),
            r.profile.total_seconds(),
            r.flops_per_sec() / 1e9,
            ai(MemLevel::L1),
            ai(MemLevel::L2),
            ai(MemLevel::Hbm),
            r.zero_ai_fraction(),
            r.tc_fraction(),
            r.profile.n_kernels(),
            r.profile.total_invocations(),
        );
    }
    out
}

/// Cross-device pivot: one row per device-less scenario stem, one
/// (time, GFLOP/s) column pair per device — the "how does the picture
/// shift from V100 to A100" table. Only meaningful for multi-device
/// runs; rows keep enumeration order of the first device.
pub fn cross_device_table(run: &MatrixRun) -> Table {
    let entries = run.device_entries();
    let mut headers: Vec<String> = vec!["scenario".into()];
    for d in &entries {
        headers.push(format!("time({})", d.short));
        headers.push(format!("GFLOP/s({})", d.short));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut aligns = vec![Align::Left];
    aligns.resize(headers.len(), Align::Right);
    let mut t = Table::new(&header_refs).aligns(&aligns);

    let mut stems: Vec<String> = Vec::new();
    let mut by_cell: HashMap<(String, &str), &ScenarioResult> = HashMap::new();
    for r in &run.results {
        let stem = r.scenario.base_id();
        if !stems.contains(&stem) {
            stems.push(stem.clone());
        }
        by_cell.insert((stem, r.scenario.device.name), r);
    }
    for stem in stems {
        let mut row = vec![stem.clone()];
        for d in &entries {
            match by_cell.get(&(stem.clone(), d.name)) {
                Some(r) if !r.is_empty() => {
                    row.push(fmt::duration(r.profile.total_seconds()));
                    row.push(format!("{:.1}", r.flops_per_sec() / 1e9));
                }
                _ => {
                    row.push("-".into());
                    row.push("-".into());
                }
            }
        }
        t.row(&row);
    }
    t
}

/// Cross-scenario step-time pivot (time-based Roofline): one row per
/// scenario — step time, compute-/memory-/overhead-bound shares, and
/// the idle (launch/drain) component share. Rendered into the matrix
/// artifact's `timeline.txt` lane.
pub fn step_time_pivot<'a, I>(results: I) -> Table
where
    I: IntoIterator<Item = &'a ScenarioResult>,
{
    let mut t = Table::new(&["scenario", "time", "compute", "memory", "overhead", "idle"])
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
        ]);
    for r in results {
        if r.is_empty() {
            t.row(&[r.id(), "-".into(), "-".into(), "-".into(), "-".into(), "-".into()]);
            continue;
        }
        let tl = r.timeline();
        let step = tl.step_seconds();
        let (c, m, o) = tl.bucket_seconds();
        let pct = |x: f64| {
            if step > 0.0 {
                fmt::pct(x / step)
            } else {
                "-".to_string()
            }
        };
        t.row(&[
            r.id(),
            fmt::duration(step),
            pct(c),
            pct(m),
            pct(o),
            pct(tl.idle_seconds()),
        ]);
    }
    t
}

/// Cross-device step-time pivot: one row per device-less scenario
/// stem, one (time, bound-mix) column pair per device. The bound mix
/// is a compact `c/m/o` percent triple — how the compute-/memory-/
/// overhead-bound split shifts between devices.
pub fn cross_device_step_table(run: &MatrixRun) -> Table {
    let entries = run.device_entries();
    let mut headers: Vec<String> = vec!["scenario".into()];
    for d in &entries {
        headers.push(format!("time({})", d.short));
        headers.push(format!("c/m/o({})", d.short));
    }
    let header_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut aligns = vec![Align::Left];
    aligns.resize(headers.len(), Align::Right);
    let mut t = Table::new(&header_refs).aligns(&aligns);

    let mut stems: Vec<String> = Vec::new();
    let mut by_cell: HashMap<(String, &str), &ScenarioResult> = HashMap::new();
    for r in &run.results {
        let stem = r.scenario.base_id();
        if !stems.contains(&stem) {
            stems.push(stem.clone());
        }
        by_cell.insert((stem, r.scenario.device.name), r);
    }
    for stem in stems {
        let mut row = vec![stem.clone()];
        for d in &entries {
            match by_cell.get(&(stem.clone(), d.name)) {
                Some(r) if !r.is_empty() => {
                    let tl = r.timeline();
                    let step = tl.step_seconds();
                    let (c, m, o) = tl.bucket_seconds();
                    row.push(fmt::duration(step));
                    row.push(if step > 0.0 {
                        format!(
                            "{:.0}/{:.0}/{:.0}",
                            100.0 * c / step,
                            100.0 * m / step,
                            100.0 * o / step
                        )
                    } else {
                        "-".into()
                    });
                }
                _ => {
                    row.push("-".into());
                    row.push("-".into());
                }
            }
        }
        t.row(&row);
    }
    t
}

/// The failed-cell table appended to the comparison artifact when any
/// cell failed: cell id, error kind, attempts, and the full error.
pub fn failure_table(failures: &[CellFailure]) -> Table {
    let mut t = Table::new(&["cell", "kind", "attempts", "error"]).aligns(&[
        Align::Left,
        Align::Left,
        Align::Right,
        Align::Left,
    ]);
    for f in failures {
        t.row(&[
            f.id(),
            f.error.kind().to_string(),
            f.error.attempts().to_string(),
            f.error.to_string(),
        ]);
    }
    t
}

/// The machine-readable failure manifest (`matrix.errors.json`): one
/// entry per failed cell with its id, enumeration index, error kind,
/// attempt count, elapsed seconds, and the rendered error. Written by
/// `repro matrix` only when at least one cell failed, so fault-free
/// runs keep the historical artifact layout exactly.
///
/// `elapsed_s` is wall time and therefore varies across reruns;
/// everything else is deterministic for a fixed
/// [`crate::exec::FaultPlan`] (test-asserted).
pub fn errors_manifest(run: &MatrixRun) -> Json {
    Json::obj(vec![
        ("schema", Json::str("hroofline-matrix-errors-v1")),
        ("n_cells", Json::num(run.n_cells() as f64)),
        ("n_ok", Json::num(run.results.len() as f64)),
        ("n_failed", Json::num(run.failures.len() as f64)),
        (
            "failures",
            Json::arr(run.failures.iter().map(|f| {
                Json::obj(vec![
                    ("cell", Json::str(f.id())),
                    ("index", Json::num(f.index as f64)),
                    ("kind", Json::str(f.error.kind())),
                    ("attempts", Json::num(f.error.attempts() as f64)),
                    ("elapsed_s", Json::num(f.error.elapsed_s())),
                    ("error", Json::str(f.error.to_string())),
                ])
            })),
        ),
    ])
}

/// The cache/simulation statistics manifest (`matrix.cache.json`),
/// written on *every* `repro matrix` run. These numbers are volatile
/// by design — store hits depend on what previous runs left on disk,
/// simulation counts on the shared-cache interleaving — which is
/// exactly why they live in their own artifact and not in the
/// comparison set: `matrix.{txt,json,svg,csv}` must stay byte-identical
/// across cold, warm, sharded and merged runs over the same cells.
///
/// The CI warm-store gate greps this file: a second `--incremental`
/// run against a warm store must report `"misses": 0` and
/// `"simulations": 0`.
pub fn cache_manifest(run: &MatrixRun) -> Json {
    let (sim_hits, sims) = run.sim_stats;
    Json::obj(vec![
        ("schema", Json::str("hroofline-matrix-cache-v1")),
        ("n_cells", Json::num(run.n_cells() as f64)),
        (
            "store",
            Json::obj(vec![
                ("hits", Json::num(run.cache_stats.hits as f64)),
                ("misses", Json::num(run.cache_stats.misses as f64)),
                ("evictions", Json::num(run.cache_stats.evictions as f64)),
            ]),
        ),
        ("simulations", Json::num(sims as f64)),
        ("sim_cache_hits", Json::num(sim_hits as f64)),
    ])
}

/// The cross-scenario report: comparison table + combined overlay
/// Roofline chart (every scenario as one labelled aggregate triplet)
/// + machine-readable JSON/CSV.
///
/// Single-device runs get that device's full ceiling set (the
/// historical `matrix` artifact, byte-compatible with the pre-registry
/// pipeline). Multi-device runs overlay every device's headline
/// ceilings ([`Ceilings::merged`], repeats dashed) and append the
/// cross-device pivot table. Volatile cache/simulation stats are NOT
/// part of this artifact (see [`cache_manifest`]): the report is a
/// pure function of the surviving profiles, byte-identical across
/// cold, warm, sharded and merged runs.
pub fn comparison_artifact(run: &MatrixRun) -> Artifact {
    let entries = run.device_entries();
    let specs: Vec<GpuSpec> = if entries.is_empty() {
        vec![devices::default_spec()]
    } else {
        entries.iter().map(|d| d.spec()).collect()
    };
    let multi_device = specs.len() > 1;
    let table = comparison_table(&run.results);
    let mut points: Vec<KernelPoint> =
        run.results.iter().filter_map(ScenarioResult::aggregate_point).collect();
    crate::roofline::model::sort_points_hot_first(&mut points);
    let (ceilings, device_name) = if multi_device {
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        (Ceilings::merged(specs.iter()), names.join(" vs "))
    } else {
        (Ceilings::from_spec(&specs[0]), specs[0].name.clone())
    };
    let model = RooflineModel { ceilings, points, device_name };
    let chart =
        RooflineChart::overlay(&model, "Scenario matrix — aggregate hierarchical Roofline");
    let non_empty = run.results.iter().filter(|r| !r.is_empty()).count();
    // Simulation/cache statistics deliberately do NOT appear here: they
    // vary with store state (cold vs warm vs merged) while this
    // artifact is required to be byte-identical across all of those.
    // They live in `matrix.cache.json` ([`cache_manifest`]) instead.
    let mut text = format!(
        "scenario matrix: {} scenarios ({} with kernels)\n\n{}",
        run.results.len(),
        non_empty,
        table.render()
    );
    if multi_device {
        text.push_str(&format!(
            "\ncross-device comparison ({}):\n{}",
            model.device_name,
            cross_device_table(run).render()
        ));
    }
    // The failure section exists only on degraded runs, keeping
    // fault-free output byte-identical to the historical artifact.
    if !run.failures.is_empty() {
        text.push_str(&format!(
            "\nfailed cells ({} of {}):\n{}",
            run.failures.len(),
            run.n_cells(),
            failure_table(&run.failures).render()
        ));
    }
    let mut json_fields = vec![
        ("n_scenarios", Json::num(run.results.len() as f64)),
        ("n_non_empty", Json::num(non_empty as f64)),
        (
            "devices",
            Json::arr(entries.iter().map(|d| Json::str(d.name))),
        ),
        (
            "scenarios",
            Json::arr(run.results.iter().map(|r| {
                Json::obj(vec![
                    ("scenario", Json::str(r.id())),
                    ("device", Json::str(r.scenario.device.name)),
                    ("total_seconds", Json::num(r.profile.total_seconds())),
                    ("gflops_per_sec", Json::num(r.flops_per_sec() / 1e9)),
                    ("zero_ai_fraction", Json::num(r.zero_ai_fraction())),
                    ("tc_flop_fraction", Json::num(r.tc_fraction())),
                    ("n_kernels", Json::num(r.profile.n_kernels() as f64)),
                ])
            })),
        ),
    ];
    if !run.failures.is_empty() {
        json_fields.push(("n_failed", Json::num(run.failures.len() as f64)));
        json_fields.push((
            "failed_cells",
            Json::arr(run.failures.iter().map(|f| Json::str(f.id()))),
        ));
    }
    let json = Json::obj(json_fields);
    let mut timeline_lane = format!(
        "cross-scenario step-time pivot (time-based Roofline):\n{}",
        step_time_pivot(&run.results).render()
    );
    if multi_device {
        timeline_lane.push_str(&format!(
            "\ncross-device step-time pivot:\n{}",
            cross_device_step_table(run).render()
        ));
    }
    Artifact {
        id: "matrix".into(),
        title: "Cross-scenario comparison (hierarchical Roofline overlay)".into(),
        text,
        json,
        svg: Some(chart.to_svg()),
        csv: Some(comparison_csv(&run.results)),
        lanes: Vec::new(),
    }
    .with_lane("timeline.txt", timeline_lane)
}

/// One device's slice of a multi-device run as its own overlay
/// artifact (`matrix@<short>`): that device's scenarios against its
/// own full ceiling set.
pub fn device_comparison_artifact(run: &MatrixRun, device: &DeviceEntry) -> Artifact {
    let spec = device.spec();
    let results = run.results_for(device);
    let mut points: Vec<KernelPoint> =
        results.iter().filter_map(|r| r.aggregate_point()).collect();
    crate::roofline::model::sort_points_hot_first(&mut points);
    let model = RooflineModel {
        ceilings: Ceilings::from_spec(&spec),
        points,
        device_name: spec.name.clone(),
    };
    let title = format!("Scenario matrix on {} — hierarchical Roofline", spec.name);
    let chart = RooflineChart::overlay(&model, &title);
    let mut t = Table::new(&["scenario", "time", "GFLOP/s", "zero-AI", "TC"]).aligns(&[
        Align::Left,
        Align::Right,
        Align::Right,
        Align::Right,
        Align::Right,
    ]);
    for r in &results {
        if r.is_empty() {
            t.row(&[r.id(), "-".into(), "-".into(), "-".into(), "-".into()]);
        } else {
            t.row(&[
                r.id(),
                fmt::duration(r.profile.total_seconds()),
                format!("{:.1}", r.flops_per_sec() / 1e9),
                fmt::pct(r.zero_ai_fraction()),
                fmt::pct(r.tc_fraction()),
            ]);
        }
    }
    let timeline_lane = format!(
        "step-time pivot on {} (time-based Roofline):\n{}",
        spec.name,
        step_time_pivot(results.iter().copied()).render()
    );
    Artifact {
        id: format!("matrix@{}", device.short),
        title: title.clone(),
        text: format!("{title}\n\n{}", t.render()),
        json: Json::obj(vec![
            ("device", Json::str(device.name)),
            ("device_spec", Json::str(&spec.name)),
            ("n_scenarios", Json::num(results.len() as f64)),
        ]),
        svg: Some(chart.to_svg()),
        csv: None,
        lanes: Vec::new(),
    }
    .with_lane("timeline.txt", timeline_lane)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_matrix() -> ScenarioMatrix {
        ScenarioMatrix {
            workloads: vec![workloads::lookup("deepcam-lite").unwrap()],
            devices: vec![devices::default_entry()],
            frameworks: vec![Framework::PyTorch],
            phases: vec![Phase::Forward, Phase::Optimizer],
            policies: vec![Policy::O1],
            scale: Scale::Quick,
        }
    }

    #[test]
    fn quick_matrix_enumerates_32_scenarios() {
        let scenarios = ScenarioMatrix::quick().enumerate();
        assert_eq!(scenarios.len(), 4 * 2 * 2 * 2);
        // Deterministic and duplicate-free.
        let ids: Vec<String> = scenarios.iter().map(Scenario::id).collect();
        let again: Vec<String> =
            ScenarioMatrix::quick().enumerate().iter().map(Scenario::id).collect();
        assert_eq!(ids, again);
        let mut dedup = ids.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), ids.len());
        // Quick mode is single-device on the registry default, so ids
        // stay in the historical device-less form.
        assert!(ids.iter().all(|id| !id.contains('@')), "{ids:?}");
    }

    #[test]
    fn full_matrix_covers_all_devices_phases_and_policies() {
        let n_devices = devices::entries().len();
        let scenarios = ScenarioMatrix::full().enumerate();
        assert_eq!(scenarios.len(), 4 * n_devices * 2 * 3 * 3);
        // Default-device cells keep legacy ids; others carry the tag.
        assert!(scenarios.iter().any(|s| s.id() == "resnet-pt-forward-O1"));
        assert!(scenarios.iter().any(|s| s.id() == "resnet-pt-forward-O1@a100"));
    }

    #[test]
    fn duplicate_axis_values_collapse() {
        let mut m = tiny_matrix();
        m.policies = vec![Policy::O1, Policy::O1];
        m.frameworks = vec![Framework::PyTorch, Framework::PyTorch];
        m.devices = vec![devices::default_entry(), devices::default_entry()];
        assert_eq!(m.enumerate().len(), 2, "phases only");
    }

    #[test]
    fn with_workloads_filters_and_rejects_unknown() {
        let m = ScenarioMatrix::quick().with_workloads("resnet, transformer").unwrap();
        assert_eq!(m.workloads.len(), 2);
        assert_eq!(m.workloads[0].name, "resnet");
        let err = ScenarioMatrix::quick().with_workloads("resnet,bogus").unwrap_err();
        assert!(err.0.contains("unknown workload 'bogus'"), "{}", err.0);
        assert!(ScenarioMatrix::quick().with_workloads(" , ").is_err());
    }

    #[test]
    fn with_devices_filters_and_rejects_unknown() {
        let m = ScenarioMatrix::quick().with_devices("a100, t4").unwrap();
        assert_eq!(m.devices.len(), 2);
        assert_eq!(m.devices[0].name, "a100-sxm4-40gb");
        let m = ScenarioMatrix::quick().with_devices("all").unwrap();
        assert_eq!(m.devices.len(), devices::entries().len());
        let err = ScenarioMatrix::quick().with_devices("a100,h100").unwrap_err();
        assert!(err.0.contains("unknown device 'h100'"), "{}", err.0);
        assert!(ScenarioMatrix::quick().with_devices(" , ").is_err());
    }

    #[test]
    fn matrix_profiles_identical_to_standalone_sessions() {
        // The shared cache + fan-out must not change a single bit
        // relative to profiling each scenario alone.
        let run = tiny_matrix().run();
        assert_eq!(run.results.len(), 2);
        for r in &run.results {
            let spec = r.scenario.device.spec();
            let g = r.scenario.workload.build(r.scenario.scale);
            let t = lower(&g, r.scenario.framework, r.scenario.policy, &spec);
            let direct = Session::standard(&spec)
                .run(&ProfileRequest::new(t.phase(r.scenario.phase)))
                .unwrap();
            assert_eq!(r.profile, direct, "{}", r.id());
        }
    }

    #[test]
    fn shared_cache_dedupes_across_scenarios() {
        // O0 vs O1 backward share many descriptors; two-policy sweep
        // must hit the cache.
        let mut m = tiny_matrix();
        m.phases = vec![Phase::Forward, Phase::Backward];
        m.policies = vec![Policy::O0, Policy::O1];
        let run = m.run();
        let (hits, sims) = run.sim_stats;
        assert!(sims > 0);
        assert!(hits > 0, "expected cross-scenario kernel reuse, got {hits} hits / {sims} sims");
    }

    #[test]
    fn aggregate_points_and_artifacts() {
        let run = tiny_matrix().run();
        for r in &run.results {
            assert!(!r.is_empty(), "{}", r.id());
            let p = r.aggregate_point().unwrap();
            assert!(p.flops_per_sec > 0.0);
            assert_eq!(p.ai.len(), MemLevel::ALL.len());
            let a = r.to_artifact();
            assert_eq!(a.id, r.id());
            assert!(a.svg.is_some() && a.csv.is_some());
            assert!(a.text.contains("kernels"));
            // Per-scenario JSON carries the per-level AI block and the
            // device the scenario ran on.
            assert!(a.json.get("ai").unwrap().opt("HBM").is_some());
            assert_eq!(
                a.json.get("device").unwrap().as_str().unwrap(),
                "v100-sxm2-16gb"
            );
            // The counter CSV travels with its device stamp.
            assert!(a.csv.as_ref().unwrap().starts_with("# device=V100-SXM2-16GB"));
            // Time-based Roofline lanes ride along: the step-time
            // breakdown and the time-weighted chart.
            let tl = a.lanes.iter().find(|(k, _)| k == "timeline.txt").unwrap();
            assert!(tl.1.contains("step total"), "{}", tl.1);
            assert!(tl.1.contains("per-kernel timing"), "{}", tl.1);
            let svg_lane = a.lanes.iter().find(|(k, _)| k == "timeline.svg").unwrap();
            assert!(svg_lane.1.starts_with("<svg"));
        }
    }

    #[test]
    fn scenario_timeline_sums_to_profile_total() {
        let run = tiny_matrix().run();
        for r in &run.results {
            let tl = r.timeline();
            let want = r.profile.total_seconds();
            let got = tl.step_seconds();
            assert!((got - want).abs() <= 1e-9 * want.max(1e-30), "{}: {got} vs {want}", r.id());
            let (c, m, o) = tl.bucket_seconds();
            let parts = c + m + o;
            assert!((parts - got).abs() <= 1e-12 * got.max(1e-30), "{}", r.id());
        }
    }

    #[test]
    fn comparison_artifact_overlays_all_scenarios() {
        let run = tiny_matrix().run();
        let a = comparison_artifact(&run);
        assert_eq!(a.id, "matrix");
        let svg = a.svg.as_ref().unwrap();
        let csv = a.csv.as_ref().unwrap();
        for r in &run.results {
            assert!(a.text.contains(&r.id()), "table row for {}", r.id());
            assert!(svg.contains(&r.id()), "chart label for {}", r.id());
            assert!(csv.contains(&r.id()), "csv row for {}", r.id());
        }
        assert_eq!(
            a.json.get("n_scenarios").unwrap().as_f64().unwrap() as usize,
            run.results.len()
        );
        // Single-device run: no cross-device section.
        assert!(!a.text.contains("cross-device comparison"), "{}", a.text);
        // The step-time pivot rides in the timeline lane, not the text
        // (the core lanes stay byte-identical to the counter-only
        // pipeline).
        assert!(!a.text.contains("step-time"), "{}", a.text);
        let tl = a.lanes.iter().find(|(k, _)| k == "timeline.txt").unwrap();
        for r in &run.results {
            assert!(tl.1.contains(&r.id()), "pivot row for {}", r.id());
        }
    }

    #[test]
    fn multi_device_run_compares_across_devices() {
        // The device axis end to end: same cell on two devices → two
        // distinct profiles, a cross-device pivot table, and a merged
        // overlay naming both devices.
        let mut m = tiny_matrix();
        m.devices = vec![devices::lookup("v100").unwrap(), devices::lookup("a100").unwrap()];
        m.phases = vec![Phase::Forward];
        let run = m.run();
        assert_eq!(run.results.len(), 2);
        assert_eq!(run.results[0].id(), "deepcam-lite-pt-forward-O1");
        assert_eq!(run.results[1].id(), "deepcam-lite-pt-forward-O1@a100");
        assert_eq!(run.device_entries().len(), 2);
        // The same trace is faster on the A100 model.
        let v = run.results[0].profile.total_seconds();
        let a = run.results[1].profile.total_seconds();
        assert!(a < v, "a100 {a} vs v100 {v}");
        // Per-device slices and artifacts.
        let a100 = devices::lookup("a100").unwrap();
        assert_eq!(run.results_for(a100).len(), 1);
        let da = device_comparison_artifact(&run, a100);
        assert_eq!(da.id, "matrix@a100");
        assert!(da.svg.as_ref().unwrap().contains("A100-SXM4-40GB"));
        let da_tl = da.lanes.iter().find(|(k, _)| k == "timeline.txt").unwrap();
        assert!(da_tl.1.contains("deepcam-lite-pt-forward-O1@a100"), "{}", da_tl.1);
        // The combined artifact carries the pivot and both ceilings.
        let c = comparison_artifact(&run);
        assert!(c.text.contains("cross-device comparison"), "{}", c.text);
        assert!(c.text.contains("GFLOP/s(a100)"), "{}", c.text);
        let svg = c.svg.as_ref().unwrap();
        assert!(svg.contains("V100-SXM2-16GB") && svg.contains("A100-SXM4-40GB"));
        assert_eq!(c.json.get("devices").unwrap().as_arr().unwrap().len(), 2);
        // Multi-device: the timeline lane additionally pivots the
        // step-time buckets across devices.
        let c_tl = c.lanes.iter().find(|(k, _)| k == "timeline.txt").unwrap();
        assert!(c_tl.1.contains("cross-device step-time pivot"), "{}", c_tl.1);
        assert!(c_tl.1.contains("c/m/o(a100)"), "{}", c_tl.1);
    }

    #[test]
    fn injected_cell_panic_degrades_gracefully() {
        let plan = crate::exec::FaultPlan::new(0).panic_on("deepcam-lite-pt-optimizer-O1");
        let inj = crate::exec::FaultInjector::new(plan);
        let run = tiny_matrix()
            .run_with(&MatrixRunOptions { fault: Some(&inj), ..Default::default() });
        assert_eq!(run.n_cells(), 2);
        assert_eq!(run.results.len(), 1, "the sibling cell survives");
        assert_eq!(run.results[0].id(), "deepcam-lite-pt-forward-O1");
        assert_eq!(run.failures.len(), 1);
        let f = &run.failures[0];
        assert_eq!(f.id(), "deepcam-lite-pt-optimizer-O1");
        assert_eq!(f.index, 1);
        assert_eq!(f.error.kind(), "panicked");
        // The surviving cell still renders its full artifact.
        assert!(run.results[0].to_artifact().svg.is_some());
        // outcomes() re-interleaves enumeration order.
        let outcomes = run.outcomes();
        assert!(matches!(outcomes[0], CellOutcome::Success(_)));
        assert!(matches!(outcomes[1], CellOutcome::Failed(_)));
        // The manifest names exactly the failed cell.
        let manifest = errors_manifest(&run);
        assert_eq!(manifest.get("n_failed").unwrap().as_f64().unwrap() as usize, 1);
        let failures = manifest.get("failures").unwrap().as_arr().unwrap();
        assert_eq!(
            failures[0].get("cell").unwrap().as_str().unwrap(),
            "deepcam-lite-pt-optimizer-O1"
        );
        assert_eq!(failures[0].get("kind").unwrap().as_str().unwrap(), "panicked");
        // The comparison artifact gains the failure section.
        let a = comparison_artifact(&run);
        assert!(a.text.contains("failed cells (1 of 2)"), "{}", a.text);
        assert!(a.text.contains("deepcam-lite-pt-optimizer-O1"), "{}", a.text);
        assert_eq!(a.json.get("n_failed").unwrap().as_f64().unwrap() as usize, 1);
    }

    #[test]
    fn kernel_grain_transient_fault_rides_retry_budget() {
        // A kernel-level FailFirst(1) fault inside one cell's session is
        // absorbed by a 2-attempt retry policy: the run is clean and
        // byte-identical to a fault-free sweep.
        let clean = tiny_matrix().run();
        let inj = crate::exec::FaultInjector::new(
            crate::exec::FaultPlan::new(0).fail_first("kernel:", 1),
        );
        let policy = crate::exec::SupervisePolicy {
            retry: crate::exec::RetryPolicy::attempts(2),
            ..Default::default()
        };
        let run = tiny_matrix()
            .run_with(&MatrixRunOptions { policy, fault: Some(&inj), ..Default::default() });
        assert!(run.failures.is_empty(), "retries must absorb the transient fault");
        assert_eq!(run.results.len(), clean.results.len());
        for (a, b) in run.results.iter().zip(&clean.results) {
            assert_eq!(a.profile, b.profile, "{}", a.id());
        }
    }

    #[test]
    fn clean_run_has_no_failure_surface() {
        let run = tiny_matrix().run();
        assert!(run.failures.is_empty());
        assert_eq!(run.n_cells(), run.results.len());
        assert!(run.outcomes().iter().all(|o| matches!(o, CellOutcome::Success(_))));
        let a = comparison_artifact(&run);
        assert!(!a.text.contains("failed cells"), "{}", a.text);
        assert!(a.json.opt("n_failed").is_none());
        let manifest = errors_manifest(&run);
        assert_eq!(manifest.get("n_failed").unwrap().as_f64().unwrap() as usize, 0);
    }

    fn store_tmpdir(tag: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("hroofline-matrix-store-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn cell_keys_are_stable_distinct_and_spec_sensitive() {
        let keys = tiny_matrix().cell_keys();
        assert_eq!(keys.len(), 2);
        assert_eq!(keys, tiny_matrix().cell_keys(), "same spec → same keys");
        assert_ne!(keys[0].0, keys[1].0, "distinct cells → distinct keys");
        assert_eq!(keys[0].1, "deepcam-lite-pt-forward-O1");

        // Dirty-cell invalidation: any GpuSpec field change moves the key.
        let sc = tiny_matrix().enumerate()[0];
        let spec = sc.device.spec();
        let g = sc.workload.build(sc.scale);
        let trace = lower(&g, sc.framework, sc.policy, &spec);
        let base = sc.cell_key(trace.phase(sc.phase), &spec);
        let mut dirty = spec.clone();
        dirty.hbm_bytes_per_sec *= 2.0;
        assert_ne!(base, sc.cell_key(trace.phase(sc.phase), &dirty));

        // An AMP policy change moves the key even before the trace
        // differences are hashed (the policy is keyed directly).
        let mut o0 = sc;
        o0.policy = Policy::O0;
        assert_ne!(base, o0.cell_key(trace.phase(sc.phase), &spec));
    }

    #[test]
    fn shard_union_equals_unsharded_enumeration() {
        let scenarios = ScenarioMatrix::quick().enumerate();
        let mut owned: Vec<usize> = Vec::new();
        for index in 0..3 {
            let shard = Shard { index, count: 3 };
            let mine: Vec<usize> =
                (0..scenarios.len()).filter(|&i| shard.owns(i)).collect();
            // 32 quick cells round-robin into 11/11/10.
            assert_eq!(mine.len(), if index < 2 { 11 } else { 10 });
            owned.extend(mine);
        }
        owned.sort();
        assert_eq!(owned, (0..scenarios.len()).collect::<Vec<_>>(), "disjoint + complete");

        // A sharded run profiles exactly its slice, in enumeration order.
        let run = tiny_matrix()
            .run_with(&MatrixRunOptions { shard: Some(Shard { index: 1, count: 2 }), ..Default::default() });
        assert_eq!(run.results.len(), 1);
        assert_eq!(run.results[0].id(), "deepcam-lite-pt-optimizer-O1");
    }

    #[test]
    fn incremental_warm_run_serves_hits_with_zero_simulations() {
        let dir = store_tmpdir("warm");
        let st = store::CellStore::open(&dir).unwrap();
        let cold = tiny_matrix().run_with(&MatrixRunOptions {
            store: Some(&st),
            incremental: true,
            ..Default::default()
        });
        assert_eq!(cold.cache_stats, CacheStats { hits: 0, misses: 2, evictions: 0 });
        assert!(cold.sim_stats.1 > 0);
        assert_eq!(st.n_entries(), 2);

        let warm = tiny_matrix().run_with(&MatrixRunOptions {
            store: Some(&st),
            incremental: true,
            ..Default::default()
        });
        assert_eq!(warm.cache_stats, CacheStats { hits: 2, misses: 0, evictions: 0 });
        assert_eq!(warm.sim_stats.1, 0, "a warm run simulates nothing");
        for (c, w) in cold.results.iter().zip(&warm.results) {
            assert_eq!(c.profile, w.profile, "{}", c.id());
        }
        // Byte-identical comparison artifact — the tentpole guarantee.
        let a = comparison_artifact(&cold);
        let b = comparison_artifact(&warm);
        assert_eq!(a.text, b.text);
        assert_eq!(a.json.to_string_pretty(), b.json.to_string_pretty());
        assert_eq!(a.csv, b.csv);
        assert_eq!(a.svg, b.svg);
        // The volatile counters land in the cache manifest instead.
        let m = cache_manifest(&warm);
        assert_eq!(m.get("schema").unwrap().as_str().unwrap(), "hroofline-matrix-cache-v1");
        assert_eq!(m.get("simulations").unwrap().as_f64().unwrap(), 0.0);
        assert_eq!(m.get("store").unwrap().get("misses").unwrap().as_f64().unwrap(), 0.0);
        assert!(!a.text.contains("simulations"), "{}", a.text);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn merge_only_unions_shard_stores_and_misses_fail_cleanly() {
        let dir_a = store_tmpdir("merge-a");
        let dir_b = store_tmpdir("merge-b");
        // Two sharded incremental runs fill two disjoint stores.
        for (index, dir) in [(0, &dir_a), (1, &dir_b)] {
            let st = store::CellStore::open(dir).unwrap();
            let run = tiny_matrix().run_with(&MatrixRunOptions {
                store: Some(&st),
                incremental: true,
                shard: Some(Shard { index, count: 2 }),
                ..Default::default()
            });
            assert_eq!(run.results.len(), 1);
            assert_eq!(st.n_entries(), 1);
        }
        // The merge run serves every cell from the union, runs nothing.
        let union = store::CellStore::open_union(vec![dir_a.clone(), dir_b.clone()]);
        let merged = tiny_matrix().run_with(&MatrixRunOptions {
            store: Some(&union),
            merge_only: true,
            ..Default::default()
        });
        assert!(merged.failures.is_empty());
        assert_eq!(merged.cache_stats.hits, 2);
        assert_eq!(merged.sim_stats.1, 0);
        let direct = tiny_matrix().run();
        assert_eq!(
            comparison_artifact(&merged).text,
            comparison_artifact(&direct).text,
            "merged output byte-identical to an unsharded run"
        );
        // A union missing a shard degrades the absent cells, not the run.
        let partial = store::CellStore::open_union(vec![dir_a.clone()]);
        let degraded = tiny_matrix().run_with(&MatrixRunOptions {
            store: Some(&partial),
            merge_only: true,
            ..Default::default()
        });
        assert_eq!(degraded.results.len(), 1);
        assert_eq!(degraded.failures.len(), 1);
        assert!(
            degraded.failures[0].error.to_string().contains("missing from the merged store"),
            "{}",
            degraded.failures[0].error
        );
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }

    #[test]
    fn run_telemetry_counts_cells_and_emits_well_formed_spans() {
        let dir = store_tmpdir("telemetry");
        let st = store::CellStore::open(&dir).unwrap();
        let tracer = crate::obs::Tracer::fixed();
        let sink = crate::obs::MetricsRegistry::new();
        let cold = {
            let root = tracer.span("matrix");
            tiny_matrix().run_with(&MatrixRunOptions {
                store: Some(&st),
                incremental: true,
                span: Some(&root),
                metrics: Some(&sink),
                ..Default::default()
            })
        };
        // Counter catalog: one miss + one run per cold cell, bytes from
        // the write-back, and CacheStats derived from the same registry.
        assert_eq!(cold.metrics.counter("matrix.cells.ran"), 2);
        assert_eq!(cold.metrics.counter("matrix.cells.replayed"), 0);
        assert_eq!(cold.metrics.counter("store.misses"), 2);
        assert!(cold.metrics.counter("store.bytes_written") > 0);
        assert_eq!(cold.cache_stats, CacheStats { hits: 0, misses: 2, evictions: 0 });
        assert_eq!(sink.counter("matrix.cells.ran"), 2, "local counters merge into the sink");
        let trace = crate::obs::Trace::parse_jsonl(&tracer.to_jsonl()).unwrap();
        trace.validate().unwrap();
        let cell_spans: Vec<_> = trace.spans.iter().filter(|s| s.name == "cell").collect();
        assert_eq!(cell_spans.len(), 2, "one cell span per attempted cell");
        assert!(cell_spans.iter().all(|s| s.field("outcome") == Some("ran")));
        assert!(cell_spans.iter().all(|s| s.field("attempt") == Some("1")));
        assert!(cell_spans
            .iter()
            .any(|s| s.field("label") == Some("cell#0:deepcam-lite-pt-forward-O1")));
        assert!(trace.spans.iter().any(|s| s.name == "prepare"));
        assert!(trace.spans.iter().any(|s| s.name == "store.save"));
        assert!(trace.spans.iter().any(|s| s.name == "profile"), "session spans nest under cells");

        // Warm replay flips the outcomes and the counters; telemetry
        // never perturbs the artifacts (byte-identity is pinned by
        // incremental_warm_run_serves_hits_with_zero_simulations and
        // rust/tests/trace_semantics.rs).
        let tracer2 = crate::obs::Tracer::fixed();
        let warm = {
            let root = tracer2.span("matrix");
            tiny_matrix().run_with(&MatrixRunOptions {
                store: Some(&st),
                incremental: true,
                span: Some(&root),
                ..Default::default()
            })
        };
        assert_eq!(warm.metrics.counter("matrix.cells.replayed"), 2);
        assert_eq!(warm.metrics.counter("matrix.cells.ran"), 0);
        assert_eq!(warm.cache_stats, CacheStats { hits: 2, misses: 0, evictions: 0 });
        let t2 = crate::obs::Trace::parse_jsonl(&tracer2.to_jsonl()).unwrap();
        t2.validate().unwrap();
        assert!(t2
            .spans
            .iter()
            .filter(|s| s.name == "cell")
            .all(|s| s.field("outcome") == Some("replayed")));
        assert!(t2.spans.iter().any(|s| s.name == "store.load"));
        assert!(!t2.spans.iter().any(|s| s.name == "store.save"), "hits write nothing back");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fault_armed_runs_never_touch_the_store() {
        let dir = store_tmpdir("faulted");
        let st = store::CellStore::open(&dir).unwrap();
        let plan = crate::exec::FaultPlan::new(0).panic_on("deepcam-lite-pt-optimizer-O1");
        let inj = crate::exec::FaultInjector::new(plan);
        let run = tiny_matrix().run_with(&MatrixRunOptions {
            fault: Some(&inj),
            store: Some(&st),
            incremental: true,
            ..Default::default()
        });
        assert_eq!(run.results.len(), 1, "the surviving cell still profiles");
        assert_eq!(run.cache_stats, CacheStats::default(), "no store traffic under faults");
        assert_eq!(st.n_entries(), 0, "fault-armed cells are never persisted");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_phase_scenarios_render_without_artifacts_payload() {
        // TF optimizer phase is empty by construction.
        let m = ScenarioMatrix {
            workloads: vec![workloads::lookup("deepcam-lite").unwrap()],
            devices: vec![devices::default_entry()],
            frameworks: vec![Framework::TensorFlow],
            phases: vec![Phase::Optimizer],
            policies: vec![Policy::O1],
            scale: Scale::Quick,
        };
        let run = m.run();
        assert_eq!(run.results.len(), 1);
        let r = &run.results[0];
        assert!(r.is_empty());
        assert!(r.aggregate_point().is_none());
        let a = r.to_artifact();
        assert!(a.svg.is_none() && a.csv.is_none());
        assert!(a.text.contains("no kernels"));
        // An empty phase still gets its (zero) step-time table, but no
        // time-weighted chart (nothing to plot).
        assert!(a.lanes.iter().any(|(k, _)| k == "timeline.txt"));
        assert!(!a.lanes.iter().any(|(k, _)| k == "timeline.svg"));
        // The comparison table still carries the row.
        let table = comparison_table(&run.results);
        assert_eq!(table.n_rows(), 1);
    }
}
