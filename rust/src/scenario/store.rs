//! On-disk, content-addressed cell store for the incremental scenario
//! matrix.
//!
//! One JSON blob per cell, named `<key>.json` under the store directory
//! (default `.hroofline-cache/`), where `<key>` is the 32-hex-char
//! [`CellKey`] computed by [`crate::scenario::Scenario::cell_key`] over
//! everything the cell's profile is a function of: the lowered kernel
//! trace, the [`crate::device::GpuSpec`], the AMP policy, the workload
//! spec, and [`CELL_SCHEMA`] itself. Because the profiler is
//! deterministic and artifacts are pure functions of the profile, a key
//! hit can replay a cell with **zero simulations** and byte-identical
//! artifacts — the contract `rust/tests/incremental_matrix.rs` pins.
//!
//! Robustness rule (the store is a cache, never a source of truth): any
//! defect in an entry — unreadable file, truncated JSON, schema or key
//! mismatch, undecodable profile — is reported as [`Lookup::Corrupt`]
//! and treated by the matrix as a miss; the cell re-runs and the entry
//! is overwritten. A store can therefore never turn a clean matrix run
//! into a hard error.
//!
//! Entry schema (`hroofline-cell-v1`):
//!
//! ```json
//! {
//!   "schema": "hroofline-cell-v1",
//!   "key": "<32 hex chars>",
//!   "cell": "<human-readable scenario id>",
//!   "profile": { ... lossless profile encoding ... }
//! }
//! ```

use std::fs;
use std::path::{Path, PathBuf};

use crate::profiler::export::{profile_from_json, profile_to_json};
use crate::profiler::Profile;
use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;

/// Store-format version, hashed into every [`CellKey`] (a format bump
/// invalidates all prior entries by construction) and stamped into
/// every entry file.
pub const CELL_SCHEMA: &str = "hroofline-cell-v1";

/// A content hash addressing one matrix cell: 32 lowercase hex chars
/// from [`crate::util::digest::StableHasher::finish_hex`]. Equal keys
/// mean bit-identical cell inputs (trace, spec, policy, workload,
/// store format) — the store never has to compare anything else.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CellKey(String);

impl CellKey {
    pub fn new(hex: String) -> CellKey {
        CellKey(hex)
    }

    /// The filesystem/wire form (the entry's file stem).
    pub fn as_hex(&self) -> &str {
        &self.0
    }
}

/// Outcome of a store probe. There is deliberately no error variant —
/// see the module docs.
#[derive(Debug)]
pub enum Lookup {
    /// A well-formed entry decoded to this profile.
    Hit(Profile),
    /// No entry on disk for this key.
    Miss,
    /// An entry exists but is unusable (truncated, wrong schema, wrong
    /// key, undecodable). Callers treat this as a miss and overwrite.
    Corrupt,
}

/// The on-disk cell store. Opened read-write on one directory for
/// `--incremental` runs, or as a read-only union over several shard
/// directories for `repro matrix --merge`.
#[derive(Clone, Debug)]
pub struct CellStore {
    /// Where [`CellStore::save`] writes; `None` for a merge union.
    write_dir: Option<PathBuf>,
    /// Probed in order by [`CellStore::load`]; the first existing entry
    /// file decides (hit or corrupt).
    read_dirs: Vec<PathBuf>,
}

impl CellStore {
    /// Open (creating if needed) a read-write store rooted at `dir`.
    pub fn open(dir: impl Into<PathBuf>) -> Result<CellStore> {
        let dir = dir.into();
        fs::create_dir_all(&dir)
            .with_context(|| format!("creating cell store dir {}", dir.display()))?;
        Ok(CellStore {
            read_dirs: vec![dir.clone()],
            write_dir: Some(dir),
        })
    }

    /// A read-only union over shard store directories, probed in the
    /// given order. Directories need not exist (an absent dir simply
    /// never hits).
    pub fn open_union(dirs: Vec<PathBuf>) -> CellStore {
        CellStore {
            write_dir: None,
            read_dirs: dirs,
        }
    }

    /// The write directory, when this store has one.
    pub fn dir(&self) -> Option<&Path> {
        self.write_dir.as_deref()
    }

    fn entry_path(dir: &Path, key: &CellKey) -> PathBuf {
        dir.join(format!("{}.json", key.as_hex()))
    }

    /// Probe the store for a key. Infallible by design: every failure
    /// mode maps to [`Lookup::Miss`] or [`Lookup::Corrupt`].
    pub fn load(&self, key: &CellKey) -> Lookup {
        for dir in &self.read_dirs {
            let path = Self::entry_path(dir, key);
            if !path.exists() {
                continue;
            }
            return match Self::decode(&path, key) {
                Some(profile) => Lookup::Hit(profile),
                None => Lookup::Corrupt,
            };
        }
        Lookup::Miss
    }

    /// Strict decode of one entry file; any defect is `None` (and the
    /// caller maps it to [`Lookup::Corrupt`]).
    fn decode(path: &Path, key: &CellKey) -> Option<Profile> {
        let text = fs::read_to_string(path).ok()?;
        // Json::parse is strict (trailing data / truncation are parse
        // errors), so a half-written or truncated entry lands here.
        let doc = Json::parse(&text).ok()?;
        if doc.get("schema").ok()?.as_str().ok()? != CELL_SCHEMA {
            return None;
        }
        if doc.get("key").ok()?.as_str().ok()? != key.as_hex() {
            return None;
        }
        profile_from_json(doc.get("profile").ok()?).ok()
    }

    /// Persist a cell's profile under its key: write-to-temp + rename,
    /// so a crashed or concurrent writer can leave at worst a stale
    /// `.tmp` turd, never a half-written entry under the final name.
    /// Returns the committed entry's byte count (feeds the
    /// `store.bytes_written` telemetry counter).
    pub fn save(&self, key: &CellKey, cell: &str, profile: &Profile) -> Result<u64> {
        let Some(dir) = &self.write_dir else {
            bail!("cell store opened as a read-only merge union");
        };
        let doc = Json::obj(vec![
            ("schema", Json::str(CELL_SCHEMA)),
            ("key", Json::str(key.as_hex())),
            ("cell", Json::str(cell)),
            ("profile", profile_to_json(profile)),
        ]);
        let path = Self::entry_path(dir, key);
        let tmp = dir.join(format!("{}.json.tmp", key.as_hex()));
        let text = doc.to_string_pretty();
        fs::write(&tmp, &text)
            .with_context(|| format!("writing cell entry {}", tmp.display()))?;
        fs::rename(&tmp, &path)
            .with_context(|| format!("publishing cell entry {}", path.display()))?;
        Ok(text.len() as u64)
    }

    /// Number of committed entries on disk (tests and CLI reporting).
    pub fn n_entries(&self) -> usize {
        let mut n = 0;
        for dir in &self.read_dirs {
            let Ok(rd) = fs::read_dir(dir) else { continue };
            n += rd
                .flatten()
                .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
                .count();
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{GpuSpec, Precision};
    use crate::profiler::{ProfileRequest, Session};
    use crate::sim::kernel::{KernelDesc, KernelInvocation};

    fn tmpdir(tag: &str) -> PathBuf {
        let dir =
            std::env::temp_dir().join(format!("hroofline-store-{tag}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn sample() -> (CellKey, Profile) {
        let spec = GpuSpec::v100();
        let trace = vec![KernelInvocation::once(KernelDesc::streaming_elementwise(
            "relu",
            1 << 16,
            Precision::Fp32,
            1,
        ))];
        let p = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        (CellKey::new("00112233445566778899aabbccddeeff".into()), p)
    }

    #[test]
    fn roundtrip_is_exact_and_missing_key_is_a_miss() {
        let dir = tmpdir("roundtrip");
        let store = CellStore::open(&dir).unwrap();
        let (key, profile) = sample();
        assert!(matches!(store.load(&key), Lookup::Miss));
        let bytes = store.save(&key, "deepcam-lite-pt-forward-O1", &profile).unwrap();
        assert!(bytes > 0, "save reports the committed entry size");
        assert_eq!(store.n_entries(), 1);
        match store.load(&key) {
            Lookup::Hit(back) => assert_eq!(back, profile, "store round-trip must be exact"),
            other => panic!("expected hit, got {other:?}"),
        }
        // No .tmp turd left behind.
        assert!(!dir.join(format!("{}.json.tmp", key.as_hex())).exists());
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_entry_is_corrupt_and_can_be_overwritten() {
        let dir = tmpdir("truncate");
        let store = CellStore::open(&dir).unwrap();
        let (key, profile) = sample();
        store.save(&key, "cell", &profile).unwrap();
        // Truncate the entry mid-JSON — the regression the satellite
        // task pins: this must read as Corrupt, never a hard error.
        let path = dir.join(format!("{}.json", key.as_hex()));
        let text = fs::read_to_string(&path).unwrap();
        fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(matches!(store.load(&key), Lookup::Corrupt));
        // Overwrite repairs it in place.
        store.save(&key, "cell", &profile).unwrap();
        assert!(matches!(store.load(&key), Lookup::Hit(_)));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn schema_and_key_mismatches_are_corrupt() {
        let dir = tmpdir("mismatch");
        let store = CellStore::open(&dir).unwrap();
        let (key, profile) = sample();
        store.save(&key, "cell", &profile).unwrap();
        let path = dir.join(format!("{}.json", key.as_hex()));

        // Version bump: same shape, different schema stamp.
        let stamped = fs::read_to_string(&path).unwrap().replace(CELL_SCHEMA, "hroofline-cell-v0");
        fs::write(&path, stamped).unwrap();
        assert!(matches!(store.load(&key), Lookup::Corrupt));

        // A well-formed entry filed under the wrong name (key mismatch).
        store.save(&key, "cell", &profile).unwrap();
        let other = CellKey::new("ffeeddccbbaa99887766554433221100".into());
        fs::copy(&path, dir.join(format!("{}.json", other.as_hex()))).unwrap();
        assert!(matches!(store.load(&other), Lookup::Corrupt));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn union_probes_shards_in_order_and_rejects_save() {
        let a = tmpdir("union-a");
        let b = tmpdir("union-b");
        let (key, profile) = sample();
        CellStore::open(&a).unwrap();
        CellStore::open(&b).unwrap().save(&key, "cell", &profile).unwrap();
        let union = CellStore::open_union(vec![a.clone(), b.clone(), tmpdir("union-absent")]);
        assert!(matches!(union.load(&key), Lookup::Hit(_)), "found in the second shard");
        assert_eq!(union.n_entries(), 1);
        assert!(union.save(&key, "cell", &profile).is_err(), "merge unions are read-only");
        let _ = fs::remove_dir_all(&a);
        let _ = fs::remove_dir_all(&b);
    }
}
