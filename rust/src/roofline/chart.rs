//! SVG rendering of hierarchical Roofline charts in the paper's idiom:
//! log-log axes (AI in FLOPs/byte vs performance in GFLOP/s), horizontal
//! compute ceilings, diagonal bandwidth ceilings, and per-kernel triplets
//! of open circles — blue (L1), red (L2), green (HBM) — with circle
//! radius proportional to aggregated kernel run time (Figs 3–9 reading
//! guide in §IV).

use std::fmt::Write as _;

use crate::device::MemLevel;
use crate::roofline::model::RooflineModel;
use crate::util::Table;

/// Chart dimensions and axis ranges.
#[derive(Clone, Debug)]
pub struct ChartConfig {
    pub width: u32,
    pub height: u32,
    pub title: String,
    /// AI axis range (log10 decades).
    pub ai_min: f64,
    pub ai_max: f64,
    /// Performance axis range, FLOP/s.
    pub perf_min: f64,
    pub perf_max: f64,
    /// Minimum/maximum circle radius in px ("we preset a minimum circle
    /// size to make all kernels visible", §IV).
    pub r_min: f64,
    pub r_max: f64,
    /// Annotate each point's HBM circle with its name — used by the
    /// scenario-matrix overlay chart, where a point is a whole scenario
    /// rather than one of hundreds of kernels.
    pub label_points: bool,
}

impl ChartConfig {
    pub fn paper_style(title: &str) -> ChartConfig {
        ChartConfig {
            width: 900,
            height: 620,
            title: title.to_string(),
            ai_min: 1e-2,
            ai_max: 1e4,
            perf_min: 1e9,  // 1 GFLOP/s
            perf_max: 2e14, // above the TC ceiling
            r_min: 4.0,
            r_max: 26.0,
            label_points: false,
        }
    }

    /// Overlay style: paper axes plus per-point name labels.
    pub fn overlay_style(title: &str) -> ChartConfig {
        ChartConfig { label_points: true, ..ChartConfig::paper_style(title) }
    }
}

/// A renderable chart: model + config.
pub struct RooflineChart<'a> {
    pub model: &'a RooflineModel,
    pub config: ChartConfig,
}

fn level_color(level: MemLevel) -> &'static str {
    match level {
        MemLevel::L1 => "#1f6fd0",  // blue
        MemLevel::L2 => "#d03030",  // red
        MemLevel::Hbm => "#1f9d3a", // green
    }
}

impl<'a> RooflineChart<'a> {
    pub fn new(model: &'a RooflineModel, config: ChartConfig) -> RooflineChart<'a> {
        RooflineChart { model, config }
    }

    /// Paper-styled hierarchical chart for a profile-derived model.
    pub fn hierarchical(model: &'a RooflineModel, title: &str) -> RooflineChart<'a> {
        RooflineChart::new(model, ChartConfig::paper_style(title))
    }

    /// Overlay chart: one labelled triplet per model point (the
    /// scenario-matrix cross-scenario view — each point aggregates a
    /// whole scenario).
    pub fn overlay(model: &'a RooflineModel, title: &str) -> RooflineChart<'a> {
        RooflineChart::new(model, ChartConfig::overlay_style(title))
    }

    // --- coordinate transforms (log-log) ---

    fn x(&self, ai: f64) -> f64 {
        let c = &self.config;
        let frac = (ai.max(1e-12).log10() - c.ai_min.log10())
            / (c.ai_max.log10() - c.ai_min.log10());
        60.0 + frac * (c.width as f64 - 90.0)
    }

    fn y(&self, perf: f64) -> f64 {
        let c = &self.config;
        let frac = (perf.max(1.0).log10() - c.perf_min.log10())
            / (c.perf_max.log10() - c.perf_min.log10());
        (c.height as f64 - 50.0) - frac * (c.height as f64 - 90.0)
    }

    fn radius(&self, seconds: f64, max_seconds: f64) -> f64 {
        let c = &self.config;
        if max_seconds <= 0.0 || seconds <= 0.0 {
            return c.r_min;
        }
        // Area ∝ runtime => radius ∝ sqrt(t).
        (c.r_min + (c.r_max - c.r_min) * (seconds / max_seconds).sqrt()).clamp(c.r_min, c.r_max)
    }

    /// Render the chart as a standalone SVG document.
    ///
    /// The output buffer is preallocated from the model's size (points
    /// dominate: one `<circle><title>…` element per (kernel, level)), so
    /// emission never reallocates mid-build; all rendering writes in
    /// place via `write!` rather than formatting temporaries.
    pub fn to_svg(&self) -> String {
        let c = &self.config;
        let ceilings =
            self.model.ceilings.compute.len() + self.model.ceilings.bandwidth.len();
        let labels = if self.config.label_points { 128 } else { 0 };
        let mut svg = String::with_capacity(
            8 * 1024
                + self.model.points.len() * (MemLevel::ALL.len() * 256 + 64 + labels)
                + ceilings * 256,
        );
        let _ = write!(
            svg,
            r##"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}" viewBox="0 0 {w} {h}">"##,
            w = c.width,
            h = c.height
        );
        let _ = write!(
            svg,
            r##"<rect width="{}" height="{}" fill="white"/>"##,
            c.width, c.height
        );
        let _ = write!(
            svg,
            r##"<text x="{}" y="24" text-anchor="middle" font-size="16" font-family="sans-serif">{}</text>"##,
            c.width / 2,
            xml_escape(&c.title)
        );

        self.push_axes(&mut svg);
        self.push_bandwidth_ceilings(&mut svg);
        self.push_compute_ceilings(&mut svg);
        self.push_points(&mut svg);
        self.push_legend(&mut svg);

        svg.push_str("</svg>\n");
        svg
    }

    fn push_axes(&self, svg: &mut String) {
        let c = &self.config;
        let x0 = 60.0;
        let x1 = c.width as f64 - 30.0;
        let y0 = c.height as f64 - 50.0;
        let y1 = 40.0;
        let _ = write!(
            svg,
            r##"<line x1="{x0}" y1="{y0}" x2="{x1}" y2="{y0}" stroke="black"/><line x1="{x0}" y1="{y0}" x2="{x0}" y2="{y1}" stroke="black"/>"##
        );
        // Decade gridlines + labels.
        let mut ai = self.config.ai_min;
        while ai <= self.config.ai_max * 1.0001 {
            let x = self.x(ai);
            let _ = write!(
                svg,
                r##"<line x1="{x}" y1="{y0}" x2="{x}" y2="{y1}" stroke="#eeeeee"/><text x="{x}" y="{ly}" text-anchor="middle" font-size="10" font-family="sans-serif">{label}</text>"##,
                ly = y0 + 16.0,
                label = pow10_label(ai),
            );
            ai *= 10.0;
        }
        let mut perf = self.config.perf_min;
        while perf <= self.config.perf_max * 1.0001 {
            let y = self.y(perf);
            let _ = write!(
                svg,
                r##"<line x1="{x0}" y1="{y}" x2="{x1}" y2="{y}" stroke="#eeeeee"/><text x="{lx}" y="{yt}" text-anchor="end" font-size="10" font-family="sans-serif">{label}</text>"##,
                lx = x0 - 6.0,
                yt = y + 3.0,
                label = perf_label(perf),
            );
            perf *= 10.0;
        }
        let _ = write!(
            svg,
            r##"<text x="{cx}" y="{by}" text-anchor="middle" font-size="12" font-family="sans-serif">Arithmetic Intensity (FLOPs/Byte)</text>"##,
            cx = (x0 + x1) / 2.0,
            by = self.config.height as f64 - 14.0
        );
        let _ = write!(
            svg,
            r##"<text x="18" y="{cy}" text-anchor="middle" font-size="12" font-family="sans-serif" transform="rotate(-90 18 {cy})">Performance (FLOP/s)</text>"##,
            cy = (y0 + y1) / 2.0
        );
    }

    fn push_compute_ceilings(&self, svg: &mut String) {
        for ceil in &self.model.ceilings.compute {
            let y = self.y(ceil.flops_per_sec);
            let x0 = 60.0;
            let x1 = self.config.width as f64 - 30.0;
            let _ = write!(
                svg,
                r##"<line x1="{x0}" y1="{y}" x2="{x1}" y2="{y}" stroke="#444444" stroke-dasharray="6,3"/><text x="{tx}" y="{ty}" text-anchor="end" font-size="10" font-family="sans-serif" fill="#333333">{label}</text>"##,
                tx = x1 - 4.0,
                ty = y - 4.0,
                label = xml_escape(&ceil.label),
            );
        }
    }

    fn push_bandwidth_ceilings(&self, svg: &mut String) {
        let c = &self.config;
        // Cross-device overlays carry several ceilings per level (one
        // per device, same color); repeats render dashed so the devices
        // stay tellable apart. Single-device charts are unaffected.
        let mut seen_levels: Vec<MemLevel> = Vec::new();
        for bw in &self.model.ceilings.bandwidth {
            let repeat = seen_levels.contains(&bw.level);
            seen_levels.push(bw.level);
            // perf = AI * BW ; clip at this ceiling's own compute roof
            // (its device's, for merged cross-device sets), else the
            // set's global maximum.
            let max_perf =
                bw.clip_flops_per_sec.unwrap_or_else(|| self.model.ceilings.max_flops());
            let ai_start = c.ai_min;
            let perf_start = ai_start * bw.bytes_per_sec;
            let ai_end = (max_perf / bw.bytes_per_sec).min(c.ai_max);
            let (x0, y0) = (self.x(ai_start), self.y(perf_start));
            let (x1, y1) = (self.x(ai_end), self.y(ai_end * bw.bytes_per_sec));
            let _ = write!(
                svg,
                r##"<line x1="{x0:.1}" y1="{y0:.1}" x2="{x1:.1}" y2="{y1:.1}" stroke="{color}" stroke-width="1.2"{dash}/><text x="{tx:.1}" y="{ty:.1}" font-size="10" font-family="sans-serif" fill="{color}">{label}</text>"##,
                color = level_color(bw.level),
                dash = if repeat { r#" stroke-dasharray="5,4""# } else { "" },
                tx = x0 + 8.0,
                ty = y0 - 6.0,
                label = xml_escape(&bw.label),
            );
        }
    }

    fn push_points(&self, svg: &mut String) {
        let max_secs = self
            .model
            .points
            .iter()
            .map(|p| p.seconds)
            .fold(0.0, f64::max);
        for p in &self.model.points {
            let r = self.radius(p.seconds, max_secs);
            let y = self.y(p.flops_per_sec);
            for &(level, ai) in &p.ai {
                let x = self.x(ai);
                let _ = write!(
                    svg,
                    r##"<circle cx="{x:.1}" cy="{y:.1}" r="{r:.1}" fill="none" stroke="{color}" stroke-width="1.5"><title>{name} [{lvl}] AI={ai:.3} perf={perf:.3e} t={t:.3e}s inv={inv}</title></circle>"##,
                    color = level_color(level),
                    name = xml_escape(&p.name),
                    lvl = level.name(),
                    perf = p.flops_per_sec,
                    t = p.seconds,
                    inv = p.invocations,
                );
            }
            if self.config.label_points {
                // Anchor the label at the rightmost (highest-AI) circle
                // of the triplet — with any cache reuse the fewest bytes
                // (hence highest AI) are at HBM.
                let ai_max = p.ai.iter().map(|&(_, a)| a).fold(0.0, f64::max);
                let lx = self.x(ai_max) + r + 4.0;
                let _ = write!(
                    svg,
                    r##"<text x="{lx:.1}" y="{ty:.1}" font-size="9" font-family="sans-serif" fill="#333333">{label}</text>"##,
                    ty = y + 3.0,
                    label = xml_escape(&truncate(&p.name, 34)),
                );
            }
        }
    }

    fn push_legend(&self, svg: &mut String) {
        let x = 70.0;
        let mut y = 50.0;
        for level in MemLevel::ALL {
            let _ = write!(
                svg,
                r##"<circle cx="{x}" cy="{y}" r="5" fill="none" stroke="{color}" stroke-width="1.5"/><text x="{tx}" y="{ty}" font-size="11" font-family="sans-serif">{name}</text>"##,
                color = level_color(level),
                tx = x + 10.0,
                ty = y + 4.0,
                name = level.name(),
            );
            y += 16.0;
        }
        let _ = write!(
            svg,
            r##"<text x="{x}" y="{y}" font-size="10" font-family="sans-serif" fill="#555555">circle area &#8733; kernel time &#8212; {}</text>"##,
            xml_escape(&self.model.device_name),
        );
    }

    /// Text rendering of the dataset (kernel table), for terminals and
    /// EXPERIMENTS.md.
    pub fn to_table(&self) -> Table {
        use crate::util::table::Align;
        let mut t = Table::new(&[
            "kernel", "time", "GFLOP/s", "AI(L1)", "AI(L2)", "AI(HBM)", "TC", "inv",
        ])
        .aligns(&[
            Align::Left,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Right,
            Align::Left,
            Align::Right,
        ]);
        for p in &self.model.points {
            let ai_of = |lvl: MemLevel| -> String {
                p.ai
                    .iter()
                    .find(|(l, _)| *l == lvl)
                    .map(|(_, a)| format!("{a:.2}"))
                    .unwrap_or_else(|| "-".into())
            };
            t.row(&[
                truncate(&p.name, 40),
                crate::util::fmt::duration(p.seconds),
                format!("{:.1}", p.flops_per_sec / 1e9),
                ai_of(MemLevel::L1),
                ai_of(MemLevel::L2),
                ai_of(MemLevel::Hbm),
                if p.tensor_dominated { "yes" } else { "no" }.to_string(),
                p.invocations.to_string(),
            ]);
        }
        t
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let cut: String = s.chars().take(n - 1).collect();
        format!("{cut}…")
    }
}

fn xml_escape(s: &str) -> String {
    s.replace('&', "&amp;")
        .replace('<', "&lt;")
        .replace('>', "&gt;")
        .replace('"', "&quot;")
}

fn pow10_label(v: f64) -> String {
    let e = v.log10().round() as i32;
    match e {
        0 => "1".into(),
        1 => "10".into(),
        2 => "100".into(),
        _ => format!("1e{e}"),
    }
}

fn perf_label(v: f64) -> String {
    crate::util::fmt::si_flops(v)
        .replace(" FLOP/s", "")
        + "F"
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{GpuSpec, Precision};
    use crate::profiler::{ProfileRequest, Session};
    use crate::roofline::model::RooflineModel;
    use crate::sim::kernel::{KernelDesc, KernelInvocation};

    fn example_model() -> (GpuSpec, RooflineModel) {
        let spec = GpuSpec::v100();
        let trace = vec![
            KernelInvocation::once(KernelDesc::gemm(
                "volta_h884gemm", 4096, 4096, 4096, Precision::Fp16, true, 128, &spec,
            )),
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("relu", 1 << 20, Precision::Fp32, 1),
                invocations: 20,
                stream: 0,
            },
        ];
        let profile = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        let model = RooflineModel::from_profile(&spec, &profile);
        (spec, model)
    }

    #[test]
    fn svg_is_well_formed_and_complete() {
        let (_, model) = example_model();
        let chart = RooflineChart::hierarchical(&model, "Test chart");
        let svg = chart.to_svg();
        assert!(svg.starts_with("<svg"));
        assert!(svg.trim_end().ends_with("</svg>"));
        // One circle per (kernel, level): 2 kernels x 3 levels.
        assert_eq!(svg.matches("<circle").count(), 6 + 3 /* legend */);
        // All ceilings drawn.
        assert_eq!(svg.matches("stroke-dasharray").count(), 4);
        // Colors for the three levels present.
        for color in ["#1f6fd0", "#d03030", "#1f9d3a"] {
            assert!(svg.contains(color));
        }
    }

    #[test]
    fn svg_buffer_preallocation_covers_output() {
        // The capacity estimate must dominate the real output so the
        // buffer never reallocates mid-emit.
        let (_, model) = example_model();
        let chart = RooflineChart::hierarchical(&model, "Preallocation check");
        let svg = chart.to_svg();
        let ceilings = model.ceilings.compute.len() + model.ceilings.bandwidth.len();
        let cap = 8 * 1024
            + model.points.len() * (MemLevel::ALL.len() * 256 + 64)
            + ceilings * 256;
        assert!(svg.len() <= cap, "svg {} > preallocated {}", svg.len(), cap);
    }

    #[test]
    fn overlay_chart_labels_every_point() {
        let (_, model) = example_model();
        let chart = RooflineChart::overlay(&model, "Overlay");
        let svg = chart.to_svg();
        for p in &model.points {
            // Name appears in both the <title> hover and the visible label.
            assert!(svg.matches(p.name.as_str()).count() >= 2, "{}", p.name);
        }
        // Paper-style charts stay label-free.
        let plain = RooflineChart::hierarchical(&model, "Plain").to_svg();
        assert_eq!(plain.matches("font-size=\"9\"").count(), 0);
    }

    #[test]
    fn bigger_kernels_bigger_circles() {
        let (_, model) = example_model();
        let chart = RooflineChart::hierarchical(&model, "t");
        let max_t = model.points.iter().map(|p| p.seconds).fold(0.0, f64::max);
        let radii: Vec<f64> = model
            .points
            .iter()
            .map(|p| chart.radius(p.seconds, max_t))
            .collect();
        // points are sorted descending by time
        assert!(radii[0] >= radii[1]);
        assert!(radii.iter().all(|&r| r >= chart.config.r_min - 1e-9));
        assert!(radii.iter().all(|&r| r <= chart.config.r_max + 1e-9));
    }

    #[test]
    fn coordinates_monotone() {
        let (_, model) = example_model();
        let chart = RooflineChart::hierarchical(&model, "t");
        assert!(chart.x(10.0) > chart.x(1.0));
        assert!(chart.y(1e12) < chart.y(1e10)); // higher perf = higher on screen (lower y)
    }

    #[test]
    fn table_lists_all_points() {
        let (_, model) = example_model();
        let chart = RooflineChart::hierarchical(&model, "t");
        let table = chart.to_table();
        assert_eq!(table.n_rows(), model.points.len());
        let text = table.render();
        assert!(text.contains("volta_h884gemm"));
    }

    #[test]
    fn xml_escaping() {
        assert_eq!(xml_escape("a<b>&\"c\""), "a&lt;b&gt;&amp;&quot;c&quot;");
    }
}
