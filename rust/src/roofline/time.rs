//! Time-based Roofline renderings (Wang et al., *Time-Based Roofline
//! for Deep Learning Performance Analysis*, arXiv 2009.04598): the
//! position-on-the-chart view answers "how efficient is this kernel",
//! the time view answers "where did the milliseconds go". Three
//! renderings:
//!
//! * [`step_table`] — per-phase step-time breakdown: elapsed time,
//!   share of step, and the compute-/memory-/overhead-bound buckets,
//!   plus the step-wide idle (launch/drain ramp) component;
//! * [`kernel_time_table`] — per-kernel durations, shares and bounds,
//!   hottest first;
//! * [`time_weighted_svg`] — the paper-style hierarchical chart with
//!   every dot labelled by its share of step time (dot area is already
//!   ∝ kernel run time in the base chart).

use crate::device::GpuSpec;
use crate::profiler::profile::Profile;
use crate::profiler::timeline::StepTimeline;
use crate::roofline::chart::RooflineChart;
use crate::roofline::model::RooflineModel;
use crate::util::fmt;
use crate::util::Table;

/// Step-time breakdown table: one row per phase, an idle component row
/// (launch/drain ramp summed over every kernel — a *component* of the
/// phase times, not an extra addend), and a "step total" row. Per-phase
/// times sum to the step total by construction.
pub fn step_table(t: &StepTimeline) -> Table {
    let mut tb = Table::new(&[
        "phase",
        "time",
        "step%",
        "compute-bound",
        "memory-bound",
        "overhead-bound",
        "kernels",
        "inv",
    ]);
    let step = t.step_seconds();
    let share = |x: f64| {
        if step > 0.0 {
            fmt::pct(x / step)
        } else {
            "-".to_string()
        }
    };
    let bucket = |x: f64, of: f64| {
        if of > 0.0 {
            format!("{} ({})", fmt::duration(x), fmt::pct(x / of))
        } else {
            "-".to_string()
        }
    };
    for p in &t.phases {
        tb.row(&[
            p.label.clone(),
            fmt::duration(p.seconds),
            share(p.seconds),
            bucket(p.compute_s, p.seconds),
            bucket(p.memory_s, p.seconds),
            bucket(p.overhead_s, p.seconds),
            p.kernels.to_string(),
            p.invocations.to_string(),
        ]);
    }
    tb.row(&[
        "idle (launch/drain)".to_string(),
        fmt::duration(t.idle_seconds()),
        share(t.idle_seconds()),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
        "-".to_string(),
    ]);
    let (c, m, o) = t.bucket_seconds();
    tb.row(&[
        "step total".to_string(),
        fmt::duration(step),
        if step > 0.0 { "100.0%".to_string() } else { "-".to_string() },
        bucket(c, step),
        bucket(m, step),
        bucket(o, step),
        t.total_kernels().to_string(),
        t.total_invocations().to_string(),
    ]);
    tb
}

/// Per-kernel "where the milliseconds went" table, hottest first.
pub fn kernel_time_table(profile: &Profile) -> Table {
    let mut tb = Table::new(&["kernel", "time", "share", "bound", "compute", "memory", "ramp"]);
    let total: f64 = profile.kernels().map(|k| k.duration_s()).sum();
    let mut kernels: Vec<_> = profile.kernels().collect();
    // total_cmp: NaN durations (conceivable from ingested traces) must
    // not panic the report; identical to partial_cmp on finite values.
    kernels.sort_by(|a, b| b.duration_s().total_cmp(&a.duration_s()));
    for k in kernels {
        let (bound, compute, memory, ramp) = match &k.timing {
            Some(t) => (
                t.bound().name().to_string(),
                fmt::duration(t.compute_s),
                fmt::duration(t.memory_s),
                fmt::duration(t.ramp_s),
            ),
            None => ("-".to_string(), "-".to_string(), "-".to_string(), "-".to_string()),
        };
        tb.row(&[
            k.name.clone(),
            fmt::duration(k.duration_s()),
            if total > 0.0 { fmt::pct(k.duration_s() / total) } else { "-".to_string() },
            bound,
            compute,
            memory,
            ramp,
        ]);
    }
    tb
}

/// Time-weighted hierarchical Roofline chart: the paper-style triplet
/// scatter with every kernel's label carrying its share of step time
/// (dot area is ∝ run time already — `ChartConfig::r_min/r_max` scale
/// by `sqrt(seconds)`). Returns `None` when the profile contributes no
/// plottable points (all-zero-AI or empty).
pub fn time_weighted_svg(spec: &GpuSpec, profile: &Profile, title: &str) -> Option<String> {
    let mut model = RooflineModel::from_profile(spec, profile);
    if model.points.is_empty() {
        return None;
    }
    let total: f64 = profile.kernels().map(|k| k.duration_s()).sum();
    if total <= 0.0 {
        return None;
    }
    // Shares are of the *whole* profile time, zero-AI kernels included
    // — the labels answer "what fraction of the step is this dot".
    let shares: Vec<String> = model
        .points
        .iter()
        .map(|p| {
            let d = profile.kernel(&p.name).map(|k| k.duration_s()).unwrap_or(p.seconds);
            fmt::pct(d / total)
        })
        .collect();
    for (p, share) in model.points.iter_mut().zip(shares) {
        p.name = format!("{} [{share}]", p.name);
    }
    Some(RooflineChart::overlay(&model, title).to_svg())
}

/// The standard `timeline.txt` lane payload: step-time breakdown +
/// per-kernel timing, under one title.
pub fn timeline_text(title: &str, timeline: &StepTimeline, profile: &Profile) -> String {
    format!(
        "== {title} — time-based Roofline ==\ndevice: {}\n\nstep-time breakdown:\n{}\n\
         per-kernel timing (hottest first):\n{}",
        timeline.device,
        step_table(timeline).render(),
        kernel_time_table(profile).render(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::profiler::{ProfileRequest, Session};
    use crate::sim::kernel::{KernelDesc, KernelInvocation};

    fn trace(spec: &GpuSpec) -> Vec<KernelInvocation> {
        vec![
            KernelInvocation {
                kernel: KernelDesc::gemm("hmma", 1024, 1024, 1024, Precision::Fp16, true, 64, spec),
                invocations: 3,
                stream: 0,
            },
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("relu", 1 << 20, Precision::Fp32, 1),
                invocations: 5,
                stream: 0,
            },
        ]
    }

    #[test]
    fn step_table_rows_and_totals() {
        let spec = GpuSpec::v100();
        let p = Session::standard(&spec).run(&ProfileRequest::new(&trace(&spec))).unwrap();
        let mut t = StepTimeline::new(&spec.name);
        t.push_phase("forward", &p);
        let text = step_table(&t).render();
        assert!(text.contains("forward"));
        assert!(text.contains("idle (launch/drain)"));
        assert!(text.contains("step total"));
        assert!(text.contains("100.0%"));
    }

    #[test]
    fn kernel_table_is_sorted_and_bounded() {
        let spec = GpuSpec::v100();
        let p = Session::standard(&spec).run(&ProfileRequest::new(&trace(&spec))).unwrap();
        let text = kernel_time_table(&p).render();
        assert!(text.contains("hmma"));
        assert!(text.contains("relu"));
        // Both bound labels appear: the tensor GEMM is compute-bound,
        // the big streaming kernel memory-bound.
        assert!(text.contains("compute"));
        assert!(text.contains("memory"));
    }

    #[test]
    fn time_weighted_chart_labels_shares() {
        let spec = GpuSpec::v100();
        let p = Session::standard(&spec).run(&ProfileRequest::new(&trace(&spec))).unwrap();
        let svg = time_weighted_svg(&spec, &p, "t").unwrap();
        assert!(svg.starts_with("<svg"));
        assert!(svg.contains('%'), "labels carry time shares");
        assert!(svg.contains("hmma ["));
        // Empty profile → no chart.
        assert!(time_weighted_svg(&spec, &Profile::for_device(&spec), "t").is_none());
    }
}
