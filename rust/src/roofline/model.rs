//! Roofline model arithmetic (paper Eq. 1) and hierarchical point
//! extraction from profiles.

use crate::device::{GpuSpec, MemLevel, Precision};
use crate::profiler::profile::{KernelProfile, Profile};

/// A compute ceiling: a horizontal line on the Roofline chart.
#[derive(Clone, Debug, PartialEq)]
pub struct ComputeCeiling {
    pub label: String,
    pub flops_per_sec: f64,
}

/// A bandwidth ceiling: a diagonal (perf = AI × BW) on the chart.
#[derive(Clone, Debug, PartialEq)]
pub struct BandwidthCeiling {
    pub label: String,
    pub level: MemLevel,
    pub bytes_per_sec: f64,
    /// Compute roof this diagonal clips at on the chart. `None` = the
    /// ceiling set's global [`Ceilings::max_flops`] (the single-device
    /// case); merged cross-device sets pin each diagonal to its own
    /// device's roof so a slower device's bandwidth lines never extend
    /// past that device's peak.
    pub clip_flops_per_sec: Option<f64>,
}

/// The full ceiling set for a device (Fig. 1).
#[derive(Clone, Debug)]
pub struct Ceilings {
    pub compute: Vec<ComputeCeiling>,
    pub bandwidth: Vec<BandwidthCeiling>,
}

impl Ceilings {
    /// Build ceilings from a device's achievable (ERT-calibrated) peaks.
    pub fn from_spec(spec: &GpuSpec) -> Ceilings {
        let mut compute = vec![ComputeCeiling {
            label: format!(
                "Tensor Core: {}",
                crate::util::fmt::si_flops(spec.achievable_tensor_flops())
            ),
            flops_per_sec: spec.achievable_tensor_flops(),
        }];
        for p in Precision::ALL {
            compute.push(ComputeCeiling {
                label: format!(
                    "{}: {}",
                    p.name(),
                    crate::util::fmt::si_flops(spec.achievable_flops(p))
                ),
                flops_per_sec: spec.achievable_flops(p),
            });
        }
        let bandwidth = MemLevel::ALL
            .iter()
            .map(|&level| BandwidthCeiling {
                label: format!(
                    "{}: {}/s",
                    level.name(),
                    crate::util::fmt::si_bytes(spec.bandwidth(level))
                ),
                level,
                bytes_per_sec: spec.bandwidth(level),
                clip_flops_per_sec: None,
            })
            .collect();
        Ceilings { compute, bandwidth }
    }

    /// Union of several devices' headline ceilings, device-tagged — the
    /// cross-device overlay chart. To keep the chart readable each
    /// device contributes its *top* compute ceiling (the tensor roof)
    /// plus all bandwidth diagonals; the full per-device ceiling set
    /// lives in that device's own artifact.
    pub fn merged<'a, I>(specs: I) -> Ceilings
    where
        I: IntoIterator<Item = &'a GpuSpec>,
    {
        let mut compute = Vec::new();
        let mut bandwidth = Vec::new();
        for spec in specs {
            let own = Ceilings::from_spec(spec);
            let roof = own.max_flops();
            if let Some(top) = own
                .compute
                .iter()
                .max_by(|a, b| a.flops_per_sec.total_cmp(&b.flops_per_sec))
            {
                compute.push(ComputeCeiling {
                    label: format!("{} {}", spec.name, top.label),
                    flops_per_sec: top.flops_per_sec,
                });
            }
            bandwidth.extend(own.bandwidth.into_iter().map(|b| BandwidthCeiling {
                label: format!("{} {}", spec.name, b.label),
                level: b.level,
                bytes_per_sec: b.bytes_per_sec,
                // Clip at this device's own roof, not the overlay's
                // global maximum — see the field docs.
                clip_flops_per_sec: Some(roof),
            }));
        }
        Ceilings { compute, bandwidth }
    }

    /// Highest compute ceiling (chart top).
    pub fn max_flops(&self) -> f64 {
        self.compute
            .iter()
            .map(|c| c.flops_per_sec)
            .fold(0.0, f64::max)
    }

    /// The Roofline bound for a given AI at a given memory level against
    /// the *highest* compute ceiling:
    /// `min(peak_flops, AI × BW(level))` (Eq. 1).
    pub fn bound(&self, level: MemLevel, ai: f64) -> f64 {
        let bw = self
            .bandwidth
            .iter()
            .find(|b| b.level == level)
            .map(|b| b.bytes_per_sec)
            .unwrap_or(0.0);
        (ai * bw).min(self.max_flops())
    }
}

/// One kernel's position on the hierarchical chart: a triplet of
/// (AI, perf) points sharing one performance value (perf is
/// level-independent; AI varies with the byte denominator).
#[derive(Clone, Debug)]
pub struct KernelPoint {
    pub name: String,
    pub seconds: f64,
    pub flops_per_sec: f64,
    /// (level, AI) for every level with traffic.
    pub ai: Vec<(MemLevel, f64)>,
    pub tensor_dominated: bool,
    pub invocations: u64,
}

impl KernelPoint {
    pub fn from_profile(k: &KernelProfile) -> Option<KernelPoint> {
        if k.is_zero_ai() {
            return None; // zero-AI kernels don't appear on the chart (AI=0 → log axis)
        }
        let ai: Vec<(MemLevel, f64)> = MemLevel::ALL
            .iter()
            .filter_map(|&l| k.ai(l).map(|v| (l, v)))
            .collect();
        if ai.is_empty() {
            return None;
        }
        Some(KernelPoint {
            name: k.name.clone(),
            seconds: k.seconds(),
            flops_per_sec: k.flops_per_sec(),
            ai,
            tensor_dominated: k.is_tensor_dominated(),
            invocations: k.invocations,
        })
    }

    /// "Streaming" signature: AI nearly equal across levels (triplet
    /// circles overlap — poor locality everywhere, paper §IV).
    pub fn is_streaming(&self) -> bool {
        let ais: Vec<f64> = self.ai.iter().map(|(_, a)| *a).collect();
        if ais.len() < 2 {
            return true;
        }
        let max = ais.iter().cloned().fold(f64::MIN, f64::max);
        let min = ais.iter().cloned().fold(f64::MAX, f64::min);
        max / min < 1.5
    }
}

/// Sort chart points longest-running first (big circles render under
/// small ones). NaN-safe: a NaN-seconds point — possible once real
/// ingested traces feed the chart — lands at a deterministic position
/// under [`f64::total_cmp`]'s total order instead of panicking the
/// render the way `partial_cmp(..).unwrap()` did. For the ordinary
/// all-finite case the ordering is identical to the historical one.
pub fn sort_points_hot_first(points: &mut [KernelPoint]) {
    points.sort_by(|a, b| b.seconds.total_cmp(&a.seconds));
}

/// A complete hierarchical Roofline dataset: ceilings + kernel points.
#[derive(Clone, Debug)]
pub struct RooflineModel {
    pub ceilings: Ceilings,
    pub points: Vec<KernelPoint>,
    pub device_name: String,
}

impl RooflineModel {
    /// Build from a profile on a device.
    pub fn from_profile(spec: &GpuSpec, profile: &Profile) -> RooflineModel {
        let mut points: Vec<KernelPoint> = profile
            .kernels()
            .filter_map(KernelPoint::from_profile)
            .collect();
        sort_points_hot_first(&mut points);
        RooflineModel {
            ceilings: Ceilings::from_spec(spec),
            points,
            device_name: spec.name.clone(),
        }
    }

    /// Verify the throughput bound: no kernel exceeds its Roofline at any
    /// level (used as a post-profile validity check; the simulator is
    /// roofline-consistent by construction, but the *profiler* pipeline
    /// could corrupt data — this is the end-to-end guard).
    pub fn validate_bounds(&self) -> Result<(), String> {
        for p in &self.points {
            for &(level, ai) in &p.ai {
                // Achievable ceilings are empirical; allow a small slack.
                let bound = self.ceilings.bound(level, ai) * 1.10;
                if p.flops_per_sec > bound {
                    return Err(format!(
                        "kernel '{}' exceeds {} roofline: {:.3e} > {:.3e} at AI {:.3}",
                        p.name, level.name(), p.flops_per_sec, bound, ai
                    ));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::profiler::{ProfileRequest, Session};
    use crate::sim::kernel::{KernelDesc, KernelInvocation};

    #[test]
    fn ceilings_match_fig1() {
        let spec = GpuSpec::v100();
        let c = Ceilings::from_spec(&spec);
        assert_eq!(c.compute.len(), 4); // TC + 3 precisions
        assert_eq!(c.bandwidth.len(), 3);
        assert!((c.max_flops() / 1e12 - 103.7).abs() < 0.2);
    }

    #[test]
    fn bound_is_min_of_two_terms() {
        let spec = GpuSpec::v100();
        let c = Ceilings::from_spec(&spec);
        // Very low AI: bandwidth-bound.
        let low = c.bound(MemLevel::Hbm, 0.1);
        assert!((low - 0.1 * spec.hbm_bytes_per_sec).abs() < 1.0);
        // Very high AI: compute-bound.
        let high = c.bound(MemLevel::Hbm, 1e6);
        assert_eq!(high, c.max_flops());
    }

    #[test]
    fn model_from_profile_drops_zero_ai() {
        let spec = GpuSpec::v100();
        let trace = vec![
            KernelInvocation::once(KernelDesc::streaming_elementwise(
                "fma", 1 << 18, Precision::Fp32, 2,
            )),
            KernelInvocation::once(KernelDesc::streaming_elementwise(
                "cast", 1 << 18, Precision::Fp16, 0,
            )),
        ];
        let profile = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        let model = RooflineModel::from_profile(&spec, &profile);
        assert_eq!(model.points.len(), 1);
        assert_eq!(model.points[0].name, "fma");
    }

    #[test]
    fn streaming_signature_detected() {
        let spec = GpuSpec::v100();
        let trace = vec![KernelInvocation::once(KernelDesc::streaming_elementwise(
            "stream", 1 << 22, Precision::Fp32, 1,
        ))];
        let profile = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        let model = RooflineModel::from_profile(&spec, &profile);
        assert!(model.points[0].is_streaming());
    }

    #[test]
    fn gemm_not_streaming() {
        let spec = GpuSpec::v100();
        let g = KernelDesc::gemm("g", 2048, 2048, 2048, Precision::Fp16, true, 64, &spec);
        let profile = Session::standard(&spec)
            .run(&ProfileRequest::new(&[KernelInvocation::once(g)]))
            .unwrap();
        let model = RooflineModel::from_profile(&spec, &profile);
        assert!(!model.points[0].is_streaming());
    }

    #[test]
    fn merged_ceilings_tag_labels_with_device_names() {
        let v100 = GpuSpec::v100();
        let a100 = GpuSpec::a100();
        let m = Ceilings::merged([&v100, &a100]);
        // One top compute ceiling per device, all bandwidths per device.
        assert_eq!(m.compute.len(), 2);
        assert_eq!(m.bandwidth.len(), 6);
        assert!(m.compute.iter().any(|c| c.label.starts_with("V100-SXM2-16GB")));
        assert!(m.compute.iter().any(|c| c.label.starts_with("A100-SXM4-40GB")));
        assert!((m.max_flops() - a100.achievable_tensor_flops()).abs() < 1.0);
        // Each device's diagonals clip at that device's own roof, not
        // the overlay's global (A100) maximum.
        for b in &m.bandwidth {
            let roof = b.clip_flops_per_sec.unwrap();
            if b.label.starts_with("V100") {
                assert!((roof - v100.achievable_tensor_flops()).abs() < 1.0, "{}", b.label);
            } else {
                assert!((roof - a100.achievable_tensor_flops()).abs() < 1.0, "{}", b.label);
            }
        }
        // Single-device ceilings stay unclipped (global max = own roof).
        assert!(Ceilings::from_spec(&v100)
            .bandwidth
            .iter()
            .all(|b| b.clip_flops_per_sec.is_none()));
        // `bound` keeps working (first matching level wins — the
        // first-listed device, which is the comparison baseline).
        assert!(m.bound(MemLevel::Hbm, 0.1) > 0.0);
    }

    #[test]
    fn sort_points_survives_nan_seconds() {
        // Regression: the hot-first sort used partial_cmp().unwrap()
        // and panicked on NaN seconds; total_cmp must not.
        let point = |name: &str, seconds: f64| KernelPoint {
            name: name.into(),
            seconds,
            flops_per_sec: 1e12,
            ai: vec![(MemLevel::Hbm, 1.0)],
            tensor_dominated: false,
            invocations: 1,
        };
        let mut points = vec![
            point("fast", 1e-6),
            point("broken", f64::NAN),
            point("slow", 2e-3),
            point("mid", 4e-5),
        ];
        sort_points_hot_first(&mut points);
        // Finite points keep the descending order; the NaN point lands
        // deterministically (total order) rather than panicking.
        let finite: Vec<&str> = points
            .iter()
            .filter(|p| p.seconds.is_finite())
            .map(|p| p.name.as_str())
            .collect();
        assert_eq!(finite, ["slow", "mid", "fast"]);
        assert_eq!(points.len(), 4);
    }

    #[test]
    fn validate_bounds_passes_for_simulated_profiles() {
        let spec = GpuSpec::v100();
        let trace = vec![
            KernelInvocation::once(KernelDesc::gemm(
                "g", 4096, 4096, 4096, Precision::Fp16, true, 128, &spec,
            )),
            KernelInvocation::once(KernelDesc::streaming_elementwise(
                "s", 1 << 20, Precision::Fp32, 8,
            )),
        ];
        let profile = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        let model = RooflineModel::from_profile(&spec, &profile);
        model.validate_bounds().unwrap();
    }
}
