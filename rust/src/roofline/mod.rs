//! The hierarchical Roofline model and its renderings.
//!
//! * [`model`] — ceilings (compute + per-level bandwidth), Roofline
//!   bound evaluation (paper Eq. 1), per-kernel hierarchical points.
//! * [`chart`] — log-log SVG scatter charts in the paper's visual
//!   idiom: blue/red/green circles for L1/L2/HBM, circle area ∝ kernel
//!   run time, diagonal bandwidth ceilings, horizontal compute ceilings
//!   (Figs 1, 3–9).
//! * [`time`] — time-based Roofline renderings (arXiv 2009.04598):
//!   step-time breakdown tables and time-weighted charts.

pub mod chart;
pub mod model;
pub mod time;

pub use chart::{ChartConfig, RooflineChart};
pub use model::{Ceilings, KernelPoint, RooflineModel};
