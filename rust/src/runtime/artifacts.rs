//! Artifact store: the contract between `python/compile/aot.py` and the
//! Rust runtime.
//!
//! `artifacts/manifest.json` describes every lowered module: its HLO
//! text file, parameter/output shapes and dtypes, and bookkeeping the
//! profiler wants (analytic FLOPs per execution, parameter counts).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::error::{bail, Context, Result};
use crate::util::Json;

/// Shape + dtype of one runtime tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub dims: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn n_elems(&self) -> usize {
        self.dims.iter().product()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dims = j
            .get("dims")?
            .as_arr()?
            .iter()
            .map(|d| Ok(d.as_usize()?))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec {
            dims,
            dtype: j.get("dtype")?.as_str()?.to_string(),
        })
    }
}

/// One AOT-compiled module's manifest entry.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    /// HLO text path, relative to the artifacts dir.
    pub hlo_file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    /// Analytic FLOPs per execution (from the JAX cost model at lowering
    /// time), if recorded.
    pub flops_per_run: Option<f64>,
    /// Free-form metadata (e.g. model parameter count).
    pub meta: BTreeMap<String, String>,
}

/// The artifact directory + parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactStore {
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactEntry>,
}

impl ArtifactStore {
    /// Open `dir` and parse `manifest.json`.
    pub fn open(dir: impl AsRef<Path>) -> Result<ArtifactStore> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| {
                format!("reading {} (run `make artifacts` first)", manifest_path.display())
            })?;
        let doc = Json::parse(&text).context("parsing manifest.json")?;
        let mut entries = BTreeMap::new();
        for (name, entry) in doc.get("modules")?.as_obj()? {
            let inputs = entry
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = entry
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(TensorSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let flops_per_run = entry.opt("flops_per_run").and_then(|v| v.as_f64().ok());
            let mut meta = BTreeMap::new();
            if let Some(m) = entry.opt("meta") {
                for (k, v) in m.as_obj()? {
                    meta.insert(k.clone(), v.as_str().unwrap_or_default().to_string());
                }
            }
            entries.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    hlo_file: entry.get("hlo_file")?.as_str()?.to_string(),
                    inputs,
                    outputs,
                    flops_per_run,
                    meta,
                },
            );
        }
        Ok(ArtifactStore { dir, entries })
    }

    /// Default location (`artifacts/` at the repo root), honouring
    /// `HROOFLINE_ARTIFACTS` for tests.
    pub fn open_default() -> Result<ArtifactStore> {
        let dir = std::env::var("HROOFLINE_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
        ArtifactStore::open(dir)
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        match self.entries.get(name) {
            Some(e) => Ok(e),
            None => bail!(
                "artifact '{name}' not in manifest (have: {})",
                self.entries.keys().cloned().collect::<Vec<_>>().join(", ")
            ),
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    pub fn hlo_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.hlo_file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d =
            std::env::temp_dir().join(format!("hroofline-artifacts-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn parses_manifest() {
        let dir = tmpdir("parse");
        write_manifest(
            &dir,
            r#"{
              "modules": {
                "train_step": {
                  "hlo_file": "train_step.hlo.txt",
                  "inputs": [{"dims": [4, 64, 64, 3], "dtype": "f32"}],
                  "outputs": [{"dims": [], "dtype": "f32"}],
                  "flops_per_run": 123456.0,
                  "meta": {"params": "1000"}
                }
              }
            }"#,
        );
        let store = ArtifactStore::open(&dir).unwrap();
        let e = store.entry("train_step").unwrap();
        assert_eq!(e.inputs[0].dims, vec![4, 64, 64, 3]);
        assert_eq!(e.inputs[0].n_elems(), 4 * 64 * 64 * 3);
        assert_eq!(e.outputs[0].dims, Vec::<usize>::new());
        assert_eq!(e.flops_per_run, Some(123456.0));
        assert_eq!(e.meta.get("params").unwrap(), "1000");
        assert_eq!(store.names(), vec!["train_step"]);
        assert!(store.hlo_path(e).ends_with("train_step.hlo.txt"));
    }

    #[test]
    fn missing_entry_lists_available() {
        let dir = tmpdir("missing");
        write_manifest(&dir, r#"{"modules": {}}"#);
        let store = ArtifactStore::open(&dir).unwrap();
        let err = store.entry("nope").unwrap_err().to_string();
        assert!(err.contains("not in manifest"));
    }

    #[test]
    fn missing_dir_mentions_make_artifacts() {
        let err = ArtifactStore::open("/nonexistent-hroofline").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
