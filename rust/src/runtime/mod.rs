//! PJRT runtime: loads AOT-compiled JAX/Pallas artifacts and executes
//! them natively. Python never runs at request time — `make artifacts`
//! produces `artifacts/*.hlo.txt` plus `manifest.json`, and this module
//! does `PjRtClient::cpu() → HloModuleProto::from_text_file →
//! compile → execute` (the /opt/xla-example/load_hlo pattern).
//!
//! HLO **text** is the interchange format: jax ≥ 0.5 serializes protos
//! with 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see DESIGN.md and aot_recipe).

pub mod artifacts;
pub mod engine;
pub mod xla;

pub use artifacts::{ArtifactEntry, ArtifactStore};
pub use engine::{Engine, LoadedModule, TimedRun};
