//! The PJRT execution engine: compile HLO-text artifacts once, execute
//! many times from the Rust hot path with timing instrumentation.

use std::time::Instant;

use crate::runtime::artifacts::{ArtifactEntry, ArtifactStore};
use crate::runtime::xla;
use crate::util::error::{self as anyhow, Context, Result};
use crate::util::Summary;

/// A compiled, executable module.
pub struct LoadedModule {
    pub entry: ArtifactEntry,
    exe: xla::PjRtLoadedExecutable,
}

/// Timing record of repeated executions.
#[derive(Clone, Debug)]
pub struct TimedRun {
    pub name: String,
    pub runs: usize,
    pub secs: Summary,
    /// FLOP/s using the manifest's analytic FLOP count, when present.
    pub flops_per_sec: Option<f64>,
}

/// Engine: one PJRT CPU client + loaded executables.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one artifact by name.
    pub fn load(&self, store: &ArtifactStore, name: &str) -> Result<LoadedModule> {
        let entry = store.entry(name)?.clone();
        let path = store.hlo_path(&entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact '{name}'"))?;
        Ok(LoadedModule { entry, exe })
    }

    /// Execute a module once on literals; returns the outputs as
    /// literals. Artifacts are lowered with `return_tuple=True`, so the
    /// single device result is untupled here.
    pub fn run(&self, module: &LoadedModule, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = module
            .exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing '{}'", module.entry.name))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let n_out = module.entry.outputs.len();
        let outs = tuple.to_tuple().context("untupling result")?;
        anyhow::ensure!(
            outs.len() == n_out,
            "artifact '{}' returned {} outputs, manifest says {}",
            module.entry.name,
            outs.len(),
            n_out
        );
        Ok(outs)
    }

    /// Execute repeatedly, timing each run (after `warmup` runs).
    pub fn run_timed(
        &self,
        module: &LoadedModule,
        inputs: &[xla::Literal],
        warmup: usize,
        runs: usize,
    ) -> Result<TimedRun> {
        for _ in 0..warmup {
            self.run(module, inputs)?;
        }
        let mut times = Vec::with_capacity(runs);
        for _ in 0..runs.max(1) {
            let t0 = Instant::now();
            let out = self.run(module, inputs)?;
            std::hint::black_box(&out);
            times.push(t0.elapsed().as_secs_f64());
        }
        let secs = Summary::of(&times);
        let flops_per_sec = module
            .entry
            .flops_per_run
            .map(|f| f / secs.median.max(1e-12));
        Ok(TimedRun {
            name: module.entry.name.clone(),
            runs: times.len(),
            secs,
            flops_per_sec,
        })
    }
}

/// Build an f32 literal of the given shape from a flat buffer.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product();
    anyhow::ensure!(data.len() == n, "buffer len {} != shape product {n}", data.len());
    let lit = xla::Literal::vec1(data);
    if dims.len() <= 1 {
        return Ok(lit);
    }
    let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims_i64).context("reshaping literal")
}

/// Extract an f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().context("extracting f32 data")
}

#[cfg(test)]
mod tests {
    //! Engine tests run against real artifacts when present; they are
    //! skipped (with a notice) when `make artifacts` hasn't run, so
    //! `cargo test` works in a fresh checkout. Full integration coverage
    //! lives in `rust/tests/runtime_integration.rs`.
    use super::*;

    #[test]
    fn literal_shape_roundtrip() {
        let lit = literal_f32(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], &[2, 3]).unwrap();
        let back = to_vec_f32(&lit).unwrap();
        assert_eq!(back, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn literal_len_mismatch_rejected() {
        assert!(literal_f32(&[1.0, 2.0], &[3]).is_err());
    }

    #[test]
    fn engine_creates_cpu_client() {
        let engine = Engine::cpu().unwrap();
        assert_eq!(engine.platform(), "cpu");
    }
}
