//! Offline stand-in for the `xla`/`xla_extension` PJRT bindings.
//!
//! The real runtime path (`/opt/xla-example/load_hlo`) goes
//! `PjRtClient::cpu() → HloModuleProto::from_text_file → compile →
//! execute`. This container has no `xla_extension` shared library, so
//! this module provides the same API surface with:
//!
//! * a **fully functional [`Literal`]** (f32/i32 buffers with shapes,
//!   `vec1`/`reshape`/`to_vec`/`to_tuple`) — everything the engine and
//!   the training driver do on the host side works for real;
//! * a **client/compile layer that loads and validates HLO text** but
//!   reports a clear [`XlaError`] at `compile` time, because no PJRT
//!   backend exists to execute it. Callers already gate on artifact
//!   presence (`ArtifactStore::open`), so in this build the execution
//!   path is never reached; when a real `xla_extension` is available,
//!   swap the `use crate::runtime::xla;` aliases back to the external
//!   crate and nothing else changes.

use std::fmt;

/// Error type for the PJRT surface. Implements `std::error::Error` so
/// call sites can attach context via [`crate::util::error::Context`].
#[derive(Clone, Debug, PartialEq)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

fn backend_unavailable(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: no XLA/PJRT backend in this offline build (xla_extension is \
         not vendored); HLO artifacts can be loaded and inspected but not \
         executed — see rust/src/runtime/xla.rs"
    ))
}

/// Element types a [`Literal`] can hold (F32 activations/parameters,
/// S32 labels — the only dtypes the AOT artifacts use).
pub trait NativeType: Copy + Sized {
    fn literal_vec1(data: &[Self]) -> Literal;
    fn extract(lit: &Literal) -> Result<Vec<Self>, XlaError>;
}

impl NativeType for f32 {
    fn literal_vec1(data: &[Self]) -> Literal {
        Literal::F32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>, XlaError> {
        match lit {
            Literal::F32 { data, .. } => Ok(data.clone()),
            other => Err(XlaError(format!("literal is not f32: {}", other.kind()))),
        }
    }
}

impl NativeType for i32 {
    fn literal_vec1(data: &[Self]) -> Literal {
        Literal::I32 {
            data: data.to_vec(),
            dims: vec![data.len() as i64],
        }
    }

    fn extract(lit: &Literal) -> Result<Vec<Self>, XlaError> {
        match lit {
            Literal::I32 { data, .. } => Ok(data.clone()),
            other => Err(XlaError(format!("literal is not s32: {}", other.kind()))),
        }
    }
}

/// A host-side tensor value: flat buffer + shape, or a tuple of values.
#[derive(Clone, Debug, PartialEq)]
pub enum Literal {
    F32 { data: Vec<f32>, dims: Vec<i64> },
    I32 { data: Vec<i32>, dims: Vec<i64> },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Build a rank-1 literal from a flat buffer.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::literal_vec1(data)
    }

    fn kind(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "s32",
            Literal::Tuple(_) => "tuple",
        }
    }

    /// Number of scalar elements (tuples report the sum).
    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(items) => items.iter().map(Literal::element_count).sum(),
        }
    }

    /// Shape dimensions; tuples have no dims.
    pub fn dims(&self) -> &[i64] {
        match self {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } => dims,
            Literal::Tuple(_) => &[],
        }
    }

    /// Reinterpret the buffer under a new shape with the same element
    /// count.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal, XlaError> {
        if dims.iter().any(|&d| d < 0) {
            return Err(XlaError(format!("reshape to negative extent {dims:?}")));
        }
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() || matches!(self, Literal::Tuple(_)) {
            return Err(XlaError(format!(
                "cannot reshape {} literal of {} elements to {:?}",
                self.kind(),
                self.element_count(),
                dims
            )));
        }
        Ok(match self {
            Literal::F32 { data, .. } => Literal::F32 {
                data: data.clone(),
                dims: dims.to_vec(),
            },
            Literal::I32 { data, .. } => Literal::I32 {
                data: data.clone(),
                dims: dims.to_vec(),
            },
            Literal::Tuple(_) => unreachable!("tuple rejected above"),
        })
    }

    /// Copy the buffer out as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, XlaError> {
        T::extract(self)
    }

    /// Destructure a tuple literal into its elements.
    pub fn to_tuple(self) -> Result<Vec<Literal>, XlaError> {
        match self {
            Literal::Tuple(items) => Ok(items),
            other => Err(XlaError(format!(
                "literal is not a tuple: {}",
                other.kind()
            ))),
        }
    }
}

/// Parsed HLO module text (id-reassignment happens in the real parser;
/// here we retain the text and its entry name for diagnostics).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    /// Load HLO text from a file (the jax ≥ 0.5 interchange format).
    pub fn from_text_file(path: &str) -> Result<HloModuleProto, XlaError> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| XlaError(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(XlaError(format!("{path} is not HLO text (no HloModule header)")));
        }
        Ok(HloModuleProto { text })
    }

    /// The module name from the `HloModule <name>` header, if present.
    pub fn name(&self) -> Option<&str> {
        self.text
            .lines()
            .find_map(|l| l.trim().strip_prefix("HloModule "))
            .map(|rest| rest.split([',', ' ']).next().unwrap_or(rest))
    }
}

/// A computation handle wrapping a parsed module.
#[derive(Clone, Debug)]
pub struct XlaComputation {
    pub proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            proto: proto.clone(),
        }
    }
}

/// The PJRT CPU client. Creation succeeds (there is always a host CPU);
/// compilation is where the missing backend surfaces.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, XlaError> {
        Ok(PjRtClient { platform: "cpu" })
    }

    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable, XlaError> {
        let name = comp.proto.name().unwrap_or("<unnamed>").to_string();
        Err(backend_unavailable(&format!("compiling HLO module '{name}'")))
    }
}

/// A compiled executable. Never constructed in the offline build (see
/// [`PjRtClient::compile`]); the type exists so the engine's signatures
/// match the real bindings.
pub struct PjRtLoadedExecutable {
    _name: String,
}

impl PjRtLoadedExecutable {
    /// Execute on device; returns per-device, per-output buffers.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>, XlaError> {
        Err(backend_unavailable(&format!(
            "executing module '{}'",
            self._name
        )))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    /// Fetch the buffer to the host synchronously.
    pub fn to_literal_sync(&self) -> Result<Literal, XlaError> {
        Ok(self.literal.clone())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_vec1_reshape_roundtrip() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(lit.dims(), &[6]);
        let shaped = lit.reshape(&[2, 3]).unwrap();
        assert_eq!(shaped.dims(), &[2, 3]);
        assert_eq!(shaped.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(shaped.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_i32_and_bad_reshape() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![1, 2, 3]);
        assert!(lit.reshape(&[2, 2]).is_err());
        // Negative extents rejected even when their product matches.
        assert!(lit.reshape(&[-1, -3]).is_err());
    }

    #[test]
    fn tuple_destructure() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        assert_eq!(t.element_count(), 2);
        let items = t.to_tuple().unwrap();
        assert_eq!(items.len(), 2);
        assert!(Literal::vec1(&[0.0f32]).to_tuple().is_err());
    }

    #[test]
    fn client_exists_compile_reports_missing_backend() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu");
        let proto = HloModuleProto {
            text: "HloModule train_step, entry_computation_layout={()->f32[]}".into(),
        };
        assert_eq!(proto.name(), Some("train_step"));
        let err = client.compile(&XlaComputation::from_proto(&proto)).unwrap_err();
        assert!(err.0.contains("train_step"), "{err}");
        assert!(err.0.contains("offline"), "{err}");
    }

    #[test]
    fn hlo_text_loader_validates_header() {
        let dir = std::env::temp_dir().join(format!("hroofline-xla-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("m.hlo.txt");
        std::fs::write(&good, "HloModule m\nENTRY main { ROOT c = f32[] constant(0) }\n").unwrap();
        assert!(HloModuleProto::from_text_file(good.to_str().unwrap()).is_ok());
        let bad = dir.join("bad.txt");
        std::fs::write(&bad, "not hlo").unwrap();
        assert!(HloModuleProto::from_text_file(bad.to_str().unwrap()).is_err());
        assert!(HloModuleProto::from_text_file("/nonexistent/x.hlo").is_err());
        let _ = std::fs::remove_dir_all(dir);
    }
}
