//! PerfWorks-style counter synthesis.
//!
//! The simulator's public output is a [`CounterSet`]: a map from metric
//! name to value using the *exact* metric names of the paper's Table II,
//! so the profiler layer consumes simulated GPUs and (hypothetically)
//! real Nsight CSV exports through one code path.
//!
//! Note: Table II as typeset in the paper lists the FP64 rows with
//! `h{add,mul,fma}` — a typesetting slip; the real Nsight FP64 counters
//! are `d{add,mul,fma}` and that is what we emit (the FP16 rows are the
//! `h` ones).

use std::collections::BTreeMap;

use crate::device::{GpuSpec, MemLevel, Precision};
use crate::sim::cache::Traffic;
use crate::sim::kernel::KernelDesc;

/// Canonical metric names (paper Table II).
pub mod names {
    pub const CYCLES: &str = "sm__cycles_elapsed.avg";
    pub const CYCLES_PER_SEC: &str = "sm__cycles_elapsed.avg.per_second";

    pub const DADD: &str = "sm__sass_thread_inst_executed_op_dadd_pred_on.sum";
    pub const DMUL: &str = "sm__sass_thread_inst_executed_op_dmul_pred_on.sum";
    pub const DFMA: &str = "sm__sass_thread_inst_executed_op_dfma_pred_on.sum";
    pub const FADD: &str = "sm__sass_thread_inst_executed_op_fadd_pred_on.sum";
    pub const FMUL: &str = "sm__sass_thread_inst_executed_op_fmul_pred_on.sum";
    pub const FFMA: &str = "sm__sass_thread_inst_executed_op_ffma_pred_on.sum";
    pub const HADD: &str = "sm__sass_thread_inst_executed_op_hadd_pred_on.sum";
    pub const HMUL: &str = "sm__sass_thread_inst_executed_op_hmul_pred_on.sum";
    pub const HFMA: &str = "sm__sass_thread_inst_executed_op_hfma_pred_on.sum";

    pub const TENSOR: &str = "sm__inst_executed_pipe_tensor.sum";

    pub const L1_BYTES: &str = "l1tex__t_bytes.sum";
    pub const L2_BYTES: &str = "lts__t_bytes.sum";
    pub const DRAM_BYTES: &str = "dram__bytes.sum";

    /// All metrics a "standard" hierarchical-Roofline session collects.
    pub const STANDARD: [&str; 15] = [
        CYCLES,
        CYCLES_PER_SEC,
        DADD,
        DMUL,
        DFMA,
        FADD,
        FMUL,
        FFMA,
        HADD,
        HMUL,
        HFMA,
        TENSOR,
        L1_BYTES,
        L2_BYTES,
        DRAM_BYTES,
    ];

    /// Per-precision (add, mul, fma) metric triplets.
    pub fn fp_triplet(p: crate::device::Precision) -> (&'static str, &'static str, &'static str) {
        match p {
            crate::device::Precision::Fp64 => (DADD, DMUL, DFMA),
            crate::device::Precision::Fp32 => (FADD, FMUL, FFMA),
            crate::device::Precision::Fp16 => (HADD, HMUL, HFMA),
        }
    }
}

/// One kernel launch's counters: metric name → value.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CounterSet {
    values: BTreeMap<String, f64>,
}

impl CounterSet {
    pub fn new() -> CounterSet {
        CounterSet::default()
    }

    pub fn set(&mut self, metric: &str, value: f64) {
        self.values.insert(metric.to_string(), value);
    }

    /// Value of a metric; 0.0 for never-set metrics (Nsight reports 0 for
    /// counters a kernel does not touch).
    pub fn get(&self, metric: &str) -> f64 {
        self.values.get(metric).copied().unwrap_or(0.0)
    }

    pub fn has(&self, metric: &str) -> bool {
        self.values.contains_key(metric)
    }

    pub fn metrics(&self) -> impl Iterator<Item = (&str, f64)> {
        self.values.iter().map(|(k, &v)| (k.as_str(), v))
    }

    /// Accumulate another invocation's counters (sums add; the rate
    /// metric `cycles.per_second` is carried over unchanged).
    pub fn accumulate(&mut self, other: &CounterSet) {
        for (k, v) in &other.values {
            if k == names::CYCLES_PER_SEC {
                self.values.insert(k.clone(), *v);
            } else {
                *self.values.entry(k.clone()).or_insert(0.0) += v;
            }
        }
    }

    // ---- derived quantities (paper §II-B) ----

    /// Kernel run time: `cycles / rate` (paper Eq. 5).
    pub fn elapsed_seconds(&self) -> f64 {
        let rate = self.get(names::CYCLES_PER_SEC);
        if rate == 0.0 {
            0.0
        } else {
            self.get(names::CYCLES) / rate
        }
    }

    /// CUDA-core FLOPs for one precision: `add + 2*fma + mul`.
    pub fn flops(&self, p: Precision) -> f64 {
        let (add, mul, fma) = names::fp_triplet(p);
        self.get(add) + 2.0 * self.get(fma) + self.get(mul)
    }

    /// Tensor-core FLOPs: `inst * 512` (paper Eq. 6) — the factor is the
    /// V100 one; pass the device's factor for other chips.
    pub fn tensor_flops(&self, flops_per_inst: f64) -> f64 {
        self.get(names::TENSOR) * flops_per_inst
    }

    /// All FLOPs (CUDA core all precisions + tensor).
    pub fn total_flops(&self, flops_per_tensor_inst: f64) -> f64 {
        Precision::ALL.iter().map(|&p| self.flops(p)).sum::<f64>()
            + self.tensor_flops(flops_per_tensor_inst)
    }

    /// Bytes at one memory level.
    pub fn bytes(&self, level: MemLevel) -> u64 {
        let m = match level {
            MemLevel::L1 => names::L1_BYTES,
            MemLevel::L2 => names::L2_BYTES,
            MemLevel::Hbm => names::DRAM_BYTES,
        };
        self.get(m) as u64
    }

    /// Arithmetic intensity at one level (FLOPs/byte); None when the
    /// level saw no traffic.
    pub fn arithmetic_intensity(&self, level: MemLevel, flops_per_tensor_inst: f64) -> Option<f64> {
        let bytes = self.bytes(level);
        if bytes == 0 {
            None
        } else {
            Some(self.total_flops(flops_per_tensor_inst) / bytes as f64)
        }
    }
}

/// Build the counter set for one simulated kernel invocation.
pub fn synthesize(spec: &GpuSpec, k: &KernelDesc, t: &Traffic, cycles: f64) -> CounterSet {
    let mut c = CounterSet::new();
    c.set(names::CYCLES, cycles);
    c.set(names::CYCLES_PER_SEC, spec.cycles_per_second());
    for p in Precision::ALL {
        let (add_m, mul_m, fma_m) = names::fp_triplet(p);
        let counts = k.mix.counts(p);
        c.set(add_m, counts.add as f64);
        c.set(mul_m, counts.mul as f64);
        c.set(fma_m, counts.fma as f64);
    }
    c.set(names::TENSOR, k.mix.tensor_insts as f64);
    c.set(names::L1_BYTES, t.l1_bytes as f64);
    c.set(names::L2_BYTES, t.l2_bytes as f64);
    c.set(names::DRAM_BYTES, t.hbm_bytes as f64);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::CacheModel;
    use crate::sim::cycles::CycleModel;

    fn counters_for(k: &KernelDesc) -> (CounterSet, GpuSpec) {
        let spec = GpuSpec::v100();
        let t = CacheModel::new(&spec).traffic(k);
        let cy = CycleModel::new(&spec).elapsed_cycles(k, &t);
        (synthesize(&spec, k, &t, cy), spec)
    }

    #[test]
    fn derived_time_matches_eq5() {
        let k = KernelDesc::streaming_elementwise("s", 1 << 20, Precision::Fp32, 2);
        let (c, spec) = counters_for(&k);
        let t = c.elapsed_seconds();
        assert!((t - c.get(names::CYCLES) / spec.clock_hz).abs() < 1e-12);
        assert!(t > 0.0);
    }

    #[test]
    fn flop_formula_add_2fma_mul() {
        let mut c = CounterSet::new();
        c.set(names::FADD, 3.0);
        c.set(names::FMUL, 5.0);
        c.set(names::FFMA, 7.0);
        assert_eq!(c.flops(Precision::Fp32), 3.0 + 5.0 + 14.0);
        assert_eq!(c.flops(Precision::Fp64), 0.0);
    }

    #[test]
    fn tensor_flops_eq6() {
        let mut c = CounterSet::new();
        c.set(names::TENSOR, 100.0);
        assert_eq!(c.tensor_flops(512.0), 51_200.0);
    }

    #[test]
    fn accumulate_sums_but_keeps_rate() {
        let k = KernelDesc::streaming_elementwise("s", 1 << 16, Precision::Fp16, 1);
        let (c1, spec) = counters_for(&k);
        let mut acc = c1.clone();
        acc.accumulate(&c1);
        assert_eq!(acc.get(names::HFMA), 2.0 * c1.get(names::HFMA));
        assert_eq!(acc.get(names::CYCLES), 2.0 * c1.get(names::CYCLES));
        assert_eq!(acc.get(names::CYCLES_PER_SEC), spec.clock_hz);
    }

    #[test]
    fn ai_none_on_zero_bytes() {
        let c = CounterSet::new();
        assert!(c.arithmetic_intensity(MemLevel::Hbm, 512.0).is_none());
    }

    #[test]
    fn standard_metric_names_spellings() {
        // Guard against typos: these strings are the tool's public
        // contract (paper Table II).
        assert_eq!(names::CYCLES, "sm__cycles_elapsed.avg");
        assert_eq!(names::L1_BYTES, "l1tex__t_bytes.sum");
        assert_eq!(names::L2_BYTES, "lts__t_bytes.sum");
        assert_eq!(names::DRAM_BYTES, "dram__bytes.sum");
        assert_eq!(names::TENSOR, "sm__inst_executed_pipe_tensor.sum");
        assert_eq!(names::STANDARD.len(), 15);
        // FFMA spelled with pred_on suffix:
        assert!(names::FFMA.ends_with("_op_ffma_pred_on.sum"));
    }

    #[test]
    fn ai_hierarchy_ordering_for_cached_kernel() {
        // For a blocked kernel, bytes(L1) >= bytes(L2) >= bytes(HBM), so
        // AI(L1) <= AI(L2) <= AI(HBM).
        let spec = GpuSpec::v100();
        let k = KernelDesc::gemm("g", 2048, 2048, 2048, Precision::Fp16, true, 64, &spec);
        let (c, spec) = counters_for(&k);
        let f = spec.flops_per_tensor_inst as f64;
        let ai_l1 = c.arithmetic_intensity(MemLevel::L1, f).unwrap();
        let ai_l2 = c.arithmetic_intensity(MemLevel::L2, f).unwrap();
        let ai_hbm = c.arithmetic_intensity(MemLevel::Hbm, f).unwrap();
        assert!(ai_l1 <= ai_l2 && ai_l2 <= ai_hbm, "{ai_l1} {ai_l2} {ai_hbm}");
    }
}
