//! PerfWorks-style counter synthesis.
//!
//! The simulator's public output is a [`CounterSet`]: metric name →
//! value using the *exact* metric names of the paper's Table II, so the
//! profiler layer consumes simulated GPUs and (hypothetically) real
//! Nsight CSV exports through one code path.
//!
//! Storage is a dense fixed-size array indexed by [`CounterId`] (the
//! Table II set) — counter reads/writes on the profiling hot path are
//! array indexing, not string hashing — with a string-keyed fallback
//! lane for metrics outside the known set (real-Nsight CSV ingestion
//! can carry counters we do not simulate; they still round-trip through
//! [`crate::profiler::export`]). The map semantics of the original
//! `BTreeMap` representation are preserved exactly: `get` of a
//! never-set metric is 0.0, equality ignores insertion order, and
//! [`CounterSet::metrics`] iterates in lexicographic metric-name order.
//!
//! Note: Table II as typeset in the paper lists the FP64 rows with
//! `h{add,mul,fma}` — a typesetting slip; the real Nsight FP64 counters
//! are `d{add,mul,fma}` and that is what we emit (the FP16 rows are the
//! `h` ones).

use std::collections::BTreeMap;

use crate::device::{GpuSpec, MemLevel, Precision};
use crate::sim::cache::Traffic;
use crate::sim::kernel::KernelDesc;

/// Canonical metric names (paper Table II).
pub mod names {
    pub const CYCLES: &str = "sm__cycles_elapsed.avg";
    pub const CYCLES_PER_SEC: &str = "sm__cycles_elapsed.avg.per_second";

    pub const DADD: &str = "sm__sass_thread_inst_executed_op_dadd_pred_on.sum";
    pub const DMUL: &str = "sm__sass_thread_inst_executed_op_dmul_pred_on.sum";
    pub const DFMA: &str = "sm__sass_thread_inst_executed_op_dfma_pred_on.sum";
    pub const FADD: &str = "sm__sass_thread_inst_executed_op_fadd_pred_on.sum";
    pub const FMUL: &str = "sm__sass_thread_inst_executed_op_fmul_pred_on.sum";
    pub const FFMA: &str = "sm__sass_thread_inst_executed_op_ffma_pred_on.sum";
    pub const HADD: &str = "sm__sass_thread_inst_executed_op_hadd_pred_on.sum";
    pub const HMUL: &str = "sm__sass_thread_inst_executed_op_hmul_pred_on.sum";
    pub const HFMA: &str = "sm__sass_thread_inst_executed_op_hfma_pred_on.sum";

    pub const TENSOR: &str = "sm__inst_executed_pipe_tensor.sum";

    pub const L1_BYTES: &str = "l1tex__t_bytes.sum";
    pub const L2_BYTES: &str = "lts__t_bytes.sum";
    pub const DRAM_BYTES: &str = "dram__bytes.sum";

    /// All metrics a "standard" hierarchical-Roofline session collects.
    pub const STANDARD: [&str; 15] = [
        CYCLES,
        CYCLES_PER_SEC,
        DADD,
        DMUL,
        DFMA,
        FADD,
        FMUL,
        FFMA,
        HADD,
        HMUL,
        HFMA,
        TENSOR,
        L1_BYTES,
        L2_BYTES,
        DRAM_BYTES,
    ];

    /// Per-precision (add, mul, fma) metric triplets.
    pub fn fp_triplet(p: crate::device::Precision) -> (&'static str, &'static str, &'static str) {
        match p {
            crate::device::Precision::Fp64 => (DADD, DMUL, DFMA),
            crate::device::Precision::Fp32 => (FADD, FMUL, FFMA),
            crate::device::Precision::Fp16 => (HADD, HMUL, HFMA),
        }
    }
}

/// Number of dense counter slots (the Table II set).
pub const N_COUNTERS: usize = 15;

/// Dense identifier for a Table II counter.
///
/// Variant order is the *lexicographic order of the metric names* — the
/// invariant that lets [`CounterSet::metrics`] emit sorted output by
/// walking the array in index order (guarded by a test below).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
#[repr(usize)]
pub enum CounterId {
    DramBytes = 0,
    L1Bytes,
    L2Bytes,
    Cycles,
    CyclesPerSec,
    Tensor,
    Dadd,
    Dfma,
    Dmul,
    Fadd,
    Ffma,
    Fmul,
    Hadd,
    Hfma,
    Hmul,
}

impl CounterId {
    /// Every dense counter, in slot (= name-sorted) order.
    pub const ALL: [CounterId; N_COUNTERS] = [
        CounterId::DramBytes,
        CounterId::L1Bytes,
        CounterId::L2Bytes,
        CounterId::Cycles,
        CounterId::CyclesPerSec,
        CounterId::Tensor,
        CounterId::Dadd,
        CounterId::Dfma,
        CounterId::Dmul,
        CounterId::Fadd,
        CounterId::Ffma,
        CounterId::Fmul,
        CounterId::Hadd,
        CounterId::Hfma,
        CounterId::Hmul,
    ];

    /// Canonical Table II metric name.
    pub fn name(self) -> &'static str {
        match self {
            CounterId::DramBytes => names::DRAM_BYTES,
            CounterId::L1Bytes => names::L1_BYTES,
            CounterId::L2Bytes => names::L2_BYTES,
            CounterId::Cycles => names::CYCLES,
            CounterId::CyclesPerSec => names::CYCLES_PER_SEC,
            CounterId::Tensor => names::TENSOR,
            CounterId::Dadd => names::DADD,
            CounterId::Dfma => names::DFMA,
            CounterId::Dmul => names::DMUL,
            CounterId::Fadd => names::FADD,
            CounterId::Ffma => names::FFMA,
            CounterId::Fmul => names::FMUL,
            CounterId::Hadd => names::HADD,
            CounterId::Hfma => names::HFMA,
            CounterId::Hmul => names::HMUL,
        }
    }

    /// Resolve a metric name to its dense slot; `None` for metrics
    /// outside the Table II set (they live in the fallback lane).
    pub fn from_name(name: &str) -> Option<CounterId> {
        Some(match name {
            names::DRAM_BYTES => CounterId::DramBytes,
            names::L1_BYTES => CounterId::L1Bytes,
            names::L2_BYTES => CounterId::L2Bytes,
            names::CYCLES => CounterId::Cycles,
            names::CYCLES_PER_SEC => CounterId::CyclesPerSec,
            names::TENSOR => CounterId::Tensor,
            names::DADD => CounterId::Dadd,
            names::DFMA => CounterId::Dfma,
            names::DMUL => CounterId::Dmul,
            names::FADD => CounterId::Fadd,
            names::FFMA => CounterId::Ffma,
            names::FMUL => CounterId::Fmul,
            names::HADD => CounterId::Hadd,
            names::HFMA => CounterId::Hfma,
            names::HMUL => CounterId::Hmul,
            _ => return None,
        })
    }

    /// Per-precision (add, mul, fma) dense triplets.
    pub fn fp_triplet(p: Precision) -> (CounterId, CounterId, CounterId) {
        match p {
            Precision::Fp64 => (CounterId::Dadd, CounterId::Dmul, CounterId::Dfma),
            Precision::Fp32 => (CounterId::Fadd, CounterId::Fmul, CounterId::Ffma),
            Precision::Fp16 => (CounterId::Hadd, CounterId::Hmul, CounterId::Hfma),
        }
    }

    /// The byte counter of one memory level.
    pub fn bytes_for(level: MemLevel) -> CounterId {
        match level {
            MemLevel::L1 => CounterId::L1Bytes,
            MemLevel::L2 => CounterId::L2Bytes,
            MemLevel::Hbm => CounterId::DramBytes,
        }
    }
}

/// One kernel launch's counters: metric name → value.
///
/// Table II metrics live in a dense array; anything else (unknown /
/// CSV-imported metrics) in a sorted fallback map. A presence bitmask
/// distinguishes "explicitly set to 0.0" from "never set", matching the
/// original map semantics.
#[derive(Clone, Debug, PartialEq)]
pub struct CounterSet {
    dense: [f64; N_COUNTERS],
    present: u16,
    extra: BTreeMap<String, f64>,
}

impl Default for CounterSet {
    fn default() -> CounterSet {
        CounterSet {
            dense: [0.0; N_COUNTERS],
            present: 0,
            extra: BTreeMap::new(),
        }
    }
}

impl CounterSet {
    pub fn new() -> CounterSet {
        CounterSet::default()
    }

    /// Set a dense counter (hot path: no string handling).
    #[inline]
    pub fn set_id(&mut self, id: CounterId, value: f64) {
        self.dense[id as usize] = value;
        self.present |= 1 << (id as usize);
    }

    /// Value of a dense counter; 0.0 when never set.
    #[inline]
    pub fn get_id(&self, id: CounterId) -> f64 {
        self.dense[id as usize]
    }

    #[inline]
    pub fn has_id(&self, id: CounterId) -> bool {
        self.present & (1 << (id as usize)) != 0
    }

    pub fn set(&mut self, metric: &str, value: f64) {
        match CounterId::from_name(metric) {
            Some(id) => self.set_id(id, value),
            None => {
                self.extra.insert(metric.to_string(), value);
            }
        }
    }

    /// Value of a metric; 0.0 for never-set metrics (Nsight reports 0 for
    /// counters a kernel does not touch).
    pub fn get(&self, metric: &str) -> f64 {
        match CounterId::from_name(metric) {
            Some(id) => self.get_id(id),
            None => self.extra.get(metric).copied().unwrap_or(0.0),
        }
    }

    pub fn has(&self, metric: &str) -> bool {
        match CounterId::from_name(metric) {
            Some(id) => self.has_id(id),
            None => self.extra.contains_key(metric),
        }
    }

    /// Iterate set metrics in lexicographic name order (the order the
    /// original map representation produced — CSV export depends on it).
    pub fn metrics(&self) -> Metrics<'_> {
        Metrics {
            set: self,
            next_dense: 0,
            extra: self.extra.iter().peekable(),
        }
    }

    /// Accumulate another invocation's counters (sums add; the rate
    /// metric `cycles.per_second` is carried over unchanged).
    pub fn accumulate(&mut self, other: &CounterSet) {
        for id in CounterId::ALL {
            let i = id as usize;
            if other.present & (1 << i) != 0 {
                if id == CounterId::CyclesPerSec {
                    self.dense[i] = other.dense[i];
                } else {
                    self.dense[i] += other.dense[i];
                }
                self.present |= 1 << i;
            }
        }
        // The fallback lane never holds the rate metric (it is a known
        // name), so everything here sums.
        for (k, v) in &other.extra {
            *self.extra.entry(k.clone()).or_insert(0.0) += v;
        }
    }

    /// Accumulate `invocations` identical executions of `other` in one
    /// step: sums scale by the invocation count, the rate metric is
    /// carried over. Float-for-float identical to building a scaled
    /// copy and calling [`CounterSet::accumulate`].
    pub fn accumulate_scaled(&mut self, other: &CounterSet, invocations: u64) {
        let n = invocations as f64;
        for id in CounterId::ALL {
            let i = id as usize;
            if other.present & (1 << i) != 0 {
                if id == CounterId::CyclesPerSec {
                    self.dense[i] = other.dense[i];
                } else {
                    self.dense[i] += other.dense[i] * n;
                }
                self.present |= 1 << i;
            }
        }
        for (k, v) in &other.extra {
            *self.extra.entry(k.clone()).or_insert(0.0) += v * n;
        }
    }

    // ---- derived quantities (paper §II-B) ----

    /// Kernel run time: `cycles / rate` (paper Eq. 5).
    pub fn elapsed_seconds(&self) -> f64 {
        let rate = self.get_id(CounterId::CyclesPerSec);
        if rate == 0.0 {
            0.0
        } else {
            self.get_id(CounterId::Cycles) / rate
        }
    }

    /// CUDA-core FLOPs for one precision: `add + 2*fma + mul`.
    pub fn flops(&self, p: Precision) -> f64 {
        let (add, mul, fma) = CounterId::fp_triplet(p);
        self.get_id(add) + 2.0 * self.get_id(fma) + self.get_id(mul)
    }

    /// Tensor-core FLOPs: `inst * 512` (paper Eq. 6) — the factor is the
    /// V100 one; pass the device's factor for other chips.
    pub fn tensor_flops(&self, flops_per_inst: f64) -> f64 {
        self.get_id(CounterId::Tensor) * flops_per_inst
    }

    /// All FLOPs (CUDA core all precisions + tensor).
    pub fn total_flops(&self, flops_per_tensor_inst: f64) -> f64 {
        Precision::ALL.iter().map(|&p| self.flops(p)).sum::<f64>()
            + self.tensor_flops(flops_per_tensor_inst)
    }

    /// Bytes at one memory level.
    pub fn bytes(&self, level: MemLevel) -> u64 {
        self.get_id(CounterId::bytes_for(level)) as u64
    }

    /// Arithmetic intensity at one level (FLOPs/byte); None when the
    /// level saw no traffic.
    pub fn arithmetic_intensity(&self, level: MemLevel, flops_per_tensor_inst: f64) -> Option<f64> {
        let bytes = self.bytes(level);
        if bytes == 0 {
            None
        } else {
            Some(self.total_flops(flops_per_tensor_inst) / bytes as f64)
        }
    }
}

/// Name-ordered metric iterator: merges the (name-sorted) dense slots
/// with the sorted fallback map.
pub struct Metrics<'a> {
    set: &'a CounterSet,
    next_dense: usize,
    extra: std::iter::Peekable<std::collections::btree_map::Iter<'a, String, f64>>,
}

impl<'a> Iterator for Metrics<'a> {
    type Item = (&'a str, f64);

    fn next(&mut self) -> Option<(&'a str, f64)> {
        while self.next_dense < N_COUNTERS && self.set.present & (1 << self.next_dense) == 0 {
            self.next_dense += 1;
        }
        let dense = if self.next_dense < N_COUNTERS {
            Some(CounterId::ALL[self.next_dense])
        } else {
            None
        };
        // Decide which lane yields first (the peeked borrow ends here;
        // known names never appear in the fallback lane, so no ties).
        let take_extra = match (dense, self.extra.peek()) {
            (Some(id), Some(&(k, _))) => k.as_str() < id.name(),
            (Some(_), None) => false,
            (None, Some(_)) => true,
            (None, None) => return None,
        };
        if take_extra {
            let (k, v) = self.extra.next().unwrap();
            Some((k.as_str(), *v))
        } else {
            let id = dense.unwrap();
            self.next_dense += 1;
            Some((id.name(), self.set.dense[id as usize]))
        }
    }
}

/// Build the counter set for one simulated kernel invocation.
pub fn synthesize(spec: &GpuSpec, k: &KernelDesc, t: &Traffic, cycles: f64) -> CounterSet {
    let mut c = CounterSet::new();
    c.set_id(CounterId::Cycles, cycles);
    c.set_id(CounterId::CyclesPerSec, spec.cycles_per_second());
    for p in Precision::ALL {
        let (add_m, mul_m, fma_m) = CounterId::fp_triplet(p);
        let counts = k.mix.counts(p);
        c.set_id(add_m, counts.add as f64);
        c.set_id(mul_m, counts.mul as f64);
        c.set_id(fma_m, counts.fma as f64);
    }
    c.set_id(CounterId::Tensor, k.mix.tensor_insts as f64);
    c.set_id(CounterId::L1Bytes, t.l1_bytes as f64);
    c.set_id(CounterId::L2Bytes, t.l2_bytes as f64);
    c.set_id(CounterId::DramBytes, t.hbm_bytes as f64);
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::CacheModel;
    use crate::sim::cycles::CycleModel;

    fn counters_for(k: &KernelDesc) -> (CounterSet, GpuSpec) {
        let spec = GpuSpec::v100();
        let t = CacheModel::new(&spec).traffic(k);
        let cy = CycleModel::new(&spec).elapsed_cycles(k, &t);
        (synthesize(&spec, k, &t, cy), spec)
    }

    #[test]
    fn derived_time_matches_eq5() {
        let k = KernelDesc::streaming_elementwise("s", 1 << 20, Precision::Fp32, 2);
        let (c, spec) = counters_for(&k);
        let t = c.elapsed_seconds();
        assert!((t - c.get(names::CYCLES) / spec.clock_hz).abs() < 1e-12);
        assert!(t > 0.0);
    }

    #[test]
    fn flop_formula_add_2fma_mul() {
        let mut c = CounterSet::new();
        c.set(names::FADD, 3.0);
        c.set(names::FMUL, 5.0);
        c.set(names::FFMA, 7.0);
        assert_eq!(c.flops(Precision::Fp32), 3.0 + 5.0 + 14.0);
        assert_eq!(c.flops(Precision::Fp64), 0.0);
    }

    #[test]
    fn tensor_flops_eq6() {
        let mut c = CounterSet::new();
        c.set(names::TENSOR, 100.0);
        assert_eq!(c.tensor_flops(512.0), 51_200.0);
    }

    #[test]
    fn accumulate_sums_but_keeps_rate() {
        let k = KernelDesc::streaming_elementwise("s", 1 << 16, Precision::Fp16, 1);
        let (c1, spec) = counters_for(&k);
        let mut acc = c1.clone();
        acc.accumulate(&c1);
        assert_eq!(acc.get(names::HFMA), 2.0 * c1.get(names::HFMA));
        assert_eq!(acc.get(names::CYCLES), 2.0 * c1.get(names::CYCLES));
        assert_eq!(acc.get(names::CYCLES_PER_SEC), spec.clock_hz);
    }

    #[test]
    fn accumulate_scaled_matches_explicit_scaling() {
        let k = KernelDesc::streaming_elementwise("s", 1 << 16, Precision::Fp32, 3);
        let (c, _) = counters_for(&k);
        // Reference: the original two-step path (build a scaled copy,
        // then accumulate it).
        let mut scaled = CounterSet::new();
        for (metric, value) in c.metrics() {
            if metric == names::CYCLES_PER_SEC {
                scaled.set(metric, value);
            } else {
                scaled.set(metric, value * 7.0);
            }
        }
        let mut reference = CounterSet::new();
        reference.accumulate(&scaled);
        let mut fast = CounterSet::new();
        fast.accumulate_scaled(&c, 7);
        assert_eq!(fast, reference);
    }

    #[test]
    fn ai_none_on_zero_bytes() {
        let c = CounterSet::new();
        assert!(c.arithmetic_intensity(MemLevel::Hbm, 512.0).is_none());
    }

    #[test]
    fn standard_metric_names_spellings() {
        // Guard against typos: these strings are the tool's public
        // contract (paper Table II).
        assert_eq!(names::CYCLES, "sm__cycles_elapsed.avg");
        assert_eq!(names::L1_BYTES, "l1tex__t_bytes.sum");
        assert_eq!(names::L2_BYTES, "lts__t_bytes.sum");
        assert_eq!(names::DRAM_BYTES, "dram__bytes.sum");
        assert_eq!(names::TENSOR, "sm__inst_executed_pipe_tensor.sum");
        assert_eq!(names::STANDARD.len(), 15);
        // FFMA spelled with pred_on suffix:
        assert!(names::FFMA.ends_with("_op_ffma_pred_on.sum"));
    }

    #[test]
    fn counter_ids_cover_standard_and_sort_by_name() {
        // Every Table II metric resolves to a dense slot and round-trips.
        for name in names::STANDARD {
            let id = CounterId::from_name(name).unwrap_or_else(|| panic!("no id for {name}"));
            assert_eq!(id.name(), name);
        }
        assert!(CounterId::from_name("sm__bogus.sum").is_none());
        // Slot order IS name order — the invariant `metrics()` relies on.
        for w in CounterId::ALL.windows(2) {
            assert!(w[0].name() < w[1].name(), "{} !< {}", w[0].name(), w[1].name());
        }
        assert_eq!(CounterId::ALL.len(), names::STANDARD.len());
    }

    #[test]
    fn metrics_iteration_sorted_and_merged_with_fallback() {
        let mut c = CounterSet::new();
        c.set(names::TENSOR, 1.0);
        c.set("zz__custom.sum", 2.0); // sorts after every sm__ metric
        c.set("aa__custom.sum", 3.0); // sorts before dram__
        c.set(names::DRAM_BYTES, 4.0);
        let got: Vec<(&str, f64)> = c.metrics().collect();
        let names_only: Vec<&str> = got.iter().map(|(n, _)| *n).collect();
        assert_eq!(
            names_only,
            vec!["aa__custom.sum", names::DRAM_BYTES, names::TENSOR, "zz__custom.sum"]
        );
        let mut sorted = names_only.clone();
        sorted.sort_unstable();
        assert_eq!(names_only, sorted);
    }

    #[test]
    fn unknown_metric_fallback_lane_round_trips() {
        let mut c = CounterSet::new();
        c.set("smsp__warps_active.avg", 42.5);
        assert!(c.has("smsp__warps_active.avg"));
        assert_eq!(c.get("smsp__warps_active.avg"), 42.5);
        assert_eq!(c.get("smsp__other.sum"), 0.0);
        let mut acc = CounterSet::new();
        acc.accumulate(&c);
        acc.accumulate(&c);
        assert_eq!(acc.get("smsp__warps_active.avg"), 85.0);
    }

    #[test]
    fn dense_set_matches_map_semantics_property() {
        // Property (vs the original BTreeMap representation): get of
        // never-set metrics is 0.0; set-then-get round-trips; equality
        // ignores insertion order; explicit 0.0 is distinct from unset.
        const NAMES: [&str; 18] = [
            names::CYCLES,
            names::CYCLES_PER_SEC,
            names::DADD,
            names::DMUL,
            names::DFMA,
            names::FADD,
            names::FMUL,
            names::FFMA,
            names::HADD,
            names::HMUL,
            names::HFMA,
            names::TENSOR,
            names::L1_BYTES,
            names::L2_BYTES,
            names::DRAM_BYTES,
            "custom__a.sum",
            "custom__b.avg",
            "other__c.sum",
        ];
        crate::prop::check("dense CounterSet == map semantics", 200, |g| {
            // Draw a random subset with random values.
            let mut chosen: Vec<(usize, f64)> = Vec::new();
            for (i, _) in NAMES.iter().enumerate() {
                if g.bool() {
                    chosen.push((i, g.f64_range(0.0, 1e12)));
                }
            }
            let mut reference: BTreeMap<&str, f64> = BTreeMap::new();
            let mut a = CounterSet::new();
            for &(i, v) in &chosen {
                a.set(NAMES[i], v);
                reference.insert(NAMES[i], v);
            }
            // Same content inserted in reverse order: equal sets.
            let mut b = CounterSet::new();
            for &(i, v) in chosen.iter().rev() {
                b.set(NAMES[i], v);
            }
            assert_eq!(a, b, "insertion order must not matter");
            // get round-trips for set metrics, 0.0 for never-set ones.
            for &name in NAMES.iter() {
                match reference.get(name) {
                    Some(&v) => {
                        assert_eq!(a.get(name), v);
                        assert!(a.has(name));
                    }
                    None => {
                        assert_eq!(a.get(name), 0.0);
                        assert!(!a.has(name));
                        // Explicitly setting 0.0 is observable (!= unset).
                        let mut c = a.clone();
                        c.set(name, 0.0);
                        assert!(c.has(name));
                        assert_ne!(c, a);
                    }
                }
            }
            // metrics() yields exactly the set metrics, name-sorted.
            let listed: Vec<(&str, f64)> = a.metrics().collect();
            let expected: Vec<(&str, f64)> =
                reference.iter().map(|(&k, &v)| (k, v)).collect();
            assert_eq!(listed, expected);
        });
    }

    #[test]
    fn ai_hierarchy_ordering_for_cached_kernel() {
        // For a blocked kernel, bytes(L1) >= bytes(L2) >= bytes(HBM), so
        // AI(L1) <= AI(L2) <= AI(HBM).
        let spec = GpuSpec::v100();
        let k = KernelDesc::gemm("g", 2048, 2048, 2048, Precision::Fp16, true, 64, &spec);
        let (c, spec) = counters_for(&k);
        let f = spec.flops_per_tensor_inst as f64;
        let ai_l1 = c.arithmetic_intensity(MemLevel::L1, f).unwrap();
        let ai_l2 = c.arithmetic_intensity(MemLevel::L2, f).unwrap();
        let ai_hbm = c.arithmetic_intensity(MemLevel::Hbm, f).unwrap();
        assert!(ai_l1 <= ai_l2 && ai_l2 <= ai_hbm, "{ai_l1} {ai_l2} {ai_hbm}");
    }
}
