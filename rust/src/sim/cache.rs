//! Analytic hierarchical cache-traffic model.
//!
//! Given a kernel's [`AccessPattern`], compute the bytes observed at each
//! memory level — the quantities Nsight Compute reports as
//! `l1tex__t_bytes.sum`, `lts__t_bytes.sum` and `dram__bytes.sum`
//! (paper Table II). The model is deliberately simple and fully
//! explainable:
//!
//! * **L1 traffic** = all thread requests (the L1TEX interface sees every
//!   global load/store, hit or miss).
//! * **L2 traffic** = L1 traffic compressed by the achieved L1 reuse,
//!   floored by the compulsory footprint, and degraded when the per-SM
//!   working set exceeds L1 capacity (capacity misses).
//! * **HBM traffic** = L2 traffic compressed by the achieved L2 reuse,
//!   floored by compulsory footprint, degraded when the footprint
//!   exceeds L2 capacity.
//!
//! The set-associative reference simulator in [`crate::sim::cache_sim`]
//! validates the orderings this model produces.

use crate::device::{GpuSpec, MemLevel};
use crate::sim::kernel::KernelDesc;

/// Per-level traffic for one kernel launch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Traffic {
    pub l1_bytes: u64,
    pub l2_bytes: u64,
    pub hbm_bytes: u64,
}

impl Traffic {
    pub fn bytes(&self, level: MemLevel) -> u64 {
        match level {
            MemLevel::L1 => self.l1_bytes,
            MemLevel::L2 => self.l2_bytes,
            MemLevel::Hbm => self.hbm_bytes,
        }
    }

    /// Scale traffic by an invocation count.
    pub fn scaled(&self, n: u64) -> Traffic {
        Traffic {
            l1_bytes: self.l1_bytes * n,
            l2_bytes: self.l2_bytes * n,
            hbm_bytes: self.hbm_bytes * n,
        }
    }

    /// Element-wise accumulate.
    pub fn add(&mut self, other: &Traffic) {
        self.l1_bytes += other.l1_bytes;
        self.l2_bytes += other.l2_bytes;
        self.hbm_bytes += other.hbm_bytes;
    }
}

/// The analytic model, parameterized by device cache geometry.
pub struct CacheModel<'a> {
    spec: &'a GpuSpec,
}

impl<'a> CacheModel<'a> {
    pub fn new(spec: &'a GpuSpec) -> CacheModel<'a> {
        CacheModel { spec }
    }

    /// Compute per-level traffic for a single kernel invocation.
    pub fn traffic(&self, k: &KernelDesc) -> Traffic {
        let a = &k.access;
        let requested = a.requested_bytes();
        if requested == 0 {
            return Traffic::default();
        }
        let footprint = a.footprint_bytes.min(requested.max(a.footprint_bytes));

        // --- L1 ---
        let l1 = requested;

        // --- L2: apply achieved L1 reuse, degraded by capacity ---
        // Residency the L1 reuse operates on: an explicit tile working
        // set when declared (blocked kernels), else the footprint spread
        // across active SMs.
        let active_sms = (k.grid as u64).min(self.spec.sms as u64).max(1);
        let ws_per_sm = a.l1_resident_bytes.unwrap_or(footprint / active_sms);
        let l1_fit = fit_factor(ws_per_sm, self.spec.l1.capacity_bytes);
        // Effective reuse interpolates between declared reuse (fits) and
        // 1.0 (thrashes).
        let l1_reuse_eff = 1.0 + (a.l1_reuse - 1.0) * l1_fit;
        let l2 = ((l1 as f64 / l1_reuse_eff) as u64).max(footprint.min(l1));

        // --- HBM: apply achieved L2 reuse, degraded by capacity ---
        let l2_ws = a.l2_resident_bytes.unwrap_or(footprint);
        let l2_fit = fit_factor(l2_ws, self.spec.l2.capacity_bytes);
        let l2_reuse_eff = 1.0 + (a.l2_reuse - 1.0) * l2_fit;
        let hbm = ((l2 as f64 / l2_reuse_eff) as u64).max(footprint.min(l2));

        // Line-granularity rounding at L2/HBM.
        let line = self.spec.l2.line_bytes;
        Traffic {
            l1_bytes: l1,
            l2_bytes: round_up(l2, line).min(l1),
            hbm_bytes: round_up(hbm, line).min(round_up(l2, line).min(l1)),
        }
    }
}

/// "Does the working set fit" factor in [0, 1]: 1 while the working set
/// fits, a short linear knee to 0 just past capacity. The hard zero
/// matters: with LRU and a working set beyond capacity, every revisit
/// misses (the line is evicted before its next use), so declared reuse
/// must collapse entirely no matter how many passes the kernel makes —
/// this is what makes the ERT sweep knees sharp.
fn fit_factor(working_set: u64, capacity: u64) -> f64 {
    if working_set == 0 {
        return 1.0;
    }
    let ratio = working_set as f64 / capacity as f64;
    if ratio <= 1.0 {
        1.0
    } else if ratio < 1.2 {
        (1.2 - ratio) / 0.2
    } else {
        0.0
    }
}

fn round_up(v: u64, to: u64) -> u64 {
    if to == 0 {
        v
    } else {
        v.div_ceil(to) * to
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::sim::kernel::AccessPattern;

    fn v100() -> GpuSpec {
        GpuSpec::v100()
    }

    #[test]
    fn streaming_kernel_has_flat_hierarchy() {
        let spec = v100();
        let k = KernelDesc::streaming_elementwise("stream", 1 << 22, Precision::Fp32, 1);
        let t = CacheModel::new(&spec).traffic(&k);
        // Triplets overlap: L1 ≈ L2 ≈ HBM (paper §IV "streaming" pattern).
        assert!(t.l1_bytes >= t.l2_bytes && t.l2_bytes >= t.hbm_bytes);
        assert!(t.hbm_bytes as f64 >= 0.9 * t.l1_bytes as f64);
    }

    #[test]
    fn blocked_gemm_filters_traffic() {
        let spec = v100();
        let k = KernelDesc::gemm("gemm", 2048, 2048, 2048, Precision::Fp16, true, 64, &spec);
        let t = CacheModel::new(&spec).traffic(&k);
        // Blocked kernel: large gaps between levels (paper Fig. 3: the
        // dominant kernel has L2≫HBM separation).
        assert!(t.l1_bytes > t.l2_bytes, "{t:?}");
        assert!(t.l2_bytes > t.hbm_bytes, "{t:?}");
    }

    #[test]
    fn ordering_invariant_l1_ge_l2_ge_hbm() {
        // Property: for any access pattern the level traffic is ordered.
        crate::prop::check("traffic ordering", 300, |g| {
            let spec = GpuSpec::v100();
            let load = g.u64_below(1 << 30);
            let store = g.u64_below(1 << 28);
            let requested = load + store;
            let footprint = if requested == 0 {
                0
            } else {
                g.u64_below(requested + 1)
            };
            let k = KernelDesc {
                name: "p".into(),
                grid: g.usize_range(1, 4096) as u32,
                block: 256,
                mix: Default::default(),
                access: AccessPattern {
                    load_bytes: load,
                    store_bytes: store,
                    footprint_bytes: footprint,
                    l1_reuse: g.f64_range(1.0, 128.0),
                    l2_reuse: g.f64_range(1.0, 64.0),
                    l1_resident_bytes: None,
                    l2_resident_bytes: None,
                },
                occupancy: 0.5,
                efficiency: 0.9,
            };
            let t = CacheModel::new(&spec).traffic(&k);
            assert!(t.l1_bytes >= t.l2_bytes, "{t:?}");
            assert!(t.l2_bytes >= t.hbm_bytes, "{t:?}");
        });
    }

    #[test]
    fn traffic_monotone_in_request_volume() {
        let spec = v100();
        let mk = |n: u64| {
            let k = KernelDesc::streaming_elementwise("s", n, Precision::Fp32, 1);
            CacheModel::new(&spec).traffic(&k)
        };
        let small = mk(1 << 16);
        let big = mk(1 << 20);
        assert!(big.l1_bytes > small.l1_bytes);
        assert!(big.hbm_bytes > small.hbm_bytes);
    }

    #[test]
    fn capacity_thrash_degrades_reuse() {
        let spec = v100();
        // Same declared reuse; footprint far beyond L2 capacity kills the
        // L2 compression.
        let mk = |footprint: u64| {
            let k = KernelDesc {
                name: "t".into(),
                grid: 80,
                block: 256,
                mix: Default::default(),
                access: AccessPattern {
                    load_bytes: 1 << 30,
                    store_bytes: 0,
                    footprint_bytes: footprint,
                    l1_reuse: 1.0,
                    l2_reuse: 16.0,
                    l1_resident_bytes: None,
                    l2_resident_bytes: None,
                },
                occupancy: 0.5,
                efficiency: 0.9,
            };
            CacheModel::new(&spec).traffic(&k)
        };
        let fits = mk(1 << 20); // 1 MiB < 6 MiB L2
        let thrashes = mk(1 << 32); // 4 GiB >> L2
        assert!(thrashes.hbm_bytes > fits.hbm_bytes * 4);
    }

    #[test]
    fn zero_request_zero_traffic() {
        let spec = v100();
        let k = KernelDesc {
            name: "null".into(),
            grid: 1,
            block: 32,
            mix: Default::default(),
            access: AccessPattern::streaming(0, 0),
            occupancy: 1.0,
            efficiency: 1.0,
        };
        let t = CacheModel::new(&spec).traffic(&k);
        assert_eq!(t, Traffic::default());
    }

    #[test]
    fn fit_factor_shape() {
        assert_eq!(fit_factor(0, 100), 1.0);
        assert_eq!(fit_factor(10, 100), 1.0);
        assert_eq!(fit_factor(100, 100), 1.0);
        // Knee region: partial reuse.
        let knee = fit_factor(110, 100);
        assert!(knee > 0.0 && knee < 1.0, "{knee}");
        // Overflowed: reuse gone entirely.
        assert_eq!(fit_factor(400, 100), 0.0);
        assert_eq!(fit_factor(4000, 100), 0.0);
    }
}
