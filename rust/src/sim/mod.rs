//! Kernel-granularity GPU performance simulator — the substrate that
//! stands in for "a V100 + Nsight Compute" (DESIGN.md §1).
//!
//! The simulator consumes [`KernelDesc`]s — SASS-level instruction mixes
//! plus memory-access descriptors, as produced by the `dl` framework
//! lowerings or written by hand — and produces PerfWorks-style hardware
//! counters ([`counters::CounterSet`]) with the exact metric names of the
//! paper's Table II. Three component models:
//!
//! * [`cache`] — analytic hierarchical traffic model (L1/L2/HBM bytes),
//!   with a reference set-associative simulator ([`cache_sim`]) used to
//!   validate the analytic model's orderings in tests.
//! * [`cycles`] — SM issue-pipeline cycle model: compute cycles per
//!   pipeline vs memory cycles per level; elapsed = max (+ ramp).
//! * [`counters`] — counter synthesis from mix + traffic + cycles.

pub mod cache;
pub mod cache_sim;
pub mod counters;
pub mod cycles;
pub mod kernel;
pub mod schedule;

pub use cache::{CacheModel, Traffic};
pub use counters::CounterSet;
pub use cycles::CycleModel;
pub use kernel::{AccessPattern, InstMix, KernelDesc, KernelInvocation};

use crate::device::GpuSpec;

/// Whole-kernel simulation: traffic + cycles + counters in one call.
pub fn simulate(spec: &GpuSpec, k: &KernelDesc) -> CounterSet {
    let traffic = CacheModel::new(spec).traffic(k);
    let cycles = CycleModel::new(spec).elapsed_cycles(k, &traffic);
    counters::synthesize(spec, k, &traffic, cycles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;

    #[test]
    fn simulate_produces_consistent_counterset() {
        let spec = GpuSpec::v100();
        let k = KernelDesc::streaming_elementwise("copy", 1 << 20, Precision::Fp32, 0);
        let c = simulate(&spec, &k);
        assert!(c.elapsed_seconds() > 0.0);
        // Streaming kernel: triplet overlaps (paper §IV reading guide).
        let l1 = c.bytes(crate::device::MemLevel::L1);
        let hbm = c.bytes(crate::device::MemLevel::Hbm);
        assert!(l1 >= hbm);
        assert!((l1 as f64) / (hbm as f64) < 1.5, "streaming => L1≈HBM bytes");
    }
}
