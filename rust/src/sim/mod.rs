//! Kernel-granularity GPU performance simulator — the substrate that
//! stands in for "a GPU + Nsight Compute" (DESIGN.md §1). Every model
//! is parameterized by the [`GpuSpec`] passed in (cache geometry,
//! pipeline widths, clocks); resolve one from
//! [`crate::device::registry`] to simulate a specific device.
//!
//! The simulator consumes [`KernelDesc`]s — SASS-level instruction mixes
//! plus memory-access descriptors, as produced by the `dl` framework
//! lowerings or written by hand — and produces PerfWorks-style hardware
//! counters ([`counters::CounterSet`]) with the exact metric names of the
//! paper's Table II. Three component models:
//!
//! * [`cache`] — analytic hierarchical traffic model (L1/L2/HBM bytes),
//!   with a reference set-associative simulator ([`cache_sim`]) used to
//!   validate the analytic model's orderings in tests.
//! * [`cycles`] — SM issue-pipeline cycle model: compute cycles per
//!   pipeline vs memory cycles per level; elapsed = max (+ ramp).
//! * [`counters`] — counter synthesis from mix + traffic + cycles.
//!
//! [`simulate`] runs all three for one kernel; [`SimCache`] memoizes it
//! over identical descriptors (simulation is pure, so cached results
//! are bit-identical).

pub mod cache;
pub mod cache_sim;
pub mod counters;
pub mod cycles;
pub mod kernel;
pub mod schedule;

pub use cache::{CacheModel, Traffic};
pub use counters::{CounterId, CounterSet};
pub use cycles::CycleModel;
pub use kernel::{AccessPattern, InstMix, KernelDesc, KernelInvocation};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::device::GpuSpec;

/// Whole-kernel simulation: traffic + cycles + counters in one call.
pub fn simulate(spec: &GpuSpec, k: &KernelDesc) -> CounterSet {
    let traffic = CacheModel::new(spec).traffic(k);
    let cycles = CycleModel::new(spec).elapsed_cycles(k, &traffic);
    counters::synthesize(spec, k, &traffic, cycles)
}

/// Memoizing wrapper around [`simulate`]: identical kernel descriptors
/// (bitwise — [`KernelDesc`] hashes its floats via `to_bits`) are
/// simulated once and the cached [`CounterSet`] is returned thereafter.
/// Simulation is a pure function of `(spec, desc)`, so cached results
/// are bit-identical to fresh ones; a trace replaying K distinct
/// kernels N times costs K simulations, not N.
pub struct SimCache<'a> {
    spec: &'a GpuSpec,
    cache: HashMap<KernelDesc, CounterSet>,
}

impl<'a> SimCache<'a> {
    pub fn new(spec: &'a GpuSpec) -> SimCache<'a> {
        SimCache {
            spec,
            cache: HashMap::new(),
        }
    }

    /// Simulate `k`, reusing the cached result for descriptors already
    /// seen (the descriptor is cloned only on first miss).
    pub fn simulate(&mut self, k: &KernelDesc) -> &CounterSet {
        if !self.cache.contains_key(k) {
            let counters = simulate(self.spec, k);
            self.cache.insert(k.clone(), counters);
        }
        &self.cache[k]
    }

    /// Number of distinct kernels simulated so far.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

/// Thread-safe memoizer shared *across* profiling sessions: the
/// scenario matrix fans many scenarios through `exec::parallel_map`,
/// and different scenarios of the same workload largely replay the
/// same kernel descriptors — with a shared cache each distinct
/// descriptor is simulated once for the whole sweep, not once per
/// scenario.
///
/// Unlike [`SimCache`], the spec is passed per call (the cache is
/// created before workers exist); callers must use one device spec per
/// cache — entries are keyed by descriptor only. Lookups clone the
/// cached [`CounterSet`] out of the lock; simulation of a miss runs
/// *outside* the lock so concurrent distinct misses don't serialize
/// (two racing identical misses both simulate, last insert wins —
/// harmless, simulation is pure).
#[derive(Default)]
pub struct SharedSimCache {
    cache: Mutex<HashMap<KernelDesc, CounterSet>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SharedSimCache {
    pub fn new() -> SharedSimCache {
        SharedSimCache::default()
    }

    /// Simulate `k` on `spec`, reusing the cached result for
    /// descriptors already seen by *any* thread.
    pub fn get_or_simulate(&self, spec: &GpuSpec, k: &KernelDesc) -> CounterSet {
        if let Some(c) = self.cache.lock().unwrap().get(k) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return c.clone();
        }
        let counters = simulate(spec, k);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.cache.lock().unwrap();
        guard.entry(k.clone()).or_insert_with(|| counters.clone());
        counters
    }

    /// Number of distinct kernels simulated so far.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (cache hits, simulations) observed so far — the sweep-level
    /// dedup ratio.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;

    #[test]
    fn memoized_simulation_bit_identical_and_deduped() {
        let spec = GpuSpec::v100();
        let a = KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1);
        let b = KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec);
        let mut cache = SimCache::new(&spec);
        // First and repeat lookups agree with the direct path exactly.
        for k in [&a, &b, &a, &b, &a] {
            assert_eq!(cache.simulate(k), &simulate(&spec, k));
        }
        assert_eq!(cache.len(), 2, "2 distinct kernels => 2 simulations");
    }

    #[test]
    fn shared_cache_matches_direct_simulation_across_threads() {
        let spec = GpuSpec::v100();
        let kernels: Vec<KernelDesc> = (0..8u64)
            .map(|i| {
                let name = format!("k{}", i % 4);
                KernelDesc::streaming_elementwise(&name, 1u64 << (12 + i % 4), Precision::Fp32, 1)
            })
            .collect();
        let cache = SharedSimCache::new();
        let out =
            crate::exec::parallel_map(kernels.clone(), 4, |k| cache.get_or_simulate(&spec, &k));
        for (k, c) in kernels.iter().zip(&out) {
            assert_eq!(c, &simulate(&spec, k));
        }
        // 4 distinct descriptors (name and size both cycle mod 4).
        assert_eq!(cache.len(), 4);
        let (hits, misses) = cache.stats();
        assert_eq!(hits + misses, 8, "every lookup counted");
        assert!(misses >= 4, "at least one simulation per distinct kernel");
    }

    #[test]
    fn simulate_produces_consistent_counterset() {
        let spec = GpuSpec::v100();
        let k = KernelDesc::streaming_elementwise("copy", 1 << 20, Precision::Fp32, 0);
        let c = simulate(&spec, &k);
        assert!(c.elapsed_seconds() > 0.0);
        // Streaming kernel: triplet overlaps (paper §IV reading guide).
        let l1 = c.bytes(crate::device::MemLevel::L1);
        let hbm = c.bytes(crate::device::MemLevel::Hbm);
        assert!(l1 >= hbm);
        assert!((l1 as f64) / (hbm as f64) < 1.5, "streaming => L1≈HBM bytes");
    }
}
