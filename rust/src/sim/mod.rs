//! Kernel-granularity GPU performance simulator — the substrate that
//! stands in for "a GPU + Nsight Compute" (DESIGN.md §1). Every model
//! is parameterized by the [`GpuSpec`] passed in (cache geometry,
//! pipeline widths, clocks); resolve one from
//! [`crate::device::registry`] to simulate a specific device.
//!
//! The simulator consumes [`KernelDesc`]s — SASS-level instruction mixes
//! plus memory-access descriptors, as produced by the `dl` framework
//! lowerings or written by hand — and produces PerfWorks-style hardware
//! counters ([`counters::CounterSet`]) with the exact metric names of the
//! paper's Table II. Three component models:
//!
//! * [`cache`] — analytic hierarchical traffic model (L1/L2/HBM bytes),
//!   with a reference set-associative simulator ([`cache_sim`]) used to
//!   validate the analytic model's orderings in tests.
//! * [`cycles`] — SM issue-pipeline cycle model: compute cycles per
//!   pipeline vs memory cycles per level; elapsed = max (+ ramp).
//! * [`counters`] — counter synthesis from mix + traffic + cycles.
//!
//! [`simulate`] runs all three for one kernel; [`SimCache`] memoizes it
//! over identical descriptors (simulation is pure, so cached results
//! are bit-identical). That purity also makes simulations cacheable
//! *across processes*: [`KernelDesc::digest_into`] feeds every field
//! of a descriptor into the process-stable [`crate::util::digest`]
//! hash behind the scenario matrix's content-addressed cell store.

pub mod cache;
pub mod cache_sim;
pub mod counters;
pub mod cycles;
pub mod kernel;
pub mod schedule;

pub use cache::{CacheModel, Traffic};
pub use counters::{CounterId, CounterSet};
pub use cycles::{Bound, CycleBreakdown, CycleModel};
pub use kernel::{AccessPattern, InstMix, KernelDesc, KernelInvocation};

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::device::GpuSpec;

/// Whole-kernel *timed* simulation: counters plus the [`CycleBreakdown`]
/// that produced them. The breakdown is the time-based Roofline's extra
/// column (Wang et al., arXiv 2009.04598): where the cycles went
/// (compute vs memory vs ramp) and which resource bound the kernel —
/// data [`simulate`] computes internally and used to discard.
pub fn simulate_timed(spec: &GpuSpec, k: &KernelDesc) -> (CounterSet, CycleBreakdown) {
    let traffic = CacheModel::new(spec).traffic(k);
    let breakdown = CycleModel::new(spec).breakdown(k, &traffic);
    let counters = counters::synthesize(spec, k, &traffic, breakdown.total_cycles);
    (counters, breakdown)
}

/// Whole-kernel simulation: traffic + cycles + counters in one call.
pub fn simulate(spec: &GpuSpec, k: &KernelDesc) -> CounterSet {
    simulate_timed(spec, k).0
}

/// The cycle breakdown alone (no counter synthesis). Pure in
/// `(spec, desc)`, so callers that obtained counters elsewhere — e.g.
/// a replayed (jittered) execution — can recompute the model-attributed
/// timing without re-running the full simulation.
pub fn breakdown_of(spec: &GpuSpec, k: &KernelDesc) -> CycleBreakdown {
    let traffic = CacheModel::new(spec).traffic(k);
    CycleModel::new(spec).breakdown(k, &traffic)
}

/// Memoizing wrapper around [`simulate`]: identical kernel descriptors
/// (bitwise — [`KernelDesc`] hashes its floats via `to_bits`) are
/// simulated once and the cached [`CounterSet`] is returned thereafter.
/// Simulation is a pure function of `(spec, desc)`, so cached results
/// are bit-identical to fresh ones; a trace replaying K distinct
/// kernels N times costs K simulations, not N.
pub struct SimCache<'a> {
    spec: &'a GpuSpec,
    cache: HashMap<KernelDesc, CounterSet>,
}

impl<'a> SimCache<'a> {
    pub fn new(spec: &'a GpuSpec) -> SimCache<'a> {
        SimCache {
            spec,
            cache: HashMap::new(),
        }
    }

    /// Simulate `k`, reusing the cached result for descriptors already
    /// seen (the descriptor is cloned only on first miss).
    pub fn simulate(&mut self, k: &KernelDesc) -> &CounterSet {
        if !self.cache.contains_key(k) {
            let counters = simulate(self.spec, k);
            self.cache.insert(k.clone(), counters);
        }
        &self.cache[k]
    }

    /// Number of distinct kernels simulated so far.
    pub fn len(&self) -> usize {
        self.cache.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cache.is_empty()
    }
}

/// Thread-safe memoizer shared *across* profiling sessions: the
/// scenario matrix fans many scenarios through `exec::parallel_map`,
/// and different scenarios of the same workload largely replay the
/// same kernel descriptors — with a shared cache each distinct
/// descriptor is simulated once for the whole sweep, not once per
/// scenario.
///
/// Unlike [`SimCache`], the spec is passed per call (the cache is
/// created before workers exist); callers must use one device spec per
/// cache — entries are keyed by descriptor only. Lookups clone the
/// cached [`CounterSet`] out of the lock; simulation of a miss runs
/// *outside* the lock so concurrent distinct misses don't serialize
/// (two racing identical misses both simulate, last insert wins —
/// harmless, simulation is pure).
#[derive(Default)]
pub struct SharedSimCache {
    cache: Mutex<HashMap<KernelDesc, (CounterSet, CycleBreakdown)>>,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl SharedSimCache {
    pub fn new() -> SharedSimCache {
        SharedSimCache::default()
    }

    /// Simulate `k` on `spec`, reusing the cached result for
    /// descriptors already seen by *any* thread.
    pub fn get_or_simulate(&self, spec: &GpuSpec, k: &KernelDesc) -> CounterSet {
        self.get_or_simulate_timed(spec, k).0
    }

    /// Timed variant of [`SharedSimCache::get_or_simulate`]: the cache
    /// stores the [`CycleBreakdown`] next to the counters, so the
    /// shared-cache profiling path yields timing bit-identical to the
    /// standalone one (both reduce to `simulate_timed`).
    pub fn get_or_simulate_timed(
        &self,
        spec: &GpuSpec,
        k: &KernelDesc,
    ) -> (CounterSet, CycleBreakdown) {
        if let Some((c, b)) = self.cache.lock().unwrap().get(k) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            return (c.clone(), *b);
        }
        let timed = simulate_timed(spec, k);
        self.misses.fetch_add(1, Ordering::Relaxed);
        let mut guard = self.cache.lock().unwrap();
        guard.entry(k.clone()).or_insert_with(|| timed.clone());
        timed
    }

    /// Number of distinct kernels simulated so far.
    pub fn len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// (cache hits, simulations) observed so far — the sweep-level
    /// dedup ratio.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits.load(Ordering::Relaxed), self.misses.load(Ordering::Relaxed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;

    #[test]
    fn memoized_simulation_bit_identical_and_deduped() {
        let spec = GpuSpec::v100();
        let a = KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1);
        let b = KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec);
        let mut cache = SimCache::new(&spec);
        // First and repeat lookups agree with the direct path exactly.
        for k in [&a, &b, &a, &b, &a] {
            assert_eq!(cache.simulate(k), &simulate(&spec, k));
        }
        assert_eq!(cache.len(), 2, "2 distinct kernels => 2 simulations");
    }

    #[test]
    fn shared_cache_matches_direct_simulation_across_threads() {
        let spec = GpuSpec::v100();
        let kernels: Vec<KernelDesc> = (0..8u64)
            .map(|i| {
                let name = format!("k{}", i % 4);
                KernelDesc::streaming_elementwise(&name, 1u64 << (12 + i % 4), Precision::Fp32, 1)
            })
            .collect();
        let cache = SharedSimCache::new();
        let out =
            crate::exec::parallel_map(kernels.clone(), 4, |k| cache.get_or_simulate(&spec, &k));
        for (k, c) in kernels.iter().zip(&out) {
            assert_eq!(c, &simulate(&spec, k));
        }
        // 4 distinct descriptors (name and size both cycle mod 4).
        assert_eq!(cache.len(), 4);
        let (hits, misses) = cache.stats();
        assert_eq!(hits + misses, 8, "every lookup counted");
        assert!(misses >= 4, "at least one simulation per distinct kernel");
    }

    #[test]
    fn timed_simulation_consistent_with_counters() {
        // The breakdown and the counters are two views of one cycle
        // model: total cycles must agree, the timed path must match the
        // plain one bitwise, and the pure breakdown_of must match the
        // breakdown simulate_timed threads through.
        let spec = GpuSpec::v100();
        for k in [
            KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1),
            KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec),
        ] {
            let (counters, b) = simulate_timed(&spec, &k);
            assert_eq!(counters, simulate(&spec, &k));
            assert_eq!(b, breakdown_of(&spec, &k));
            assert_eq!(counters.get_id(CounterId::Cycles), b.total_cycles);
            let body = b.compute_cycles.max(b.memory_cycles);
            assert_eq!(b.total_cycles, body + b.ramp_cycles);
            assert!(b.total_cycles > 0.0);
        }
    }

    #[test]
    fn simulate_produces_consistent_counterset() {
        let spec = GpuSpec::v100();
        let k = KernelDesc::streaming_elementwise("copy", 1 << 20, Precision::Fp32, 0);
        let c = simulate(&spec, &k);
        assert!(c.elapsed_seconds() > 0.0);
        // Streaming kernel: triplet overlaps (paper §IV reading guide).
        let l1 = c.bytes(crate::device::MemLevel::L1);
        let hbm = c.bytes(crate::device::MemLevel::Hbm);
        assert!(l1 >= hbm);
        assert!((l1 as f64) / (hbm as f64) < 1.5, "streaming => L1≈HBM bytes");
    }
}
