//! Kernel descriptors: the simulator's input language.
//!
//! A [`KernelDesc`] captures what Nsight Compute would observe about one
//! kernel launch: the predicated-on SASS floating-point instruction mix
//! per precision (paper §II-B2), tensor-pipe warp instructions, and the
//! memory request pattern from which per-level traffic follows.

use std::hash::{Hash, Hasher};

use crate::device::{Precision, GpuSpec};
use crate::util::digest::StableHasher;

/// Thread-level SASS floating-point instruction counts for one precision.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct FpCounts {
    pub add: u64,
    pub mul: u64,
    pub fma: u64,
}

impl FpCounts {
    /// FLOPs contributed: `add + 2*fma + mul` (paper §II-B2).
    pub fn flops(&self) -> u64 {
        self.add + 2 * self.fma + self.mul
    }

    pub fn insts(&self) -> u64 {
        self.add + self.mul + self.fma
    }

    /// Feed every field into a process-stable digest (the cell-store
    /// content key — see [`crate::util::digest`]).
    pub fn digest_into(&self, h: &mut StableHasher) {
        h.write_u64(self.add);
        h.write_u64(self.mul);
        h.write_u64(self.fma);
    }
}

/// Full instruction mix of a kernel (thread-level except tensor, which is
/// counted in warp instructions as `sm__inst_executed_pipe_tensor` does).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct InstMix {
    pub fp64: FpCounts,
    pub fp32: FpCounts,
    pub fp16: FpCounts,
    /// Warp-level tensor-pipe instructions (HMMA). FLOPs = inst × 512 on
    /// V100 (paper Eq. 6).
    pub tensor_insts: u64,
    /// Thread-level integer/address ops (dual-issued on the INT pipe).
    pub int_ops: u64,
}

impl InstMix {
    pub fn counts(&self, p: Precision) -> FpCounts {
        match p {
            Precision::Fp64 => self.fp64,
            Precision::Fp32 => self.fp32,
            Precision::Fp16 => self.fp16,
        }
    }

    pub fn counts_mut(&mut self, p: Precision) -> &mut FpCounts {
        match p {
            Precision::Fp64 => &mut self.fp64,
            Precision::Fp32 => &mut self.fp32,
            Precision::Fp16 => &mut self.fp16,
        }
    }

    /// Total FLOPs on the general-purpose core across precisions.
    pub fn cuda_core_flops(&self) -> u64 {
        self.fp64.flops() + self.fp32.flops() + self.fp16.flops()
    }

    /// Tensor-core FLOPs given the device's per-instruction FLOP factor.
    pub fn tensor_flops(&self, spec: &GpuSpec) -> u64 {
        self.tensor_insts * spec.flops_per_tensor_inst
    }

    /// Total FLOPs (CUDA core + tensor core).
    pub fn total_flops(&self, spec: &GpuSpec) -> u64 {
        self.cuda_core_flops() + self.tensor_flops(spec)
    }

    /// A kernel is "zero-AI" when it performs no floating-point work at
    /// all (paper §IV-D: data conversion / layout / transfer kernels).
    pub fn is_zero_ai(&self, spec: &GpuSpec) -> bool {
        self.total_flops(spec) == 0
    }

    /// Feed every field into a process-stable digest.
    pub fn digest_into(&self, h: &mut StableHasher) {
        self.fp64.digest_into(h);
        self.fp32.digest_into(h);
        self.fp16.digest_into(h);
        h.write_u64(self.tensor_insts);
        h.write_u64(self.int_ops);
    }
}

/// Memory behaviour of a kernel, from which the cache model derives
/// per-level traffic.
///
/// `l1_reuse`/`l2_reuse` are *achieved request compressions*: how many
/// bytes of traffic arriving at that level are served per byte passed
/// down to the next level. 1.0 = pure streaming (every request misses
/// through), N = each line fetched from below is referenced N times.
/// Equality and hashing are *bitwise* on the float fields (`to_bits`),
/// making the pattern usable as a memoization key ([`crate::sim::SimCache`],
/// the session's kernel dedup) with the Eq/Hash consistency the std
/// collections require. Descriptors built by the same code path compare
/// equal; `0.0` vs `-0.0` (never produced here) would not.
#[derive(Clone, Copy, Debug)]
pub struct AccessPattern {
    /// Bytes requested by threads from the L1/TEX interface (loads).
    /// NOTE: shared-memory traffic is *excluded*, as in Nsight's
    /// `l1tex__t_bytes` (paper §II-B3) — a smem-staged GEMM therefore
    /// shows only its global loads here.
    pub load_bytes: u64,
    /// Bytes stored through L1.
    pub store_bytes: u64,
    /// Unique bytes touched (compulsory traffic floor at every level).
    pub footprint_bytes: u64,
    /// Achieved L1-level reuse factor (>= 1): requests served per byte
    /// passed down to L2.
    pub l1_reuse: f64,
    /// Achieved L2-level reuse factor (>= 1): e.g. GEMM wave-panel
    /// sharing across concurrent threadblocks.
    pub l2_reuse: f64,
    /// Instantaneous per-SM working set the L1 reuse operates on
    /// (e.g. the staged GEMM tile). None => footprint / active SMs.
    pub l1_resident_bytes: Option<u64>,
    /// Instantaneous device-wide working set the L2 reuse operates on
    /// (e.g. the current wave's panels). None => full footprint.
    pub l2_resident_bytes: Option<u64>,
}

impl AccessPattern {
    /// Pure streaming: every byte touched once, no reuse anywhere.
    pub fn streaming(load_bytes: u64, store_bytes: u64) -> AccessPattern {
        AccessPattern {
            load_bytes,
            store_bytes,
            footprint_bytes: load_bytes + store_bytes,
            l1_reuse: 1.0,
            l2_reuse: 1.0,
            l1_resident_bytes: None,
            l2_resident_bytes: None,
        }
    }

    /// Reuse at both levels over explicit resident working sets.
    pub fn with_reuse(
        load_bytes: u64,
        store_bytes: u64,
        footprint_bytes: u64,
        l1_reuse: f64,
        l2_reuse: f64,
    ) -> AccessPattern {
        AccessPattern {
            load_bytes,
            store_bytes,
            footprint_bytes,
            l1_reuse,
            l2_reuse,
            l1_resident_bytes: None,
            l2_resident_bytes: None,
        }
    }

    pub fn requested_bytes(&self) -> u64 {
        self.load_bytes + self.store_bytes
    }

    /// Feed every field into a process-stable digest. Floats go in
    /// bitwise (`to_bits`), mirroring this type's `Eq`/`Hash` contract:
    /// digest-equal patterns are exactly the `Eq`-equal ones.
    pub fn digest_into(&self, h: &mut StableHasher) {
        h.write_u64(self.load_bytes);
        h.write_u64(self.store_bytes);
        h.write_u64(self.footprint_bytes);
        h.write_f64(self.l1_reuse);
        h.write_f64(self.l2_reuse);
        h.write_opt_u64(self.l1_resident_bytes);
        h.write_opt_u64(self.l2_resident_bytes);
    }
}

impl PartialEq for AccessPattern {
    fn eq(&self, other: &AccessPattern) -> bool {
        self.load_bytes == other.load_bytes
            && self.store_bytes == other.store_bytes
            && self.footprint_bytes == other.footprint_bytes
            && self.l1_reuse.to_bits() == other.l1_reuse.to_bits()
            && self.l2_reuse.to_bits() == other.l2_reuse.to_bits()
            && self.l1_resident_bytes == other.l1_resident_bytes
            && self.l2_resident_bytes == other.l2_resident_bytes
    }
}

impl Eq for AccessPattern {}

impl Hash for AccessPattern {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.load_bytes.hash(state);
        self.store_bytes.hash(state);
        self.footprint_bytes.hash(state);
        self.l1_reuse.to_bits().hash(state);
        self.l2_reuse.to_bits().hash(state);
        self.l1_resident_bytes.hash(state);
        self.l2_resident_bytes.hash(state);
    }
}

/// One kernel's static description (aggregatable over many invocations).
///
/// Hashable (bitwise on the float fields, see [`AccessPattern`]): the
/// simulator memoizes on whole descriptors, so a trace with N
/// invocations of K distinct kernels costs K simulations.
#[derive(Clone, Debug)]
pub struct KernelDesc {
    pub name: String,
    /// Launch geometry: threads = grid * block.
    pub grid: u32,
    pub block: u32,
    pub mix: InstMix,
    pub access: AccessPattern,
    /// Achieved occupancy in (0, 1]; scales latency-hiding ability.
    pub occupancy: f64,
    /// Issue efficiency in (0, 1]: fraction of peak issue rate the kernel
    /// sustains when compute-bound (tail effects, bank conflicts, ...).
    pub efficiency: f64,
}

impl PartialEq for KernelDesc {
    fn eq(&self, other: &KernelDesc) -> bool {
        self.name == other.name
            && self.grid == other.grid
            && self.block == other.block
            && self.mix == other.mix
            && self.access == other.access
            && self.occupancy.to_bits() == other.occupancy.to_bits()
            && self.efficiency.to_bits() == other.efficiency.to_bits()
    }
}

impl Eq for KernelDesc {}

impl Hash for KernelDesc {
    fn hash<H: Hasher>(&self, state: &mut H) {
        self.name.hash(state);
        self.grid.hash(state);
        self.block.hash(state);
        self.mix.hash(state);
        self.access.hash(state);
        self.occupancy.to_bits().hash(state);
        self.efficiency.to_bits().hash(state);
    }
}

impl KernelDesc {
    /// Total threads launched.
    pub fn threads(&self) -> u64 {
        self.grid as u64 * self.block as u64
    }

    /// Feed the whole descriptor into a process-stable digest — the
    /// serialized counterpart of this type's bitwise `Hash`: two
    /// descriptors digest equal iff they compare `Eq`, but unlike
    /// `std::hash` the digest is identical across processes and
    /// machines, making it usable as a persistent cache key.
    pub fn digest_into(&self, h: &mut StableHasher) {
        h.write_str(&self.name);
        h.write_u32(self.grid);
        h.write_u32(self.block);
        self.mix.digest_into(h);
        self.access.digest_into(h);
        h.write_f64(self.occupancy);
        h.write_f64(self.efficiency);
    }

    /// Constructor used across tests & the ERT driver: an elementwise
    /// streaming kernel over `n` elements of precision `p` performing
    /// `fma_per_elem` FMAs per element (0 = zero-AI copy/cast kernel).
    pub fn streaming_elementwise(
        name: &str,
        n: u64,
        p: Precision,
        fma_per_elem: u64,
    ) -> KernelDesc {
        let bytes = n * p.bytes() as u64;
        let mut mix = InstMix::default();
        mix.counts_mut(p).fma = n * fma_per_elem;
        mix.int_ops = n; // index arithmetic
        let block = 256u32;
        let grid = ((n + block as u64 - 1) / block as u64).max(1) as u32;
        KernelDesc {
            name: name.to_string(),
            grid,
            block,
            mix,
            access: AccessPattern::streaming(bytes, bytes),
            occupancy: 0.8,
            efficiency: 0.95,
        }
    }

    /// A dense GEMM kernel descriptor: C[M,N] += A[M,K] B[K,N].
    ///
    /// `tile` is the square shared-memory/register tile edge; it sets the
    /// achieved data reuse (each A/B element ideally reused `tile` times
    /// out of L1, and L2 captures cross-threadblock reuse).
    pub fn gemm(
        name: &str,
        m: u64,
        n: u64,
        k: u64,
        p: Precision,
        tensor_core: bool,
        tile: u64,
        spec: &GpuSpec,
    ) -> KernelDesc {
        let elem = p.bytes() as u64;
        let macs = m * n * k;
        let mut mix = InstMix::default();
        if tensor_core {
            // Warp HMMA instruction count: FLOPs / flops_per_inst.
            mix.tensor_insts = (2 * macs) / spec.flops_per_tensor_inst;
            // Epilogue (alpha/beta scaling) runs on the CUDA core.
            mix.counts_mut(Precision::Fp32).fma = m * n;
        } else {
            mix.counts_mut(p).fma = macs;
        }
        mix.int_ops = macs / tile.max(1); // amortized addressing

        // Global-load traffic: each threadblock reads its (tile x K) A
        // panel and (K x tile) B panel once from global memory (operand
        // reuse inside the tile lives in shared memory, which the L1
        // byte metric does not see — paper §II-B3):
        //   loads = A read ceil(N/bn) times + B read ceil(M/bm) times.
        // For square GEMMs this is the familiar 2*MACs/tile; the ceil
        // form stays correct for skinny shapes (conv wgrads).
        let t = tile.max(1);
        let load_elems = m * k * n.div_ceil(t) + k * n * m.div_ceil(t);
        let load_bytes = load_elems * elem;
        let store_bytes = m * n * elem;
        let footprint = (m * k + k * n + m * n) * elem;
        // L1 filters global loads only slightly (Fig. 3: the dominant
        // kernel's L1 and L2 circles nearly overlap); L2 captures the
        // wave-level panel sharing across concurrent threadblocks
        // (Fig. 3: "the large gap between its L2 and HBM circles").
        let l1_reuse = 1.2;
        let wave_blocks = (m / tile.max(1)).max(1).min(8) as f64;
        let l2_reuse = wave_blocks.max(1.0);
        // Residency: the staged tile (bk-deep) per SM; the current
        // wave's panel slices device-wide.
        let bk = 32u64.min(k.max(1));
        let l1_resident = (tile * bk + bk * tile + tile * tile) * elem;
        let l2_resident = spec.sms as u64 * (2 * tile) * bk * elem;
        // Launch geometry: output tiles, with split-K when the output is
        // too skinny to fill the device (how library wgrad kernels keep
        // SMs busy; small *square* GEMMs still suffer wave quantization
        // because split-K cannot help an already-deep launch).
        let out_tiles = ((m * n) / (tile * tile).max(1)).max(1);
        let split_k_blocks = (macs / ((tile * tile).max(1) * 512)).max(1);
        KernelDesc {
            name: name.to_string(),
            grid: out_tiles.max(split_k_blocks).min(u32::MAX as u64) as u32,
            block: 256,
            mix,
            access: AccessPattern {
                load_bytes,
                store_bytes,
                footprint_bytes: footprint,
                l1_reuse,
                l2_reuse,
                l1_resident_bytes: Some(l1_resident),
                l2_resident_bytes: Some(l2_resident),
            },
            occupancy: 0.5,
            efficiency: if tensor_core { 0.93 } else { 0.9 },
        }
    }
}

/// A dynamic invocation record: a kernel plus how many times it ran and
/// on which stream — the trace element the profiler aggregates
/// (paper §IV: "the data presented ... is the aggregation of all these
/// invocations of the same kernel").
#[derive(Clone, Debug)]
pub struct KernelInvocation {
    pub kernel: KernelDesc,
    pub invocations: u64,
    pub stream: u32,
}

impl KernelInvocation {
    pub fn once(kernel: KernelDesc) -> KernelInvocation {
        KernelInvocation {
            kernel,
            invocations: 1,
            stream: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flop_accounting_matches_paper_formula() {
        let c = FpCounts {
            add: 10,
            mul: 5,
            fma: 20,
        };
        assert_eq!(c.flops(), 10 + 5 + 2 * 20);
    }

    #[test]
    fn tensor_flops_512_per_inst() {
        let spec = GpuSpec::v100();
        let mut mix = InstMix::default();
        mix.tensor_insts = 1000;
        assert_eq!(mix.tensor_flops(&spec), 512_000);
    }

    #[test]
    fn zero_ai_detection() {
        let spec = GpuSpec::v100();
        let mut mix = InstMix::default();
        mix.int_ops = 1_000_000; // integer-only => still zero-AI
        assert!(mix.is_zero_ai(&spec));
        mix.fp32.add = 1;
        assert!(!mix.is_zero_ai(&spec));
    }

    #[test]
    fn streaming_pattern_invariants() {
        let a = AccessPattern::streaming(1000, 500);
        assert_eq!(a.requested_bytes(), 1500);
        assert_eq!(a.footprint_bytes, 1500);
        assert_eq!(a.l1_reuse, 1.0);
    }

    #[test]
    fn gemm_desc_scales_with_size() {
        let spec = GpuSpec::v100();
        let small = KernelDesc::gemm("g", 256, 256, 256, Precision::Fp16, true, 64, &spec);
        let large = KernelDesc::gemm("g", 1024, 1024, 1024, Precision::Fp16, true, 64, &spec);
        assert!(large.mix.tensor_insts > small.mix.tensor_insts * 32);
        assert!(large.access.footprint_bytes > small.access.footprint_bytes);
    }

    #[test]
    fn kernel_desc_usable_as_hash_key() {
        use std::collections::HashMap;
        let spec = GpuSpec::v100();
        let a = KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec);
        let b = KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec);
        let c = KernelDesc::gemm("g", 512, 512, 256, Precision::Fp16, true, 64, &spec);
        assert_eq!(a, b, "identical construction => equal");
        assert_ne!(a, c);
        let mut map: HashMap<KernelDesc, u32> = HashMap::new();
        map.insert(a, 1);
        *map.entry(b).or_insert(0) += 10; // must land on a's slot
        map.insert(c, 2);
        assert_eq!(map.len(), 2);
        assert_eq!(map.values().copied().max(), Some(11));
    }

    #[test]
    fn stable_digest_tracks_descriptor_equality() {
        let spec = GpuSpec::v100();
        let digest = |k: &KernelDesc| {
            let mut h = StableHasher::new();
            k.digest_into(&mut h);
            h.finish_hex()
        };
        let a = KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec);
        let b = KernelDesc::gemm("g", 512, 512, 512, Precision::Fp16, true, 64, &spec);
        let c = KernelDesc::gemm("g", 512, 512, 256, Precision::Fp16, true, 64, &spec);
        assert_eq!(digest(&a), digest(&b), "Eq descriptors digest equal");
        assert_ne!(digest(&a), digest(&c));
        // Any single field change moves the digest.
        let mut d = a.clone();
        d.occupancy += 0.01;
        assert_ne!(digest(&a), digest(&d));
        let mut e = a.clone();
        e.access.l2_resident_bytes = None;
        assert_ne!(digest(&a), digest(&e));
    }

    #[test]
    fn gemm_flops_exact() {
        let spec = GpuSpec::v100();
        let m = 512u64;
        let k = KernelDesc::gemm("g", m, m, m, Precision::Fp32, false, 32, &spec);
        // Non-TC GEMM: FLOPs = 2*M^3 (paper §II-A2).
        assert_eq!(k.mix.cuda_core_flops(), 2 * m * m * m);
    }
}
