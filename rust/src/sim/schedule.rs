//! Multi-stream execution schedule model.
//!
//! Nsight Compute 2020.1.0 *serializes* multi-stream execution while
//! profiling (paper §II-B), so the profiler reports per-kernel times as
//! if sequential. The application, un-profiled, may overlap streams —
//! which is exactly the caveat the paper raises about zero-AI kernels:
//! "this may not inadvertently affect the overall performance much if
//! these kernels are perfectly overlapped with other kernel executions,
//! but it is very hard to achieve that in reality" (§IV-D).
//!
//! This model quantifies that spread: given a trace with stream
//! assignments, it computes wall time under (a) full serialization
//! (what the profiler sees), (b) ideal overlap (streams perfectly
//! concurrent, resource-unaware), and (c) bandwidth-aware overlap
//! (streams share HBM bandwidth — the realistic bound).

use crate::device::GpuSpec;
use crate::sim::cache::CacheModel;
use crate::sim::cycles::CycleModel;
use crate::sim::kernel::KernelInvocation;

/// Wall-clock estimates for a trace under different execution modes.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ScheduleEstimate {
    /// Every launch sequential + launch latency (profiler view).
    pub serialized_s: f64,
    /// Streams run concurrently; wall = max over streams.
    pub ideal_overlap_s: f64,
    /// Streams run concurrently but total HBM traffic is bandwidth-
    /// limited; wall = max(longest stream compute, total-bytes/BW).
    pub bandwidth_aware_s: f64,
    /// Pure launch overhead component (invocations x launch latency).
    pub launch_overhead_s: f64,
}

impl ScheduleEstimate {
    /// How much of the serialized time ideal overlap could hide.
    pub fn overlap_headroom(&self) -> f64 {
        if self.serialized_s == 0.0 {
            0.0
        } else {
            1.0 - self.bandwidth_aware_s / self.serialized_s
        }
    }
}

/// Evaluate a trace's schedule envelope.
pub fn estimate(spec: &GpuSpec, trace: &[KernelInvocation]) -> ScheduleEstimate {
    let cache = CacheModel::new(spec);
    let cycles = CycleModel::new(spec);

    let mut per_stream: std::collections::BTreeMap<u32, f64> = Default::default();
    let mut serialized = 0.0;
    let mut launches = 0u64;
    let mut total_hbm_bytes = 0.0;
    for inv in trace {
        let t = cache.traffic(&inv.kernel);
        let secs = cycles.elapsed_seconds(&inv.kernel, &t) * inv.invocations as f64;
        serialized += secs;
        launches += inv.invocations;
        total_hbm_bytes += t.hbm_bytes as f64 * inv.invocations as f64;
        *per_stream.entry(inv.stream).or_insert(0.0) += secs;
    }
    let launch_overhead_s = launches as f64 * spec.launch_latency_s;
    serialized += launch_overhead_s;

    let longest_stream = per_stream.values().cloned().fold(0.0, f64::max);
    let hbm_floor = total_hbm_bytes / spec.hbm_bytes_per_sec;
    ScheduleEstimate {
        serialized_s: serialized,
        ideal_overlap_s: longest_stream + launch_overhead_s / per_stream.len().max(1) as f64,
        bandwidth_aware_s: longest_stream.max(hbm_floor)
            + launch_overhead_s / per_stream.len().max(1) as f64,
        launch_overhead_s,
    }
}

/// Assign zero-AI kernels to a side stream (the §IV-D "perfect overlap"
/// hypothetical): returns a trace copy with FP-work kernels on stream 0
/// and zero-AI kernels on stream 1.
pub fn split_zero_ai_to_side_stream(
    spec: &GpuSpec,
    trace: &[KernelInvocation],
) -> Vec<KernelInvocation> {
    trace
        .iter()
        .map(|inv| {
            let mut inv = inv.clone();
            inv.stream = if inv.kernel.mix.is_zero_ai(spec) { 1 } else { 0 };
            inv
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::sim::kernel::KernelDesc;

    fn trace() -> Vec<KernelInvocation> {
        vec![
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("fma", 1 << 20, Precision::Fp32, 8),
                invocations: 10,
                stream: 0,
            },
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("cast", 1 << 20, Precision::Fp16, 0),
                invocations: 10,
                stream: 1,
            },
        ]
    }

    #[test]
    fn serialized_at_least_ideal() {
        let spec = GpuSpec::v100();
        let e = estimate(&spec, &trace());
        assert!(e.serialized_s >= e.ideal_overlap_s);
        assert!(e.bandwidth_aware_s >= e.ideal_overlap_s);
        assert!(e.serialized_s >= e.bandwidth_aware_s);
        assert!(e.launch_overhead_s > 0.0);
    }

    #[test]
    fn single_stream_has_no_overlap_headroom() {
        let spec = GpuSpec::v100();
        let mut t = trace();
        for inv in &mut t {
            inv.stream = 0;
        }
        let e = estimate(&spec, &t);
        // Everything on one stream: bandwidth-aware == serialized minus
        // nothing meaningful (launch attribution aside).
        assert!(e.overlap_headroom() < 0.05, "{e:?}");
    }

    #[test]
    fn overlapping_zero_ai_reclaims_time_but_not_all() {
        // The §IV-D point: overlap helps, but both streams share HBM, so
        // streaming zero-AI kernels cannot be hidden for free.
        let spec = GpuSpec::v100();
        let serial_all: Vec<KernelInvocation> = trace()
            .into_iter()
            .map(|mut i| {
                i.stream = 0;
                i
            })
            .collect();
        let base = estimate(&spec, &serial_all);
        let split = split_zero_ai_to_side_stream(&spec, &serial_all);
        let overlapped = estimate(&spec, &split);
        assert!(overlapped.bandwidth_aware_s < base.serialized_s);
        // ...but the bandwidth floor keeps it well above the ideal.
        assert!(overlapped.bandwidth_aware_s > 0.5 * base.serialized_s,
            "streaming zero-AI kernels share HBM: {overlapped:?} vs {base:?}");
    }

    #[test]
    fn empty_trace() {
        let spec = GpuSpec::v100();
        let e = estimate(&spec, &[]);
        assert_eq!(e.serialized_s, 0.0);
        assert_eq!(e.overlap_headroom(), 0.0);
    }
}
