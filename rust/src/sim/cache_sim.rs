//! Reference set-associative cache simulator.
//!
//! A small, exact LRU set-associative cache used to *validate* the
//! analytic traffic model in [`crate::sim::cache`]: tests drive synthetic
//! address streams (streaming, strided, tiled-GEMM-like) through a
//! two-level hierarchy and check that the analytic model predicts the
//! same qualitative orderings (hit-rate monotonicity, streaming flatness,
//! tiling compression). It is also used directly by the `ablation`
//! section of the hotpath bench to quantify the cost of exact simulation
//! versus the analytic fast path.

/// One set-associative LRU cache level.
///
/// Recency is tracked with an **age-stamp scheme**: every access gets a
/// monotonically increasing tick, a hit refreshes the line's stamp, and
/// eviction picks the smallest stamp in the set (invalid slots stamp 0
/// fill first). Exact LRU, but `access` only scans the ways — no
/// MRU-list `remove`/`insert` shifting per access like the original
/// Vec-stack representation (the `cache_exact_100k_accesses` hot loop).
///
/// Storage is SoA: two flat preallocated arrays (tags and stamps),
/// set-major, indexed by `set * ways + way`; `stamp == 0` marks an
/// invalid (never-filled) slot. The tag scan — the hot half of every
/// access — walks a contiguous `u64` run instead of striding through
/// interleaved (tag, stamp) pairs, which halves the bytes touched on
/// the common hit path (the `cache_sim_soa_stream` bench case).
#[derive(Clone, Debug)]
pub struct SetAssocCache {
    tags: Vec<u64>,   // n_sets * ways, flat, set-major
    stamps: Vec<u64>, // parallel to tags; 0 = invalid
    ways: usize,
    line_bytes: u64,
    n_sets: u64,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl SetAssocCache {
    /// Build a cache; `capacity` is rounded down to a whole number of
    /// sets. Panics if the geometry is degenerate.
    pub fn new(capacity_bytes: u64, line_bytes: u64, ways: u32) -> SetAssocCache {
        assert!(line_bytes.is_power_of_two(), "line size must be 2^k");
        let n_lines = capacity_bytes / line_bytes;
        let n_sets = (n_lines / ways as u64).max(1);
        let slots = n_sets as usize * ways as usize;
        SetAssocCache {
            tags: vec![0; slots],
            stamps: vec![0; slots],
            ways: ways as usize,
            line_bytes,
            n_sets,
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Access one byte address; returns true on hit. On miss the line is
    /// filled (allocate-on-miss for both loads and stores), evicting the
    /// least-recently-used way.
    pub fn access(&mut self, addr: u64) -> bool {
        let line = addr / self.line_bytes;
        let base = (line % self.n_sets) as usize * self.ways;
        self.tick += 1;
        let tags = &mut self.tags[base..base + self.ways];
        let stamps = &mut self.stamps[base..base + self.ways];
        let mut victim = 0usize;
        let mut victim_stamp = u64::MAX;
        for (way, (tag, stamp)) in tags.iter().zip(stamps.iter_mut()).enumerate() {
            if *stamp != 0 && *tag == line {
                *stamp = self.tick;
                self.hits += 1;
                return true;
            }
            if *stamp < victim_stamp {
                victim_stamp = *stamp;
                victim = way;
            }
        }
        tags[victim] = line;
        stamps[victim] = self.tick;
        self.misses += 1;
        false
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn line_bytes(&self) -> u64 {
        self.line_bytes
    }
}

/// A two-level hierarchy fed with line-granularity accesses; counts bytes
/// of traffic at L1, L2 and memory, mirroring the Nsight byte metrics.
#[derive(Clone, Debug)]
pub struct Hierarchy {
    pub l1: SetAssocCache,
    pub l2: SetAssocCache,
    pub l1_bytes: u64,
    pub l2_bytes: u64,
    pub mem_bytes: u64,
    access_bytes: u64,
}

impl Hierarchy {
    pub fn new(l1: SetAssocCache, l2: SetAssocCache, access_bytes: u64) -> Hierarchy {
        assert!(access_bytes > 0);
        Hierarchy {
            l1,
            l2,
            l1_bytes: 0,
            l2_bytes: 0,
            mem_bytes: 0,
            access_bytes,
        }
    }

    /// Access `access_bytes` at `addr`: L1 always sees the request; L2
    /// sees it on L1 miss; memory on L2 miss. Miss traffic moves whole
    /// lines.
    pub fn access(&mut self, addr: u64) {
        self.l1_bytes += self.access_bytes;
        if !self.l1.access(addr) {
            let line = self.l1.line_bytes();
            self.l2_bytes += line;
            if !self.l2.access(addr) {
                self.mem_bytes += self.l2.line_bytes();
            }
        }
    }
}

/// Build a hierarchy with one device's cache geometry at reduced scale
/// (keeps tests fast while preserving set/way geometry ratios).
pub fn scaled(spec: &crate::device::GpuSpec, scale_down: u64) -> Hierarchy {
    let l1 = SetAssocCache::new(
        (spec.l1.capacity_bytes / scale_down).max(spec.l1.line_bytes * spec.l1.ways as u64),
        spec.l1.line_bytes,
        spec.l1.ways,
    );
    let l2 = SetAssocCache::new(
        (spec.l2.capacity_bytes / scale_down).max(spec.l2.line_bytes * spec.l2.ways as u64),
        spec.l2.line_bytes,
        spec.l2.ways,
    );
    Hierarchy::new(l1, l2, 4)
}

/// Back-compat shorthand: the default (V100) geometry at reduced scale.
pub fn v100_scaled(scale_down: u64) -> Hierarchy {
    scaled(&crate::device::registry::default_spec(), scale_down)
}

/// Drive a tiled-GEMM-like access stream: for each (i-tile, j-tile),
/// sweep A-panel and B-panel addresses `reps` times. Returns the
/// hierarchy for inspection.
pub fn run_tiled_stream(
    h: &mut Hierarchy,
    a_base: u64,
    b_base: u64,
    panel_bytes: u64,
    tiles: u64,
    reps: u64,
) {
    let step = h.l1.line_bytes();
    for t in 0..tiles {
        for _ in 0..reps {
            let mut off = 0;
            while off < panel_bytes {
                h.access(a_base + t * panel_bytes + off);
                h.access(b_base + off);
                off += step;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeated_access_hits() {
        let mut c = SetAssocCache::new(1024, 64, 4);
        assert!(!c.access(0));
        assert!(c.access(0));
        assert!(c.access(32)); // same line
        assert_eq!(c.misses, 1);
        assert_eq!(c.hits, 2);
    }

    #[test]
    fn lru_evicts_oldest() {
        // 1 set, 2 ways, 64B lines, 128B capacity.
        let mut c = SetAssocCache::new(128, 64, 2);
        c.access(0); // miss, cache {0}
        c.access(64 * 1); // miss, {1,0}  (same set: n_sets = 1)
        c.access(64 * 2); // miss, evicts 0 → {2,1}
        assert!(!c.access(0), "0 was evicted");
        assert!(c.access(64 * 2), "2 still resident");
    }

    /// Reference implementation: the original MRU-first Vec-stack LRU.
    struct StackLru {
        sets: Vec<Vec<u64>>,
        ways: usize,
        line_bytes: u64,
        n_sets: u64,
    }

    impl StackLru {
        fn new(capacity_bytes: u64, line_bytes: u64, ways: u32) -> StackLru {
            let n_sets = ((capacity_bytes / line_bytes) / ways as u64).max(1);
            StackLru {
                sets: vec![Vec::new(); n_sets as usize],
                ways: ways as usize,
                line_bytes,
                n_sets,
            }
        }

        fn access(&mut self, addr: u64) -> bool {
            let line = addr / self.line_bytes;
            let set = &mut self.sets[(line % self.n_sets) as usize];
            if let Some(pos) = set.iter().position(|&t| t == line) {
                set.remove(pos);
                set.insert(0, line);
                true
            } else {
                if set.len() == self.ways {
                    set.pop();
                }
                set.insert(0, line);
                false
            }
        }
    }

    #[test]
    fn age_stamp_scheme_is_exact_lru() {
        // Every access's hit/miss outcome must match the reference
        // MRU-stack implementation over a mixed random/looping stream.
        let mut fast = SetAssocCache::new(4 * 1024, 128, 4);
        let mut reference = StackLru::new(4 * 1024, 128, 4);
        let mut rng = crate::util::Rng::new(99);
        for i in 0..50_000u64 {
            // Mix regimes: random, strided, and small-loop reuse.
            let addr = match i % 3 {
                0 => rng.below(1 << 16),
                1 => (i * 128) % (1 << 14),
                _ => (i % 40) * 128,
            };
            assert_eq!(
                fast.access(addr),
                reference.access(addr),
                "divergence at access {i} addr {addr}"
            );
        }
        assert!(fast.hits > 0 && fast.misses > 0);
    }

    #[test]
    fn streaming_stream_misses_everywhere() {
        let mut h = v100_scaled(64);
        for i in 0..50_000u64 {
            h.access(i * 128); // new line every access
        }
        // All levels see ~equal traffic: the streaming signature.
        assert!(h.l1.hit_rate() < 0.01);
        assert!(h.l2.hit_rate() < 0.01);
        assert!(h.mem_bytes >= h.l2_bytes * 9 / 10);
    }

    #[test]
    fn small_working_set_hits_l1() {
        let mut h = v100_scaled(64);
        let ws = 512u64; // lines 0..4 at 128B
        for i in 0..40_000u64 {
            h.access((i * 128) % ws);
        }
        assert!(h.l1.hit_rate() > 0.99, "{}", h.l1.hit_rate());
        assert!(h.mem_bytes < 1024);
    }

    #[test]
    fn medium_working_set_hits_l2_not_l1() {
        let mut h = v100_scaled(64);
        // Working set: larger than L1 (2 KiB scaled) but within L2 (96 KiB
        // scaled).
        let ws = 32 * 1024u64;
        for i in 0..200_000u64 {
            h.access((i * 128) % ws);
        }
        assert!(h.l1.hit_rate() < 0.2, "l1 {}", h.l1.hit_rate());
        assert!(h.l2.hit_rate() > 0.9, "l2 {}", h.l2.hit_rate());
    }

    #[test]
    fn tiling_compresses_lower_level_traffic() {
        // B-panel reused across tiles => it should live in L2 and cut
        // memory traffic versus a no-reuse run.
        let mut with_reuse = v100_scaled(64);
        run_tiled_stream(&mut with_reuse, 0, 1 << 24, 8 * 1024, 8, 4);
        let mut without = v100_scaled(64);
        // unique B per tile: emulate by bumping b_base per tile
        for t in 0..8u64 {
            run_tiled_stream(
                &mut without,
                t * (1 << 20),
                (1 << 24) + t * (1 << 20),
                8 * 1024,
                1,
                4,
            );
        }
        assert!(with_reuse.mem_bytes < without.mem_bytes,
            "reuse {} vs none {}", with_reuse.mem_bytes, without.mem_bytes);
    }

    #[test]
    fn hierarchy_byte_ordering_at_line_granularity() {
        // With line-sized requests the level traffic is strictly ordered.
        let l1 = SetAssocCache::new(2 * 1024, 128, 4);
        let l2 = SetAssocCache::new(96 * 1024, 128, 16);
        let mut h = Hierarchy::new(l1, l2, 128);
        let mut rng = crate::util::Rng::new(42);
        for _ in 0..100_000 {
            h.access(rng.below(1 << 22));
        }
        assert!(h.l1_bytes >= h.l2_bytes);
        assert!(h.l2_bytes >= h.mem_bytes);
    }

    #[test]
    fn fine_grained_random_access_amplifies_below_l1() {
        // Documented behaviour (matches real counters): 4-byte random
        // requests miss whole 128-byte lines, so L2 traffic can *exceed*
        // the L1 request bytes — the analytic model's ordering invariant
        // applies to its own line-rounded semantics, not to raw request
        // amplification.
        let mut h = v100_scaled(64);
        let mut rng = crate::util::Rng::new(7);
        for _ in 0..50_000 {
            h.access(rng.below(1 << 22));
        }
        assert!(h.l2_bytes > h.l1_bytes);
    }

    /// Cross-validation: the analytic model and the exact simulator agree
    /// on the *ordering* of HBM traffic across locality regimes.
    #[test]
    fn analytic_model_agrees_with_simulator_ordering() {
        use crate::device::GpuSpec;
        use crate::sim::cache::CacheModel;
        use crate::sim::kernel::{AccessPattern, KernelDesc};

        let spec = GpuSpec::v100();
        let model = CacheModel::new(&spec);
        let mk = |reuse: f64| {
            let k = KernelDesc {
                name: "x".into(),
                grid: 80,
                block: 256,
                mix: Default::default(),
                access: AccessPattern {
                    load_bytes: 1 << 24,
                    store_bytes: 0,
                    footprint_bytes: (1 << 24) / reuse as u64,
                    l1_reuse: reuse,
                    l2_reuse: 1.0,
                    l1_resident_bytes: None,
                    l2_resident_bytes: None,
                },
                occupancy: 0.5,
                efficiency: 0.9,
            };
            model.traffic(&k).hbm_bytes
        };
        // More reuse => less HBM traffic, same as the simulator showed in
        // small_working_set_hits_l1 vs streaming.
        assert!(mk(16.0) < mk(4.0));
        assert!(mk(4.0) < mk(1.0));
    }
}
