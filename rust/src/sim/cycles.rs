//! SM cycle model: how long a kernel occupies the GPU.
//!
//! Elapsed cycles are the max over (a) per-pipeline compute cycles and
//! (b) per-memory-level transfer cycles — the throughput assumption
//! underlying the Roofline model itself (paper Eq. 1) — plus a fixed
//! ramp term representing launch/drain that keeps tiny kernels from
//! reporting zero time (and makes zero-AI kernels overhead-bound,
//! §IV-D).

use crate::device::{GpuSpec, MemLevel, PipelineKind, Precision};
use crate::sim::cache::Traffic;
use crate::sim::kernel::KernelDesc;

/// Cycle model bound to a device spec.
pub struct CycleModel<'a> {
    spec: &'a GpuSpec,
}

/// Breakdown of where the cycles went (for reports and tests).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CycleBreakdown {
    pub compute_cycles: f64,
    pub memory_cycles: f64,
    pub ramp_cycles: f64,
    pub total_cycles: f64,
    /// Which resource bound the kernel.
    pub bound: Bound,
}

#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Bound {
    #[default]
    Overhead,
    Compute,
    Memory,
}

impl Bound {
    /// Lower-case label for tables and CSV ("compute" / "memory" /
    /// "overhead").
    pub fn name(&self) -> &'static str {
        match self {
            Bound::Overhead => "overhead",
            Bound::Compute => "compute",
            Bound::Memory => "memory",
        }
    }
}

impl<'a> CycleModel<'a> {
    pub fn new(spec: &'a GpuSpec) -> CycleModel<'a> {
        CycleModel { spec }
    }

    /// Elapsed SM cycles for one invocation of `k` with traffic `t`.
    pub fn elapsed_cycles(&self, k: &KernelDesc, t: &Traffic) -> f64 {
        self.breakdown(k, t).total_cycles
    }

    /// Full cycle breakdown.
    pub fn breakdown(&self, k: &KernelDesc, t: &Traffic) -> CycleBreakdown {
        let spec = self.spec;
        let occ = k.occupancy.clamp(0.05, 1.0);
        let eff = k.efficiency.clamp(0.05, 1.0);

        // --- compute ---
        // Thread-level ops per pipeline; tensor counted in warp insts.
        let mut compute_cycles: f64 = 0.0;
        for pipe in spec.pipelines() {
            let ops = match pipe.kind {
                PipelineKind::Fp64 => k.mix.counts(Precision::Fp64).insts(),
                PipelineKind::Fp32 => k.mix.counts(Precision::Fp32).insts(),
                PipelineKind::Fp16 => k.mix.counts(Precision::Fp16).insts(),
                PipelineKind::Int => k.mix.int_ops,
                PipelineKind::Tensor => k.mix.tensor_insts,
            };
            if ops == 0 {
                continue;
            }
            let device_lanes = pipe.lanes_per_sm as f64 * spec.sms as f64;
            // Tensor instructions are warp-level HMMA ops: each carries
            // `flops_per_tensor_inst` FLOPs (512 on V100, Eq. 6) but a
            // tensor core only retires `flops_per_tc_per_cycle` (4^3*2 =
            // 128) per cycle, so one HMMA occupies a TC for several
            // cycles. The TC also runs at the paper's Eq. 3 clock.
            let (cycles_per_op, clock_ratio) = if pipe.kind == PipelineKind::Tensor {
                (
                    spec.flops_per_tensor_inst as f64 / spec.flops_per_tc_per_cycle as f64,
                    spec.tc_clock_hz / spec.clock_hz,
                )
            } else {
                (1.0, 1.0)
            };
            let cycles = ops as f64 * cycles_per_op / (device_lanes * eff * clock_ratio);
            compute_cycles = compute_cycles.max(cycles);
        }
        // Wave quantization: a launch with fewer blocks than SMs leaves
        // SMs idle — the dominant effect for small GEMMs (Fig. 2's rise
        // with matrix size).
        let active_frac = (k.grid as f64 / spec.sms as f64).min(1.0).max(1e-3);
        compute_cycles /= active_frac;

        // --- memory ---
        let mut memory_cycles: f64 = 0.0;
        for level in MemLevel::ALL {
            let bytes = t.bytes(level) as f64;
            if bytes == 0.0 {
                continue;
            }
            let secs = bytes / spec.bandwidth(level);
            memory_cycles = memory_cycles.max(secs * spec.clock_hz);
        }
        // Low occupancy hurts achievable bandwidth (fewer outstanding
        // requests to hide memory latency behind). Compute-bound kernels
        // are deliberately *not* penalized: tuned GEMMs sustain peak at
        // 25% occupancy through ILP (the cuBLAS 96.5% point in Fig. 2).
        memory_cycles /= occ.powf(0.25).max(0.5);

        // --- ramp ---
        // Fixed pipeline fill/drain: ~2 µs of cycles. This is *kernel
        // execution* ramp; the API-side launch latency is modelled
        // separately in the schedule (sim::kernel::KernelInvocation).
        let ramp_cycles = 2.0e-6 * spec.clock_hz;

        let body = compute_cycles.max(memory_cycles);
        let total = body + ramp_cycles;
        let bound = if body < ramp_cycles {
            Bound::Overhead
        } else if compute_cycles >= memory_cycles {
            Bound::Compute
        } else {
            Bound::Memory
        };
        CycleBreakdown {
            compute_cycles,
            memory_cycles,
            ramp_cycles,
            total_cycles: total,
            bound,
        }
    }

    /// Elapsed wall seconds for one invocation.
    pub fn elapsed_seconds(&self, k: &KernelDesc, t: &Traffic) -> f64 {
        self.elapsed_cycles(k, t) / self.spec.clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::cache::CacheModel;

    fn sim(k: &KernelDesc) -> (CycleBreakdown, GpuSpec) {
        let spec = GpuSpec::v100();
        let t = CacheModel::new(&spec).traffic(k);
        let b = CycleModel::new(&spec).breakdown(k, &t);
        (b, spec)
    }

    #[test]
    fn streaming_kernel_is_memory_bound() {
        let k = KernelDesc::streaming_elementwise("s", 1 << 24, Precision::Fp32, 1);
        let (b, _) = sim(&k);
        assert_eq!(b.bound, Bound::Memory);
        assert!(b.memory_cycles > b.compute_cycles);
    }

    #[test]
    fn big_tc_gemm_is_compute_bound_near_peak() {
        let spec = GpuSpec::v100();
        let k = KernelDesc::gemm("g", 8192, 8192, 8192, Precision::Fp16, true, 128, &spec);
        let t = CacheModel::new(&spec).traffic(&k);
        let b = CycleModel::new(&spec).breakdown(&k, &t);
        assert_eq!(b.bound, Bound::Compute);
        // Attained TFLOP/s should be within ~2x of the TC peak and below it.
        let secs = b.total_cycles / spec.clock_hz;
        let flops = k.mix.total_flops(&spec) as f64;
        let attained = flops / secs;
        assert!(attained < spec.theoretical_tensor_flops());
        assert!(attained > 0.4 * spec.theoretical_tensor_flops(), "{attained:.3e}");
    }

    #[test]
    fn tiny_kernel_is_overhead_bound() {
        let k = KernelDesc::streaming_elementwise("tiny", 32, Precision::Fp32, 1);
        let (b, _) = sim(&k);
        assert_eq!(b.bound, Bound::Overhead);
    }

    #[test]
    fn zero_ai_kernel_time_dominated_by_bytes_or_ramp() {
        let k = KernelDesc::streaming_elementwise("cast", 1 << 24, Precision::Fp16, 0);
        let (b, _) = sim(&k);
        assert!(b.compute_cycles < b.memory_cycles.max(b.ramp_cycles));
    }

    #[test]
    fn lower_occupancy_never_speeds_up() {
        let spec = GpuSpec::v100();
        let mut k = KernelDesc::streaming_elementwise("s", 1 << 22, Precision::Fp32, 4);
        let t = CacheModel::new(&spec).traffic(&k);
        k.occupancy = 1.0;
        let fast = CycleModel::new(&spec).elapsed_cycles(&k, &t);
        k.occupancy = 0.2;
        let slow = CycleModel::new(&spec).elapsed_cycles(&k, &t);
        assert!(slow >= fast);
    }

    #[test]
    fn elapsed_monotone_in_work() {
        crate::prop::check("cycles monotone in elements", 100, |g| {
            let spec = GpuSpec::v100();
            let n = g.usize_range(1 << 10, 1 << 22) as u64;
            let k1 = KernelDesc::streaming_elementwise("a", n, Precision::Fp32, 2);
            let k2 = KernelDesc::streaming_elementwise("b", n * 2, Precision::Fp32, 2);
            let cm = CacheModel::new(&spec);
            let cy = CycleModel::new(&spec);
            let t1 = cm.traffic(&k1);
            let t2 = cm.traffic(&k2);
            assert!(cy.elapsed_cycles(&k2, &t2) >= cy.elapsed_cycles(&k1, &t1));
        });
    }

    #[test]
    fn roofline_bound_respected() {
        // Attained FLOP/s never exceeds min(peak, AI * BW) by more than
        // the ramp slack — the model is roofline-consistent by
        // construction; verify over random kernels.
        crate::prop::check("attained <= roofline", 200, |g| {
            let spec = GpuSpec::v100();
            let n = g.usize_range(1 << 12, 1 << 24) as u64;
            let fma = g.usize_range(0, 64) as u64;
            let k = KernelDesc::streaming_elementwise("r", n, Precision::Fp32, fma);
            let t = CacheModel::new(&spec).traffic(&k);
            let secs = CycleModel::new(&spec).elapsed_seconds(&k, &t);
            let flops = k.mix.total_flops(&spec) as f64;
            if flops == 0.0 {
                return;
            }
            let attained = flops / secs;
            let ai_hbm = flops / t.hbm_bytes.max(1) as f64;
            let roof = spec
                .theoretical_flops(Precision::Fp32)
                .min(ai_hbm * spec.hbm_bytes_per_sec);
            assert!(
                attained <= roof * 1.001,
                "attained {attained:.3e} roof {roof:.3e}"
            );
        });
    }
}
