//! Empirical ERT driver: real micro-kernels on the host CPU.
//!
//! This is the "runs on actual silicon" half of the ERT reproduction: a
//! templated FMA-chain kernel (the C++-templates redesign of §II-A1,
//! here via Rust generics over f32/f64) and a streaming triad kernel,
//! swept over working sets straddling the host cache levels. Wall-clock
//! is measured with `Instant`; the best trial is kept, exactly as ERT
//! reports empirical maxima.
//!
//! The resulting ceilings power the *CPU* roofline onto which the
//! end-to-end example maps the real PJRT-executed DeepCAM-lite training
//! step.

use std::time::Instant;

use crate::device::MemLevel;
use crate::ert::sweep::{SweepConfig, SweepPoint, SweepResult};
use crate::util::Summary;

/// Element type a micro-kernel runs on (the "C++ template" axis).
pub trait ErtElem: Copy {
    const BYTES: usize;
    const NAME: &'static str;
    fn splat(v: f64) -> Self;
    fn fma(self, a: Self, b: Self) -> Self;
    fn to_f64(self) -> f64;
}

impl ErtElem for f64 {
    const BYTES: usize = 8;
    const NAME: &'static str = "FP64";
    fn splat(v: f64) -> f64 {
        v
    }
    #[inline(always)]
    fn fma(self, a: f64, b: f64) -> f64 {
        self.mul_add(a, b)
    }
    fn to_f64(self) -> f64 {
        self
    }
}

impl ErtElem for f32 {
    const BYTES: usize = 4;
    const NAME: &'static str = "FP32";
    fn splat(v: f64) -> f32 {
        v as f32
    }
    #[inline(always)]
    fn fma(self, a: f32, b: f32) -> f32 {
        self.mul_add(a, b)
    }
    fn to_f64(self) -> f64 {
        self as f64
    }
}

/// The ERT FMA-chain kernel: for each element, run `flops_per_elem/2`
/// chained FMAs (each FMA = 2 FLOPs), then write back. Mirrors the
/// original ERT kernel's `KERNEL1/KERNEL2` macro ladder.
#[inline(never)]
pub fn fma_chain_kernel<T: ErtElem>(buf: &mut [T], flops_per_elem: u64) -> f64 {
    let alpha = T::splat(1.000001);
    let beta = T::splat(0.999999);
    let fmas = (flops_per_elem / 2).max(1);
    let mut checksum = T::splat(0.0);
    for x in buf.iter_mut() {
        let mut v = *x;
        for _ in 0..fmas {
            v = v.fma(alpha, beta);
        }
        *x = v;
        checksum = checksum.fma(T::splat(1.0), v);
    }
    checksum.to_f64()
}

/// Streaming triad (bandwidth probe): `a[i] = b[i] * s + a[i]`.
#[inline(never)]
pub fn triad_kernel<T: ErtElem>(a: &mut [T], b: &[T]) -> f64 {
    let s = T::splat(1.0000001);
    let mut checksum = T::splat(0.0);
    for (x, y) in a.iter_mut().zip(b.iter()) {
        *x = y.fma(s, *x);
        checksum = checksum.fma(T::splat(1.0), *x);
    }
    checksum.to_f64()
}

/// Run the empirical sweep for one element type.
///
/// For each (working set, flops/elem) point, `trials` timed runs of the
/// FMA chain are taken; GFLOP/s and GB/s are computed from the known
/// operation counts (2 FLOPs per FMA; bytes = one read + one write per
/// element per pass — matching how ERT credits its kernel).
pub fn run_sweep<T: ErtElem>(config: &SweepConfig) -> SweepResult {
    let mut points = Vec::new();
    for &ws in &config.working_sets {
        let n = (ws as usize / T::BYTES).max(16);
        let mut buf: Vec<T> = (0..n).map(|i| T::splat(1.0 + (i % 7) as f64 * 1e-6)).collect();
        for &fpe in &config.flops_per_elem {
            // Repeat passes so tiny working sets still run long enough
            // to time (≥ ~1e6 FLOPs per trial).
            let passes = (1_000_000 / (n as u64 * fpe).max(1)).clamp(1, 10_000);
            let mut times = Vec::with_capacity(config.trials as usize);
            let mut sink = 0.0;
            for _ in 0..config.trials {
                let t0 = Instant::now();
                for _ in 0..passes {
                    sink += fma_chain_kernel(&mut buf, fpe);
                }
                times.push(t0.elapsed().as_secs_f64());
            }
            std::hint::black_box(sink);
            let flops = (n as u64 * fpe * passes) as f64;
            let bytes = (n * T::BYTES * 2) as f64 * passes as f64;
            let best = times.iter().cloned().fold(f64::MAX, f64::min).max(1e-12);
            points.push(SweepPoint {
                working_set_bytes: ws,
                flops_per_elem: fpe,
                flops,
                bytes,
                gflops: flops / best / 1e9,
                gbytes: bytes / best / 1e9,
                time: Summary::of(&times),
            });
        }
    }
    SweepResult {
        label: T::NAME.to_string(),
        points,
        level_capacity: detect_level_capacities(),
    }
}

/// Attribute host cache levels. We use typical per-core L1d/L2 capacities
/// (sysfs parsing is unreliable inside containers); the knee positions
/// only gate *which* sweep points may claim a level's bandwidth, so
/// coarse values are fine.
fn detect_level_capacities() -> Vec<(MemLevel, u64)> {
    vec![
        (MemLevel::L1, 48 * 1024),
        (MemLevel::L2, 2 * 1024 * 1024),
        (MemLevel::Hbm, u64::MAX), // host DRAM plays the HBM role
    ]
}

/// Convenience: full empirical characterization (FP64 + FP32).
pub fn characterize(config: &SweepConfig) -> Vec<SweepResult> {
    vec![run_sweep::<f64>(config), run_sweep::<f32>(config)]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_config() -> SweepConfig {
        SweepConfig {
            working_sets: vec![16 * 1024, 8 * 1024 * 1024],
            flops_per_elem: vec![2, 64],
            trials: 2,
        }
    }

    #[test]
    fn kernels_compute_finite_values() {
        let mut buf = vec![1.0f64; 1024];
        let c = fma_chain_kernel(&mut buf, 8);
        assert!(c.is_finite());
        assert!(buf.iter().all(|v| v.is_finite()));
        let b = vec![1.0f64; 1024];
        let c2 = triad_kernel(&mut buf, &b);
        assert!(c2.is_finite());
    }

    #[test]
    fn sweep_produces_grid() {
        let r = run_sweep::<f32>(&tiny_config());
        assert_eq!(r.points.len(), 4);
        assert!(r.points.iter().all(|p| p.gflops > 0.0));
        assert!(r.points.iter().all(|p| p.gbytes > 0.0));
        assert_eq!(r.label, "FP32");
    }

    #[test]
    fn high_intensity_attains_more_flops() {
        // The defining ERT shape: FLOP rate rises with FLOPs/elem until
        // compute-bound.
        let r = run_sweep::<f64>(&tiny_config());
        let low = r
            .points
            .iter()
            .filter(|p| p.flops_per_elem == 2)
            .map(|p| p.gflops)
            .fold(0.0, f64::max);
        let high = r
            .points
            .iter()
            .filter(|p| p.flops_per_elem == 64)
            .map(|p| p.gflops)
            .fold(0.0, f64::max);
        assert!(high > low, "high {high} !> low {low}");
    }

    #[test]
    fn ceilings_positive_and_ordered() {
        let r = run_sweep::<f32>(&tiny_config());
        let peak = r.peak_gflops();
        assert!(peak > 0.05, "host should exceed 50 MFLOP/s, got {peak}");
        // Bandwidths are positive at both windows. (Strict L1 > DRAM
        // ordering is not asserted here: cargo test runs suites in
        // parallel on a shared core, which can distort the tiny-config
        // timings; the `repro ert --mode empirical` path uses the full
        // grid where the ordering is reliable.)
        let l1 = r.peak_bandwidth(MemLevel::L1);
        let dram = r.peak_bandwidth(MemLevel::Hbm);
        assert!(l1 > 0.0 && dram > 0.0);
        assert!(l1 >= dram * 0.3, "L1 {l1} vs DRAM {dram}");
    }
}
