//! Modeled ERT driver: the same sweep run through the V100 simulator,
//! regenerating the paper's Fig. 1 machine characterization.
//!
//! Each sweep point becomes a [`KernelDesc`] whose instruction mix and
//! access pattern match the ERT micro-kernel (chained FMAs over a
//! buffer, read+write per pass); the simulator's cache + cycle models
//! produce the sustained rates. Ceiling extraction then works exactly as
//! in the empirical driver.

use crate::device::{GpuSpec, MemLevel, Precision};
use crate::ert::sweep::{Ceilings, SweepConfig, SweepPoint, SweepResult};
use crate::sim::kernel::{AccessPattern, InstMix, KernelDesc};
use crate::sim::{CacheModel, CycleModel};
use crate::util::Summary;

/// Build the ERT kernel descriptor for one sweep point.
///
/// Passes over a `ws`-byte buffer doing `fpe` FLOPs per element. The
/// working set is re-swept `passes` times, so all reuse happens at
/// whichever cache level the buffer fits — that locality is what the
/// sweep exploits to expose per-level bandwidths.
pub fn ert_kernel(spec: &GpuSpec, p: Precision, ws: u64, fpe: u64, passes: u64) -> KernelDesc {
    let n = (ws / p.bytes() as u64).max(1);
    let mut mix = InstMix::default();
    mix.counts_mut(p).fma = n * (fpe / 2).max(1) * passes;
    // Tuned ERT keeps index arithmetic minimal (Table I v5 lesson):
    // one u32 update per element.
    mix.int_ops = n * passes;
    let request_bytes = 2 * ws * passes; // read + write per pass
    let block = 256u32;
    let grid = ((n.min(1 << 20) / block as u64).max(1)) as u32 * spec.sms.max(1);
    KernelDesc {
        name: format!("ert_{}_{}B_{}f", p.name(), ws, fpe),
        grid,
        block,
        mix,
        access: AccessPattern {
            load_bytes: request_bytes / 2,
            store_bytes: request_bytes / 2,
            footprint_bytes: ws,
            // Reuse across passes: `passes` sweeps of the same buffer.
            // Reuse across passes is captured by the innermost level the
            // buffer fits (the fit factor zeroes the rest) — declare it
            // at both levels and let capacity decide.
            l1_reuse: passes as f64,
            l2_reuse: passes as f64,
            // Residency dispersion: block scheduling is not perfectly
            // balanced, so ~an eighth of the buffer streams through each
            // L1 over the run rather than 1/sms of it.
            l1_resident_bytes: Some(ws / (spec.sms as u64 / 8).max(1)),
            l2_resident_bytes: None,
            // (If the buffer exceeds a level's capacity the cache model's
            // fit factor kills the reuse — that is the sweep's knee.)
        },
        occupancy: 0.9,
        efficiency: 0.98,
    }
}

/// Run the modeled sweep on a device for one precision, fanning the
/// (working set × FLOPs/elem) grid across the machine's cores.
pub fn run_sweep(spec: &GpuSpec, p: Precision, config: &SweepConfig) -> SweepResult {
    // No artificial cap: `parallel_map` clamps the worker count to the
    // grid size (standard config: 19 × 9 = 171 independent points).
    run_sweep_threads(spec, p, config, crate::exec::default_workers(usize::MAX))
}

/// [`run_sweep`] with an explicit worker count. Every grid point is an
/// independent pure evaluation of the analytic models, and
/// `parallel_map` preserves input order, so the output is *identical*
/// to the serial path (`threads = 1`) at any worker count.
pub fn run_sweep_threads(
    spec: &GpuSpec,
    p: Precision,
    config: &SweepConfig,
    threads: usize,
) -> SweepResult {
    let grid: Vec<(u64, u64)> = config
        .working_sets
        .iter()
        .flat_map(|&ws| config.flops_per_elem.iter().map(move |&fpe| (ws, fpe)))
        .collect();
    let points = crate::exec::parallel_map(grid, threads, |(ws, fpe)| {
        let cache = CacheModel::new(spec);
        let cycles = CycleModel::new(spec);
        // Enough passes that ramp is negligible, as real ERT does by
        // repeating trials until the duration is measurable.
        let passes = ((256u64 << 20) / ws.max(1)).clamp(4, 4096);
        let k = ert_kernel(spec, p, ws, fpe, passes);
        let t = cache.traffic(&k);
        let secs = cycles.elapsed_seconds(&k, &t);
        let flops = k.mix.cuda_core_flops() as f64;
        // ERT credits *algorithmic* bytes (the kernel's requests) —
        // the empirical bandwidth of the level the buffer lives in
        // emerges from the sweep timing, exactly as on hardware.
        let algorithmic_bytes = k.access.requested_bytes() as f64;
        SweepPoint {
            working_set_bytes: ws,
            flops_per_elem: fpe,
            flops,
            bytes: algorithmic_bytes,
            gflops: flops / secs / 1e9,
            gbytes: algorithmic_bytes / secs / 1e9,
            time: Summary::of(&[secs]),
        }
    });
    SweepResult {
        label: p.name().to_string(),
        points,
        level_capacity: vec![
            (MemLevel::L1, l1_window(spec)),
            (MemLevel::L2, l2_window(spec)),
            (MemLevel::Hbm, u64::MAX),
        ],
    }
}

/// Largest buffer that stays L1-resident device-wide. V100's aggregate
/// L1 (80 × 128 KiB = 10 MiB) nominally exceeds its 6 MiB L2; with the
/// scheduling-dispersion factor (see [`ert_kernel`]) the effective
/// L1-resident window is sms/8 × capacity.
fn l1_window(spec: &GpuSpec) -> u64 {
    (spec.sms as u64 / 8).max(1) * spec.l1.capacity_bytes
}

/// Largest buffer that stays L2-resident.
fn l2_window(spec: &GpuSpec) -> u64 {
    spec.l2.capacity_bytes * 9 / 10
}

/// Which level a working set resides in (device-wide view).
fn residency(spec: &GpuSpec, ws: u64) -> MemLevel {
    if ws <= l1_window(spec) {
        MemLevel::L1
    } else if ws <= l2_window(spec) {
        MemLevel::L2
    } else {
        MemLevel::Hbm
    }
}

/// Full modeled machine characterization: per-precision compute ceilings
/// (scaled by the device's ERT-calibrated achievable fractions) plus the
/// tensor-core ceiling from the GEMM sweep's asymptote, and per-level
/// bandwidths — the Fig. 1 dataset.
pub fn characterize(spec: &GpuSpec, config: &SweepConfig) -> Ceilings {
    let mut compute = Vec::new();
    let mut bandwidth: Vec<(MemLevel, f64)> = Vec::new();
    for p in Precision::ALL {
        let sweep = run_sweep(spec, p, config);
        // The simulator's FMA pipe attains theory; the achievable
        // fraction models the instruction-overhead gap ERT measures
        // (Table I quantifies that gap mechanistically for FP16).
        let peak = sweep.peak_gflops() * spec.achievable.for_precision(p);
        compute.push((p.name().to_string(), peak));
        if bandwidth.is_empty() {
            bandwidth = MemLevel::ALL
                .iter()
                .map(|&l| (l, sweep.peak_bandwidth(l)))
                .collect();
        }
    }
    // Tensor-core ceiling: asymptotic cuBLAS GEMM (Fig. 2 right edge).
    let tc = crate::ert::gemm::asymptotic_tensor_gflops(spec);
    compute.push(("TensorCore".to_string(), tc));
    Ceilings {
        compute_gflops: compute,
        bandwidth_gbs: bandwidth,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn modeled_fp64_sweep_shapes() {
        let spec = GpuSpec::v100();
        let r = run_sweep(&spec, Precision::Fp64, &SweepConfig::quick());
        assert!(!r.points.is_empty());
        // High-intensity cache-resident point approaches the FP64 pipe.
        let peak = r.peak_gflops();
        let theory = spec.theoretical_flops(Precision::Fp64) / 1e9;
        assert!(peak > 0.7 * theory, "peak {peak} theory {theory}");
        assert!(peak <= theory * 1.001);
    }

    #[test]
    fn fig1_ceilings_reproduced() {
        let spec = GpuSpec::v100();
        let c = characterize(&spec, &SweepConfig::quick());
        let get = |label: &str| c.compute(label).unwrap() / 1000.0; // TFLOP/s
        assert!((get("FP64") - 7.7).abs() < 0.5, "FP64 {}", get("FP64"));
        assert!((get("FP32") - 15.2).abs() < 1.0, "FP32 {}", get("FP32"));
        assert!((get("FP16") - 29.2).abs() < 2.0, "FP16 {}", get("FP16"));
        assert!((get("TensorCore") - 103.7).abs() < 5.0, "TC {}", get("TensorCore"));
        // Ceiling ordering (Fig. 1): TC > FP16 > FP32 > FP64.
        assert!(get("TensorCore") > get("FP16"));
        assert!(get("FP16") > get("FP32"));
        assert!(get("FP32") > get("FP64"));
    }

    #[test]
    fn bandwidth_hierarchy_from_sweep() {
        let spec = GpuSpec::v100();
        let r = run_sweep(&spec, Precision::Fp32, &SweepConfig::standard());
        let l1 = r.peak_bandwidth(MemLevel::L1);
        let l2 = r.peak_bandwidth(MemLevel::L2);
        let hbm = r.peak_bandwidth(MemLevel::Hbm);
        assert!(l1 > l2 && l2 > hbm, "{l1} {l2} {hbm}");
        // HBM band should be near the spec's 900 GB/s (within model slack).
        assert!((hbm - 900.0).abs() < 200.0, "hbm {hbm}");
    }

    #[test]
    fn parallel_sweep_identical_to_serial() {
        // The coordinator's speed win must not change a single bit of
        // output: grid points are pure and order is preserved.
        let spec = GpuSpec::v100();
        let cfg = SweepConfig::quick();
        let serial = run_sweep_threads(&spec, Precision::Fp32, &cfg, 1);
        let parallel = run_sweep_threads(&spec, Precision::Fp32, &cfg, 4);
        assert_eq!(serial.points.len(), parallel.points.len());
        for (a, b) in serial.points.iter().zip(&parallel.points) {
            assert_eq!(a.working_set_bytes, b.working_set_bytes);
            assert_eq!(a.flops_per_elem, b.flops_per_elem);
            assert_eq!(a.gflops.to_bits(), b.gflops.to_bits());
            assert_eq!(a.gbytes.to_bits(), b.gbytes.to_bits());
        }
    }

    #[test]
    fn residency_mapping() {
        let spec = GpuSpec::v100();
        // Windows: L1 ≤ 640 KiB (10 SMs' worth of half-L1), L2 ≤ 5.4 MiB.
        assert_eq!(residency(&spec, 64 * 1024), MemLevel::L1);
        assert_eq!(residency(&spec, 4 * 1024 * 1024), MemLevel::L2);
        assert_eq!(residency(&spec, 1 << 30), MemLevel::Hbm);
    }
}
