//! The FP16 CUDA-core tuning ladder (paper Table I).
//!
//! The paper walks five ERT implementations from a naive 15.4 TFLOP/s to
//! 29.2 TFLOP/s. Each step is an *instruction-selection* phenomenon, so
//! we model it mechanistically on the V100 issue model rather than
//! through the (interpret-mode) Pallas path:
//!
//! | v | change | mechanism modelled |
//! |---|--------|--------------------|
//! | v1 | naive `half` | FP16 ops issue down the FP32 pipe unpacked: one instruction per scalar op — half the packed rate |
//! | v2 | `half2` packing | packed (2 ops/inst) but `uint64_t` indexing: 64-bit adds split into 2 INT32 ops + carry, plus I2I conversions; only partially dual-issued |
//! | v3 | `uint32_t` indexing | index arithmetic shrinks to native INT32 ops |
//! | v4 | inline intermediates | register-move elimination removes MOV overhead |
//! | v5 | all-`uint32_t` | remaining 64-bit stragglers converted; minimal loop overhead |
//!
//! Throughput: `flops_per_iter / cycles_per_iter × fp32_lanes × SMs ×
//! clock`, where `cycles_per_iter = fp_insts + unhidden_overhead` and
//! overhead instructions dual-issue against the FP pipe with efficiency
//! `DUAL_ISSUE_HIDE` (Volta's independent INT32 pipe hides about half of
//! well-scheduled integer work in an FMA-saturated loop).

use crate::device::GpuSpec;

/// One rung of the ladder.
#[derive(Clone, Debug)]
pub struct LadderVersion {
    pub name: &'static str,
    pub description: &'static str,
    /// FP instructions per unrolled iteration (U = 8 elements-pairs).
    pub fp_insts: f64,
    /// Elements of useful FLOP work per iteration: U pairs × 2 elems × 2
    /// FLOPs (FMA).
    pub flops: f64,
    /// Overhead instructions per iteration (INT adds, I2I conversions,
    /// MOVs) before dual-issue hiding.
    pub overhead_insts: f64,
    /// Paper-reported TFLOP/s (Table I) for validation.
    pub paper_tflops: f64,
}

/// Fraction of overhead instructions hidden by dual-issue.
const DUAL_ISSUE_HIDE: f64 = 0.5;
/// Loop unroll factor (element-pairs per iteration).
const UNROLL: f64 = 8.0;

/// The five versions of Table I.
pub fn ladder() -> Vec<LadderVersion> {
    vec![
        LadderVersion {
            name: "v1",
            description: "naive",
            // Unpacked: one FP inst per scalar element => 2U insts for U
            // pairs; FLOPs unchanged (2 per FMA x 2U elements).
            fp_insts: 2.0 * UNROLL,
            flops: 4.0 * UNROLL,
            // u64 loop overhead amortizes over twice as many FP issue
            // slots; the FP32-pipe serialization dominates instead.
            overhead_insts: 0.51,
            paper_tflops: 15.421,
        },
        LadderVersion {
            name: "v2",
            description: "replace half with half2",
            // Packed: U half2 FMA insts carry 4U FLOPs.
            fp_insts: UNROLL,
            flops: 4.0 * UNROLL,
            // uint64_t indexing: per iteration ≈ two 64-bit adds (2 INT32
            // ops + carry each = 6), two I2I.64.32 conversions (2), and a
            // 64-bit compare/branch (1).
            overhead_insts: 8.9,
            paper_tflops: 20.142,
        },
        LadderVersion {
            name: "v3",
            description: "uint32_t for indexing",
            // Native INT32: one add, one compare/branch, plus residual
            // MOVs for intermediates.
            overhead_insts: 1.81,
            fp_insts: UNROLL,
            flops: 4.0 * UNROLL,
            paper_tflops: 28.152,
        },
        LadderVersion {
            name: "v4",
            description: "inline intermediate variables",
            overhead_insts: 1.67,
            fp_insts: UNROLL,
            flops: 4.0 * UNROLL,
            paper_tflops: 28.376,
        },
        LadderVersion {
            name: "v5",
            description: "uint32_t only",
            overhead_insts: 1.18,
            fp_insts: UNROLL,
            flops: 4.0 * UNROLL,
            paper_tflops: 29.182,
        },
    ]
}

impl LadderVersion {
    /// Modelled sustained TFLOP/s on a device.
    pub fn tflops(&self, spec: &GpuSpec) -> f64 {
        let unhidden = self.overhead_insts * (1.0 - DUAL_ISSUE_HIDE);
        let cycles_per_iter = self.fp_insts + unhidden;
        let lane_cycles_per_sec =
            spec.fp32_lanes_per_sm as f64 * spec.sms as f64 * spec.clock_hz;
        self.flops / cycles_per_iter * lane_cycles_per_sec / 1e12
    }

    /// Relative error vs the paper's measurement.
    pub fn error_vs_paper(&self, spec: &GpuSpec) -> f64 {
        crate::util::stats::rel_diff(self.tflops(spec), self.paper_tflops)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ladder_reproduces_table1_within_3pct() {
        let spec = GpuSpec::v100();
        for v in ladder() {
            let err = v.error_vs_paper(&spec);
            assert!(
                err < 0.03,
                "{}: model {:.3} vs paper {:.3} (err {:.1}%)",
                v.name,
                v.tflops(&spec),
                v.paper_tflops,
                err * 100.0
            );
        }
    }

    #[test]
    fn ladder_is_monotone() {
        let spec = GpuSpec::v100();
        let rungs = ladder();
        for w in rungs.windows(2) {
            assert!(
                w[1].tflops(&spec) > w[0].tflops(&spec),
                "{} !< {}",
                w[0].name,
                w[1].name
            );
        }
    }

    #[test]
    fn v5_approaches_packed_peak() {
        let spec = GpuSpec::v100();
        let v5 = &ladder()[4];
        let packed_peak = spec.theoretical_flops(crate::device::Precision::Fp16) / 1e12;
        let ratio = v5.tflops(&spec) / packed_peak;
        // Paper: "brought on par to the theoretical peak".
        assert!(ratio > 0.9, "ratio {ratio}");
        assert!(ratio <= 1.0);
    }

    #[test]
    fn v1_matches_fp32_rate() {
        // "each FP16 operation is essentially executed as an FP32
        // operation" — v1 should sit at the FP32 peak, not the FP16 one.
        let spec = GpuSpec::v100();
        let v1 = &ladder()[0];
        let fp32_peak = spec.theoretical_flops(crate::device::Precision::Fp32) / 1e12;
        assert!((v1.tflops(&spec) - fp32_peak).abs() / fp32_peak < 0.03);
    }

    #[test]
    fn biggest_jump_is_u32_indexing() {
        // Table I: v2→v3 (uint64→uint32 indexing) "has proven to bring
        // the most performance gain".
        let spec = GpuSpec::v100();
        let r = ladder();
        let gains: Vec<f64> =
            r.windows(2).map(|w| w[1].tflops(&spec) - w[0].tflops(&spec)).collect();
        let max_gain = gains.iter().cloned().fold(f64::MIN, f64::max);
        assert_eq!(gains[1], max_gain, "v2->v3 should be the largest gain: {gains:?}");
    }
}
