//! The ERT sweep algorithm: working-set × intensity grid, trial
//! repetition, and ceiling extraction — shared by the empirical (host
//! CPU) and modeled (V100 simulator) drivers.

use crate::device::MemLevel;
use crate::util::Summary;

/// Sweep configuration.
#[derive(Clone, Debug)]
pub struct SweepConfig {
    /// Working-set sizes in bytes (log-spaced, straddling cache levels).
    pub working_sets: Vec<u64>,
    /// FLOPs-per-element settings (the ERT "ERT_FLOPS" knob).
    pub flops_per_elem: Vec<u64>,
    /// Trials per point; the max is kept (ERT's convention: report the
    /// best sustained rate, since the ceiling is an upper bound).
    pub trials: u32,
}

impl SweepConfig {
    /// Default grid: 4 KiB … 1 GiB working sets, 1…256 FLOPs/elem.
    pub fn standard() -> SweepConfig {
        let mut working_sets = Vec::new();
        let mut ws = 4 * 1024u64;
        while ws <= 1 << 30 {
            working_sets.push(ws);
            ws *= 2;
        }
        SweepConfig {
            working_sets,
            flops_per_elem: vec![1, 2, 4, 8, 16, 32, 64, 128, 256],
            trials: 3,
        }
    }

    /// Reduced grid for smoke tests / `--quick`.
    pub fn quick() -> SweepConfig {
        SweepConfig {
            working_sets: vec![16 * 1024, 256 * 1024, 4 * 1024 * 1024, 64 * 1024 * 1024],
            flops_per_elem: vec![1, 16, 128],
            trials: 2,
        }
    }
}

/// One measured/modelled sweep point.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub working_set_bytes: u64,
    pub flops_per_elem: u64,
    /// Total FLOPs executed.
    pub flops: f64,
    /// Total bytes moved at the *measurement* boundary (for the
    /// empirical driver: bytes requested by the kernel; for the modeled
    /// driver: per-level traffic is attached separately).
    pub bytes: f64,
    /// Best sustained GFLOP/s across trials.
    pub gflops: f64,
    /// Best sustained GB/s across trials.
    pub gbytes: f64,
    /// Trial time summary (seconds).
    pub time: Summary,
}

impl SweepPoint {
    pub fn arithmetic_intensity(&self) -> f64 {
        if self.bytes == 0.0 {
            0.0
        } else {
            self.flops / self.bytes
        }
    }
}

/// Extracted machine ceilings: the ERT output consumed by Roofline
/// charts.
#[derive(Clone, Debug, Default)]
pub struct Ceilings {
    /// (label, GFLOP/s) compute ceilings, e.g. one per precision.
    pub compute_gflops: Vec<(String, f64)>,
    /// (level, GB/s) bandwidth ceilings.
    pub bandwidth_gbs: Vec<(MemLevel, f64)>,
}

impl Ceilings {
    pub fn compute(&self, label: &str) -> Option<f64> {
        self.compute_gflops
            .iter()
            .find(|(l, _)| l == label)
            .map(|(_, v)| *v)
    }

    pub fn bandwidth(&self, level: MemLevel) -> Option<f64> {
        self.bandwidth_gbs
            .iter()
            .find(|(l, _)| *l == level)
            .map(|(_, v)| *v)
    }
}

/// A sweep result: all points, plus the level boundaries used for
/// bandwidth attribution.
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub label: String,
    pub points: Vec<SweepPoint>,
    /// (level, max working set that fits) — from the device's cache
    /// geometry (empirical driver estimates these from the knees).
    pub level_capacity: Vec<(MemLevel, u64)>,
}

impl SweepResult {
    /// Compute ceiling: best GFLOP/s anywhere in the sweep (attained at
    /// the high-intensity, cache-resident corner).
    pub fn peak_gflops(&self) -> f64 {
        self.points.iter().map(|p| p.gflops).fold(0.0, f64::max)
    }

    /// Bandwidth ceiling for a level: best GB/s among low-intensity
    /// points whose working set fits that level (and does not fit the
    /// faster level above it — otherwise L1-resident runs would claim
    /// the L2 ceiling too).
    pub fn peak_bandwidth(&self, level: MemLevel) -> f64 {
        let cap = |l: MemLevel| -> u64 {
            self.level_capacity
                .iter()
                .find(|(ll, _)| *ll == l)
                .map(|(_, c)| *c)
                .unwrap_or(u64::MAX)
        };
        let upper = match level {
            MemLevel::L1 => 0,
            MemLevel::L2 => cap(MemLevel::L1),
            MemLevel::Hbm => cap(MemLevel::L2),
        };
        let this_cap = cap(level);
        let min_intensity = self
            .points
            .iter()
            .map(|p| p.flops_per_elem)
            .min()
            .unwrap_or(1);
        self.points
            .iter()
            .filter(|p| {
                p.flops_per_elem == min_intensity
                    && p.working_set_bytes > upper
                    && p.working_set_bytes <= this_cap
            })
            .map(|p| p.gbytes)
            .fold(0.0, f64::max)
    }

    /// Full ceiling extraction.
    pub fn ceilings(&self) -> Ceilings {
        Ceilings {
            compute_gflops: vec![(self.label.clone(), self.peak_gflops())],
            bandwidth_gbs: MemLevel::ALL
                .iter()
                .map(|&l| (l, self.peak_bandwidth(l)))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synthetic_result() -> SweepResult {
        // Hand-built sweep shaped like a 3-level machine:
        //   L1 ≤ 64 KiB @ 2000 GB/s, L2 ≤ 1 MiB @ 800 GB/s, DRAM @ 100 GB/s
        //   compute peak 5000 GFLOP/s at high intensity.
        let mut points = Vec::new();
        for &(ws, bw) in &[(32 * 1024u64, 2000.0), (512 * 1024, 800.0), (64 << 20, 100.0)] {
            for &f in &[1u64, 256] {
                let gflops = if f == 256 {
                    5000.0_f64.min(bw * f as f64 / 8.0)
                } else {
                    bw * f as f64 / 8.0
                };
                points.push(SweepPoint {
                    working_set_bytes: ws,
                    flops_per_elem: f,
                    flops: 1e9,
                    bytes: 8e9 / f as f64,
                    gflops,
                    gbytes: if f == 1 { bw } else { gflops * 8.0 / f as f64 },
                    time: Summary::of(&[1.0]),
                });
            }
        }
        SweepResult {
            label: "FP64".into(),
            points,
            level_capacity: vec![
                (MemLevel::L1, 64 * 1024),
                (MemLevel::L2, 1024 * 1024),
                (MemLevel::Hbm, u64::MAX),
            ],
        }
    }

    #[test]
    fn ceiling_extraction_finds_peaks() {
        let r = synthetic_result();
        let c = r.ceilings();
        assert_eq!(c.compute("FP64").unwrap(), 5000.0);
        assert_eq!(c.bandwidth(MemLevel::L1).unwrap(), 2000.0);
        assert_eq!(c.bandwidth(MemLevel::L2).unwrap(), 800.0);
        assert_eq!(c.bandwidth(MemLevel::Hbm).unwrap(), 100.0);
    }

    #[test]
    fn bandwidth_attribution_respects_level_windows() {
        let r = synthetic_result();
        // The L2 ceiling must NOT pick up the L1-resident 2000 GB/s point.
        assert!(r.peak_bandwidth(MemLevel::L2) < 2000.0);
        // And HBM must not claim L2's 800.
        assert!(r.peak_bandwidth(MemLevel::Hbm) < 800.0);
    }

    #[test]
    fn ai_of_point() {
        let p = SweepPoint {
            working_set_bytes: 1024,
            flops_per_elem: 4,
            flops: 100.0,
            bytes: 50.0,
            gflops: 1.0,
            gbytes: 1.0,
            time: Summary::of(&[1.0]),
        };
        assert_eq!(p.arithmetic_intensity(), 2.0);
    }

    #[test]
    fn config_grids() {
        let std = SweepConfig::standard();
        assert!(std.working_sets.len() > 10);
        assert!(std.working_sets.windows(2).all(|w| w[0] < w[1]));
        let quick = SweepConfig::quick();
        assert!(quick.working_sets.len() < std.working_sets.len());
    }
}
