//! Tensor-core GEMM size sweep (paper Fig. 2): sustained TFLOP/s as a
//! function of square matrix size for two implementations —
//! a cuBLAS-class library kernel and a hand-written WMMA kernel.
//!
//! Both are expressed as [`KernelDesc::gemm`] descriptors and run
//! through the simulator; they differ exactly where the paper says the
//! real ones do (§II-A2): the library kernel's larger tiles, shared-
//! memory padding and tuned block geometry give it higher sustained
//! issue efficiency (96.5% asymptotically) while the straightforward
//! WMMA version reaches ~54%.

use crate::device::{GpuSpec, Precision};
use crate::sim::kernel::KernelDesc;
use crate::sim::{CacheModel, CycleModel};

/// GEMM implementation flavor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmImpl {
    /// cuBLAS-class: 128×128 tiles, padded shared memory, tuned launch.
    Cublas,
    /// Hand-written WMMA: 64×64 tiles, bank conflicts, naive launch.
    Wmma,
}

impl GemmImpl {
    pub fn name(self) -> &'static str {
        match self {
            GemmImpl::Cublas => "cuBLAS",
            GemmImpl::Wmma => "wmma",
        }
    }

    fn tile(self, m: u64) -> u64 {
        match self {
            // cuBLAS heuristically picks smaller tiles for small
            // problems to keep all SMs busy (wave quantization); the
            // hand-written WMMA kernel has one fixed tile.
            GemmImpl::Cublas => {
                if m >= 2048 {
                    128
                } else {
                    64
                }
            }
            GemmImpl::Wmma => 64,
        }
    }

    /// Sustained issue efficiency of the inner loop. The WMMA number is
    /// the paper's observed 54%-of-peak asymptote (bank conflicts from
    /// unpadded shared memory + unoverlapped global loads); cuBLAS's
    /// 96.5% comes from Fig. 2.
    fn efficiency(self) -> f64 {
        match self {
            GemmImpl::Cublas => 0.965,
            GemmImpl::Wmma => 0.552,
        }
    }
}

/// One sweep point of Fig. 2.
#[derive(Clone, Debug)]
pub struct GemmPoint {
    pub m: u64,
    pub imp: GemmImpl,
    pub tflops: f64,
    pub fraction_of_peak: f64,
    pub seconds: f64,
}

/// Build the kernel descriptor for a square FP16 tensor-core GEMM.
pub fn gemm_kernel(spec: &GpuSpec, m: u64, imp: GemmImpl) -> KernelDesc {
    let mut k = KernelDesc::gemm(
        &format!("{}_m{}", imp.name(), m),
        m,
        m,
        m,
        Precision::Fp16,
        true,
        imp.tile(m),
        spec,
    );
    k.efficiency = imp.efficiency();
    // cuBLAS's tuned launch geometry reaches full occupancy earlier.
    k.occupancy = match imp {
        GemmImpl::Cublas => 0.6,
        GemmImpl::Wmma => 0.4,
    };
    k
}

/// Simulate one GEMM size/implementation point.
pub fn gemm_point(spec: &GpuSpec, m: u64, imp: GemmImpl) -> GemmPoint {
    let k = gemm_kernel(spec, m, imp);
    let t = CacheModel::new(spec).traffic(&k);
    let secs = CycleModel::new(spec).elapsed_seconds(&k, &t);
    // Fig. 2 credits `2*M^3 / t` (the paper's estimation, constant-coeff
    // epilogue excluded).
    let flops = 2.0 * (m as f64).powi(3);
    let tflops = flops / secs / 1e12;
    GemmPoint {
        m,
        imp,
        tflops,
        fraction_of_peak: tflops * 1e12 / spec.theoretical_tensor_flops(),
        seconds: secs,
    }
}

/// The full Fig. 2 sweep: M = 256 … 32768 for both implementations.
pub fn gemm_sweep(spec: &GpuSpec) -> Vec<GemmPoint> {
    let mut points = Vec::new();
    let mut m = 256u64;
    while m <= 32768 {
        points.push(gemm_point(spec, m, GemmImpl::Cublas));
        points.push(gemm_point(spec, m, GemmImpl::Wmma));
        m *= 2;
    }
    points
}

/// The asymptotic library GEMM rate in GFLOP/s — the Tensor Core ceiling
/// ERT adopts ("for the rest of this paper, we will use 103.7 TFLOP/s as
/// the Tensor Core peak").
pub fn asymptotic_tensor_gflops(spec: &GpuSpec) -> f64 {
    gemm_point(spec, 32768, GemmImpl::Cublas).tflops * 1000.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_asymptotes() {
        let spec = GpuSpec::v100();
        let cublas = gemm_point(&spec, 32768, GemmImpl::Cublas);
        let wmma = gemm_point(&spec, 32768, GemmImpl::Wmma);
        // Paper: 103.7 TFLOP/s at 96.5% (cuBLAS), 58 TFLOP/s at 54% (wmma).
        assert!(
            (cublas.fraction_of_peak - 0.965).abs() < 0.02,
            "cublas frac {}",
            cublas.fraction_of_peak
        );
        assert!((cublas.tflops - 103.7).abs() < 2.5, "cublas {}", cublas.tflops);
        assert!((wmma.fraction_of_peak - 0.54).abs() < 0.03, "wmma frac {}", wmma.fraction_of_peak);
        assert!((wmma.tflops - 58.0).abs() < 3.0, "wmma {}", wmma.tflops);
    }

    #[test]
    fn performance_rises_with_size() {
        // "as the matrix size increases, so does the performance of both
        // wmma and cuBLAS approaches".
        let spec = GpuSpec::v100();
        let sweep = gemm_sweep(&spec);
        for imp in [GemmImpl::Cublas, GemmImpl::Wmma] {
            let series: Vec<f64> = sweep
                .iter()
                .filter(|p| p.imp == imp)
                .map(|p| p.tflops)
                .collect();
            assert!(series.len() >= 8);
            for w in series.windows(2) {
                assert!(w[1] >= w[0] * 0.98, "{imp:?} non-increasing: {series:?}");
            }
            // Small sizes far below peak (wave quantization).
            assert!(series[0] < 0.25 * series.last().unwrap());
        }
    }

    #[test]
    fn cublas_dominates_wmma_everywhere() {
        let spec = GpuSpec::v100();
        for p in gemm_sweep(&spec).chunks(2) {
            let (cublas, wmma) = (&p[0], &p[1]);
            assert!(cublas.tflops > wmma.tflops, "m={}", cublas.m);
        }
    }

    #[test]
    fn asymptotic_ceiling_close_to_paper() {
        let spec = GpuSpec::v100();
        let gf = asymptotic_tensor_gflops(&spec);
        assert!((gf / 1000.0 - 103.7).abs() < 2.5, "{gf}");
    }
}
