//! The Empirical Roofline Toolkit (ERT), re-implemented (paper §II-A).
//!
//! ERT characterizes a machine by sweeping a finely tuned FMA-chain
//! micro-kernel over working-set sizes that straddle each cache level and
//! over FLOPs-per-byte configurations, then taking empirical maxima:
//! compute ceilings from the high-intensity end, per-level bandwidths
//! from working sets that fit each level.
//!
//! Two drivers share the sweep algorithm ([`sweep`]):
//!
//! * [`empirical`] — runs *real* native micro-kernels on the host CPU
//!   and measures wall-clock. This is the mode that proves the harness
//!   on actual silicon (this machine), and its ceilings feed the
//!   end-to-end example's CPU roofline.
//! * [`modeled`] — runs the same sweep through the V100 simulator,
//!   regenerating the paper's Fig. 1 ceilings.
//!
//! The FP16 tuning ladder of Table I lives in [`fp16_ladder`]; the
//! tensor-core GEMM size sweep of Fig. 2 in [`gemm`].

pub mod empirical;
pub mod fp16_ladder;
pub mod gemm;
pub mod modeled;
pub mod sweep;

pub use fp16_ladder::{ladder, LadderVersion};
pub use gemm::{gemm_sweep, GemmImpl, GemmPoint};
pub use sweep::{Ceilings as ErtCeilings, SweepConfig, SweepPoint, SweepResult};
