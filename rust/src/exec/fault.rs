//! Deterministic fault injection for the supervised execution layer.
//!
//! Real collection runs fail in ways unit tests never see: a single
//! kernel aborts metric replay, a cell hangs, a counter read flakes
//! once and then succeeds. A [`FaultPlan`] scripts exactly those
//! shapes — "panic on the cell matching X", "fail the first N attempts
//! of the kernel matching Y", "delay Z by D ms", "fail with probability
//! p" — and a [`FaultInjector`] built from the plan is threaded into
//! [`crate::profiler::Session`] (per-kernel labels) and
//! [`crate::scenario::ScenarioMatrix`] (per-cell labels) so every
//! failure path in the pipeline is exercisable on demand, byte-for-byte
//! reproducibly.
//!
//! Determinism: nothing here consults wall clocks or global RNG state.
//! Probabilistic faults derive their coin flip from
//! `FaultPlan::seed ^ fnv1a(label)` via [`crate::util::rng::Rng`], so
//! the same plan over the same labels fires identically regardless of
//! scheduling order or thread count. Stateful faults (`FailFirst`)
//! count applications per label, which is also order-independent.
//!
//! Labels are plain strings; the pipeline uses two schemes:
//! `cell#<index>:<scenario-id>` for matrix cells and `kernel:<name>`
//! for per-kernel simulation inside a session. A fault's `target`
//! matches any label containing it as a substring, or everything when
//! it is `"*"`.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Duration;

use super::supervise::TaskError;
use crate::cli::CliError;
use crate::util::rng::Rng;

/// One scripted fault. `target` is a substring matched against the
/// label passed to [`FaultInjector::apply`] (`"*"` matches every
/// label).
#[derive(Clone, Debug, PartialEq)]
pub enum Fault {
    /// Panic whenever a matching label is applied — models a hard
    /// crash inside the work function.
    PanicOn { target: String },
    /// Return a *transient* [`TaskError`] for the first `attempts`
    /// applications per matching label, then succeed — models flaky
    /// collection that a retry rides out.
    FailFirst { target: String, attempts: u32 },
    /// Sleep for `millis` before succeeding — models a slow cell for
    /// exercising soft deadlines.
    Delay { target: String, millis: u64 },
    /// Return a transient error with probability `probability`, decided
    /// deterministically per label from the plan seed.
    Chaos { target: String, probability: f64 },
}

impl Fault {
    fn target(&self) -> &str {
        match self {
            Fault::PanicOn { target }
            | Fault::FailFirst { target, .. }
            | Fault::Delay { target, .. }
            | Fault::Chaos { target, .. } => target,
        }
    }

    fn matches(&self, label: &str) -> bool {
        let t = self.target();
        t == "*" || label.contains(t)
    }
}

/// A scripted set of faults plus the seed that makes probabilistic
/// ones reproducible. Build programmatically or parse from the CLI
/// spec grammar (see [`FaultPlan::parse`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    pub seed: u64,
    pub faults: Vec<Fault>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan { seed, faults: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn panic_on(mut self, target: impl Into<String>) -> FaultPlan {
        self.faults.push(Fault::PanicOn { target: target.into() });
        self
    }

    pub fn fail_first(mut self, target: impl Into<String>, attempts: u32) -> FaultPlan {
        self.faults.push(Fault::FailFirst { target: target.into(), attempts });
        self
    }

    pub fn delay(mut self, target: impl Into<String>, millis: u64) -> FaultPlan {
        self.faults.push(Fault::Delay { target: target.into(), millis });
        self
    }

    pub fn chaos(mut self, target: impl Into<String>, probability: f64) -> FaultPlan {
        self.faults.push(Fault::Chaos { target: target.into(), probability });
        self
    }

    /// Parse the CLI spec grammar: `;`-separated clauses, each one of
    ///
    /// * `panic:<target>`
    /// * `fail:<target>:<attempts>`
    /// * `delay:<target>:<millis>`
    /// * `chaos:<target>:<probability>`
    /// * `seed=<u64>`
    ///
    /// Targets may themselves contain `:` (cell labels do) — the
    /// numeric argument is split off the *last* `:`. Example:
    /// `--inject-fault "panic:transformer-tf-forward-O0;seed=7"`.
    pub fn parse(spec: &str) -> Result<FaultPlan, CliError> {
        let mut plan = FaultPlan::default();
        for clause in spec.split(';') {
            let clause = clause.trim();
            if clause.is_empty() {
                continue;
            }
            if let Some(seed) = clause.strip_prefix("seed=") {
                plan.seed = seed
                    .parse::<u64>()
                    .map_err(|_| CliError(format!("bad fault seed '{seed}' (want a u64)")))?;
                continue;
            }
            let (kind, rest) = clause.split_once(':').ok_or_else(|| {
                CliError(format!(
                    "bad fault clause '{clause}' (want panic:<t>, fail:<t>:<n>, \
                     delay:<t>:<ms>, chaos:<t>:<p>, or seed=<n>)"
                ))
            })?;
            let split_num = |rest: &str| -> Result<(String, String), CliError> {
                let (target, num) = rest.rsplit_once(':').ok_or_else(|| {
                    CliError(format!("fault clause '{clause}' is missing its numeric argument"))
                })?;
                if target.is_empty() {
                    return Err(CliError(format!("fault clause '{clause}' has an empty target")));
                }
                Ok((target.to_string(), num.to_string()))
            };
            match kind {
                "panic" => {
                    if rest.is_empty() {
                        return Err(CliError(format!(
                            "fault clause '{clause}' has an empty target"
                        )));
                    }
                    plan = plan.panic_on(rest);
                }
                "fail" => {
                    let (target, num) = split_num(rest)?;
                    let attempts = num.parse::<u32>().map_err(|_| {
                        CliError(format!("bad attempt count '{num}' in '{clause}'"))
                    })?;
                    plan = plan.fail_first(target, attempts);
                }
                "delay" => {
                    let (target, num) = split_num(rest)?;
                    let millis = num.parse::<u64>().map_err(|_| {
                        CliError(format!("bad delay millis '{num}' in '{clause}'"))
                    })?;
                    plan = plan.delay(target, millis);
                }
                "chaos" => {
                    let (target, num) = split_num(rest)?;
                    let p = num.parse::<f64>().map_err(|_| {
                        CliError(format!("bad probability '{num}' in '{clause}'"))
                    })?;
                    if !(0.0..=1.0).contains(&p) {
                        return Err(CliError(format!(
                            "probability {p} in '{clause}' is outside [0, 1]"
                        )));
                    }
                    plan = plan.chaos(target, p);
                }
                other => {
                    return Err(CliError(format!(
                        "unknown fault kind '{other}' in '{clause}' \
                         (want panic, fail, delay, or chaos)"
                    )));
                }
            }
        }
        Ok(plan)
    }
}

fn fnv1a(s: &str) -> u64 {
    let mut hash: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        hash ^= u64::from(b);
        hash = hash.wrapping_mul(0x0000_0100_0000_01b3);
    }
    hash
}

/// Executes a [`FaultPlan`] against labeled work sites. Thread-safe;
/// one injector is shared across all workers of a fan-out so stateful
/// faults count applications globally.
pub struct FaultInjector {
    plan: FaultPlan,
    // Applications per (fault index, label) — keys FailFirst counting.
    counts: Mutex<HashMap<String, u32>>,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector { plan, counts: Mutex::new(HashMap::new()) }
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }

    /// Fire every fault whose target matches `label`. Returns `Ok(())`
    /// when nothing (or only a delay) fired; panics for `PanicOn`;
    /// returns a transient [`TaskError`] for `FailFirst` (within its
    /// budget) and `Chaos` (when the deterministic coin lands).
    pub fn apply(&self, label: &str) -> Result<(), TaskError> {
        for (index, fault) in self.plan.faults.iter().enumerate() {
            if !fault.matches(label) {
                continue;
            }
            match fault {
                Fault::Delay { millis, .. } => {
                    std::thread::sleep(Duration::from_millis(*millis));
                }
                Fault::PanicOn { .. } => {
                    panic!("fault injected: panic on '{label}'");
                }
                Fault::FailFirst { attempts, .. } => {
                    // Tolerate poisoning: a PanicOn arm never holds this
                    // lock, but a caller's catch_unwind may outlive one.
                    let mut counts =
                        self.counts.lock().unwrap_or_else(|poisoned| poisoned.into_inner());
                    let seen = counts.entry(format!("{index}:{label}")).or_insert(0);
                    if *seen < *attempts {
                        *seen += 1;
                        return Err(TaskError::transient(format!(
                            "fault injected: failing attempt {seen} of first {attempts} \
                             for '{label}'"
                        )));
                    }
                }
                Fault::Chaos { probability, .. } => {
                    let mut rng = Rng::new(self.plan.seed ^ fnv1a(label));
                    if rng.chance(*probability) {
                        return Err(TaskError::transient(format!(
                            "fault injected: chaos (p={probability}) on '{label}'"
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_round_trips_the_grammar() {
        let plan =
            FaultPlan::parse("panic:cell#3;fail:kernel:conv2d:2;delay:relu:15;chaos:*:0.25;seed=9")
                .unwrap();
        assert_eq!(plan.seed, 9);
        assert_eq!(
            plan.faults,
            vec![
                Fault::PanicOn { target: "cell#3".into() },
                Fault::FailFirst { target: "kernel:conv2d".into(), attempts: 2 },
                Fault::Delay { target: "relu".into(), millis: 15 },
                Fault::Chaos { target: "*".into(), probability: 0.25 },
            ]
        );
    }

    #[test]
    fn parse_rejects_malformed_clauses() {
        for bad in [
            "explode:everything",
            "panic:",
            "fail:conv2d",
            "fail:conv2d:many",
            "delay:relu:soon",
            "chaos:*:1.5",
            "seed=minus-one",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "'{bad}' should not parse");
        }
        assert!(FaultPlan::parse("").unwrap().is_empty());
    }

    #[test]
    fn panic_on_fires_only_for_matching_labels() {
        let inj = FaultInjector::new(FaultPlan::new(0).panic_on("cell#2:"));
        assert!(inj.apply("cell#1:deepcam").is_ok());
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            inj.apply("cell#2:deepcam").ok();
        }));
        assert!(caught.is_err(), "matching label must panic");
    }

    #[test]
    fn fail_first_is_transient_then_clears() {
        let inj = FaultInjector::new(FaultPlan::new(0).fail_first("conv", 2));
        let first = inj.apply("kernel:conv2d").unwrap_err();
        assert!(first.transient);
        assert!(inj.apply("kernel:conv2d").is_err());
        assert!(inj.apply("kernel:conv2d").is_ok(), "budget spent => success");
        // Budgets are per label.
        assert!(inj.apply("kernel:conv1d").is_err());
    }

    #[test]
    fn chaos_is_deterministic_per_label_and_seed() {
        let labels: Vec<String> = (0..64).map(|i| format!("kernel:k{i}")).collect();
        let fire = |seed: u64| -> Vec<bool> {
            let inj = FaultInjector::new(FaultPlan::new(seed).chaos("*", 0.5));
            labels.iter().map(|l| inj.apply(l).is_err()).collect()
        };
        let a = fire(7);
        assert_eq!(a, fire(7), "same seed => same outcomes");
        assert_ne!(a, fire(8), "different seed => different outcomes");
        let fired = a.iter().filter(|&&b| b).count();
        assert!((8..=56).contains(&fired), "p=0.5 over 64 labels fired {fired} times");
    }

    #[test]
    fn delay_passes_through() {
        let inj = FaultInjector::new(FaultPlan::new(0).delay("slow", 1));
        assert!(inj.apply("cell#0:slow-cell").is_ok());
        assert!(inj.apply("cell#0:fast").is_ok());
    }
}
