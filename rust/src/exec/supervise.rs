//! Supervised execution: the panic-safe, deadline-aware, retryable
//! sibling of [`crate::exec::parallel_map`].
//!
//! The plain fan-out is the right tool for pure, infallible work, but
//! one panic aborts every sibling item — unacceptable once the pipeline
//! ingests real-world traces where individual cells fail all the time
//! (kernels that fail metric replay, truncated exports; cf. arXiv
//! 2009.02449 §"collection pitfalls"). [`parallel_try_map`] isolates
//! each item instead:
//!
//! * every attempt runs under `catch_unwind`, so a panicking item
//!   becomes an [`ExecError::Panicked`] result while its siblings keep
//!   running;
//! * a per-item **soft deadline** is enforced by a watchdog thread: std
//!   threads cannot be cancelled, so an overdue item is not killed, but
//!   it is counted as failed the moment it goes overdue (so fail-fast
//!   engages while it still runs) and its eventual result is replaced
//!   by [`ExecError::TimedOut`];
//! * errors classified *transient* by the work function are retried
//!   under a [`RetryPolicy`] with a deterministic exponential backoff
//!   schedule; panics and fatal errors are never retried;
//! * [`SupervisePolicy::stop_after_failures`] stops *scheduling* new
//!   items once enough failures accumulated (the CLI's `--fail-fast` /
//!   `--max-failures`); already-claimed items run to completion and
//!   unclaimed ones are recorded as [`ExecError::Skipped`].
//!
//! Results come back in input order, one `Result` per item, so callers
//! degrade gracefully instead of all-or-nothing. With the default
//! policy and an infallible work function the output is item-for-item
//! identical to `parallel_map` (test-asserted); the only happy-path
//! cost is `catch_unwind` + clock bookkeeping, tracked by the
//! `exec_parallel_try_map_supervised_10k` hotpath bench case.
//!
//! Note: a caught panic still runs the process's panic hook, so the
//! default hook prints the usual `thread ... panicked` line to stderr.
//! That noise is deliberate — a supervised panic is contained, not
//! hidden.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// A work-function failure: a message plus the transience
/// classification the [`RetryPolicy`] keys on. Only errors explicitly
/// marked [`TaskError::transient`] are retried.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskError {
    pub message: String,
    pub transient: bool,
}

impl TaskError {
    /// A permanent failure: never retried.
    pub fn fatal(message: impl Into<String>) -> TaskError {
        TaskError { message: message.into(), transient: false }
    }

    /// A transient failure: retried under the [`RetryPolicy`].
    pub fn transient(message: impl Into<String>) -> TaskError {
        TaskError { message: message.into(), transient: true }
    }
}

impl std::fmt::Display for TaskError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.message)
    }
}

/// How a supervised item failed. Returned per item by
/// [`parallel_try_map`]; the matrix error manifest serializes
/// [`ExecError::kind`], [`ExecError::attempts`] and
/// [`ExecError::elapsed_s`] per failed cell.
#[derive(Clone, Debug, PartialEq)]
pub enum ExecError {
    /// The work function panicked; the payload is the panic message.
    Panicked { payload: String, attempts: u32, elapsed_s: f64 },
    /// The item exceeded the soft deadline. The work itself was not
    /// cancelled (std threads cannot be), but its result is discarded.
    TimedOut { elapsed_s: f64, deadline_s: f64 },
    /// The work function returned an error; `attempts` counts every
    /// try, so a transient error that exhausted its retry budget
    /// reports `attempts == max_attempts`.
    Failed { error: String, attempts: u32, elapsed_s: f64 },
    /// Never attempted: the failure budget was already spent when this
    /// item came up for scheduling (fail-fast / max-failures).
    Skipped { after_failures: usize },
}

impl ExecError {
    /// Stable machine-readable discriminant (manifest `kind` field).
    pub fn kind(&self) -> &'static str {
        match self {
            ExecError::Panicked { .. } => "panicked",
            ExecError::TimedOut { .. } => "timed_out",
            ExecError::Failed { .. } => "failed",
            ExecError::Skipped { .. } => "skipped",
        }
    }

    /// How many attempts ran (0 for skipped items, 1 for timeouts —
    /// an overdue item is never retried).
    pub fn attempts(&self) -> u32 {
        match self {
            ExecError::Panicked { attempts, .. } | ExecError::Failed { attempts, .. } => *attempts,
            ExecError::TimedOut { .. } => 1,
            ExecError::Skipped { .. } => 0,
        }
    }

    /// Wall-clock seconds spent on the item before it failed.
    pub fn elapsed_s(&self) -> f64 {
        match self {
            ExecError::Panicked { elapsed_s, .. }
            | ExecError::TimedOut { elapsed_s, .. }
            | ExecError::Failed { elapsed_s, .. } => *elapsed_s,
            ExecError::Skipped { .. } => 0.0,
        }
    }
}

impl std::fmt::Display for ExecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ExecError::Panicked { payload, attempts, .. } => {
                write!(f, "panicked on attempt {attempts}: {payload}")
            }
            ExecError::TimedOut { elapsed_s, deadline_s } => {
                write!(f, "exceeded soft deadline ({elapsed_s:.3}s > {deadline_s:.3}s)")
            }
            ExecError::Failed { error, attempts, .. } => {
                write!(f, "failed after {attempts} attempt(s): {error}")
            }
            ExecError::Skipped { after_failures } => {
                write!(f, "skipped after {after_failures} earlier failure(s)")
            }
        }
    }
}

/// Retry budget and backoff schedule for transient failures. The
/// schedule is deterministic (base · 2^(attempt−1), capped) so reruns
/// of the same plan behave identically.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Total attempts including the first (>= 1).
    pub max_attempts: u32,
    /// Backoff before the second attempt; doubles per further attempt.
    pub backoff: Duration,
    /// Upper bound on any single backoff sleep.
    pub backoff_cap: Duration,
}

impl RetryPolicy {
    /// No retries: one attempt, no backoff.
    pub fn none() -> RetryPolicy {
        RetryPolicy {
            max_attempts: 1,
            backoff: Duration::ZERO,
            backoff_cap: Duration::ZERO,
        }
    }

    /// Up to `max_attempts` total attempts with no backoff sleeps
    /// (tests and in-memory work rarely want real waiting).
    pub fn attempts(max_attempts: u32) -> RetryPolicy {
        RetryPolicy { max_attempts: max_attempts.max(1), ..RetryPolicy::none() }
    }

    /// Add an exponential backoff schedule: `base` before the second
    /// attempt, doubling per attempt, never exceeding `cap`.
    pub fn with_backoff(mut self, base: Duration, cap: Duration) -> RetryPolicy {
        self.backoff = base;
        self.backoff_cap = cap;
        self
    }

    /// The deterministic sleep before attempt `attempt + 1` (attempts
    /// are 1-based: `backoff_for(1)` precedes the second attempt).
    pub fn backoff_for(&self, attempt: u32) -> Duration {
        if self.backoff.is_zero() {
            return Duration::ZERO;
        }
        let doublings = attempt.saturating_sub(1).min(16);
        (self.backoff * 2u32.saturating_pow(doublings)).min(self.backoff_cap.max(self.backoff))
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy::none()
    }
}

/// Everything [`parallel_try_map`] needs to know beyond the work
/// function: retry budget, per-item soft deadline, and the failure
/// budget after which unclaimed items are skipped.
#[derive(Clone, Copy, Debug, Default)]
pub struct SupervisePolicy {
    pub retry: RetryPolicy,
    /// Per-item soft deadline. `None` disables the watchdog.
    pub soft_deadline: Option<Duration>,
    /// Stop scheduling new items once this many failures were recorded
    /// (`Some(1)` = fail-fast). `None` = always run every item.
    pub stop_after_failures: Option<usize>,
}

fn payload_string(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "<non-string panic payload>".to_string()
    }
}

/// One item end to end: attempts loop + panic capture + deadline
/// classification. `overdue` is pre-set by the watchdog when the item
/// went over its deadline mid-flight. Returns the result plus how many
/// attempts actually ran (the error variants embed it too; the success
/// path needs it for the `exec.retries` telemetry counter).
fn run_attempts<T, R, F>(
    item: &T,
    policy: &SupervisePolicy,
    overdue: &AtomicBool,
    f: &F,
) -> (Result<R, ExecError>, u32)
where
    F: Fn(&T) -> Result<R, TaskError>,
{
    let start = Instant::now();
    let over = |elapsed: Duration| {
        overdue.load(Ordering::SeqCst) || policy.soft_deadline.is_some_and(|d| elapsed > d)
    };
    let mut attempt: u32 = 1;
    loop {
        let outcome = catch_unwind(AssertUnwindSafe(|| f(item)));
        let elapsed = start.elapsed();
        let elapsed_s = elapsed.as_secs_f64();
        match outcome {
            Ok(Ok(value)) => {
                if over(elapsed) {
                    let deadline_s =
                        policy.soft_deadline.unwrap_or(elapsed).as_secs_f64();
                    return (Err(ExecError::TimedOut { elapsed_s, deadline_s }), attempt);
                }
                return (Ok(value), attempt);
            }
            Ok(Err(task_err)) => {
                if task_err.transient && attempt < policy.retry.max_attempts && !over(elapsed) {
                    let pause = policy.retry.backoff_for(attempt);
                    if !pause.is_zero() {
                        std::thread::sleep(pause);
                    }
                    attempt += 1;
                    continue;
                }
                return (
                    Err(ExecError::Failed {
                        error: task_err.message,
                        attempts: attempt,
                        elapsed_s,
                    }),
                    attempt,
                );
            }
            Err(payload) => {
                return (
                    Err(ExecError::Panicked {
                        payload: payload_string(payload),
                        attempts: attempt,
                        elapsed_s,
                    }),
                    attempt,
                );
            }
        }
    }
}

/// Record one completed item's scheduling telemetry: how long it sat
/// queued before a worker claimed it, how long it ran, and any attempts
/// beyond the first.
fn record_item(
    metrics: Option<&crate::obs::MetricsRegistry>,
    queue_wait: Duration,
    run: Duration,
    attempts: u32,
) {
    let Some(m) = metrics else { return };
    m.observe_s("exec.queue_wait_s", queue_wait.as_secs_f64());
    m.observe_s("exec.run_s", run.as_secs_f64());
    if attempts > 1 {
        m.add("exec.retries", u64::from(attempts - 1));
    }
}

/// Apply `f` to every item in parallel across up to `threads` workers,
/// preserving input order, isolating each item's failures. See the
/// module docs for the semantics of panics, deadlines, retries and
/// fail-fast skipping. With the default [`SupervisePolicy`] and an
/// infallible `f`, output values are identical to
/// [`crate::exec::parallel_map`]'s.
///
/// Unlike `parallel_map`, `f` borrows its item (`Fn(&T)`) so a
/// transient failure can be retried on the same input.
pub fn parallel_try_map<T, R, F>(
    items: Vec<T>,
    threads: usize,
    policy: &SupervisePolicy,
    f: F,
) -> Vec<Result<R, ExecError>>
where
    T: Send,
    R: Send,
    F: Fn(&T) -> Result<R, TaskError> + Sync,
{
    parallel_try_map_observed(items, threads, policy, None, f)
}

/// [`parallel_try_map`] with scheduling telemetry: when a
/// [`crate::obs::MetricsRegistry`] is supplied, every executed item
/// records its queue wait (fan-out start → worker claim) and run time
/// into the `exec.queue_wait_s` / `exec.run_s` histograms, and attempts
/// beyond the first accumulate into the `exec.retries` counter. With
/// `metrics = None` this *is* `parallel_try_map` — the plain entry
/// point is a thin wrapper.
pub fn parallel_try_map_observed<T, R, F>(
    items: Vec<T>,
    threads: usize,
    policy: &SupervisePolicy,
    metrics: Option<&crate::obs::MetricsRegistry>,
    f: F,
) -> Vec<Result<R, ExecError>>
where
    T: Send,
    R: Send,
    F: Fn(&T) -> Result<R, TaskError> + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    let fan_out_start = Instant::now();

    if threads == 1 {
        // Serial path: deterministic scheduling (items run in order, so
        // fail-fast skips exactly the suffix after the budget is spent)
        // and no watchdog thread — the deadline is classified from the
        // measured elapsed time after each item completes.
        let mut failures = 0usize;
        let overdue = AtomicBool::new(false);
        return items
            .iter()
            .map(|item| {
                if policy.stop_after_failures.is_some_and(|stop| failures >= stop) {
                    return Err(ExecError::Skipped { after_failures: failures });
                }
                overdue.store(false, Ordering::SeqCst);
                let queue_wait = fan_out_start.elapsed();
                let t0 = Instant::now();
                let (out, attempts) = run_attempts(item, policy, &overdue, &f);
                record_item(metrics, queue_wait, t0.elapsed(), attempts);
                if out.is_err() {
                    failures += 1;
                }
                out
            })
            .collect();
    }

    let next = AtomicUsize::new(0);
    let completed = AtomicUsize::new(0);
    let failures = AtomicUsize::new(0);
    let overdue: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let counted: Vec<AtomicBool> = (0..n).map(|_| AtomicBool::new(false)).collect();
    let starts: Vec<Mutex<Option<Instant>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let outputs: Vec<Mutex<Option<Result<R, ExecError>>>> =
        (0..n).map(|_| Mutex::new(None)).collect();
    let count_failure = |i: usize| {
        if !counted[i].swap(true, Ordering::SeqCst) {
            failures.fetch_add(1, Ordering::SeqCst);
        }
    };

    std::thread::scope(|scope| {
        // The watchdog: scans in-flight items and marks overdue ones as
        // failed *immediately*, so the fail-fast budget engages even
        // while a hung item is still running (its thread cannot be
        // cancelled; its eventual result is discarded).
        if let Some(deadline) = policy.soft_deadline {
            scope.spawn(|| {
                let poll = (deadline / 8)
                    .clamp(Duration::from_millis(1), Duration::from_millis(50));
                while completed.load(Ordering::SeqCst) < n {
                    std::thread::sleep(poll);
                    for i in 0..n {
                        if overdue[i].load(Ordering::SeqCst) {
                            continue;
                        }
                        let started = *starts[i].lock().unwrap();
                        if started.is_some_and(|s| s.elapsed() > deadline) {
                            overdue[i].store(true, Ordering::SeqCst);
                            count_failure(i);
                        }
                    }
                }
            });
        }

        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let failed_so_far = failures.load(Ordering::SeqCst);
                let out = if policy
                    .stop_after_failures
                    .is_some_and(|stop| failed_so_far >= stop)
                {
                    Err(ExecError::Skipped { after_failures: failed_so_far })
                } else {
                    let queue_wait = fan_out_start.elapsed();
                    let t0 = Instant::now();
                    *starts[i].lock().unwrap() = Some(t0);
                    let (out, attempts) = run_attempts(&items[i], policy, &overdue[i], &f);
                    *starts[i].lock().unwrap() = None;
                    record_item(metrics, queue_wait, t0.elapsed(), attempts);
                    out
                };
                if out.is_err() {
                    count_failure(i);
                }
                *outputs[i].lock().unwrap() = Some(out);
                completed.fetch_add(1, Ordering::SeqCst);
            });
        }
    });

    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing supervised output"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn counts() -> Mutex<HashMap<i64, u32>> {
        Mutex::new(HashMap::new())
    }

    #[test]
    fn matches_parallel_map_on_infallible_work() {
        let items: Vec<i64> = (0..500).collect();
        let raw = crate::exec::parallel_map(items.clone(), 8, |x| x * x);
        let supervised = parallel_try_map(items, 8, &SupervisePolicy::default(), |&x| {
            Ok::<i64, TaskError>(x * x)
        });
        assert_eq!(supervised.len(), raw.len());
        for (s, r) in supervised.into_iter().zip(raw) {
            assert_eq!(s.unwrap(), r);
        }
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<Result<i32, ExecError>> =
            parallel_try_map(Vec::<i32>::new(), 4, &SupervisePolicy::default(), |&x| Ok(x));
        assert!(out.is_empty());
        let out = parallel_try_map(vec![41], 4, &SupervisePolicy::default(), |&x| {
            Ok::<i32, TaskError>(x + 1)
        });
        assert_eq!(out[0].as_ref().unwrap(), &42);
    }

    #[test]
    fn panic_is_isolated_and_reported() {
        let items: Vec<i64> = (0..8).collect();
        let out = parallel_try_map(items, 4, &SupervisePolicy::default(), |&x| {
            if x == 3 {
                panic!("boom on {x}");
            }
            Ok::<i64, TaskError>(x)
        });
        for (i, r) in out.iter().enumerate() {
            if i == 3 {
                match r {
                    Err(ExecError::Panicked { payload, attempts, .. }) => {
                        assert_eq!(payload, "boom on 3");
                        assert_eq!(*attempts, 1);
                    }
                    other => panic!("expected Panicked, got {other:?}"),
                }
                assert_eq!(r.as_ref().unwrap_err().kind(), "panicked");
            } else {
                assert_eq!(*r.as_ref().unwrap(), i as i64);
            }
        }
    }

    #[test]
    fn transient_errors_retry_to_success() {
        let seen = counts();
        let policy = SupervisePolicy { retry: RetryPolicy::attempts(3), ..Default::default() };
        let out = parallel_try_map(vec![7i64], 2, &policy, |&x| {
            let mut seen = seen.lock().unwrap();
            let n = seen.entry(x).or_insert(0);
            *n += 1;
            if *n < 3 {
                Err(TaskError::transient(format!("flaky attempt {n}")))
            } else {
                Ok(x * 10)
            }
        });
        assert_eq!(out[0].as_ref().unwrap(), &70);
        assert_eq!(seen.lock().unwrap()[&7], 3, "two retries then success");
    }

    #[test]
    fn fatal_errors_do_not_retry() {
        let seen = counts();
        let policy = SupervisePolicy { retry: RetryPolicy::attempts(5), ..Default::default() };
        let out = parallel_try_map(vec![1i64], 1, &policy, |&x| {
            *seen.lock().unwrap().entry(x).or_insert(0) += 1;
            Err::<i64, _>(TaskError::fatal("permanent"))
        });
        match &out[0] {
            Err(ExecError::Failed { error, attempts, .. }) => {
                assert_eq!(error, "permanent");
                assert_eq!(*attempts, 1, "fatal => single attempt");
            }
            other => panic!("expected Failed, got {other:?}"),
        }
        assert_eq!(seen.lock().unwrap()[&1], 1);
    }

    #[test]
    fn transient_exhaustion_reports_attempt_count() {
        let policy = SupervisePolicy { retry: RetryPolicy::attempts(3), ..Default::default() };
        let out = parallel_try_map(vec![0u8], 1, &policy, |_| {
            Err::<(), _>(TaskError::transient("always down"))
        });
        match &out[0] {
            Err(ExecError::Failed { attempts, .. }) => assert_eq!(*attempts, 3),
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn soft_deadline_times_out_slow_items() {
        let policy = SupervisePolicy {
            soft_deadline: Some(Duration::from_millis(5)),
            ..Default::default()
        };
        for threads in [1, 3] {
            let out = parallel_try_map(vec![0u8, 1, 2], threads, &policy, |&x| {
                if x == 1 {
                    std::thread::sleep(Duration::from_millis(40));
                }
                Ok::<u8, TaskError>(x)
            });
            assert_eq!(out[0].as_ref().unwrap(), &0, "threads={threads}");
            assert_eq!(out[2].as_ref().unwrap(), &2, "threads={threads}");
            match &out[1] {
                Err(ExecError::TimedOut { elapsed_s, deadline_s }) => {
                    assert!(*elapsed_s >= *deadline_s, "threads={threads}");
                    assert_eq!(out[1].as_ref().unwrap_err().attempts(), 1);
                }
                other => panic!("expected TimedOut (threads={threads}), got {other:?}"),
            }
        }
    }

    #[test]
    fn fail_fast_skips_the_rest_serially() {
        let policy = SupervisePolicy { stop_after_failures: Some(1), ..Default::default() };
        let out = parallel_try_map((0..5i64).collect(), 1, &policy, |&x| {
            if x == 1 {
                Err(TaskError::fatal("first failure"))
            } else {
                Ok(x)
            }
        });
        assert_eq!(out[0].as_ref().unwrap(), &0);
        assert!(matches!(out[1], Err(ExecError::Failed { .. })));
        for r in &out[2..] {
            assert!(
                matches!(r, Err(ExecError::Skipped { after_failures: 1 })),
                "tail must be skipped: {r:?}"
            );
            assert_eq!(r.as_ref().unwrap_err().attempts(), 0);
        }
    }

    #[test]
    fn failure_budget_accounts_all_outcomes_in_parallel() {
        // Parallel fail-fast cannot pin *which* items skip, but every
        // item must come back classified and the budget must bite.
        let policy = SupervisePolicy { stop_after_failures: Some(1), ..Default::default() };
        let out = parallel_try_map((0..64i64).collect(), 8, &policy, |&x| {
            if x == 0 {
                Err(TaskError::fatal("seed failure"))
            } else {
                Ok(x)
            }
        });
        assert_eq!(out.len(), 64);
        let failed = out.iter().filter(|r| r.is_err()).count();
        assert!(failed >= 1);
    }

    #[test]
    fn backoff_schedule_is_deterministic_and_capped() {
        let p = RetryPolicy::attempts(8)
            .with_backoff(Duration::from_millis(10), Duration::from_millis(35));
        assert_eq!(p.backoff_for(1), Duration::from_millis(10));
        assert_eq!(p.backoff_for(2), Duration::from_millis(20));
        assert_eq!(p.backoff_for(3), Duration::from_millis(35), "capped");
        assert_eq!(p.backoff_for(7), Duration::from_millis(35), "capped");
        assert_eq!(RetryPolicy::none().backoff_for(4), Duration::ZERO);
    }

    #[test]
    fn observed_fanout_records_waits_runs_and_retries() {
        let m = crate::obs::MetricsRegistry::new();
        let seen = counts();
        let policy = SupervisePolicy { retry: RetryPolicy::attempts(3), ..Default::default() };
        for threads in [1, 4] {
            let out = parallel_try_map_observed((0..8i64).collect(), threads, &policy, Some(&m), |&x| {
                let mut seen = seen.lock().unwrap();
                let n = seen.entry(x).or_insert(0);
                *n += 1;
                // Item 2 fails once per sweep, then succeeds on retry.
                if x == 2 && *n % 2 == 1 {
                    Err(TaskError::transient("flaky"))
                } else {
                    Ok(x)
                }
            });
            assert!(out.iter().all(|r| r.is_ok()), "threads={threads}");
        }
        let snap = m.snapshot();
        assert_eq!(snap.counter("exec.retries"), 2, "one retry per sweep");
        assert_eq!(snap.histograms["exec.run_s"].count, 16, "every item observed");
        assert_eq!(snap.histograms["exec.queue_wait_s"].count, 16);

        // The plain wrapper records nothing and behaves identically.
        let out = parallel_try_map((0..8i64).collect(), 4, &SupervisePolicy::default(), |&x| {
            Ok::<i64, TaskError>(x * 2)
        });
        assert_eq!(out.len(), 8);
    }

    #[test]
    fn error_accessors_and_display() {
        let e = ExecError::Failed { error: "x".into(), attempts: 2, elapsed_s: 0.5 };
        assert_eq!(e.kind(), "failed");
        assert_eq!(e.attempts(), 2);
        assert_eq!(e.elapsed_s(), 0.5);
        assert!(e.to_string().contains("after 2 attempt(s)"));
        let s = ExecError::Skipped { after_failures: 3 };
        assert_eq!((s.kind(), s.attempts(), s.elapsed_s()), ("skipped", 0, 0.0));
        assert!(s.to_string().contains("3 earlier failure(s)"));
    }
}
