//! Execution substrate: a scoped thread pool and `parallel_map` used by
//! the coordinator for profiling sweeps (one pass per metric, many
//! kernels per pass). Replaces `tokio`/`rayon`, which are not in the
//! offline vendor set — the workload here is CPU-bound, so plain std
//! threads with a work queue are the right shape anyway.
//!
//! `parallel_map` is the infallible fast path: one panic aborts the
//! whole fan-out. Work that must degrade gracefully — matrix cells,
//! per-kernel simulation over real traces — goes through the
//! [`supervise`] sibling instead, which isolates panics, enforces soft
//! deadlines, retries transient failures, and reports a structured
//! [`ExecError`] per item. [`fault`] provides the deterministic fault
//! injection that makes every one of those paths testable. One
//! interaction rule to know: a fault-armed matrix run bypasses the
//! scenario cell store entirely — profiles built under injection are
//! never persisted, so drills can't poison incremental caches.

pub mod fault;
pub mod supervise;

pub use fault::{Fault, FaultInjector, FaultPlan};
pub use supervise::{
    parallel_try_map, parallel_try_map_observed, ExecError, RetryPolicy, SupervisePolicy,
    TaskError,
};

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// A fixed-size pool executing boxed jobs.
///
/// Jobs are `FnOnce() + Send`; results flow back through whatever channel
/// the caller closes over. Most users want [`parallel_map`] instead.
pub struct ThreadPool {
    queue: Arc<JobQueue>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

struct JobQueue {
    jobs: Mutex<(Vec<Job>, bool)>, // (pending, shutdown)
    cv: Condvar,
}

impl ThreadPool {
    /// Spawn a pool of `n` workers (`n >= 1`).
    pub fn new(n: usize) -> ThreadPool {
        assert!(n >= 1);
        let queue = Arc::new(JobQueue {
            jobs: Mutex::new((Vec::new(), false)),
            cv: Condvar::new(),
        });
        let workers = (0..n)
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("hroofline-worker-{i}"))
                    .spawn(move || worker_loop(&q))
                    .expect("spawn worker")
            })
            .collect();
        ThreadPool { queue, workers }
    }

    /// Pool sized to the machine (at least 1, at most `cap`).
    pub fn machine_sized(cap: usize) -> ThreadPool {
        ThreadPool::new(default_workers(cap))
    }

    /// Submit a job.
    pub fn submit(&self, job: impl FnOnce() + Send + 'static) {
        let mut guard = self.queue.jobs.lock().unwrap();
        assert!(!guard.1, "submit after shutdown");
        guard.0.push(Box::new(job));
        drop(guard);
        self.queue.cv.notify_one();
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }
}

fn worker_loop(q: &JobQueue) {
    loop {
        let job = {
            let mut guard = q.jobs.lock().unwrap();
            loop {
                if let Some(job) = guard.0.pop() {
                    break job;
                }
                if guard.1 {
                    return;
                }
                guard = q.cv.wait(guard).unwrap();
            }
        };
        job();
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut guard = self.queue.jobs.lock().unwrap();
            guard.1 = true;
        }
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Default worker count for CPU-bound sweeps: the machine's available
/// parallelism, at least 2 on any multi-core host (so coordinator
/// sweeps actually fan out), capped by `cap` and floored at 1.
pub fn default_workers(cap: usize) -> usize {
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .clamp(1, cap.max(1))
}

/// Apply `f` to every item, in parallel across up to `threads` workers,
/// preserving input order in the output. Panics in `f` propagate.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        return items.into_iter().map(f).collect();
    }

    let next = AtomicUsize::new(0);
    let inputs: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let outputs: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();

    std::thread::scope(|scope| {
        for _ in 0..threads {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = inputs[i].lock().unwrap().take().expect("item taken twice");
                let out = f(item);
                *outputs[i].lock().unwrap() = Some(out);
            });
        }
    });

    outputs
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("missing output"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let counter = Arc::new(AtomicU64::new(0));
        {
            let pool = ThreadPool::new(4);
            for _ in 0..100 {
                let c = Arc::clone(&counter);
                pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                });
            }
            // Drop waits for drain.
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..1000).collect::<Vec<i64>>(), 8, |x| x * x);
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, (i as i64) * (i as i64));
        }
    }

    #[test]
    fn parallel_map_empty_and_single() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 4, |x| x);
        assert!(out.is_empty());
        let out = parallel_map(vec![5], 4, |x| x + 1);
        assert_eq!(out, vec![6]);
    }

    #[test]
    fn machine_sized_at_least_one() {
        let pool = ThreadPool::machine_sized(64);
        assert!(pool.n_workers() >= 1);
    }

    #[test]
    fn default_workers_bounds() {
        assert!(default_workers(8) >= 1);
        assert!(default_workers(8) <= 8);
        assert_eq!(default_workers(1), 1);
        // On any multi-core machine the coordinator fans out.
        if std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1) >= 2 {
            assert!(default_workers(16) >= 2);
        }
    }
}
