//! Issue-pipeline description: which execution unit a SASS-level
//! instruction stream occupies. The cycle model in [`crate::sim`] charges
//! each kernel's instruction mix against these pipelines and takes the
//! max (pipelines execute concurrently on an SM, as INT/FP32 dual-issue
//! does on Volta).

/// Execution pipeline classes modelled per SM.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PipelineKind {
    Fp64,
    Fp32,
    /// FP16 on the general-purpose core (half2-packed rate).
    Fp16,
    /// INT32 / address arithmetic.
    Int,
    /// Tensor core (HMMA).
    Tensor,
}

impl PipelineKind {
    pub fn name(self) -> &'static str {
        match self {
            PipelineKind::Fp64 => "fp64",
            PipelineKind::Fp32 => "fp32",
            PipelineKind::Fp16 => "fp16",
            PipelineKind::Int => "int",
            PipelineKind::Tensor => "tensor",
        }
    }

    pub const ALL: [PipelineKind; 5] = [
        PipelineKind::Fp64,
        PipelineKind::Fp32,
        PipelineKind::Fp16,
        PipelineKind::Int,
        PipelineKind::Tensor,
    ];
}

/// A pipeline instance on a device: its kind and per-SM lane count
/// (thread-level operations retired per cycle per SM).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pipeline {
    pub kind: PipelineKind,
    pub lanes_per_sm: u32,
}

impl Pipeline {
    /// Thread-level operations retired per second device-wide.
    pub fn ops_per_second(&self, sms: u32, clock_hz: f64) -> f64 {
        self.lanes_per_sm as f64 * sms as f64 * clock_hz
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ops_rate() {
        let p = Pipeline {
            kind: PipelineKind::Fp32,
            lanes_per_sm: 64,
        };
        // 64 lanes * 80 SMs * 1 GHz = 5.12 Top/s
        assert!((p.ops_per_second(80, 1e9) - 5.12e12).abs() < 1.0);
    }

    #[test]
    fn names_unique() {
        let mut names: Vec<&str> = PipelineKind::ALL.iter().map(|k| k.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), PipelineKind::ALL.len());
    }
}
