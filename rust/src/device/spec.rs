//! GPU hardware specification and derived theoretical peaks.

use crate::device::pipeline::{Pipeline, PipelineKind};
use crate::util::digest::StableHasher;

/// Data precision of a floating-point operation stream. `Fp16` means
/// FP16 on the general-purpose (CUDA) core; Tensor Core traffic is
/// accounted separately via [`PipelineKind::Tensor`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Precision {
    Fp64,
    Fp32,
    Fp16,
}

impl Precision {
    pub fn bytes(self) -> u64 {
        match self {
            Precision::Fp64 => 8,
            Precision::Fp32 => 4,
            Precision::Fp16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp64 => "FP64",
            Precision::Fp32 => "FP32",
            Precision::Fp16 => "FP16",
        }
    }

    pub const ALL: [Precision; 3] = [Precision::Fp64, Precision::Fp32, Precision::Fp16];
}

/// A level of the memory hierarchy, ordered nearest-to-farthest from the
/// execution units. The hierarchical Roofline plots one point per level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum MemLevel {
    L1,
    L2,
    Hbm,
}

impl MemLevel {
    pub fn name(self) -> &'static str {
        match self {
            MemLevel::L1 => "L1",
            MemLevel::L2 => "L2",
            MemLevel::Hbm => "HBM",
        }
    }

    pub const ALL: [MemLevel; 3] = [MemLevel::L1, MemLevel::L2, MemLevel::Hbm];
}

/// Geometry of one cache level.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CacheLevel {
    /// Total capacity in bytes (per-SM for L1, device-wide for L2).
    pub capacity_bytes: u64,
    /// Cache line size in bytes.
    pub line_bytes: u64,
    /// Set associativity (modelled; V100 L1 is ~4-way sectored, L2 16-way).
    pub ways: u32,
    /// Peak bandwidth of this level, bytes/s, device-wide.
    pub peak_bytes_per_sec: f64,
}

impl CacheLevel {
    /// Feed every field, in declaration order, into a process-stable
    /// digest (cell-store keying; see [`crate::util::digest`]).
    pub fn digest_into(&self, h: &mut StableHasher) {
        h.write_u64(self.capacity_bytes);
        h.write_u64(self.line_bytes);
        h.write_u32(self.ways);
        h.write_f64(self.peak_bytes_per_sec);
    }
}

/// Full GPU specification. All modelled quantities derive from these
/// fields — there are no hidden constants in the simulator.
#[derive(Clone, Debug)]
pub struct GpuSpec {
    pub name: String,
    pub sms: u32,
    /// SM boost clock in Hz (drives CUDA-core peaks; V100: 1.53 GHz,
    /// giving the advertised 15.7 TFLOP/s FP32).
    pub clock_hz: f64,
    /// Clock used for the tensor-core peak. The paper's Eq. 3 evaluates
    /// the V100 TC peak at 1.312 GHz (107.479 TFLOP/s); we reproduce
    /// that convention.
    pub tc_clock_hz: f64,
    /// FP32 CUDA cores per SM (V100: 64).
    pub fp32_lanes_per_sm: u32,
    /// FP64 lanes per SM (V100: 32).
    pub fp64_lanes_per_sm: u32,
    /// Tensor cores per SM (V100: 8).
    pub tensor_cores_per_sm: u32,
    /// FLOPs per tensor-core instruction per warp. The paper (Eq. 6)
    /// counts 512 FLOPs per HMMA warp instruction.
    pub flops_per_tensor_inst: u64,
    /// 4x4x4 MACs per tensor core per cycle → 4^3 * 2 FLOPs (Eq. 3).
    pub flops_per_tc_per_cycle: u64,
    /// L1 (combined L1/shared) — per SM.
    pub l1: CacheLevel,
    /// L2 — device wide.
    pub l2: CacheLevel,
    /// HBM peak bandwidth, bytes/s.
    pub hbm_bytes_per_sec: f64,
    /// HBM capacity in bytes.
    pub hbm_capacity_bytes: u64,
    /// Kernel launch latency in seconds (microsecond-scale; drives the
    /// zero-AI overhead analysis of §IV-D).
    pub launch_latency_s: f64,
    /// ERT-empirical fraction of theoretical peak achievable by tuned
    /// code, per pipeline. These are the paper's own Fig. 1 / Fig. 2
    /// calibration points (e.g. FP64 7.7/7.83, TC 103.7/107.5 = 96.5%).
    pub achievable: AchievableFrac,
    /// Warp width (threads per warp).
    pub warp_size: u32,
}

/// Measured-over-theoretical efficiency per pipeline (ERT calibration).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AchievableFrac {
    pub fp64: f64,
    pub fp32: f64,
    pub fp16: f64,
    pub tensor: f64,
}

impl AchievableFrac {
    pub fn for_precision(&self, p: Precision) -> f64 {
        match p {
            Precision::Fp64 => self.fp64,
            Precision::Fp32 => self.fp32,
            Precision::Fp16 => self.fp16,
        }
    }

    /// Feed every field, bitwise, into a process-stable digest.
    pub fn digest_into(&self, h: &mut StableHasher) {
        h.write_f64(self.fp64);
        h.write_f64(self.fp32);
        h.write_f64(self.fp16);
        h.write_f64(self.tensor);
    }
}

impl GpuSpec {
    /// NVIDIA V100-SXM2-16GB, the paper's testbed GPU (§III-A).
    pub fn v100() -> GpuSpec {
        GpuSpec {
            name: "V100-SXM2-16GB".into(),
            sms: 80,
            clock_hz: 1.530e9,    // boost clock: 15.67 TFLOP/s FP32 theoretical
            tc_clock_hz: 1.312e9, // the clock the paper uses in Eq. 3
            fp32_lanes_per_sm: 64,
            fp64_lanes_per_sm: 32,
            tensor_cores_per_sm: 8,
            flops_per_tensor_inst: 512,
            flops_per_tc_per_cycle: 4 * 4 * 4 * 2,
            l1: CacheLevel {
                capacity_bytes: 128 * 1024,
                line_bytes: 128,
                ways: 4,
                // ~14 TB/s aggregate L1 bandwidth (ERT-measured band, Fig 1).
                peak_bytes_per_sec: 14.0e12,
            },
            l2: CacheLevel {
                capacity_bytes: 6 * 1024 * 1024,
                line_bytes: 128,
                ways: 16,
                // ~2.5 TB/s L2 bandwidth.
                peak_bytes_per_sec: 2.5e12,
            },
            hbm_bytes_per_sec: 900.0e9,
            hbm_capacity_bytes: 16 * 1024 * 1024 * 1024,
            launch_latency_s: 4.0e-6,
            achievable: AchievableFrac {
                fp64: 7.7 / 7.8336,     // Fig. 1: 7.7 TFLOP/s measured
                fp32: 15.2 / 15.6672,   // Fig. 1: 15.2
                fp16: 29.182 / 31.3344, // Tab. I v5: 29.182
                tensor: 0.965,          // Fig. 2: cuBLAS at 96.5% of Eq. 3 peak
            },
            warp_size: 32,
        }
    }

    /// NVIDIA A100-SXM4-40GB (GA100, Ampere) — the paper's §V "future
    /// work" target, registered as `a100-sxm4-40gb`.
    ///
    /// Datasheet cross-check (dense, no sparsity):
    /// * FP64: 108 × 32 × 1.410e9 × 2 = 9.75 TFLOP/s (datasheet 9.7)
    /// * FP32: 108 × 64 × 1.410e9 × 2 = 19.49 TFLOP/s (datasheet 19.5)
    /// * TC:   108 × 4 × 1.410e9 × 512 = 311.9 TFLOP/s (datasheet 312)
    /// * HBM2e: 1555 GB/s, 40 GB
    pub fn a100() -> GpuSpec {
        GpuSpec {
            name: "A100-SXM4-40GB".into(),
            sms: 108,
            clock_hz: 1.410e9,
            tc_clock_hz: 1.410e9,
            fp32_lanes_per_sm: 64,
            fp64_lanes_per_sm: 32,
            tensor_cores_per_sm: 4,
            flops_per_tensor_inst: 2048,
            // 3rd-gen TC: 256 dense FP16 MACs per cycle → 512 FLOPs
            // (Eq. 3 on Ampere: 108 x 4 x 1.41e9 x 512 = 311.9 TFLOP/s).
            flops_per_tc_per_cycle: 8 * 4 * 8 * 2,
            l1: CacheLevel {
                capacity_bytes: 192 * 1024,
                line_bytes: 128,
                ways: 4,
                peak_bytes_per_sec: 19.0e12,
            },
            l2: CacheLevel {
                capacity_bytes: 40 * 1024 * 1024,
                line_bytes: 128,
                ways: 16,
                peak_bytes_per_sec: 4.5e12,
            },
            hbm_bytes_per_sec: 1555.0e9,
            hbm_capacity_bytes: 40 * 1024 * 1024 * 1024,
            launch_latency_s: 3.5e-6,
            achievable: AchievableFrac {
                fp64: 0.97,
                fp32: 0.97,
                fp16: 0.93,
                tensor: 0.95,
            },
            warp_size: 32,
        }
    }

    /// NVIDIA T4 (TU104, Turing, 70 W PCIe) — the inference-class
    /// contrast device, registered as `t4-pcie-16gb`.
    ///
    /// Datasheet cross-check:
    /// * FP32: 40 × 64 × 1.590e9 × 2 = 8.14 TFLOP/s (datasheet 8.1)
    /// * FP16 (half2 on the CUDA core): 2 × FP32 = 16.28 (datasheet 16.2)
    /// * FP64: 40 × 2 × 1.590e9 × 2 = 254 GFLOP/s (1/32 rate, ~0.25 TFLOP/s)
    /// * TC:   40 × 8 × 1.590e9 × 128 = 65.1 TFLOP/s (datasheet 65)
    /// * GDDR6: 320 GB/s, 16 GB
    ///
    /// The achievable fractions are modelled (no published ERT run for
    /// the T4 in the paper's series): the 70 W power cap keeps sustained
    /// rates a notch below the Volta calibration points.
    pub fn t4() -> GpuSpec {
        GpuSpec {
            name: "T4-PCIE-16GB".into(),
            sms: 40,
            clock_hz: 1.590e9,
            tc_clock_hz: 1.590e9,
            fp32_lanes_per_sm: 64,
            fp64_lanes_per_sm: 2, // 1/32 FP32 rate on Turing
            tensor_cores_per_sm: 8,
            flops_per_tensor_inst: 512,
            flops_per_tc_per_cycle: 4 * 4 * 4 * 2, // 2nd-gen TC, Volta-width MMA
            l1: CacheLevel {
                // Unified L1/shared with the 64 KiB shared carve — half
                // the V100's staging capacity (drives smaller GEMM tiles
                // in `dl::lower`).
                capacity_bytes: 64 * 1024,
                line_bytes: 128,
                ways: 4,
                // ~114 B/cycle/SM as on Volta: 40 × 1.59e9 × 114 ≈ 7.3 TB/s.
                peak_bytes_per_sec: 7.3e12,
            },
            l2: CacheLevel {
                capacity_bytes: 4 * 1024 * 1024,
                line_bytes: 128,
                ways: 16,
                peak_bytes_per_sec: 1.3e12,
            },
            hbm_bytes_per_sec: 320.0e9, // GDDR6, not HBM — same model slot
            hbm_capacity_bytes: 16 * 1024 * 1024 * 1024,
            launch_latency_s: 4.5e-6, // PCIe submission path
            achievable: AchievableFrac {
                fp64: 0.90,
                fp32: 0.92,
                fp16: 0.90,
                tensor: 0.85,
            },
            warp_size: 32,
        }
    }

    /// Theoretical peak FLOP/s for a general-purpose-core precision.
    ///
    /// FP16 on the V100 CUDA core peaks at 2x FP32 *only* via `half2`
    /// packing; this returns the packed peak (the Fig. 1 ceiling).
    pub fn theoretical_flops(&self, p: Precision) -> f64 {
        let lanes = match p {
            Precision::Fp64 => self.fp64_lanes_per_sm,
            Precision::Fp32 => self.fp32_lanes_per_sm,
            Precision::Fp16 => self.fp32_lanes_per_sm * 2, // half2: 2 per FP32 lane
        };
        self.sms as f64 * lanes as f64 * self.clock_hz * 2.0 // FMA = 2 FLOPs
    }

    /// Theoretical tensor-core peak FLOP/s (paper Eq. 3:
    /// `80 x 8 x 1.312e9 x 4^3 x 2 = 107.479 TFLOP/s` for V100).
    pub fn theoretical_tensor_flops(&self) -> f64 {
        self.sms as f64
            * self.tensor_cores_per_sm as f64
            * self.tc_clock_hz
            * self.flops_per_tc_per_cycle as f64
    }

    /// Achievable (ERT-style empirical) compute ceiling.
    pub fn achievable_flops(&self, p: Precision) -> f64 {
        self.theoretical_flops(p) * self.achievable.for_precision(p)
    }

    /// Achievable tensor-core ceiling (cuBLAS reached 96.5% in Fig. 2).
    pub fn achievable_tensor_flops(&self) -> f64 {
        self.theoretical_tensor_flops() * self.achievable.tensor
    }

    /// Peak bandwidth of a memory level, bytes/s.
    pub fn bandwidth(&self, level: MemLevel) -> f64 {
        match level {
            MemLevel::L1 => self.l1.peak_bytes_per_sec,
            MemLevel::L2 => self.l2.peak_bytes_per_sec,
            MemLevel::Hbm => self.hbm_bytes_per_sec,
        }
    }

    /// The issue pipelines this device exposes (used by the cycle model).
    pub fn pipelines(&self) -> Vec<Pipeline> {
        vec![
            Pipeline {
                kind: PipelineKind::Fp64,
                lanes_per_sm: self.fp64_lanes_per_sm,
            },
            Pipeline {
                kind: PipelineKind::Fp32,
                lanes_per_sm: self.fp32_lanes_per_sm,
            },
            Pipeline {
                kind: PipelineKind::Fp16,
                // Issued through the FP32 pipeline; half2 doubles lane
                // throughput. The ladder model (ert::fp16_ladder) covers
                // the unpacked case.
                lanes_per_sm: self.fp32_lanes_per_sm * 2,
            },
            Pipeline {
                kind: PipelineKind::Int,
                lanes_per_sm: self.fp32_lanes_per_sm, // INT32 units mirror FP32 on Volta
            },
            Pipeline {
                kind: PipelineKind::Tensor,
                lanes_per_sm: self.tensor_cores_per_sm,
            },
        ]
    }

    /// Total cycles/s across all SMs (for `sm__cycles_elapsed.avg.per_second`).
    pub fn cycles_per_second(&self) -> f64 {
        self.clock_hz
    }

    /// Feed every field, in declaration order, into a process-stable
    /// digest. Any spec change — even a bandwidth recalibration — moves
    /// the cell key, which is what makes the incremental matrix store
    /// safe to trust across builds.
    pub fn digest_into(&self, h: &mut StableHasher) {
        h.write_str(&self.name);
        h.write_u32(self.sms);
        h.write_f64(self.clock_hz);
        h.write_f64(self.tc_clock_hz);
        h.write_u32(self.fp32_lanes_per_sm);
        h.write_u32(self.fp64_lanes_per_sm);
        h.write_u32(self.tensor_cores_per_sm);
        h.write_u64(self.flops_per_tensor_inst);
        h.write_u64(self.flops_per_tc_per_cycle);
        self.l1.digest_into(h);
        self.l2.digest_into(h);
        h.write_f64(self.hbm_bytes_per_sec);
        h.write_u64(self.hbm_capacity_bytes);
        h.write_f64(self.launch_latency_s);
        self.achievable.digest_into(h);
        h.write_u32(self.warp_size);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_matches_paper_eq3() {
        let v = GpuSpec::v100();
        let tc = v.theoretical_tensor_flops();
        // Paper: 107.479 TFLOP/s.
        assert!((tc / 1e12 - 107.479).abs() < 0.01, "{tc}");
    }

    #[test]
    fn v100_cuda_core_peaks() {
        let v = GpuSpec::v100();
        // 80 * 64 * 1.53e9 * 2 = 15.67 TFLOP/s theoretical (advertised 15.7).
        let fp32 = v.theoretical_flops(Precision::Fp32);
        assert!((fp32 / 1e12 - 15.67).abs() < 0.05, "{fp32}");
        let fp64 = v.theoretical_flops(Precision::Fp64);
        assert!((fp64 * 2.0 - fp32).abs() < 1.0);
        let fp16 = v.theoretical_flops(Precision::Fp16);
        assert!((fp16 - 2.0 * fp32).abs() < 1.0);
    }

    #[test]
    fn v100_fig1_achieved_ceilings() {
        let v = GpuSpec::v100();
        // Fig. 1: 7.7 / 15.2 / 29.2 / 103.7 TFLOP/s.
        assert!((v.achievable_flops(Precision::Fp64) / 1e12 - 7.7).abs() < 0.05);
        assert!((v.achievable_flops(Precision::Fp32) / 1e12 - 15.2).abs() < 0.05);
        assert!((v.achievable_flops(Precision::Fp16) / 1e12 - 29.182).abs() < 0.05);
        assert!((v.achievable_tensor_flops() / 1e12 - 103.7).abs() < 0.15);
    }

    #[test]
    fn precision_bytes() {
        assert_eq!(Precision::Fp64.bytes(), 8);
        assert_eq!(Precision::Fp32.bytes(), 4);
        assert_eq!(Precision::Fp16.bytes(), 2);
    }

    #[test]
    fn bandwidth_ordering() {
        let v = GpuSpec::v100();
        assert!(v.bandwidth(MemLevel::L1) > v.bandwidth(MemLevel::L2));
        assert!(v.bandwidth(MemLevel::L2) > v.bandwidth(MemLevel::Hbm));
    }

    #[test]
    fn a100_faster_than_v100() {
        let v = GpuSpec::v100();
        let a = GpuSpec::a100();
        assert!(a.theoretical_tensor_flops() > v.theoretical_tensor_flops());
        assert!(a.hbm_bytes_per_sec > v.hbm_bytes_per_sec);
    }

    #[test]
    fn a100_matches_datasheet_peaks() {
        // Dense (no-sparsity) datasheet numbers, cross-checked in the
        // constructor comment.
        let a = GpuSpec::a100();
        assert!((a.theoretical_tensor_flops() / 1e12 - 311.9).abs() < 0.5);
        assert!((a.theoretical_flops(Precision::Fp32) / 1e12 - 19.49).abs() < 0.1);
        assert!((a.theoretical_flops(Precision::Fp64) / 1e12 - 9.75).abs() < 0.1);
    }

    #[test]
    fn t4_matches_datasheet_peaks() {
        let t = GpuSpec::t4();
        assert!((t.theoretical_tensor_flops() / 1e12 - 65.1).abs() < 0.2);
        assert!((t.theoretical_flops(Precision::Fp32) / 1e12 - 8.14).abs() < 0.05);
        assert!((t.theoretical_flops(Precision::Fp16) / 1e12 - 16.28).abs() < 0.1);
        assert!((t.theoretical_flops(Precision::Fp64) / 1e9 - 254.4).abs() < 2.0);
    }

    #[test]
    fn spec_digest_tracks_every_field() {
        let digest = |s: &GpuSpec| {
            let mut h = StableHasher::new();
            s.digest_into(&mut h);
            h.finish_hex()
        };
        let base = GpuSpec::v100();
        assert_eq!(digest(&base), digest(&base.clone()), "digest is deterministic");
        assert_ne!(digest(&GpuSpec::v100()), digest(&GpuSpec::a100()));

        let mut bw = GpuSpec::v100();
        bw.hbm_bytes_per_sec *= 2.0;
        assert_ne!(digest(&base), digest(&bw), "bandwidth recalibration moves the digest");

        let mut frac = GpuSpec::v100();
        frac.achievable.tensor = 0.99;
        assert_ne!(digest(&base), digest(&frac), "achievable-frac change moves the digest");

        let mut l2 = GpuSpec::v100();
        l2.l2.ways = 8;
        assert_ne!(digest(&base), digest(&l2), "cache geometry change moves the digest");
    }

    #[test]
    fn every_builtin_orders_bandwidth_nearest_to_farthest() {
        for spec in [GpuSpec::v100(), GpuSpec::a100(), GpuSpec::t4()] {
            assert!(spec.bandwidth(MemLevel::L1) > spec.bandwidth(MemLevel::L2), "{}", spec.name);
            assert!(spec.bandwidth(MemLevel::L2) > spec.bandwidth(MemLevel::Hbm), "{}", spec.name);
        }
    }
}
