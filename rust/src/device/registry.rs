//! Named device registry — the *machines* the pipeline characterizes.
//!
//! The paper's methodology is machine-independent (ERT-style machine
//! characterization + Nsight-style application characterization), so the
//! device is a first-class axis of the whole pipeline rather than a
//! constant: every CLI surface (`repro ert|profile|matrix --device`),
//! the scenario matrix and the report generators resolve a [`GpuSpec`]
//! by name through this registry. Unknown names get a clean
//! [`CliError`] with the same did-you-mean hints as unknown workloads
//! and commands ([`crate::cli::suggest`]).
//!
//! Built-in devices (canonical name → alias):
//!
//! * `v100-sxm2-16gb` (`v100`) — the paper's testbed (§III-A); the
//!   registry default, so every legacy output stays bit-identical;
//! * `a100-sxm4-40gb` (`a100`) — the §V "future work" Ampere part;
//! * `t4-pcie-16gb` (`t4`) — the inference-class Turing contrast
//!   device (small L1 carve, GDDR6).
//!
//! Adding a device is three steps: a `GpuSpec` constructor in
//! [`crate::device::spec`] with datasheet-derived clocks/SM counts/cache
//! geometry (pin the Eq.-3-style peak math in a test), a
//! [`DeviceEntry`] row in [`REGISTRY`], and a README table row.

use crate::cli::{hint, CliError};
use crate::device::spec::GpuSpec;

/// One registry entry: a named device-spec builder.
pub struct DeviceEntry {
    /// Canonical CLI name, e.g. `v100-sxm2-16gb`.
    pub name: &'static str,
    /// Short alias, also the scenario-id tag, e.g. `v100`.
    pub short: &'static str,
    /// The spec's display name, e.g. `V100-SXM2-16GB` — duplicated here
    /// so captions/titles don't have to build a whole [`GpuSpec`] to
    /// read one string (pinned equal to `spec().name` by a test).
    pub display: &'static str,
    pub description: &'static str,
    builder: fn() -> GpuSpec,
}

impl DeviceEntry {
    /// Build the full specification for this device.
    pub fn spec(&self) -> GpuSpec {
        (self.builder)()
    }
}

impl std::fmt::Debug for DeviceEntry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("DeviceEntry").field("name", &self.name).finish()
    }
}

static REGISTRY: [DeviceEntry; 3] = [
    DeviceEntry {
        name: "v100-sxm2-16gb",
        short: "v100",
        display: "V100-SXM2-16GB",
        description: "NVIDIA V100-SXM2-16GB — the paper's testbed (80 SMs, 900 GB/s HBM2)",
        builder: GpuSpec::v100,
    },
    DeviceEntry {
        name: "a100-sxm4-40gb",
        short: "a100",
        display: "A100-SXM4-40GB",
        description: "NVIDIA A100-SXM4-40GB — Ampere (108 SMs, 1555 GB/s HBM2e)",
        builder: GpuSpec::a100,
    },
    DeviceEntry {
        name: "t4-pcie-16gb",
        short: "t4",
        display: "T4-PCIE-16GB",
        description: "NVIDIA T4 — Turing inference part (40 SMs, 320 GB/s GDDR6, 70 W)",
        builder: GpuSpec::t4,
    },
];

/// All registered devices, in registry (and matrix-enumeration) order.
pub fn entries() -> &'static [DeviceEntry] {
    &REGISTRY
}

/// Registered canonical device names, in registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|e| e.name).collect()
}

/// The default device — the paper's V100 testbed. Every surface that
/// does not take an explicit `--device` resolves to this entry, which
/// keeps the single-testbed outputs bit-identical to the pre-registry
/// pipeline.
pub fn default_entry() -> &'static DeviceEntry {
    &REGISTRY[0]
}

/// Convenience: the default entry's spec.
pub fn default_spec() -> GpuSpec {
    default_entry().spec()
}

/// Resolve a device by canonical name or short alias; unknown names get
/// a clean [`CliError`] with a did-you-mean hint and the available set.
pub fn lookup(name: &str) -> Result<&'static DeviceEntry, CliError> {
    if let Some(e) = REGISTRY.iter().find(|e| e.name == name || e.short == name) {
        return Ok(e);
    }
    let hint = hint(name, "", REGISTRY.iter().flat_map(|e| [e.name, e.short]));
    Err(CliError(format!(
        "unknown device '{name}'{hint}; available: {}",
        names().join(", ")
    )))
}

/// Facade over the registry for spec-by-name resolution:
/// `DeviceRegistry::get("a100-sxm4-40gb")`.
pub struct DeviceRegistry;

impl DeviceRegistry {
    /// Resolve a name (or alias) straight to a built [`GpuSpec`].
    pub fn get(name: &str) -> Result<GpuSpec, CliError> {
        lookup(name).map(DeviceEntry::spec)
    }

    /// All registered devices, in registry order.
    pub fn entries() -> &'static [DeviceEntry] {
        entries()
    }

    /// Registered canonical names, in registry order.
    pub fn names() -> Vec<&'static str> {
        names()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::MemLevel;
    use crate::roofline::model::Ceilings;

    #[test]
    fn enumeration_is_deterministic_and_duplicate_free() {
        let a = names();
        let b = names();
        assert_eq!(a, b);
        let mut dedup: Vec<&str> =
            REGISTRY.iter().flat_map(|e| [e.name, e.short]).collect();
        dedup.sort_unstable();
        let before = dedup.len();
        dedup.dedup();
        assert_eq!(dedup.len(), before, "names and aliases collide");
        assert_eq!(a[0], "v100-sxm2-16gb", "default device leads the registry");
    }

    #[test]
    fn lookup_resolves_canonical_names_and_aliases() {
        for e in entries() {
            assert_eq!(lookup(e.name).unwrap().name, e.name);
            assert_eq!(lookup(e.short).unwrap().name, e.name);
            assert_eq!(DeviceRegistry::get(e.name).unwrap().name, e.spec().name);
            // The static display name is a cache of the spec's name —
            // the two must never diverge.
            assert_eq!(e.display, e.spec().name, "{}", e.name);
        }
        assert_eq!(default_entry().spec().name, "V100-SXM2-16GB");
    }

    #[test]
    fn unknown_device_gets_did_you_mean() {
        let err = DeviceRegistry::get("a100-sxm4-40g").unwrap_err();
        assert!(err.0.contains("unknown device 'a100-sxm4-40g'"), "{}", err.0);
        assert!(err.0.contains("did you mean 'a100-sxm4-40gb'?"), "{}", err.0);
        assert!(err.0.contains("available:"), "{}", err.0);
        // A close alias typo also resolves to a suggestion.
        let err = DeviceRegistry::get("t44").unwrap_err();
        assert!(err.0.contains("did you mean 't4'?"), "{}", err.0);
        // Nothing-alike input gets the available list but no suggestion.
        let err = DeviceRegistry::get("strawberry").unwrap_err();
        assert!(!err.0.contains("did you mean"), "{}", err.0);
    }

    #[test]
    fn v100_entry_preserves_eq3_bit_identically() {
        // The registry must hand out exactly the paper's V100 — same
        // Eq. 3 peak to the last bit.
        let from_registry = DeviceRegistry::get("v100-sxm2-16gb").unwrap();
        let direct = GpuSpec::v100();
        assert_eq!(
            from_registry.theoretical_tensor_flops().to_bits(),
            direct.theoretical_tensor_flops().to_bits()
        );
        assert_eq!(from_registry.sms, direct.sms);
        assert_eq!(from_registry.l1.capacity_bytes, direct.l1.capacity_bytes);
    }

    #[test]
    fn ceilings_monotone_with_bandwidth_for_every_device() {
        // At any fixed AI the Roofline bound must decrease from L1 to
        // L2 to HBM, for every registered device — the hierarchical
        // chart's reading depends on it.
        for e in entries() {
            let spec = e.spec();
            let c = Ceilings::from_spec(&spec);
            for ai in [0.01, 1.0, 100.0] {
                let b1 = c.bound(MemLevel::L1, ai);
                let b2 = c.bound(MemLevel::L2, ai);
                let bh = c.bound(MemLevel::Hbm, ai);
                assert!(b1 >= b2 && b2 >= bh, "{} at AI {ai}: {b1} {b2} {bh}", e.name);
            }
            // And the compute ceilings order FP64 < FP32 < tensor.
            let max = c.max_flops();
            assert!(max >= spec.achievable_tensor_flops());
        }
    }
}
