//! Device models: the parameterized GPU specification used by the ERT
//! modeled mode and the counter simulator, plus the named registry
//! ([`registry`]) that every pipeline surface resolves devices through.
//!
//! The V100 constants are the ones the paper itself quotes (§II-A, Eq. 3,
//! Fig. 1): 80 SMs at 1.312 GHz boost, 8 tensor cores/SM, 128 KiB
//! combined L1/shared per SM, 6 MiB L2, 900 GB/s HBM2. The A100 and T4
//! entries carry datasheet-derived geometry pinned by unit tests.

pub mod pipeline;
pub mod registry;
pub mod spec;

pub use pipeline::{Pipeline, PipelineKind};
pub use registry::{DeviceEntry, DeviceRegistry};
pub use spec::{CacheLevel, GpuSpec, MemLevel, Precision};
