//! Device models: the parameterized GPU specification used by the ERT
//! modeled mode and the counter simulator, plus an "empirical" device
//! built from measured ERT results (the host CPU path).
//!
//! The V100 constants are the ones the paper itself quotes (§II-A, Eq. 3,
//! Fig. 1): 80 SMs at 1.312 GHz boost, 8 tensor cores/SM, 128 KiB
//! combined L1/shared per SM, 6 MiB L2, 900 GB/s HBM2.

pub mod pipeline;
pub mod spec;

pub use pipeline::{Pipeline, PipelineKind};
pub use spec::{CacheLevel, GpuSpec, MemLevel, Precision};
