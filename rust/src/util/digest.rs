//! Process-stable content hashing for the cell store.
//!
//! `std::hash` is explicitly *not* stable across processes (SipHash
//! with a random per-process key), so content-addressed storage — the
//! scenario matrix's [`crate::scenario::store::CellStore`], where a key
//! computed today must match the key computed on another machine
//! tomorrow — needs its own hasher. [`StableHasher`] runs two parallel
//! FNV-1a-64 streams (distinct offset bases, the second stream
//! decorrelated by a byte mask) for a 128-bit hex digest.
//!
//! Why FNV-1a: the offline vendor set has no hashing crate, the
//! algorithm is a dozen lines with published known-answer vectors
//! (tested below), and the keys are not adversarial — they address a
//! build's own simulation outputs, so collision resistance only has to
//! beat "different scenario specs hashing together by accident".
//!
//! Framing rules callers must keep to (and the digest methods on
//! [`crate::sim::kernel::KernelDesc`] / [`crate::device::GpuSpec`] do):
//!
//! * strings and byte slices are **length-prefixed** via [`StableHasher::write_str`]
//!   / the explicit `write_u64(len)` idiom, so `("ab","c")` never
//!   collides with `("a","bc")`;
//! * floats are hashed **bitwise** ([`f64::to_bits`]), matching the
//!   bitwise `Eq`/`Hash` the simulator's descriptors already use —
//!   equal keys mean bit-identical inputs, which is exactly the
//!   contract the byte-identical-artifact guarantee needs;
//! * `Option`s are tag-prefixed ([`StableHasher::write_opt_u64`]).

/// The FNV-1a-64 offset basis.
pub const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
/// The FNV-1a-64 prime.
pub const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// One-shot FNV-1a-64 over a byte slice (the reference stream of
/// [`StableHasher`], exposed for the known-answer tests and for small
/// standalone keys).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = FNV_OFFSET;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Decorrelation constant for the second stream: the 64-bit golden
/// ratio, the usual choice for splitting one seed into two.
const HI_OFFSET: u64 = FNV_OFFSET ^ 0x9e37_79b9_7f4a_7c15;
/// Byte mask applied to the second stream so the two streams never see
/// the same input sequence.
const HI_MASK: u8 = 0xa5;

/// A process-stable 128-bit content hasher (two FNV-1a-64 streams).
///
/// Unlike `std::hash::Hasher` this has no random state: the same write
/// sequence yields the same [`StableHasher::finish_hex`] digest in
/// every process, on every platform, in every build of the same store
/// format version.
#[derive(Clone, Debug)]
pub struct StableHasher {
    lo: u64,
    hi: u64,
}

impl Default for StableHasher {
    fn default() -> StableHasher {
        StableHasher::new()
    }
}

impl StableHasher {
    pub fn new() -> StableHasher {
        StableHasher { lo: FNV_OFFSET, hi: HI_OFFSET }
    }

    /// Feed raw bytes (unframed — prefer the typed writers, which
    /// frame their input).
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.lo = (self.lo ^ b as u64).wrapping_mul(FNV_PRIME);
            self.hi = (self.hi ^ (b ^ HI_MASK) as u64).wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write_bytes(&v.to_le_bytes());
    }

    pub fn write_u32(&mut self, v: u32) {
        self.write_bytes(&v.to_le_bytes());
    }

    /// Bitwise float hashing (`to_bits`), consistent with the bitwise
    /// `Eq` on the simulator's descriptors: `0.0` and `-0.0` hash
    /// differently, NaN payloads are distinguished.
    pub fn write_f64(&mut self, v: f64) {
        self.write_u64(v.to_bits());
    }

    pub fn write_bool(&mut self, v: bool) {
        self.write_bytes(&[v as u8]);
    }

    /// Length-prefixed string framing (see module docs).
    pub fn write_str(&mut self, s: &str) {
        self.write_u64(s.len() as u64);
        self.write_bytes(s.as_bytes());
    }

    /// Tag-prefixed `Option<u64>` framing: `None` and `Some(0)` differ.
    pub fn write_opt_u64(&mut self, v: Option<u64>) {
        match v {
            None => self.write_bytes(&[0]),
            Some(x) => {
                self.write_bytes(&[1]);
                self.write_u64(x);
            }
        }
    }

    /// The 128-bit digest as 32 lowercase hex characters — filesystem-
    /// and JSON-safe, the [`crate::scenario::store::CellKey`] wire form.
    pub fn finish_hex(&self) -> String {
        format!("{:016x}{:016x}", self.lo, self.hi)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fnv1a64_known_answer_vectors() {
        // Published FNV-1a 64-bit test vectors (Fowler/Noll/Vo).
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn hasher_lo_stream_is_reference_fnv1a() {
        let mut h = StableHasher::new();
        h.write_bytes(b"foobar");
        assert!(h.finish_hex().starts_with(&format!("{:016x}", fnv1a64(b"foobar"))));
    }

    #[test]
    fn digest_is_deterministic_and_well_formed() {
        let digest = |f: &dyn Fn(&mut StableHasher)| {
            let mut h = StableHasher::new();
            f(&mut h);
            h.finish_hex()
        };
        let a = digest(&|h| {
            h.write_str("scenario");
            h.write_u64(42);
            h.write_f64(1.5);
        });
        let b = digest(&|h| {
            h.write_str("scenario");
            h.write_u64(42);
            h.write_f64(1.5);
        });
        assert_eq!(a, b);
        assert_eq!(a.len(), 32);
        assert!(a.chars().all(|c| c.is_ascii_hexdigit() && !c.is_ascii_uppercase()));
    }

    #[test]
    fn string_framing_prevents_concatenation_collisions() {
        let mut a = StableHasher::new();
        a.write_str("ab");
        a.write_str("c");
        let mut b = StableHasher::new();
        b.write_str("a");
        b.write_str("bc");
        assert_ne!(a.finish_hex(), b.finish_hex());
    }

    #[test]
    fn floats_hash_bitwise() {
        let mut pos = StableHasher::new();
        pos.write_f64(0.0);
        let mut neg = StableHasher::new();
        neg.write_f64(-0.0);
        assert_ne!(pos.finish_hex(), neg.finish_hex(), "0.0 vs -0.0 are distinct bit patterns");
    }

    #[test]
    fn option_framing_distinguishes_none_from_zero() {
        let mut none = StableHasher::new();
        none.write_opt_u64(None);
        let mut zero = StableHasher::new();
        zero.write_opt_u64(Some(0));
        assert_ne!(none.finish_hex(), zero.finish_hex());
    }

    #[test]
    fn single_bit_input_changes_flip_the_digest() {
        let mut a = StableHasher::new();
        a.write_u64(1 << 17);
        let mut b = StableHasher::new();
        b.write_u64(1 << 18);
        assert_ne!(a.finish_hex(), b.finish_hex());
    }
}
