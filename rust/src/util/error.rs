//! Error-handling substrate: an `anyhow`-compatible dynamic error type
//! plus the `anyhow!` / `bail!` / `ensure!` macros and the `Context`
//! extension trait.
//!
//! The offline vendor set has neither `anyhow` nor `thiserror` (see
//! DESIGN.md §1), so this module provides the same call-site surface:
//! import it under the familiar name and existing code compiles
//! unchanged:
//!
//! ```
//! use hroofline::util::error as anyhow;
//! use hroofline::util::error::{Context, Result};
//!
//! fn parse(s: &str) -> Result<u32> {
//!     let n: u32 = s.parse().context("not a number")?;
//!     anyhow::ensure!(n > 0, "need a positive count, got {n}");
//!     Ok(n)
//! }
//!
//! let err = parse("zzz").unwrap_err();
//! assert!(format!("{err:#}").contains("not a number"));
//! ```
//!
//! Design notes, mirroring `anyhow`:
//!
//! * [`Error`] deliberately does **not** implement `std::error::Error`;
//!   that is what makes the blanket `impl<E: std::error::Error> From<E>`
//!   coherent alongside the reflexive `From<Error> for Error`.
//! * `{err}` displays the outermost message; `{err:#}` displays the full
//!   `context: cause: root-cause` chain, like `anyhow`'s alternate mode.

use std::fmt;

/// A dynamic error: an ordered chain of messages, outermost context
/// first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

/// `Result` specialized to [`Error`], with the same escape hatch
/// (`Result<T, E>`) as `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message.
    pub fn msg(message: impl fmt::Display) -> Error {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Wrap with an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Debug renders the full chain: that is what `.unwrap()` panics
        // print, where the whole story matters.
        f.write_str(&self.chain.join(": "))
    }
}

// The `anyhow` trick: `Error` is not `std::error::Error`, so this
// blanket conversion cannot overlap the reflexive `From<Error>`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Context-attaching extension for `Result` and `Option`, mirroring
/// `anyhow::Context`.
pub trait Context<T> {
    /// Attach a context message, converting the error into [`Error`].
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;

    /// Attach a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
///
/// Divergence from real `anyhow`: the expression arm flattens its
/// argument to one Display message — `anyhow!(err)` on an error with a
/// source chain keeps only the outermost message (real `anyhow` keeps
/// the chain via autoref specialization, which is not worth vendoring
/// here). To preserve a chain, convert with `?`/`.into()` instead,
/// which routes through `From<E: std::error::Error>`.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!("condition failed: ", stringify!($cond))));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

// Make the macros addressable through this module (and through aliases
// of it, e.g. `use crate::util::error as anyhow; anyhow::bail!(...)`).
pub use crate::{anyhow, bail, ensure};

#[cfg(test)]
mod tests {
    use super::*;

    #[derive(Debug, PartialEq)]
    struct Leaf;
    impl fmt::Display for Leaf {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            f.write_str("leaf failure")
        }
    }
    impl std::error::Error for Leaf {}

    #[test]
    fn display_outermost_alternate_full_chain() {
        let e: Error = Leaf.into();
        let e = e.context("middle").context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: middle: leaf failure");
        assert_eq!(e.root_cause(), "leaf failure");
        assert_eq!(e.chain().count(), 3);
    }

    #[test]
    fn from_preserves_source_chain() {
        let io = std::io::Error::new(std::io::ErrorKind::Other, Leaf);
        let e: Error = io.into();
        assert!(format!("{e:#}").contains("leaf failure"), "{e:#}");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), Leaf> = Err(Leaf);
        let e = r.context("while doing x").unwrap_err();
        assert_eq!(format!("{e:#}"), "while doing x: leaf failure");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(format!("{e}"), "missing thing");
        assert_eq!(Some(5).context("never used").unwrap(), 5);
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            let _ = "zz".parse::<u32>()?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_and_bail() {
        fn positive(n: i64) -> Result<i64> {
            ensure!(n != 0);
            ensure!(n > 0, "need positive, got {n}");
            if n == 13 {
                bail!("unlucky {}", n);
            }
            Ok(n)
        }
        assert_eq!(positive(4).unwrap(), 4);
        assert!(format!("{}", positive(0).unwrap_err()).contains("condition failed"));
        assert_eq!(format!("{}", positive(-2).unwrap_err()), "need positive, got -2");
        assert_eq!(format!("{}", positive(13).unwrap_err()), "unlucky 13");
        let from_string = anyhow!(String::from("prebuilt"));
        assert_eq!(format!("{from_string}"), "prebuilt");
    }
}
