//! Human-unit formatting for throughput, bytes and durations — the
//! report modules print paper-style numbers ("103.7 TFLOP/s", "900 GB/s").

/// Format a value with SI decade prefixes (k/M/G/T/P) and a unit suffix.
pub fn si(value: f64, unit: &str) -> String {
    let (scaled, prefix) = si_scale(value);
    format!("{} {}{}", trim3(scaled), prefix, unit)
}

/// Format a FLOP/s rate, e.g. `si_flops(1.037e14)` → "103.7 TFLOP/s".
pub fn si_flops(value: f64) -> String {
    si(value, "FLOP/s")
}

/// Format a byte count with binary-friendly decimal prefixes (the paper
/// reports GB/s decimal), e.g. "16.0 GB".
pub fn si_bytes(value: f64) -> String {
    si(value, "B")
}

/// Format seconds adaptively: ns/µs/ms/s.
pub fn duration(secs: f64) -> String {
    let a = secs.abs();
    if a == 0.0 {
        "0 s".into()
    } else if a < 1e-6 {
        format!("{} ns", trim3(secs * 1e9))
    } else if a < 1e-3 {
        format!("{} µs", trim3(secs * 1e6))
    } else if a < 1.0 {
        format!("{} ms", trim3(secs * 1e3))
    } else {
        format!("{} s", trim3(secs))
    }
}

fn si_scale(value: f64) -> (f64, &'static str) {
    let a = value.abs();
    if a >= 1e15 {
        (value / 1e15, "P")
    } else if a >= 1e12 {
        (value / 1e12, "T")
    } else if a >= 1e9 {
        (value / 1e9, "G")
    } else if a >= 1e6 {
        (value / 1e6, "M")
    } else if a >= 1e3 {
        (value / 1e3, "k")
    } else {
        (value, "")
    }
}

/// Render with up to 3 significant-ish decimals, trimming trailing zeros.
fn trim3(v: f64) -> String {
    let s = format!("{v:.3}");
    let s = s.trim_end_matches('0').trim_end_matches('.');
    s.to_string()
}

/// Percentage with one decimal, e.g. "96.5%".
pub fn pct(fraction: f64) -> String {
    format!("{:.1}%", fraction * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_prefixes() {
        assert_eq!(si_flops(103.7e12), "103.7 TFLOP/s");
        assert_eq!(si_flops(7.7e12), "7.7 TFLOP/s");
        assert_eq!(si_flops(900.0e9), "900 GFLOP/s");
        assert_eq!(si_flops(12.0), "12 FLOP/s");
    }

    #[test]
    fn bytes_prefixes() {
        assert_eq!(si_bytes(16e9), "16 GB");
        assert_eq!(si_bytes(1.5e3), "1.5 kB");
    }

    #[test]
    fn durations() {
        assert_eq!(duration(1.25), "1.25 s");
        assert_eq!(duration(0.00125), "1.25 ms");
        assert_eq!(duration(2.5e-7), "250 ns");
        assert_eq!(duration(0.0), "0 s");
    }

    #[test]
    fn pct_format() {
        assert_eq!(pct(0.965), "96.5%");
        assert_eq!(pct(0.419), "41.9%");
    }
}
