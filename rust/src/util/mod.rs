//! Shared substrate utilities: deterministic PRNG, statistics, JSON,
//! human-unit formatting, fixed-width text tables, process-stable
//! content hashing, and the `anyhow`-compatible error type.
//!
//! These exist in-repo because the offline vendor set has no `rand`,
//! `serde`, `prettytable`, `anyhow` or `thiserror` — see DESIGN.md §1.

pub mod digest;
pub mod error;
pub mod fmt;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;

pub use digest::StableHasher;
pub use error::{Context, Error};
pub use fmt::{si, si_bytes, si_flops};
pub use json::Json;
pub use rng::Rng;
pub use stats::Summary;
pub use table::Table;
