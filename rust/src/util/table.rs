//! Fixed-width text-table rendering for paper-style tables (Tables I–III)
//! in terminal reports and EXPERIMENTS.md snippets.

/// Column alignment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Align {
    Left,
    Right,
}

/// A simple text table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Create a table with the given column headers; all columns default
    /// to left alignment.
    pub fn new(header: &[&str]) -> Table {
        Table {
            header: header.iter().map(|s| s.to_string()).collect(),
            aligns: vec![Align::Left; header.len()],
            rows: Vec::new(),
        }
    }

    /// Set per-column alignment (length must match the header).
    pub fn aligns(mut self, aligns: &[Align]) -> Table {
        assert_eq!(aligns.len(), self.header.len());
        self.aligns = aligns.to_vec();
        self
    }

    /// Append a row; must match the header arity.
    pub fn row(&mut self, cells: &[String]) -> &mut Table {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity {} != header arity {}",
            cells.len(),
            self.header.len()
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for &str rows.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Table {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render as an ASCII box table.
    pub fn render(&self) -> String {
        let widths = self.widths();
        let mut out = String::new();
        self.rule(&mut out, &widths);
        self.line(&mut out, &widths, &self.header);
        self.rule(&mut out, &widths);
        for row in &self.rows {
            self.line(&mut out, &widths, row);
        }
        self.rule(&mut out, &widths);
        out
    }

    /// Render as GitHub-flavoured markdown.
    pub fn render_markdown(&self) -> String {
        let mut out = String::new();
        out.push('|');
        for h in &self.header {
            out.push_str(&format!(" {h} |"));
        }
        out.push_str("\n|");
        for a in &self.aligns {
            out.push_str(match a {
                Align::Left => "---|",
                Align::Right => "--:|",
            });
        }
        out.push('\n');
        for row in &self.rows {
            out.push('|');
            for cell in row {
                out.push_str(&format!(" {cell} |"));
            }
            out.push('\n');
        }
        out
    }

    fn widths(&self) -> Vec<usize> {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (w, cell) in widths.iter_mut().zip(row) {
                *w = (*w).max(cell.chars().count());
            }
        }
        widths
    }

    fn rule(&self, out: &mut String, widths: &[usize]) {
        out.push('+');
        for w in widths {
            out.push_str(&"-".repeat(w + 2));
            out.push('+');
        }
        out.push('\n');
    }

    fn line(&self, out: &mut String, widths: &[usize], cells: &[String]) {
        out.push('|');
        for ((cell, w), align) in cells.iter().zip(widths).zip(&self.aligns) {
            let pad = w - cell.chars().count();
            match align {
                Align::Left => out.push_str(&format!(" {cell}{} |", " ".repeat(pad))),
                Align::Right => out.push_str(&format!(" {}{cell} |", " ".repeat(pad))),
            }
        }
        out.push('\n');
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new(&["Version", "TFLOP/s"]).aligns(&[Align::Left, Align::Right]);
        t.row_str(&["v1", "15.421"]);
        t.row_str(&["v5", "29.182"]);
        let s = t.render();
        assert!(s.contains("| Version | TFLOP/s |"), "{s}");
        assert!(s.contains("| v1      |  15.421 |"), "{s}");
        // box rule width is consistent
        let lines: Vec<&str> = s.lines().collect();
        assert!(lines.iter().all(|l| l.chars().count() == lines[0].chars().count()));
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new(&["a", "b"]).aligns(&[Align::Left, Align::Right]);
        t.row_str(&["x", "1"]);
        let md = t.render_markdown();
        assert_eq!(md.lines().count(), 3);
        assert!(md.lines().nth(1).unwrap().contains("--:"));
    }

    #[test]
    #[should_panic]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_str(&["only-one"]);
    }
}
