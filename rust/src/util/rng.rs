//! Deterministic pseudo-random number generation (splitmix64 +
//! xoshiro256**). Used by the property-test driver, synthetic workload
//! generation and the cache simulator's address sampling. Fully
//! reproducible from a seed — profiling runs must be deterministic for
//! multi-pass metric collection to be sound (paper §II-B).

/// xoshiro256** seeded via splitmix64. Not cryptographic; fast and
/// statistically adequate for workload synthesis and property testing.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a seed. Equal seeds yield equal streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Uses Lemire's multiply-shift with rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "Rng::below(0)");
        // Rejection sampling to remove modulo bias.
        let threshold = n.wrapping_neg() % n;
        loop {
            let r = self.next_u64();
            let (hi, lo) = {
                let wide = (r as u128) * (n as u128);
                ((wide >> 64) as u64, wide as u64)
            };
            if lo >= threshold {
                return hi;
            }
        }
    }

    /// Uniform integer in the inclusive range `[lo, hi]`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "Rng::range: lo > hi");
        lo + self.below(hi - lo + 1)
    }

    /// Uniform float in `[0, 1)` with 53 bits of precision.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Log-uniform float in `[lo, hi)`; both bounds must be positive.
    /// Used for sampling arithmetic intensities across decades.
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        assert!(lo > 0.0 && hi > lo);
        (self.f64_range(lo.ln(), hi.ln())).exp()
    }

    /// Bernoulli draw with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick a uniformly random element reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "Rng::choose on empty slice");
        &xs[self.below(xs.len() as u64) as usize]
    }

    /// Standard normal via Box–Muller (used for synthetic training data).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fork an independent child stream (stable across platforms).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit in 1000 draws");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn log_uniform_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.log_uniform(0.01, 100.0);
            assert!(v >= 0.0099 && v < 100.1);
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(13);
        let mut hit_lo = false;
        let mut hit_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 6);
            assert!((3..=6).contains(&v));
            hit_lo |= v == 3;
            hit_hi |= v == 6;
        }
        assert!(hit_lo && hit_hi);
    }
}
