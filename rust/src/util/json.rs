//! Minimal JSON value type with an emitter and a strict recursive-descent
//! parser. Replaces `serde_json` (absent from the offline vendor set).
//!
//! Used for `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and for the machine-readable reports under `out/`.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON document. Objects use a BTreeMap so emission is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse or access error.
#[derive(Debug, PartialEq)]
pub enum JsonError {
    Parse(usize, String),
    MissingKey(String),
    WrongType(&'static str),
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            JsonError::Parse(pos, what) => write!(f, "json parse error at byte {pos}: {what}"),
            JsonError::MissingKey(key) => write!(f, "json: missing key '{key}'"),
            JsonError::WrongType(wanted) => write!(f, "json: wrong type, wanted {wanted}"),
        }
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ---------- constructors ----------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Result<&Json, JsonError> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| JsonError::MissingKey(key.into())),
            _ => Err(JsonError::WrongType("object")),
        }
    }

    /// `get` that tolerates absence.
    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(v) => Ok(*v),
            _ => Err(JsonError::WrongType("number")),
        }
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        let v = self.as_f64()?;
        if v < 0.0 || v.fract() != 0.0 {
            return Err(JsonError::WrongType("non-negative integer"));
        }
        Ok(v as usize)
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(JsonError::WrongType("string")),
        }
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(JsonError::WrongType("bool")),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => Err(JsonError::WrongType("array")),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>, JsonError> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(JsonError::WrongType("object")),
        }
    }

    // ---------- emit ----------

    /// Compact single-line emission.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, None, 0);
        out
    }

    /// Pretty 2-space-indented emission with trailing newline.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.emit(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn emit(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(v) => emit_num(out, *v),
            Json::Str(s) => emit_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.emit(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    emit_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.emit(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }

    // ---------- parse ----------

    /// Strict parse of a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        skip_ws(bytes, &mut pos);
        let value = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(JsonError::Parse(pos, "trailing data".into()));
        }
        Ok(value)
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn emit_num(out: &mut String, v: f64) {
    if v.is_finite() {
        if v.fract() == 0.0 && v.abs() < 9e15 {
            out.push_str(&format!("{}", v as i64));
        } else {
            out.push_str(&format!("{v}"));
        }
    } else {
        // JSON has no inf/nan; emit null like serde_json does.
        out.push_str("null");
    }
}

fn emit_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    if *pos >= b.len() {
        return Err(JsonError::Parse(*pos, "unexpected end of input".into()));
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        b'-' | b'0'..=b'9' => parse_num(b, pos),
        c => Err(JsonError::Parse(*pos, format!("unexpected byte '{}'", c as char))),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, value: Json) -> Result<Json, JsonError> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(value)
    } else {
        Err(JsonError::Parse(*pos, format!("expected '{lit}'")))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    let start = *pos;
    if b[*pos] == b'-' {
        *pos += 1;
    }
    while *pos < b.len() && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-') {
        *pos += 1;
    }
    let text = std::str::from_utf8(&b[start..*pos])
        .map_err(|_| JsonError::Parse(start, "bad utf8 in number".into()))?;
    text.parse::<f64>()
        .map(Json::Num)
        .map_err(|e| JsonError::Parse(start, format!("bad number '{text}': {e}")))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, JsonError> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            return Err(JsonError::Parse(*pos, "unterminated string".into()));
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err(JsonError::Parse(*pos, "bad escape".into()));
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err(JsonError::Parse(*pos, "short \\u escape".into()));
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| JsonError::Parse(*pos, "bad \\u escape".into()))?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| JsonError::Parse(*pos, "bad \\u escape".into()))?;
                        // BMP only; surrogate pairs unsupported (not needed
                        // for manifests we produce ourselves).
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => {
                        return Err(JsonError::Parse(
                            *pos,
                            format!("unknown escape '\\{}'", c as char),
                        ))
                    }
                }
                *pos += 1;
            }
            _ => {
                // Copy a full UTF-8 scalar.
                let s = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| JsonError::Parse(*pos, "bad utf8".into()))?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        skip_ws(b, pos);
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => return Err(JsonError::Parse(*pos, "expected ',' or ']'".into())),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, JsonError> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        if *pos >= b.len() || b[*pos] != b'"' {
            return Err(JsonError::Parse(*pos, "expected object key".into()));
        }
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(JsonError::Parse(*pos, "expected ':'".into()));
        }
        *pos += 1;
        skip_ws(b, pos);
        let value = parse_value(b, pos)?;
        map.insert(key, value);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => return Err(JsonError::Parse(*pos, "expected ',' or '}'".into())),
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let doc = Json::obj(vec![
            ("name", Json::str("gemm")),
            ("flops", Json::num(2.0 * 128.0 * 128.0 * 128.0)),
            ("shapes", Json::arr([Json::num(128.0), Json::num(256.0)])),
            ("tc", Json::Bool(true)),
            ("note", Json::Null),
        ]);
        let text = doc.to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, doc);
    }

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::str("a\nb"));
    }

    #[test]
    fn parse_rejects_trailing_garbage() {
        assert!(matches!(Json::parse("{} x"), Err(JsonError::Parse(..))));
        assert!(matches!(Json::parse("[1,]"), Err(JsonError::Parse(..))));
        assert!(matches!(Json::parse(""), Err(JsonError::Parse(..))));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let s = Json::str("quote\" slash\\ tab\t nl\n unicode→");
        let parsed = Json::parse(&s.to_string_compact()).unwrap();
        assert_eq!(parsed, s);
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::num(5.0).to_string_compact(), "5");
        assert_eq!(Json::num(5.25).to_string_compact(), "5.25");
    }

    #[test]
    fn accessors() {
        let doc = Json::parse(r#"{"a": {"b": [1, 2, 3]}, "s": "x"}"#).unwrap();
        assert_eq!(doc.get("a").unwrap().get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(doc.get("s").unwrap().as_str().unwrap(), "x");
        assert!(matches!(doc.get("zzz"), Err(JsonError::MissingKey(_))));
        assert!(matches!(doc.get("s").unwrap().as_f64(), Err(JsonError::WrongType(_))));
    }

    #[test]
    fn as_usize_rejects_fraction() {
        assert_eq!(Json::Num(3.0).as_usize().unwrap(), 3);
        assert!(Json::Num(3.5).as_usize().is_err());
        assert!(Json::Num(-1.0).as_usize().is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::str("A"));
    }
}
