//! Small descriptive-statistics toolkit used by the bench harness, the
//! ERT sweep driver (empirical max extraction) and the report modules.

/// Descriptive summary of a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stdev: f64,
    pub min: f64,
    pub max: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "Summary::of on empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        Summary {
            n,
            mean,
            stdev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            median: percentile_sorted(&sorted, 50.0),
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
        }
    }

    /// Coefficient of variation (stdev/mean); 0 for a zero mean.
    pub fn cv(&self) -> f64 {
        if self.mean == 0.0 {
            0.0
        } else {
            self.stdev / self.mean
        }
    }
}

/// Linear-interpolated percentile over a pre-sorted slice, `p` in [0,100].
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Geometric mean; all inputs must be positive.
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|&x| {
            assert!(x > 0.0, "geomean needs positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

/// Ordinary least squares fit `y = a + b*x`; returns `(a, b, r2)`.
/// Used to sanity-check scaling trajectories in the GEMM sweep.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64, f64) {
    assert_eq!(xs.len(), ys.len());
    assert!(xs.len() >= 2);
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let sxx: f64 = xs.iter().map(|x| (x - mx).powi(2)).sum();
    let syy: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let r2 = if sxx == 0.0 || syy == 0.0 {
        1.0
    } else {
        (sxy * sxy) / (sxx * syy)
    };
    (a, b, r2)
}

/// Relative difference |a-b| / max(|a|,|b|, eps).
pub fn rel_diff(a: f64, b: f64) -> f64 {
    let denom = a.abs().max(b.abs()).max(1e-300);
    (a - b).abs() / denom
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.stdev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_element() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stdev, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert!((percentile_sorted(&sorted, 50.0) - 5.0).abs() < 1e-12);
        assert!((percentile_sorted(&sorted, 25.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_matches_hand() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn linear_fit_exact_line() {
        let xs = [0.0, 1.0, 2.0, 3.0];
        let ys = [1.0, 3.0, 5.0, 7.0];
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 1.0).abs() < 1e-12);
        assert!((b - 2.0).abs() < 1e-12);
        assert!((r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rel_diff_symmetric() {
        assert!((rel_diff(1.0, 2.0) - 0.5).abs() < 1e-12);
        assert_eq!(rel_diff(0.0, 0.0), 0.0);
    }
}
