//! Backward-graph generation: reverse-mode gradient ops for every
//! forward op, plus optimizer-update ops per parameter tensor.
//!
//! The output remains an op graph; framework lowerings decide how the
//! ops become kernels (TF fuses grad-update into the backward stream,
//! PyTorch runs a separate optimizer phase — the distinction behind
//! Fig. 4 vs Figs 6+7 and the Table III column split).

use crate::dl::graph::{DType, Graph, Op, OpKind, TensorShape};

/// The training graph: forward ops + generated backward ops + optimizer
/// ops, kept in separate vectors so lowerings can assign phases.
#[derive(Clone, Debug)]
pub struct TrainGraph {
    pub graph: Graph,
    /// Indices into `graph.ops` for forward ops.
    pub forward_ops: Vec<usize>,
    /// Indices for backward (gradient) ops.
    pub backward_ops: Vec<usize>,
    /// Indices for optimizer-update ops.
    pub optimizer_ops: Vec<usize>,
}

/// Generate the backward + optimizer extension of a forward graph.
pub fn differentiate(mut graph: Graph) -> TrainGraph {
    let forward_ops: Vec<usize> = (0..graph.ops.len()).collect();
    let fwd_snapshot: Vec<Op> = graph.ops.clone();
    let mut backward_ops = Vec::new();

    // Reverse topological order (ops were appended in topo order).
    for op in fwd_snapshot.iter().rev() {
        let grads = backward_of(op, &graph);
        for (name, kind, flops, out_shape, dt) in grads {
            let out = graph.tensor(&format!("{name}_out"), out_shape, dt);
            graph.ops.push(Op {
                id: graph.ops.len(),
                name,
                kind,
                inputs: vec![op.output],
                output: out,
                compute_dtype: dt,
                flops,
            });
            backward_ops.push(graph.ops.len() - 1);
        }
    }

    // Optimizer: one SGD-momentum update per parameter tensor.
    let mut optimizer_ops = Vec::new();
    for p in graph.params() {
        let shape = graph.shape(p).clone();
        let n = shape.n_elems();
        let out = graph.tensor(&format!("{}_updated", graph.tensors[p.0].name), shape, DType::F32);
        graph.ops.push(Op {
            id: graph.ops.len(),
            name: format!("sgd_update_{}", graph.tensors[p.0].name),
            kind: OpKind::OptimizerUpdate,
            inputs: vec![p],
            output: out,
            compute_dtype: DType::F32,
            // v = mu*v + g (2 FLOPs), p = p - lr*v (2 FLOPs).
            flops: 4 * n,
        });
        optimizer_ops.push(graph.ops.len() - 1);
    }

    TrainGraph {
        graph,
        forward_ops,
        backward_ops,
        optimizer_ops,
    }
}

/// The gradient ops of one forward op:
/// (name, kind, flops, output shape, dtype).
fn backward_of(op: &Op, g: &Graph) -> Vec<(String, OpKind, u64, TensorShape, DType)> {
    let out_shape = g.shape(op.output).clone();
    let dt = op.compute_dtype;
    match &op.kind {
        OpKind::Conv2d { kh, kw, stride, dilation } => {
            // dX: correlation with flipped filter (same FLOPs as fwd);
            // dW: input x grad-output contraction (same FLOPs as fwd).
            let x_shape = g.shape(op.inputs[0]).clone();
            let w_shape = g.shape(op.inputs[1]).clone();
            vec![
                (
                    format!("{}_bwd_data", op.name),
                    OpKind::Conv2dBwdData {
                        kh: *kh,
                        kw: *kw,
                        stride: *stride,
                        dilation: *dilation,
                    },
                    op.flops,
                    x_shape,
                    dt,
                ),
                (
                    format!("{}_bwd_filter", op.name),
                    OpKind::Conv2dBwdFilter {
                        kh: *kh,
                        kw: *kw,
                        stride: *stride,
                        dilation: *dilation,
                    },
                    op.flops,
                    w_shape,
                    dt,
                ),
            ]
        }
        OpKind::ConvTranspose2d { kh, kw, stride } => {
            let x_shape = g.shape(op.inputs[0]).clone();
            let w_shape = g.shape(op.inputs[1]).clone();
            vec![
                (
                    format!("{}_bwd_data", op.name),
                    OpKind::Conv2dBwdData { kh: *kh, kw: *kw, stride: *stride, dilation: 1 },
                    op.flops,
                    x_shape,
                    dt,
                ),
                (
                    format!("{}_bwd_filter", op.name),
                    OpKind::Conv2dBwdFilter { kh: *kh, kw: *kw, stride: *stride, dilation: 1 },
                    op.flops,
                    w_shape,
                    dt,
                ),
            ]
        }
        OpKind::MatMul => {
            let x_shape = g.shape(op.inputs[0]).clone();
            vec![(
                format!("{}_bwd", op.name),
                OpKind::MatMulBwd,
                2 * op.flops,
                x_shape,
                dt,
            )]
        }
        OpKind::BatchNorm => {
            // dX, dGamma, dBeta in one multi-output kernel class.
            vec![(
                format!("{}_bwd", op.name),
                OpKind::BatchNormBwd,
                2 * op.flops,
                out_shape,
                dt,
            )]
        }
        OpKind::Relu => vec![(
            format!("{}_bwd", op.name),
            OpKind::ReluBwd,
            op.flops,
            out_shape,
            dt,
        )],
        OpKind::Add => Vec::new(), // gradient is identity fan-out
        OpKind::GlobalAvgPool | OpKind::Softmax => vec![(
            format!("{}_bwd", op.name),
            OpKind::ReluBwd, // elementwise-scale class
            op.flops,
            g.shape(op.inputs[0]).clone(),
            dt,
        )],
        OpKind::CrossEntropyLoss => vec![(
            format!("{}_bwd", op.name),
            OpKind::SoftmaxCrossEntropyBwd,
            op.flops,
            g.shape(op.inputs[0]).clone(),
            DType::F32,
        )],
        // Pure-movement ops have pure-movement gradients; emitted only
        // for casts (the AMP unscale path), skipped otherwise.
        OpKind::Cast { .. } => vec![(
            format!("{}_bwd_cast", op.name),
            OpKind::Cast { to: DType::F32 },
            0,
            g.shape(op.inputs[0]).clone(),
            DType::F32,
        )],
        _ => Vec::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dl::deepcam::{deepcam, DeepCamConfig};

    fn lite_train() -> TrainGraph {
        differentiate(deepcam(&DeepCamConfig::lite()))
    }

    #[test]
    fn every_conv_gets_two_grad_ops() {
        let t = lite_train();
        let fwd_convs = t
            .forward_ops
            .iter()
            .filter(|&&i| {
                matches!(
                    t.graph.ops[i].kind,
                    OpKind::Conv2d { .. } | OpKind::ConvTranspose2d { .. }
                )
            })
            .count();
        let bwd_data = t
            .backward_ops
            .iter()
            .filter(|&&i| matches!(t.graph.ops[i].kind, OpKind::Conv2dBwdData { .. }))
            .count();
        let bwd_filter = t
            .backward_ops
            .iter()
            .filter(|&&i| matches!(t.graph.ops[i].kind, OpKind::Conv2dBwdFilter { .. }))
            .count();
        assert_eq!(bwd_data, fwd_convs);
        assert_eq!(bwd_filter, fwd_convs);
    }

    #[test]
    fn one_optimizer_op_per_param() {
        let t = lite_train();
        assert_eq!(t.optimizer_ops.len(), t.graph.params().len());
        // PyTorch DeepCAM: "2709 kernel invocations" in the optimizer —
        // at paper scale our param-tensor count drives a comparable
        // number through the per-param update + momentum streams.
        let paper = differentiate(deepcam(&DeepCamConfig::paper()));
        assert!(paper.optimizer_ops.len() > 80, "{}", paper.optimizer_ops.len());
    }

    #[test]
    fn backward_flops_roughly_2x_forward() {
        // The classic rule: backward ≈ 2x forward compute (dX + dW per
        // conv). Our generator enforces it structurally.
        let t = lite_train();
        let fwd: u64 = t.forward_ops.iter().map(|&i| t.graph.ops[i].flops).sum();
        let bwd: u64 = t.backward_ops.iter().map(|&i| t.graph.ops[i].flops).sum();
        let ratio = bwd as f64 / fwd as f64;
        assert!((1.5..=2.5).contains(&ratio), "bwd/fwd = {ratio}");
    }

    #[test]
    fn optimizer_flops_linear_in_params() {
        let t = lite_train();
        let opt: u64 = t.optimizer_ops.iter().map(|&i| t.graph.ops[i].flops).sum();
        assert_eq!(opt, 4 * t.graph.n_param_elems());
    }

    #[test]
    fn phases_partition_ops() {
        let t = lite_train();
        let total = t.forward_ops.len() + t.backward_ops.len() + t.optimizer_ops.len();
        assert_eq!(total, t.graph.ops.len());
    }
}
