//! Automatic Mixed Precision pass (paper §IV-C).
//!
//! Rewrites a training graph's compute dtypes and inserts cast ops,
//! following apex.amp's documented optimization levels:
//!
//! * `O0` — FP32 baseline: no conversion, no tensor core (Fig. 9).
//! * `O1` — conservative: TC-eligible ops (convs/GEMMs) run FP16 with
//!   casts around them; norms/losses stay FP32 (the paper's default for
//!   PyTorch, Fig. 6).
//! * `O2` — aggressive: almost everything FP16, FP32 master weights;
//!   fewer casts but loss-scaling ops appear.
//! * `ManualFp16` — the hand-written cast placement of §IV-C/Fig. 8;
//!   *profiler-visible effect identical to O1* (that equivalence is the
//!   figure's point), with casts attributed to explicit graph ops.
//! * `Off` — TensorFlow without AMP: like O0.

use crate::dl::autodiff::TrainGraph;
use crate::dl::graph::{DType, Op, OpKind};

/// AMP optimization level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Policy {
    Off,
    O0,
    O1,
    O2,
    ManualFp16,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Off => "off",
            Policy::O0 => "O0",
            Policy::O1 => "O1",
            Policy::O2 => "O2",
            Policy::ManualFp16 => "manual-fp16",
        }
    }

    pub fn parse(s: &str) -> Option<Policy> {
        Some(match s {
            "off" => Policy::Off,
            "O0" | "o0" => Policy::O0,
            "O1" | "o1" => Policy::O1,
            "O2" | "o2" => Policy::O2,
            "manual-fp16" | "manual" => Policy::ManualFp16,
            _ => return None,
        })
    }

    /// Does this policy run TC-eligible math in FP16?
    pub fn uses_fp16(self) -> bool {
        !matches!(self, Policy::Off | Policy::O0)
    }
}

/// Apply AMP: mutate compute dtypes and insert cast ops. Returns the
/// number of cast ops inserted (all zero-AI, feeding Table III).
pub fn apply(t: &mut TrainGraph, policy: Policy) -> usize {
    if !policy.uses_fp16() {
        return 0;
    }
    let aggressive = policy == Policy::O2;
    let mut casts = 0usize;
    let mut new_ops: Vec<(usize, Op)> = Vec::new(); // (insert-after op idx, cast op)

    for idx in 0..t.graph.ops.len() {
        let op = &mut t.graph.ops[idx];
        let make_fp16 = if aggressive {
            // O2: everything except loss/optimizer/norm statistics.
            !matches!(
                op.kind,
                OpKind::CrossEntropyLoss
                    | OpKind::SoftmaxCrossEntropyBwd
                    | OpKind::OptimizerUpdate
            )
        } else {
            // O1/manual: TC-eligible ops only.
            op.kind.is_tensor_core_eligible()
        };
        if !make_fp16 || op.compute_dtype != DType::F32 {
            continue;
        }
        op.compute_dtype = DType::F16;
        // O1 wraps each converted *forward* op with input/output casts;
        // the backward pass runs in the dtype of the saved activations
        // (autocast does not re-cast gradients). O2 casts once at graph
        // entry (master weights) so per-op casts are rare.
        let is_forward = t.forward_ops.contains(&idx);
        if !aggressive && is_forward {
            let shape = t.graph.tensors[op.output.0].shape.clone();
            let op_name = op.name.clone();
            let out_id = op.output;
            let in_id = op.inputs[0];
            let in_shape = t.graph.tensors[in_id.0].shape.clone();
            // input cast f32->f16
            new_ops.push((
                idx,
                Op {
                    id: 0,
                    name: format!("{op_name}_cast_in"),
                    kind: OpKind::Cast { to: DType::F16 },
                    inputs: vec![in_id],
                    output: in_id,
                    compute_dtype: DType::F16,
                    flops: 0,
                },
            ));
            let _ = in_shape;
            // output cast f16->f32
            new_ops.push((
                idx,
                Op {
                    id: 0,
                    name: format!("{op_name}_cast_out"),
                    kind: OpKind::Cast { to: DType::F32 },
                    inputs: vec![out_id],
                    output: out_id,
                    compute_dtype: DType::F32,
                    flops: 0,
                },
            ));
            let _ = shape;
            casts += 2;
        }
    }

    if aggressive {
        // O2: one master-weight cast per parameter + loss-scaling ops.
        for p in t.graph.params() {
            let name = format!("{}_master_cast", t.graph.tensors[p.0].name);
            new_ops.push((
                usize::MAX,
                Op {
                    id: 0,
                    name,
                    kind: OpKind::Cast { to: DType::F16 },
                    inputs: vec![p],
                    output: p,
                    compute_dtype: DType::F16,
                    flops: 0,
                },
            ));
            casts += 1;
        }
    }

    // Loss scaling (both O1 and O2): scale + unscale elementwise passes.
    // These carry FLOPs (one mul/elem) but are tiny; modelled as two ops.
    // apex also emits inf/nan checks — movement-only.
    let loss_scale_ops = 2;
    for i in 0..loss_scale_ops {
        let scalar = t.graph.tensor(
            &format!("loss_scale_{i}"),
            crate::dl::graph::TensorShape(vec![1]),
            DType::F32,
        );
        new_ops.push((
            usize::MAX,
            Op {
                id: 0,
                name: format!("amp_loss_scale_{i}"),
                kind: OpKind::Memset,
                inputs: vec![scalar],
                output: scalar,
                compute_dtype: DType::F32,
                flops: 0,
            },
        ));
        casts += 1;
    }

    // Append cast ops to the graph op list, tagging phases: casts wrap
    // both forward and backward ops; attribute by the wrapped op's phase.
    for (after_idx, mut op) in new_ops {
        op.id = t.graph.ops.len();
        let is_fwd = after_idx != usize::MAX && t.forward_ops.contains(&after_idx);
        t.graph.ops.push(op);
        let new_idx = t.graph.ops.len() - 1;
        if is_fwd {
            t.forward_ops.push(new_idx);
        } else {
            t.backward_ops.push(new_idx);
        }
    }
    casts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dl::autodiff::differentiate;
    use crate::dl::deepcam::{deepcam, DeepCamConfig};

    fn train_graph() -> TrainGraph {
        differentiate(deepcam(&DeepCamConfig::lite()))
    }

    #[test]
    fn o0_is_identity() {
        let mut t = train_graph();
        let before = t.graph.ops.len();
        let casts = apply(&mut t, Policy::O0);
        assert_eq!(casts, 0);
        assert_eq!(t.graph.ops.len(), before);
        assert!(t.graph.ops.iter().all(|o| o.compute_dtype != DType::F16));
    }

    #[test]
    fn o1_converts_tc_ops_only() {
        let mut t = train_graph();
        apply(&mut t, Policy::O1);
        for op in &t.graph.ops {
            if op.kind.is_tensor_core_eligible() {
                assert_eq!(op.compute_dtype, DType::F16, "{}", op.name);
            }
            if matches!(op.kind, OpKind::BatchNorm | OpKind::CrossEntropyLoss) {
                assert_eq!(op.compute_dtype, DType::F32, "{}", op.name);
            }
        }
    }

    #[test]
    fn o1_inserts_two_casts_per_converted_forward_op() {
        let mut t = train_graph();
        let fwd_tc_ops = t
            .forward_ops
            .iter()
            .filter(|&&i| t.graph.ops[i].kind.is_tensor_core_eligible())
            .count();
        let casts = apply(&mut t, Policy::O1);
        assert_eq!(casts, 2 * fwd_tc_ops + 2 /* loss scaling */);
    }

    #[test]
    fn backward_tc_ops_converted_without_casts() {
        let mut t = train_graph();
        apply(&mut t, Policy::O1);
        // Backward conv ops run FP16 (saved-dtype)...
        assert!(t
            .backward_ops
            .iter()
            .filter(|&&i| t.graph.ops[i].kind.is_tensor_core_eligible())
            .all(|&i| t.graph.ops[i].compute_dtype == DType::F16));
        // ...but no cast ops were attributed to the backward phase other
        // than the loss-scaling bookkeeping.
        let bwd_casts = t
            .backward_ops
            .iter()
            .filter(|&&i| matches!(t.graph.ops[i].kind, OpKind::Cast { .. }))
            .count();
        assert_eq!(bwd_casts, 0, "autocast inserts no backward casts");
    }

    #[test]
    fn o2_more_fp16_fewer_casts_than_o1() {
        let mut t1 = train_graph();
        let c1 = apply(&mut t1, Policy::O1);
        let mut t2 = train_graph();
        let c2 = apply(&mut t2, Policy::O2);
        let fp16 = |t: &TrainGraph| {
            t.graph.ops.iter().filter(|o| o.compute_dtype == DType::F16 && o.flops > 0).count()
        };
        assert!(fp16(&t2) > fp16(&t1), "O2 converts more compute ops");
        // O2's casts are per-parameter master-weight syncs rather than
        // per-op wrappers: far fewer casts *per converted op*.
        let per_op_1 = c1 as f64 / fp16(&t1) as f64;
        let per_op_2 = c2 as f64 / fp16(&t2) as f64;
        assert!(per_op_2 < per_op_1, "{per_op_2} vs {per_op_1}");
    }

    #[test]
    fn manual_fp16_equals_o1_conversion_effect() {
        // Fig. 8's claim: manual casting matches AMP. Same converted-op
        // set and cast census.
        let mut a = train_graph();
        let ca = apply(&mut a, Policy::O1);
        let mut b = train_graph();
        let cb = apply(&mut b, Policy::ManualFp16);
        assert_eq!(ca, cb);
        let dtypes = |t: &TrainGraph| -> Vec<DType> {
            t.graph.ops.iter().map(|o| o.compute_dtype).collect()
        };
        assert_eq!(dtypes(&a), dtypes(&b));
    }

    #[test]
    fn casts_are_zero_ai() {
        let mut t = train_graph();
        apply(&mut t, Policy::O1);
        for op in &t.graph.ops {
            if matches!(op.kind, OpKind::Cast { .. }) {
                assert!(op.kind.is_zero_ai());
                assert_eq!(op.flops, 0);
            }
        }
    }

    #[test]
    fn policy_parsing() {
        assert_eq!(Policy::parse("O1"), Some(Policy::O1));
        assert_eq!(Policy::parse("manual-fp16"), Some(Policy::ManualFp16));
        assert_eq!(Policy::parse("bogus"), None);
    }
}
