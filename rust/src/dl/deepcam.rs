//! DeepCAM network builder: the DeepLabv3+ encoder-decoder the paper
//! profiles (§III-B), expressed as an operator graph.
//!
//! Two configurations:
//! * [`DeepCamConfig::paper`] — the published scale: 768x1152x16 climate
//!   tiles, ResNet-50-class encoder (16 residual blocks in 4 stages),
//!   ASPP, nine-layer decoder, 3 classes. This is what the Figs 3-9 and
//!   Table III traces are generated from.
//! * [`DeepCamConfig::lite`] — the AOT-compiled JAX twin
//!   (python/compile/model.py) used by the end-to-end example; kept in
//!   structural lockstep so the trace generator and the real model agree
//!   (cross-checked in tests against the artifact manifest).

use crate::dl::graph::{DType, Graph, TensorId, TensorShape};

/// Model hyper-parameters.
#[derive(Clone, Debug)]
pub struct DeepCamConfig {
    pub batch: u64,
    pub height: u64,
    pub width: u64,
    pub in_channels: u64,
    pub classes: u64,
    pub stem_channels: u64,
    pub encoder_channels: Vec<u64>,
    pub blocks_per_stage: u64,
    pub aspp_channels: u64,
    pub decoder_channels: u64,
}

impl DeepCamConfig {
    /// Published DeepCAM scale (Gordon-Bell/MLPerf configuration):
    /// 768x1152x16 climate tiles, a ResNet-50-class encoder (the 3x3
    /// working channels 64..512 of its bottleneck stages, 16 residual
    /// blocks), 256-channel ASPP + decoder, 3 classes. ~34M params —
    /// the DeepLabv3+/ResNet-50 ballpark.
    pub fn paper() -> DeepCamConfig {
        DeepCamConfig {
            batch: 2,
            height: 768,
            width: 1152,
            in_channels: 16,
            classes: 3,
            stem_channels: 64,
            encoder_channels: vec![64, 128, 256, 512],
            blocks_per_stage: 4, // 16 residual blocks ~ ResNet-50's (3,4,6,3)
            aspp_channels: 256,
            decoder_channels: 256,
        }
    }

    /// The AOT-compiled configuration (matches python model.DeepCamConfig.lite defaults
    /// as lowered by aot.py: 32x32 batch-2).
    pub fn lite() -> DeepCamConfig {
        DeepCamConfig {
            batch: 2,
            height: 32,
            width: 32,
            in_channels: 4,
            classes: 3,
            stem_channels: 16,
            encoder_channels: vec![16, 32, 64],
            blocks_per_stage: 1,
            aspp_channels: 32,
            decoder_channels: 32,
        }
    }
}

/// Build the DeepCAM forward graph. Returns the graph and the loss
/// tensor (a CE loss over per-pixel logits).
pub fn deepcam(cfg: &DeepCamConfig) -> Graph {
    let mut g = Graph::new();
    let x = g.tensor(
        "input",
        TensorShape::nhwc(cfg.batch, cfg.height, cfg.width, cfg.in_channels),
        DType::F32,
    );
    let labels = g.tensor(
        "labels",
        TensorShape::nhwc(cfg.batch, cfg.height, cfg.width, 1),
        DType::I32,
    );

    let conv_bn_relu = |g: &mut Graph,
                        name: &str,
                        x: TensorId,
                        cin: u64,
                        cout: u64,
                        k: u64,
                        stride: u64,
                        dilation: u64|
     -> TensorId {
        let w = g.param(&format!("{name}_w"), TensorShape(vec![k, k, cin, cout]), DType::F32);
        let y = g.conv2d(&format!("{name}_conv"), x, w, stride, dilation);
        let gamma = g.param(&format!("{name}_gamma"), TensorShape(vec![cout]), DType::F32);
        let beta = g.param(&format!("{name}_beta"), TensorShape(vec![cout]), DType::F32);
        let y = g.batch_norm(&format!("{name}_bn"), y, gamma, beta);
        g.relu(&format!("{name}_relu"), y)
    };

    // Stem.
    let stem = conv_bn_relu(&mut g, "stem", x, cfg.in_channels, cfg.stem_channels, 3, 1, 1);

    // Encoder stages.
    let mut feats = stem;
    let mut cin = cfg.stem_channels;
    let mut mid = stem;
    for (si, &ch) in cfg.encoder_channels.iter().enumerate() {
        feats = conv_bn_relu(&mut g, &format!("enc{si}_down"), feats, cin, ch, 3, 2, 1);
        for bi in 0..cfg.blocks_per_stage {
            let name = format!("enc{si}_blk{bi}");
            let y = conv_bn_relu(&mut g, &format!("{name}_a"), feats, ch, ch, 3, 1, 1);
            // Second conv + BN, then residual add + relu.
            let w2 = g.param(&format!("{name}_b_w"), TensorShape(vec![3, 3, ch, ch]), DType::F32);
            let y2 = g.conv2d(&format!("{name}_b_conv"), y, w2, 1, 1);
            let gamma = g.param(&format!("{name}_b_gamma"), TensorShape(vec![ch]), DType::F32);
            let beta = g.param(&format!("{name}_b_beta"), TensorShape(vec![ch]), DType::F32);
            let y2 = g.batch_norm(&format!("{name}_b_bn"), y2, gamma, beta);
            let sum = g.add(&format!("{name}_add"), y2, feats);
            feats = g.relu(&format!("{name}_relu"), sum);
        }
        if si == 0 {
            mid = feats;
        }
        cin = ch;
    }

    // ASPP: 1x1 + three dilated 3x3 branches + image pooling.
    let ac = cfg.aspp_channels;
    let b0 = conv_bn_relu(&mut g, "aspp_b0", feats, cin, ac, 1, 1, 1);
    let b1 = conv_bn_relu(&mut g, "aspp_b1", feats, cin, ac, 3, 1, 1);
    let b2 = conv_bn_relu(&mut g, "aspp_b2", feats, cin, ac, 3, 1, 2);
    let b3 = conv_bn_relu(&mut g, "aspp_b3", feats, cin, ac, 3, 1, 4);
    let pooled = g.global_avg_pool("aspp_pool", feats);
    let wp = g.param("aspp_pool_w", TensorShape(vec![1, 1, cin, ac]), DType::F32);
    let pooled = g.conv2d("aspp_pool_conv", pooled, wp, 1, 1);
    let feat_h = g.shape(b0).dim(1);
    let pooled = g.upsample("aspp_pool_up", pooled, feat_h);
    let cat = g.concat("aspp_cat", &[b0, b1, b2, b3, pooled]);
    let y = conv_bn_relu(&mut g, "aspp_fuse", cat, 5 * ac, ac, 1, 1, 1);

    // Decoder: nine layers, two skips (paper §III-B).
    let dc = cfg.decoder_channels;
    let wu1 = g.param("dec_up1_w", TensorShape(vec![3, 3, ac, dc]), DType::F32);
    let mut y = g.conv2d_transpose("dec_up1", y, wu1, 2); // layer 1
    let mid_h = g.shape(mid).dim(1);
    let y_h = g.shape(y).dim(1);
    if y_h != mid_h {
        y = g.upsample("dec_align1", y, mid_h / y_h);
    }
    let mid_ch = g.shape(mid).dim(3);
    let cat1 = g.concat("dec_skip1_cat", &[y, mid]);
    let y = conv_bn_relu(&mut g, "dec_skip1", cat1, dc + mid_ch, dc, 1, 1, 1); // layer 2
    let y = conv_bn_relu(&mut g, "dec_c1", y, dc, dc, 3, 1, 1); // layer 3
    let y = conv_bn_relu(&mut g, "dec_c2", y, dc, dc, 3, 1, 1); // layer 4
    let wu2 = g.param("dec_up2_w", TensorShape(vec![3, 3, dc, dc]), DType::F32);
    let mut y = g.conv2d_transpose("dec_up2", y, wu2, 2); // layer 5
    let stem_h = g.shape(stem).dim(1);
    let y_h = g.shape(y).dim(1);
    if y_h != stem_h {
        y = g.upsample("dec_align2", y, stem_h / y_h);
    }
    let stem_ch = g.shape(stem).dim(3);
    let cat2 = g.concat("dec_skip2_cat", &[y, stem]);
    let y = conv_bn_relu(&mut g, "dec_skip2", cat2, dc + stem_ch, dc, 1, 1, 1); // layer 6
    let y = conv_bn_relu(&mut g, "dec_c3", y, dc, dc, 3, 1, 1); // layer 7
    let y = conv_bn_relu(&mut g, "dec_c4", y, dc, dc, 3, 1, 1); // layer 8
    let wcls = g.param("dec_cls_w", TensorShape(vec![1, 1, dc, cfg.classes]), DType::F32);
    let logits = g.conv2d("dec_cls", y, wcls, 1, 1); // layer 9

    g.softmax_ce_loss("loss", logits, labels);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dl::graph::OpKind;

    #[test]
    fn paper_graph_builds_at_published_scale() {
        let g = deepcam(&DeepCamConfig::paper());
        // ResNet-50-class op census: >100 compute ops.
        assert!(g.ops.len() > 100, "{} ops", g.ops.len());
        // DeepLabv3+/ResNet-50 ballpark parameter count.
        let params = g.n_param_elems();
        assert!(params > 15_000_000 && params < 90_000_000, "{params}");
        // Forward cost: TFLOP-scale for batch 2 at 768x1152.
        let tflops = g.total_flops() as f64 / 1e12;
        assert!(tflops > 1.0 && tflops < 120.0, "{tflops} TFLOP");
    }

    #[test]
    fn lite_graph_matches_aot_twin_structure() {
        let g = deepcam(&DeepCamConfig::lite());
        // Same op-kind census as the python model: counted per kind.
        let convs = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Conv2d { .. } | OpKind::ConvTranspose2d { .. }))
            .count();
        // stem 1 + enc (3 down + 3 blocks x2) + aspp (4+1 pool) + fuse +
        // decoder (2 skip + 4 conv + 2 deconv + up1 + cls): 25 conv-class ops.
        assert_eq!(convs, 25, "conv census");
        // stem 1 + enc (3 downs + 3 blocks x 2 bn) + aspp 5 + decoder 6.
        let bns = g.ops.iter().filter(|o| o.kind == OpKind::BatchNorm).count();
        assert_eq!(bns, 21, "bn census");
    }

    #[test]
    fn logits_at_input_resolution() {
        let cfg = DeepCamConfig::lite();
        let g = deepcam(&cfg);
        let cls = g.ops.iter().find(|o| o.name == "dec_cls").unwrap();
        let shape = g.shape(cls.output);
        assert_eq!(shape.dim(1), cfg.height);
        assert_eq!(shape.dim(2), cfg.width);
        assert_eq!(shape.dim(3), cfg.classes);
    }

    #[test]
    fn loss_is_scalar_and_last() {
        let g = deepcam(&DeepCamConfig::lite());
        let last = g.ops.last().unwrap();
        assert_eq!(last.kind, OpKind::CrossEntropyLoss);
        assert_eq!(g.shape(last.output).n_elems(), 1);
    }

    #[test]
    fn residual_blocks_have_matching_shapes() {
        // The add ops assert shape equality internally; building the
        // paper config without panicking is the test.
        let g = deepcam(&DeepCamConfig::paper());
        let adds = g.ops.iter().filter(|o| o.kind == OpKind::Add).count();
        assert_eq!(adds as u64, 4 * DeepCamConfig::paper().blocks_per_stage);
    }
}
