//! Framework personalities: lowering a training graph to kernel traces.
//!
//! This is where the paper's TensorFlow-vs-PyTorch differences live
//! (§IV, Table III). The two lowerings share op→kernel cost accounting
//! but differ exactly where the real runtimes do:
//!
//! **TensorFlow (graph mode + grappler fusion)**
//! * conv+BN+ReLU triples fuse into one cudnn kernel, named by *algo
//!   class* — so every large encoder conv aggregates under one kernel
//!   name. That aggregation is the paper's dominant forward kernel
//!   ("three largest circles", 33% of runtime, Fig. 3).
//! * NCHW-internal: a layout transpose accompanies each conv (zero-AI).
//! * The gradient *update* runs inside the backward stream (Table III
//!   footnote a).
//!
//! **PyTorch (eager + cudnn benchmark autotuning)**
//! * every op is its own kernel; names carry the shape bucket, so
//!   aggregation is thin — "no dominant kernels" (Fig. 5).
//! * AMP O1 autocast inserts per-op casts; `.contiguous()` copies and
//!   broadcast expansions add more zero-AI launches.
//! * cudnn's heuristics pick a *non-tensor-core FP32* algorithm for
//!   dilated/strided backward-filter convs — the paper's surprising
//!   ~1 TFLOP/s top backward kernel (Fig. 6).
//! * the optimizer is a separate phase of pure streaming kernels with
//!   zero zero-AI launches (Fig. 7, Table III).

use crate::device::{GpuSpec, Precision};
use crate::dl::amp::{self, Policy};
use crate::dl::autodiff::{differentiate, TrainGraph};
use crate::dl::graph::{DType, Graph, Op, OpKind};
use crate::sim::kernel::{AccessPattern, InstMix, KernelDesc, KernelInvocation};

/// Which framework personality to lower with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Framework {
    TensorFlow,
    PyTorch,
}

impl Framework {
    /// Both personalities, in matrix-enumeration order.
    pub const ALL: [Framework; 2] = [Framework::TensorFlow, Framework::PyTorch];

    pub fn name(self) -> &'static str {
        match self {
            Framework::TensorFlow => "tensorflow",
            Framework::PyTorch => "pytorch",
        }
    }

    /// Short tag for scenario ids and file names.
    pub fn short(self) -> &'static str {
        match self {
            Framework::TensorFlow => "tf",
            Framework::PyTorch => "pt",
        }
    }

    pub fn parse(s: &str) -> Option<Framework> {
        match s {
            "tensorflow" | "tf" => Some(Framework::TensorFlow),
            "pytorch" | "pt" => Some(Framework::PyTorch),
            _ => None,
        }
    }
}

/// Training phase a kernel belongs to.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Phase {
    Forward,
    Backward,
    Optimizer,
}

impl Phase {
    /// All phases, in execution order.
    pub const ALL: [Phase; 3] = [Phase::Forward, Phase::Backward, Phase::Optimizer];

    pub fn name(self) -> &'static str {
        match self {
            Phase::Forward => "forward",
            Phase::Backward => "backward",
            Phase::Optimizer => "optimizer",
        }
    }

    pub fn parse(s: &str) -> Option<Phase> {
        match s {
            "forward" | "fwd" => Some(Phase::Forward),
            "backward" | "bwd" => Some(Phase::Backward),
            "optimizer" | "opt" => Some(Phase::Optimizer),
            _ => None,
        }
    }
}

/// The lowered trace, phase-split. For TensorFlow the optimizer stream
/// is folded into `backward` and `optimizer` is empty (Table III
/// footnote); for PyTorch all three are populated.
#[derive(Clone, Debug, Default)]
pub struct FrameworkTrace {
    pub forward: Vec<KernelInvocation>,
    pub backward: Vec<KernelInvocation>,
    pub optimizer: Vec<KernelInvocation>,
}

impl FrameworkTrace {
    pub fn phase(&self, p: Phase) -> &[KernelInvocation] {
        match p {
            Phase::Forward => &self.forward,
            Phase::Backward => &self.backward,
            Phase::Optimizer => &self.optimizer,
        }
    }

    /// All phases concatenated.
    pub fn all(&self) -> Vec<KernelInvocation> {
        let mut v = self.forward.clone();
        v.extend(self.backward.iter().cloned());
        v.extend(self.optimizer.iter().cloned());
        v
    }

    /// (zero-AI, total) invocation census for a phase — the Table III
    /// quantities. Zero-AI = no FP instructions at all.
    pub fn zero_ai_census(&self, p: Phase, spec: &GpuSpec) -> (u64, u64) {
        let mut zero = 0;
        let mut total = 0;
        for inv in self.phase(p) {
            total += inv.invocations;
            if inv.kernel.mix.is_zero_ai(spec) {
                zero += inv.invocations;
            }
        }
        (zero, total)
    }
}

/// Lower DeepCAM (or any forward graph) under TensorFlow semantics.
pub fn tensorflow(forward_graph: &Graph, policy: Policy, spec: &GpuSpec) -> FrameworkTrace {
    lower(forward_graph, Framework::TensorFlow, policy, spec)
}

/// Lower under PyTorch semantics.
pub fn pytorch(forward_graph: &Graph, policy: Policy, spec: &GpuSpec) -> FrameworkTrace {
    lower(forward_graph, Framework::PyTorch, policy, spec)
}

/// Full lowering: autodiff + AMP + framework personality, targeting one
/// device. Lowering never constructs its own spec — the caller decides
/// which registry device the trace is for (kernel tile selection and
/// tensor-instruction width are device properties, so the same graph
/// lowers differently on different GPUs).
pub fn lower(
    forward_graph: &Graph,
    fw: Framework,
    policy: Policy,
    spec: &GpuSpec,
) -> FrameworkTrace {
    let mut train = differentiate(forward_graph.clone());
    amp::apply(&mut train, policy);
    let mut out = FrameworkTrace::default();

    lower_phase(&train, fw, policy, Phase::Forward, spec, &mut out);
    lower_phase(&train, fw, policy, Phase::Backward, spec, &mut out);
    lower_phase(&train, fw, policy, Phase::Optimizer, spec, &mut out);
    out
}

fn lower_phase(
    train: &TrainGraph,
    fw: Framework,
    policy: Policy,
    phase: Phase,
    spec: &GpuSpec,
    out: &mut FrameworkTrace,
) {
    let op_ids: &[usize] = match phase {
        Phase::Forward => &train.forward_ops,
        Phase::Backward => &train.backward_ops,
        Phase::Optimizer => &train.optimizer_ops,
    };
    // TF folds the optimizer into the backward stream.
    let dest_phase = if fw == Framework::TensorFlow && phase == Phase::Optimizer {
        Phase::Backward
    } else {
        phase
    };

    let mut kernels: Vec<KernelDesc> = Vec::new();
    let g = &train.graph;

    let mut skip_until = 0usize; // for TF fusion lookahead
    for (pos, &oi) in op_ids.iter().enumerate() {
        if pos < skip_until {
            continue;
        }
        let op = &g.ops[oi];
        match (&op.kind, fw) {
            // ---- TF: fuse conv+BN (+residual add) into one kernel ----
            (OpKind::Conv2d { .. } | OpKind::ConvTranspose2d { .. }, Framework::TensorFlow)
                if phase == Phase::Forward =>
            {
                let mut flops = op.flops;
                let mut fused = 1usize;
                // Lookahead in *graph order* for the BN/add that consume
                // this conv (builder emits them consecutively). ReLU
                // stays a separate TF kernel.
                for look in 1..=2 {
                    if pos + look >= op_ids.len() {
                        break;
                    }
                    let next = &g.ops[op_ids[pos + look]];
                    match next.kind {
                        OpKind::BatchNorm | OpKind::Add => {
                            flops += next.flops;
                            fused += 1;
                        }
                        _ => break,
                    }
                }
                skip_until = pos + fused;
                kernels.push(conv_kernel(g, op, fw, policy, spec, flops, "fused_bn"));
                // NCHW layout transpose companion (zero-AI).
                kernels.push(movement_kernel(
                    "tf_nchw_transpose",
                    g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)),
                ));
                if policy.uses_fp16() && op.kind.is_tensor_core_eligible() {
                    // grappler sinks most casts; one survives per conv.
                    kernels.push(movement_kernel(
                        "tf_cast_f2h",
                        g.tensors[op.inputs[0].0].shape.bytes(DType::F16),
                    ));
                }
            }

            // ---- compute ops, per-framework kernel granularity ----
            (OpKind::Conv2d { .. } | OpKind::ConvTranspose2d { .. }, _) => {
                kernels.push(conv_kernel(g, op, fw, policy, spec, op.flops, "fwd"));
                if fw == Framework::PyTorch {
                    push_pytorch_conv_companions(g, op, policy, &mut kernels);
                }
            }
            (OpKind::Conv2dBwdData { .. }, _) => {
                match fw {
                    Framework::TensorFlow => {
                        // TF splits dgrad into k-chunk partials + an
                        // accumulation pass (3 launches of the same
                        // kernel), plus layout + gradient staging copies.
                        for _ in 0..3 {
                            kernels.push(conv_kernel(
                                g,
                                op,
                                fw,
                                policy,
                                spec,
                                op.flops / 3,
                                "bwd_data",
                            ));
                        }
                        kernels.push(movement_kernel(
                            "tf_nchw_transpose_grad",
                            g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)),
                        ));
                        kernels.push(movement_kernel(
                            "tf_grad_stage_copy",
                            g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)) / 2,
                        ));
                    }
                    Framework::PyTorch => {
                        kernels.push(conv_kernel(g, op, fw, policy, spec, op.flops, "bwd_data"));
                        // eager grad layout copy
                        kernels.push(movement_kernel(
                            "pt_grad_copy",
                            g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)),
                        ));
                    }
                }
            }
            (OpKind::Conv2dBwdFilter { kh, kw, stride, dilation }, _) => {
                // PyTorch quirk (Fig. 6): cudnn's heuristics pick a
                // non-TC FP32 atomics wgrad algorithm for (a) dilated
                // (atrous) convolutions, (b) mid-resolution strided
                // deconvolutions, and (c) full-resolution 1x1 wgrads —
                // a degenerate skinny GEMM with a multi-million-element
                // reduction dimension, where the atomics algorithm wins
                // the heuristic. Independent of AMP (algorithm
                // selection), so it afflicts O0 identically.
                let weight_elems = g.tensors[op.output.0].shape.n_elems().max(1);
                let reduction_pixels = op.flops / (2 * weight_elems);
                let pt_fallback = fw == Framework::PyTorch
                    && (*dilation > 1
                        || (op.name.contains("up") && *stride > 1 && op.flops < 1_000_000_000_000)
                        || (*kh == 1 && *kw == 1 && reduction_pixels >= 1_500_000));
                if pt_fallback {
                    kernels.push(fp32_fallback_wgrad(g, op, spec));
                } else if fw == Framework::TensorFlow {
                    // Same k-chunk split as dgrad.
                    for _ in 0..3 {
                        kernels.push(conv_kernel(
                            g,
                            op,
                            fw,
                            policy,
                            spec,
                            op.flops / 3,
                            "bwd_filter",
                        ));
                    }
                } else {
                    kernels.push(conv_kernel(g, op, fw, policy, spec, op.flops, "bwd_filter"));
                }
                if fw == Framework::TensorFlow {
                    kernels.push(movement_kernel(
                        "tf_wgrad_transpose",
                        g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)),
                    ));
                    kernels.push(movement_kernel(
                        "tf_grad_stage_copy",
                        g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)) / 2,
                    ));
                    let _ = (kh, kw);
                }
            }
            (OpKind::BatchNorm, Framework::PyTorch) => {
                // Eager BN: stats kernel + normalize kernel + a stat
                // staging/broadcast copy.
                kernels.push(elementwise_kernel(g, op, fw, "bn_stats", op.flops / 2));
                kernels.push(elementwise_kernel(g, op, fw, "bn_apply", op.flops / 2));
                kernels.push(movement_kernel(
                    "pt_contiguous",
                    g.tensors[op.output.0].shape.bytes(DType::F32) / 4,
                ));
            }
            (OpKind::BatchNorm, Framework::TensorFlow) => {
                // Unfused BNs (ASPP/decoder tails) — one fused TF kernel.
                kernels.push(elementwise_kernel(g, op, fw, "fused_batch_norm", op.flops));
            }
            (OpKind::BatchNormBwd, Framework::TensorFlow) => {
                // TF splits BN backward into reduce + elementwise.
                kernels.push(elementwise_kernel(g, op, fw, "bn_bwd_reduce", op.flops / 2));
                kernels.push(elementwise_kernel(g, op, fw, "bn_bwd_apply", op.flops / 2));
            }
            (OpKind::BatchNormBwd, Framework::PyTorch) => {
                kernels.push(elementwise_kernel(g, op, fw, "bn_bwd", op.flops));
                kernels.push(movement_kernel(
                    "pt_grad_memset",
                    g.tensors[op.output.0].shape.bytes(DType::F32) / 8,
                ));
            }
            (OpKind::Relu | OpKind::Add | OpKind::GlobalAvgPool | OpKind::Softmax, _) => {
                kernels.push(elementwise_kernel(g, op, fw, kind_label(&op.kind), op.flops));
            }
            (OpKind::ReluBwd, Framework::PyTorch) => {
                // threshold_backward fused into the surrounding bn_bwd in
                // recent eager traces — folded (no separate kernel).
            }
            (OpKind::ReluBwd, Framework::TensorFlow) => {
                kernels.push(elementwise_kernel(g, op, fw, "relu_grad", op.flops));
            }
            (OpKind::CrossEntropyLoss | OpKind::SoftmaxCrossEntropyBwd, _) => {
                kernels.push(elementwise_kernel(g, op, fw, kind_label(&op.kind), op.flops));
                if fw == Framework::TensorFlow {
                    // loss scalar readback
                    kernels.push(movement_kernel("tf_host_copy", 4096));
                }
            }
            (OpKind::MatMul | OpKind::MatMulBwd, _) => {
                kernels.push(conv_kernel(g, op, fw, policy, spec, op.flops, "gemm"));
            }
            (OpKind::OptimizerUpdate, _) => {
                // SGD momentum: weight-decay + momentum + apply — three
                // streaming kernels per parameter tensor in eager PT;
                // TF emits a single fused apply + a grad-zero memset.
                let bytes = g.tensors[op.output.0].shape.bytes(DType::F32);
                let n = g.tensors[op.output.0].shape.n_elems();
                match fw {
                    Framework::PyTorch => {
                        kernels.push(streaming_named("sgd_weight_decay", n, 1, bytes));
                        kernels.push(streaming_named("sgd_momentum", n, 2, bytes));
                        kernels.push(streaming_named("sgd_apply", n, 1, bytes));
                    }
                    Framework::TensorFlow => {
                        // Gradient aggregation (AddN), the fused apply,
                        // plus grad staging + zeroing (zero-AI).
                        kernels.push(streaming_named("tf_addn_grad", n, 1, bytes));
                        kernels.push(streaming_named("resource_apply_momentum", n, 4, bytes));
                        kernels.push(movement_kernel("tf_grad_cast_stage", bytes / 2));
                        kernels.push(movement_kernel("tf_grad_zero_memset", bytes));
                    }
                }
            }
            // ---- movement-only graph ops ----
            (OpKind::Cast { .. }, Framework::TensorFlow) => {
                // grappler folds AMP casts into the fused kernels — no
                // launch (the surviving per-conv cast is emitted by the
                // conv arm above).
            }
            (OpKind::Cast { .. }, Framework::PyTorch) => {
                kernels.push(movement_kernel(
                    cast_label(fw),
                    g.tensors[op.output.0].shape.bytes(DType::F16),
                ));
            }
            (OpKind::Concat | OpKind::Upsample { .. } | OpKind::Transpose, _) => {
                let label = match op.kind {
                    OpKind::Concat => "concat_copy",
                    OpKind::Upsample { .. } => "upsample_copy",
                    _ => "transpose",
                };
                kernels.push(movement_kernel(
                    label,
                    g.tensors[op.output.0].shape.bytes(dtype_of(op, policy)),
                ));
                if fw == Framework::PyTorch {
                    // eager launches a shape-probe copy alongside
                    kernels.push(movement_kernel("pt_copy_", 4096));
                }
            }
            (OpKind::Memset | OpKind::HostCopy, _) => {
                kernels.push(movement_kernel("memset", 4096));
            }
        }
    }

    // Emit one KernelInvocation per launch. Same-shape launches of the
    // same kernel name stay separate here; the profiler aggregates by
    // kernel name exactly as Nsight does ("the data presented ... is the
    // aggregation of all these invocations of the same kernel", §IV) —
    // TF's algo-class naming is what turns many launches into one
    // dominant aggregated kernel.
    let dest = match dest_phase {
        Phase::Forward => &mut out.forward,
        Phase::Backward => &mut out.backward,
        Phase::Optimizer => &mut out.optimizer,
    };
    for k in kernels {
        dest.push(KernelInvocation {
            kernel: k,
            invocations: 1,
            stream: 0,
        });
    }
}

fn dtype_of(op: &Op, policy: Policy) -> DType {
    if policy.uses_fp16() && op.compute_dtype == DType::F16 {
        DType::F16
    } else {
        DType::F32
    }
}

fn kind_label(kind: &OpKind) -> &'static str {
    match kind {
        OpKind::Relu => "relu",
        OpKind::Add => "residual_add",
        OpKind::GlobalAvgPool => "global_avg_pool",
        OpKind::Softmax => "softmax",
        OpKind::CrossEntropyLoss => "softmax_ce_loss",
        OpKind::SoftmaxCrossEntropyBwd => "softmax_ce_bwd",
        _ => "elementwise",
    }
}

fn cast_label(fw: Framework) -> &'static str {
    match fw {
        Framework::TensorFlow => "tf_cast",
        Framework::PyTorch => "pt_autocast",
    }
}

/// Eager-PyTorch conv companions: autocast casts on activation + weight
/// (AMP O1/O2/manual) and a `.contiguous()` layout copy — the zero-AI
/// launches that put PyTorch's forward at ~55% zero-AI (Table III).
fn push_pytorch_conv_companions(
    g: &Graph,
    op: &Op,
    policy: Policy,
    kernels: &mut Vec<KernelDesc>,
) {
    let in_bytes = g.tensors[op.inputs[0].0].shape.bytes(DType::F16);
    // The activation/weight autocast casts are modelled as graph Cast
    // ops by amp.rs; the remaining eager launches are layout copies.
    kernels.push(movement_kernel("pt_contiguous_conv", in_bytes));
    if op.inputs.len() > 1 {
        let w_bytes = g.tensors[op.inputs[1].0].shape.bytes(DType::F16);
        kernels.push(movement_kernel("pt_weight_copy", w_bytes));
    }
    let _ = policy;
}

/// Conv-class kernel: GEMM-shaped cost model. Kernel *names* encode the
/// aggregation behaviour: TF names by algo class (heavy aggregation →
/// dominant kernels), PyTorch names carry the shape bucket (thin
/// aggregation → no dominant kernel).
fn conv_kernel(
    g: &Graph,
    op: &Op,
    fw: Framework,
    policy: Policy,
    spec: &GpuSpec,
    flops: u64,
    tag: &str,
) -> KernelDesc {
    let dt = dtype_of(op, policy);
    let tc = dt == DType::F16 && op.kind.is_tensor_core_eligible();
    // GEMM dims from the implicit-GEMM view. `m` is the batched row
    // space (every axis but the innermost), rank-agnostic so matmul
    // outputs of any rank land here safely.
    let out_shape = &g.tensors[op.output.0].shape;
    let n = out_shape.0.last().copied().unwrap_or(1).max(1);
    let m = (out_shape.n_elems() / n).max(1);
    let k = (flops / 2).checked_div(m * n).unwrap_or(1).max(1);
    // Library tile selection tracks the device's combined L1/shared
    // capacity: a ≥128 KiB carve (V100/A100-class) stages 128×128 TC
    // tiles, a smaller one (T4: 64 KiB) halves the tile edge — which is
    // why the same graph launches a different grid on each device.
    let big_l1 = spec.l1.capacity_bytes >= 128 * 1024;
    let tile = match (tc, big_l1) {
        (true, true) => 128,
        (true, false) | (false, true) => 64,
        (false, false) => 32,
    };
    // Algo-class descriptor: cudnn picks kernels by filter size, stride
    // and channel band — all layers sharing a class share a kernel name
    // (and therefore aggregate on the chart).
    let (ksz, stride) = match &op.kind {
        OpKind::Conv2d { kh, stride, .. }
        | OpKind::Conv2dBwdData { kh, stride, .. }
        | OpKind::Conv2dBwdFilter { kh, stride, .. }
        | OpKind::ConvTranspose2d { kh, stride, .. } => (*kh, *stride),
        _ => (1, 1),
    };
    let band = if n >= 256 { "wide" } else if n >= 64 { "mid" } else { "narrow" };
    let name = match fw {
        Framework::TensorFlow => {
            if tc {
                format!("volta_h884cudnn_{tag}_{ksz}x{ksz}s{stride}_{band}_256x128")
            } else {
                format!("volta_scudnn_{tag}_{ksz}x{ksz}s{stride}_{band}_128x128")
            }
        }
        Framework::PyTorch => {
            if tc {
                format!("cudnn_h884_{tag}_c{n}_k{k}")
            } else {
                format!("cudnn_sgemm_{tag}_c{n}_k{k}")
            }
        }
    };
    let mut kd = KernelDesc::gemm(&name, m, n, k, dt.precision(), tc, tile, spec);
    // The generic GEMM footprint ((m*k + k*n + m*n) elems) would count
    // the *im2col-expanded* operand; the kernel's unique bytes are the
    // actual tensors it touches.
    let unique_bytes: u64 = op
        .inputs
        .iter()
        .map(|t| g.tensors[t.0].shape.bytes(dt))
        .sum::<u64>()
        + g.tensors[op.output.0].shape.bytes(dt);
    kd.access.footprint_bytes = unique_bytes.min(kd.access.footprint_bytes);
    // cudnn library kernels sustain near-library efficiency; the fused
    // TF kernels run slightly hotter thanks to fused epilogues.
    kd.efficiency = match fw {
        Framework::TensorFlow => 0.9,
        Framework::PyTorch => 0.82,
    };
    kd.occupancy = 0.55;
    kd
}

/// The PyTorch FP32 non-TC backward-filter fallback (Fig. 6's ~1 TFLOP/s
/// top kernel): atomics-heavy wgrad with poor issue efficiency.
fn fp32_fallback_wgrad(g: &Graph, op: &Op, spec: &GpuSpec) -> KernelDesc {
    let out_shape = &g.tensors[op.output.0].shape;
    let flops = op.flops;
    // GEMM view with macs == flops/2 (m: filter elems, n fixed 64).
    let m = out_shape.n_elems().max(1).min(1 << 20);
    let n = 64u64;
    let k = (flops / 2 / (m * n)).max(1);
    let mut kd = KernelDesc::gemm(
        "cudnn_bwd_filter_fp32_algo1_atomics",
        m,
        n,
        k,
        Precision::Fp32,
        false,
        32,
        spec,
    );
    // Atomic serialization destroys issue efficiency: ~1 TFLOP/s out of
    // the 15.2 FP32 peak.
    kd.efficiency = 0.066;
    kd.occupancy = 0.35;
    // Re-derive the mix from the *actual* op flops.
    kd.mix = InstMix::default();
    kd.mix.fp32.fma = flops / 2;
    kd.mix.int_ops = flops / 16;
    kd
}

/// Elementwise compute kernel (streaming signature).
fn elementwise_kernel(g: &Graph, op: &Op, fw: Framework, label: &str, flops: u64) -> KernelDesc {
    let shape = &g.tensors[op.output.0].shape;
    let n = shape.n_elems().max(1);
    let dt = op.compute_dtype;
    let name = match fw {
        Framework::TensorFlow => format!("tf_{label}"),
        Framework::PyTorch => format!("pt_{label}_c{}", shape.0.last().copied().unwrap_or(1)),
    };
    let mut kd = KernelDesc::streaming_elementwise(&name, n, dt.precision(), 0);
    kd.mix = InstMix::default();
    *kd.mix.counts_mut(dt.precision()) = crate::sim::kernel::FpCounts {
        add: flops / 3,
        mul: flops / 3,
        fma: (flops - 2 * (flops / 3)) / 2,
    };
    kd.mix.int_ops = n;
    kd.access = AccessPattern::streaming(2 * n * dt.bytes(), n * dt.bytes());
    kd
}

/// Pure-movement (zero-AI) kernel.
fn movement_kernel(name: &str, bytes: u64) -> KernelDesc {
    let mut kd = KernelDesc::streaming_elementwise(name, (bytes / 4).max(1), Precision::Fp32, 0);
    kd.mix = InstMix {
        int_ops: (bytes / 4).max(1),
        ..Default::default()
    };
    kd.access = AccessPattern::streaming(bytes, bytes);
    kd
}

/// Named streaming compute kernel over n elements with `fma_per_elem`.
fn streaming_named(name: &str, n: u64, fma_per_elem: u64, bytes: u64) -> KernelDesc {
    let mut kd = KernelDesc::streaming_elementwise(name, n, Precision::Fp32, fma_per_elem);
    // Optimizer streams read grad+momentum+param and write two: ~3x.
    kd.access = AccessPattern::streaming(2 * bytes, bytes);
    kd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dl::deepcam::{deepcam, DeepCamConfig};

    fn paper_graph() -> Graph {
        deepcam(&DeepCamConfig::paper())
    }

    #[test]
    fn tf_optimizer_folds_into_backward() {
        let spec = GpuSpec::v100();
        let t = tensorflow(&paper_graph(), Policy::O1, &spec);
        assert!(t.optimizer.is_empty());
        assert!(!t.backward.is_empty());
        // TF backward contains the update kernels.
        assert!(t
            .backward
            .iter()
            .any(|i| i.kernel.name.contains("apply_momentum")));
    }

    #[test]
    fn pytorch_optimizer_is_separate_and_non_zero_ai() {
        let spec = GpuSpec::v100();
        let t = pytorch(&paper_graph(), Policy::O1, &spec);
        assert!(!t.optimizer.is_empty());
        let (zero, total) = t.zero_ai_census(Phase::Optimizer, &spec);
        assert_eq!(zero, 0, "Table III: PyTorch optimizer has 0 zero-AI");
        assert!(total > 100);
    }

    #[test]
    fn zero_ai_fractions_match_table3_shape() {
        let spec = GpuSpec::v100();
        // Paper defaults: AMP enabled for both frameworks (§III-B).
        let tf = tensorflow(&paper_graph(), Policy::O1, &spec);
        let pt = pytorch(&paper_graph(), Policy::O1, &spec);
        let frac = |t: &FrameworkTrace, p: Phase| {
            let (z, n) = t.zero_ai_census(p, &spec);
            z as f64 / n as f64
        };
        // Paper: TF fwd 54.7%, TF bwd 40.1%, PT fwd 54.8%, PT bwd 38.7%.
        let tf_fwd = frac(&tf, Phase::Forward);
        let tf_bwd = frac(&tf, Phase::Backward);
        let pt_fwd = frac(&pt, Phase::Forward);
        let pt_bwd = frac(&pt, Phase::Backward);
        assert!((tf_fwd - 0.547).abs() < 0.10, "tf fwd {tf_fwd}");
        assert!((tf_bwd - 0.401).abs() < 0.10, "tf bwd {tf_bwd}");
        assert!((pt_fwd - 0.548).abs() < 0.10, "pt fwd {pt_fwd}");
        assert!((pt_bwd - 0.387).abs() < 0.10, "pt bwd {pt_bwd}");
    }

    #[test]
    fn tf_forward_has_dominant_aggregated_kernel() {
        // Fig. 3: TF's algo-class naming makes the big encoder convs
        // aggregate under one kernel name.
        let spec = GpuSpec::v100();
        let t = tensorflow(&paper_graph(), Policy::O1, &spec);
        let launches: u64 = t
            .forward
            .iter()
            .filter(|i| i.kernel.name.contains("h884"))
            .map(|i| i.invocations)
            .sum();
        assert!(launches > 10, "TC conv kernel aggregates many launches: {launches}");
    }

    #[test]
    fn pytorch_forward_kernel_names_are_diverse() {
        // Fig. 5: no dominant kernel — shape-bucketed names.
        let spec = GpuSpec::v100();
        let tf = tensorflow(&paper_graph(), Policy::O1, &spec);
        let pt = pytorch(&paper_graph(), Policy::O1, &spec);
        let distinct = |t: &FrameworkTrace| {
            let mut names: Vec<&str> =
                t.forward.iter().map(|i| i.kernel.name.as_str()).collect();
            names.sort_unstable();
            names.dedup();
            names.len()
        };
        assert!(
            distinct(&pt) > 2 * distinct(&tf),
            "pt {} vs tf {}",
            distinct(&pt),
            distinct(&tf)
        );
    }

    #[test]
    fn pytorch_bwd_filter_fallback_exists_under_amp() {
        // Fig. 6: the top backward kernel runs FP32 without TC.
        let spec = GpuSpec::v100();
        let pt = pytorch(&paper_graph(), Policy::O1, &spec);
        let fallback = pt
            .backward
            .iter()
            .find(|i| i.kernel.name.contains("fp32_algo1"))
            .expect("fallback wgrad kernel present");
        assert_eq!(fallback.kernel.mix.tensor_insts, 0);
        assert!(fallback.kernel.mix.fp32.fma > 0);
    }

    #[test]
    fn amp_o0_has_no_tensor_core_kernels() {
        let spec = GpuSpec::v100();
        let pt = pytorch(&paper_graph(), Policy::O0, &spec);
        for inv in pt.all() {
            assert_eq!(
                inv.kernel.mix.tensor_insts, 0,
                "O0 must not touch TC: {}",
                inv.kernel.name
            );
        }
    }

    #[test]
    fn amp_o1_moves_convs_to_tensor_core() {
        let spec = GpuSpec::v100();
        let pt_o0 = pytorch(&paper_graph(), Policy::O0, &spec);
        let pt_o1 = pytorch(&paper_graph(), Policy::O1, &spec);
        let tc_insts = |t: &FrameworkTrace| -> u64 {
            t.all().iter().map(|i| i.kernel.mix.tensor_insts * i.invocations).sum()
        };
        assert_eq!(tc_insts(&pt_o0), 0);
        assert!(tc_insts(&pt_o1) > 0);
    }

    #[test]
    fn total_trace_flops_conserved_across_frameworks() {
        // Both lowerings must account the same model FLOPs (within the
        // fusion/fallback bookkeeping): within 15%.
        let spec = GpuSpec::v100();
        let tf = tensorflow(&paper_graph(), Policy::O1, &spec);
        let pt = pytorch(&paper_graph(), Policy::O1, &spec);
        let flops = |t: &FrameworkTrace| -> f64 {
            t.all()
                .iter()
                .map(|i| i.kernel.mix.total_flops(&spec) as f64 * i.invocations as f64)
                .sum()
        };
        let (f_tf, f_pt) = (flops(&tf), flops(&pt));
        let ratio = f_tf / f_pt;
        assert!((0.85..1.15).contains(&ratio), "tf {f_tf:.3e} pt {f_pt:.3e}");
    }

    #[test]
    fn lowering_is_device_aware() {
        // The device-registry refactor's guard: lowering takes the spec
        // from the caller, and the same graph lowers to *different*
        // kernel launch geometries on different devices (tile selection
        // follows L1 capacity; HMMA width follows the tensor-core
        // generation). A hidden `GpuSpec::v100()` inside `lower` would
        // make these asserts fail.
        let v100 = GpuSpec::v100();
        let t4 = GpuSpec::t4();
        let a100 = GpuSpec::a100();
        let on_v100 = pytorch(&paper_graph(), Policy::O1, &v100);
        let on_t4 = pytorch(&paper_graph(), Policy::O1, &t4);
        let on_a100 = pytorch(&paper_graph(), Policy::O1, &a100);

        // Same kernel census either way — the network didn't change.
        assert_eq!(on_v100.forward.len(), on_t4.forward.len());
        assert_eq!(on_v100.backward.len(), on_a100.backward.len());

        // T4's 64 KiB L1 halves the GEMM tile → more, smaller blocks.
        let grids = |t: &FrameworkTrace| -> Vec<u32> {
            t.forward.iter().map(|i| i.kernel.grid).collect()
        };
        assert_ne!(grids(&on_v100), grids(&on_t4), "tile selection must follow the device");

        // A100's wider HMMA (2048 FLOPs/inst vs 512) issues fewer
        // tensor instructions for the same FLOPs.
        let tc_insts = |t: &FrameworkTrace| -> u64 {
            t.all().iter().map(|i| i.kernel.mix.tensor_insts).sum()
        };
        assert!(
            tc_insts(&on_a100) < tc_insts(&on_v100),
            "a100 {} vs v100 {}",
            tc_insts(&on_a100),
            tc_insts(&on_v100)
        );
    }
}
