//! Named workload registry — the profiling *subjects* the scenario
//! matrix sweeps over.
//!
//! The paper profiles exactly one network (DeepCAM). The ROADMAP's
//! north star is "as many scenarios as you can imagine", so this module
//! turns the graph builders into a registry of named [`WorkloadSpec`]s
//! that every sweep/CLI surface resolves by name:
//!
//! * `deepcam-paper` — the published DeepLabv3+ configuration (§III-B);
//! * `deepcam-lite` — the AOT-twin scale used by the e2e example;
//! * `resnet` — a ResNet-style residual conv stack (image
//!   classification head), the canonical conv-heavy contrast case;
//! * `transformer` — a Transformer encoder block stack (Q/K/V
//!   projections, attention matmuls + softmax, FFN), the GEMM-heavy
//!   contrast case with eager transpose/copy traffic.
//!
//! Every workload builds at two scales: [`Scale::Full`] for paper-style
//! runs and [`Scale::Quick`] for CI smoke sweeps (same op census,
//! reduced tensor extents). Unknown names resolve to a clean
//! [`CliError`] with a did-you-mean hint.

use crate::cli::{hint, CliError};
use crate::dl::deepcam::{deepcam, DeepCamConfig};
use crate::dl::graph::{DType, Graph, TensorId, TensorShape};

/// Workload build scale.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Paper-style extents.
    Full,
    /// Reduced extents for smoke runs: identical op census, smaller
    /// tensors — kernel *population* is preserved, cost is not.
    Quick,
}

impl Scale {
    pub fn name(self) -> &'static str {
        match self {
            Scale::Full => "full",
            Scale::Quick => "quick",
        }
    }
}

/// One registry entry: a named forward-graph builder.
pub struct WorkloadSpec {
    pub name: &'static str,
    pub description: &'static str,
    builder: fn(Scale) -> Graph,
}

impl WorkloadSpec {
    /// Build the forward graph at the requested scale.
    pub fn build(&self, scale: Scale) -> Graph {
        (self.builder)(scale)
    }
}

impl std::fmt::Debug for WorkloadSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("WorkloadSpec").field("name", &self.name).finish()
    }
}

static REGISTRY: [WorkloadSpec; 4] = [
    WorkloadSpec {
        name: "deepcam-paper",
        description: "DeepCAM (DeepLabv3+) at the published configuration (quick: 192x288 tiles)",
        builder: build_deepcam_paper,
    },
    WorkloadSpec {
        name: "deepcam-lite",
        description: "DeepCAM at the AOT-compiled lite scale (python/compile twin)",
        builder: build_deepcam_lite,
    },
    WorkloadSpec {
        name: "resnet",
        description: "ResNet-style residual conv stack with a classification head",
        builder: build_resnet,
    },
    WorkloadSpec {
        name: "transformer",
        description: "Transformer encoder block stack (attention matmuls + FFN)",
        builder: build_transformer,
    },
];

/// All registered workloads, in registry (and matrix-enumeration) order.
pub fn registry() -> &'static [WorkloadSpec] {
    &REGISTRY
}

/// Registered workload names, in registry order.
pub fn names() -> Vec<&'static str> {
    REGISTRY.iter().map(|w| w.name).collect()
}

/// Resolve a workload by name; unknown names get a clean [`CliError`]
/// with a did-you-mean hint and the available set.
pub fn lookup(name: &str) -> Result<&'static WorkloadSpec, CliError> {
    if let Some(w) = REGISTRY.iter().find(|w| w.name == name) {
        return Ok(w);
    }
    let hint = hint(name, "", REGISTRY.iter().map(|w| w.name));
    Err(CliError(format!(
        "unknown workload '{name}'{hint}; available: {}",
        names().join(", ")
    )))
}

// ---------- builders ----------

fn build_deepcam_paper(scale: Scale) -> Graph {
    let mut cfg = DeepCamConfig::paper();
    if scale == Scale::Quick {
        // Same network structure and parameter census, 1/16th of the
        // spatial extent — quick sweeps keep the kernel population.
        cfg.height = 192;
        cfg.width = 288;
    }
    deepcam(&cfg)
}

fn build_deepcam_lite(_scale: Scale) -> Graph {
    // Already the smoke scale; identical at both scales by design (the
    // lite config is pinned to the AOT artifact manifest).
    deepcam(&DeepCamConfig::lite())
}

/// conv → BN → ReLU triple (shared by the ResNet builder).
fn conv_bn_relu(
    g: &mut Graph,
    name: &str,
    x: TensorId,
    cin: u64,
    cout: u64,
    k: u64,
    stride: u64,
) -> TensorId {
    let w = g.param(&format!("{name}_w"), TensorShape(vec![k, k, cin, cout]), DType::F32);
    let y = g.conv2d(&format!("{name}_conv"), x, w, stride, 1);
    let gamma = g.param(&format!("{name}_gamma"), TensorShape(vec![cout]), DType::F32);
    let beta = g.param(&format!("{name}_beta"), TensorShape(vec![cout]), DType::F32);
    let y = g.batch_norm(&format!("{name}_bn"), y, gamma, beta);
    g.relu(&format!("{name}_relu"), y)
}

/// ResNet-style stack: stem + strided stages of residual blocks +
/// global-average-pool classification head.
fn build_resnet(scale: Scale) -> Graph {
    let (batch, hw, stem_ch, stages, blocks, classes): (u64, u64, u64, &[u64], u64, u64) =
        match scale {
            Scale::Full => (8, 64, 64, &[64, 128, 256, 512], 2, 100),
            Scale::Quick => (2, 32, 16, &[16, 32, 64], 1, 10),
        };
    let mut g = Graph::new();
    let x = g.tensor("input", TensorShape::nhwc(batch, hw, hw, 3), DType::F32);
    let labels = g.tensor("labels", TensorShape::nhwc(batch, 1, 1, 1), DType::I32);

    let mut feats = conv_bn_relu(&mut g, "stem", x, 3, stem_ch, 3, 1);
    let mut cin = stem_ch;
    for (si, &ch) in stages.iter().enumerate() {
        feats = conv_bn_relu(&mut g, &format!("s{si}_down"), feats, cin, ch, 3, 2);
        for bi in 0..blocks {
            let name = format!("s{si}_b{bi}");
            let y = conv_bn_relu(&mut g, &format!("{name}_a"), feats, ch, ch, 3, 1);
            let w2 = g.param(&format!("{name}_b_w"), TensorShape(vec![3, 3, ch, ch]), DType::F32);
            let y2 = g.conv2d(&format!("{name}_b_conv"), y, w2, 1, 1);
            let gamma = g.param(&format!("{name}_b_gamma"), TensorShape(vec![ch]), DType::F32);
            let beta = g.param(&format!("{name}_b_beta"), TensorShape(vec![ch]), DType::F32);
            let y2 = g.batch_norm(&format!("{name}_b_bn"), y2, gamma, beta);
            let sum = g.add(&format!("{name}_add"), y2, feats);
            feats = g.relu(&format!("{name}_relu"), sum);
        }
        cin = ch;
    }

    let pooled = g.global_avg_pool("head_pool", feats);
    let wcls = g.param("head_w", TensorShape(vec![cin, classes]), DType::F32);
    let logits = g.matmul("head_fc", pooled, wcls);
    g.softmax_ce_loss("loss", logits, labels);
    g
}

/// Transformer encoder block stack over `[batch, seq, 1, d_model]`
/// activations: per layer Q/K/V projections, Q·Kᵀ scores, softmax,
/// attention apply (with an eager transpose copy), output projection,
/// residual + norm, then a two-matmul FFN with its own residual + norm.
fn build_transformer(scale: Scale) -> Graph {
    let (batch, seq, in_dim, d_model, d_ff, layers, classes): (u64, u64, u64, u64, u64, u64, u64) =
        match scale {
            Scale::Full => (8, 256, 64, 512, 2048, 2, 16),
            Scale::Quick => (2, 64, 32, 128, 256, 1, 8),
        };
    let mut g = Graph::new();
    let tokens = g.tensor("tokens", TensorShape::nhwc(batch, seq, 1, in_dim), DType::F32);
    let labels = g.tensor("labels", TensorShape::nhwc(batch, 1, 1, 1), DType::I32);

    let w_embed = g.param("embed_w", TensorShape(vec![in_dim, d_model]), DType::F32);
    let mut x = g.matmul("embed", tokens, w_embed);

    let norm = |g: &mut Graph, name: &str, x: TensorId, ch: u64| -> TensorId {
        let gamma = g.param(&format!("{name}_gamma"), TensorShape(vec![ch]), DType::F32);
        let beta = g.param(&format!("{name}_beta"), TensorShape(vec![ch]), DType::F32);
        g.batch_norm(name, x, gamma, beta)
    };

    for li in 0..layers {
        let p = format!("l{li}");
        let wq = g.param(&format!("{p}_wq"), TensorShape(vec![d_model, d_model]), DType::F32);
        let wk = g.param(&format!("{p}_wk"), TensorShape(vec![d_model, d_model]), DType::F32);
        let wv = g.param(&format!("{p}_wv"), TensorShape(vec![d_model, d_model]), DType::F32);
        let q = g.matmul(&format!("{p}_q"), x, wq);
        let k = g.matmul(&format!("{p}_k"), x, wk);
        let v = g.matmul(&format!("{p}_v"), x, wv);
        let scores = g.batched_matmul(&format!("{p}_scores"), q, k);
        let probs = g.softmax(&format!("{p}_attn_softmax"), scores);
        let vt = g.transpose_inner(&format!("{p}_v_transpose"), v);
        let ctx = g.batched_matmul(&format!("{p}_attn_apply"), probs, vt);
        let wo = g.param(&format!("{p}_wo"), TensorShape(vec![d_model, d_model]), DType::F32);
        let proj = g.matmul(&format!("{p}_out_proj"), ctx, wo);
        let res1 = g.add(&format!("{p}_residual1"), x, proj);
        let normed = norm(&mut g, &format!("{p}_norm1"), res1, d_model);

        let w1 = g.param(&format!("{p}_ffn_w1"), TensorShape(vec![d_model, d_ff]), DType::F32);
        let w2 = g.param(&format!("{p}_ffn_w2"), TensorShape(vec![d_ff, d_model]), DType::F32);
        let h = g.matmul(&format!("{p}_ffn1"), normed, w1);
        let h = g.relu(&format!("{p}_ffn_relu"), h);
        let h = g.matmul(&format!("{p}_ffn2"), h, w2);
        let res2 = g.add(&format!("{p}_residual2"), normed, h);
        x = norm(&mut g, &format!("{p}_norm2"), res2, d_model);
    }

    let pooled = g.global_avg_pool("head_pool", x);
    let wcls = g.param("head_w", TensorShape(vec![d_model, classes]), DType::F32);
    let logits = g.matmul("head_fc", pooled, wcls);
    g.softmax_ce_loss("loss", logits, labels);
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dl::graph::OpKind;
    use crate::dl::lower::{lower, Framework};
    use crate::dl::Policy;

    #[test]
    fn registry_names_unique_and_stable() {
        let mut ns = names();
        assert_eq!(ns, vec!["deepcam-paper", "deepcam-lite", "resnet", "transformer"]);
        ns.sort_unstable();
        ns.dedup();
        assert_eq!(ns.len(), REGISTRY.len());
    }

    #[test]
    fn lookup_finds_every_registered_name() {
        for w in registry() {
            assert_eq!(lookup(w.name).unwrap().name, w.name);
        }
    }

    #[test]
    fn unknown_workload_is_clean_cli_error_with_hint() {
        let err = lookup("resnet50").unwrap_err();
        assert!(err.0.contains("unknown workload 'resnet50'"), "{}", err.0);
        assert!(err.0.contains("did you mean 'resnet'?"), "{}", err.0);
        assert!(err.0.contains("available: deepcam-paper"), "{}", err.0);
        // Nothing-like-anything: no hint, but the available set prints.
        let err = lookup("qqqqq").unwrap_err();
        assert!(!err.0.contains("did you mean"), "{}", err.0);
        assert!(err.0.contains("available:"), "{}", err.0);
    }

    #[test]
    fn every_workload_builds_at_both_scales() {
        for w in registry() {
            for scale in [Scale::Full, Scale::Quick] {
                let g = w.build(scale);
                assert!(!g.ops.is_empty(), "{} {:?}", w.name, scale);
                assert!(g.total_flops() > 0, "{} {:?}", w.name, scale);
                assert!(g.n_param_elems() > 0, "{} {:?}", w.name, scale);
                // Every workload ends in the loss the autodiff seeds on.
                assert_eq!(g.ops.last().unwrap().kind, OpKind::CrossEntropyLoss);
            }
        }
    }

    #[test]
    fn quick_scale_is_cheaper_but_same_census() {
        for w in registry() {
            let full = w.build(Scale::Full);
            let quick = w.build(Scale::Quick);
            assert!(
                quick.total_flops() <= full.total_flops(),
                "{}: quick {} > full {}",
                w.name,
                quick.total_flops(),
                full.total_flops()
            );
        }
        // deepcam-paper quick preserves the exact op census.
        let full = lookup("deepcam-paper").unwrap().build(Scale::Full);
        let quick = lookup("deepcam-paper").unwrap().build(Scale::Quick);
        assert_eq!(full.ops.len(), quick.ops.len());
        assert_eq!(full.n_param_elems(), quick.n_param_elems());
    }

    #[test]
    fn resnet_is_conv_dominated() {
        let g = build_resnet(Scale::Quick);
        let conv_flops: u64 = g
            .ops
            .iter()
            .filter(|o| matches!(o.kind, OpKind::Conv2d { .. }))
            .map(|o| o.flops)
            .sum();
        assert!(conv_flops as f64 > 0.8 * g.total_flops() as f64);
    }

    #[test]
    fn transformer_is_matmul_dominated_with_zero_ai_transposes() {
        let g = build_transformer(Scale::Quick);
        let mm_flops: u64 = g
            .ops
            .iter()
            .filter(|o| o.kind == OpKind::MatMul)
            .map(|o| o.flops)
            .sum();
        assert!(mm_flops as f64 > 0.7 * g.total_flops() as f64);
        assert!(g.ops.iter().any(|o| o.kind == OpKind::Transpose));
        assert!(g.ops.iter().any(|o| o.kind == OpKind::Softmax));
    }

    #[test]
    fn new_workloads_lower_under_both_frameworks() {
        let spec = crate::device::GpuSpec::v100();
        for name in ["resnet", "transformer"] {
            let g = lookup(name).unwrap().build(Scale::Quick);
            for fw in Framework::ALL {
                let t = lower(&g, fw, Policy::O1, &spec);
                assert!(!t.forward.is_empty(), "{name}/{}", fw.name());
                assert!(!t.backward.is_empty(), "{name}/{}", fw.name());
            }
        }
    }
}
