//! Deep-learning framework execution model — the profiling *subject*.
//!
//! The paper profiles DeepCAM under two frameworks whose runtime
//! behaviour differs (kernel fusion, implicit zero-AI data-conversion
//! kernels, where the optimizer lives, tensor-core eligibility). This
//! module reconstructs that machinery:
//!
//! * [`graph`] — a framework-neutral operator IR with shape inference;
//! * [`deepcam`] — the DeepCAM network builder (DeepLabv3+: ResNet-style
//!   encoder, ASPP, nine-layer decoder with two skips) at paper scale
//!   and at the AOT "lite" scale;
//! * [`autodiff`] — backward-graph generation (gradient op per forward
//!   op) plus optimizer-op emission;
//! * [`amp`] — the Automatic Mixed Precision pass: O0/O1/O2 policies and
//!   the manual-FP16 variant (§IV-C), inserting cast ops and marking
//!   tensor-core eligibility;
//! * [`lower`] — framework personalities: TensorFlow-like and
//!   PyTorch-like lowering of an op graph to kernel traces
//!   ([`crate::sim::KernelInvocation`]), including each framework's
//!   characteristic zero-AI kernel population (§IV-D, Table III);
//! * [`workloads`] — the named workload registry (DeepCAM plus
//!   synthetic ResNet/Transformer contrast cases) that the scenario
//!   matrix ([`crate::scenario`]) sweeps over.

pub mod amp;
pub mod autodiff;
pub mod deepcam;
pub mod graph;
pub mod lower;
pub mod workloads;

pub use amp::Policy;
pub use graph::{DType, Graph, Op, OpKind, TensorShape};
pub use lower::{lower, Framework, FrameworkTrace, Phase};
pub use workloads::{Scale, WorkloadSpec};
