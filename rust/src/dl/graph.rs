//! Framework-neutral operator IR with shape inference.
//!
//! A [`Graph`] is a DAG of [`Op`]s over NHWC tensors. The builder
//! methods do shape inference and FLOP/byte accounting per op — the
//! numbers later charged to kernels by the framework lowerings.

use crate::device::Precision;

/// Tensor element types the frameworks juggle (AMP casts between them).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F16,
    F32,
    F64,
    I32,
}

impl DType {
    pub fn bytes(self) -> u64 {
        match self {
            DType::F16 => 2,
            DType::F32 | DType::I32 => 4,
            DType::F64 => 8,
        }
    }

    /// The SASS FP pipeline this dtype's math lands on.
    pub fn precision(self) -> Precision {
        match self {
            DType::F16 => Precision::Fp16,
            DType::F32 | DType::I32 => Precision::Fp32,
            DType::F64 => Precision::Fp64,
        }
    }
}

/// Dense NHWC (or arbitrary-rank) tensor shape.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TensorShape(pub Vec<u64>);

impl TensorShape {
    pub fn nhwc(n: u64, h: u64, w: u64, c: u64) -> TensorShape {
        TensorShape(vec![n, h, w, c])
    }

    pub fn n_elems(&self) -> u64 {
        self.0.iter().product()
    }

    pub fn bytes(&self, dt: DType) -> u64 {
        self.n_elems() * dt.bytes()
    }

    pub fn dim(&self, i: usize) -> u64 {
        self.0[i]
    }
}

/// Tensor id within a graph.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct TensorId(pub usize);

/// A graph tensor: shape + dtype + whether it is a trainable parameter.
#[derive(Clone, Debug)]
pub struct TensorInfo {
    pub shape: TensorShape,
    pub dtype: DType,
    pub is_param: bool,
    pub name: String,
}

/// Operator kinds. Forward ops are built by [`crate::dl::deepcam`];
/// `*Bwd` variants and `Optimizer*` are added by [`crate::dl::autodiff`];
/// `Cast`/`Transpose` mostly by [`crate::dl::amp`] and the lowerings.
#[derive(Clone, Debug, PartialEq)]
pub enum OpKind {
    Conv2d { kh: u64, kw: u64, stride: u64, dilation: u64 },
    ConvTranspose2d { kh: u64, kw: u64, stride: u64 },
    MatMul,
    BatchNorm,
    Relu,
    Add,
    Concat,
    GlobalAvgPool,
    Upsample { factor: u64 },
    Softmax,
    CrossEntropyLoss,
    /// Gradient of a conv w.r.t. its input (data grad).
    Conv2dBwdData { kh: u64, kw: u64, stride: u64, dilation: u64 },
    /// Gradient of a conv w.r.t. its filter (weight grad).
    Conv2dBwdFilter { kh: u64, kw: u64, stride: u64, dilation: u64 },
    MatMulBwd,
    BatchNormBwd,
    ReluBwd,
    SoftmaxCrossEntropyBwd,
    /// SGD-momentum parameter update (one per parameter tensor).
    OptimizerUpdate,
    /// Pure data movement (zero-AI by construction, §IV-D).
    Cast { to: DType },
    Transpose,
    Memset,
    HostCopy,
}

impl OpKind {
    /// Whether the op performs no floating-point work (zero-AI class).
    pub fn is_zero_ai(&self) -> bool {
        matches!(
            self,
            OpKind::Cast { .. } | OpKind::Transpose | OpKind::Memset | OpKind::HostCopy
                | OpKind::Concat
                | OpKind::Upsample { .. }
        )
    }

    /// Whether a GEMM-shaped MXU/tensor-core implementation exists.
    pub fn is_tensor_core_eligible(&self) -> bool {
        matches!(
            self,
            OpKind::Conv2d { .. }
                | OpKind::ConvTranspose2d { .. }
                | OpKind::MatMul
                | OpKind::Conv2dBwdData { .. }
                | OpKind::Conv2dBwdFilter { .. }
                | OpKind::MatMulBwd
        )
    }
}

/// One operator instance.
#[derive(Clone, Debug)]
pub struct Op {
    pub id: usize,
    pub name: String,
    pub kind: OpKind,
    pub inputs: Vec<TensorId>,
    pub output: TensorId,
    /// Compute dtype (AMP may differ from tensor storage dtype).
    pub compute_dtype: DType,
    /// FLOPs this op performs per execution.
    pub flops: u64,
}

/// The operator DAG.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    pub tensors: Vec<TensorInfo>,
    pub ops: Vec<Op>,
}

impl Graph {
    pub fn new() -> Graph {
        Graph::default()
    }

    pub fn tensor(&mut self, name: &str, shape: TensorShape, dtype: DType) -> TensorId {
        self.tensors.push(TensorInfo {
            shape,
            dtype,
            is_param: false,
            name: name.to_string(),
        });
        TensorId(self.tensors.len() - 1)
    }

    pub fn param(&mut self, name: &str, shape: TensorShape, dtype: DType) -> TensorId {
        let id = self.tensor(name, shape, dtype);
        self.tensors[id.0].is_param = true;
        id
    }

    pub fn shape(&self, t: TensorId) -> &TensorShape {
        &self.tensors[t.0].shape
    }

    pub fn dtype(&self, t: TensorId) -> DType {
        self.tensors[t.0].dtype
    }

    pub fn params(&self) -> Vec<TensorId> {
        (0..self.tensors.len())
            .filter(|&i| self.tensors[i].is_param)
            .map(TensorId)
            .collect()
    }

    fn push_op(
        &mut self,
        name: &str,
        kind: OpKind,
        inputs: Vec<TensorId>,
        out_shape: TensorShape,
        out_dtype: DType,
        flops: u64,
    ) -> TensorId {
        let output = self.tensor(&format!("{name}_out"), out_shape, out_dtype);
        self.ops.push(Op {
            id: self.ops.len(),
            name: name.to_string(),
            kind,
            inputs,
            output,
            compute_dtype: out_dtype,
            flops,
        });
        output
    }

    // ---------- builder ops with shape inference ----------

    /// SAME-padded conv, NHWC x HWIO.
    pub fn conv2d(
        &mut self,
        name: &str,
        x: TensorId,
        w: TensorId,
        stride: u64,
        dilation: u64,
    ) -> TensorId {
        let xs = self.shape(x).clone();
        let ws = self.shape(w).clone();
        let (n, h, wd) = (xs.dim(0), xs.dim(1), xs.dim(2));
        let (kh, kw, cin, cout) = (ws.dim(0), ws.dim(1), ws.dim(2), ws.dim(3));
        assert_eq!(xs.dim(3), cin, "conv {name}: channel mismatch");
        let (oh, ow) = (h.div_ceil(stride), wd.div_ceil(stride));
        let flops = 2 * n * oh * ow * kh * kw * cin * cout;
        self.push_op(
            name,
            OpKind::Conv2d { kh, kw, stride, dilation },
            vec![x, w],
            TensorShape::nhwc(n, oh, ow, cout),
            self.dtype(x),
            flops,
        )
    }

    /// Transposed conv (x2 upsampling decoder layers).
    pub fn conv2d_transpose(
        &mut self,
        name: &str,
        x: TensorId,
        w: TensorId,
        stride: u64,
    ) -> TensorId {
        let xs = self.shape(x).clone();
        let ws = self.shape(w).clone();
        let (n, h, wd) = (xs.dim(0), xs.dim(1), xs.dim(2));
        let (kh, kw, cin, cout) = (ws.dim(0), ws.dim(1), ws.dim(2), ws.dim(3));
        assert_eq!(xs.dim(3), cin, "deconv {name}: channel mismatch");
        let (oh, ow) = (h * stride, wd * stride);
        let flops = 2 * n * oh * ow * kh * kw * cin * cout;
        self.push_op(
            name,
            OpKind::ConvTranspose2d { kh, kw, stride },
            vec![x, w],
            TensorShape::nhwc(n, oh, ow, cout),
            self.dtype(x),
            flops,
        )
    }

    pub fn batch_norm(
        &mut self,
        name: &str,
        x: TensorId,
        gamma: TensorId,
        beta: TensorId,
    ) -> TensorId {
        let xs = self.shape(x).clone();
        // ~10 FLOPs/element: stats + normalize + affine.
        let flops = 10 * xs.n_elems();
        let dt = self.dtype(x);
        self.push_op(name, OpKind::BatchNorm, vec![x, gamma, beta], xs, dt, flops)
    }

    pub fn relu(&mut self, name: &str, x: TensorId) -> TensorId {
        let xs = self.shape(x).clone();
        let flops = xs.n_elems();
        let dt = self.dtype(x);
        self.push_op(name, OpKind::Relu, vec![x], xs, dt, flops)
    }

    pub fn add(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let xs = self.shape(a).clone();
        assert_eq!(xs, *self.shape(b), "add {name}: shape mismatch");
        let flops = xs.n_elems();
        let dt = self.dtype(a);
        self.push_op(name, OpKind::Add, vec![a, b], xs, dt, flops)
    }

    pub fn concat(&mut self, name: &str, xs_in: &[TensorId]) -> TensorId {
        let first = self.shape(xs_in[0]).clone();
        let c: u64 = xs_in.iter().map(|&t| self.shape(t).dim(3)).sum();
        let dt = self.dtype(xs_in[0]);
        self.push_op(
            name,
            OpKind::Concat,
            xs_in.to_vec(),
            TensorShape::nhwc(first.dim(0), first.dim(1), first.dim(2), c),
            dt,
            0,
        )
    }

    pub fn global_avg_pool(&mut self, name: &str, x: TensorId) -> TensorId {
        let xs = self.shape(x).clone();
        let flops = xs.n_elems();
        let dt = self.dtype(x);
        self.push_op(
            name,
            OpKind::GlobalAvgPool,
            vec![x],
            TensorShape::nhwc(xs.dim(0), 1, 1, xs.dim(3)),
            dt,
            flops,
        )
    }

    pub fn upsample(&mut self, name: &str, x: TensorId, factor: u64) -> TensorId {
        let xs = self.shape(x).clone();
        let dt = self.dtype(x);
        self.push_op(
            name,
            OpKind::Upsample { factor },
            vec![x],
            TensorShape::nhwc(xs.dim(0), xs.dim(1) * factor, xs.dim(2) * factor, xs.dim(3)),
            dt,
            0,
        )
    }

    pub fn softmax_ce_loss(&mut self, name: &str, logits: TensorId, labels: TensorId) -> TensorId {
        let xs = self.shape(logits).clone();
        // softmax + log + weighted reduce ≈ 8 FLOPs/element.
        let flops = 8 * xs.n_elems();
        self.push_op(
            name,
            OpKind::CrossEntropyLoss,
            vec![logits, labels],
            TensorShape(vec![1]),
            DType::F32,
            flops,
        )
    }

    pub fn cast(&mut self, name: &str, x: TensorId, to: DType) -> TensorId {
        let xs = self.shape(x).clone();
        self.push_op(name, OpKind::Cast { to }, vec![x], xs, to, 0)
    }

    /// Dense projection: `x @ w` contracting `x`'s innermost axis with a
    /// rank-2 weight `[k, n]`. Works for any `x` rank ≥ 1 (the leading
    /// axes are the batched row space) — the shape Transformer Q/K/V,
    /// output and FFN projections take.
    pub fn matmul(&mut self, name: &str, x: TensorId, w: TensorId) -> TensorId {
        let xs = self.shape(x).clone();
        let ws = self.shape(w).clone();
        assert_eq!(ws.0.len(), 2, "matmul {name}: weight must be rank-2 [k, n]");
        let (k, n) = (ws.dim(0), ws.dim(1));
        let last = *xs.0.last().expect("matmul input needs at least one axis");
        assert_eq!(last, k, "matmul {name}: contraction mismatch");
        let rows = xs.n_elems() / k;
        let mut out = xs.0.clone();
        *out.last_mut().unwrap() = n;
        let flops = 2 * rows * k * n;
        let dt = self.dtype(x);
        self.push_op(name, OpKind::MatMul, vec![x, w], TensorShape(out), dt, flops)
    }

    /// Batched activation-by-activation matmul `a · bᵀ` contracting the
    /// innermost axis: `a = [B, M, 1, K]` × `b = [B, N, 1, K]` →
    /// `[B, M, 1, N]`. This is the attention-score / attention-apply
    /// shape (Q·Kᵀ and P·V once V is transposed).
    pub fn batched_matmul(&mut self, name: &str, a: TensorId, b: TensorId) -> TensorId {
        let as_ = self.shape(a).clone();
        let bs = self.shape(b).clone();
        assert_eq!(as_.0.len(), 4, "batched_matmul {name}: lhs must be rank-4");
        assert_eq!(bs.0.len(), 4, "batched_matmul {name}: rhs must be rank-4");
        assert_eq!(as_.dim(0), bs.dim(0), "batched_matmul {name}: batch mismatch");
        assert_eq!(as_.dim(3), bs.dim(3), "batched_matmul {name}: contraction mismatch");
        let (batch, m, n, k) = (as_.dim(0), as_.dim(1), bs.dim(1), as_.dim(3));
        let flops = 2 * batch * m * n * k;
        let dt = self.dtype(a);
        self.push_op(
            name,
            OpKind::MatMul,
            vec![a, b],
            TensorShape::nhwc(batch, m, 1, n),
            dt,
            flops,
        )
    }

    /// Swap the row/innermost axes of a `[B, M, 1, N]` activation —
    /// pure data movement (zero-AI), like an eager `.transpose()` copy.
    pub fn transpose_inner(&mut self, name: &str, x: TensorId) -> TensorId {
        let xs = self.shape(x).clone();
        assert_eq!(xs.0.len(), 4, "transpose {name}: needs rank-4");
        let dt = self.dtype(x);
        self.push_op(
            name,
            OpKind::Transpose,
            vec![x],
            TensorShape::nhwc(xs.dim(0), xs.dim(3), xs.dim(2), xs.dim(1)),
            dt,
            0,
        )
    }

    /// Row-wise softmax over the innermost axis (attention weights):
    /// exp + reduce + normalize ≈ 5 FLOPs/element.
    pub fn softmax(&mut self, name: &str, x: TensorId) -> TensorId {
        let xs = self.shape(x).clone();
        let flops = 5 * xs.n_elems();
        let dt = self.dtype(x);
        self.push_op(name, OpKind::Softmax, vec![x], xs, dt, flops)
    }

    // ---------- whole-graph accounting ----------

    /// Total forward FLOPs.
    pub fn total_flops(&self) -> u64 {
        self.ops.iter().map(|o| o.flops).sum()
    }

    /// Count of ops by zero-AI class.
    pub fn zero_ai_op_count(&self) -> (usize, usize) {
        let zero = self.ops.iter().filter(|o| o.kind.is_zero_ai()).count();
        (zero, self.ops.len())
    }

    /// Total parameter scalars.
    pub fn n_param_elems(&self) -> u64 {
        self.params().iter().map(|&p| self.shape(p).n_elems()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_graph() -> (Graph, TensorId) {
        let mut g = Graph::new();
        let x = g.tensor("x", TensorShape::nhwc(2, 8, 8, 3), DType::F32);
        let w = g.param("w", TensorShape(vec![3, 3, 3, 16]), DType::F32);
        let y = g.conv2d("conv", x, w, 1, 1);
        (g, y)
    }

    #[test]
    fn conv_shape_inference_same_padding() {
        let (g, y) = tiny_graph();
        assert_eq!(g.shape(y), &TensorShape::nhwc(2, 8, 8, 16));
        // stride 2
        let mut g2 = Graph::new();
        let x = g2.tensor("x", TensorShape::nhwc(1, 9, 9, 3), DType::F32);
        let w = g2.param("w", TensorShape(vec![3, 3, 3, 4]), DType::F32);
        let y = g2.conv2d("c", x, w, 2, 1);
        assert_eq!(g2.shape(y), &TensorShape::nhwc(1, 5, 5, 4));
    }

    #[test]
    fn conv_flops_formula() {
        let (g, _) = tiny_graph();
        // 2 * N*OH*OW*KH*KW*Cin*Cout
        assert_eq!(g.ops[0].flops, 2 * 2 * 8 * 8 * 3 * 3 * 3 * 16);
    }

    #[test]
    fn deconv_doubles_spatial() {
        let mut g = Graph::new();
        let x = g.tensor("x", TensorShape::nhwc(1, 4, 4, 8), DType::F32);
        let w = g.param("w", TensorShape(vec![3, 3, 8, 4]), DType::F32);
        let y = g.conv2d_transpose("d", x, w, 2);
        assert_eq!(g.shape(y), &TensorShape::nhwc(1, 8, 8, 4));
    }

    #[test]
    fn concat_sums_channels() {
        let mut g = Graph::new();
        let a = g.tensor("a", TensorShape::nhwc(1, 4, 4, 3), DType::F32);
        let b = g.tensor("b", TensorShape::nhwc(1, 4, 4, 5), DType::F32);
        let y = g.concat("cat", &[a, b]);
        assert_eq!(g.shape(y).dim(3), 8);
        assert!(g.ops.last().unwrap().kind.is_zero_ai());
    }

    #[test]
    fn zero_ai_classification() {
        assert!(OpKind::Cast { to: DType::F16 }.is_zero_ai());
        assert!(OpKind::Transpose.is_zero_ai());
        assert!(!OpKind::Relu.is_zero_ai());
        assert!(!OpKind::Conv2d { kh: 3, kw: 3, stride: 1, dilation: 1 }.is_zero_ai());
    }

    #[test]
    fn tc_eligibility() {
        assert!(OpKind::MatMul.is_tensor_core_eligible());
        assert!(OpKind::Conv2dBwdFilter { kh: 3, kw: 3, stride: 1, dilation: 1 }
            .is_tensor_core_eligible());
        assert!(!OpKind::BatchNorm.is_tensor_core_eligible());
        assert!(!OpKind::OptimizerUpdate.is_tensor_core_eligible());
    }

    #[test]
    fn param_accounting() {
        let (g, _) = tiny_graph();
        assert_eq!(g.params().len(), 1);
        assert_eq!(g.n_param_elems(), 3 * 3 * 3 * 16);
    }

    #[test]
    fn matmul_shape_and_flops() {
        let mut g = Graph::new();
        let x = g.tensor("x", TensorShape::nhwc(2, 16, 1, 32), DType::F32);
        let w = g.param("w", TensorShape(vec![32, 64]), DType::F32);
        let y = g.matmul("proj", x, w);
        assert_eq!(g.shape(y), &TensorShape::nhwc(2, 16, 1, 64));
        // 2 * rows * k * n, rows = 2*16*1.
        assert_eq!(g.ops[0].flops, 2 * 32 * 32 * 64);
        assert!(g.ops[0].kind.is_tensor_core_eligible());
    }

    #[test]
    fn batched_matmul_is_attention_shaped() {
        let mut g = Graph::new();
        let q = g.tensor("q", TensorShape::nhwc(2, 8, 1, 32), DType::F32);
        let k = g.tensor("k", TensorShape::nhwc(2, 8, 1, 32), DType::F32);
        let s = g.batched_matmul("scores", q, k);
        assert_eq!(g.shape(s), &TensorShape::nhwc(2, 8, 1, 8));
        assert_eq!(g.ops[0].flops, 2 * 2 * 8 * 8 * 32);
    }

    #[test]
    fn transpose_swaps_axes_and_is_zero_ai() {
        let mut g = Graph::new();
        let v = g.tensor("v", TensorShape::nhwc(2, 8, 1, 32), DType::F32);
        let vt = g.transpose_inner("vt", v);
        assert_eq!(g.shape(vt), &TensorShape::nhwc(2, 32, 1, 8));
        assert!(g.ops[0].kind.is_zero_ai());
        assert_eq!(g.ops[0].flops, 0);
    }

    #[test]
    fn softmax_preserves_shape() {
        let mut g = Graph::new();
        let s = g.tensor("s", TensorShape::nhwc(2, 8, 1, 8), DType::F32);
        let p = g.softmax("attn", s);
        assert_eq!(g.shape(p), g.shape(s));
        assert_eq!(g.ops[0].flops, 5 * 2 * 8 * 8);
    }

    #[test]
    #[should_panic(expected = "contraction mismatch")]
    fn matmul_contraction_mismatch_panics() {
        let mut g = Graph::new();
        let x = g.tensor("x", TensorShape::nhwc(1, 4, 1, 8), DType::F32);
        let w = g.param("w", TensorShape(vec![16, 4]), DType::F32);
        g.matmul("bad", x, w);
    }

    #[test]
    #[should_panic(expected = "channel mismatch")]
    fn conv_channel_mismatch_panics() {
        let mut g = Graph::new();
        let x = g.tensor("x", TensorShape::nhwc(1, 4, 4, 3), DType::F32);
        let w = g.param("w", TensorShape(vec![3, 3, 7, 4]), DType::F32);
        g.conv2d("bad", x, w, 1, 1);
    }
}
