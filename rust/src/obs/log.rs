//! Leveled stderr logging: the controllable replacement for the bare
//! `eprintln!` chatter the coordinator used to emit.
//!
//! The level is a process-wide atomic. The **library default is
//! [`Level::Silent`]** so `cargo test` output stays clean; the `repro`
//! binary raises it at startup ([`init`]): [`Level::Warn`] by default,
//! overridden by the `HROOFLINE_LOG` environment variable
//! (`silent|error|warn|info|debug`), overridden in turn by the
//! `--quiet` (→ [`Level::Error`]) and `-v`/`--verbose`
//! (→ [`Level::Debug`]) global flags — an explicit flag beats an
//! ambient env var. Messages print verbatim (no prefix), so existing
//! grep-based CI gates keep matching.

use std::sync::atomic::{AtomicU8, Ordering};

/// Log severities, in ascending verbosity. A message prints when its
/// level is at or below the configured level.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    /// Nothing prints (the library default — test-silent).
    Silent = 0,
    /// Failures the user must see even under `--quiet`.
    Error = 1,
    /// Degraded-but-continuing conditions (the binary's default).
    Warn = 2,
    Info = 3,
    Debug = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Silent as u8);

/// Set the process-wide log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// The current process-wide log level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Silent,
        1 => Level::Error,
        2 => Level::Warn,
        3 => Level::Info,
        _ => Level::Debug,
    }
}

/// Whether a message at `at` would print.
pub fn enabled(at: Level) -> bool {
    at != Level::Silent && at <= level()
}

/// Parse a level name (`HROOFLINE_LOG` syntax). `quiet` is accepted as
/// an alias for `error` to match the `--quiet` flag.
pub fn parse_level(s: &str) -> Option<Level> {
    match s.trim().to_ascii_lowercase().as_str() {
        "silent" | "off" | "none" => Some(Level::Silent),
        "error" | "quiet" => Some(Level::Error),
        "warn" | "warning" => Some(Level::Warn),
        "info" => Some(Level::Info),
        "debug" | "verbose" => Some(Level::Debug),
        _ => None,
    }
}

/// Binary startup: set `default`, letting `HROOFLINE_LOG` override it.
/// Returns the level that took effect (before any `--quiet`/`-v`).
pub fn init(default: Level) -> Level {
    let level = std::env::var("HROOFLINE_LOG")
        .ok()
        .and_then(|v| parse_level(&v))
        .unwrap_or(default);
    set_level(level);
    level
}

fn emit(at: Level, msg: &str) {
    if enabled(at) {
        eprintln!("{msg}");
    }
}

/// Print at [`Level::Error`] (survives `--quiet`).
pub fn error(msg: impl AsRef<str>) {
    emit(Level::Error, msg.as_ref());
}

/// Print at [`Level::Warn`].
pub fn warn(msg: impl AsRef<str>) {
    emit(Level::Warn, msg.as_ref());
}

/// Print at [`Level::Info`].
pub fn info(msg: impl AsRef<str>) {
    emit(Level::Info, msg.as_ref());
}

/// Print at [`Level::Debug`] (needs `-v`).
pub fn debug(msg: impl AsRef<str>) {
    emit(Level::Debug, msg.as_ref());
}

#[cfg(test)]
mod tests {
    use super::*;

    // NOTE: the level is process-global, so this single test covers all
    // the threshold arithmetic without racing parallel test threads
    // against a mutated level.
    #[test]
    fn threshold_logic_and_parsing() {
        assert_eq!(level(), Level::Silent, "library default is silent");
        assert!(!enabled(Level::Error), "silent mutes even errors");
        assert!(!enabled(Level::Silent), "Silent is never an emit level");

        assert_eq!(parse_level("warn"), Some(Level::Warn));
        assert_eq!(parse_level("WARNING"), Some(Level::Warn));
        assert_eq!(parse_level("quiet"), Some(Level::Error));
        assert_eq!(parse_level("verbose"), Some(Level::Debug));
        assert_eq!(parse_level("off"), Some(Level::Silent));
        assert_eq!(parse_level("nope"), None);

        assert!(Level::Error < Level::Warn && Level::Warn < Level::Debug);
    }
}
