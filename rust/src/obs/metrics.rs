//! Named counters and fixed-bucket duration histograms, snapshotted
//! into `run.metrics.json`.
//!
//! A [`MetricsRegistry`] is instantiable — the scenario matrix records
//! each run's cache traffic into a run-local registry (so parallel
//! tests never cross-pollinate) and merges it into the caller's
//! registry afterwards — while [`MetricsRegistry::global`] gives the
//! CLI one process-wide sink that also collects cross-cutting counters
//! like bytes-per-artifact-lane from [`crate::report::Artifact`].
//!
//! Counter catalog (the README "Observability" section keeps the
//! user-facing copy of this list):
//!
//! | counter | incremented by |
//! |---|---|
//! | `store.hits` / `store.misses` / `store.evictions` | matrix cell-store probes |
//! | `store.bytes_written` | committed cell-store entries |
//! | `matrix.cells.replayed` / `matrix.cells.ran` / `matrix.cells.failed` | matrix cell outcomes |
//! | `sim.kernels.simulated` / `sim.kernels.deduped` | session baseline dedup |
//! | `exec.retries` | supervised attempts beyond the first |
//! | `artifact.bytes.<lane>` | [`crate::report::Artifact::write_all`] |
//!
//! Histograms (`exec.queue_wait_s`, `exec.run_s`) use the fixed
//! log-spaced bounds in [`DURATION_BUCKETS_S`] plus an overflow bucket,
//! so snapshots from different runs merge bucket-for-bucket.

use std::collections::BTreeMap;
use std::sync::{Mutex, OnceLock};

use crate::util::json::Json;

/// Snapshot-format version, stamped into `run.metrics.json`.
pub const METRICS_SCHEMA: &str = "hroofline-metrics-v1";

/// Upper bounds (seconds) of the duration histogram buckets; every
/// histogram gets one extra overflow bucket on top (serialized with a
/// `null` bound, JSON's spelling of +inf).
pub const DURATION_BUCKETS_S: [f64; 7] = [1e-4, 1e-3, 1e-2, 1e-1, 1.0, 10.0, 60.0];

const N_BUCKETS: usize = DURATION_BUCKETS_S.len() + 1;

#[derive(Clone, Debug, Default)]
struct Hist {
    counts: [u64; N_BUCKETS],
    count: u64,
    sum_s: f64,
}

impl Hist {
    fn observe(&mut self, seconds: f64) {
        let idx = DURATION_BUCKETS_S
            .iter()
            .position(|&le| seconds <= le)
            .unwrap_or(N_BUCKETS - 1);
        self.counts[idx] += 1;
        self.count += 1;
        self.sum_s += seconds.max(0.0);
    }

    fn merge(&mut self, other: &Hist) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum_s += other.sum_s;
    }
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Hist>,
}

/// A thread-safe sink of named counters and duration histograms.
#[derive(Default)]
pub struct MetricsRegistry {
    inner: Mutex<Inner>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// The process-wide registry. Library code takes a registry by
    /// reference; only the `repro` binary (and cross-cutting sinks like
    /// artifact byte counters) reach for the global.
    pub fn global() -> &'static MetricsRegistry {
        static GLOBAL: OnceLock<MetricsRegistry> = OnceLock::new();
        GLOBAL.get_or_init(MetricsRegistry::new)
    }

    /// Add `n` to a counter (creating it at 0).
    pub fn add(&self, name: &str, n: u64) {
        let mut inner = self.inner.lock().unwrap();
        *inner.counters.entry(name.to_string()).or_insert(0) += n;
    }

    /// Increment a counter by 1.
    pub fn incr(&self, name: &str) {
        self.add(name, 1);
    }

    /// Record one duration observation into a histogram.
    pub fn observe_s(&self, name: &str, seconds: f64) {
        let mut inner = self.inner.lock().unwrap();
        inner.histograms.entry(name.to_string()).or_default().observe(seconds);
    }

    /// Current value of a counter (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().counters.get(name).copied().unwrap_or(0)
    }

    /// Fold this registry's contents into `other` (counters add,
    /// histograms merge bucket-for-bucket). Self is left untouched.
    pub fn merge_into(&self, other: &MetricsRegistry) {
        if std::ptr::eq(self, other) {
            return;
        }
        let inner = self.inner.lock().unwrap();
        let mut dst = other.inner.lock().unwrap();
        for (k, v) in &inner.counters {
            *dst.counters.entry(k.clone()).or_insert(0) += v;
        }
        for (k, h) in &inner.histograms {
            dst.histograms.entry(k.clone()).or_default().merge(h);
        }
    }

    /// An immutable copy of the current state.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            counters: inner.counters.clone(),
            histograms: inner
                .histograms
                .iter()
                .map(|(k, h)| {
                    let buckets = DURATION_BUCKETS_S
                        .iter()
                        .copied()
                        .chain([f64::INFINITY])
                        .zip(h.counts.iter().copied())
                        .collect();
                    (
                        k.clone(),
                        HistogramSnapshot { count: h.count, sum_s: h.sum_s, buckets },
                    )
                })
                .collect(),
        }
    }
}

/// A frozen histogram: total count, summed seconds, and per-bucket
/// counts keyed by upper bound (the last bound is `+inf`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_s: f64,
    pub buckets: Vec<(f64, u64)>,
}

/// A frozen registry, as embedded in [`crate::scenario::MatrixRun`] and
/// serialized to `run.metrics.json`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub counters: BTreeMap<String, u64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
}

impl MetricsSnapshot {
    /// Counter value at snapshot time (0 when never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.get(name).copied().unwrap_or(0)
    }

    pub fn is_empty(&self) -> bool {
        self.counters.is_empty() && self.histograms.is_empty()
    }

    /// The `run.metrics.json` document ([`METRICS_SCHEMA`]). Overflow
    /// bucket bounds serialize as `null` (JSON has no infinity).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                .collect(),
        );
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    let buckets = h.buckets.iter().map(|&(le, n)| {
                        Json::obj(vec![("le_s", Json::num(le)), ("n", Json::num(n as f64))])
                    });
                    (
                        k.clone(),
                        Json::obj(vec![
                            ("buckets", Json::arr(buckets)),
                            ("count", Json::num(h.count as f64)),
                            ("sum_s", Json::num(h.sum_s)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("counters", counters),
            ("histograms", histograms),
            ("schema", Json::str(METRICS_SCHEMA)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_default_to_zero() {
        let m = MetricsRegistry::new();
        assert_eq!(m.counter("store.hits"), 0);
        m.incr("store.hits");
        m.add("store.hits", 4);
        assert_eq!(m.counter("store.hits"), 5);
        assert_eq!(m.snapshot().counter("store.hits"), 5);
        assert_eq!(m.snapshot().counter("store.misses"), 0);
    }

    #[test]
    fn histogram_buckets_are_fixed_and_cumulative_by_merge() {
        let m = MetricsRegistry::new();
        m.observe_s("exec.run_s", 5e-5); // first bucket (<= 1e-4)
        m.observe_s("exec.run_s", 0.5); // <= 1.0
        m.observe_s("exec.run_s", 1e6); // overflow
        let snap = m.snapshot();
        let h = &snap.histograms["exec.run_s"];
        assert_eq!(h.count, 3);
        assert_eq!(h.buckets.len(), DURATION_BUCKETS_S.len() + 1);
        assert_eq!(h.buckets[0], (1e-4, 1));
        assert_eq!(h.buckets[4], (1.0, 1));
        let (last_le, last_n) = h.buckets[h.buckets.len() - 1];
        assert!(last_le.is_infinite());
        assert_eq!(last_n, 1);

        let dst = MetricsRegistry::new();
        dst.observe_s("exec.run_s", 0.5);
        m.merge_into(&dst);
        let merged = dst.snapshot();
        assert_eq!(merged.histograms["exec.run_s"].count, 4);
        assert_eq!(merged.histograms["exec.run_s"].buckets[4].1, 2);
    }

    #[test]
    fn merge_into_adds_counters_and_self_merge_is_a_noop() {
        let a = MetricsRegistry::new();
        a.add("x", 2);
        let b = MetricsRegistry::new();
        b.add("x", 3);
        b.add("y", 1);
        a.merge_into(&b);
        assert_eq!(b.counter("x"), 5);
        assert_eq!(b.counter("y"), 1);
        assert_eq!(a.counter("x"), 2, "source untouched");
        a.merge_into(&a);
        assert_eq!(a.counter("x"), 2, "self-merge must not deadlock or double");
    }

    #[test]
    fn snapshot_json_is_versioned_and_parses() {
        let m = MetricsRegistry::new();
        m.add("store.hits", 7);
        m.observe_s("exec.queue_wait_s", 0.002);
        let doc = m.snapshot().to_json();
        let text = doc.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("schema").unwrap().as_str().unwrap(), METRICS_SCHEMA);
        assert_eq!(
            back.get("counters").unwrap().get("store.hits").unwrap().as_usize().unwrap(),
            7
        );
        let h = back.get("histograms").unwrap().get("exec.queue_wait_s").unwrap();
        assert_eq!(h.get("count").unwrap().as_usize().unwrap(), 1);
        // The overflow bound serializes as null.
        let buckets = h.get("buckets").unwrap().as_arr().unwrap();
        assert_eq!(buckets.last().unwrap().get("le_s").unwrap(), &Json::Null);
    }

    #[test]
    fn empty_snapshot_is_empty() {
        assert!(MetricsRegistry::new().snapshot().is_empty());
        let m = MetricsRegistry::new();
        m.incr("z");
        assert!(!m.snapshot().is_empty());
    }

    #[test]
    fn global_registry_is_a_singleton() {
        let a = MetricsRegistry::global();
        let b = MetricsRegistry::global();
        assert!(std::ptr::eq(a, b));
    }
}
