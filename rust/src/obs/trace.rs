//! Span-based structured tracing with a versioned JSONL event log.
//!
//! A [`Tracer`] owns a clock and a thread-safe span sink. Instrumented
//! code opens RAII [`Span`] guards ([`Tracer::span`] for roots,
//! [`Span::child`] for nesting — explicit parenting, so spans cross
//! thread boundaries without thread-local state), annotates them with
//! string fields ([`Span::set`]), and lets scope exit stamp the
//! duration. A disabled tracer ([`Tracer::disabled`]) makes every one
//! of those operations a no-op `Option` check, which is how the
//! untraced pipeline keeps its perf profile.
//!
//! Serialized form ([`TRACE_SCHEMA`], one JSON object per line via
//! [`crate::util::json::Json`]):
//!
//! ```text
//! {"clock":"monotonic-us","schema":"hroofline-trace-v1","spans":3}
//! {"dur_us":120,"fields":{},"id":1,"name":"matrix","parent":null,"start_us":0}
//! {"dur_us":60,"fields":{"label":"cell#0:..."},"id":2,"name":"cell","parent":1,"start_us":10}
//! ```
//!
//! Spans are emitted sorted by id, so a serial run under the
//! deterministic [`Clock::Fixed`] test clock produces byte-identical
//! traces across reruns (pinned by `rust/tests/trace_semantics.rs`).
//! [`Trace::parse_jsonl`] reads the format back for `repro trace
//! report` and the well-formedness suite.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;

/// Trace-format version, stamped into the JSONL header line.
pub const TRACE_SCHEMA: &str = "hroofline-trace-v1";

/// Timestamp source for span start/duration stamps.
#[derive(Debug)]
pub enum Clock {
    /// Microseconds elapsed since the tracer was created (production).
    Monotonic(Instant),
    /// A deterministic tick counter: every read returns the next
    /// integer. Tests inject this so trace bytes are reproducible.
    Fixed(AtomicU64),
}

impl Clock {
    fn now_us(&self) -> u64 {
        match self {
            Clock::Monotonic(epoch) => epoch.elapsed().as_micros() as u64,
            Clock::Fixed(tick) => tick.fetch_add(1, Ordering::Relaxed),
        }
    }

    /// The header's `clock` stamp (readers must not compare durations
    /// across clock kinds).
    fn label(&self) -> &'static str {
        match self {
            Clock::Monotonic(_) => "monotonic-us",
            Clock::Fixed(_) => "fixed-tick",
        }
    }
}

/// One finished span, as collected and as parsed back from JSONL.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanRecord {
    /// 1-based, unique within a trace (0 never occurs).
    pub id: u64,
    /// Parent span id; `None` for roots.
    pub parent: Option<u64>,
    pub name: String,
    /// Ordered key/value annotations; duplicate keys collapse
    /// last-wins at serialization (fields emit as a JSON object).
    pub fields: Vec<(String, String)>,
    pub start_us: u64,
    pub dur_us: u64,
}

impl SpanRecord {
    pub fn end_us(&self) -> u64 {
        self.start_us + self.dur_us
    }

    /// The value of a field, if set.
    pub fn field(&self, key: &str) -> Option<&str> {
        self.fields.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }
}

struct TracerInner {
    clock: Clock,
    next_id: AtomicU64,
    spans: Mutex<Vec<SpanRecord>>,
}

/// A span collector. Cheap to clone (shared sink); a disabled tracer
/// never allocates and never locks.
#[derive(Clone, Default)]
pub struct Tracer {
    inner: Option<Arc<TracerInner>>,
}

impl Tracer {
    /// The no-op tracer: every span it (or its children) produce is
    /// dropped without recording.
    pub fn disabled() -> Tracer {
        Tracer { inner: None }
    }

    /// A recording tracer on the monotonic clock.
    pub fn new() -> Tracer {
        Tracer::with_clock(Clock::Monotonic(Instant::now()))
    }

    /// A recording tracer on the deterministic tick clock (tests).
    pub fn fixed() -> Tracer {
        Tracer::with_clock(Clock::Fixed(AtomicU64::new(0)))
    }

    pub fn with_clock(clock: Clock) -> Tracer {
        Tracer {
            inner: Some(Arc::new(TracerInner {
                clock,
                next_id: AtomicU64::new(1),
                spans: Mutex::new(Vec::new()),
            })),
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Open a root span (no parent). Nested work hangs children off the
    /// returned guard via [`Span::child`].
    pub fn span(&self, name: impl Into<String>) -> Span {
        Span::open(self.inner.clone(), None, name.into())
    }

    /// Finished spans so far, sorted by id. Live (undropped) spans are
    /// not included — snapshot after the guards are gone.
    pub fn records(&self) -> Vec<SpanRecord> {
        let Some(inner) = &self.inner else { return Vec::new() };
        let mut spans = inner.spans.lock().unwrap().clone();
        spans.sort_by_key(|s| s.id);
        spans
    }

    /// Serialize the collected spans as versioned JSONL (header line +
    /// one compact object per span, sorted by id).
    pub fn to_jsonl(&self) -> String {
        let spans = self.records();
        let clock = match &self.inner {
            Some(inner) => inner.clock.label(),
            None => "monotonic-us",
        };
        let mut out = Json::obj(vec![
            ("clock", Json::str(clock)),
            ("schema", Json::str(TRACE_SCHEMA)),
            ("spans", Json::num(spans.len() as f64)),
        ])
        .to_string_compact();
        out.push('\n');
        for s in &spans {
            out.push_str(&span_to_json(s).to_string_compact());
            out.push('\n');
        }
        out
    }

    /// Write the JSONL log to `path` (creating parent directories) and
    /// return the byte count written.
    pub fn write_jsonl(&self, path: &std::path::Path) -> Result<u64> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating trace dir {}", parent.display()))?;
            }
        }
        let text = self.to_jsonl();
        std::fs::write(path, &text)
            .with_context(|| format!("writing trace {}", path.display()))?;
        Ok(text.len() as u64)
    }
}

fn span_to_json(s: &SpanRecord) -> Json {
    let fields = Json::Obj(
        s.fields
            .iter()
            .map(|(k, v)| (k.clone(), Json::str(v.clone())))
            .collect(),
    );
    Json::obj(vec![
        ("dur_us", Json::num(s.dur_us as f64)),
        ("fields", fields),
        ("id", Json::num(s.id as f64)),
        ("name", Json::str(s.name.clone())),
        ("parent", s.parent.map(|p| Json::num(p as f64)).unwrap_or(Json::Null)),
        ("start_us", Json::num(s.start_us as f64)),
    ])
}

/// RAII span guard: the duration is stamped when the guard drops.
/// `&Span` is `Sync`, so a fan-out closure can hang per-item children
/// off a shared parent from worker threads.
pub struct Span {
    tracer: Option<Arc<TracerInner>>,
    id: u64,
    parent: Option<u64>,
    name: String,
    fields: Vec<(String, String)>,
    start_us: u64,
}

impl Span {
    /// A span that records nothing — the `Option<&Span>::None` arm for
    /// call sites threading optional telemetry.
    pub fn disabled() -> Span {
        Span {
            tracer: None,
            id: 0,
            parent: None,
            name: String::new(),
            fields: Vec::new(),
            start_us: 0,
        }
    }

    fn open(tracer: Option<Arc<TracerInner>>, parent: Option<u64>, name: String) -> Span {
        let Some(inner) = tracer else { return Span::disabled() };
        let id = inner.next_id.fetch_add(1, Ordering::Relaxed);
        let start_us = inner.clock.now_us();
        Span { tracer: Some(inner), id, parent, name, fields: Vec::new(), start_us }
    }

    /// Open a child span. Works across threads (the child carries the
    /// tracer handle and the parent id; nothing is thread-local).
    pub fn child(&self, name: impl Into<String>) -> Span {
        Span::open(self.tracer.clone(), (self.id != 0).then_some(self.id), name.into())
    }

    /// Annotate the span with a string field.
    pub fn set(&mut self, key: &str, value: impl Into<String>) {
        if self.tracer.is_some() {
            self.fields.push((key.to_string(), value.into()));
        }
    }

    pub fn is_enabled(&self) -> bool {
        self.tracer.is_some()
    }

    pub fn id(&self) -> u64 {
        self.id
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        let Some(inner) = self.tracer.take() else { return };
        let end_us = inner.clock.now_us();
        let record = SpanRecord {
            id: self.id,
            parent: self.parent,
            name: std::mem::take(&mut self.name),
            fields: std::mem::take(&mut self.fields),
            start_us: self.start_us,
            dur_us: end_us.saturating_sub(self.start_us),
        };
        inner.spans.lock().unwrap().push(record);
    }
}

/// A parsed trace: the header's clock stamp plus every span record.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    pub clock: String,
    pub spans: Vec<SpanRecord>,
}

impl Trace {
    /// Strict parse of a [`TRACE_SCHEMA`] JSONL log.
    pub fn parse_jsonl(text: &str) -> Result<Trace> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header_line = match lines.next() {
            Some(l) => l,
            None => bail!("empty trace"),
        };
        let header = Json::parse(header_line).context("trace header")?;
        let schema = header.get("schema")?.as_str()?.to_string();
        if schema != TRACE_SCHEMA {
            bail!("unsupported trace schema '{schema}' (want '{TRACE_SCHEMA}')");
        }
        let clock = header.get("clock")?.as_str()?.to_string();
        let mut spans = Vec::new();
        for (i, line) in lines.enumerate() {
            let doc = Json::parse(line).with_context(|| format!("trace span line {}", i + 2))?;
            let parent = match doc.get("parent")? {
                Json::Null => None,
                v => Some(v.as_usize()? as u64),
            };
            let mut fields = Vec::new();
            for (k, v) in doc.get("fields")?.as_obj()? {
                fields.push((k.clone(), v.as_str()?.to_string()));
            }
            spans.push(SpanRecord {
                id: doc.get("id")?.as_usize()? as u64,
                parent,
                name: doc.get("name")?.as_str()?.to_string(),
                fields,
                start_us: doc.get("start_us")?.as_usize()? as u64,
                dur_us: doc.get("dur_us")?.as_usize()? as u64,
            });
        }
        Ok(Trace { clock, spans })
    }

    /// Well-formedness: ids unique and nonzero, every parent id exists,
    /// and every child's interval nests inside its parent's.
    pub fn validate(&self) -> Result<()> {
        let mut by_id = std::collections::BTreeMap::new();
        for s in &self.spans {
            if s.id == 0 {
                bail!("span id 0 in '{}'", s.name);
            }
            if by_id.insert(s.id, s).is_some() {
                bail!("duplicate span id {}", s.id);
            }
        }
        for s in &self.spans {
            let Some(pid) = s.parent else { continue };
            let Some(p) = by_id.get(&pid) else {
                bail!("span {} '{}' has unknown parent {pid}", s.id, s.name);
            };
            if s.start_us < p.start_us || s.end_us() > p.end_us() {
                bail!(
                    "span {} '{}' [{}..{}] escapes parent {} '{}' [{}..{}]",
                    s.id,
                    s.name,
                    s.start_us,
                    s.end_us(),
                    p.id,
                    p.name,
                    p.start_us,
                    p.end_us()
                );
            }
        }
        Ok(())
    }

    /// Spans without a parent.
    pub fn roots(&self) -> Vec<&SpanRecord> {
        self.spans.iter().filter(|s| s.parent.is_none()).collect()
    }

    /// Trace wall-clock: latest end minus earliest start (0 when empty).
    pub fn wall_us(&self) -> u64 {
        let start = self.spans.iter().map(|s| s.start_us).min();
        let end = self.spans.iter().map(|s| s.end_us()).max();
        match (start, end) {
            (Some(a), Some(b)) => b.saturating_sub(a),
            _ => 0,
        }
    }

    /// Per-span self time: duration minus the summed durations of
    /// direct children, keyed by span id.
    pub fn self_us(&self) -> std::collections::BTreeMap<u64, u64> {
        let mut child_sum: std::collections::BTreeMap<u64, u64> =
            std::collections::BTreeMap::new();
        for s in &self.spans {
            if let Some(p) = s.parent {
                *child_sum.entry(p).or_insert(0) += s.dur_us;
            }
        }
        self.spans
            .iter()
            .map(|s| {
                let children = child_sum.get(&s.id).copied().unwrap_or(0);
                (s.id, s.dur_us.saturating_sub(children))
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::disabled();
        assert!(!t.is_enabled());
        {
            let root = t.span("root");
            assert!(!root.is_enabled());
            assert_eq!(root.id(), 0);
            let mut child = root.child("child");
            child.set("k", "v");
        }
        assert!(t.records().is_empty());
        // Header-only JSONL still parses.
        let trace = Trace::parse_jsonl(&t.to_jsonl()).unwrap();
        assert!(trace.spans.is_empty());
        assert_eq!(trace.wall_us(), 0);
    }

    #[test]
    fn spans_nest_and_roundtrip_through_jsonl() {
        let t = Tracer::fixed();
        {
            let mut root = t.span("matrix");
            root.set("cells", "2");
            {
                let mut c = root.child("cell");
                c.set("label", "cell#0:a");
                let _g = c.child("store.load");
            }
            let _c2 = root.child("cell");
        }
        let text = t.to_jsonl();
        let trace = Trace::parse_jsonl(&text).unwrap();
        trace.validate().unwrap();
        assert_eq!(trace.clock, "fixed-tick");
        assert_eq!(trace.spans.len(), 4);
        assert_eq!(trace.roots().len(), 1);
        let root = &trace.spans[0];
        assert_eq!(root.name, "matrix");
        assert_eq!(root.field("cells"), Some("2"));
        assert!(trace.spans.iter().filter(|s| s.name == "cell").count() == 2);
        assert!(trace
            .spans
            .iter()
            .all(|s| s.parent.is_none() || s.parent.unwrap() < s.id));
        // Root self-time excludes the children's ticks.
        let self_us = trace.self_us();
        let kids: u64 = trace
            .spans
            .iter()
            .filter(|s| s.parent == Some(root.id))
            .map(|s| s.dur_us)
            .sum();
        assert_eq!(self_us[&root.id], root.dur_us - kids);
    }

    #[test]
    fn fixed_clock_trace_is_deterministic() {
        let mk = || {
            let t = Tracer::fixed();
            {
                let root = t.span("run");
                let _a = root.child("phase-a");
                let _b = root.child("phase-b");
            }
            t.to_jsonl()
        };
        assert_eq!(mk(), mk());
    }

    #[test]
    fn cross_thread_children_attach_to_the_shared_parent() {
        let t = Tracer::new();
        {
            let root = t.span("fanout");
            std::thread::scope(|scope| {
                for i in 0..4 {
                    let root = &root;
                    scope.spawn(move || {
                        let mut s = root.child("item");
                        s.set("i", i.to_string());
                    });
                }
            });
        }
        let trace = Trace::parse_jsonl(&t.to_jsonl()).unwrap();
        trace.validate().unwrap();
        let root_id = trace.roots()[0].id;
        let items: Vec<_> = trace.spans.iter().filter(|s| s.name == "item").collect();
        assert_eq!(items.len(), 4);
        assert!(items.iter().all(|s| s.parent == Some(root_id)));
    }

    #[test]
    fn validate_rejects_unknown_parent_and_duplicate_ids() {
        let span = |id: u64, parent: Option<u64>| SpanRecord {
            id,
            parent,
            name: "x".into(),
            fields: Vec::new(),
            start_us: 0,
            dur_us: 1,
        };
        let t = Trace { clock: "fixed-tick".into(), spans: vec![span(1, Some(9))] };
        assert!(t.validate().is_err());
        let t = Trace { clock: "fixed-tick".into(), spans: vec![span(1, None), span(1, None)] };
        assert!(t.validate().is_err());
        let t = Trace { clock: "fixed-tick".into(), spans: vec![span(1, None), span(2, Some(1))] };
        t.validate().unwrap();
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(Trace::parse_jsonl("").is_err());
        assert!(Trace::parse_jsonl("{\"schema\":\"other\",\"clock\":\"x\"}").is_err());
        let good = Tracer::fixed().to_jsonl();
        assert!(Trace::parse_jsonl(&good).is_ok());
        assert!(Trace::parse_jsonl(&format!("{good}not json")).is_err());
    }
}
