//! Run-telemetry substrate: structured tracing, a metrics registry, and
//! leveled logging — the observability layer the rest of the pipeline
//! reports through (vendor-free, like [`crate::util::json`] for serde
//! and [`crate::exec`] for rayon).
//!
//! Three pieces, deliberately small:
//!
//! * [`trace`] — span-based structured tracing. A [`Tracer`] hands out
//!   RAII [`Span`] guards (nested via [`Span::child`], annotated via
//!   [`Span::set`]) and serializes the finished spans as a versioned
//!   JSONL event log ([`trace::TRACE_SCHEMA`]). A disabled tracer is a
//!   handful of `Option` checks — the untraced hot path stays the hot
//!   path.
//! * [`metrics`] — a [`MetricsRegistry`] of named monotonic counters
//!   and fixed-bucket duration histograms (cache hits/misses, kernels
//!   simulated vs deduped, retry attempts, bytes per artifact lane),
//!   snapshotted into `run.metrics.json` ([`metrics::METRICS_SCHEMA`]).
//! * [`log`] — leveled stderr logging behind `--quiet`/`-v` and
//!   `HROOFLINE_LOG`. The library default is [`log::Level::Silent`] so
//!   tests stay quiet; the `repro` binary raises it at startup.
//!
//! The cardinal rule, pinned by `rust/tests/trace_semantics.rs`:
//! telemetry is **strictly additive**. Wall-clock data lives only in
//! the trace/metrics lanes, so every txt/json/svg/csv artifact is
//! byte-identical whether tracing is on or off.

pub mod log;
pub mod metrics;
pub mod trace;

pub use metrics::{MetricsRegistry, MetricsSnapshot, METRICS_SCHEMA};
pub use trace::{Clock, Span, SpanRecord, Trace, Tracer, TRACE_SCHEMA};

/// Resolve the `--trace` opt-in: an explicit flag value wins, else the
/// `HROOFLINE_TRACE` environment variable, else tracing stays off.
pub fn trace_path(flag: &str) -> Option<String> {
    if !flag.is_empty() {
        return Some(flag.to_string());
    }
    match std::env::var("HROOFLINE_TRACE") {
        Ok(v) if !v.is_empty() => Some(v),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn trace_path_prefers_flag() {
        // Env-dependent branch is covered in CI; the flag branch is pure.
        assert_eq!(super::trace_path("out/t.jsonl").as_deref(), Some("out/t.jsonl"));
    }
}
