//! Metric registry: the structured PerfWorks naming convention.
//!
//! Nsight Compute metric names decompose as
//! `unit__counter_name.rollup[.submetric]` — e.g.
//! `sm__cycles_elapsed.avg.per_second` is unit `sm`, counter
//! `cycles_elapsed`, rollup `avg`, submetric `per_second` (paper §II-B:
//! "components such as unit, subunit, interface, counter name, rollup
//! metric and submetric"). The registry parses names, validates them
//! against the known set, and groups them into collection passes.

use std::collections::BTreeSet;

use crate::sim::counters::{names, CounterId};

/// A parsed metric name.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct Metric {
    pub raw: String,
    pub unit: String,
    pub counter: String,
    pub rollup: String,
    pub submetric: Option<String>,
    /// Dense counter slot, resolved once at parse time so the session's
    /// per-pass metric copies are array indexing instead of string
    /// lookups. `None` = fallback-lane metric (outside Table II).
    pub id: Option<CounterId>,
}

/// Metric-name error.
#[derive(Debug, PartialEq)]
pub enum MetricError {
    Malformed(String),
    Unknown(String),
}

impl std::fmt::Display for MetricError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MetricError::Malformed(name) => write!(
                f,
                "malformed metric name '{name}': expected unit__counter.rollup[.submetric]"
            ),
            MetricError::Unknown(name) => {
                write!(f, "unknown metric '{name}' (not in the Table II set)")
            }
        }
    }
}

impl std::error::Error for MetricError {}

impl Metric {
    /// Parse a metric name into its structural components.
    pub fn parse(name: &str) -> Result<Metric, MetricError> {
        let (unit, rest) = name
            .split_once("__")
            .ok_or_else(|| MetricError::Malformed(name.into()))?;
        let mut dot_parts = rest.split('.');
        let counter = dot_parts
            .next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| MetricError::Malformed(name.into()))?;
        let rollup = dot_parts
            .next()
            .filter(|s| !s.is_empty())
            .ok_or_else(|| MetricError::Malformed(name.into()))?;
        let submetric = dot_parts.next().map(|s| s.to_string());
        if dot_parts.next().is_some() || unit.is_empty() {
            return Err(MetricError::Malformed(name.into()));
        }
        Ok(Metric {
            raw: name.to_string(),
            unit: unit.to_string(),
            counter: counter.to_string(),
            rollup: rollup.to_string(),
            submetric,
            id: CounterId::from_name(name),
        })
    }
}

/// Registry of collectable metrics with pass planning.
#[derive(Clone, Debug)]
pub struct MetricRegistry {
    known: BTreeSet<String>,
    /// How many raw hardware counters one replay pass can gather — the
    /// reason Nsight replays kernels (paper §II-B "kernel replay when
    /// multiple metrics are being collected").
    pub counters_per_pass: usize,
}

impl Default for MetricRegistry {
    fn default() -> Self {
        Self::standard()
    }
}

impl MetricRegistry {
    /// Registry holding the paper's Table II metric set.
    pub fn standard() -> MetricRegistry {
        MetricRegistry {
            known: names::STANDARD.iter().map(|s| s.to_string()).collect(),
            counters_per_pass: 4,
        }
    }

    /// Validate + parse a requested metric list.
    pub fn resolve(&self, requested: &[&str]) -> Result<Vec<Metric>, MetricError> {
        requested
            .iter()
            .map(|name| {
                if !self.known.contains(*name) {
                    return Err(MetricError::Unknown(name.to_string()));
                }
                Metric::parse(name)
            })
            .collect()
    }

    /// All known metric names (stable order).
    pub fn all(&self) -> Vec<&str> {
        self.known.iter().map(|s| s.as_str()).collect()
    }

    /// Plan replay passes: metrics sharing a hardware unit can often be
    /// gathered together; we model the constraint as a flat
    /// counters-per-pass budget, with the *same-unit grouping* Nsight
    /// uses (metrics of one unit are packed into the same pass first).
    pub fn plan_passes(&self, metrics: &[Metric]) -> Vec<Vec<Metric>> {
        let mut sorted: Vec<Metric> = metrics.to_vec();
        sorted.sort_by(|a, b| (&a.unit, &a.raw).cmp(&(&b.unit, &b.raw)));
        // Derived submetrics (e.g. .per_second) ride along with their base
        // counter and don't consume a slot.
        let mut passes: Vec<Vec<Metric>> = Vec::new();
        let mut current: Vec<Metric> = Vec::new();
        let mut slots = 0usize;
        for m in sorted {
            let consumes_slot = m.submetric.is_none();
            if consumes_slot && slots == self.counters_per_pass {
                passes.push(std::mem::take(&mut current));
                slots = 0;
            }
            if consumes_slot {
                slots += 1;
            }
            current.push(m);
        }
        if !current.is_empty() {
            passes.push(current);
        }
        passes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_structured_name() {
        let m = Metric::parse("sm__cycles_elapsed.avg.per_second").unwrap();
        assert_eq!(m.unit, "sm");
        assert_eq!(m.counter, "cycles_elapsed");
        assert_eq!(m.rollup, "avg");
        assert_eq!(m.submetric.as_deref(), Some("per_second"));

        let m = Metric::parse("l1tex__t_bytes.sum").unwrap();
        assert_eq!(m.unit, "l1tex");
        assert_eq!(m.counter, "t_bytes");
        assert_eq!(m.rollup, "sum");
        assert_eq!(m.submetric, None);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(Metric::parse("nounit.sum").is_err());
        assert!(Metric::parse("sm__").is_err());
        assert!(Metric::parse("sm__cycles").is_err());
        assert!(Metric::parse("sm__a.b.c.d").is_err());
        assert!(Metric::parse("__x.sum").is_err());
    }

    #[test]
    fn registry_knows_table2() {
        let reg = MetricRegistry::standard();
        let resolved = reg.resolve(&names::STANDARD).unwrap();
        assert_eq!(resolved.len(), 15);
        // Every Table II metric carries its dense slot.
        for m in &resolved {
            assert_eq!(m.id.map(|id| id.name()), Some(m.raw.as_str()));
        }
    }

    #[test]
    fn well_formed_unknown_metric_has_no_dense_slot() {
        let m = Metric::parse("smsp__warps_active.avg").unwrap();
        assert_eq!(m.id, None);
    }

    #[test]
    fn registry_rejects_unknown() {
        let reg = MetricRegistry::standard();
        let err = reg.resolve(&["sm__bogus_counter.sum"]).unwrap_err();
        assert!(matches!(err, MetricError::Unknown(_)));
    }

    #[test]
    fn pass_planning_respects_budget() {
        let reg = MetricRegistry::standard();
        let metrics = reg.resolve(&names::STANDARD).unwrap();
        let passes = reg.plan_passes(&metrics);
        // 14 slot-consuming counters (per_second rides along) at 4/pass
        // => 4 passes.
        assert_eq!(passes.len(), 4);
        let total: usize = passes.iter().map(|p| p.len()).sum();
        assert_eq!(total, 15);
        for pass in &passes {
            let slots = pass.iter().filter(|m| m.submetric.is_none()).count();
            assert!(slots <= reg.counters_per_pass);
        }
    }

    #[test]
    fn single_metric_single_pass() {
        let reg = MetricRegistry::standard();
        let metrics = reg.resolve(&[names::DRAM_BYTES]).unwrap();
        assert_eq!(reg.plan_passes(&metrics).len(), 1);
    }
}
