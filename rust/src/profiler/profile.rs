//! Aggregated application profiles: the output of a collection session
//! and the input to Roofline chart construction.

use std::collections::BTreeMap;

use crate::device::{GpuSpec, MemLevel, Precision};
use crate::sim::counters::CounterSet;
use crate::sim::cycles::{Bound, CycleBreakdown};

/// Model-attributed timing for one kernel aggregate — the time-based
/// Roofline's "extra column" (Wang et al., arXiv 2009.04598). Cycle
/// components come from [`CycleBreakdown`], converted to seconds via
/// the device's SM clock; `total_s` is the elapsed time (max(compute,
/// memory) + ramp per invocation), so the components overlap rather
/// than stack:  `total_s = max(compute_s, memory_s) + ramp_s`.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct KernelTiming {
    /// Seconds the compute pipelines needed (fully overlapped view).
    pub compute_s: f64,
    /// Seconds the memory hierarchy needed (fully overlapped view).
    pub memory_s: f64,
    /// Launch/drain ramp seconds (paid per invocation — the "idle"
    /// slice of a step timeline).
    pub ramp_s: f64,
    /// Elapsed seconds across all invocations.
    pub total_s: f64,
}

impl KernelTiming {
    /// Fold `invocations` executions of a kernel with breakdown `b`
    /// into this aggregate, converting cycles to seconds via the SM
    /// clock.
    pub fn accumulate(&mut self, b: &CycleBreakdown, invocations: u64, clock_hz: f64) {
        let scale = invocations as f64 / clock_hz;
        self.compute_s += b.compute_cycles * scale;
        self.memory_s += b.memory_cycles * scale;
        self.ramp_s += b.ramp_cycles * scale;
        self.total_s += b.total_cycles * scale;
    }

    /// Elapsed seconds net of ramp — what the kernel body took.
    pub fn body_s(&self) -> f64 {
        self.total_s - self.ramp_s
    }

    /// Which resource bound this aggregate. Matches the per-invocation
    /// [`CycleBreakdown::bound`] exactly for single-descriptor
    /// aggregates (the scaling preserves every comparison).
    pub fn bound(&self) -> Bound {
        if self.body_s() < self.ramp_s {
            Bound::Overhead
        } else if self.compute_s >= self.memory_s {
            Bound::Compute
        } else {
            Bound::Memory
        }
    }
}

/// Aggregate over all invocations of one kernel (keyed by kernel name),
/// as the paper plots: "there could be many invocations of the same
/// kernel and the data presented ... is the aggregation" (§IV).
#[derive(Clone, Debug, PartialEq)]
pub struct KernelProfile {
    pub name: String,
    pub invocations: u64,
    pub counters: CounterSet,
    /// FLOPs per tensor instruction of the profiled device (Eq. 6 factor).
    pub flops_per_tensor_inst: f64,
    /// Model-attributed timing, when the session collected it. `None`
    /// for counter-only sessions, hand-assembled profiles and CSV
    /// imports — timing is strictly additive and never feeds back into
    /// counters or their serialization.
    pub timing: Option<KernelTiming>,
}

impl KernelProfile {
    /// Aggregated run time over all invocations (Eq. 5).
    pub fn seconds(&self) -> f64 {
        self.counters.elapsed_seconds()
    }

    /// Model-attributed duration: [`KernelTiming::total_s`] when timing
    /// was collected, else the counter time base. The two agree to
    /// rounding for session-built profiles (both are Cycles over the SM
    /// clock).
    pub fn duration_s(&self) -> f64 {
        match &self.timing {
            Some(t) => t.total_s,
            None => self.seconds(),
        }
    }

    /// Which resource bound this kernel, when timing was collected.
    pub fn bound(&self) -> Option<Bound> {
        self.timing.as_ref().map(KernelTiming::bound)
    }

    /// Total FLOPs over all invocations.
    pub fn flops(&self) -> f64 {
        self.counters.total_flops(self.flops_per_tensor_inst)
    }

    /// FLOPs executed on the tensor pipe.
    pub fn tensor_flops(&self) -> f64 {
        self.counters.tensor_flops(self.flops_per_tensor_inst)
    }

    /// CUDA-core FLOPs for one precision.
    pub fn flops_precision(&self, p: Precision) -> f64 {
        self.counters.flops(p)
    }

    /// Sustained performance, FLOP/s.
    pub fn flops_per_sec(&self) -> f64 {
        let s = self.seconds();
        if s == 0.0 {
            0.0
        } else {
            self.flops() / s
        }
    }

    /// Arithmetic intensity at a memory level.
    pub fn ai(&self, level: MemLevel) -> Option<f64> {
        self.counters
            .arithmetic_intensity(level, self.flops_per_tensor_inst)
    }

    /// Whether the kernel performed zero floating-point work (§IV-D).
    pub fn is_zero_ai(&self) -> bool {
        self.flops() == 0.0
    }

    /// Whether the majority of FLOPs ran on the tensor pipe.
    pub fn is_tensor_dominated(&self) -> bool {
        self.flops() > 0.0 && self.tensor_flops() > 0.5 * self.flops()
    }
}

/// A full application profile: per-kernel aggregates plus session
/// bookkeeping.
///
/// `PartialEq` is exact (bitwise on counter values) — the profiler's
/// memoized/parallel paths are required to produce *identical* output
/// to the serial path, and tests assert it through this impl.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Profile {
    kernels: BTreeMap<String, KernelProfile>,
    /// Number of replay passes the session used.
    pub passes: u64,
    /// Wall overhead the profiler itself added (replays + serialization).
    pub profiling_overhead_s: f64,
    /// Name of the device the profile was collected on (empty for
    /// hand-assembled profiles). Sessions stamp it from their spec;
    /// CSV export/import round-trips it.
    pub device: String,
}

impl Profile {
    pub fn new() -> Profile {
        Profile::default()
    }

    /// An empty profile stamped with a device name.
    pub fn for_device(spec: &GpuSpec) -> Profile {
        Profile {
            device: spec.name.clone(),
            ..Profile::default()
        }
    }

    /// The aggregate slot for one kernel name, created empty on first use.
    fn entry_for(&mut self, name: &str, spec: &GpuSpec) -> &mut KernelProfile {
        self.kernels
            .entry(name.to_string())
            .or_insert_with(|| KernelProfile {
                name: name.to_string(),
                invocations: 0,
                counters: CounterSet::new(),
                flops_per_tensor_inst: spec.flops_per_tensor_inst as f64,
                timing: None,
            })
    }

    /// Merge one kernel invocation's counters into the aggregate.
    pub fn record(
        &mut self,
        name: &str,
        invocations: u64,
        counters: &CounterSet,
        spec: &GpuSpec,
    ) {
        let entry = self.entry_for(name, spec);
        entry.invocations += invocations;
        entry.counters.accumulate(counters);
    }

    /// Record `invocations` identical executions in one accumulate by
    /// scaling the counters (§Perf L3-2; valid because deterministic
    /// invocations of one kernel observe identical counters). Runs on
    /// the dense representation directly — no intermediate scaled copy.
    pub fn record_scaled(
        &mut self,
        name: &str,
        invocations: u64,
        counters: &CounterSet,
        spec: &GpuSpec,
    ) {
        if invocations == 0 {
            return;
        }
        let entry = self.entry_for(name, spec);
        entry.invocations += invocations;
        entry.counters.accumulate_scaled(counters, invocations);
    }

    /// Fold a cycle breakdown for `invocations` executions into the
    /// kernel's timing aggregate. Counters are untouched: timing lives
    /// next to them, so counter-only outputs (CSV, charts built from
    /// counters) stay byte-identical whether or not timing was
    /// collected.
    pub fn record_timing(
        &mut self,
        name: &str,
        invocations: u64,
        b: &CycleBreakdown,
        spec: &GpuSpec,
    ) {
        if invocations == 0 {
            return;
        }
        let clock_hz = spec.clock_hz;
        let entry = self.entry_for(name, spec);
        entry
            .timing
            .get_or_insert_with(KernelTiming::default)
            .accumulate(b, invocations, clock_hz);
    }

    /// Insert a fully-built kernel aggregate verbatim, keyed by its
    /// name. This is the deserialization entry point (cell-store and
    /// JSON round-trips): unlike [`Profile::record`] it does not stamp
    /// `flops_per_tensor_inst` from a spec or drop `timing`, so a
    /// decoded profile compares exactly equal to the original.
    pub fn insert(&mut self, kernel: KernelProfile) {
        self.kernels.insert(kernel.name.clone(), kernel);
    }

    pub fn kernel(&self, name: &str) -> Option<&KernelProfile> {
        self.kernels.get(name)
    }

    pub fn kernels(&self) -> impl Iterator<Item = &KernelProfile> {
        self.kernels.values()
    }

    pub fn n_kernels(&self) -> usize {
        self.kernels.len()
    }

    /// Total GPU time across kernels (serialized execution — Nsight
    /// 2020.1.0 serializes multi-stream launches, §II-B).
    pub fn total_seconds(&self) -> f64 {
        self.kernels.values().map(|k| k.seconds()).sum()
    }

    /// Total invocations across kernels.
    pub fn total_invocations(&self) -> u64 {
        self.kernels.values().map(|k| k.invocations).sum()
    }

    /// Kernels sorted by descending aggregated run time.
    pub fn by_time(&self) -> Vec<&KernelProfile> {
        let mut ks: Vec<&KernelProfile> = self.kernels.values().collect();
        // total_cmp: NaN seconds (conceivable from ingested traces)
        // must not panic; identical to partial_cmp on finite values.
        ks.sort_by(|a, b| b.seconds().total_cmp(&a.seconds()));
        ks
    }

    /// Runtime share of the single hottest kernel (Fig. 3 caption: the
    /// dominant TF forward kernel consumes 33% of run time).
    pub fn top_kernel_time_share(&self) -> f64 {
        let total = self.total_seconds();
        if total == 0.0 {
            return 0.0;
        }
        self.by_time()
            .first()
            .map(|k| k.seconds() / total)
            .unwrap_or(0.0)
    }

    /// (zero-AI invocations, total invocations) — Table III census.
    pub fn zero_ai_census(&self) -> (u64, u64) {
        let zero: u64 = self
            .kernels
            .values()
            .filter(|k| k.is_zero_ai())
            .map(|k| k.invocations)
            .sum();
        (zero, self.total_invocations())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{self, KernelDesc};

    fn spec() -> GpuSpec {
        GpuSpec::v100()
    }

    fn profile_of(kernels: &[(&str, u64, KernelDesc)]) -> Profile {
        let spec = spec();
        let mut p = Profile::new();
        for (name, inv, k) in kernels {
            let c = sim::simulate(&spec, k);
            for _ in 0..*inv {
                p.record(name, 1, &c, &spec);
            }
        }
        p
    }

    #[test]
    fn aggregation_sums_invocations() {
        let k = KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1);
        let p = profile_of(&[("relu", 3, k)]);
        let kp = p.kernel("relu").unwrap();
        assert_eq!(kp.invocations, 3);
        // 3 invocations => 3x the single-run flops.
        let single = (1u64 << 18) * 2;
        assert_eq!(kp.flops() as u64, 3 * single);
    }

    #[test]
    fn record_scaled_identical_to_explicit_scaled_record() {
        // The dense fast path must be bit-identical to the original
        // implementation (build a scaled copy, then record it).
        let spec = spec();
        let k = KernelDesc::streaming_elementwise("relu", 1 << 16, Precision::Fp32, 2);
        let c = sim::simulate(&spec, &k);
        let mut scaled = CounterSet::new();
        for (metric, value) in c.metrics() {
            if metric == crate::sim::counters::names::CYCLES_PER_SEC {
                scaled.set(metric, value);
            } else {
                scaled.set(metric, value * 5.0);
            }
        }
        let mut reference = Profile::new();
        reference.record("relu", 5, &scaled, &spec);
        let mut fast = Profile::new();
        fast.record_scaled("relu", 5, &c, &spec);
        assert_eq!(fast, reference, "scaled accumulate must be bit-identical");
    }

    #[test]
    fn by_time_sorted_desc() {
        let big = KernelDesc::streaming_elementwise("big", 1 << 24, Precision::Fp32, 2);
        let small = KernelDesc::streaming_elementwise("small", 1 << 12, Precision::Fp32, 2);
        let p = profile_of(&[("big", 1, big), ("small", 1, small)]);
        let order: Vec<&str> = p.by_time().iter().map(|k| k.name.as_str()).collect();
        assert_eq!(order, vec!["big", "small"]);
        assert!(p.top_kernel_time_share() > 0.5);
    }

    #[test]
    fn zero_ai_census_counts_invocations() {
        let cast = KernelDesc::streaming_elementwise("cast", 1 << 16, Precision::Fp16, 0);
        let fma = KernelDesc::streaming_elementwise("fma", 1 << 16, Precision::Fp32, 4);
        let p = profile_of(&[("cast", 10, cast), ("fma", 5, fma)]);
        let (zero, total) = p.zero_ai_census();
        assert_eq!(zero, 10);
        assert_eq!(total, 15);
    }

    #[test]
    fn tensor_domination_flag() {
        let spec = spec();
        let g = KernelDesc::gemm("hmma", 1024, 1024, 1024, Precision::Fp16, true, 64, &spec);
        let p = profile_of(&[("hmma", 1, g)]);
        assert!(p.kernel("hmma").unwrap().is_tensor_dominated());
    }

    #[test]
    fn timing_accumulates_and_stays_out_of_counters() {
        let spec = spec();
        let k = KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1);
        let (c, b) = sim::simulate_timed(&spec, &k);

        let mut timed = Profile::new();
        timed.record_scaled("relu", 3, &c, &spec);
        timed.record_timing("relu", 3, &b, &spec);
        let mut plain = Profile::new();
        plain.record_scaled("relu", 3, &c, &spec);

        let kt = timed.kernel("relu").unwrap();
        let kp = plain.kernel("relu").unwrap();
        assert_eq!(kt.counters, kp.counters, "timing must never touch counters");
        assert_eq!(kp.timing, None);
        assert_eq!(kp.duration_s(), kp.seconds());

        let t = kt.timing.unwrap();
        let expect = 3.0 * b.total_cycles / spec.clock_hz;
        assert!((t.total_s - expect).abs() <= 1e-12 * expect);
        assert_eq!(t.bound(), b.bound, "aggregate bound matches per-invocation bound");
        // The two time bases are the same cycle count over the same
        // clock — they agree to rounding.
        let dt = (kt.duration_s() - kt.seconds()).abs();
        assert!(dt <= 1e-9 * kt.seconds(), "duration_s vs counter seconds: {dt}");
        // Components overlap, they don't stack.
        let body = t.compute_s.max(t.memory_s);
        assert!((t.body_s() - body).abs() <= 1e-12 * body.max(1e-30));
    }

    #[test]
    fn empty_profile_is_sane() {
        let p = Profile::new();
        assert_eq!(p.total_seconds(), 0.0);
        assert_eq!(p.top_kernel_time_share(), 0.0);
        assert_eq!(p.zero_ai_census(), (0, 0));
    }
}
