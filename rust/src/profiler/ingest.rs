//! Streaming Nsight CSV ingestion with bounded memory.
//!
//! [`crate::profiler::export::from_csv`] historically materialized the
//! whole export as one `String` plus a row `Vec` — fine at paper scale,
//! hopeless for real traces with millions of kernel launches. This
//! module is the production-scale path: a chunked reader over any
//! `std::io::Read` (fixed-size buffer, lines re-assembled across chunk
//! boundaries) feeding an online aggregator that dedups launches into
//! digest-keyed accumulators ([`crate::util::digest::fnv1a64`] over the
//! kernel name, the same FNV substrate `SimCache`/`CellStore` keys come
//! from). Resident memory is O(unique kernels) + one chunk + the
//! longest line — never O(rows).
//!
//! The in-memory entry points (`from_csv`/`from_csv_lenient`) are thin
//! wrappers over [`from_reader`], so the two paths are one
//! implementation and produce byte-identical [`Profile`]s — asserted by
//! `rust/tests/ingest_semantics.rs`.
//!
//! Telemetry (armed by [`IngestConfig::with_span`]/`with_metrics`, the
//! PR-9 idiom): an `ingest` span wrapping the run with `ingest.chunk`
//! children per buffer refill and an `ingest.aggregate` child for the
//! final profile build, plus `ingest.rows` / `ingest.unique_kernels` /
//! `ingest.bytes` counters.

use std::collections::HashMap;
use std::io::Read;

use crate::device::GpuSpec;
use crate::profiler::export::{parse_csv_row, RowDiagnostics, DEVICE_PREFIX};
use crate::profiler::profile::Profile;
use crate::sim::counters::CounterSet;
use crate::util::digest::fnv1a64;
use crate::util::error::{anyhow, bail, Context, Result};

/// Knobs for a streaming ingest. Defaults match the strict in-memory
/// path: `from_csv` is literally `from_reader` with this default.
pub struct IngestConfig<'a> {
    lenient: bool,
    chunk_bytes: usize,
    span: Option<&'a crate::obs::Span>,
    metrics: Option<&'a crate::obs::MetricsRegistry>,
}

impl<'a> IngestConfig<'a> {
    /// Default streaming read granularity. Small enough to keep the
    /// resident buffer negligible, large enough that syscall count is
    /// not the bottleneck on multi-GB exports.
    pub const DEFAULT_CHUNK_BYTES: usize = 64 * 1024;

    pub fn new() -> IngestConfig<'a> {
        IngestConfig {
            lenient: false,
            chunk_bytes: Self::DEFAULT_CHUNK_BYTES,
            span: None,
            metrics: None,
        }
    }

    /// Skip-and-report malformed rows instead of failing the file
    /// (the `from_csv_lenient` semantics).
    pub fn lenient(mut self, yes: bool) -> IngestConfig<'a> {
        self.lenient = yes;
        self
    }

    /// Streaming read granularity in bytes (clamped to ≥ 1). Output is
    /// invariant under this knob — tests drive it down to 1 byte to
    /// force every row across a buffer boundary.
    pub fn chunk_bytes(mut self, n: usize) -> IngestConfig<'a> {
        self.chunk_bytes = n.max(1);
        self
    }

    /// Hang the `ingest` span (and its chunk/aggregate children) off
    /// this parent.
    pub fn with_span(mut self, span: &'a crate::obs::Span) -> IngestConfig<'a> {
        self.span = Some(span);
        self
    }

    /// Sink `ingest.*` counters into this registry.
    pub fn with_metrics(mut self, m: &'a crate::obs::MetricsRegistry) -> IngestConfig<'a> {
        self.metrics = Some(m);
        self
    }
}

impl Default for IngestConfig<'_> {
    fn default() -> Self {
        IngestConfig::new()
    }
}

/// What a streaming ingest observed, alongside the profile itself.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct IngestStats {
    /// Non-blank data rows seen past the header (folded or, in lenient
    /// mode, rejected).
    pub rows: u64,
    /// Distinct kernel names — the accumulator count.
    pub unique_kernels: usize,
    /// Raw bytes pulled from the reader.
    pub bytes_read: u64,
    /// High-water mark of resident accumulators. Aggregation never
    /// evicts, so this equals `unique_kernels` — the bounded-memory
    /// contract in one number, independent of `rows`.
    pub peak_resident_accumulators: usize,
}

impl IngestStats {
    /// Launch-dedup compression: data rows per unique kernel.
    pub fn dedup_ratio(&self) -> f64 {
        if self.unique_kernels == 0 {
            0.0
        } else {
            self.rows as f64 / self.unique_kernels as f64
        }
    }
}

/// A completed streaming ingest: the aggregated profile, the run stats,
/// and (lenient mode) the per-row diagnostics. Strict runs always carry
/// empty diagnostics.
pub struct IngestOutput {
    pub profile: Profile,
    pub stats: IngestStats,
    pub diagnostics: RowDiagnostics,
}

/// Chunked line reader: pulls fixed-size chunks from the source and
/// re-assembles `\n`-terminated lines across chunk boundaries, matching
/// `str::lines` exactly (one trailing `\r` stripped from terminated
/// lines; an unterminated final line emitted verbatim). Resident memory
/// is one chunk plus the longest line.
struct LineReader<'r> {
    src: &'r mut dyn Read,
    chunk_bytes: usize,
    buf: Vec<u8>,
    start: usize,
    cur: (usize, usize),
    eof: bool,
    bytes_read: u64,
    span: &'r crate::obs::Span,
}

impl<'r> LineReader<'r> {
    fn new(
        src: &'r mut dyn Read,
        chunk_bytes: usize,
        span: &'r crate::obs::Span,
    ) -> LineReader<'r> {
        LineReader {
            src,
            chunk_bytes,
            buf: Vec::with_capacity(chunk_bytes),
            start: 0,
            cur: (0, 0),
            eof: false,
            bytes_read: 0,
            span,
        }
    }

    /// Advance to the next line; `false` at end of input. The line is
    /// readable via [`LineReader::line`] until the next call.
    fn advance(&mut self) -> Result<bool> {
        loop {
            if let Some(pos) = self.buf[self.start..].iter().position(|&b| b == b'\n') {
                let end = self.start + pos;
                let line_end =
                    if end > self.start && self.buf[end - 1] == b'\r' { end - 1 } else { end };
                self.cur = (self.start, line_end);
                self.start = end + 1;
                return Ok(true);
            }
            if self.eof {
                if self.start < self.buf.len() {
                    // Trailing line without a terminator: emitted as-is
                    // (str::lines does not strip a bare trailing \r).
                    self.cur = (self.start, self.buf.len());
                    self.start = self.buf.len();
                    return Ok(true);
                }
                return Ok(false);
            }
            // No terminator buffered: drop consumed bytes and pull the
            // next chunk. The buffer only outgrows chunk_bytes when one
            // line does.
            self.buf.drain(..self.start);
            self.start = 0;
            let old_len = self.buf.len();
            self.buf.resize(old_len + self.chunk_bytes, 0);
            let mut chunk_span = self.span.child("ingest.chunk");
            let n = self
                .src
                .read(&mut self.buf[old_len..])
                .context("reading csv chunk")?;
            self.buf.truncate(old_len + n);
            self.bytes_read += n as u64;
            chunk_span.set("bytes", n.to_string());
            if n == 0 {
                self.eof = true;
            }
        }
    }

    fn line(&self) -> &[u8] {
        &self.buf[self.cur.0..self.cur.1]
    }
}

/// One resident per-kernel accumulator.
struct Acc {
    name: String,
    invocations: u64,
    counters: CounterSet,
}

/// Online launch-dedup: rows fold into accumulators keyed by the FNV
/// digest of the kernel name (collision chains checked by full name
/// equality, so a 64-bit collision costs a comparison, never
/// correctness). Memory is O(unique kernels) regardless of row count.
#[derive(Default)]
struct OnlineAggregator {
    index: HashMap<u64, Vec<usize>>,
    accs: Vec<Acc>,
}

impl OnlineAggregator {
    /// Parse and fold one data row — the single definition of row
    /// semantics for both strict and lenient, streaming and in-memory
    /// ingest (field count, value/invocations parses, and the
    /// conflicting-Invocations check).
    fn fold_row(&mut self, line: &str, lineno: usize) -> Result<()> {
        let fields =
            parse_csv_row(line).with_context(|| format!("csv line {lineno}: '{line}'"))?;
        if fields.len() != 4 {
            bail!("csv line {lineno}: expected 4 fields, got {}", fields.len());
        }
        let value: f64 = fields[2]
            .parse()
            .with_context(|| format!("csv line {lineno}: bad value '{}'", fields[2]))?;
        let invocations: u64 = fields[3]
            .parse()
            .with_context(|| format!("csv line {lineno}: bad invocations '{}'", fields[3]))?;
        let digest = fnv1a64(fields[0].as_bytes());
        let chain = self.index.entry(digest).or_default();
        let idx = match chain.iter().copied().find(|&i| self.accs[i].name == fields[0]) {
            Some(i) => i,
            None => {
                let i = self.accs.len();
                self.accs.push(Acc {
                    name: fields[0].clone(),
                    invocations,
                    counters: CounterSet::new(),
                });
                chain.push(i);
                i
            }
        };
        let acc = &mut self.accs[idx];
        // Nsight emits one invocation count per kernel; a disagreement
        // means a corrupt or spliced export. Structured error naming
        // both values (lenient mode skips the row; the kernel keeps the
        // first count it declared).
        if acc.invocations != invocations {
            bail!(
                "csv line {lineno}: conflicting Invocations for kernel '{}': \
                 {} earlier vs {} here",
                fields[0],
                acc.invocations,
                invocations
            );
        }
        acc.counters.set(&fields[1], value);
        Ok(())
    }
}

/// Stream a Nsight-idiom counter CSV out of any reader into an
/// aggregated [`Profile`] — the one implementation behind `from_csv`,
/// `from_csv_lenient`, and `repro ingest`. Header problems (including a
/// missing header) are fatal in both modes; row handling follows
/// `cfg.lenient`.
pub fn from_reader(
    src: &mut dyn Read,
    spec: &GpuSpec,
    cfg: &IngestConfig,
) -> Result<IngestOutput> {
    let mut ingest_span = match cfg.span {
        Some(parent) => parent.child("ingest"),
        None => crate::obs::Span::disabled(),
    };
    let mut reader = LineReader::new(src, cfg.chunk_bytes, &ingest_span);

    // Header: optional `# device=` stamp, then the column header —
    // identical acceptance to the historical split_header.
    if !reader.advance()? {
        bail!("empty csv");
    }
    let mut header =
        std::str::from_utf8(reader.line()).context("csv header is not valid utf-8")?;
    let mut device = spec.name.clone();
    let mut first_data_line = 2usize;
    if let Some(name) = header.strip_prefix(DEVICE_PREFIX) {
        device = name.trim().to_string();
        if !reader.advance()? {
            bail!("csv has a device line but no header");
        }
        header =
            std::str::from_utf8(reader.line()).context("csv header is not valid utf-8")?;
        first_data_line = 3;
    }
    if !header.contains("Kernel Name") || !header.contains("Metric Name") {
        bail!("unrecognized csv header: {header}");
    }

    let mut agg = OnlineAggregator::default();
    let mut diagnostics = RowDiagnostics::default();
    let mut stats = IngestStats::default();
    let mut lineno = first_data_line;
    while reader.advance()? {
        let current = lineno;
        lineno += 1;
        let outcome = match std::str::from_utf8(reader.line()) {
            Ok(line) => {
                if line.trim().is_empty() {
                    continue;
                }
                stats.rows += 1;
                agg.fold_row(line, current)
            }
            Err(_) => {
                stats.rows += 1;
                Err(anyhow!("csv line {current}: not valid utf-8"))
            }
        };
        if let Err(e) = outcome {
            if cfg.lenient {
                diagnostics.push(current, format!("{e:#}"));
            } else {
                return Err(e);
            }
        }
        stats.peak_resident_accumulators =
            stats.peak_resident_accumulators.max(agg.accs.len());
    }

    let profile = {
        let _agg_span = ingest_span.child("ingest.aggregate");
        let mut profile = Profile::new();
        profile.device = device;
        for acc in &agg.accs {
            profile.record(&acc.name, acc.invocations, &acc.counters, spec);
        }
        profile
    };
    stats.unique_kernels = agg.accs.len();
    stats.bytes_read = reader.bytes_read;
    drop(reader);

    ingest_span.set("rows", stats.rows.to_string());
    ingest_span.set("unique_kernels", stats.unique_kernels.to_string());
    ingest_span.set("bytes", stats.bytes_read.to_string());
    if let Some(m) = cfg.metrics {
        m.add("ingest.rows", stats.rows);
        m.add("ingest.unique_kernels", stats.unique_kernels as u64);
        m.add("ingest.bytes", stats.bytes_read);
    }
    Ok(IngestOutput { profile, stats, diagnostics })
}

#[cfg(test)]
mod tests {
    use super::*;

    const HEADER: &str = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n";

    fn ingest(text: &str, cfg: IngestConfig) -> IngestOutput {
        let spec = GpuSpec::v100();
        let mut src = text.as_bytes();
        from_reader(&mut src, &spec, &cfg).unwrap()
    }

    #[test]
    fn stats_count_rows_uniques_and_bytes() {
        let csv = format!(
            "{HEADER}\"a\",\"sm__cycles_elapsed.avg\",1000,1\n\
             \"a\",\"dram__bytes.sum\",2000,1\n\
             \"b\",\"sm__cycles_elapsed.avg\",3000,2\n"
        );
        let out = ingest(&csv, IngestConfig::new());
        assert_eq!(out.stats.rows, 3);
        assert_eq!(out.stats.unique_kernels, 2);
        assert_eq!(out.stats.peak_resident_accumulators, 2);
        assert_eq!(out.stats.bytes_read, csv.len() as u64);
        assert!((out.stats.dedup_ratio() - 1.5).abs() < 1e-12);
        assert!(out.diagnostics.is_empty());
        assert_eq!(out.profile.kernel("b").unwrap().invocations, 2);
    }

    #[test]
    fn output_is_invariant_under_chunk_size() {
        // Device stamp + CRLF line endings + no trailing newline, read
        // at every pathological chunk size including 1 byte.
        let csv = format!(
            "# device=V100-SXM2-16GB\r\n{HEADER}\"k, with commas\",\"dram__bytes.sum\",42,1\r\n\
             \"k2\",\"lts__t_bytes.sum\",7,3"
        );
        let reference = ingest(&csv, IngestConfig::new());
        for chunk in [1usize, 2, 3, 7, 13, 31, 64, 4096] {
            let out = ingest(&csv, IngestConfig::new().chunk_bytes(chunk));
            assert_eq!(out.profile, reference.profile, "chunk_bytes={chunk}");
            assert_eq!(out.stats, reference.stats, "chunk_bytes={chunk}");
        }
        assert_eq!(reference.profile.device, "V100-SXM2-16GB");
        assert!(reference.profile.kernel("k, with commas").is_some());
        assert_eq!(reference.profile.kernel("k2").unwrap().invocations, 3);
    }

    #[test]
    fn digest_chains_disambiguate_by_name() {
        // Distinct names always land in distinct accumulators even when
        // folded through the digest index (collision chains compare the
        // full name; with distinct digests this is the common path).
        let mut csv = String::from(HEADER);
        for i in 0..100 {
            csv.push_str(&format!("\"kernel_{i}\",\"dram__bytes.sum\",{i},1\n"));
        }
        let out = ingest(&csv, IngestConfig::new());
        assert_eq!(out.stats.unique_kernels, 100);
        for i in 0..100 {
            let k = out.profile.kernel(&format!("kernel_{i}")).unwrap();
            assert_eq!(k.counters.get("dram__bytes.sum"), i as f64);
        }
    }

    #[test]
    fn strict_mode_propagates_row_errors_with_line_numbers() {
        let csv = format!("{HEADER}\"k\",\"m\",notanumber,1\n");
        let spec = GpuSpec::v100();
        let err = from_reader(&mut csv.as_bytes(), &spec, &IngestConfig::new()).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("line 2") && msg.contains("bad value"), "{msg}");
    }

    #[test]
    fn lenient_rejected_rows_still_count_in_stats() {
        let csv = format!("{HEADER}garbage,row\n\"k\",\"dram__bytes.sum\",1,1\n");
        let out = ingest(&csv, IngestConfig::new().lenient(true));
        assert_eq!(out.stats.rows, 2, "rejected rows are still rows");
        assert_eq!(out.stats.unique_kernels, 1);
        assert_eq!(out.diagnostics.total(), 1);
    }

    #[test]
    fn telemetry_arming_changes_no_output() {
        let csv = format!("{HEADER}\"k\",\"dram__bytes.sum\",5,2\n");
        let plain = ingest(&csv, IngestConfig::new());
        let tracer = crate::obs::Tracer::fixed();
        let metrics = crate::obs::MetricsRegistry::new();
        let armed = {
            let root = tracer.span("test");
            ingest(&csv, IngestConfig::new().with_span(&root).with_metrics(&metrics))
        };
        assert_eq!(armed.profile, plain.profile);
        assert_eq!(armed.stats, plain.stats);
        let names: Vec<String> =
            tracer.records().into_iter().map(|s| s.name).collect();
        assert!(names.contains(&"ingest".to_string()), "{names:?}");
        assert!(names.contains(&"ingest.chunk".to_string()), "{names:?}");
        assert!(names.contains(&"ingest.aggregate".to_string()), "{names:?}");
        assert_eq!(metrics.counter("ingest.rows"), 1);
        assert_eq!(metrics.counter("ingest.unique_kernels"), 1);
        assert_eq!(metrics.counter("ingest.bytes"), csv.len() as u64);
    }
}
