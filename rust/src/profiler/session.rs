//! Collection sessions: replaying a kernel trace to gather metrics.
//!
//! Mirrors the Nsight Compute behaviours the paper leans on (§II-B,
//! §III-B):
//!
//! * **kernel replay** — when the requested metrics need more hardware
//!   counters than one pass can gather, the kernel set is re-executed
//!   once per pass;
//! * **determinism check** — "these metrics can be collected on separate
//!   runs as well, as long as the execution of the application is
//!   deterministic"; the session verifies counters agree across passes
//!   and reports a [`SessionError::NonDeterministic`] otherwise (the
//!   paper hit this with TensorFlow autotuning and fixed it with
//!   tensorflow-determinism);
//! * **stream serialization** — "as of 2020.1.0, Nsight Compute
//!   serializes multi-stream execution": per-stream overlap is ignored
//!   when profiling (the schedule layer can still model overlap for
//!   un-profiled runs);
//! * **profiling overhead** — each pass costs a per-kernel replay setup;
//!   the session accounts it so `repro profile` can report overhead like
//!   the real tool.

use crate::device::GpuSpec;
use crate::profiler::metrics::{Metric, MetricRegistry};
use crate::profiler::profile::Profile;
use crate::sim::counters::names;
use crate::sim::kernel::KernelInvocation;
use crate::sim::{self, CounterSet};

/// Session configuration.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Metrics to collect (must resolve in the registry).
    pub metrics: Vec<String>,
    /// Collect one metric per application execution (the paper's §III-B
    /// protocol "to minimize the profiling overhead" distortion); when
    /// false, pack metrics into passes.
    pub one_metric_per_run: bool,
    /// Warm-up iterations excluded from collection (paper: 5-iteration
    /// warm-up loop before the profiled region).
    pub warmup_iterations: u32,
    /// Per-kernel, per-pass replay overhead in seconds.
    pub replay_overhead_s: f64,
    /// Inject nondeterminism (test hook modelling TF autotuning; the
    /// library user never sets this).
    pub nondeterminism: Option<u64>,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            metrics: names::STANDARD.iter().map(|s| s.to_string()).collect(),
            one_metric_per_run: false,
            warmup_iterations: 5,
            replay_overhead_s: 150e-6,
            nondeterminism: None,
        }
    }
}

/// Session failure modes.
#[derive(Debug)]
pub enum SessionError {
    Metric(crate::profiler::metrics::MetricError),
    NonDeterministic {
        kernel: String,
        metric: String,
        a: f64,
        b: f64,
    },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // Transparent: delegate to the wrapped metric error.
            SessionError::Metric(e) => write!(f, "{e}"),
            SessionError::NonDeterministic { kernel, metric, a, b } => write!(
                f,
                "non-deterministic execution detected for kernel '{kernel}' on metric \
                 '{metric}' across replay passes ({a} vs {b}); enable determinism \
                 (cf. tensorflow-determinism)"
            ),
        }
    }
}

impl std::error::Error for SessionError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // Transparent: Display already *is* the inner error, so the
            // source chain must continue past it (not repeat it).
            SessionError::Metric(e) => e.source(),
            SessionError::NonDeterministic { .. } => None,
        }
    }
}

impl From<crate::profiler::metrics::MetricError> for SessionError {
    fn from(e: crate::profiler::metrics::MetricError) -> SessionError {
        SessionError::Metric(e)
    }
}

/// A profiling session bound to a device.
pub struct Session<'a> {
    spec: &'a GpuSpec,
    registry: MetricRegistry,
    config: SessionConfig,
}

impl<'a> Session<'a> {
    pub fn new(spec: &'a GpuSpec, config: SessionConfig) -> Session<'a> {
        Session {
            spec,
            registry: MetricRegistry::standard(),
            config,
        }
    }

    /// Standard hierarchical-Roofline session: the full Table II set.
    pub fn standard(spec: &'a GpuSpec) -> Session<'a> {
        Session::new(spec, SessionConfig::default())
    }

    /// Profile a trace, aggregating by kernel name. Panics never; returns
    /// [`SessionError`] on unknown metrics or nondeterminism.
    pub fn try_profile(&self, trace: &[KernelInvocation]) -> Result<Profile, SessionError> {
        let metric_refs: Vec<&str> = self.config.metrics.iter().map(|s| s.as_str()).collect();
        let metrics = self.registry.resolve(&metric_refs)?;
        let passes: Vec<Vec<Metric>> = if self.config.one_metric_per_run {
            metrics.iter().map(|m| vec![m.clone()]).collect()
        } else {
            self.registry.plan_passes(&metrics)
        };

        let mut profile = Profile::new();
        profile.passes = passes.len() as u64;

        // Simulate each kernel once per pass; each pass observes its own
        // metric subset. Counters must agree across passes (determinism).
        //
        // Perf (§Perf L3-1 in EXPERIMENTS.md): when the execution target
        // is deterministic (no nondeterminism injected), all replay
        // passes observe identical counters, so the kernel is simulated
        // once and the counter set is reused across passes — the replay
        // accounting (overhead, pass census) is unchanged. With the
        // nondeterminism hook armed, every pass re-executes and the
        // cross-pass consistency check runs exactly as the real tool's
        // workflow requires.
        for inv in trace {
            let mut merged = CounterSet::new();
            let baseline = sim::simulate(self.spec, &inv.kernel);
            if self.config.nondeterminism.is_none() {
                // §Perf L3-3: deterministic fast path — no per-pass
                // counter clones; copy the requested metrics straight
                // from the single simulation.
                for pass in &passes {
                    for m in pass {
                        merged.set(&m.raw, baseline.get(&m.raw));
                    }
                }
                merged.set(names::CYCLES, baseline.get(names::CYCLES));
                merged.set(names::CYCLES_PER_SEC, baseline.get(names::CYCLES_PER_SEC));
                profile.record_scaled(&inv.kernel.name, inv.invocations, &merged, self.spec);
                profile.profiling_overhead_s +=
                    passes.len() as f64 * inv.invocations as f64 * self.config.replay_overhead_s;
                continue;
            }
            let mut reference: Option<CounterSet> = None;
            for (pass_idx, pass) in passes.iter().enumerate() {
                let observed = if let Some(seed) = self.config.nondeterminism {
                    // Model autotuning flakiness: perturb cycle counts per
                    // pass, as a re-autotuned algorithm would.
                    let mut fresh = sim::simulate(self.spec, &inv.kernel);
                    let jitter = 1.0
                        + 0.05
                            * (((seed
                                .wrapping_mul(pass_idx as u64 + 1)
                                .wrapping_mul(inv.kernel.name.len() as u64 + 1))
                                % 7) as f64);
                    fresh.set(names::CYCLES, fresh.get(names::CYCLES) * jitter);
                    // Determinism check on the time base, which every
                    // pass re-measures.
                    if let Some(ref first) = reference {
                        let a = first.get(names::CYCLES);
                        let b = fresh.get(names::CYCLES);
                        if (a - b).abs() > 1e-9 * a.abs().max(1.0) {
                            return Err(SessionError::NonDeterministic {
                                kernel: inv.kernel.name.clone(),
                                metric: names::CYCLES.to_string(),
                                a,
                                b,
                            });
                        }
                    } else {
                        reference = Some(fresh.clone());
                    }
                    fresh
                } else {
                    baseline.clone()
                };
                // Keep only this pass's metrics (plus the time base).
                for m in pass {
                    merged.set(&m.raw, observed.get(&m.raw));
                }
                merged.set(names::CYCLES, observed.get(names::CYCLES));
                merged.set(names::CYCLES_PER_SEC, observed.get(names::CYCLES_PER_SEC));
            }
            // One merged CounterSet scaled by the invocation count
            // (invocations of one kernel are identical in a deterministic
            // app) — §Perf L3-2: scale once instead of re-accumulating
            // per invocation.
            profile.record_scaled(&inv.kernel.name, inv.invocations, &merged, self.spec);
            profile.profiling_overhead_s +=
                passes.len() as f64 * inv.invocations as f64 * self.config.replay_overhead_s;
        }
        Ok(profile)
    }

    /// Convenience: standard sessions on valid traces cannot fail.
    pub fn profile(&self, trace: &[KernelInvocation]) -> Profile {
        self.try_profile(trace).expect("standard session must succeed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::sim::kernel::KernelDesc;

    fn trace() -> Vec<KernelInvocation> {
        vec![
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1),
                invocations: 4,
                stream: 0,
            },
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("cast", 1 << 18, Precision::Fp16, 0),
                invocations: 2,
                stream: 1,
            },
        ]
    }

    #[test]
    fn standard_session_collects_everything() {
        let spec = GpuSpec::v100();
        let p = Session::standard(&spec).profile(&trace());
        assert_eq!(p.n_kernels(), 2);
        assert_eq!(p.total_invocations(), 6);
        let relu = p.kernel("relu").unwrap();
        assert!(relu.flops() > 0.0);
        assert!(relu.seconds() > 0.0);
        assert!(p.kernel("cast").unwrap().is_zero_ai());
    }

    #[test]
    fn multi_pass_equals_single_pass_on_deterministic_app() {
        let spec = GpuSpec::v100();
        let packed = Session::standard(&spec).profile(&trace());
        let mut cfg = SessionConfig::default();
        cfg.one_metric_per_run = true;
        let separate = Session::new(&spec, cfg).profile(&trace());
        // "these metrics can be collected on separate runs as well, as
        // long as the execution ... is deterministic" (§II-B3).
        for k in packed.kernels() {
            let other = separate.kernel(&k.name).unwrap();
            assert!((k.flops() - other.flops()).abs() < 1e-6);
            assert!((k.seconds() - other.seconds()).abs() < 1e-12);
        }
    }

    #[test]
    fn one_metric_per_run_uses_more_passes_and_overhead() {
        let spec = GpuSpec::v100();
        let packed = Session::standard(&spec).profile(&trace());
        let mut cfg = SessionConfig::default();
        cfg.one_metric_per_run = true;
        let separate = Session::new(&spec, cfg).profile(&trace());
        assert!(separate.passes > packed.passes);
        assert!(separate.profiling_overhead_s > packed.profiling_overhead_s);
    }

    #[test]
    fn nondeterminism_detected() {
        let spec = GpuSpec::v100();
        let mut cfg = SessionConfig::default();
        cfg.nondeterminism = Some(1234);
        let err = Session::new(&spec, cfg).try_profile(&trace()).unwrap_err();
        assert!(matches!(err, SessionError::NonDeterministic { .. }), "{err}");
    }

    #[test]
    fn unknown_metric_rejected() {
        let spec = GpuSpec::v100();
        let mut cfg = SessionConfig::default();
        cfg.metrics = vec!["sm__no_such.sum".into()];
        let err = Session::new(&spec, cfg).try_profile(&trace()).unwrap_err();
        assert!(matches!(err, SessionError::Metric(_)));
    }

    #[test]
    fn empty_trace_empty_profile() {
        let spec = GpuSpec::v100();
        let p = Session::standard(&spec).profile(&[]);
        assert_eq!(p.n_kernels(), 0);
        assert_eq!(p.profiling_overhead_s, 0.0);
    }
}
