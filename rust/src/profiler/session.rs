//! Collection sessions: replaying a kernel trace to gather metrics.
//!
//! Mirrors the Nsight Compute behaviours the paper leans on (§II-B,
//! §III-B):
//!
//! * **kernel replay** — when the requested metrics need more hardware
//!   counters than one pass can gather, the kernel set is re-executed
//!   once per pass;
//! * **determinism check** — "these metrics can be collected on separate
//!   runs as well, as long as the execution of the application is
//!   deterministic"; the session verifies counters agree across passes
//!   and reports a [`SessionError::NonDeterministic`] otherwise (the
//!   paper hit this with TensorFlow autotuning and fixed it with
//!   tensorflow-determinism);
//! * **stream serialization** — "as of 2020.1.0, Nsight Compute
//!   serializes multi-stream execution": per-stream overlap is ignored
//!   when profiling (the schedule layer can still model overlap for
//!   un-profiled runs);
//! * **profiling overhead** — each pass costs a per-kernel replay setup;
//!   the session accounts it so `repro profile` can report overhead like
//!   the real tool.

use std::collections::HashMap;

use crate::device::GpuSpec;
use crate::profiler::metrics::{Metric, MetricRegistry};
use crate::profiler::profile::Profile;
use crate::sim::counters::{names, CounterId};
use crate::sim::cycles::CycleBreakdown;
use crate::sim::kernel::{KernelDesc, KernelInvocation};
use crate::sim::{self, CounterSet};

/// What to profile and how — the single argument to [`Session::run`],
/// replacing the old `try_profile` / `try_profile_shared` / `profile`
/// trio. Defaults: direct simulation (no shared cache), timing on.
///
/// ```text
/// session.run(&ProfileRequest::new(&trace))?                    // standalone, timed
/// session.run(&ProfileRequest::new(&trace).shared_cache(&c))?   // sweep-deduped
/// session.run(&ProfileRequest::new(&trace).counters_only())?    // pre-timeline behaviour
/// ```
#[derive(Clone, Copy)]
pub struct ProfileRequest<'a> {
    trace: &'a [KernelInvocation],
    cache: Option<&'a sim::SharedSimCache>,
    timing: bool,
    fault: Option<&'a crate::exec::FaultInjector>,
    span: Option<&'a crate::obs::Span>,
    metrics: Option<&'a crate::obs::MetricsRegistry>,
}

impl<'a> ProfileRequest<'a> {
    pub fn new(trace: &'a [KernelInvocation]) -> ProfileRequest<'a> {
        ProfileRequest {
            trace,
            cache: None,
            timing: true,
            fault: None,
            span: None,
            metrics: None,
        }
    }

    /// Route baseline simulations through a cross-session
    /// [`sim::SharedSimCache`]: a scenario sweep profiling many traces
    /// over one device simulates each distinct descriptor once for the
    /// *whole sweep*. Bit-identical to the standalone path (cached
    /// simulation is pure; test-asserted).
    pub fn shared_cache(mut self, cache: &'a sim::SharedSimCache) -> ProfileRequest<'a> {
        self.cache = Some(cache);
        self
    }

    /// Skip the per-kernel timing stamp ([`KernelProfile::timing`]
    /// stays `None`). Counters are identical either way — this exists
    /// for byte-identity cross-checks and to keep the hot path's
    /// historical baseline measurable.
    ///
    /// [`KernelProfile::timing`]: crate::profiler::profile::KernelProfile
    pub fn counters_only(mut self) -> ProfileRequest<'a> {
        self.timing = false;
        self
    }

    /// Arm a deterministic [`crate::exec::FaultInjector`] over the
    /// per-kernel simulation fan-out: each unique kernel applies the
    /// plan under the label `kernel:<name>` before simulating. Injected
    /// panics and errors surface as [`SessionError::Exec`] instead of
    /// unwinding — this is how every session failure path is exercised
    /// without real flakiness.
    pub fn fault_injector(mut self, injector: &'a crate::exec::FaultInjector) -> ProfileRequest<'a> {
        self.fault = Some(injector);
        self
    }

    /// Attach a parent [`crate::obs::Span`]: the run records a
    /// `profile` child span with per-phase and per-unique-kernel
    /// children under it. Telemetry is strictly additive — the profile
    /// is bit-identical with or without a span (test-asserted).
    pub fn with_span(mut self, span: &'a crate::obs::Span) -> ProfileRequest<'a> {
        self.span = Some(span);
        self
    }

    /// Attach a [`crate::obs::MetricsRegistry`]: the run counts
    /// `sim.kernels.simulated` / `sim.kernels.deduped` and the
    /// supervised fan-out's queue-wait/run-time/retry telemetry.
    pub fn with_metrics(mut self, metrics: &'a crate::obs::MetricsRegistry) -> ProfileRequest<'a> {
        self.metrics = Some(metrics);
        self
    }
}

/// Session configuration.
#[derive(Clone, Debug)]
pub struct SessionConfig {
    /// Metrics to collect (must resolve in the registry).
    pub metrics: Vec<String>,
    /// Collect one metric per application execution (the paper's §III-B
    /// protocol "to minimize the profiling overhead" distortion); when
    /// false, pack metrics into passes.
    pub one_metric_per_run: bool,
    /// Warm-up iterations excluded from collection (paper: 5-iteration
    /// warm-up loop before the profiled region).
    pub warmup_iterations: u32,
    /// Per-kernel, per-pass replay overhead in seconds.
    pub replay_overhead_s: f64,
    /// Inject nondeterminism (test hook modelling TF autotuning; the
    /// library user never sets this).
    pub nondeterminism: Option<u64>,
    /// Memoize simulation across identical kernel descriptors: a trace
    /// with N invocations of K distinct kernels costs K simulations,
    /// not N. Valid because simulation is a pure function of the
    /// descriptor — output is bit-identical either way (test-asserted).
    /// Disable only to cross-check that equivalence.
    pub memoize: bool,
    /// Worker threads for the trace fan-out; `None` = automatic (serial
    /// for small traces, machine-sized for large ones). Per-entry work
    /// is pure and aggregation preserves trace order, so the profile is
    /// bit-identical for every setting (test-asserted).
    pub threads: Option<usize>,
    /// Retry budget for *transient* per-kernel simulation failures
    /// (e.g. a flaky counter read scripted by a fault plan). The
    /// default is no retries; real collection wrappers typically want
    /// 2–3 attempts (cf. Nsight replay-failure retries).
    pub retry: crate::exec::RetryPolicy,
}

impl Default for SessionConfig {
    fn default() -> Self {
        SessionConfig {
            metrics: names::STANDARD.iter().map(|s| s.to_string()).collect(),
            one_metric_per_run: false,
            warmup_iterations: 5,
            replay_overhead_s: 150e-6,
            nondeterminism: None,
            memoize: true,
            threads: None,
            retry: crate::exec::RetryPolicy::none(),
        }
    }
}

/// Session failure modes.
#[derive(Debug)]
pub enum SessionError {
    Metric(crate::profiler::metrics::MetricError),
    NonDeterministic {
        kernel: String,
        metric: String,
        a: f64,
        b: f64,
    },
    /// A kernel's supervised simulation failed (panicked, timed out, or
    /// exhausted its retry budget). The first failing kernel in trace
    /// order wins, matching a serial collection scan.
    Exec {
        kernel: String,
        error: crate::exec::ExecError,
    },
}

impl std::fmt::Display for SessionError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            // Transparent: delegate to the wrapped metric error.
            SessionError::Metric(e) => write!(f, "{e}"),
            SessionError::NonDeterministic { kernel, metric, a, b } => write!(
                f,
                "non-deterministic execution detected for kernel '{kernel}' on metric \
                 '{metric}' across replay passes ({a} vs {b}); enable determinism \
                 (cf. tensorflow-determinism)"
            ),
            SessionError::Exec { kernel, error } => {
                write!(f, "simulation of kernel '{kernel}' {error}")
            }
        }
    }
}

impl std::error::Error for SessionError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // Transparent: Display already *is* the inner error, so the
            // source chain must continue past it (not repeat it).
            SessionError::Metric(e) => e.source(),
            SessionError::NonDeterministic { .. } | SessionError::Exec { .. } => None,
        }
    }
}

impl From<crate::profiler::metrics::MetricError> for SessionError {
    fn from(e: crate::profiler::metrics::MetricError) -> SessionError {
        SessionError::Metric(e)
    }
}

/// A profiling session bound to a device.
pub struct Session<'a> {
    spec: &'a GpuSpec,
    registry: MetricRegistry,
    config: SessionConfig,
}

impl<'a> Session<'a> {
    pub fn new(spec: &'a GpuSpec, config: SessionConfig) -> Session<'a> {
        Session {
            spec,
            registry: MetricRegistry::standard(),
            config,
        }
    }

    /// Standard hierarchical-Roofline session: the full Table II set.
    pub fn standard(spec: &'a GpuSpec) -> Session<'a> {
        Session::new(spec, SessionConfig::default())
    }

    /// Profile a request's trace, aggregating by kernel name. Panics
    /// never; returns [`SessionError`] on unknown metrics or
    /// nondeterminism. This is the single profiling entry point — build
    /// a [`ProfileRequest`] to pick standalone vs shared-cache
    /// simulation and whether to stamp kernels with model-attributed
    /// timing.
    ///
    /// Hot-path structure (§Perf L3 in EXPERIMENTS.md):
    ///
    /// 1. **Dedup + memoize** — identical kernel descriptors share one
    ///    simulation (K simulations for N entries); valid because
    ///    simulation is pure, disabled when the nondeterminism hook is
    ///    armed (each pass must then genuinely re-execute).
    /// 2. **Fan out** — the unique-kernel simulations run through the
    ///    supervised [`crate::exec::parallel_try_map`] (panic-isolated,
    ///    retryable, fault-injectable) and the per-entry pass merges
    ///    through [`crate::exec::parallel_map`]; every unit of work is
    ///    pure, so parallelism cannot change the result.
    /// 3. **Order-preserving aggregation** — merged counter sets (and
    ///    timing, when requested) are recorded into the [`Profile`]
    ///    strictly in trace order, making the output bit-identical to
    ///    the serial path (test-asserted, like PR 1's ERT sweep).
    pub fn run(&self, req: &ProfileRequest<'_>) -> Result<Profile, SessionError> {
        match req.cache {
            Some(cache) => {
                self.profile_with(req.trace, req.timing, req.fault, req.span, req.metrics, &|k| {
                    cache.get_or_simulate_timed(self.spec, k)
                })
            }
            None => {
                self.profile_with(req.trace, req.timing, req.fault, req.span, req.metrics, &|k| {
                    sim::simulate_timed(self.spec, k)
                })
            }
        }
    }

    /// Former entry point; use [`Session::run`].
    #[deprecated(since = "0.6.0", note = "use Session::run(&ProfileRequest::new(trace))")]
    pub fn try_profile(&self, trace: &[KernelInvocation]) -> Result<Profile, SessionError> {
        self.run(&ProfileRequest::new(trace))
    }

    /// Former shared-cache entry point; use [`Session::run`] with
    /// [`ProfileRequest::shared_cache`].
    #[deprecated(
        since = "0.6.0",
        note = "use Session::run(&ProfileRequest::new(trace).shared_cache(cache))"
    )]
    pub fn try_profile_shared(
        &self,
        trace: &[KernelInvocation],
        cache: &sim::SharedSimCache,
    ) -> Result<Profile, SessionError> {
        self.run(&ProfileRequest::new(trace).shared_cache(cache))
    }

    /// Core profiling path, parameterized on how a kernel descriptor
    /// becomes baseline counters + timing (direct simulation or a
    /// shared cache).
    fn profile_with(
        &self,
        trace: &[KernelInvocation],
        timing: bool,
        fault: Option<&crate::exec::FaultInjector>,
        span: Option<&crate::obs::Span>,
        obs_metrics: Option<&crate::obs::MetricsRegistry>,
        simulate_kernel: &(dyn Fn(&KernelDesc) -> (CounterSet, CycleBreakdown) + Sync),
    ) -> Result<Profile, SessionError> {
        // Telemetry is observational only: spans and counters must not
        // influence a single byte of the profile (pinned by
        // rust/tests/trace_semantics.rs).
        let mut run_span = match span {
            Some(s) => s.child("profile"),
            None => crate::obs::Span::disabled(),
        };
        run_span.set("trace_entries", trace.len().to_string());

        let metric_refs: Vec<&str> = self.config.metrics.iter().map(|s| s.as_str()).collect();
        let metrics = self.registry.resolve(&metric_refs)?;
        let passes: Vec<Vec<Metric>> = if self.config.one_metric_per_run {
            metrics.iter().map(|m| vec![m.clone()]).collect()
        } else {
            self.registry.plan_passes(&metrics)
        };

        let mut profile = Profile::for_device(self.spec);
        profile.passes = passes.len() as u64;
        if trace.is_empty() {
            return Ok(profile);
        }
        let deterministic = self.config.nondeterminism.is_none();

        // 1. Baseline simulations, one per distinct kernel descriptor.
        // `baseline_of[i]` maps trace entry i to its slot in `baselines`.
        let dedup_span = run_span.child("dedup");
        let mut unique: Vec<&KernelDesc> = Vec::new();
        let mut baseline_of: Vec<usize> = Vec::with_capacity(trace.len());
        if deterministic && self.config.memoize {
            let mut seen: HashMap<&KernelDesc, usize> = HashMap::new();
            for inv in trace {
                let next = unique.len();
                let idx = *seen.entry(&inv.kernel).or_insert(next);
                if idx == next {
                    unique.push(&inv.kernel);
                }
                baseline_of.push(idx);
            }
        } else if deterministic {
            for (i, inv) in trace.iter().enumerate() {
                unique.push(&inv.kernel);
                baseline_of.push(i);
            }
        }
        drop(dedup_span);
        if let Some(m) = obs_metrics {
            m.add("sim.kernels.simulated", unique.len() as u64);
            // `baseline_of` is empty on the nondeterministic path, so
            // this is 0 there (nothing was deduped — nothing ran yet).
            m.add("sim.kernels.deduped", (baseline_of.len() - unique.len()) as u64);
        }
        // The baseline fan-out runs supervised: a panic inside one
        // kernel's simulation (or an injected fault) becomes a
        // structured `SessionError::Exec` instead of unwinding through
        // the whole session — the isolation boundary matrix cells rely
        // on. With no faults armed the work function is infallible, so
        // the output (and thus the profile) is bit-identical to the old
        // `parallel_map` path (test-asserted).
        let sim_workers = self.workers_for(unique.len());
        let policy = crate::exec::SupervisePolicy {
            retry: self.config.retry,
            ..Default::default()
        };
        // Cheap Vec-of-refs clone, kept for error attribution by index.
        let kernel_of = unique.clone();
        let sim_span = run_span.child("simulate");
        let sim_results =
            crate::exec::parallel_try_map_observed(unique, sim_workers, &policy, obs_metrics, |k| {
                let mut kernel_span = sim_span.child("kernel");
                kernel_span.set("kernel", k.name.as_str());
                if let Some(inj) = fault {
                    inj.apply(&format!("kernel:{}", k.name))?;
                }
                Ok(simulate_kernel(k))
            });
        drop(sim_span);
        let mut baselines: Vec<(CounterSet, CycleBreakdown)> =
            Vec::with_capacity(sim_results.len());
        for (idx, result) in sim_results.into_iter().enumerate() {
            match result {
                Ok(b) => baselines.push(b),
                Err(error) => {
                    return Err(SessionError::Exec {
                        kernel: kernel_of[idx].name.clone(),
                        error,
                    })
                }
            }
        }

        // 2. Merge each entry's replay passes (pure per entry; with the
        // nondeterminism hook armed, `baseline = None` forces per-pass
        // re-execution plus the cross-pass consistency check).
        let merge_span = run_span.child("merge");
        let entries: Vec<(usize, &KernelInvocation)> = trace.iter().enumerate().collect();
        let merge_workers = self.workers_for(entries.len());
        let merged: Vec<Result<CounterSet, SessionError>> =
            crate::exec::parallel_map(entries, merge_workers, |(i, inv)| {
                let baseline = deterministic.then(|| &baselines[baseline_of[i]].0);
                self.merge_replay_passes(inv, &passes, baseline)
            });
        drop(merge_span);

        // 3. Aggregate in trace order; the first failing entry (in trace
        // order) wins, exactly as a serial scan would report.
        let aggregate_span = run_span.child("aggregate");
        for (i, (inv, counters)) in trace.iter().zip(merged).enumerate() {
            // One merged CounterSet scaled by the invocation count
            // (invocations of one kernel are identical in a
            // deterministic app) — §Perf L3-2: scale once instead of
            // re-accumulating per invocation.
            let counters = counters?;
            profile.record_scaled(&inv.kernel.name, inv.invocations, &counters, self.spec);
            if timing {
                // Deterministic runs reuse the baseline breakdown; the
                // nondeterministic path (jittered counters) recomputes
                // the pure model attribution per entry.
                let b = if deterministic {
                    baselines[baseline_of[i]].1
                } else {
                    sim::breakdown_of(self.spec, &inv.kernel)
                };
                profile.record_timing(&inv.kernel.name, inv.invocations, &b, self.spec);
            }
            profile.profiling_overhead_s +=
                passes.len() as f64 * inv.invocations as f64 * self.config.replay_overhead_s;
        }
        drop(aggregate_span);
        Ok(profile)
    }

    /// Merge one trace entry's replay passes into a single counter set;
    /// each pass observes its own metric subset plus the time base.
    ///
    /// `baseline = Some(c)`: deterministic execution — every pass
    /// observes the same counters `c` (simulated once, possibly shared
    /// across entries by the memoizer), so requested metrics are copied
    /// straight out of it with no per-pass clone.
    /// `baseline = None`: every pass re-executes the kernel and the
    /// determinism check runs, as the real tool's workflow requires.
    fn merge_replay_passes(
        &self,
        inv: &KernelInvocation,
        passes: &[Vec<Metric>],
        baseline: Option<&CounterSet>,
    ) -> Result<CounterSet, SessionError> {
        let mut merged = CounterSet::new();
        let mut reference_cycles: Option<f64> = None;
        for (pass_idx, pass) in passes.iter().enumerate() {
            let replayed;
            let observed = match baseline {
                Some(c) => c,
                None => {
                    replayed = self.replay_once(inv, pass_idx, &mut reference_cycles)?;
                    &replayed
                }
            };
            // Keep only this pass's metrics (plus the time base).
            for m in pass {
                match m.id {
                    Some(id) => merged.set_id(id, observed.get_id(id)),
                    None => merged.set(&m.raw, observed.get(&m.raw)),
                }
            }
            merged.set_id(CounterId::Cycles, observed.get_id(CounterId::Cycles));
            merged.set_id(
                CounterId::CyclesPerSec,
                observed.get_id(CounterId::CyclesPerSec),
            );
        }
        Ok(merged)
    }

    /// Re-execute one kernel for one replay pass with the nondeterminism
    /// hook armed, and verify the time base agrees across passes.
    fn replay_once(
        &self,
        inv: &KernelInvocation,
        pass_idx: usize,
        reference_cycles: &mut Option<f64>,
    ) -> Result<CounterSet, SessionError> {
        let seed = self
            .config
            .nondeterminism
            .expect("replay_once requires the nondeterminism hook");
        // Model autotuning flakiness: perturb cycle counts per pass, as
        // a re-autotuned algorithm would.
        let mut fresh = sim::simulate(self.spec, &inv.kernel);
        let jitter = 1.0
            + 0.05
                * (((seed
                    .wrapping_mul(pass_idx as u64 + 1)
                    .wrapping_mul(inv.kernel.name.len() as u64 + 1))
                    % 7) as f64);
        fresh.set_id(CounterId::Cycles, fresh.get_id(CounterId::Cycles) * jitter);
        // Determinism check on the time base, which every pass
        // re-measures.
        let b = fresh.get_id(CounterId::Cycles);
        match *reference_cycles {
            Some(a) => {
                if (a - b).abs() > 1e-9 * a.abs().max(1.0) {
                    return Err(SessionError::NonDeterministic {
                        kernel: inv.kernel.name.clone(),
                        metric: names::CYCLES.to_string(),
                        a,
                        b,
                    });
                }
            }
            None => *reference_cycles = Some(b),
        }
        Ok(fresh)
    }

    /// Worker count for a fan-out of `items` units: explicit override,
    /// else serial below the point where thread spawn costs more than
    /// the work, else machine-sized (capped by the item count — more
    /// workers than items would idle).
    fn workers_for(&self, items: usize) -> usize {
        match self.config.threads {
            Some(n) => n.max(1),
            None if items < 32 => 1,
            None => crate::exec::default_workers(items),
        }
    }

    /// Former panicking convenience; use [`Session::run`] and handle
    /// (or `.expect`) the `Result`.
    #[deprecated(
        since = "0.6.0",
        note = "use Session::run(&ProfileRequest::new(trace)) and handle the Result"
    )]
    pub fn profile(&self, trace: &[KernelInvocation]) -> Profile {
        self.run(&ProfileRequest::new(trace)).expect("standard session must succeed")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::sim::kernel::KernelDesc;

    /// The common case in tests: standalone timed run, must succeed.
    fn profiled(session: &Session, t: &[KernelInvocation]) -> Profile {
        session.run(&ProfileRequest::new(t)).unwrap()
    }

    fn trace() -> Vec<KernelInvocation> {
        vec![
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1),
                invocations: 4,
                stream: 0,
            },
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise("cast", 1 << 18, Precision::Fp16, 0),
                invocations: 2,
                stream: 1,
            },
        ]
    }

    #[test]
    fn standard_session_collects_everything() {
        let spec = GpuSpec::v100();
        let p = profiled(&Session::standard(&spec), &trace());
        assert_eq!(p.n_kernels(), 2);
        assert_eq!(p.total_invocations(), 6);
        let relu = p.kernel("relu").unwrap();
        assert!(relu.flops() > 0.0);
        assert!(relu.seconds() > 0.0);
        assert!(relu.timing.is_some(), "run() stamps timing by default");
        assert!(p.kernel("cast").unwrap().is_zero_ai());
    }

    #[test]
    fn multi_pass_equals_single_pass_on_deterministic_app() {
        let spec = GpuSpec::v100();
        let packed = profiled(&Session::standard(&spec), &trace());
        let cfg = SessionConfig { one_metric_per_run: true, ..Default::default() };
        let separate = profiled(&Session::new(&spec, cfg), &trace());
        // "these metrics can be collected on separate runs as well, as
        // long as the execution ... is deterministic" (§II-B3).
        for k in packed.kernels() {
            let other = separate.kernel(&k.name).unwrap();
            assert!((k.flops() - other.flops()).abs() < 1e-6);
            assert!((k.seconds() - other.seconds()).abs() < 1e-12);
        }
    }

    #[test]
    fn one_metric_per_run_uses_more_passes_and_overhead() {
        let spec = GpuSpec::v100();
        let packed = profiled(&Session::standard(&spec), &trace());
        let cfg = SessionConfig { one_metric_per_run: true, ..Default::default() };
        let separate = profiled(&Session::new(&spec, cfg), &trace());
        assert!(separate.passes > packed.passes);
        assert!(separate.profiling_overhead_s > packed.profiling_overhead_s);
    }

    /// A trace exercising the memoizer: distinct descriptors plus exact
    /// duplicates under different entries/streams.
    fn trace_with_duplicates() -> Vec<KernelInvocation> {
        let mut t = trace();
        t.push(KernelInvocation {
            kernel: KernelDesc::streaming_elementwise("relu", 1 << 18, Precision::Fp32, 1),
            invocations: 3,
            stream: 2,
        });
        t.push(KernelInvocation {
            kernel: KernelDesc::gemm(
                "hmma", 512, 512, 512, Precision::Fp16, true, 64, &GpuSpec::v100(),
            ),
            invocations: 2,
            stream: 0,
        });
        t
    }

    #[test]
    fn memoized_profile_identical_to_unmemoized() {
        // Regression: the simulation memoizer must not change a single
        // bit of the profile (simulation is pure, so a cached baseline
        // equals a fresh one exactly).
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let memoized = profiled(&Session::standard(&spec), &t);
        let cfg = SessionConfig { memoize: false, threads: Some(1), ..Default::default() };
        let unmemoized = profiled(&Session::new(&spec, cfg), &t);
        assert_eq!(memoized, unmemoized);
    }

    #[test]
    fn shared_cache_profile_identical_to_plain_profile() {
        // The cross-session memoizer must not change a single bit
        // (timing included — Profile equality covers it), and a second
        // session over the same cache must re-simulate nothing.
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let plain = profiled(&Session::standard(&spec), &t);
        let cache = sim::SharedSimCache::new();
        let session = Session::standard(&spec);
        let shared = session.run(&ProfileRequest::new(&t).shared_cache(&cache)).unwrap();
        assert_eq!(shared, plain);
        let first_sims = cache.stats().1;
        assert_eq!(first_sims as usize, cache.len());
        let again = session.run(&ProfileRequest::new(&t).shared_cache(&cache)).unwrap();
        assert_eq!(again, plain);
        assert_eq!(cache.stats().1, first_sims, "second run fully cached");
    }

    #[test]
    fn parallel_profile_bit_identical_to_serial() {
        // Like PR 1's ERT sweep: the fan-out is pure and aggregation is
        // order-preserving, so thread count cannot change the output.
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let serial_cfg = SessionConfig { threads: Some(1), ..Default::default() };
        let serial = profiled(&Session::new(&spec, serial_cfg), &t);
        for threads in [2, 4, 8] {
            let cfg = SessionConfig { threads: Some(threads), ..Default::default() };
            let parallel = profiled(&Session::new(&spec, cfg), &t);
            assert_eq!(parallel, serial, "threads={threads}");
        }
    }

    #[test]
    fn counters_only_run_differs_only_in_timing() {
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let session = Session::standard(&spec);
        let timed = profiled(&session, &t);
        let plain = session.run(&ProfileRequest::new(&t).counters_only()).unwrap();
        assert_ne!(timed, plain, "timing is the only difference, but it is one");
        for k in timed.kernels() {
            let other = plain.kernel(&k.name).unwrap();
            assert_eq!(k.counters, other.counters, "{}", k.name);
            assert_eq!(k.invocations, other.invocations);
            assert!(k.timing.is_some() && other.timing.is_none());
        }
        assert_eq!(timed.profiling_overhead_s, plain.profiling_overhead_s);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_match_run() {
        // The migration shims must stay behaviourally identical to the
        // new surface until they are removed.
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let session = Session::standard(&spec);
        let reference = profiled(&session, &t);
        assert_eq!(session.profile(&t), reference);
        assert_eq!(session.try_profile(&t).unwrap(), reference);
        let cache = sim::SharedSimCache::new();
        assert_eq!(session.try_profile_shared(&t, &cache).unwrap(), reference);
    }

    #[test]
    fn traced_run_is_bit_identical_and_well_formed() {
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let session = Session::standard(&spec);
        let clean = profiled(&session, &t);
        let tracer = crate::obs::Tracer::fixed();
        let metrics = crate::obs::MetricsRegistry::new();
        let traced = {
            let root = tracer.span("test");
            session
                .run(&ProfileRequest::new(&t).with_span(&root).with_metrics(&metrics))
                .unwrap()
        };
        assert_eq!(traced, clean, "telemetry must not change the profile");
        let records = tracer.records();
        assert!(records.iter().any(|s| s.name == "profile"));
        for phase in ["dedup", "simulate", "merge", "aggregate"] {
            assert!(records.iter().any(|s| s.name == phase), "missing phase span {phase}");
        }
        // 4 trace entries, 3 distinct kernel descriptors (one dup relu).
        assert_eq!(records.iter().filter(|s| s.name == "kernel").count(), 3);
        assert_eq!(metrics.counter("sim.kernels.simulated"), 3);
        assert_eq!(metrics.counter("sim.kernels.deduped"), 1);
        assert_eq!(metrics.snapshot().histograms["exec.run_s"].count, 3);
    }

    #[test]
    fn nondeterminism_detected_under_parallel_fanout() {
        let spec = GpuSpec::v100();
        let cfg = SessionConfig {
            nondeterminism: Some(1234),
            threads: Some(4),
            ..Default::default()
        };
        let err =
            Session::new(&spec, cfg).run(&ProfileRequest::new(&trace())).unwrap_err();
        assert!(matches!(err, SessionError::NonDeterministic { .. }), "{err}");
    }

    #[test]
    fn nondeterminism_detected() {
        let spec = GpuSpec::v100();
        let cfg = SessionConfig { nondeterminism: Some(1234), ..Default::default() };
        let err =
            Session::new(&spec, cfg).run(&ProfileRequest::new(&trace())).unwrap_err();
        assert!(matches!(err, SessionError::NonDeterministic { .. }), "{err}");
    }

    #[test]
    fn unknown_metric_rejected() {
        let spec = GpuSpec::v100();
        let cfg = SessionConfig {
            metrics: vec!["sm__no_such.sum".into()],
            ..Default::default()
        };
        let err =
            Session::new(&spec, cfg).run(&ProfileRequest::new(&trace())).unwrap_err();
        assert!(matches!(err, SessionError::Metric(_)));
    }

    #[test]
    fn injected_kernel_panic_becomes_structured_error() {
        let spec = GpuSpec::v100();
        let session = Session::standard(&spec);
        let t = trace();
        let inj =
            crate::exec::FaultInjector::new(crate::exec::FaultPlan::new(0).panic_on("kernel:cast"));
        let err = session.run(&ProfileRequest::new(&t).fault_injector(&inj)).unwrap_err();
        match &err {
            SessionError::Exec { kernel, error } => {
                assert_eq!(kernel, "cast");
                assert_eq!(error.kind(), "panicked");
            }
            other => panic!("expected Exec error, got {other:?}"),
        }
        assert!(err.to_string().contains("cast"), "{err}");
    }

    #[test]
    fn retry_budget_rides_out_transient_kernel_faults() {
        let spec = GpuSpec::v100();
        let t = trace();
        let clean = profiled(&Session::standard(&spec), &t);
        // Fail the first simulation attempt of every kernel; with no
        // retry budget the session fails...
        let inj =
            crate::exec::FaultInjector::new(crate::exec::FaultPlan::new(0).fail_first("kernel:", 1));
        let session = Session::standard(&spec);
        let err = session.run(&ProfileRequest::new(&t).fault_injector(&inj)).unwrap_err();
        assert!(matches!(err, SessionError::Exec { .. }), "{err}");
        // ...and with two attempts the retry clears the fault and the
        // profile is identical to a fault-free run.
        let inj =
            crate::exec::FaultInjector::new(crate::exec::FaultPlan::new(0).fail_first("kernel:", 1));
        let cfg =
            SessionConfig { retry: crate::exec::RetryPolicy::attempts(2), ..Default::default() };
        let retried = Session::new(&spec, cfg)
            .run(&ProfileRequest::new(&t).fault_injector(&inj))
            .unwrap();
        assert_eq!(retried, clean);
    }

    #[test]
    fn armed_but_non_matching_injector_changes_nothing() {
        let spec = GpuSpec::v100();
        let t = trace_with_duplicates();
        let session = Session::standard(&spec);
        let clean = profiled(&session, &t);
        let inj = crate::exec::FaultInjector::new(
            crate::exec::FaultPlan::new(7).panic_on("kernel:no-such-kernel"),
        );
        let supervised =
            session.run(&ProfileRequest::new(&t).fault_injector(&inj)).unwrap();
        assert_eq!(supervised, clean);
    }

    #[test]
    fn empty_trace_empty_profile() {
        let spec = GpuSpec::v100();
        let p = profiled(&Session::standard(&spec), &[]);
        assert_eq!(p.n_kernels(), 0);
        assert_eq!(p.profiling_overhead_s, 0.0);
    }

    #[test]
    fn profiles_are_stamped_with_the_session_device() {
        let v100 = GpuSpec::v100();
        assert_eq!(profiled(&Session::standard(&v100), &trace()).device, "V100-SXM2-16GB");
        let a100 = GpuSpec::a100();
        assert_eq!(profiled(&Session::standard(&a100), &trace()).device, "A100-SXM4-40GB");
    }
}
