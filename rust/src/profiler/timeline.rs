//! Step timelines: where the milliseconds of a training step went
//! (Wang et al., *Time-Based Roofline for Deep Learning Performance
//! Analysis*, arXiv 2009.04598). A [`StepTimeline`] folds one
//! [`Profile`] per phase (forward / backward / optimizer) into
//! [`PhaseSlice`]s — per-phase elapsed time partitioned into compute-,
//! memory- and overhead-bound buckets via each kernel's
//! [`Bound`](crate::sim::Bound) — plus the step-wide idle (launch/
//! drain ramp) component. Rendering lives in
//! [`crate::roofline::time`].
//!
//! Phase labels are plain strings so the profiler layer stays
//! independent of `dl::lower::Phase`; callers pass `phase.name()`.

use crate::profiler::profile::Profile;
use crate::sim::cycles::Bound;

/// One phase's slice of the step: elapsed seconds plus the
/// bound-bucket partition. The three buckets (`compute_s`, `memory_s`,
/// `overhead_s`) partition `seconds` exactly — each kernel's full
/// elapsed time lands in the single bucket its [`Bound`] names.
/// `ramp_s` is a *component* (launch/drain cycles inside every
/// kernel), not a fourth bucket.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseSlice {
    pub label: String,
    /// Elapsed seconds of the phase (sum of kernel durations).
    pub seconds: f64,
    /// Seconds spent in compute-bound kernels.
    pub compute_s: f64,
    /// Seconds spent in memory-bound kernels.
    pub memory_s: f64,
    /// Seconds spent in overhead-bound kernels (ramp dominates the
    /// body). Kernels without timing data also land here.
    pub overhead_s: f64,
    /// Launch/drain ramp seconds across all kernels of the phase.
    pub ramp_s: f64,
    /// Distinct kernels in the phase.
    pub kernels: usize,
    /// Total kernel invocations in the phase.
    pub invocations: u64,
}

/// A training step assembled from per-phase profiles.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StepTimeline {
    /// Device the step ran on (from the first profile's stamp, or set
    /// via [`StepTimeline::new`]).
    pub device: String,
    pub phases: Vec<PhaseSlice>,
}

impl StepTimeline {
    pub fn new(device: &str) -> StepTimeline {
        StepTimeline {
            device: device.to_string(),
            phases: Vec::new(),
        }
    }

    /// Fold one phase's profile into a [`PhaseSlice`] and append it.
    /// Empty profiles produce a zero slice — a TF step keeps its
    /// (empty) optimizer row rather than dropping the phase.
    pub fn push_phase(&mut self, label: &str, profile: &Profile) {
        if self.device.is_empty() {
            self.device = profile.device.clone();
        }
        let mut slice = PhaseSlice {
            label: label.to_string(),
            ..PhaseSlice::default()
        };
        for k in profile.kernels() {
            let d = k.duration_s();
            slice.seconds += d;
            match k.bound().unwrap_or(Bound::Overhead) {
                Bound::Compute => slice.compute_s += d,
                Bound::Memory => slice.memory_s += d,
                Bound::Overhead => slice.overhead_s += d,
            }
            if let Some(t) = &k.timing {
                slice.ramp_s += t.ramp_s;
            }
            slice.kernels += 1;
            slice.invocations += k.invocations;
        }
        self.phases.push(slice);
    }

    /// Build a timeline from `(label, profile)` pairs in step order.
    pub fn from_phases<'a, I>(device: &str, phases: I) -> StepTimeline
    where
        I: IntoIterator<Item = (&'a str, &'a Profile)>,
    {
        let mut t = StepTimeline::new(device);
        for (label, p) in phases {
            t.push_phase(label, p);
        }
        t
    }

    /// Total step time: the sum of phase times (per-phase times sum to
    /// this by construction).
    pub fn step_seconds(&self) -> f64 {
        self.phases.iter().map(|p| p.seconds).sum()
    }

    /// Step-wide idle time: launch/drain ramp summed over every kernel
    /// invocation. A component of `step_seconds`, not an addition to it.
    pub fn idle_seconds(&self) -> f64 {
        self.phases.iter().map(|p| p.ramp_s).sum()
    }

    /// Step-wide `(compute, memory, overhead)` bucket seconds.
    pub fn bucket_seconds(&self) -> (f64, f64, f64) {
        self.phases.iter().fold((0.0, 0.0, 0.0), |acc, p| {
            (acc.0 + p.compute_s, acc.1 + p.memory_s, acc.2 + p.overhead_s)
        })
    }

    /// Total distinct kernels across phases (phases are separate
    /// profiles, so a kernel appearing in two phases counts twice).
    pub fn total_kernels(&self) -> usize {
        self.phases.iter().map(|p| p.kernels).sum()
    }

    /// Total invocations across phases.
    pub fn total_invocations(&self) -> u64 {
        self.phases.iter().map(|p| p.invocations).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::{GpuSpec, Precision};
    use crate::sim::{self, KernelDesc};

    fn timed_profile(spec: &GpuSpec, kernels: &[(&str, u64, KernelDesc)]) -> Profile {
        let mut p = Profile::for_device(spec);
        for (name, inv, k) in kernels {
            let (c, b) = sim::simulate_timed(spec, k);
            p.record_scaled(name, *inv, &c, spec);
            p.record_timing(name, *inv, &b, spec);
        }
        p
    }

    #[test]
    fn buckets_partition_phase_time() {
        let spec = GpuSpec::v100();
        let fwd = timed_profile(
            &spec,
            &[
                (
                    "gemm",
                    4,
                    KernelDesc::gemm("gemm", 1024, 1024, 1024, Precision::Fp16, true, 64, &spec),
                ),
                ("relu", 8, KernelDesc::streaming_elementwise("relu", 1 << 20, Precision::Fp32, 1)),
                ("tiny", 2, KernelDesc::streaming_elementwise("tiny", 64, Precision::Fp32, 0)),
            ],
        );
        let mut t = StepTimeline::new("");
        t.push_phase("forward", &fwd);
        assert_eq!(t.device, spec.name, "device picked up from the profile");
        let s = &t.phases[0];
        let parts = s.compute_s + s.memory_s + s.overhead_s;
        assert!((parts - s.seconds).abs() <= 1e-12 * s.seconds, "buckets partition the phase");
        assert!(s.compute_s > 0.0, "tensor GEMM is compute-bound");
        assert!(s.memory_s > 0.0, "streaming relu is memory-bound");
        assert!(s.overhead_s > 0.0, "tiny kernel is ramp-dominated");
        assert!(s.ramp_s > 0.0 && s.ramp_s < s.seconds);
        assert_eq!(s.kernels, 3);
        assert_eq!(s.invocations, 14);
    }

    #[test]
    fn phase_times_sum_to_step_total_and_empty_phases_survive() {
        let spec = GpuSpec::v100();
        let a = timed_profile(
            &spec,
            &[("x", 2, KernelDesc::streaming_elementwise("x", 1 << 16, Precision::Fp32, 1))],
        );
        let b = timed_profile(
            &spec,
            &[("y", 3, KernelDesc::streaming_elementwise("y", 1 << 18, Precision::Fp16, 2))],
        );
        let empty = Profile::for_device(&spec);
        let t = StepTimeline::from_phases(
            &spec.name,
            [("forward", &a), ("backward", &b), ("optimizer", &empty)],
        );
        assert_eq!(t.phases.len(), 3, "empty optimizer keeps its row");
        assert_eq!(t.phases[2].seconds, 0.0);
        let by_phase: f64 = t.phases.iter().map(|p| p.seconds).sum();
        assert_eq!(t.step_seconds(), by_phase);
        let want = a.total_seconds() + b.total_seconds();
        assert!((t.step_seconds() - want).abs() <= 1e-9 * want);
        assert!(t.idle_seconds() > 0.0);
        assert!(t.idle_seconds() < t.step_seconds());
    }

    #[test]
    fn untimed_profiles_fall_into_overhead_bucket() {
        // Hand-assembled / CSV-imported profiles carry no timing; the
        // timeline still renders, attributing them to overhead.
        let spec = GpuSpec::v100();
        let k = KernelDesc::streaming_elementwise("z", 1 << 18, Precision::Fp32, 1);
        let c = sim::simulate(&spec, &k);
        let mut p = Profile::for_device(&spec);
        p.record_scaled("z", 2, &c, &spec);
        let mut t = StepTimeline::new(&spec.name);
        t.push_phase("forward", &p);
        let s = &t.phases[0];
        assert_eq!(s.overhead_s, s.seconds);
        assert_eq!(s.ramp_s, 0.0);
    }
}
