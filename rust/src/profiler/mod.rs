//! `nsim-cu` — the Nsight-Compute-analog application-characterization
//! layer (paper §II-B).
//!
//! Responsibilities, mirroring the tool the paper describes:
//!
//! * a **metric registry** ([`metrics`]) that parses and validates the
//!   structured `unit__counter.rollup.submetric` naming convention;
//! * **collection sessions** ([`session`]): a session takes a kernel
//!   trace and a metric list, *replays* the trace once per collection
//!   pass (Nsight's kernel-replay behaviour when more metrics are
//!   requested than fit one pass), checks execution determinism across
//!   passes, serializes streams (as Nsight 2020.1.0 does), and charges a
//!   per-kernel profiling overhead;
//! * **aggregation** ([`profile`]): invocations of the same kernel are
//!   summed — "the data presented on these Roofline charts is the
//!   aggregation of all these invocations of the same kernel" (§IV) —
//!   and derived quantities (time via Eq. 5, FLOPs via add+2·fma+mul,
//!   TC FLOPs via Eq. 6, AI per level) are exposed per kernel;
//! * **step timelines** ([`timeline`]): per-phase profiles folded into
//!   the time-based Roofline's step-time breakdown (arXiv 2009.04598);
//! * **serialization** ([`export`]): CSV in the `nv-nsight-cu-cli --csv`
//!   idiom for external tooling, plus a lossless JSON form used by the
//!   scenario matrix's incremental cell store;
//! * **streaming ingestion** ([`ingest`]): the bounded-memory CSV path
//!   for real (multi-million-row) Nsight exports — chunked reading with
//!   online kernel dedup into digest-keyed accumulators; `from_csv` is
//!   a thin wrapper over it, and `repro ingest` surfaces it on the CLI.

pub mod export;
pub mod ingest;
pub mod metrics;
pub mod profile;
pub mod session;
pub mod timeline;

pub use export::{profile_from_json, profile_to_json, RowDiagnostic, RowDiagnostics};
pub use ingest::{IngestConfig, IngestOutput, IngestStats};
pub use metrics::{Metric, MetricRegistry};
pub use profile::{KernelProfile, KernelTiming, Profile};
pub use session::{ProfileRequest, Session, SessionConfig, SessionError};
pub use timeline::{PhaseSlice, StepTimeline};
