//! CSV export/import in the `nv-nsight-cu-cli --csv` idiom.
//!
//! Export lets downstream tooling (spreadsheets, the paper's own
//! plotting scripts) consume our profiles; import lets the Roofline
//! pipeline ingest counter tables measured by the *real* Nsight Compute
//! on real hardware — the two front-ends (simulated and measured) meet
//! at this format, which is the practical payoff of keeping the paper's
//! exact metric names.
//!
//! Format: one row per (kernel, metric):
//! `"Kernel Name","Metric Name","Metric Value","Invocations"`

use std::collections::BTreeMap;

use crate::device::GpuSpec;
use crate::util::error::{bail, Context, Result};
use crate::profiler::profile::Profile;
use crate::sim::counters::CounterSet;

/// Comment prefix carrying the device the profile was collected on —
/// skipped (and restored) by [`from_csv`], ignored by plain CSV readers.
const DEVICE_PREFIX: &str = "# device=";

/// Serialize a profile to CSV. Profiles stamped with a device (every
/// session-produced profile) lead with a `# device=<name>` comment so
/// the collection device travels with the counters.
pub fn to_csv(profile: &Profile) -> String {
    use std::fmt::Write as _;
    // One row per (kernel, metric): ~16 metrics/kernel at < 96 bytes/row.
    let mut out = String::with_capacity(96 + profile.n_kernels() * 16 * 96);
    if !profile.device.is_empty() {
        let _ = writeln!(out, "{DEVICE_PREFIX}{}", profile.device);
    }
    out.push_str("\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n");
    for k in profile.kernels() {
        for (metric, value) in k.counters.metrics() {
            let _ = writeln!(
                out,
                "\"{}\",\"{}\",{},{}",
                escape(&k.name),
                metric,
                value,
                k.invocations
            );
        }
    }
    out
}

/// Parse a CSV back into a [`Profile`] (aggregated counters per kernel).
pub fn from_csv(text: &str, spec: &GpuSpec) -> Result<Profile> {
    let mut per_kernel: BTreeMap<String, (u64, CounterSet)> = BTreeMap::new();
    let mut lines = text.lines();
    let mut header = lines.next().context("empty csv")?;
    // Optional device stamp ahead of the column header; external Nsight
    // exports without one fall back to the caller's spec.
    let mut device = spec.name.clone();
    if let Some(name) = header.strip_prefix(DEVICE_PREFIX) {
        device = name.trim().to_string();
        header = lines.next().context("csv has a device line but no header")?;
    }
    if !header.contains("Kernel Name") || !header.contains("Metric Name") {
        bail!("unrecognized csv header: {header}");
    }
    for (lineno, line) in lines.enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        let fields = parse_csv_row(line)
            .with_context(|| format!("csv line {}: '{line}'", lineno + 2))?;
        if fields.len() != 4 {
            bail!("csv line {}: expected 4 fields, got {}", lineno + 2, fields.len());
        }
        let value: f64 = fields[2]
            .parse()
            .with_context(|| format!("csv line {}: bad value '{}'", lineno + 2, fields[2]))?;
        let invocations: u64 = fields[3]
            .parse()
            .with_context(|| format!("csv line {}: bad invocations '{}'", lineno + 2, fields[3]))?;
        let entry = per_kernel
            .entry(fields[0].clone())
            .or_insert_with(|| (invocations, CounterSet::new()));
        entry.0 = invocations;
        entry.1.set(&fields[1], value);
    }
    let mut profile = Profile::new();
    profile.device = device;
    for (name, (invocations, counters)) in per_kernel {
        profile.record(&name, invocations, &counters, spec);
    }
    Ok(profile)
}

fn escape(s: &str) -> String {
    s.replace('"', "\"\"")
}

/// Minimal RFC-4180-ish row parser (quoted fields, doubled quotes).
fn parse_csv_row(line: &str) -> Result<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    loop {
        match chars.peek() {
            None => {
                fields.push(cur);
                return Ok(fields);
            }
            Some('"') => {
                chars.next();
                loop {
                    match chars.next() {
                        Some('"') => {
                            if chars.peek() == Some(&'"') {
                                chars.next();
                                cur.push('"');
                            } else {
                                break;
                            }
                        }
                        Some(c) => cur.push(c),
                        None => bail!("unterminated quote"),
                    }
                }
            }
            Some(',') => {
                chars.next();
                fields.push(std::mem::take(&mut cur));
            }
            Some(_) => cur.push(chars.next().unwrap()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::profiler::{ProfileRequest, Session};
    use crate::sim::kernel::{KernelDesc, KernelInvocation};

    fn sample_profile() -> (GpuSpec, Profile) {
        let spec = GpuSpec::v100();
        let trace = vec![
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise(
                    "relu, \"fused\"",
                    1 << 16,
                    Precision::Fp32,
                    1,
                ),
                invocations: 3,
                stream: 0,
            },
            KernelInvocation::once(KernelDesc::gemm(
                "hmma", 512, 512, 512, Precision::Fp16, true, 64, &spec,
            )),
        ];
        let p = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        (spec, p)
    }

    #[test]
    fn roundtrip_preserves_derived_quantities() {
        let (spec, p) = sample_profile();
        let csv = to_csv(&p);
        let back = from_csv(&csv, &spec).unwrap();
        assert_eq!(back.n_kernels(), p.n_kernels());
        for k in p.kernels() {
            let other = back.kernel(&k.name).unwrap();
            assert_eq!(other.invocations, k.invocations);
            assert!((other.flops() - k.flops()).abs() < 1e-6);
            assert!((other.seconds() - k.seconds()).abs() < 1e-12);
        }
    }

    #[test]
    fn quoted_names_with_commas_survive() {
        let (spec, p) = sample_profile();
        let back = from_csv(&to_csv(&p), &spec).unwrap();
        assert!(back.kernel("relu, \"fused\"").is_some());
    }

    #[test]
    fn rejects_garbage() {
        let spec = GpuSpec::v100();
        assert!(from_csv("", &spec).is_err());
        assert!(from_csv("bogus header\n", &spec).is_err());
        assert!(from_csv(
            "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\"k\",\"m\",notanumber,1\n",
            &spec
        )
        .is_err());
    }

    #[test]
    fn ingested_external_counters_chart_cleanly() {
        // A hand-written "real Nsight" export drives the Roofline path.
        let spec = GpuSpec::v100();
        let csv = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\
            \"external_gemm\",\"sm__cycles_elapsed.avg\",1000000,1\n\
            \"external_gemm\",\"sm__cycles_elapsed.avg.per_second\",1530000000,1\n\
            \"external_gemm\",\"sm__inst_executed_pipe_tensor.sum\",100000000,1\n\
            \"external_gemm\",\"l1tex__t_bytes.sum\",1000000000,1\n\
            \"external_gemm\",\"lts__t_bytes.sum\",800000000,1\n\
            \"external_gemm\",\"dram__bytes.sum\",200000000,1\n";
        let p = from_csv(csv, &spec).unwrap();
        let model = crate::roofline::model::RooflineModel::from_profile(&spec, &p);
        assert_eq!(model.points.len(), 1);
        let point = &model.points[0];
        assert!(point.tensor_dominated);
        // 1e8 insts * 512 = 5.12e10 FLOPs over 1e6/1.53e9 s.
        let expected = 5.12e10 / (1e6 / 1.53e9);
        assert!((point.flops_per_sec - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn unknown_metrics_survive_roundtrip_via_fallback_lane() {
        // Real-Nsight exports can carry counters outside the Table II
        // set; they ride the CounterSet fallback lane and must survive
        // ingest → profile → re-export unchanged.
        let spec = GpuSpec::v100();
        let csv = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\
            \"k\",\"sm__cycles_elapsed.avg\",1000,1\n\
            \"k\",\"sm__cycles_elapsed.avg.per_second\",1530000000,1\n\
            \"k\",\"smsp__warps_active.avg\",47.5,1\n";
        let p = from_csv(csv, &spec).unwrap();
        let k = p.kernel("k").unwrap();
        assert_eq!(k.counters.get("smsp__warps_active.avg"), 47.5);
        let re = to_csv(&p);
        assert!(re.contains("\"smsp__warps_active.avg\",47.5,1"), "{re}");
        // And it parses back once more, identically.
        let p2 = from_csv(&re, &spec).unwrap();
        assert_eq!(
            p2.kernel("k").unwrap().counters.get("smsp__warps_active.avg"),
            47.5
        );
    }

    #[test]
    fn device_stamp_roundtrips_and_defaults() {
        // A session profile carries its device through export → import.
        let (spec, p) = sample_profile();
        let csv = to_csv(&p);
        assert!(csv.starts_with("# device=V100-SXM2-16GB\n"), "{csv}");
        let back = from_csv(&csv, &spec).unwrap();
        assert_eq!(back.device, "V100-SXM2-16GB");
        // A device-less external export (real Nsight) falls back to the
        // ingesting spec — and re-exports stamped.
        let external = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\
            \"k\",\"sm__cycles_elapsed.avg\",1000,1\n";
        let a100 = GpuSpec::a100();
        let ingested = from_csv(external, &a100).unwrap();
        assert_eq!(ingested.device, "A100-SXM4-40GB");
        assert!(to_csv(&ingested).starts_with("# device=A100-SXM4-40GB\n"));
    }

    #[test]
    fn csv_row_parser_edges() {
        assert_eq!(parse_csv_row("a,b,c").unwrap(), vec!["a", "b", "c"]);
        assert_eq!(parse_csv_row("\"a,b\",c").unwrap(), vec!["a,b", "c"]);
        assert_eq!(parse_csv_row("\"he said \"\"hi\"\"\",x").unwrap(), vec!["he said \"hi\"", "x"]);
        assert!(parse_csv_row("\"unterminated").is_err());
    }
}
