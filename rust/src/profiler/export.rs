//! CSV export/import in the `nv-nsight-cu-cli --csv` idiom.
//!
//! Export lets downstream tooling (spreadsheets, the paper's own
//! plotting scripts) consume our profiles; import lets the Roofline
//! pipeline ingest counter tables measured by the *real* Nsight Compute
//! on real hardware — the two front-ends (simulated and measured) meet
//! at this format, which is the practical payoff of keeping the paper's
//! exact metric names.
//!
//! Format: one row per (kernel, metric):
//! `"Kernel Name","Metric Name","Metric Value","Invocations"`
//!
//! A second, JSON-valued form ([`profile_to_json`]/[`profile_from_json`])
//! serializes *every* profile field — timing, passes, overhead — with an
//! exact (`Profile::eq`) round-trip guarantee; it is the wire format of
//! the scenario matrix cell store ([`crate::scenario::store`]), where a
//! decoded profile must regenerate byte-identical artifacts.

use crate::device::GpuSpec;
use crate::profiler::ingest::{self, IngestConfig};
use crate::profiler::profile::{KernelProfile, KernelTiming, Profile};
use crate::sim::counters::CounterSet;
use crate::util::error::{bail, Context, Result};
use crate::util::json::Json;

/// Comment prefix carrying the device the profile was collected on —
/// skipped (and restored) by [`from_csv`], ignored by plain CSV readers.
pub(crate) const DEVICE_PREFIX: &str = "# device=";

/// Serialize a profile to CSV. Profiles stamped with a device (every
/// session-produced profile) lead with a `# device=<name>` comment so
/// the collection device travels with the counters.
pub fn to_csv(profile: &Profile) -> String {
    use std::fmt::Write as _;
    // One row per (kernel, metric): ~16 metrics/kernel at < 96 bytes/row.
    let mut out = String::with_capacity(96 + profile.n_kernels() * 16 * 96);
    if !profile.device.is_empty() {
        let _ = writeln!(out, "{DEVICE_PREFIX}{}", profile.device);
    }
    out.push_str("\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n");
    for k in profile.kernels() {
        for (metric, value) in k.counters.metrics() {
            let _ = writeln!(
                out,
                "\"{}\",\"{}\",{},{}",
                escape(&k.name),
                metric,
                value,
                k.invocations
            );
        }
    }
    out
}

/// One rejected row from a lenient ingest: the 1-based file line and
/// why the row was skipped.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RowDiagnostic {
    pub line: usize,
    pub reason: String,
}

/// The diagnostics side of [`from_csv_lenient`]: per-row reasons,
/// capped at [`RowDiagnostics::CAP`] entries (a multi-million-row
/// export with a systematic defect must not balloon memory), plus the
/// count of diagnostics suppressed past the cap.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RowDiagnostics {
    pub rows: Vec<RowDiagnostic>,
    pub suppressed: usize,
}

impl RowDiagnostics {
    pub const CAP: usize = 64;

    pub(crate) fn push(&mut self, line: usize, reason: String) {
        if self.rows.len() < Self::CAP {
            self.rows.push(RowDiagnostic { line, reason });
        } else {
            self.suppressed += 1;
        }
    }

    /// Total rejected rows, including suppressed ones.
    pub fn total(&self) -> usize {
        self.rows.len() + self.suppressed
    }

    pub fn is_empty(&self) -> bool {
        self.rows.is_empty() && self.suppressed == 0
    }

    /// Human-readable digest for CLI surfacing: one line per diagnostic
    /// plus, when the cap was hit, an overflow trailer carrying the
    /// *total* rejected-row count — at millions of rows the 64 retained
    /// diagnostics are a sample, and hiding the total would hide the
    /// real error rate.
    pub fn summary(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        for d in &self.rows {
            let _ = writeln!(out, "line {}: {}", d.line, d.reason);
        }
        if self.suppressed > 0 {
            let _ = writeln!(
                out,
                "... and {} more malformed row(s) ({} rejected in total)",
                self.suppressed,
                self.total()
            );
        }
        out
    }
}

/// Parse a CSV back into a [`Profile`] (aggregated counters per
/// kernel). Strict: the first malformed row — including rows whose
/// `Invocations` conflict with an earlier row of the same kernel — is
/// an error carrying its file line number.
///
/// A thin wrapper over the streaming core
/// ([`crate::profiler::ingest::from_reader`]) with the text as the
/// reader — one implementation for the in-memory and streaming paths,
/// byte-identical output (asserted by `rust/tests/ingest_semantics.rs`).
pub fn from_csv(text: &str, spec: &GpuSpec) -> Result<Profile> {
    let mut src = text.as_bytes();
    Ok(ingest::from_reader(&mut src, spec, &IngestConfig::new())?.profile)
}

/// Lenient ingest for real-world exports: malformed rows are *skipped*
/// (each recorded as a [`RowDiagnostic`] with its line and reason,
/// capped with an overflow count) and every well-formed row still
/// lands in the profile. Header problems remain fatal. A conflicting-
/// invocations row is skipped too — the kernel keeps the first count
/// it declared. Surfaced on the CLI as `repro profile --from-csv
/// <file> --lenient`. Same thin wrapper over the streaming core as
/// [`from_csv`].
pub fn from_csv_lenient(text: &str, spec: &GpuSpec) -> Result<(Profile, RowDiagnostics)> {
    let mut src = text.as_bytes();
    let out = ingest::from_reader(&mut src, spec, &IngestConfig::new().lenient(true))?;
    Ok((out.profile, out.diagnostics))
}

/// Serialize a profile to a JSON document carrying every field — unlike
/// [`to_csv`] (counters only), this is a lossless encoding: device,
/// passes, overhead, per-kernel invocations, `flops_per_tensor_inst`,
/// all counters (dense and fallback lane), and timing when collected.
pub fn profile_to_json(profile: &Profile) -> Json {
    let kernels = profile.kernels().map(|k| {
        let counters = Json::Obj(
            k.counters
                .metrics()
                .map(|(metric, value)| (metric.to_string(), Json::num(value)))
                .collect(),
        );
        let timing = match &k.timing {
            None => Json::Null,
            Some(t) => Json::obj(vec![
                ("compute_s", Json::num(t.compute_s)),
                ("memory_s", Json::num(t.memory_s)),
                ("ramp_s", Json::num(t.ramp_s)),
                ("total_s", Json::num(t.total_s)),
            ]),
        };
        Json::obj(vec![
            ("name", Json::str(k.name.clone())),
            ("invocations", Json::num(k.invocations as f64)),
            ("flops_per_tensor_inst", Json::num(k.flops_per_tensor_inst)),
            ("counters", counters),
            ("timing", timing),
        ])
    });
    Json::obj(vec![
        ("device", Json::str(profile.device.clone())),
        ("passes", Json::num(profile.passes as f64)),
        ("profiling_overhead_s", Json::num(profile.profiling_overhead_s)),
        ("kernels", Json::arr(kernels)),
    ])
}

/// Decode a [`profile_to_json`] document back into a [`Profile`] that
/// compares *exactly equal* (`Profile`'s bitwise `PartialEq`) to the
/// original: kernels are restored verbatim via [`Profile::insert`], not
/// re-recorded, so nothing gets re-stamped from a spec or dropped.
/// Every f64 survives the JSON layer exactly — the emitter prints
/// shortest-round-trip decimal and `str::parse::<f64>` restores the
/// original bits.
pub fn profile_from_json(doc: &Json) -> Result<Profile> {
    let mut profile = Profile::new();
    profile.device = doc.get("device")?.as_str()?.to_string();
    profile.passes = json_u64(doc.get("passes")?).context("profile passes")?;
    profile.profiling_overhead_s = doc.get("profiling_overhead_s")?.as_f64()?;
    for k in doc.get("kernels")?.as_arr()? {
        let name = k.get("name")?.as_str()?.to_string();
        let mut counters = CounterSet::new();
        for (metric, value) in k.get("counters")?.as_obj()? {
            counters.set(metric, value.as_f64()?);
        }
        let timing = match k.get("timing")? {
            Json::Null => None,
            t => Some(KernelTiming {
                compute_s: t.get("compute_s")?.as_f64()?,
                memory_s: t.get("memory_s")?.as_f64()?,
                ramp_s: t.get("ramp_s")?.as_f64()?,
                total_s: t.get("total_s")?.as_f64()?,
            }),
        };
        profile.insert(KernelProfile {
            invocations: json_u64(k.get("invocations")?)
                .with_context(|| format!("kernel '{name}' invocations"))?,
            counters,
            flops_per_tensor_inst: k.get("flops_per_tensor_inst")?.as_f64()?,
            timing,
            name,
        });
    }
    Ok(profile)
}

/// A JSON number that must be a non-negative integer (u64 counts).
fn json_u64(v: &Json) -> Result<u64> {
    let f = v.as_f64()?;
    // NaN/inf land in the fract() arm (their fract is NaN).
    if f < 0.0 || f.fract() != 0.0 {
        bail!("expected a non-negative integer, got {f}");
    }
    Ok(f as u64)
}

fn escape(s: &str) -> String {
    s.replace('"', "\"\"")
}

/// Minimal RFC-4180-ish row parser (quoted fields, doubled quotes).
/// Shared with the streaming aggregator in [`crate::profiler::ingest`].
pub(crate) fn parse_csv_row(line: &str) -> Result<Vec<String>> {
    let mut fields = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    loop {
        match chars.peek() {
            None => {
                fields.push(cur);
                return Ok(fields);
            }
            Some('"') => {
                chars.next();
                loop {
                    match chars.next() {
                        Some('"') => {
                            if chars.peek() == Some(&'"') {
                                chars.next();
                                cur.push('"');
                            } else {
                                break;
                            }
                        }
                        Some(c) => cur.push(c),
                        None => bail!("unterminated quote"),
                    }
                }
            }
            Some(',') => {
                chars.next();
                fields.push(std::mem::take(&mut cur));
            }
            Some(_) => cur.push(chars.next().unwrap()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::device::Precision;
    use crate::profiler::{ProfileRequest, Session};
    use crate::sim::kernel::{KernelDesc, KernelInvocation};

    fn sample_profile() -> (GpuSpec, Profile) {
        let spec = GpuSpec::v100();
        let trace = vec![
            KernelInvocation {
                kernel: KernelDesc::streaming_elementwise(
                    "relu, \"fused\"",
                    1 << 16,
                    Precision::Fp32,
                    1,
                ),
                invocations: 3,
                stream: 0,
            },
            KernelInvocation::once(KernelDesc::gemm(
                "hmma", 512, 512, 512, Precision::Fp16, true, 64, &spec,
            )),
        ];
        let p = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        (spec, p)
    }

    #[test]
    fn roundtrip_preserves_derived_quantities() {
        let (spec, p) = sample_profile();
        let csv = to_csv(&p);
        let back = from_csv(&csv, &spec).unwrap();
        assert_eq!(back.n_kernels(), p.n_kernels());
        for k in p.kernels() {
            let other = back.kernel(&k.name).unwrap();
            assert_eq!(other.invocations, k.invocations);
            assert!((other.flops() - k.flops()).abs() < 1e-6);
            assert!((other.seconds() - k.seconds()).abs() < 1e-12);
        }
    }

    #[test]
    fn quoted_names_with_commas_survive() {
        let (spec, p) = sample_profile();
        let back = from_csv(&to_csv(&p), &spec).unwrap();
        assert!(back.kernel("relu, \"fused\"").is_some());
    }

    #[test]
    fn rejects_garbage() {
        let spec = GpuSpec::v100();
        assert!(from_csv("", &spec).is_err());
        assert!(from_csv("bogus header\n", &spec).is_err());
        assert!(from_csv(
            "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\"k\",\"m\",notanumber,1\n",
            &spec
        )
        .is_err());
    }

    #[test]
    fn ingested_external_counters_chart_cleanly() {
        // A hand-written "real Nsight" export drives the Roofline path.
        let spec = GpuSpec::v100();
        let csv = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\
            \"external_gemm\",\"sm__cycles_elapsed.avg\",1000000,1\n\
            \"external_gemm\",\"sm__cycles_elapsed.avg.per_second\",1530000000,1\n\
            \"external_gemm\",\"sm__inst_executed_pipe_tensor.sum\",100000000,1\n\
            \"external_gemm\",\"l1tex__t_bytes.sum\",1000000000,1\n\
            \"external_gemm\",\"lts__t_bytes.sum\",800000000,1\n\
            \"external_gemm\",\"dram__bytes.sum\",200000000,1\n";
        let p = from_csv(csv, &spec).unwrap();
        let model = crate::roofline::model::RooflineModel::from_profile(&spec, &p);
        assert_eq!(model.points.len(), 1);
        let point = &model.points[0];
        assert!(point.tensor_dominated);
        // 1e8 insts * 512 = 5.12e10 FLOPs over 1e6/1.53e9 s.
        let expected = 5.12e10 / (1e6 / 1.53e9);
        assert!((point.flops_per_sec - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn unknown_metrics_survive_roundtrip_via_fallback_lane() {
        // Real-Nsight exports can carry counters outside the Table II
        // set; they ride the CounterSet fallback lane and must survive
        // ingest → profile → re-export unchanged.
        let spec = GpuSpec::v100();
        let csv = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\
            \"k\",\"sm__cycles_elapsed.avg\",1000,1\n\
            \"k\",\"sm__cycles_elapsed.avg.per_second\",1530000000,1\n\
            \"k\",\"smsp__warps_active.avg\",47.5,1\n";
        let p = from_csv(csv, &spec).unwrap();
        let k = p.kernel("k").unwrap();
        assert_eq!(k.counters.get("smsp__warps_active.avg"), 47.5);
        let re = to_csv(&p);
        assert!(re.contains("\"smsp__warps_active.avg\",47.5,1"), "{re}");
        // And it parses back once more, identically.
        let p2 = from_csv(&re, &spec).unwrap();
        assert_eq!(
            p2.kernel("k").unwrap().counters.get("smsp__warps_active.avg"),
            47.5
        );
    }

    #[test]
    fn device_stamp_roundtrips_and_defaults() {
        // A session profile carries its device through export → import.
        let (spec, p) = sample_profile();
        let csv = to_csv(&p);
        assert!(csv.starts_with("# device=V100-SXM2-16GB\n"), "{csv}");
        let back = from_csv(&csv, &spec).unwrap();
        assert_eq!(back.device, "V100-SXM2-16GB");
        // A device-less external export (real Nsight) falls back to the
        // ingesting spec — and re-exports stamped.
        let external = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n\
            \"k\",\"sm__cycles_elapsed.avg\",1000,1\n";
        let a100 = GpuSpec::a100();
        let ingested = from_csv(external, &a100).unwrap();
        assert_eq!(ingested.device, "A100-SXM4-40GB");
        assert!(to_csv(&ingested).starts_with("# device=A100-SXM4-40GB\n"));
    }

    const HEADER: &str = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n";

    #[test]
    fn conflicting_invocations_are_a_structured_error() {
        let spec = GpuSpec::v100();
        let csv = format!(
            "{HEADER}\"k\",\"sm__cycles_elapsed.avg\",1000,3\n\
             \"k\",\"dram__bytes.sum\",5000,7\n"
        );
        let err = from_csv(&csv, &spec).unwrap_err();
        let msg = format!("{err:#}");
        // The error names the line and both disagreeing values.
        assert!(msg.contains("line 3"), "{msg}");
        assert!(msg.contains("conflicting Invocations"), "{msg}");
        assert!(msg.contains('3') && msg.contains('7'), "{msg}");
        // Consistent counts across rows of one kernel still pass.
        let ok = format!(
            "{HEADER}\"k\",\"sm__cycles_elapsed.avg\",1000,3\n\
             \"k\",\"dram__bytes.sum\",5000,3\n"
        );
        let p = from_csv(&ok, &spec).unwrap();
        assert_eq!(p.kernel("k").unwrap().invocations, 3);
    }

    #[test]
    fn lenient_ingest_skips_bad_rows_and_reports_them() {
        let spec = GpuSpec::v100();
        let csv = format!(
            "{HEADER}\"k\",\"sm__cycles_elapsed.avg\",1000,1\n\
             \"k\",\"dram__bytes.sum\",notanumber,1\n\
             too,few\n\
             \"k\",\"lts__t_bytes.sum\",800,2\n\
             \"k\",\"l1tex__t_bytes.sum\",900,1\n"
        );
        let (p, diags) = from_csv_lenient(&csv, &spec).unwrap();
        // Good rows landed; the conflicting-invocations row (line 5)
        // kept the kernel's first count.
        let k = p.kernel("k").unwrap();
        assert_eq!(k.invocations, 1);
        assert_eq!(k.counters.get("l1tex__t_bytes.sum"), 900.0);
        assert_eq!(k.counters.get("lts__t_bytes.sum"), 0.0, "conflicting row skipped");
        // Three diagnostics with the right lines, in order.
        assert_eq!(diags.total(), 3);
        let lines: Vec<usize> = diags.rows.iter().map(|d| d.line).collect();
        assert_eq!(lines, [3, 4, 5]);
        assert!(diags.rows[0].reason.contains("bad value"), "{}", diags.rows[0].reason);
        assert!(diags.rows[1].reason.contains("expected 4 fields"), "{}", diags.rows[1].reason);
        assert!(
            diags.rows[2].reason.contains("conflicting Invocations"),
            "{}",
            diags.rows[2].reason
        );
        assert!(diags.summary().contains("line 4"), "{}", diags.summary());
        // Strict mode rejects the same text outright.
        assert!(from_csv(&csv, &spec).is_err());
        // A clean file yields empty diagnostics and the same profile as
        // strict ingest.
        let clean = format!("{HEADER}\"k\",\"sm__cycles_elapsed.avg\",1000,1\n");
        let (lenient, d) = from_csv_lenient(&clean, &spec).unwrap();
        assert!(d.is_empty());
        assert_eq!(lenient, from_csv(&clean, &spec).unwrap());
    }

    #[test]
    fn lenient_diagnostics_cap_with_overflow_count() {
        let spec = GpuSpec::v100();
        let mut csv = String::from(HEADER);
        for _ in 0..(RowDiagnostics::CAP + 10) {
            csv.push_str("garbage,row\n");
        }
        let (p, diags) = from_csv_lenient(&csv, &spec).unwrap();
        assert_eq!(p.n_kernels(), 0);
        assert_eq!(diags.rows.len(), RowDiagnostics::CAP);
        assert_eq!(diags.suppressed, 10);
        assert_eq!(diags.total(), RowDiagnostics::CAP + 10);
        assert!(diags.summary().contains("10 more malformed row(s)"), "{}", diags.summary());
        // The trailer reports the *total* rejected-row count, not just
        // the overflow past the cap — the cap must never hide the real
        // error rate of a large export.
        assert!(
            diags.summary().contains(&format!("{} rejected in total", RowDiagnostics::CAP + 10)),
            "{}",
            diags.summary()
        );
    }

    #[test]
    fn lenient_line_numbers_account_for_the_device_stamp() {
        let spec = GpuSpec::v100();
        let csv = format!("# device=V100-SXM2-16GB\n{HEADER}bad,row\n");
        let (_, diags) = from_csv_lenient(&csv, &spec).unwrap();
        assert_eq!(diags.rows.len(), 1);
        assert_eq!(diags.rows[0].line, 3, "stamp shifts data rows to line 3");
        // Header errors stay fatal even in lenient mode.
        assert!(from_csv_lenient("", &spec).is_err());
        assert!(from_csv_lenient("bogus header\n", &spec).is_err());
    }

    #[test]
    fn json_roundtrip_is_exact() {
        let (_spec, p) = sample_profile();
        assert!(p.kernels().any(|k| k.timing.is_some()), "sample must carry timing");
        assert!(p.passes > 0 && p.profiling_overhead_s > 0.0);
        let text = profile_to_json(&p).to_string_pretty();
        let back = profile_from_json(&Json::parse(&text).unwrap()).unwrap();
        // Profile's PartialEq is exact/bitwise — this is the cell-store
        // byte-identity guarantee in one assert.
        assert_eq!(back, p);
        assert_eq!(back.profiling_overhead_s.to_bits(), p.profiling_overhead_s.to_bits());
    }

    #[test]
    fn json_decode_rejects_malformed_documents() {
        assert!(profile_from_json(&Json::parse("{}").unwrap()).is_err());
        assert!(profile_from_json(&Json::parse("[1,2]").unwrap()).is_err());
        let fractional = Json::parse(
            r#"{"device":"d","passes":1.5,"profiling_overhead_s":0,"kernels":[]}"#,
        )
        .unwrap();
        assert!(profile_from_json(&fractional).is_err(), "fractional passes rejected");
        let bad_kernel = Json::parse(
            r#"{"device":"d","passes":1,"profiling_overhead_s":0,"kernels":[{"name":"k"}]}"#,
        )
        .unwrap();
        assert!(profile_from_json(&bad_kernel).is_err(), "kernel missing fields rejected");
    }

    #[test]
    fn csv_row_parser_edges() {
        assert_eq!(parse_csv_row("a,b,c").unwrap(), vec!["a", "b", "c"]);
        assert_eq!(parse_csv_row("\"a,b\",c").unwrap(), vec!["a,b", "c"]);
        assert_eq!(parse_csv_row("\"he said \"\"hi\"\"\",x").unwrap(), vec!["he said \"hi\"", "x"]);
        assert!(parse_csv_row("\"unterminated").is_err());
    }
}
