//! # hroofline — Hierarchical Roofline Performance Analysis for Deep Learning
//!
//! A production-shaped reimplementation of the measurement stack from
//! *"Hierarchical Roofline Performance Analysis for Deep Learning
//! Applications"* (Wang, Yang, Farrell, Kurth, Williams; CS.DC 2020):
//!
//! * [`ert`] — the Empirical Roofline Toolkit: micro-kernel sweeps for
//!   machine characterization across data precisions and matrix units
//!   (paper §II-A, Fig. 1, Table I, Fig. 2).
//! * [`profiler`] — an Nsight-Compute-analog metric collection layer using
//!   the paper's exact PerfWorks metric names (paper §II-B, Table II).
//! * [`sim`] — a kernel-granularity GPU performance simulator that
//!   produces those counters (pipelines, hierarchical caches, launch
//!   overhead), fully parameterized by a [`device::GpuSpec`] from the
//!   [`device::registry`] (V100/A100/T4 built in) — the hardware
//!   substrate this repo substitutes for a real GPU + Nsight
//!   (see DESIGN.md §1).
//! * [`dl`] — the profiling subject: an operator-graph deep-learning
//!   framework model with a DeepCAM (DeepLabv3+) network builder,
//!   autodiff, AMP (O0/O1/O2) and two framework lowering personalities
//!   (TensorFlow-like, PyTorch-like) that emit kernel traces.
//! * [`roofline`] — the hierarchical Roofline model itself plus SVG chart
//!   and text-table rendering (Figs 3–9).
//! * [`runtime`] — the PJRT bridge that loads AOT-compiled JAX/Pallas
//!   artifacts (`artifacts/*.hlo.txt`) and executes them natively; used by
//!   the end-to-end DeepCAM-lite training example.
//! * [`report`] — one reproduction harness per paper table/figure.
//! * [`scenario`] — the scenario matrix: the [`dl::workloads`] registry
//!   crossed with the [`device::registry`] × framework × phase × AMP
//!   policy, profiled through per-device shared simulation caches and
//!   compared on one overlay Roofline (plus a cross-device pivot).
//!   Cells are content-addressed ([`util::digest`]) into an on-disk
//!   store ([`scenario::store`]): incremental re-runs replay clean
//!   cells byte-identically with zero simulations, and shard runs
//!   merge back into one artifact set.
//! * [`coordinator`] — job orchestration: sweeps, output layout, the
//!   end-to-end train driver.
//!
//! Substrate modules ([`util`], [`cli`], [`exec`], [`prop`],
//! [`bench_harness`]) replace crates unavailable in the offline build
//! (clap/tokio/proptest/criterion/serde); [`util::error`] stands in for
//! `anyhow`/`thiserror` and [`runtime::xla`] for the PJRT bindings.
//! Fan-outs that must survive bad cells run through the panic-safe
//! supervised substrate ([`exec::supervise`]) with deterministic fault
//! injection ([`exec::fault`]) for drills — a failing matrix cell or
//! kernel simulation degrades that cell, not the process. The [`obs`]
//! layer (structured [`obs::trace`] spans, an [`obs::metrics`]
//! registry, leveled [`obs::log`]) threads run telemetry through every
//! execution layer behind `--trace`/`HROOFLINE_TRACE`, strictly
//! additively: tracing on or off, artifact bytes are identical.
//!
//! ## Quickstart
//!
//! ```no_run
//! use hroofline::device::DeviceRegistry;
//! use hroofline::dl::{deepcam, lower, amp};
//! use hroofline::profiler::{ProfileRequest, Session};
//! use hroofline::roofline::RooflineChart;
//!
//! // The device is a first-class axis: resolve it by registry name
//! // (`v100-sxm2-16gb`, `a100-sxm4-40gb`, `t4-pcie-16gb`, or a short
//! // alias) — unknown names get a did-you-mean CliError.
//! let gpu = DeviceRegistry::get("v100").unwrap();
//! let net = deepcam::deepcam(&deepcam::DeepCamConfig::paper());
//! let trace = lower::tensorflow(&net, amp::Policy::O1, &gpu).forward;
//! let profile = Session::standard(&gpu).run(&ProfileRequest::new(&trace)).unwrap();
//! let model = hroofline::roofline::RooflineModel::from_profile(&gpu, &profile);
//! let chart = RooflineChart::hierarchical(&model, "TF DeepCAM forward");
//! std::fs::write("roofline.svg", chart.to_svg()).unwrap();
//! ```

pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod device;
pub mod dl;
pub mod ert;
pub mod exec;
pub mod obs;
pub mod profiler;
pub mod prop;
pub mod report;
pub mod roofline;
pub mod scenario;
pub mod runtime;
pub mod sim;
pub mod util;
