//! Figs 3–9 — hierarchical Rooflines of DeepCAM under the two framework
//! personalities, phases, and AMP settings:
//!
//! | fig | framework | phase | AMP |
//! |-----|-----------|-------|-----|
//! | 3 | TensorFlow | forward | on (default) |
//! | 4 | TensorFlow | backward (incl. update) | on |
//! | 5 | PyTorch | forward | O1 |
//! | 6 | PyTorch | backward | O1 |
//! | 7 | PyTorch | optimizer | O1 |
//! | 8 | TensorFlow | backward | manual FP16 |
//! | 9 | PyTorch | backward | O0 |

use std::sync::OnceLock;

use crate::device::GpuSpec;
use crate::util::error::{self as anyhow, Result};
use crate::dl::deepcam::{deepcam, DeepCamConfig};
use crate::dl::lower::{lower, Framework, FrameworkTrace, Phase};
use crate::dl::{Graph, Policy};
use crate::profiler::{Profile, ProfileRequest, Session};
use crate::roofline::chart::RooflineChart;
use crate::roofline::model::RooflineModel;
use crate::util::Json;

use super::Artifact;

/// The experiment matrix entry for one figure.
#[derive(Clone, Copy, Debug)]
pub struct FigSpec {
    pub id: &'static str,
    pub framework: Framework,
    pub phase: Phase,
    pub policy: Policy,
    pub title: &'static str,
}

pub const FIGS: [FigSpec; 7] = [
    FigSpec {
        id: "fig3",
        framework: Framework::TensorFlow,
        phase: Phase::Forward,
        policy: Policy::O1,
        title: "Fig. 3 — TensorFlow DeepCAM forward (AMP)",
    },
    FigSpec {
        id: "fig4",
        framework: Framework::TensorFlow,
        phase: Phase::Backward,
        policy: Policy::O1,
        title: "Fig. 4 — TensorFlow DeepCAM backward+update (AMP)",
    },
    FigSpec {
        id: "fig5",
        framework: Framework::PyTorch,
        phase: Phase::Forward,
        policy: Policy::O1,
        title: "Fig. 5 — PyTorch DeepCAM forward (AMP O1)",
    },
    FigSpec {
        id: "fig6",
        framework: Framework::PyTorch,
        phase: Phase::Backward,
        policy: Policy::O1,
        title: "Fig. 6 — PyTorch DeepCAM backward (AMP O1)",
    },
    FigSpec {
        id: "fig7",
        framework: Framework::PyTorch,
        phase: Phase::Optimizer,
        policy: Policy::O1,
        title: "Fig. 7 — PyTorch DeepCAM optimizer step",
    },
    FigSpec {
        id: "fig8",
        framework: Framework::TensorFlow,
        phase: Phase::Backward,
        policy: Policy::ManualFp16,
        title: "Fig. 8 — manual-FP16 TensorFlow backward",
    },
    FigSpec {
        id: "fig9",
        framework: Framework::PyTorch,
        phase: Phase::Backward,
        policy: Policy::O0,
        title: "Fig. 9 — PyTorch backward, AMP O0",
    },
];

/// The paper-scale DeepCAM operator graph, built once per process: the
/// graph is immutable and every figure (and the fig3–fig9 benches)
/// lowers the same one, so rebuilding it per artifact was pure waste.
pub(crate) fn paper_graph() -> &'static Graph {
    static GRAPH: OnceLock<Graph> = OnceLock::new();
    GRAPH.get_or_init(|| deepcam(&DeepCamConfig::paper()))
}

/// Profile one figure's (framework, phase, policy) at paper scale on a
/// device (lowering and collection both target the same spec).
pub fn profile_for(spec: &GpuSpec, fig: &FigSpec) -> (FrameworkTrace, Profile) {
    let trace = lower(paper_graph(), fig.framework, fig.policy, spec);
    let profile = Session::standard(spec)
        .run(&ProfileRequest::new(trace.phase(fig.phase)))
        .expect("standard session on a lowered trace cannot fail");
    (trace, profile)
}

pub fn generate(id: &str) -> Result<Artifact> {
    generate_for(&crate::device::registry::default_spec(), id)
}

/// Generate one DeepCAM figure on an explicit device; the caption and
/// chart title carry the device name.
pub fn generate_for(spec: &GpuSpec, id: &str) -> Result<Artifact> {
    let fig = FIGS
        .iter()
        .find(|f| f.id == id)
        .ok_or_else(|| anyhow::anyhow!("unknown figure '{id}'"))?;
    let (_trace, profile) = profile_for(spec, fig);
    let model = RooflineModel::from_profile(spec, &profile);
    model
        .validate_bounds()
        .map_err(|e| anyhow::anyhow!("roofline bound violated: {e}"))?;
    let title = format!("{} [{}]", fig.title, spec.name);
    let chart = RooflineChart::hierarchical(&model, &title);

    let top = profile.by_time();
    let top_share = profile.top_kernel_time_share();
    let tc_time: f64 = top
        .iter()
        .filter(|k| k.is_tensor_dominated())
        .map(|k| k.seconds())
        .sum();
    let total = profile.total_seconds();

    let mut text = format!(
        "{}\n\ntotal GPU time {} | kernels {} | invocations {} | \
         top-kernel share {:.1}% | tensor-core time share {:.1}%\n\n{}",
        title,
        crate::util::fmt::duration(total),
        profile.n_kernels(),
        profile.total_invocations(),
        top_share * 100.0,
        if total > 0.0 { tc_time / total * 100.0 } else { 0.0 },
        chart.to_table().render()
    );
    text.push('\n');

    Ok(Artifact {
        id: fig.id.into(),
        title,
        text,
        json: Json::obj(vec![
            ("device", Json::str(&spec.name)),
            ("framework", Json::str(fig.framework.name())),
            ("policy", Json::str(fig.policy.name())),
            ("total_seconds", Json::num(total)),
            ("n_kernels", Json::num(profile.n_kernels() as f64)),
            ("top_kernel_time_share", Json::num(top_share)),
            (
                "tc_time_share",
                Json::num(if total > 0.0 { tc_time / total } else { 0.0 }),
            ),
            (
                "kernels",
                Json::arr(top.iter().take(20).map(|k| {
                    Json::obj(vec![
                        ("name", Json::str(&k.name)),
                        ("seconds", Json::num(k.seconds())),
                        ("gflops_per_sec", Json::num(k.flops_per_sec() / 1e9)),
                        ("tensor", Json::Bool(k.is_tensor_dominated())),
                        ("invocations", Json::num(k.invocations as f64)),
                    ])
                })),
            ),
        ]),
        svg: Some(chart.to_svg()),
        csv: None,
        lanes: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn meta(id: &str) -> Json {
        generate(id).unwrap().json
    }

    #[test]
    fn fig3_tf_forward_dominant_tc_kernel() {
        // Paper: dominant kernel w/ very high TC utilization, ~33% of
        // runtime.
        let j = meta("fig3");
        let share = j.get("top_kernel_time_share").unwrap().as_f64().unwrap();
        assert!((0.20..=0.60).contains(&share), "top share {share}");
        let kernels = j.get("kernels").unwrap().as_arr().unwrap();
        assert!(kernels[0].get("tensor").unwrap().as_bool().unwrap(),
            "top TF fwd kernel is tensor-dominated");
    }

    #[test]
    fn fig4_tf_backward_more_tc_time_than_forward() {
        // Paper: backward has *more* compute-intensive TC kernels
        // (41.9% of time near TC peak vs 33% fwd).
        let f3 = meta("fig3");
        let f4 = meta("fig4");
        let tc3 = f3.get("tc_time_share").unwrap().as_f64().unwrap();
        let tc4 = f4.get("tc_time_share").unwrap().as_f64().unwrap();
        assert!(tc4 > 0.2, "tc share bwd {tc4}");
        // Backward total time exceeds forward (paper: "generally more
        // time-consuming").
        let t3 = f3.get("total_seconds").unwrap().as_f64().unwrap();
        let t4 = f4.get("total_seconds").unwrap().as_f64().unwrap();
        assert!(t4 > t3, "bwd {t4} fwd {t3}");
        let _ = tc3;
    }

    #[test]
    fn fig5_pytorch_forward_no_dominant_kernel() {
        let j = meta("fig5");
        let share = j.get("top_kernel_time_share").unwrap().as_f64().unwrap();
        let tf_share = meta("fig3")
            .get("top_kernel_time_share")
            .unwrap()
            .as_f64()
            .unwrap();
        assert!(share < tf_share, "pt {share} vs tf {tf_share}");
    }

    #[test]
    fn fig6_pytorch_backward_top_kernel_low_tflops_no_tc() {
        // Paper: "the number one time-consuming kernel does not utilize
        // Tensor Core and delivers only about 1 TFLOP/s".
        let j = meta("fig6");
        let top = &j.get("kernels").unwrap().as_arr().unwrap()[0];
        assert!(!top.get("tensor").unwrap().as_bool().unwrap());
        let gf = top.get("gflops_per_sec").unwrap().as_f64().unwrap();
        assert!((300.0..3000.0).contains(&gf), "top kernel {gf} GFLOP/s");
    }

    #[test]
    fn fig7_optimizer_memory_bound_low_flops() {
        let j = meta("fig7");
        let kernels = j.get("kernels").unwrap().as_arr().unwrap();
        // All optimizer kernels well below 1 TFLOP/s (streaming).
        for k in kernels {
            let gf = k.get("gflops_per_sec").unwrap().as_f64().unwrap();
            assert!(gf < 1000.0, "{k}");
            assert!(!k.get("tensor").unwrap().as_bool().unwrap());
        }
    }

    #[test]
    fn fig8_manual_fp16_matches_fig4_amp() {
        // The §IV-C equivalence: manual FP16 ≈ AMP backward performance.
        let f4 = meta("fig4");
        let f8 = meta("fig8");
        let t4 = f4.get("total_seconds").unwrap().as_f64().unwrap();
        let t8 = f8.get("total_seconds").unwrap().as_f64().unwrap();
        assert!((t4 - t8).abs() / t4 < 0.05, "fig4 {t4} vs fig8 {t8}");
    }

    #[test]
    fn fig9_o0_slower_and_no_tc() {
        // O0 vs O1 backward: kernel time largely reduced by O1 and many
        // kernels move to TC (§IV-C).
        let f6 = meta("fig6");
        let f9 = meta("fig9");
        let t6 = f6.get("total_seconds").unwrap().as_f64().unwrap();
        let t9 = f9.get("total_seconds").unwrap().as_f64().unwrap();
        assert!(t9 > 1.3 * t6, "O0 {t9} vs O1 {t6}");
        assert_eq!(f9.get("tc_time_share").unwrap().as_f64().unwrap(), 0.0);
        assert!(f6.get("tc_time_share").unwrap().as_f64().unwrap() > 0.1);
    }
}
