//! Reproduction harnesses: one module per paper artifact.
//!
//! Every table and figure in the paper's evaluation has a generator here
//! that produces (a) a text rendering for the terminal/EXPERIMENTS.md,
//! (b) machine-readable JSON, and for the figures (c) an SVG chart in
//! the paper's visual idiom. `repro report` and the `benches/` harnesses
//! call into these.

pub mod deepcam_figs;
pub mod fig1;
pub mod fig2;
pub mod tab1;
pub mod tab3;

use crate::util::error::{self as anyhow, Result};
use std::path::Path;

/// A rendered artifact.
pub struct Artifact {
    /// e.g. "fig3".
    pub id: String,
    /// One-line description.
    pub title: String,
    /// Text rendering (table or summary).
    pub text: String,
    /// Machine-readable payload.
    pub json: crate::util::Json,
    /// SVG chart, when the artifact is a figure.
    pub svg: Option<String>,
    /// CSV payload (Nsight-style counter rows or summary tables), when
    /// the artifact carries one — scenario-matrix artifacts do.
    pub csv: Option<String>,
    /// Extra named lanes, written as `{id}.{kind}` by
    /// [`Artifact::write_all`] — e.g. the time-based Roofline lanes
    /// `timeline.txt` / `timeline.svg` that ride alongside the four
    /// core lanes without perturbing their bytes. Attach with
    /// [`Artifact::with_lane`].
    pub lanes: Vec<(String, String)>,
}

impl Artifact {
    /// Attach an extra output lane. `kind` is the file suffix after the
    /// artifact id — `with_lane("timeline.txt", ..)` on artifact `fig3`
    /// writes `fig3.timeline.txt`.
    pub fn with_lane(mut self, kind: &str, content: impl Into<String>) -> Artifact {
        self.lanes.push((kind.to_string(), content.into()));
        self
    }

    /// Write every lane into `dir`: the core text/json[/svg][/csv]
    /// quartet plus all extra lanes. The single emission point for all
    /// artifact producers (`repro report|profile|matrix`) — and
    /// therefore the single place bytes-per-lane telemetry is counted
    /// (`artifact.bytes.<lane>` in the global
    /// [`crate::obs::MetricsRegistry`]).
    pub fn write_all(&self, dir: &Path) -> Result<()> {
        let emit = |lane: &str, content: &str| -> Result<()> {
            std::fs::write(dir.join(format!("{}.{lane}", self.id)), content)?;
            crate::obs::MetricsRegistry::global()
                .add(&format!("artifact.bytes.{lane}"), content.len() as u64);
            Ok(())
        };
        std::fs::create_dir_all(dir)?;
        emit("txt", &self.text)?;
        emit("json", &self.json.to_string_pretty())?;
        if let Some(svg) = &self.svg {
            emit("svg", svg)?;
        }
        if let Some(csv) = &self.csv {
            emit("csv", csv)?;
        }
        for (kind, content) in &self.lanes {
            emit(kind, content)?;
        }
        Ok(())
    }

    /// Back-compat alias for [`Artifact::write_all`].
    pub fn write_to(&self, dir: &Path) -> Result<()> {
        self.write_all(dir)
    }
}

/// All artifact ids, in paper order.
pub const ALL_IDS: [&str; 11] = [
    "fig1", "tab1", "fig2", "fig3", "fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "tab3",
];

/// Generate one artifact by id on the default (paper-testbed) device.
pub fn generate(id: &str) -> Result<Artifact> {
    generate_for(&crate::device::registry::default_spec(), id)
}

/// Generate one artifact by id on an explicit device. The paper
/// reference columns only apply on the V100 testbed; the other
/// generators carry the device name in their captions so cross-device
/// artifact sets stay tellable apart.
pub fn generate_for(spec: &crate::device::GpuSpec, id: &str) -> Result<Artifact> {
    match id {
        "fig1" => fig1::generate_for(spec),
        "tab1" => tab1::generate_for(spec),
        "fig2" => fig2::generate_for(spec),
        "fig3" | "fig4" | "fig5" | "fig6" | "fig7" | "fig8" | "fig9" => {
            deepcam_figs::generate_for(spec, id)
        }
        "tab3" => tab3::generate_for(spec),
        other => anyhow::bail!("unknown artifact id '{other}' (have {ALL_IDS:?})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_id_is_error() {
        assert!(generate("fig99").is_err());
    }

    #[test]
    fn lanes_write_next_to_core_files() {
        let a = Artifact {
            id: "probe".into(),
            title: "probe".into(),
            text: "text".into(),
            json: crate::util::Json::str("x"),
            svg: Some("<svg/>".into()),
            csv: None,
            lanes: Vec::new(),
        }
        .with_lane("timeline.txt", "step total");
        let dir =
            std::env::temp_dir().join(format!("hroofline-lanes-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        a.write_all(&dir).unwrap();
        assert!(dir.join("probe.txt").exists());
        assert!(dir.join("probe.json").exists());
        assert!(dir.join("probe.svg").exists());
        assert!(!dir.join("probe.csv").exists());
        let lane = std::fs::read_to_string(dir.join("probe.timeline.txt")).unwrap();
        assert_eq!(lane, "step total");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn all_ids_unique() {
        let mut ids = ALL_IDS.to_vec();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), ALL_IDS.len());
    }
}
