//! Table III — zero-AI kernel invocation census per framework/phase.
//!
//! Absolute counts depend on the profiled-loop iteration count (the
//! paper profiles several iterations; we report one training step) —
//! the *fractions* and the TF≈2×PT zero-AI relationship are the
//! reproduction targets (see EXPERIMENTS.md).

use crate::device::GpuSpec;
use crate::util::error::Result;
use crate::dl::lower::{lower, Framework, FrameworkTrace, Phase};
use crate::dl::Policy;
use crate::util::{fmt, Json, Table};

use super::Artifact;

/// Paper reference fractions.
pub const PAPER_FRACTIONS: [(&str, f64); 5] = [
    ("tf_forward", 0.547),
    ("tf_backward", 0.401),
    ("pt_forward", 0.548),
    ("pt_backward", 0.387),
    ("pt_optimizer", 0.0),
];

pub struct Census {
    pub tf: FrameworkTrace,
    pub pt: FrameworkTrace,
    pub spec: GpuSpec,
}

pub fn census() -> Census {
    census_for(&crate::device::registry::default_spec())
}

/// Census on an explicit device (the zero-AI classification itself is
/// device-independent, but lowering needs the device's spec).
pub fn census_for(spec: &GpuSpec) -> Census {
    // Shares the process-wide paper-scale graph with the figure
    // generators (see `deepcam_figs::paper_graph`).
    let graph = super::deepcam_figs::paper_graph();
    Census {
        tf: lower(graph, Framework::TensorFlow, Policy::O1, spec),
        pt: lower(graph, Framework::PyTorch, Policy::O1, spec),
        spec: spec.clone(),
    }
}

impl Census {
    pub fn fraction(&self, key: &str) -> f64 {
        let (trace, phase) = self.lookup(key);
        let (z, n) = trace.zero_ai_census(phase, &self.spec);
        if n == 0 {
            0.0
        } else {
            z as f64 / n as f64
        }
    }

    pub fn counts(&self, key: &str) -> (u64, u64) {
        let (trace, phase) = self.lookup(key);
        trace.zero_ai_census(phase, &self.spec)
    }

    fn lookup(&self, key: &str) -> (&FrameworkTrace, Phase) {
        match key {
            "tf_forward" => (&self.tf, Phase::Forward),
            "tf_backward" => (&self.tf, Phase::Backward),
            "pt_forward" => (&self.pt, Phase::Forward),
            "pt_backward" => (&self.pt, Phase::Backward),
            "pt_optimizer" => (&self.pt, Phase::Optimizer),
            other => panic!("unknown census key {other}"),
        }
    }

    /// Total zero-AI invocations per framework (paper: TF 2137, PT 1046
    /// — TF over double PT).
    pub fn total_zero_ai(&self, fw: Framework) -> u64 {
        let trace = match fw {
            Framework::TensorFlow => &self.tf,
            Framework::PyTorch => &self.pt,
        };
        [Phase::Forward, Phase::Backward, Phase::Optimizer]
            .iter()
            .map(|&p| trace.zero_ai_census(p, &self.spec).0)
            .sum()
    }
}

pub fn generate() -> Result<Artifact> {
    generate_for(&crate::device::registry::default_spec())
}

/// Table III on an explicit device, named in the caption.
pub fn generate_for(spec: &GpuSpec) -> Result<Artifact> {
    let c = census_for(spec);
    let mut table = Table::new(&["segment", "zero-AI", "total", "frac (ours)", "frac (paper)"]);
    let mut rows = Vec::new();
    for (key, paper_frac) in PAPER_FRACTIONS {
        let (z, n) = c.counts(key);
        let frac = c.fraction(key);
        table.row(&[
            key.to_string(),
            z.to_string(),
            n.to_string(),
            fmt::pct(frac),
            fmt::pct(paper_frac),
        ]);
        rows.push(Json::obj(vec![
            ("segment", Json::str(key)),
            ("zero_ai", Json::num(z as f64)),
            ("total", Json::num(n as f64)),
            ("fraction", Json::num(frac)),
            ("paper_fraction", Json::num(paper_frac)),
        ]));
    }
    let tf_total = c.total_zero_ai(Framework::TensorFlow);
    let pt_total = c.total_zero_ai(Framework::PyTorch);
    let text = format!(
        "Table III — zero-AI kernel invocations (one training step, {})\n\n{}\n\
         TF total zero-AI: {tf_total}  |  PyTorch total zero-AI: {pt_total}  \
         (paper ratio 2137/1046 = 2.04; ours {:.2})\n",
        c.spec.name,
        table.render(),
        tf_total as f64 / pt_total.max(1) as f64
    );
    Ok(Artifact {
        id: "tab3".into(),
        title: "Zero-AI kernel invocation census (Table III)".into(),
        text,
        json: Json::obj(vec![
            ("rows", Json::arr(rows)),
            ("tf_total_zero_ai", Json::num(tf_total as f64)),
            ("pt_total_zero_ai", Json::num(pt_total as f64)),
        ]),
        svg: None,
        csv: None,
        lanes: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_within_ten_points_of_paper() {
        let c = census();
        for (key, paper) in PAPER_FRACTIONS {
            let ours = c.fraction(key);
            assert!(
                (ours - paper).abs() < 0.10,
                "{key}: ours {ours:.3} vs paper {paper:.3}"
            );
        }
    }

    #[test]
    fn tf_zero_ai_roughly_double_pytorch() {
        let c = census();
        let ratio = c.total_zero_ai(Framework::TensorFlow) as f64
            / c.total_zero_ai(Framework::PyTorch) as f64;
        assert!((1.5..=2.6).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn artifact_renders() {
        let a = generate().unwrap();
        assert!(a.text.contains("pt_optimizer"));
        assert!(a.json.get("tf_total_zero_ai").is_ok());
    }
}
