//! Fig. 1 — ERT machine characterization of the V100: compute ceilings
//! for FP64 / FP32 / FP16 / Tensor Core plus L1/L2/HBM bandwidths,
//! rendered as a roofline chart with no application points.

use crate::device::{GpuSpec, MemLevel};
use crate::util::error::Result;
use crate::ert::modeled;
use crate::ert::sweep::SweepConfig;
use crate::roofline::chart::{ChartConfig, RooflineChart};
use crate::roofline::model::{Ceilings, RooflineModel};
use crate::util::{fmt, Json, Table};

use super::Artifact;

/// Paper reference values (TFLOP/s) for the validation table.
pub const PAPER: [(&str, f64); 4] = [
    ("FP64", 7.7),
    ("FP32", 15.2),
    ("FP16", 29.2),
    ("TensorCore", 103.7),
];

pub fn generate() -> Result<Artifact> {
    generate_for(&crate::device::registry::default_spec())
}

/// Fig. 1 for an explicit device. The paper-reference comparison
/// columns only exist on the V100 testbed; other devices get their
/// swept ceilings without a paper column (there is nothing to validate
/// against), with the device named in every caption.
pub fn generate_for(spec: &GpuSpec) -> Result<Artifact> {
    let ceilings = modeled::characterize(spec, &SweepConfig::standard());
    // The paper columns belong to the registry's default entry (the
    // paper's testbed) — compared by name so the check tracks the
    // registry instead of duplicating the literal.
    let is_testbed = spec.name == crate::device::registry::default_spec().name;

    let mut json_rows = Vec::new();
    let table = if is_testbed {
        let mut table = Table::new(&["ceiling", "paper (TFLOP/s)", "ours (TFLOP/s)", "err"]);
        for (label, paper_tf) in PAPER {
            let ours = ceilings.compute(label).unwrap_or(0.0) / 1000.0;
            let err = crate::util::stats::rel_diff(ours, paper_tf);
            table.row(&[
                label.to_string(),
                format!("{paper_tf:.1}"),
                format!("{ours:.1}"),
                fmt::pct(err),
            ]);
            json_rows.push(Json::obj(vec![
                ("label", Json::str(label)),
                ("paper_tflops", Json::num(paper_tf)),
                ("ours_tflops", Json::num(ours)),
            ]));
        }
        table
    } else {
        let mut table = Table::new(&["ceiling", "swept (TFLOP/s)"]);
        for (label, gf) in &ceilings.compute_gflops {
            table.row(&[label.clone(), format!("{:.1}", gf / 1000.0)]);
            json_rows.push(Json::obj(vec![
                ("label", Json::str(label)),
                ("ours_tflops", Json::num(gf / 1000.0)),
            ]));
        }
        table
    };
    let mut bw_table = Table::new(&["level", "GB/s (swept)"]);
    for level in MemLevel::ALL {
        bw_table.row(&[
            level.name().to_string(),
            format!("{:.0}", ceilings.bandwidth(level).unwrap_or(0.0)),
        ]);
    }

    // Chart: device ceilings only (empty profile).
    let model = RooflineModel {
        ceilings: Ceilings::from_spec(spec),
        points: Vec::new(),
        device_name: spec.name.clone(),
    };
    let chart = RooflineChart::new(
        &model,
        ChartConfig::paper_style(&format!(
            "Fig. 1 — {} Roofline ceilings (ERT, modeled)",
            spec.name
        )),
    );

    let text = format!(
        "Fig. 1 — ERT machine characterization ({})\n\n{}\n{}",
        spec.name,
        table.render(),
        bw_table.render()
    );
    Ok(Artifact {
        id: "fig1".into(),
        title: format!("ERT roofline ceilings ({})", spec.name),
        text,
        json: Json::obj(vec![
            ("ceilings", Json::arr(json_rows)),
            (
                "bandwidth_gbs",
                Json::arr(MemLevel::ALL.iter().map(|&l| {
                    Json::obj(vec![
                        ("level", Json::str(l.name())),
                        ("gbs", Json::num(ceilings.bandwidth(l).unwrap_or(0.0))),
                    ])
                })),
            ),
        ]),
        svg: Some(chart.to_svg()),
        csv: None,
        lanes: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_artifact_matches_paper_within_7pct() {
        let a = generate().unwrap();
        let rows = a.json.get("ceilings").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        for row in rows {
            let paper = row.get("paper_tflops").unwrap().as_f64().unwrap();
            let ours = row.get("ours_tflops").unwrap().as_f64().unwrap();
            let err = crate::util::stats::rel_diff(ours, paper);
            assert!(err < 0.07, "{row}: err {err}");
        }
        assert!(a.svg.is_some());
        assert!(a.text.contains("TensorCore"));
    }

    #[test]
    fn fig1_generates_for_alternate_devices() {
        // Non-testbed devices: swept ceilings, no paper column, device
        // named in caption and chart.
        let spec = GpuSpec::a100();
        let a = generate_for(&spec).unwrap();
        assert!(a.text.contains("A100-SXM4-40GB"), "{}", a.text);
        assert!(!a.text.contains("paper (TFLOP/s)"), "{}", a.text);
        assert!(a.svg.unwrap().contains("A100-SXM4-40GB"));
    }
}
