//! Fig. 1 — ERT machine characterization of the V100: compute ceilings
//! for FP64 / FP32 / FP16 / Tensor Core plus L1/L2/HBM bandwidths,
//! rendered as a roofline chart with no application points.

use crate::device::{GpuSpec, MemLevel};
use crate::util::error::Result;
use crate::ert::modeled;
use crate::ert::sweep::SweepConfig;
use crate::roofline::chart::{ChartConfig, RooflineChart};
use crate::roofline::model::{Ceilings, RooflineModel};
use crate::util::{fmt, Json, Table};

use super::Artifact;

/// Paper reference values (TFLOP/s) for the validation table.
pub const PAPER: [(&str, f64); 4] = [
    ("FP64", 7.7),
    ("FP32", 15.2),
    ("FP16", 29.2),
    ("TensorCore", 103.7),
];

pub fn generate() -> Result<Artifact> {
    let spec = GpuSpec::v100();
    let ceilings = modeled::characterize(&spec, &SweepConfig::standard());

    let mut table = Table::new(&["ceiling", "paper (TFLOP/s)", "ours (TFLOP/s)", "err"]);
    let mut json_rows = Vec::new();
    for (label, paper_tf) in PAPER {
        let ours = ceilings.compute(label).unwrap_or(0.0) / 1000.0;
        let err = crate::util::stats::rel_diff(ours, paper_tf);
        table.row(&[
            label.to_string(),
            format!("{paper_tf:.1}"),
            format!("{ours:.1}"),
            fmt::pct(err),
        ]);
        json_rows.push(Json::obj(vec![
            ("label", Json::str(label)),
            ("paper_tflops", Json::num(paper_tf)),
            ("ours_tflops", Json::num(ours)),
        ]));
    }
    let mut bw_table = Table::new(&["level", "GB/s (swept)"]);
    for level in MemLevel::ALL {
        bw_table.row(&[
            level.name().to_string(),
            format!("{:.0}", ceilings.bandwidth(level).unwrap_or(0.0)),
        ]);
    }

    // Chart: device ceilings only (empty profile).
    let model = RooflineModel {
        ceilings: Ceilings::from_spec(&spec),
        points: Vec::new(),
        device_name: spec.name.clone(),
    };
    let chart = RooflineChart::new(
        &model,
        ChartConfig::paper_style("Fig. 1 — V100 Roofline ceilings (ERT, modeled)"),
    );

    let text = format!(
        "Fig. 1 — ERT machine characterization (V100)\n\n{}\n{}",
        table.render(),
        bw_table.render()
    );
    Ok(Artifact {
        id: "fig1".into(),
        title: "ERT roofline ceilings (V100)".into(),
        text,
        json: Json::obj(vec![
            ("ceilings", Json::arr(json_rows)),
            (
                "bandwidth_gbs",
                Json::arr(MemLevel::ALL.iter().map(|&l| {
                    Json::obj(vec![
                        ("level", Json::str(l.name())),
                        ("gbs", Json::num(ceilings.bandwidth(l).unwrap_or(0.0))),
                    ])
                })),
            ),
        ]),
        svg: Some(chart.to_svg()),
        csv: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_artifact_matches_paper_within_7pct() {
        let a = generate().unwrap();
        let rows = a.json.get("ceilings").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 4);
        for row in rows {
            let paper = row.get("paper_tflops").unwrap().as_f64().unwrap();
            let ours = row.get("ours_tflops").unwrap().as_f64().unwrap();
            let err = crate::util::stats::rel_diff(ours, paper);
            assert!(err < 0.07, "{row}: err {err}");
        }
        assert!(a.svg.is_some());
        assert!(a.text.contains("TensorCore"));
    }
}
