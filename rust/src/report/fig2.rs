//! Fig. 2 — Tensor-core GEMM performance vs matrix size, cuBLAS-class
//! vs hand-written WMMA. Rendered as an SVG line chart plus a table.

use crate::device::GpuSpec;
use crate::util::error::Result;
use crate::ert::gemm::{gemm_sweep, GemmImpl, GemmPoint};
use crate::util::{Json, Table};

use super::Artifact;

pub fn generate() -> Result<Artifact> {
    generate_for(&crate::device::registry::default_spec())
}

/// Fig. 2 on an explicit device (the paper asymptote note only applies
/// on the V100 testbed; the sweep itself is device-parametric).
pub fn generate_for(spec: &GpuSpec) -> Result<Artifact> {
    let sweep = gemm_sweep(spec);

    let mut table = Table::new(&["M=N=K", "cuBLAS (TFLOP/s)", "wmma (TFLOP/s)", "cuBLAS %peak"]);
    let mut rows = Vec::new();
    for pair in sweep.chunks(2) {
        let (cublas, wmma) = (&pair[0], &pair[1]);
        table.row(&[
            cublas.m.to_string(),
            format!("{:.1}", cublas.tflops),
            format!("{:.1}", wmma.tflops),
            format!("{:.1}%", cublas.fraction_of_peak * 100.0),
        ]);
        rows.push(Json::obj(vec![
            ("m", Json::num(cublas.m as f64)),
            ("cublas_tflops", Json::num(cublas.tflops)),
            ("wmma_tflops", Json::num(wmma.tflops)),
        ]));
    }
    let svg = line_chart(spec, &sweep);
    Ok(Artifact {
        id: "fig2".into(),
        title: format!("Tensor-core GEMM vs matrix size (Fig. 2, {})", spec.name),
        text: format!(
            "Fig. 2 — TC GEMM sweep on {} (paper asymptotes on the V100 testbed: \
             cuBLAS 103.7 TFLOP/s @96.5%, wmma 58 @54%)\n\n{}",
            spec.name,
            table.render()
        ),
        json: Json::obj(vec![("rows", Json::arr(rows))]),
        svg: Some(svg),
        csv: None,
        lanes: Vec::new(),
    })
}

/// Simple log-x line chart for the sweep.
fn line_chart(spec: &GpuSpec, sweep: &[GemmPoint]) -> String {
    let (w, h) = (800.0, 500.0);
    let peak = spec.theoretical_tensor_flops() / 1e12;
    let x = |m: u64| -> f64 {
        let lo = (256f64).log2();
        let hi = (32768f64).log2();
        60.0 + ((m as f64).log2() - lo) / (hi - lo) * (w - 100.0)
    };
    let y = |tf: f64| -> f64 { (h - 50.0) - tf / (peak * 1.05) * (h - 90.0) };
    let mut svg = format!(
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{w}" height="{h}"><rect width="{w}" height="{h}" fill="white"/><text x="{tx}" y="24" text-anchor="middle" font-size="15" font-family="sans-serif">Fig. 2 — Tensor Core GEMM performance vs matrix size</text>"##,
        tx = w / 2.0
    );
    // peak line
    svg.push_str(&format!(
        r##"<line x1="60" y1="{py:.1}" x2="{xe}" y2="{py:.1}" stroke="#888888" stroke-dasharray="5,3"/><text x="{xe}" y="{ty:.1}" text-anchor="end" font-size="10" font-family="sans-serif">theoretical peak {peak:.1} TFLOP/s</text>"##,
        py = y(peak),
        ty = y(peak) - 5.0,
        xe = w - 40.0,
    ));
    for (imp, color) in [(GemmImpl::Cublas, "#1f6fd0"), (GemmImpl::Wmma, "#d03030")] {
        let pts: Vec<String> = sweep
            .iter()
            .filter(|p| p.imp == imp)
            .map(|p| format!("{:.1},{:.1}", x(p.m), y(p.tflops)))
            .collect();
        svg.push_str(&format!(
            r##"<polyline points="{}" fill="none" stroke="{color}" stroke-width="2"/>"##,
            pts.join(" ")
        ));
        for p in sweep.iter().filter(|p| p.imp == imp) {
            svg.push_str(&format!(
                r##"<circle cx="{:.1}" cy="{:.1}" r="3" fill="{color}"><title>{} M={} {:.1} TFLOP/s</title></circle>"##,
                x(p.m),
                y(p.tflops),
                imp.name(),
                p.m,
                p.tflops
            ));
        }
    }
    svg.push_str(&format!(
        r##"<text x="80" y="60" font-size="11" font-family="sans-serif" fill="#1f6fd0">cuBLAS</text><text x="80" y="76" font-size="11" font-family="sans-serif" fill="#d03030">wmma</text><line x1="60" y1="{yb}" x2="{xe}" y2="{yb}" stroke="black"/><line x1="60" y1="{yb}" x2="60" y2="40" stroke="black"/></svg>"##,
        yb = h - 50.0,
        xe = w - 40.0,
    ));
    svg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig2_artifact_shape() {
        let a = generate().unwrap();
        let rows = a.json.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 8); // 256..32768 by powers of 2
        // who-wins holds in every row
        for r in rows {
            let c = r.get("cublas_tflops").unwrap().as_f64().unwrap();
            let w = r.get("wmma_tflops").unwrap().as_f64().unwrap();
            assert!(c > w);
        }
        let svg = a.svg.unwrap();
        assert_eq!(svg.matches("<polyline").count(), 2);
    }
}
