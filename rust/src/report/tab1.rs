//! Table I — the FP16 CUDA-core tuning ladder (v1 naive → v5 u32-only).

use crate::device::GpuSpec;
use crate::util::error::Result;
use crate::ert::fp16_ladder::ladder;
use crate::util::{fmt, Json, Table};

use super::Artifact;

pub fn generate() -> Result<Artifact> {
    generate_for(&crate::device::registry::default_spec())
}

/// Table I on an explicit device: the ladder *model* evaluates on any
/// spec; the paper column is the published V100 measurement.
pub fn generate_for(spec: &GpuSpec) -> Result<Artifact> {
    let mut table = Table::new(&[
        "Version",
        "Implementation",
        "Paper (TFLOP/s)",
        "Model (TFLOP/s)",
        "err",
    ]);
    let mut rows = Vec::new();
    for v in ladder() {
        let model = v.tflops(spec);
        table.row(&[
            v.name.to_string(),
            v.description.to_string(),
            format!("{:.3}", v.paper_tflops),
            format!("{model:.3}"),
            fmt::pct(v.error_vs_paper(spec)),
        ]);
        rows.push(Json::obj(vec![
            ("version", Json::str(v.name)),
            ("description", Json::str(v.description)),
            ("paper_tflops", Json::num(v.paper_tflops)),
            ("model_tflops", Json::num(model)),
        ]));
    }
    Ok(Artifact {
        id: "tab1".into(),
        title: "FP16 performance ladder on the CUDA core (Table I)".into(),
        text: format!(
            "Table I — FP16 CUDA-core tuning ladder ({})\n\n{}",
            spec.name,
            table.render()
        ),
        json: Json::obj(vec![("rows", Json::arr(rows))]),
        svg: None,
        csv: None,
        lanes: Vec::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tab1_five_rows_in_order() {
        let a = generate().unwrap();
        let rows = a.json.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 5);
        let tflops: Vec<f64> = rows
            .iter()
            .map(|r| r.get("model_tflops").unwrap().as_f64().unwrap())
            .collect();
        for w in tflops.windows(2) {
            assert!(w[1] > w[0]);
        }
    }
}
