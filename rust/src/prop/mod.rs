//! Deterministic property-based testing substrate (no `proptest`
//! offline). A property is checked against `cases` pseudo-random inputs
//! drawn from caller-supplied generators; failures report the seed and
//! case index so they can be replayed exactly.
//!
//! ```
//! use hroofline::prop::{check, Gen};
//! check("abs is non-negative", 256, |g| {
//!     let x = g.i64_range(-1000, 1000);
//!     assert!(x.abs() >= 0);
//! });
//! ```

use crate::util::Rng;

/// Input generator handed to each property case.
pub struct Gen {
    rng: Rng,
}

impl Gen {
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }

    pub fn u64_below(&mut self, n: u64) -> u64 {
        self.rng.below(n)
    }

    pub fn usize_range(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo as u64, hi as u64) as usize
    }

    pub fn i64_range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + self.rng.below((hi - lo + 1) as u64) as i64
    }

    pub fn f64_range(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.f64_range(lo, hi)
    }

    /// Log-uniform positive float — natural for sizes/intensities that
    /// span decades.
    pub fn f64_log(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.log_uniform(lo, hi)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        self.rng.choose(xs)
    }

    /// Vector of `len` items from `f`.
    pub fn vec_of<T>(&mut self, len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        (0..len).map(|_| f(self)).collect()
    }
}

/// Environment seed override for replaying failures:
/// `HROOFLINE_PROP_SEED=<u64> cargo test`.
fn base_seed() -> u64 {
    std::env::var("HROOFLINE_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FF_EE00_D15E_A5E5)
}

/// Run `property` against `cases` generated inputs. Panics (failing the
/// enclosing `#[test]`) on the first violated case, reporting seed+index.
pub fn check(name: &str, cases: u32, property: impl Fn(&mut Gen) + std::panic::RefUnwindSafe) {
    let seed = base_seed();
    for case in 0..cases {
        let case_seed = seed ^ (case as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(case_seed),
            };
            property(&mut g);
        });
        if let Err(panic) = result {
            let msg = panic
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| panic.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: HROOFLINE_PROP_SEED={seed}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum commutes", 128, |g| {
            let a = g.i64_range(-100, 100);
            let b = g.i64_range(-100, 100);
            assert_eq!(a + b, b + a);
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        check("always fails", 16, |_| panic!("boom"));
    }

    #[test]
    fn generators_respect_bounds() {
        check("bounds", 256, |g| {
            let u = g.usize_range(2, 9);
            assert!((2..=9).contains(&u));
            let f = g.f64_log(1e-3, 1e3);
            assert!(f >= 0.99e-3 && f <= 1.01e3);
            let v = g.vec_of(5, |g| g.bool());
            assert_eq!(v.len(), 5);
        });
    }

    #[test]
    fn deterministic_given_seed() {
        // Two runs of the same property observe identical draws.
        use std::sync::Mutex;
        let log1 = Mutex::new(Vec::new());
        check("collect1", 8, |g| log1.lock().unwrap().push(g.u64_below(1000)));
        let log2 = Mutex::new(Vec::new());
        check("collect2", 8, |g| log2.lock().unwrap().push(g.u64_below(1000)));
        assert_eq!(*log1.lock().unwrap(), *log2.lock().unwrap());
    }
}
