//! `repro` — the hroofline command-line interface.
//!
//! Subcommands map onto the paper's workflow:
//!   ert         machine characterization (§II-A): empirical host sweep
//!               and/or modeled V100 sweep; writes Fig. 1 data + SVG
//!   metrics     list/inspect the Nsight-analog metric registry (Table II)
//!   profile     application characterization (§II-B): lower DeepCAM under
//!               a framework personality + AMP policy, collect counters,
//!               print the kernel table, write the hierarchical roofline
//!   matrix      scenario-matrix sweep: workload registry × device
//!               registry × framework × phase × AMP policy,
//!               per-scenario artifacts + comparison (+ cross-device);
//!               --incremental replays clean cells from a content-
//!               addressed store, --shard/--merge split the sweep
//!               across CI jobs and union the results
//!   report      regenerate paper artifacts (figures/tables) into out/
//!   ingest      stream a raw Nsight Compute counter CSV (any size;
//!               bounded memory) into the same artifact set as a
//!               simulated profile: `repro ingest <csv>`
//!   train       end-to-end: run the AOT-compiled DeepCAM-lite training
//!               loop through PJRT, logging the loss curve
//!   bench-diff  gate the bench trajectory against a committed baseline
//!   trace       digest a --trace run log: `repro trace report <jsonl>`
//!
//! Global stderr verbosity (any command): `--quiet`/`-q` shows errors
//! only, `-v`/`--verbose` adds debug detail; `HROOFLINE_LOG` sets the
//! ambient default (an explicit flag beats the env var). The `--trace
//! PATH` flag on `ert`/`profile`/`matrix` (or `HROOFLINE_TRACE`) arms
//! span tracing: the run writes a `hroofline-trace-v1` JSONL log to
//! PATH plus a `run.metrics.json` counter snapshot next to the
//! artifacts, without perturbing any artifact bytes.
//!
//! Exit codes:
//!   0  success
//!   1  command error (bad input, I/O failure — nothing useful produced)
//!   2  CLI/usage error (unknown command or flag)
//!   3  matrix: one or more cells failed; surviving cells still wrote
//!      artifacts and matrix.errors.json lists the casualties
//!
//! Run `repro <cmd> --help` for flags.

use hroofline::cli::{App, Cmd};
use hroofline::obs::log::{self, Level};

fn main() {
    // Peel the global verbosity flags off before command parsing so
    // they work uniformly on every subcommand, then set the level:
    // binary default Warn < HROOFLINE_LOG < explicit flag.
    let mut quiet = false;
    let mut verbose = false;
    let argv: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| match a.as_str() {
            "--quiet" | "-q" => {
                quiet = true;
                false
            }
            "--verbose" | "-v" => {
                verbose = true;
                false
            }
            _ => true,
        })
        .collect();
    log::init(Level::Warn);
    if quiet {
        log::set_level(Level::Error);
    }
    if verbose {
        log::set_level(Level::Debug);
    }
    // `trace report <path>` and `ingest <csv>` take positional
    // operands, which the flag-only Cmd grammar can't express — route
    // them directly. The Cmds registered below only serve the usage
    // listing.
    if argv.first().is_some_and(|a| a == "trace") {
        if let Err(e) = hroofline::coordinator::cmd_trace(&argv[1..]) {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    if argv.first().is_some_and(|a| a == "ingest") {
        if let Err(e) = hroofline::coordinator::cmd_ingest(&argv[1..]) {
            eprintln!("error: {e:#}");
            std::process::exit(1);
        }
        return;
    }
    let app = App::new("repro", "Hierarchical Roofline analysis for deep learning (cs.DC 2020)")
        .command(
            Cmd::new("ert", "Machine characterization sweeps (Fig. 1, Tab. I, Fig. 2)")
                .flag("mode", "modeled", "modeled | empirical | both")
                .flag(
                    "device",
                    "default",
                    "comma-separated registry devices, 'all', or 'default' (the V100 testbed)",
                )
                .flag("out", "out/ert", "output directory")
                .flag("trace", "", "write a span trace (hroofline-trace-v1 JSONL) to this path")
                .switch("quick", "reduced sweep grid"),
        )
        .command(Cmd::new("metrics", "List the Nsight-analog metric registry (Tab. II)"))
        .command(
            Cmd::new("profile", "Profile DeepCAM under a framework personality (Figs 3-7)")
                .flag("framework", "tensorflow", "tensorflow | pytorch")
                .flag("phase", "forward", "forward | backward | optimizer | all")
                .flag("amp", "O1", "O0 | O1 | O2 | off | manual-fp16")
                .flag("scale", "paper", "paper | lite")
                .flag(
                    "device",
                    "default",
                    "comma-separated registry devices, 'all', or 'default' (the V100 testbed)",
                )
                .flag(
                    "from-csv",
                    "",
                    "re-ingest an exported counter CSV instead of simulating",
                )
                .switch("lenient", "with --from-csv: skip and report malformed rows")
                .flag("out", "out/profile", "output directory")
                .flag("trace", "", "write a span trace (hroofline-trace-v1 JSONL) to this path"),
        )
        .command(
            Cmd::new(
                "matrix",
                "Scenario-matrix sweep: workloads x devices x frameworks x phases x AMP",
            )
            .flag("workloads", "all", "comma-separated workload names, or 'all'")
            .flag(
                "device",
                "default",
                "comma-separated registry devices, 'all', or 'default' \
                 (quick: v100 only; full: all registered)",
            )
            .flag("out", "out/matrix", "output directory")
            .flag(
                "max-failures",
                "unlimited",
                "stop the sweep after this many failed cells (default: never stop early)",
            )
            .flag(
                "inject-fault",
                "",
                "deterministic fault plan for drills, e.g. 'panic:<cell-id>;seed=7'",
            )
            .flag(
                "store",
                ".hroofline-cache",
                "cell-store directory for --incremental (content-addressed profiles)",
            )
            .flag("shard", "", "own every Nth cell of the enumeration, as 'i/N'")
            .flag(
                "merge",
                "",
                "comma-separated shard store dirs: replay their union into one report",
            )
            .flag("trace", "", "write a span trace (hroofline-trace-v1 JSONL) to this path")
            .switch("fail-fast", "stop the sweep at the first failed cell")
            .switch("quick", "reduced matrix at smoke scale (the CI gate)")
            .switch(
                "incremental",
                "serve clean cells from --store, re-run and persist dirty ones",
            )
            .switch("print-keys", "print '<cell key> <scenario id>' per cell and exit"),
        )
        .command(
            Cmd::new("report", "Regenerate paper tables/figures into out/report")
                .flag(
                    "only",
                    "all",
                    "all | fig1 | fig2 | fig3 | fig4 | fig5 | fig6 | fig7 | fig8 | fig9 | tab1 | tab3",
                )
                .flag("out", "out/report", "output directory"),
        )
        .command(
            Cmd::new("train", "End-to-end PJRT training of DeepCAM-lite (loss curve)")
                .flag("steps", "100", "training steps")
                .flag("artifacts", "artifacts", "artifact directory")
                .flag("out", "out/train", "output directory")
                .flag("log-every", "10", "steps between loss log lines"),
        )
        .command(
            Cmd::new("bench-diff", "Diff a fresh BENCH_<group>.json against a baseline")
                .flag_required("baseline", "committed baseline BENCH_<group>.json")
                .flag_required("fresh", "freshly generated BENCH_<group>.json")
                .flag("max-regress", "0.25", "allowed fractional ns/iter slowdown"),
        )
        // Parsed by the early intercepts above; listed here for usage.
        .command(hroofline::coordinator::ingest_cmd_spec())
        .command(Cmd::new("trace", "Digest a span trace: repro trace report <trace.jsonl>"));

    let (cmd, parsed) = match app.dispatch(&argv) {
        Ok(x) => x,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };

    let result = match cmd.as_str() {
        "ert" => hroofline::coordinator::cmd_ert(&parsed),
        "metrics" => hroofline::coordinator::cmd_metrics(&parsed),
        "profile" => hroofline::coordinator::cmd_profile(&parsed),
        // `matrix` signals partial failure (some cells died, the rest
        // produced artifacts) through its own exit code — see the
        // module docs above.
        "matrix" => match hroofline::coordinator::cmd_matrix(&parsed) {
            Ok(0) => Ok(()),
            Ok(code) => std::process::exit(code),
            Err(e) => Err(e),
        },
        "report" => hroofline::coordinator::cmd_report(&parsed),
        "train" => hroofline::coordinator::cmd_train(&parsed),
        "bench-diff" => hroofline::coordinator::cmd_bench_diff(&parsed),
        other => {
            eprintln!("unhandled command {other}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}
