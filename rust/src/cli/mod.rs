//! Declarative command-line parsing substrate (the offline vendor set has
//! no `clap`). Supports subcommands, `--flag value`, `--flag=value`,
//! boolean switches, defaults, and auto-generated `--help`.
//!
//! ```
//! use hroofline::cli::{Cmd, Parsed};
//! let cmd = Cmd::new("ert", "Run machine characterization")
//!     .flag("mode", "modeled", "empirical|modeled|both")
//!     .switch("quick", "Reduced sweep for smoke runs");
//! let parsed = cmd.parse(&["--mode".into(), "both".into(), "--quick".into()]).unwrap();
//! assert_eq!(parsed.get("mode"), "both");
//! assert!(parsed.has("quick"));
//! ```

use std::collections::BTreeMap;

/// A flag specification.
#[derive(Clone, Debug)]
struct FlagSpec {
    name: String,
    default: Option<String>,
    help: String,
    is_switch: bool,
}

/// A (sub)command specification.
#[derive(Clone, Debug)]
pub struct Cmd {
    pub name: String,
    pub about: String,
    flags: Vec<FlagSpec>,
}

/// Parse result: resolved flag values.
#[derive(Clone, Debug)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    switches: BTreeMap<String, bool>,
}

/// CLI parse error with a user-facing message.
#[derive(Debug, PartialEq)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CliError {}

/// Edit distance for did-you-mean suggestions (classic two-row DP).
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, &ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, &cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// The closest candidate to `input`, when close enough to be a
/// plausible typo (distance ≤ 2, or ≤ a third of the input length for
/// long names). Used for "did you mean" hints on unknown flags,
/// commands, and workload names.
pub fn suggest<'a, I>(input: &str, candidates: I) -> Option<&'a str>
where
    I: IntoIterator<Item = &'a str>,
{
    let budget = 2usize.max(input.chars().count() / 3);
    candidates
        .into_iter()
        .map(|c| (levenshtein(input, c), c))
        .filter(|&(d, _)| d <= budget)
        .min_by_key(|&(d, _)| d)
        .map(|(_, c)| c)
}

/// Render a did-you-mean suffix for an error message ("" when no
/// candidate is close enough). `prefix` decorates the suggestion (e.g.
/// "--" for flags). Shared by flag/command errors here and by
/// name-resolving registries ([`crate::dl::workloads`]).
pub fn hint<'a, I>(input: &str, prefix: &str, candidates: I) -> String
where
    I: IntoIterator<Item = &'a str>,
{
    match suggest(input, candidates) {
        Some(s) => format!(" (did you mean '{prefix}{s}'?)"),
        None => String::new(),
    }
}

/// Parse the unified `--device` list syntax shared by `repro
/// ert|profile|matrix`: a comma-separated list of registry names or
/// short aliases, `all` (every registered device, registry order), or
/// `default` (the registry default — the paper's V100 testbed).
/// Duplicates collapse; unknown names get the registry's did-you-mean
/// hint.
pub fn parse_device_list(
    list: &str,
) -> Result<Vec<&'static crate::device::registry::DeviceEntry>, CliError> {
    use crate::device::registry as devices;
    if list == "all" {
        return Ok(devices::entries().iter().collect());
    }
    if list == "default" {
        return Ok(vec![devices::default_entry()]);
    }
    let mut selected: Vec<&'static devices::DeviceEntry> = Vec::new();
    for name in list.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let d = devices::lookup(name)?;
        if !selected.iter().any(|s| s.name == d.name) {
            selected.push(d);
        }
    }
    if selected.is_empty() {
        return Err(CliError("--device selected nothing (try --help)".into()));
    }
    Ok(selected)
}

/// Parse the `--shard i/N` syntax shared by `repro matrix` and its CI
/// sharding topology: a 0-based shard index and the total shard count,
/// `i < N`, `N ≥ 1`. Returns `(index, count)`.
pub fn parse_shard(s: &str) -> Result<(usize, usize), CliError> {
    let err = || CliError(format!("--shard expects 'i/N' with 0 <= i < N, got '{s}'"));
    let (i, n) = s.split_once('/').ok_or_else(err)?;
    let index: usize = i.trim().parse().map_err(|_| err())?;
    let count: usize = n.trim().parse().map_err(|_| err())?;
    if count == 0 || index >= count {
        return Err(err());
    }
    Ok((index, count))
}

impl Cmd {
    pub fn new(name: &str, about: &str) -> Cmd {
        Cmd {
            name: name.to_string(),
            about: about.to_string(),
            flags: Vec::new(),
        }
    }

    /// Value flag with a default.
    pub fn flag(mut self, name: &str, default: &str, help: &str) -> Cmd {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            default: Some(default.to_string()),
            help: help.to_string(),
            is_switch: false,
        });
        self
    }

    /// Required value flag (no default).
    pub fn flag_required(mut self, name: &str, help: &str) -> Cmd {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_switch: false,
        });
        self
    }

    /// Boolean switch (present/absent).
    pub fn switch(mut self, name: &str, help: &str) -> Cmd {
        self.flags.push(FlagSpec {
            name: name.to_string(),
            default: None,
            help: help.to_string(),
            is_switch: true,
        });
        self
    }

    /// Render usage text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nFlags:\n", self.name, self.about);
        for f in &self.flags {
            let head = if f.is_switch {
                format!("  --{}", f.name)
            } else if let Some(d) = &f.default {
                format!("  --{} <value>  (default: {})", f.name, d)
            } else {
                format!("  --{} <value>  (required)", f.name)
            };
            out.push_str(&format!("{head}\n        {}\n", f.help));
        }
        out.push_str("  --help\n        Show this message\n");
        out
    }

    /// Parse an argument list (without the subcommand name itself).
    pub fn parse(&self, args: &[String]) -> Result<Parsed, CliError> {
        let mut values = BTreeMap::new();
        let mut switches = BTreeMap::new();
        for f in &self.flags {
            if f.is_switch {
                switches.insert(f.name.clone(), false);
            } else if let Some(d) = &f.default {
                values.insert(f.name.clone(), d.clone());
            }
        }

        let mut i = 0;
        while i < args.len() {
            let arg = &args[i];
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            let Some(body) = arg.strip_prefix("--") else {
                return Err(CliError(format!(
                    "unexpected positional argument '{arg}' (try --help)"
                )));
            };
            let (name, inline_value) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let Some(spec) = self.flags.iter().find(|f| f.name == name) else {
                let hint = hint(name, "--", self.flags.iter().map(|f| f.name.as_str()));
                return Err(CliError(format!(
                    "unknown flag '--{name}'{hint} (try --help)"
                )));
            };
            if spec.is_switch {
                if inline_value.is_some() {
                    return Err(CliError(format!("switch '--{name}' takes no value")));
                }
                switches.insert(name.to_string(), true);
                i += 1;
            } else {
                let value = match inline_value {
                    Some(v) => v,
                    None => {
                        i += 1;
                        args.get(i)
                            .cloned()
                            .ok_or_else(|| CliError(format!("flag '--{name}' needs a value")))?
                    }
                };
                values.insert(name.to_string(), value);
                i += 1;
            }
        }

        // Check required flags.
        for f in &self.flags {
            if !f.is_switch && f.default.is_none() && !values.contains_key(&f.name) {
                return Err(CliError(format!("missing required flag '--{}'", f.name)));
            }
        }
        Ok(Parsed { values, switches })
    }
}

impl Parsed {
    /// Get a value flag (panics if the flag was not declared — programmer
    /// error, not user error).
    pub fn get(&self, name: &str) -> &str {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag '{name}' not declared"))
    }

    /// Parse a flag value into any FromStr type.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        self.get(name)
            .parse()
            .map_err(|_| CliError(format!("flag '--{name}': cannot parse '{}'", self.get(name))))
    }

    /// Whether a switch was passed.
    pub fn has(&self, name: &str) -> bool {
        *self
            .switches
            .get(name)
            .unwrap_or_else(|| panic!("switch '{name}' not declared"))
    }
}

/// A multi-command application: dispatches `argv[1]` to a subcommand.
pub struct App {
    pub name: String,
    pub about: String,
    pub commands: Vec<Cmd>,
}

impl App {
    pub fn new(name: &str, about: &str) -> App {
        App {
            name: name.to_string(),
            about: about.to_string(),
            commands: Vec::new(),
        }
    }

    pub fn command(mut self, cmd: Cmd) -> App {
        self.commands.push(cmd);
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nCommands:\n", self.name, self.about);
        for c in &self.commands {
            out.push_str(&format!("  {:<12} {}\n", c.name, c.about));
        }
        out.push_str("\nRun '<command> --help' for command flags.\n");
        out
    }

    /// Resolve argv into (command name, parsed flags).
    pub fn dispatch(&self, argv: &[String]) -> Result<(String, Parsed), CliError> {
        let Some(cmd_name) = argv.first() else {
            return Err(CliError(self.usage()));
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(CliError(self.usage()));
        }
        let Some(cmd) = self.commands.iter().find(|c| &c.name == cmd_name) else {
            let hint = hint(cmd_name, "", self.commands.iter().map(|c| c.name.as_str()));
            return Err(CliError(format!(
                "unknown command '{cmd_name}'{hint}\n\n{}",
                self.usage()
            )));
        };
        let parsed = cmd.parse(&argv[1..])?;
        Ok((cmd.name.clone(), parsed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let cmd = Cmd::new("x", "t").flag("mode", "modeled", "h").switch("quick", "h");
        let p = cmd.parse(&argv(&[])).unwrap();
        assert_eq!(p.get("mode"), "modeled");
        assert!(!p.has("quick"));
        let p = cmd.parse(&argv(&["--mode=empirical", "--quick"])).unwrap();
        assert_eq!(p.get("mode"), "empirical");
        assert!(p.has("quick"));
    }

    #[test]
    fn space_separated_value() {
        let cmd = Cmd::new("x", "t").flag("steps", "100", "h");
        let p = cmd.parse(&argv(&["--steps", "250"])).unwrap();
        assert_eq!(p.get_as::<usize>("steps").unwrap(), 250);
    }

    #[test]
    fn required_flag_enforced() {
        let cmd = Cmd::new("x", "t").flag_required("out", "h");
        assert!(cmd.parse(&argv(&[])).is_err());
        assert!(cmd.parse(&argv(&["--out", "/tmp"])).is_ok());
    }

    #[test]
    fn unknown_flag_rejected() {
        let cmd = Cmd::new("x", "t");
        let err = cmd.parse(&argv(&["--bogus"])).unwrap_err();
        assert!(err.0.contains("unknown flag"));
    }

    #[test]
    fn unknown_flag_gets_did_you_mean() {
        let cmd = Cmd::new("x", "t").flag("workloads", "all", "h").switch("quick", "h");
        let err = cmd.parse(&argv(&["--workload", "a"])).unwrap_err();
        assert!(err.0.contains("unknown flag '--workload'"), "{}", err.0);
        assert!(err.0.contains("did you mean '--workloads'?"), "{}", err.0);
        // A flag nothing like any spec gets no suggestion.
        let err = cmd.parse(&argv(&["--zzzzzzzz"])).unwrap_err();
        assert!(!err.0.contains("did you mean"), "{}", err.0);
    }

    #[test]
    fn unknown_command_gets_did_you_mean() {
        let app = App::new("repro", "t")
            .command(Cmd::new("matrix", "a"))
            .command(Cmd::new("report", "b"));
        let err = app.dispatch(&argv(&["matrxi"])).unwrap_err();
        assert!(err.0.contains("did you mean 'matrix'?"), "{}", err.0);
    }

    #[test]
    fn suggest_picks_closest_within_budget() {
        assert_eq!(suggest("pytorch", ["pytorch", "tensorflow"]), Some("pytorch"));
        assert_eq!(suggest("pytroch", ["pytorch", "tensorflow"]), Some("pytorch"));
        assert_eq!(suggest("resnt", ["resnet", "transformer"]), Some("resnet"));
        assert_eq!(suggest("caffe", ["pytorch", "tensorflow"]), None);
        assert_eq!(levenshtein("kitten", "sitting"), 3);
        assert_eq!(levenshtein("", "abc"), 3);
        assert_eq!(levenshtein("abc", "abc"), 0);
    }

    #[test]
    fn missing_value_rejected() {
        let cmd = Cmd::new("x", "t").flag("mode", "a", "h");
        assert!(cmd.parse(&argv(&["--mode"])).is_err());
    }

    #[test]
    fn switch_with_value_rejected() {
        let cmd = Cmd::new("x", "t").switch("quick", "h");
        assert!(cmd.parse(&argv(&["--quick=1"])).is_err());
    }

    #[test]
    fn device_list_syntax_is_unified() {
        use crate::device::registry as devices;
        // Comma list with aliases and spaces, deduped, order-preserving.
        let d = parse_device_list("a100, t4, a100").unwrap();
        assert_eq!(d.len(), 2);
        assert_eq!(d[0].name, "a100-sxm4-40gb");
        assert_eq!(d[1].name, "t4-pcie-16gb");
        // `all` is the registry, in order; `default` is the V100 testbed.
        let all = parse_device_list("all").unwrap();
        assert_eq!(all.len(), devices::entries().len());
        let def = parse_device_list("default").unwrap();
        assert_eq!(def.len(), 1);
        assert_eq!(def[0].name, devices::default_entry().name);
        // Unknown names keep the registry's did-you-mean hint.
        let err = parse_device_list("v100,t44").unwrap_err();
        assert!(err.0.contains("unknown device 't44'"), "{}", err.0);
        assert!(err.0.contains("did you mean 't4'?"), "{}", err.0);
        // Empty selections are rejected.
        assert!(parse_device_list(" , ").is_err());
    }

    #[test]
    fn shard_syntax() {
        assert_eq!(parse_shard("0/3").unwrap(), (0, 3));
        assert_eq!(parse_shard("2/3").unwrap(), (2, 3));
        assert_eq!(parse_shard(" 1 / 2 ").unwrap(), (1, 2));
        assert_eq!(parse_shard("0/1").unwrap(), (0, 1));
        for bad in ["", "3", "3/3", "4/3", "-1/3", "0/0", "a/b", "1/2/3"] {
            let err = parse_shard(bad).unwrap_err();
            assert!(err.0.contains("i/N"), "{bad}: {}", err.0);
        }
    }

    #[test]
    fn app_dispatch() {
        let app = App::new("repro", "t")
            .command(Cmd::new("ert", "a").flag("mode", "modeled", "h"))
            .command(Cmd::new("report", "b"));
        let (name, p) = app.dispatch(&argv(&["ert", "--mode", "both"])).unwrap();
        assert_eq!(name, "ert");
        assert_eq!(p.get("mode"), "both");
        assert!(app.dispatch(&argv(&["nope"])).is_err());
        assert!(app.dispatch(&argv(&[])).is_err());
    }

    #[test]
    fn get_as_parse_error() {
        let cmd = Cmd::new("x", "t").flag("steps", "abc", "h");
        let p = cmd.parse(&argv(&[])).unwrap();
        assert!(p.get_as::<usize>("steps").is_err());
    }
}
