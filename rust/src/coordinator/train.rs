//! End-to-end training driver: run the AOT-compiled DeepCAM-lite
//! `train_step` through PJRT for N steps on synthetic climate tiles,
//! logging the loss curve and step timings — the proof that all three
//! layers (Pallas kernel → JAX model → Rust runtime) compose.

use std::time::Instant;

use crate::runtime::engine::{literal_f32, to_vec_f32};
use crate::runtime::xla;
use crate::runtime::{ArtifactStore, Engine};
use crate::util::error::{self as anyhow, Context, Result};
use crate::util::{Rng, Summary};

/// Training-run configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: usize,
    pub artifacts_dir: String,
    pub log_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            artifacts_dir: "artifacts".into(),
            log_every: 10,
            seed: 7,
        }
    }
}

/// Result: the loss curve and timing statistics.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub losses: Vec<f32>,
    pub step_seconds: Summary,
    pub n_params: Option<u64>,
    pub flops_per_step: Option<f64>,
}

impl TrainResult {
    pub fn final_loss(&self) -> f32 {
        *self.losses.last().unwrap()
    }

    /// Attained FLOP/s of the real run (for the empirical CPU roofline).
    pub fn attained_flops_per_sec(&self) -> Option<f64> {
        self.flops_per_step.map(|f| f / self.step_seconds.median)
    }
}

/// Run the training loop. `on_log` receives (step, loss, step_seconds).
pub fn run_training(
    cfg: &TrainConfig,
    mut on_log: impl FnMut(usize, f32, f64),
) -> Result<TrainResult> {
    let store = ArtifactStore::open(&cfg.artifacts_dir)?;
    let engine = Engine::cpu()?;
    let module = engine.load(&store, "train_step")?;
    let specs = module.entry.inputs.clone();
    let n_out = module.entry.outputs.len();
    let n_state = n_out - 1; // params + momentum; last output is loss

    // Initialize parameter/momentum state. He-style scaling keeps the
    // loss finite from step 0 (matches python init closely enough for a
    // from-scratch train).
    let mut rng = Rng::new(cfg.seed);
    let mut state: Vec<xla::Literal> = Vec::with_capacity(n_state);
    for (i, spec) in specs[..n_state].iter().enumerate() {
        let n: usize = spec.dims.iter().product::<usize>().max(1);
        let is_momentum = i >= n_state / 2;
        let fan_in: usize = spec.dims.iter().take(spec.dims.len().saturating_sub(1)).product();
        let scale = if is_momentum {
            0.0
        } else if spec.dims.len() >= 2 {
            (2.0 / fan_in.max(1) as f64).sqrt()
        } else if spec.dims.len() == 1 {
            // BN gamma=1 / beta=0 handled below.
            0.0
        } else {
            0.0
        };
        let data: Vec<f32> = if spec.dims.len() == 1 && !is_momentum {
            // Can't distinguish gamma/beta from the manifest; init at 1.0
            // works for both (beta=1 just shifts activations slightly).
            vec![1.0; n]
        } else {
            (0..n).map(|_| (rng.normal() * scale) as f32).collect()
        };
        state.push(literal_f32(&data, &spec.dims)?);
    }

    // Synthetic climate batch (fixed across steps: the smoke target is
    // optimization progress, i.e. loss decreasing on the batch).
    let x_spec = &specs[n_state];
    let nx: usize = x_spec.dims.iter().product();
    let x: Vec<f32> = (0..nx).map(|_| rng.normal() as f32 * 0.5).collect();
    let lx = literal_f32(&x, &x_spec.dims)?;
    let l_spec = &specs[n_state + 1];
    let nl: usize = l_spec.dims.iter().product();
    let labels: Vec<i32> = (0..nl).map(|_| rng.below(3) as i32).collect();
    let ll = {
        let lit = xla::Literal::vec1(&labels);
        let dims: Vec<i64> = l_spec.dims.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).context("labels reshape")?
    };

    let mut losses = Vec::with_capacity(cfg.steps);
    let mut times = Vec::with_capacity(cfg.steps);
    for step in 0..cfg.steps {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(n_state + 2);
        for s in &state {
            inputs.push(s.clone());
        }
        inputs.push(lx.clone());
        inputs.push(ll.clone());
        let t0 = Instant::now();
        let out = engine.run(&module, &inputs)?;
        let dt = t0.elapsed().as_secs_f64();
        let loss = to_vec_f32(&out[n_out - 1])?[0];
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}: {loss}");
        state = out.into_iter().take(n_state).collect();
        losses.push(loss);
        times.push(dt);
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            on_log(step, loss, dt);
        }
    }

    let n_params = module
        .entry
        .meta
        .get("params")
        .and_then(|s| s.parse().ok());
    Ok(TrainResult {
        losses,
        step_seconds: Summary::of(&times),
        n_params,
        flops_per_step: module.entry.flops_per_run,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Short real training run (needs artifacts; skipped otherwise).
    #[test]
    fn training_loss_decreases_in_ten_steps() {
        if ArtifactStore::open_default().is_err() {
            eprintln!("skipping: no artifacts");
            return;
        }
        let cfg = TrainConfig {
            steps: 10,
            log_every: 0,
            ..Default::default()
        };
        let result = run_training(&cfg, |_, _, _| {}).unwrap();
        assert_eq!(result.losses.len(), 10);
        assert!(
            result.final_loss() < result.losses[0],
            "{:?}",
            result.losses
        );
        assert!(result.step_seconds.median > 0.0);
    }
}
