//! CLI command implementations (dispatched from `main.rs`).

use std::path::Path;

use crate::cli::{Cmd, Parsed};
use crate::util::error::{self as anyhow, Context, Result};
use crate::device::registry as devices;
use crate::device::MemLevel;
use crate::dl::deepcam::{deepcam, DeepCamConfig};
use crate::dl::lower::{lower, Framework, Phase};
use crate::dl::Policy;
use crate::ert::sweep::SweepConfig;
use crate::ert::{empirical, modeled};
use crate::profiler::{
    export, ingest, IngestConfig, MetricRegistry, Profile, ProfileRequest, Session, StepTimeline,
};
use crate::report::Artifact;
use crate::roofline::chart::RooflineChart;
use crate::roofline::model::RooflineModel;
use crate::roofline::time as rtime;
use crate::util::{fmt, Json, Table};

/// Resolve the unified `--device` list syntax (comma lists, `all`,
/// `default`) through the registry, with a did-you-mean hint on
/// unknown names. Shared by `ert`, `profile` and `matrix`.
fn resolve_devices(p: &Parsed) -> Result<Vec<&'static devices::DeviceEntry>> {
    crate::cli::parse_device_list(p.get("device")).map_err(Into::into)
}

/// Resolve the `--trace` opt-in (flag value, else `HROOFLINE_TRACE`)
/// into an armed monotonic tracer plus the JSONL output path. `None`
/// keeps the whole pipeline on the disabled no-op path.
fn arm_tracing(p: &Parsed) -> Option<(crate::obs::Tracer, String)> {
    crate::obs::trace_path(p.get("trace")).map(|path| (crate::obs::Tracer::new(), path))
}

/// The command's root telemetry span (`run`, tagged with the command
/// name), or `None` when tracing is off.
fn root_span(armed: &Option<(crate::obs::Tracer, String)>, cmd: &str) -> Option<crate::obs::Span> {
    armed.as_ref().map(|(tracer, _)| {
        let mut span = tracer.span("run");
        span.set("cmd", cmd);
        span
    })
}

/// Surface an armed trace: write the span JSONL to the `--trace` path
/// and snapshot the global metrics registry into `<out>/run.metrics.json`.
/// Callers must drop their root span first (live spans are not
/// serialized). A no-op when tracing is off, so untraced runs keep the
/// historical artifact layout exactly.
fn finish_tracing(armed: &Option<(crate::obs::Tracer, String)>, out_dir: &str) -> Result<()> {
    let Some((tracer, path)) = armed else { return Ok(()) };
    let bytes = tracer.write_jsonl(Path::new(path))?;
    let metrics_path = Path::new(out_dir).join("run.metrics.json");
    std::fs::write(
        &metrics_path,
        crate::obs::MetricsRegistry::global().snapshot().to_json().to_string_pretty(),
    )?;
    crate::obs::log::info(format!(
        "wrote trace {path} ({bytes} bytes) and {}",
        metrics_path.display()
    ));
    Ok(())
}

/// Artifact-id suffix for a device within a selection: single-device
/// selections keep the plain ids (so `--device a100` writes the same
/// file names as the default run, just on another device), and in
/// multi-device selections only non-default devices get `@short`
/// tagged — mirroring the scenario-matrix id scheme.
fn device_suffix(entry: &devices::DeviceEntry, n_selected: usize) -> String {
    if n_selected > 1 && entry.name != devices::default_entry().name {
        format!("@{}", entry.short)
    } else {
        String::new()
    }
}

/// `repro ert` — machine characterization.
pub fn cmd_ert(p: &Parsed) -> Result<()> {
    let out_dir = p.get("out").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let config = if p.has("quick") {
        SweepConfig::quick()
    } else {
        SweepConfig::standard()
    };
    let mode = p.get("mode");
    // Validate --device up front so a typo fails with the registry's
    // did-you-mean even in empirical mode (which characterizes the host
    // CPU and does not use the GPU specs).
    let selected = resolve_devices(p)?;
    let armed = arm_tracing(p);
    let root = root_span(&armed, "ert");

    if mode == "modeled" || mode == "both" {
        for entry in &selected {
            let mut dev_span = match &root {
                Some(r) => r.child("characterize"),
                None => crate::obs::Span::disabled(),
            };
            dev_span.set("device", entry.name);
            let spec = entry.spec();
            // The modeled sweep fans its working-set × intensity grid
            // across the machine's cores via `exec::parallel_map` (see
            // `ert::modeled::run_sweep_threads`); output is identical to
            // the serial path because every grid point is a pure
            // evaluation.
            let ceilings = modeled::characterize(&spec, &config);
            let mut t = Table::new(&["ceiling", "value"]);
            for (label, gf) in &ceilings.compute_gflops {
                t.row(&[label.clone(), fmt::si_flops(gf * 1e9)]);
            }
            for (level, gb) in &ceilings.bandwidth_gbs {
                t.row(&[format!("{} bandwidth", level.name()), fmt::si(gb * 1e9, "B/s")]);
            }
            println!("== modeled {} (Fig. 1) ==\n{}", spec.name, t.render());
            let mut artifact = crate::report::fig1::generate_for(&spec)?;
            artifact.id = format!("{}{}", artifact.id, device_suffix(entry, selected.len()));
            artifact.write_all(Path::new(&out_dir))?;
            println!("wrote {out_dir}/{}.{{txt,json,svg}}", artifact.id);
        }
    }

    if mode == "empirical" || mode == "both" {
        // Deliberately serial: the empirical driver measures wall-clock
        // bandwidth on real silicon, and concurrent sweeps would contend
        // for the very cache/memory hierarchy being characterized.
        let _emp_span = match &root {
            Some(r) => r.child("empirical"),
            None => crate::obs::Span::disabled(),
        };
        println!("== empirical host CPU sweep (this machine) ==");
        for result in empirical::characterize(&config) {
            let peak = result.peak_gflops();
            println!(
                "{}: compute {}  L1 {}  L2 {}  DRAM {}",
                result.label,
                fmt::si_flops(peak * 1e9),
                fmt::si(result.peak_bandwidth(MemLevel::L1) * 1e9, "B/s"),
                fmt::si(result.peak_bandwidth(MemLevel::L2) * 1e9, "B/s"),
                fmt::si(result.peak_bandwidth(MemLevel::Hbm) * 1e9, "B/s"),
            );
            let doc = Json::obj(vec![
                ("label", Json::str(&result.label)),
                ("peak_gflops", Json::num(peak)),
                (
                    "points",
                    Json::arr(result.points.iter().map(|pt| {
                        Json::obj(vec![
                            ("ws", Json::num(pt.working_set_bytes as f64)),
                            ("fpe", Json::num(pt.flops_per_elem as f64)),
                            ("gflops", Json::num(pt.gflops)),
                            ("gbytes", Json::num(pt.gbytes)),
                        ])
                    })),
                ),
            ]);
            std::fs::write(
                Path::new(&out_dir).join(format!("empirical_{}.json", result.label)),
                doc.to_string_pretty(),
            )?;
        }
        println!("wrote {out_dir}/empirical_*.json");
    }
    drop(root);
    finish_tracing(&armed, &out_dir)?;
    Ok(())
}

/// `repro metrics` — the Table II registry.
pub fn cmd_metrics(_p: &Parsed) -> Result<()> {
    let reg = MetricRegistry::standard();
    let mut t = Table::new(&["metric", "unit", "counter", "rollup"]);
    for name in reg.all() {
        let m = crate::profiler::Metric::parse(name)?;
        t.row(&[m.raw.clone(), m.unit.clone(), m.counter.clone(), m.rollup.clone()]);
    }
    println!("Nsight-analog metric registry (paper Table II):\n{}", t.render());
    Ok(())
}

/// `repro profile --from-csv` — re-ingest a previously exported
/// counter CSV and re-render the hierarchical Roofline from it.
/// `--lenient` routes through [`export::from_csv_lenient`]: malformed
/// rows are skipped and reported instead of failing the whole file.
fn cmd_profile_from_csv(p: &Parsed, csv_path: &str) -> Result<()> {
    let out_dir = p.get("out").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let selected = resolve_devices(p)?;
    // The CSV's own device stamp wins inside the importer; the
    // --device selection only supplies the ceiling set (first entry).
    let spec = selected[0].spec();
    let text = std::fs::read_to_string(csv_path).with_context(|| format!("reading '{csv_path}'"))?;
    let profile = if p.has("lenient") {
        let (profile, diagnostics) = export::from_csv_lenient(&text, &spec)?;
        if !diagnostics.is_empty() {
            crate::obs::log::warn(format!(
                "skipped {} malformed row(s) in '{csv_path}':\n{}",
                diagnostics.total(),
                diagnostics.summary()
            ));
        }
        profile
    } else {
        export::from_csv(&text, &spec)?
    };
    let model = RooflineModel::from_profile(&spec, &profile);
    // Headerless CSVs carry no device stamp; fall back to the ceiling
    // device so the title and json are never blank.
    let device_name =
        if profile.device.is_empty() { spec.name.clone() } else { profile.device.clone() };
    let title = format!("ingested profile on {device_name}");
    let chart = RooflineChart::hierarchical(&model, &title);
    let artifact = Artifact {
        id: "ingested".to_string(),
        title: title.clone(),
        text: format!(
            "== {title} ==\ntotal {} | kernels {} | invocations {}\n{}",
            fmt::duration(profile.total_seconds()),
            profile.n_kernels(),
            profile.total_invocations(),
            chart.to_table().render()
        ),
        json: Json::obj(vec![
            ("device", Json::str(&device_name)),
            ("source", Json::str(csv_path)),
            ("total_seconds", Json::num(profile.total_seconds())),
            ("n_kernels", Json::num(profile.n_kernels() as f64)),
            ("invocations", Json::num(profile.total_invocations() as f64)),
        ]),
        svg: Some(chart.to_svg()),
        csv: Some(export::to_csv(&profile)),
        lanes: Vec::new(),
    };
    println!("{}", artifact.text);
    artifact.write_all(Path::new(&out_dir))?;
    println!("wrote {out_dir}/{}.{{txt,json,svg,csv}}", artifact.id);
    Ok(())
}

/// `repro profile` — application characterization.
pub fn cmd_profile(p: &Parsed) -> Result<()> {
    let csv_path = p.get("from-csv");
    if !csv_path.is_empty() {
        return cmd_profile_from_csv(p, csv_path);
    }
    let fw = Framework::parse(p.get("framework"))
        .with_context(|| format!("bad framework '{}'", p.get("framework")))?;
    let policy = Policy::parse(p.get("amp"))
        .with_context(|| format!("bad AMP policy '{}'", p.get("amp")))?;
    let cfg = match p.get("scale") {
        "paper" => DeepCamConfig::paper(),
        "lite" => DeepCamConfig::lite(),
        other => anyhow::bail!("bad scale '{other}'"),
    };
    let out_dir = p.get("out").to_string();
    std::fs::create_dir_all(&out_dir)?;

    let selected = resolve_devices(p)?;
    let armed = arm_tracing(p);
    let root = root_span(&armed, "profile");
    let obs_metrics = armed.as_ref().map(|_| crate::obs::MetricsRegistry::global());
    let graph = deepcam(&cfg);
    let phases: Vec<(Phase, &str)> = match p.get("phase") {
        "forward" => vec![(Phase::Forward, "forward")],
        "backward" => vec![(Phase::Backward, "backward")],
        "optimizer" => vec![(Phase::Optimizer, "optimizer")],
        "all" => vec![
            (Phase::Forward, "forward"),
            (Phase::Backward, "backward"),
            (Phase::Optimizer, "optimizer"),
        ],
        other => anyhow::bail!("bad phase '{other}'"),
    };

    for entry in &selected {
        let spec = entry.spec();
        let suffix = device_suffix(entry, selected.len());
        let trace = lower(&graph, fw, policy, &spec);

        // Profile the requested phases in parallel (each phase is an
        // independent, deterministic simulation pass; within each phase
        // the session additionally dedupes kernel descriptors and fans
        // the trace out — see `Session::run`). Rendering is captured
        // into Artifacts inside the workers and written in input order
        // below, so stdout and the written files are byte-identical to
        // a serial run. The fan-out is supervised: a phase that fails
        // (or panics) is isolated and reported at the end instead of
        // aborting its siblings mid-write.
        let session = Session::standard(&spec);
        let workers = crate::exec::default_workers(phases.len());
        let sup = crate::exec::SupervisePolicy::default();
        let rendered = crate::exec::parallel_try_map(
            phases.clone(),
            workers,
            &sup,
            |&(phase, label)| {
            let kernel_trace = trace.phase(phase);
            if kernel_trace.is_empty() {
                return Ok((label, None));
            }
            let mut phase_span = match &root {
                Some(r) => r.child("phase"),
                None => crate::obs::Span::disabled(),
            };
            phase_span.set("label", label);
            let mut req = ProfileRequest::new(kernel_trace).with_span(&phase_span);
            if let Some(m) = obs_metrics {
                req = req.with_metrics(m);
            }
            let profile = session
                .run(&req)
                .map_err(|e| crate::exec::TaskError::fatal(e.to_string()))?;
            let model = RooflineModel::from_profile(&spec, &profile);
            let title =
                format!("{} DeepCAM {label} ({}) on {}", fw.name(), policy.name(), spec.name);
            let chart = RooflineChart::hierarchical(&model, &title);
            let text = format!(
                "== {title} ==\ntotal {} | kernels {} | invocations {} | profiler overhead {}\n{}",
                fmt::duration(profile.total_seconds()),
                profile.n_kernels(),
                profile.total_invocations(),
                fmt::duration(profile.profiling_overhead_s),
                chart.to_table().render()
            );
            let mut timeline = StepTimeline::new(&spec.name);
            timeline.push_phase(label, &profile);
            let artifact = Artifact {
                id: format!("{}_{label}{suffix}", fw.name()),
                title: title.clone(),
                json: Json::obj(vec![
                    ("device", Json::str(&spec.name)),
                    ("framework", Json::str(fw.name())),
                    ("phase", Json::str(label)),
                    ("amp", Json::str(policy.name())),
                    ("total_seconds", Json::num(profile.total_seconds())),
                    ("n_kernels", Json::num(profile.n_kernels() as f64)),
                    ("invocations", Json::num(profile.total_invocations() as f64)),
                    ("profiling_overhead_s", Json::num(profile.profiling_overhead_s)),
                ]),
                svg: Some(chart.to_svg()),
                csv: Some(export::to_csv(&profile)),
                text,
                lanes: Vec::new(),
            }
            .with_lane("timeline.txt", rtime::timeline_text(&title, &timeline, &profile));
            let artifact = match rtime::time_weighted_svg(
                &spec,
                &profile,
                &format!("{title} — time-weighted"),
            ) {
                Some(svg) => artifact.with_lane("timeline.svg", svg),
                None => artifact,
            };
            Ok((label, Some((artifact, profile))))
            },
        );
        let mut phase_profiles: Vec<(&str, Profile)> = Vec::new();
        let mut failed_phases: Vec<String> = Vec::new();
        // An Err slot loses its label, so zip the input order back in.
        for ((_, in_label), outcome) in phases.iter().zip(rendered) {
            match outcome {
                Ok((label, Some((artifact, profile)))) => {
                    println!("{}", artifact.text);
                    artifact.write_all(Path::new(&out_dir))?;
                    println!(
                        "wrote {out_dir}/{}.{{txt,json,svg,csv,timeline.txt,timeline.svg}}",
                        artifact.id
                    );
                    phase_profiles.push((label, profile));
                }
                Ok((label, None)) => {
                    println!("[{label}] no kernels (TF folds the optimizer into backward)");
                }
                Err(e) => failed_phases.push(format!("{in_label} ({e})")),
            }
        }
        if !failed_phases.is_empty() {
            anyhow::bail!(
                "{} of {} phase(s) failed to profile on {}: {}",
                failed_phases.len(),
                phases.len(),
                spec.name,
                failed_phases.join("; ")
            );
        }
        // Whole-step timeline: only meaningful when more than one phase
        // actually ran (a single-phase request *is* its own breakdown).
        if phase_profiles.len() > 1 {
            let timeline =
                StepTimeline::from_phases(&spec.name, phase_profiles.iter().map(|(l, p)| (*l, p)));
            let title =
                format!("{} DeepCAM step ({}) on {}", fw.name(), policy.name(), spec.name);
            let step_artifact = Artifact {
                id: format!("{}_step{suffix}", fw.name()),
                title: title.clone(),
                text: format!(
                    "== {title} — time-based Roofline ==\n{}",
                    rtime::step_table(&timeline).render()
                ),
                json: Json::obj(vec![
                    ("device", Json::str(&spec.name)),
                    ("framework", Json::str(fw.name())),
                    ("amp", Json::str(policy.name())),
                    ("step_seconds", Json::num(timeline.step_seconds())),
                    ("idle_seconds", Json::num(timeline.idle_seconds())),
                    (
                        "phases",
                        Json::arr(timeline.phases.iter().map(|ph| {
                            Json::obj(vec![
                                ("label", Json::str(&ph.label)),
                                ("seconds", Json::num(ph.seconds)),
                                ("compute_s", Json::num(ph.compute_s)),
                                ("memory_s", Json::num(ph.memory_s)),
                                ("overhead_s", Json::num(ph.overhead_s)),
                                ("ramp_s", Json::num(ph.ramp_s)),
                            ])
                        })),
                    ),
                ]),
                svg: None,
                csv: None,
                lanes: Vec::new(),
            };
            println!("{}", step_artifact.text);
            step_artifact.write_all(Path::new(&out_dir))?;
            println!("wrote {out_dir}/{}.{{txt,json}}", step_artifact.id);
        }
    }
    drop(root);
    finish_tracing(&armed, &out_dir)?;
    Ok(())
}

/// Flag grammar for `repro ingest`. The positional `<csv>` operand
/// forces direct routing in `main.rs` (the flag-only `Cmd` grammar
/// can't express it — same arrangement as `trace`); this spec parses
/// the flags after the path and serves the usage listing.
pub fn ingest_cmd_spec() -> Cmd {
    Cmd::new(
        "ingest",
        "Stream a Nsight Compute counter CSV into Roofline artifacts: repro ingest <csv>",
    )
    .flag(
        "device",
        "default",
        "ceiling device when the csv carries no '# device=' stamp",
    )
    .flag("out", "out/ingest", "output directory")
    .flag(
        "chunk-bytes",
        "65536",
        "streaming read granularity in bytes (output is invariant under this knob)",
    )
    .flag("trace", "", "write a span trace (hroofline-trace-v1 JSONL) to this path")
    .switch("lenient", "skip and report malformed rows instead of failing the file")
}

/// `repro ingest <csv>` — stream a raw Nsight Compute export (any
/// size) into the same artifact set as a simulated profile, with
/// O(unique kernels) memory. The heavy lifting is
/// [`ingest::from_reader`]: chunked reads, online launch dedup into
/// digest-keyed accumulators, and an [`crate::profiler::IngestStats`]
/// summary that lands in the txt/json artifacts. `--lenient` mirrors
/// `repro profile --from-csv --lenient`; `--trace` arms the PR-9
/// telemetry (`ingest`/`ingest.chunk`/`ingest.aggregate` spans plus
/// `ingest.*` counters) without perturbing any artifact bytes.
pub fn cmd_ingest(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: repro ingest <csv> [--device D] [--lenient] [--out DIR] \
                         [--chunk-bytes N] [--trace PATH]";
    let spec_cmd = ingest_cmd_spec();
    if args.first().is_some_and(|a| a == "--help" || a == "-h") {
        println!("{}", spec_cmd.usage());
        return Ok(());
    }
    let Some(csv_path) = args.first().filter(|a| !a.starts_with('-')) else {
        anyhow::bail!("missing csv path\n{USAGE}");
    };
    let p = spec_cmd.parse(&args[1..])?;
    let out_dir = p.get("out").to_string();
    std::fs::create_dir_all(&out_dir)?;
    let selected = resolve_devices(&p)?;
    // The CSV's own device stamp wins inside the importer; the
    // --device selection only supplies the ceiling set (first entry).
    let spec = selected[0].spec();
    let chunk_bytes: usize = p
        .get("chunk-bytes")
        .parse()
        .with_context(|| format!("bad --chunk-bytes '{}'", p.get("chunk-bytes")))?;

    let armed = arm_tracing(&p);
    let root = root_span(&armed, "ingest");
    let mut cfg = IngestConfig::new().lenient(p.has("lenient")).chunk_bytes(chunk_bytes);
    if let Some(r) = &root {
        cfg = cfg.with_span(r);
    }
    if armed.is_some() {
        cfg = cfg.with_metrics(crate::obs::MetricsRegistry::global());
    }
    let mut file =
        std::fs::File::open(csv_path).with_context(|| format!("opening '{csv_path}'"))?;
    let out = ingest::from_reader(&mut file, &spec, &cfg)?;
    let (profile, stats, diagnostics) = (out.profile, out.stats, out.diagnostics);
    if !diagnostics.is_empty() {
        crate::obs::log::warn(format!(
            "skipped {} malformed row(s) in '{csv_path}':\n{}",
            diagnostics.total(),
            diagnostics.summary()
        ));
    }

    let model = RooflineModel::from_profile(&spec, &profile);
    // Headerless CSVs carry no device stamp; fall back to the ceiling
    // device so the title and json are never blank.
    let device_name =
        if profile.device.is_empty() { spec.name.clone() } else { profile.device.clone() };
    let title = format!("ingested profile on {device_name}");
    let chart = RooflineChart::hierarchical(&model, &title);
    let stats_line = format!(
        "ingest stats: {} row(s) -> {} unique kernel(s) (dedup {:.1}x) | {} read | \
         peak resident accumulators {}",
        stats.rows,
        stats.unique_kernels,
        stats.dedup_ratio(),
        fmt::si(stats.bytes_read as f64, "B"),
        stats.peak_resident_accumulators
    );
    // Ingested counters carry no timing, so the step timeline lands
    // entirely in the overhead bucket — still worth emitting: the lane
    // layout matches `repro profile` and fills in when real-duration
    // ingestion arrives.
    let mut timeline = StepTimeline::new(&spec.name);
    timeline.push_phase("ingest", &profile);
    let artifact = Artifact {
        id: "ingested".to_string(),
        title: title.clone(),
        text: format!(
            "== {title} ==\ntotal {} | kernels {} | invocations {}\n{stats_line}\n{}",
            fmt::duration(profile.total_seconds()),
            profile.n_kernels(),
            profile.total_invocations(),
            chart.to_table().render()
        ),
        json: Json::obj(vec![
            ("device", Json::str(&device_name)),
            ("source", Json::str(csv_path)),
            ("total_seconds", Json::num(profile.total_seconds())),
            ("n_kernels", Json::num(profile.n_kernels() as f64)),
            ("invocations", Json::num(profile.total_invocations() as f64)),
            ("rows", Json::num(stats.rows as f64)),
            ("unique_kernels", Json::num(stats.unique_kernels as f64)),
            ("dedup_ratio", Json::num(stats.dedup_ratio())),
            ("bytes_read", Json::num(stats.bytes_read as f64)),
            (
                "peak_resident_accumulators",
                Json::num(stats.peak_resident_accumulators as f64),
            ),
        ]),
        svg: Some(chart.to_svg()),
        csv: Some(export::to_csv(&profile)),
        lanes: Vec::new(),
    }
    .with_lane("timeline.txt", rtime::timeline_text(&title, &timeline, &profile));
    let artifact =
        match rtime::time_weighted_svg(&spec, &profile, &format!("{title} — time-weighted")) {
            Some(svg) => artifact.with_lane("timeline.svg", svg),
            None => artifact,
        };
    println!("{}", artifact.text);
    artifact.write_all(Path::new(&out_dir))?;
    println!("wrote {out_dir}/{}.{{txt,json,svg,csv,timeline.txt}}", artifact.id);
    drop(root);
    finish_tracing(&armed, &out_dir)?;
    Ok(())
}

/// Process exit code for a matrix run in which one or more cells
/// failed (surviving cells still produced artifacts). Distinct from
/// `1` (command error: nothing ran) and `2` (CLI/usage error) so
/// scripts can tell "degraded but useful" from "broken".
pub const EXIT_MATRIX_CELLS_FAILED: i32 = 3;

/// `repro matrix` — the scenario-matrix sweep: workload registry ×
/// framework × phase × AMP policy, profiled through one shared
/// simulation cache, with per-scenario artifacts plus the
/// cross-scenario comparison report.
///
/// Cells run under `exec::supervise`: a panicking or failing cell is
/// isolated, the survivors keep profiling, and the failures land in
/// `matrix.errors.json` + the comparison report. Returns the process
/// exit code: `0` for a clean sweep, [`EXIT_MATRIX_CELLS_FAILED`]
/// when any cell failed.
///
/// Incremental mode (`--incremental --store DIR`) replays clean cells
/// from the content-addressed cell store with zero simulations;
/// `--shard i/N` partitions the cell enumeration across CI jobs and
/// `--merge DIR,...` unions finished shard stores into one report.
/// Cache stats land in `matrix.cache.json` (never in the comparison
/// report, which stays byte-identical across cold/warm/merged runs).
pub fn cmd_matrix(p: &Parsed) -> Result<i32> {
    let matrix = if p.has("quick") {
        crate::scenario::ScenarioMatrix::quick()
    } else {
        crate::scenario::ScenarioMatrix::full()
    };
    let mut matrix = matrix.with_workloads(p.get("workloads"))?;
    // Device axis: `default` keeps the mode's own axis (quick = the
    // registry default V100 so the CI gate's cost stays flat; full =
    // every registered device); an explicit name/alias list (or `all`)
    // overrides it, with registry did-you-mean on typos.
    let device_flag = p.get("device");
    if device_flag != "default" {
        matrix = matrix.with_devices(device_flag)?;
    }
    // --shard i/N: deterministically own every Nth cell of the global
    // enumeration (cell index % N == i), so N CI jobs cover the matrix
    // disjointly and a later --merge can union their stores.
    let shard = match p.get("shard") {
        "" => None,
        s => {
            let (index, count) = crate::cli::parse_shard(s)?;
            Some(crate::scenario::Shard { index, count })
        }
    };
    // --print-keys: emit "<32-hex cell key> <scenario id>" per owned
    // cell (enumeration order) and exit without profiling or writing
    // anything. rust/tests/incremental_matrix.rs pins this output to
    // prove keys are stable across processes.
    if p.has("print-keys") {
        for (i, (key, id)) in matrix.cell_keys().into_iter().enumerate() {
            let owned = match shard {
                Some(s) => s.owns(i),
                None => true,
            };
            if owned {
                println!("{} {id}", key.as_hex());
            }
        }
        return Ok(0);
    }
    let out_dir = p.get("out").to_string();
    let scenario_dir = Path::new(&out_dir).join("scenarios");
    std::fs::create_dir_all(&scenario_dir)?;
    let armed = arm_tracing(p);
    let root = root_span(&armed, "matrix");

    // Failure budget: --fail-fast stops at the first failure;
    // --max-failures N tolerates N and stops at the N+1st (the default
    // 'unlimited' never stops early). Any failure still exits nonzero.
    let stop_after = if p.has("fail-fast") {
        Some(1)
    } else {
        match p.get("max-failures") {
            "unlimited" => None,
            n => {
                let n: usize = n.parse().map_err(|_| {
                    anyhow::anyhow!("bad --max-failures '{n}': expected a count or 'unlimited'")
                })?;
                Some(n + 1)
            }
        }
    };
    let policy =
        crate::exec::SupervisePolicy { stop_after_failures: stop_after, ..Default::default() };
    // --inject-fault: a deterministic FaultPlan for drills and CI
    // smokes ("panic:<cell-id>;seed=7" — see `exec::fault`).
    let fault_spec = p.get("inject-fault");
    let injector = if fault_spec.is_empty() {
        None
    } else {
        Some(crate::exec::FaultInjector::new(crate::exec::FaultPlan::parse(fault_spec)?))
    };
    // Cell-store wiring. `--merge` opens a read-only union over
    // finished shard stores (every cell must hit; a miss is a cell
    // failure); `--incremental` opens a read-write store, replays
    // clean cells from it and re-runs + persists dirty ones. Fault-
    // armed runs bypass the store entirely (run_with enforces this).
    let merge_dirs = p.get("merge");
    let store: Option<crate::scenario::store::CellStore> = if !merge_dirs.is_empty() {
        if shard.is_some() {
            anyhow::bail!("--merge unions finished shard stores; it cannot be combined with --shard");
        }
        if !fault_spec.is_empty() {
            anyhow::bail!("--merge replays cached cells; it cannot be combined with --inject-fault");
        }
        if p.has("incremental") {
            anyhow::bail!("--merge opens a read-only store union; drop --incremental");
        }
        let dirs: Vec<std::path::PathBuf> =
            merge_dirs.split(',').map(|d| std::path::PathBuf::from(d.trim())).collect();
        Some(crate::scenario::store::CellStore::open_union(dirs))
    } else if p.has("incremental") {
        Some(crate::scenario::store::CellStore::open(p.get("store"))?)
    } else {
        None
    };
    let options = crate::scenario::MatrixRunOptions {
        policy,
        fault: injector.as_ref(),
        store: store.as_ref(),
        incremental: p.has("incremental"),
        merge_only: !merge_dirs.is_empty(),
        shard,
        span: root.as_ref(),
        metrics: armed.as_ref().map(|_| crate::obs::MetricsRegistry::global()),
    };

    let run = matrix.run_with(&options);

    let mut written = 0usize;
    {
        let _render_span = match &root {
            Some(r) => r.child("render"),
            None => crate::obs::Span::disabled(),
        };
        for result in &run.results {
            result.to_artifact().write_all(&scenario_dir)?;
            written += 1;
        }
    }
    let comparison = crate::scenario::comparison_artifact(&run);
    comparison.write_all(Path::new(&out_dir))?;
    // Cache and simulation stats live in their own artifact, not the
    // comparison report — the report must stay byte-identical across
    // cold, warm and merged runs while these numbers vary.
    let cache_path = Path::new(&out_dir).join("matrix.cache.json");
    std::fs::write(&cache_path, crate::scenario::cache_manifest(&run).to_string_pretty())?;
    // Multi-device sweeps additionally get one overlay per device
    // (each against its own full ceiling set).
    let run_devices = run.device_entries();
    if run_devices.len() > 1 {
        for entry in &run_devices {
            crate::scenario::device_comparison_artifact(&run, entry)
                .write_all(Path::new(&out_dir))?;
        }
        println!(
            "wrote per-device overlays: {}",
            run_devices
                .iter()
                .map(|d| format!("matrix@{}", d.short))
                .collect::<Vec<_>>()
                .join(", ")
        );
    }

    println!("== {} ==\n{}", comparison.title, comparison.text);
    let cache = run.cache_stats;
    let (sim_hits, sims) = run.sim_stats;
    println!(
        "store: {} hits, {} misses, {} evictions | simulations: {sims} \
         (shared-cache hits {sim_hits}) -> {}",
        cache.hits,
        cache.misses,
        cache.evictions,
        cache_path.display()
    );
    println!(
        "wrote {written} scenario artifacts (each with timeline lanes) under {}/ and the \
         comparison report (matrix.{{txt,json,svg,csv,timeline.txt}} + matrix.cache.json) \
         under {out_dir}/",
        scenario_dir.display()
    );
    drop(root);
    finish_tracing(&armed, &out_dir)?;
    if run.failures.is_empty() {
        return Ok(0);
    }
    // Degraded sweep: persist the machine-readable error manifest next
    // to the comparison report and signal via the exit code.
    let manifest_path = Path::new(&out_dir).join("matrix.errors.json");
    std::fs::write(&manifest_path, crate::scenario::errors_manifest(&run).to_string_pretty())?;
    // Error level: the degraded-run summary must survive `--quiet` (CI
    // greps this message verbatim).
    crate::obs::log::error(format!(
        "{} of {} cells failed:\n{}wrote {}",
        run.failures.len(),
        run.n_cells(),
        crate::scenario::failure_table(&run.failures).render(),
        manifest_path.display()
    ));
    Ok(EXIT_MATRIX_CELLS_FAILED)
}

/// `repro bench-diff` — gate the bench trajectory: compare a fresh
/// `BENCH_<group>.json` against a committed baseline and fail on
/// ns/iter regressions beyond the threshold.
pub fn cmd_bench_diff(p: &Parsed) -> Result<()> {
    let max_regress: f64 = p.get_as("max-regress")?;
    let read = |path: &str| -> Result<Json> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading '{path}'"))?;
        Json::parse(&text).with_context(|| format!("parsing '{path}'"))
    };
    let baseline = read(p.get("baseline"))?;
    let fresh = read(p.get("fresh"))?;
    let report = crate::bench_harness::diff::diff(&baseline, &fresh, max_regress)?;
    print!("{}", report.render());
    let regressions = report.regressions();
    if !regressions.is_empty() {
        let names: Vec<&str> = regressions.iter().map(|c| c.name.as_str()).collect();
        anyhow::bail!(
            "{} case(s) regressed beyond +{:.0}%: {}",
            regressions.len(),
            max_regress * 100.0,
            names.join(", ")
        );
    }
    println!("bench trajectory OK ({} cases within threshold)", report.compared.len());
    Ok(())
}

/// `repro report` — regenerate paper artifacts.
pub fn cmd_report(p: &Parsed) -> Result<()> {
    let out_dir = p.get("out").to_string();
    let only = p.get("only");
    let ids: Vec<&str> = if only == "all" {
        crate::report::ALL_IDS.to_vec()
    } else {
        vec![only]
    };
    for id in ids {
        let artifact = crate::report::generate(id)?;
        artifact.write_all(Path::new(&out_dir))?;
        println!("== {} — {} ==\n{}", artifact.id, artifact.title, artifact.text);
    }
    println!("artifacts under {out_dir}/");
    Ok(())
}

/// `repro train` — end-to-end PJRT training with loss logging + a CPU
/// roofline placement of the measured run.
pub fn cmd_train(p: &Parsed) -> Result<()> {
    let cfg = crate::coordinator::train::TrainConfig {
        steps: p.get_as::<usize>("steps").map_err(|e| anyhow::anyhow!(e.0))?,
        artifacts_dir: p.get("artifacts").to_string(),
        log_every: p.get_as::<usize>("log-every").map_err(|e| anyhow::anyhow!(e.0))?,
        seed: 7,
    };
    let out_dir = p.get("out").to_string();
    std::fs::create_dir_all(&out_dir)?;

    println!("training DeepCAM-lite for {} steps via PJRT ...", cfg.steps);
    let result = crate::coordinator::train::run_training(&cfg, |step, loss, dt| {
        println!("step {step:>5}  loss {loss:.5}  ({})", fmt::duration(dt));
    })?;
    println!(
        "final loss {:.5} (from {:.5}); median step {}",
        result.final_loss(),
        result.losses[0],
        fmt::duration(result.step_seconds.median)
    );
    if let Some(fps) = result.attained_flops_per_sec() {
        // Place the measured run on the empirical host roofline.
        let host = empirical::characterize(&SweepConfig::quick());
        let fp32_peak = host
            .iter()
            .find(|r| r.label == "FP32")
            .map(|r| r.peak_gflops() * 1e9)
            .unwrap_or(0.0);
        println!(
            "attained {} ({}% of this host's empirical FP32 peak {})",
            fmt::si_flops(fps),
            if fp32_peak > 0.0 {
                format!("{:.1}", fps / fp32_peak * 100.0)
            } else {
                "?".into()
            },
            fmt::si_flops(fp32_peak),
        );
    }
    // Persist the loss curve.
    let doc = Json::obj(vec![
        (
            "losses",
            Json::arr(result.losses.iter().map(|&l| Json::num(l as f64))),
        ),
        ("median_step_s", Json::num(result.step_seconds.median)),
        (
            "flops_per_step",
            result.flops_per_step.map(Json::num).unwrap_or(Json::Null),
        ),
    ]);
    let path = Path::new(&out_dir).join("loss_curve.json");
    std::fs::write(&path, doc.to_string_pretty())?;
    println!("wrote {}", path.display());
    Ok(())
}

/// `repro trace report PATH` — digest a `hroofline-trace-v1` JSONL log:
/// hottest span names by self time, the per-cell breakdown for matrix
/// runs, the span tree with self times, and a wall-clock attribution
/// footer (root spans should cover ~all of the trace's wall interval).
pub fn cmd_trace(args: &[String]) -> Result<()> {
    const USAGE: &str = "usage: repro trace report <trace.jsonl>";
    let Some(sub) = args.first() else {
        anyhow::bail!("missing trace subcommand\n{USAGE}");
    };
    if sub != "report" {
        anyhow::bail!("unknown trace subcommand '{sub}'\n{USAGE}");
    }
    let [path] = &args[1..] else {
        anyhow::bail!("'trace report' takes exactly one JSONL path\n{USAGE}");
    };
    let text = std::fs::read_to_string(path).with_context(|| format!("reading '{path}'"))?;
    let trace = crate::obs::Trace::parse_jsonl(&text)
        .with_context(|| format!("parsing '{path}'"))?;
    trace.validate().with_context(|| format!("validating '{path}'"))?;
    print!("{}", render_trace_report(&trace, path));
    Ok(())
}

/// Render the `trace report` text. A pure function of the parsed trace
/// so tests can pin its shape on a fixed-clock tracer.
fn render_trace_report(trace: &crate::obs::Trace, source: &str) -> String {
    use std::collections::BTreeMap;
    // Durations are only comparable within one clock kind; label them.
    let unit = if trace.clock == "fixed-tick" { "ticks" } else { "us" };
    let wall = trace.wall_us();
    let self_by_id = trace.self_us();
    let pct = |part: u64| {
        if wall == 0 {
            "100.0".to_string()
        } else {
            format!("{:.1}", part as f64 / wall as f64 * 100.0)
        }
    };
    let mut out = format!(
        "== trace report: {source} ==\nclock {} | {} span(s) | wall {wall} {unit}\n",
        trace.clock,
        trace.spans.len()
    );

    // Hottest span names, ranked by aggregate self time (time spent in
    // a span minus its direct children — where the run actually went).
    struct Agg {
        count: u64,
        total: u64,
        self_t: u64,
    }
    let mut by_name: BTreeMap<&str, Agg> = BTreeMap::new();
    for s in &trace.spans {
        let e = by_name.entry(s.name.as_str()).or_insert(Agg { count: 0, total: 0, self_t: 0 });
        e.count += 1;
        e.total += s.dur_us;
        e.self_t += self_by_id.get(&s.id).copied().unwrap_or(0);
    }
    let mut hottest: Vec<(&str, Agg)> = by_name.into_iter().collect();
    hottest.sort_by(|a, b| b.1.self_t.cmp(&a.1.self_t).then(a.0.cmp(b.0)));
    let mut t = Table::new(&["span", "count", "total", "self", "self % of wall"]);
    for (name, a) in &hottest {
        t.row(&[
            name.to_string(),
            a.count.to_string(),
            a.total.to_string(),
            a.self_t.to_string(),
            pct(a.self_t),
        ]);
    }
    out.push_str(&format!("\nhottest spans (by self {unit}):\n{}", t.render()));

    // Matrix runs: one row per `cell` span, hottest first.
    let mut cells: Vec<&crate::obs::SpanRecord> =
        trace.spans.iter().filter(|s| s.name == "cell").collect();
    if !cells.is_empty() {
        cells.sort_by(|a, b| b.dur_us.cmp(&a.dur_us).then(a.id.cmp(&b.id)));
        let mut t = Table::new(&["cell", "outcome", "attempt", "dur", "% of wall"]);
        for s in &cells {
            t.row(&[
                s.field("label").unwrap_or("?").to_string(),
                s.field("outcome").unwrap_or("?").to_string(),
                s.field("attempt").unwrap_or("?").to_string(),
                s.dur_us.to_string(),
                pct(s.dur_us),
            ]);
        }
        out.push_str(&format!("\ncells ({} total, by dur {unit}):\n{}", cells.len(), t.render()));
    }

    // The span tree, names merged per level, heaviest subtree first.
    #[derive(Default)]
    struct Node {
        count: u64,
        dur: u64,
        self_t: u64,
        children: BTreeMap<String, Node>,
    }
    fn insert(
        node: &mut Node,
        span: &crate::obs::SpanRecord,
        by_parent: &BTreeMap<u64, Vec<&crate::obs::SpanRecord>>,
        self_by_id: &BTreeMap<u64, u64>,
    ) {
        let child = node.children.entry(span.name.clone()).or_default();
        child.count += 1;
        child.dur += span.dur_us;
        child.self_t += self_by_id.get(&span.id).copied().unwrap_or(0);
        for kid in by_parent.get(&span.id).into_iter().flatten() {
            insert(child, kid, by_parent, self_by_id);
        }
    }
    fn render_nodes(node: &Node, depth: usize, unit: &str, out: &mut String) {
        let mut kids: Vec<(&String, &Node)> = node.children.iter().collect();
        kids.sort_by(|a, b| b.1.dur.cmp(&a.1.dur).then(a.0.cmp(b.0)));
        for (name, kid) in kids {
            out.push_str(&format!(
                "{}{name} — {} span(s), total {} {unit}, self {} {unit}\n",
                "  ".repeat(depth),
                kid.count,
                kid.dur,
                kid.self_t
            ));
            render_nodes(kid, depth + 1, unit, out);
        }
    }
    let mut by_parent: BTreeMap<u64, Vec<&crate::obs::SpanRecord>> = BTreeMap::new();
    for s in &trace.spans {
        if let Some(p) = s.parent {
            by_parent.entry(p).or_default().push(s);
        }
    }
    let mut tree = Node::default();
    for root in trace.roots() {
        insert(&mut tree, root, &by_parent, &self_by_id);
    }
    out.push_str("\nspan tree:\n");
    render_nodes(&tree, 0, unit, &mut out);

    // Attribution: how much of the trace's wall interval the root spans
    // cover — the figure of merit for instrumentation completeness.
    let covered: u64 = trace.roots().iter().map(|s| s.dur_us).sum();
    out.push_str(&format!(
        "\nattribution: {covered} of {wall} wall {unit} covered by root spans ({}%)\n",
        pct(covered)
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cli::Cmd;

    fn parsed(cmd: Cmd, args: &[&str]) -> Parsed {
        let argv: Vec<String> = args.iter().map(|s| s.to_string()).collect();
        cmd.parse(&argv).unwrap()
    }

    #[test]
    fn metrics_command_runs() {
        let cmd = Cmd::new("metrics", "t");
        cmd_metrics(&parsed(cmd, &[])).unwrap();
    }

    fn profile_cmd(out: &str) -> Cmd {
        Cmd::new("profile", "t")
            .flag("framework", "pytorch", "h")
            .flag("phase", "forward", "h")
            .flag("amp", "O1", "h")
            .flag("scale", "lite", "h")
            .flag("device", "v100-sxm2-16gb", "h")
            .flag("from-csv", "", "h")
            .switch("lenient", "h")
            .flag("out", out, "h")
            .flag("trace", "", "h")
    }

    #[test]
    fn profile_command_lite_scale() {
        let dir = std::env::temp_dir().join(format!("hroofline-profcmd-{}", std::process::id()));
        cmd_profile(&parsed(profile_cmd(dir.to_str().unwrap()), &[])).unwrap();
        for ext in ["txt", "json", "svg", "csv", "timeline.txt", "timeline.svg"] {
            assert!(dir.join(format!("pytorch_forward.{ext}")).exists(), "{ext}");
        }
        // The default device is stamped into the artifacts.
        let txt = std::fs::read_to_string(dir.join("pytorch_forward.txt")).unwrap();
        assert!(txt.contains("V100-SXM2-16GB"), "{txt}");
        // The timeline lane carries the step-time breakdown; a
        // single-phase run gets no separate step artifact.
        let tl = std::fs::read_to_string(dir.join("pytorch_forward.timeline.txt")).unwrap();
        assert!(tl.contains("step-time breakdown"), "{tl}");
        assert!(tl.contains("step total"), "{tl}");
        assert!(!dir.join("pytorch_step.txt").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn profile_all_phases_emits_step_timeline() {
        let dir =
            std::env::temp_dir().join(format!("hroofline-profstep-{}", std::process::id()));
        let cmd = profile_cmd(dir.to_str().unwrap());
        cmd_profile(&parsed(cmd, &["--phase", "all"])).unwrap();
        for label in ["forward", "backward", "optimizer"] {
            assert!(dir.join(format!("pytorch_{label}.timeline.txt")).exists(), "{label}");
        }
        let step = std::fs::read_to_string(dir.join("pytorch_step.txt")).unwrap();
        assert!(step.contains("time-based Roofline"), "{step}");
        for row in ["forward", "backward", "optimizer", "idle (launch/drain)", "step total"] {
            assert!(step.contains(row), "missing '{row}' in {step}");
        }
        assert!(dir.join("pytorch_step.json").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn profile_multi_device_suffixes_non_default_artifacts() {
        // The unified --device list syntax: the default device keeps the
        // plain artifact ids, the rest get @short tags.
        let dir =
            std::env::temp_dir().join(format!("hroofline-profmulti-{}", std::process::id()));
        let cmd = profile_cmd(dir.to_str().unwrap());
        cmd_profile(&parsed(cmd, &["--device", "v100,a100"])).unwrap();
        assert!(dir.join("pytorch_forward.txt").exists());
        assert!(dir.join("pytorch_forward@a100.txt").exists());
        let txt = std::fs::read_to_string(dir.join("pytorch_forward@a100.txt")).unwrap();
        assert!(txt.contains("A100-SXM4-40GB"), "{txt}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn profile_command_alternate_device() {
        // The CI device-axis smoke in miniature: --device a100 puts the
        // A100's name into the txt and json artifacts.
        let dir =
            std::env::temp_dir().join(format!("hroofline-profcmd-a100-{}", std::process::id()));
        let cmd = profile_cmd(dir.to_str().unwrap());
        cmd_profile(&parsed(cmd, &["--device", "a100-sxm4-40gb"])).unwrap();
        let txt = std::fs::read_to_string(dir.join("pytorch_forward.txt")).unwrap();
        assert!(txt.contains("A100-SXM4-40GB"), "{txt}");
        let json = std::fs::read_to_string(dir.join("pytorch_forward.json")).unwrap();
        assert!(json.contains("A100-SXM4-40GB"), "{json}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn profile_rejects_bad_framework() {
        let cmd = profile_cmd("/tmp/x");
        assert!(cmd_profile(&parsed(cmd, &["--framework", "caffe"])).is_err());
    }

    #[test]
    fn profile_rejects_unknown_device_with_hint() {
        let cmd = profile_cmd("/tmp/x");
        let err = cmd_profile(&parsed(cmd, &["--device", "a100-sxm-40gb"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown device"), "{msg}");
        assert!(msg.contains("did you mean 'a100-sxm4-40gb'?"), "{msg}");
    }

    fn matrix_cmd(out: &str) -> Cmd {
        Cmd::new("matrix", "t")
            .flag("workloads", "all", "h")
            .flag("device", "default", "h")
            .flag("out", out, "h")
            .flag("max-failures", "unlimited", "h")
            .flag("inject-fault", "", "h")
            .flag("store", ".hroofline-cache", "h")
            .flag("shard", "", "h")
            .flag("merge", "", "h")
            .flag("trace", "", "h")
            .switch("fail-fast", "h")
            .switch("quick", "h")
            .switch("incremental", "h")
            .switch("print-keys", "h")
    }

    #[test]
    fn matrix_quick_restricted_writes_artifacts() {
        let dir = std::env::temp_dir().join(format!("hroofline-matrixcmd-{}", std::process::id()));
        let cmd = matrix_cmd(dir.to_str().unwrap());
        let code = cmd_matrix(&parsed(cmd, &["--quick", "--workloads", "deepcam-lite,transformer"]))
            .unwrap();
        assert_eq!(code, 0, "clean sweep exits 0");
        assert!(!dir.join("matrix.errors.json").exists(), "no manifest on a clean sweep");
        for name in ["matrix.txt", "matrix.json", "matrix.svg", "matrix.csv"] {
            assert!(dir.join(name).exists(), "{name}");
        }
        // 2 workloads x 2 frameworks x 2 phases x 2 policies.
        let scenario_jsons = std::fs::read_dir(dir.join("scenarios"))
            .unwrap()
            .filter(|e| {
                e.as_ref().unwrap().path().extension().is_some_and(|x| x == "json")
            })
            .count();
        assert_eq!(scenario_jsons, 16);
        assert!(dir.join("scenarios/transformer-pt-forward-O1.svg").exists());
        assert!(dir.join("scenarios/transformer-pt-forward-O1.csv").exists());
        // Every scenario gets its time-based Roofline lanes, and the
        // comparison report gets the step-time pivot lane.
        let tl = std::fs::read_to_string(
            dir.join("scenarios/transformer-pt-forward-O1.timeline.txt"),
        )
        .unwrap();
        assert!(tl.contains("step total"), "{tl}");
        assert!(dir.join("scenarios/transformer-pt-forward-O1.timeline.svg").exists());
        let pivot = std::fs::read_to_string(dir.join("matrix.timeline.txt")).unwrap();
        assert!(pivot.contains("step-time pivot"), "{pivot}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn matrix_multi_device_writes_per_device_and_cross_artifacts() {
        let dir =
            std::env::temp_dir().join(format!("hroofline-matrixdev-{}", std::process::id()));
        let cmd = matrix_cmd(dir.to_str().unwrap());
        cmd_matrix(&parsed(
            cmd,
            &["--quick", "--workloads", "transformer", "--device", "v100,a100"],
        ))
        .unwrap();
        // Per-device overlays plus the combined report.
        assert!(dir.join("matrix.txt").exists());
        assert!(dir.join("matrix@v100.svg").exists());
        assert!(dir.join("matrix@a100.svg").exists());
        // The combined report carries the cross-device pivot table.
        let txt = std::fs::read_to_string(dir.join("matrix.txt")).unwrap();
        assert!(txt.contains("cross-device comparison"), "{txt}");
        // Device-tagged scenario artifacts exist alongside default ones.
        assert!(dir.join("scenarios/transformer-pt-forward-O1.json").exists());
        assert!(dir.join("scenarios/transformer-pt-forward-O1@a100.json").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn matrix_rejects_unknown_workload_cleanly() {
        let cmd = matrix_cmd("/tmp/x");
        let err = cmd_matrix(&parsed(cmd, &["--quick", "--workloads", "resnet50"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown workload 'resnet50'"), "{msg}");
        assert!(msg.contains("did you mean 'resnet'?"), "{msg}");
    }

    #[test]
    fn matrix_rejects_unknown_device_cleanly() {
        let cmd = matrix_cmd("/tmp/x");
        let err =
            cmd_matrix(&parsed(cmd, &["--quick", "--device", "a100-sxm4-40g"])).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown device 'a100-sxm4-40g'"), "{msg}");
        assert!(msg.contains("did you mean 'a100-sxm4-40gb'?"), "{msg}");
    }

    #[test]
    fn matrix_injected_fault_degrades_and_exits_nonzero() {
        let dir =
            std::env::temp_dir().join(format!("hroofline-matrixfault-{}", std::process::id()));
        let cmd = matrix_cmd(dir.to_str().unwrap());
        let code = cmd_matrix(&parsed(
            cmd,
            &[
                "--quick",
                "--workloads",
                "transformer",
                "--inject-fault",
                "panic:transformer-tf-forward-O0",
            ],
        ))
        .unwrap();
        assert_eq!(code, EXIT_MATRIX_CELLS_FAILED);
        // The failed cell got no artifact; its siblings all did, and
        // the comparison report still landed.
        assert!(!dir.join("scenarios/transformer-tf-forward-O0.json").exists());
        assert!(dir.join("scenarios/transformer-pt-forward-O0.json").exists());
        assert!(dir.join("matrix.txt").exists());
        let manifest = std::fs::read_to_string(dir.join("matrix.errors.json")).unwrap();
        assert!(manifest.contains("hroofline-matrix-errors-v1"), "{manifest}");
        assert!(manifest.contains("transformer-tf-forward-O0"), "{manifest}");
        assert!(manifest.contains("panicked"), "{manifest}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn matrix_incremental_warm_run_is_byte_identical_with_zero_sims() {
        let base =
            std::env::temp_dir().join(format!("hroofline-matrixinc-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        let store = base.join("store");
        let run = |out: &std::path::Path| {
            let cmd = matrix_cmd(out.to_str().unwrap());
            cmd_matrix(&parsed(
                cmd,
                &[
                    "--quick",
                    "--workloads",
                    "transformer",
                    "--incremental",
                    "--store",
                    store.to_str().unwrap(),
                ],
            ))
            .unwrap()
        };
        let cold_out = base.join("cold");
        let warm_out = base.join("warm");
        assert_eq!(run(&cold_out), 0);
        assert_eq!(run(&warm_out), 0);
        // The warm run served every cell from the store: no misses,
        // zero simulations — the numbers the CI warm-store smoke greps.
        let cache = std::fs::read_to_string(warm_out.join("matrix.cache.json")).unwrap();
        assert!(cache.contains("hroofline-matrix-cache-v1"), "{cache}");
        assert!(cache.contains("\"misses\": 0"), "{cache}");
        assert!(cache.contains("\"simulations\": 0"), "{cache}");
        // And the comparison artifacts are byte-identical to the cold run.
        for name in ["matrix.txt", "matrix.json", "matrix.svg", "matrix.csv"] {
            assert_eq!(
                std::fs::read(cold_out.join(name)).unwrap(),
                std::fs::read(warm_out.join(name)).unwrap(),
                "cold and warm {name} must be byte-identical"
            );
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn matrix_print_keys_runs_nothing() {
        let dir =
            std::env::temp_dir().join(format!("hroofline-matrixkeys-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cmd = matrix_cmd(dir.to_str().unwrap());
        let code = cmd_matrix(&parsed(cmd, &["--quick", "--print-keys"])).unwrap();
        assert_eq!(code, 0);
        assert!(!dir.exists(), "--print-keys must not write artifacts");
    }

    #[test]
    fn matrix_merge_unions_shard_stores_into_one_report() {
        let base =
            std::env::temp_dir().join(format!("hroofline-matrixmerge-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&base);
        // Two incremental shard runs fill two disjoint stores...
        for shard in 0..2usize {
            let store = base.join(format!("store-{shard}"));
            let out = base.join(format!("shard-{shard}"));
            let cmd = matrix_cmd(out.to_str().unwrap());
            let code = cmd_matrix(&parsed(
                cmd,
                &[
                    "--quick",
                    "--workloads",
                    "transformer",
                    "--incremental",
                    "--store",
                    store.to_str().unwrap(),
                    "--shard",
                    &format!("{shard}/2"),
                ],
            ))
            .unwrap();
            assert_eq!(code, 0);
        }
        // ...and --merge replays their union with zero simulations.
        let merged = base.join("merged");
        let merge_arg =
            format!("{},{}", base.join("store-0").display(), base.join("store-1").display());
        let cmd = matrix_cmd(merged.to_str().unwrap());
        let code = cmd_matrix(&parsed(
            cmd,
            &["--quick", "--workloads", "transformer", "--merge", &merge_arg],
        ))
        .unwrap();
        assert_eq!(code, 0);
        let cache = std::fs::read_to_string(merged.join("matrix.cache.json")).unwrap();
        assert!(cache.contains("\"simulations\": 0"), "{cache}");
        // Reference: a plain unsharded run of the same selection.
        let direct = base.join("direct");
        let cmd = matrix_cmd(direct.to_str().unwrap());
        assert_eq!(
            cmd_matrix(&parsed(cmd, &["--quick", "--workloads", "transformer"])).unwrap(),
            0
        );
        for name in ["matrix.txt", "matrix.json", "matrix.svg", "matrix.csv"] {
            assert_eq!(
                std::fs::read(merged.join(name)).unwrap(),
                std::fs::read(direct.join(name)).unwrap(),
                "merged and direct {name} must be byte-identical"
            );
        }
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn matrix_rejects_bad_shard_and_merge_combinations() {
        let cmd = matrix_cmd("/tmp/x");
        let err = cmd_matrix(&parsed(cmd, &["--quick", "--shard", "3/3"])).unwrap_err();
        assert!(format!("{err:#}").contains("i/N"), "{err:#}");
        let cmd = matrix_cmd("/tmp/x");
        let err = cmd_matrix(&parsed(cmd, &["--quick", "--merge", "/tmp/a", "--shard", "0/2"]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("--shard"), "{err:#}");
        let cmd = matrix_cmd("/tmp/x");
        let err = cmd_matrix(&parsed(
            cmd,
            &["--quick", "--merge", "/tmp/a", "--inject-fault", "panic:x"],
        ))
        .unwrap_err();
        assert!(format!("{err:#}").contains("--inject-fault"), "{err:#}");
        let cmd = matrix_cmd("/tmp/x");
        let err = cmd_matrix(&parsed(cmd, &["--quick", "--merge", "/tmp/a", "--incremental"]))
            .unwrap_err();
        assert!(format!("{err:#}").contains("read-only"), "{err:#}");
    }

    #[test]
    fn matrix_rejects_bad_flag_values() {
        let cmd = matrix_cmd("/tmp/x");
        let err =
            cmd_matrix(&parsed(cmd, &["--quick", "--max-failures", "many"])).unwrap_err();
        assert!(format!("{err:#}").contains("bad --max-failures"), "{err:#}");
        let cmd = matrix_cmd("/tmp/x");
        let err =
            cmd_matrix(&parsed(cmd, &["--quick", "--inject-fault", "panic"])).unwrap_err();
        assert!(format!("{err:#}").contains("bad fault clause"), "{err:#}");
    }

    #[test]
    fn profile_from_csv_round_trips_an_exported_profile() {
        use crate::device::GpuSpec;
        let dir =
            std::env::temp_dir().join(format!("hroofline-profcsv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // Export a real profile, then re-ingest it through the CLI path.
        let spec = GpuSpec::v100();
        let graph = deepcam(&DeepCamConfig::lite());
        let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
        let profile = Session::standard(&spec)
            .run(&ProfileRequest::new(trace.phase(Phase::Forward)))
            .unwrap();
        let csv_path = dir.join("exported.csv");
        std::fs::write(&csv_path, export::to_csv(&profile)).unwrap();
        let cmd = profile_cmd(dir.to_str().unwrap());
        cmd_profile(&parsed(cmd, &["--from-csv", csv_path.to_str().unwrap()])).unwrap();
        let txt = std::fs::read_to_string(dir.join("ingested.txt")).unwrap();
        assert!(txt.contains("ingested profile on V100-SXM2-16GB"), "{txt}");
        assert!(dir.join("ingested.json").exists());
        assert!(dir.join("ingested.svg").exists());
        // A corrupted row fails strict ingestion but passes --lenient.
        let mut text = std::fs::read_to_string(&csv_path).unwrap();
        text.push_str("\"broken\",\"not-a-number\"\n");
        std::fs::write(&csv_path, text).unwrap();
        let cmd = profile_cmd(dir.to_str().unwrap());
        assert!(
            cmd_profile(&parsed(cmd, &["--from-csv", csv_path.to_str().unwrap()])).is_err()
        );
        let cmd = profile_cmd(dir.to_str().unwrap());
        cmd_profile(&parsed(cmd, &["--from-csv", csv_path.to_str().unwrap(), "--lenient"]))
            .unwrap();
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ingest_cmd_streams_a_csv_into_the_full_artifact_set() {
        use crate::device::GpuSpec;
        let dir = std::env::temp_dir().join(format!("hroofline-ingest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // A synthetic repeated-launch export: 3 kernels x 2 metrics x 4
        // repeats = 24 rows, dedup 8.0x.
        let mut csv = String::from(
            "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n",
        );
        for _ in 0..4 {
            for k in 0..3 {
                let cyc = 1000 * (k + 1);
                csv.push_str(&format!("\"k{k}\",\"sm__cycles_elapsed.avg\",{cyc},2\n"));
                csv.push_str(&format!("\"k{k}\",\"dram__bytes.sum\",{},2\n", 500 * (k + 1)));
            }
        }
        let csv_path = dir.join("trace.csv");
        std::fs::write(&csv_path, &csv).unwrap();
        let args: Vec<String> =
            vec![csv_path.to_str().unwrap().into(), "--out".into(), dir.to_str().unwrap().into()];
        cmd_ingest(&args).unwrap();
        let txt = std::fs::read_to_string(dir.join("ingested.txt")).unwrap();
        assert!(txt.contains("24 row(s) -> 3 unique kernel(s) (dedup 8.0x)"), "{txt}");
        assert!(txt.contains("peak resident accumulators 3"), "{txt}");
        let json = std::fs::read_to_string(dir.join("ingested.json")).unwrap();
        assert!(json.contains("\"unique_kernels\": 3"), "{json}");
        assert!(dir.join("ingested.svg").exists());
        assert!(dir.join("ingested.csv").exists());
        assert!(dir.join("ingested.timeline.txt").exists());
        // A non-default chunk size produces byte-identical artifacts.
        let dir4k = dir.join("4k");
        let args4k: Vec<String> = vec![
            csv_path.to_str().unwrap().into(),
            "--out".into(),
            dir4k.to_str().unwrap().into(),
            "--chunk-bytes".into(),
            "7".into(),
        ];
        cmd_ingest(&args4k).unwrap();
        for f in ["ingested.txt", "ingested.json", "ingested.svg", "ingested.csv"] {
            assert_eq!(
                std::fs::read(dir.join(f)).unwrap(),
                std::fs::read(dir4k.join(f)).unwrap(),
                "{f} differs under --chunk-bytes 7"
            );
        }
        // Usage-shape errors: a missing positional path is a command
        // error naming the usage line, not a panic.
        let err = cmd_ingest(&["--out".to_string(), dir.to_str().unwrap().to_string()])
            .unwrap_err();
        assert!(format!("{err:#}").contains("missing csv path"), "{err:#}");
        // The ceiling spec only matters when the csv has no stamp; both
        // paths must agree with the library-level ingest.
        let spec = GpuSpec::v100();
        let lib = export::from_csv(&csv, &spec).unwrap();
        assert_eq!(lib.n_kernels(), 3);
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn bench_diff_gates_regressions() {
        let dir = std::env::temp_dir().join(format!("hroofline-benchdiff-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let summary = |ns: f64| {
            format!(
                "{{\"schema\": \"hroofline-bench-v1\", \"group\": \"g\", \"iters\": 3, \
                 \"cases\": {{\"a\": {{\"ns_per_iter\": {ns}, \"items_per_sec\": 0}}}}}}"
            )
        };
        let base = dir.join("base.json");
        let ok = dir.join("ok.json");
        let slow = dir.join("slow.json");
        std::fs::write(&base, summary(1000.0)).unwrap();
        std::fs::write(&ok, summary(1100.0)).unwrap();
        std::fs::write(&slow, summary(2000.0)).unwrap();
        let cmd = || {
            Cmd::new("bench-diff", "t")
                .flag_required("baseline", "h")
                .flag_required("fresh", "h")
                .flag("max-regress", "0.25", "h")
        };
        let args_ok = ["--baseline", base.to_str().unwrap(), "--fresh", ok.to_str().unwrap()];
        cmd_bench_diff(&parsed(cmd(), &args_ok)).unwrap();
        let args_slow = ["--baseline", base.to_str().unwrap(), "--fresh", slow.to_str().unwrap()];
        let err = cmd_bench_diff(&parsed(cmd(), &args_slow)).unwrap_err();
        assert!(format!("{err:#}").contains("regressed"), "{err:#}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ert_quick_modeled_runs() {
        let dir = std::env::temp_dir().join(format!("hroofline-ertcmd-{}", std::process::id()));
        let cmd = Cmd::new("ert", "t")
            .flag("mode", "modeled", "h")
            .flag("device", "v100-sxm2-16gb", "h")
            .flag("out", dir.to_str().unwrap(), "h")
            .flag("trace", "", "h")
            .switch("quick", "h");
        cmd_ert(&parsed(cmd, &["--quick"])).unwrap();
        assert!(dir.join("fig1.svg").exists());
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ert_rejects_unknown_device_even_in_empirical_mode() {
        // The empirical sweep doesn't use the GPU spec, but a typo'd
        // --device must still fail fast with the registry hint instead
        // of silently running.
        let cmd = Cmd::new("ert", "t")
            .flag("mode", "modeled", "h")
            .flag("device", "v100-sxm2-16gb", "h")
            .flag("out", "/tmp/x", "h")
            .flag("trace", "", "h")
            .switch("quick", "h");
        let err = cmd_ert(&parsed(cmd, &["--mode", "empirical", "--device", "t44"]))
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unknown device 't44'"), "{msg}");
        assert!(msg.contains("did you mean 't4'?"), "{msg}");
    }

    #[test]
    fn ert_quick_modeled_runs_on_t4() {
        let dir = std::env::temp_dir().join(format!("hroofline-ertcmd-t4-{}", std::process::id()));
        let cmd = Cmd::new("ert", "t")
            .flag("mode", "modeled", "h")
            .flag("device", "v100-sxm2-16gb", "h")
            .flag("out", dir.to_str().unwrap(), "h")
            .flag("trace", "", "h")
            .switch("quick", "h");
        cmd_ert(&parsed(cmd, &["--quick", "--device", "t4"])).unwrap();
        let txt = std::fs::read_to_string(dir.join("fig1.txt")).unwrap();
        assert!(txt.contains("T4-PCIE-16GB"), "{txt}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn ert_device_list_writes_suffixed_fig1() {
        let dir =
            std::env::temp_dir().join(format!("hroofline-ertmulti-{}", std::process::id()));
        let cmd = Cmd::new("ert", "t")
            .flag("mode", "modeled", "h")
            .flag("device", "default", "h")
            .flag("out", dir.to_str().unwrap(), "h")
            .flag("trace", "", "h")
            .switch("quick", "h");
        cmd_ert(&parsed(cmd, &["--quick", "--device", "v100,t4"])).unwrap();
        // Default device stays plain, the T4 gets the @short tag.
        let v100 = std::fs::read_to_string(dir.join("fig1.txt")).unwrap();
        assert!(v100.contains("V100-SXM2-16GB"), "{v100}");
        let t4 = std::fs::read_to_string(dir.join("fig1@t4.txt")).unwrap();
        assert!(t4.contains("T4-PCIE-16GB"), "{t4}");
        let _ = std::fs::remove_dir_all(dir);
    }

    #[test]
    fn matrix_trace_writes_versioned_spans_and_metrics_snapshot() {
        let dir =
            std::env::temp_dir().join(format!("hroofline-matrixtrace-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let trace_path = dir.join("run.trace.jsonl");
        let cmd = matrix_cmd(dir.to_str().unwrap());
        let code = cmd_matrix(&parsed(
            cmd,
            &["--quick", "--workloads", "deepcam-lite", "--trace", trace_path.to_str().unwrap()],
        ))
        .unwrap();
        assert_eq!(code, 0);
        // The trace is a parseable, well-formed hroofline-trace-v1 log
        // with one `cell` span per enumerated cell (1 workload x 2
        // frameworks x 2 phases x 2 policies = 8).
        let text = std::fs::read_to_string(&trace_path).unwrap();
        assert!(text.starts_with("{\"clock\":\"monotonic-us\""), "{text}");
        let trace = crate::obs::Trace::parse_jsonl(&text).unwrap();
        trace.validate().unwrap();
        let cells: Vec<_> = trace.spans.iter().filter(|s| s.name == "cell").collect();
        assert_eq!(cells.len(), 8, "{text}");
        assert!(cells.iter().all(|s| s.field("outcome") == Some("ran")), "{text}");
        // The metrics snapshot landed next to the artifacts. Counters
        // come from the process-global registry (shared with parallel
        // tests), so only lower-bound them.
        let metrics = std::fs::read_to_string(dir.join("run.metrics.json")).unwrap();
        assert!(metrics.contains("hroofline-metrics-v1"), "{metrics}");
        let doc = Json::parse(&metrics).unwrap();
        let ran =
            doc.get("counters").unwrap().get("matrix.cells.ran").unwrap().as_usize().unwrap();
        assert!(ran >= 8, "{metrics}");
        // And the reporter digests the written log end to end.
        cmd_trace(&["report".to_string(), trace_path.to_str().unwrap().to_string()]).unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn trace_report_rejects_bad_usage() {
        let s = |v: &[&str]| v.iter().map(|x| x.to_string()).collect::<Vec<String>>();
        let msg = |args: &[&str]| format!("{:#}", cmd_trace(&s(args)).unwrap_err());
        assert!(msg(&[]).contains("usage:"), "{}", msg(&[]));
        assert!(msg(&["digest", "x"]).contains("unknown trace subcommand"));
        assert!(msg(&["report"]).contains("exactly one"));
        assert!(msg(&["report", "a", "b"]).contains("exactly one"));
        assert!(msg(&["report", "/nonexistent/trace.jsonl"]).contains("reading"));
    }

    #[test]
    fn trace_report_renders_cells_and_attribution() {
        // A fixed-tick tracer makes the report fully deterministic:
        // root [0..3] with one cell child [1..2], wall 3 ticks, all of
        // it covered by the root span.
        let tracer = crate::obs::Tracer::fixed();
        {
            let root = tracer.span("run");
            let mut cell = root.child("cell");
            cell.set("label", "cell#0:deepcam-lite-pt-forward-O1");
            cell.set("attempt", "1");
            cell.set("outcome", "ran");
        }
        let trace = crate::obs::Trace::parse_jsonl(&tracer.to_jsonl()).unwrap();
        let report = render_trace_report(&trace, "t.jsonl");
        assert!(report.contains("clock fixed-tick"), "{report}");
        assert!(report.contains("2 span(s)"), "{report}");
        assert!(report.contains("cell#0:deepcam-lite-pt-forward-O1"), "{report}");
        assert!(report.contains("span tree:"), "{report}");
        assert!(report.contains("attribution: 3 of 3 wall ticks"), "{report}");
        assert!(report.contains("(100.0%)"), "{report}");
    }
}
