//! Coordinator: orchestration of sweeps, profiling jobs, reports and the
//! end-to-end PJRT training loop — the implementations behind the
//! `repro` CLI.

pub mod commands;
pub mod train;

pub use commands::{
    cmd_bench_diff, cmd_ert, cmd_ingest, cmd_matrix, cmd_metrics, cmd_profile, cmd_report,
    cmd_trace, cmd_train, ingest_cmd_spec, EXIT_MATRIX_CELLS_FAILED,
};
pub use train::{run_training, TrainConfig, TrainResult};
