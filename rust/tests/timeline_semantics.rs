//! Semantics guards for the time-based Roofline (arXiv 2009.04598)
//! layer: per-kernel durations must tile the step exactly, the
//! bound-bucket decomposition must partition every phase, the timeline
//! must be deterministic across shared-cache and standalone sessions,
//! and — crucially — the pre-existing counter-only outputs (CSV, SVG,
//! counter sets) must stay byte-identical when timing is collected.

use hroofline::device::GpuSpec;
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework, Phase};
use hroofline::dl::Policy;
use hroofline::profiler::export::to_csv;
use hroofline::profiler::{ProfileRequest, Session, StepTimeline};
use hroofline::roofline::chart::RooflineChart;
use hroofline::roofline::model::RooflineModel;
use hroofline::sim::SharedSimCache;

const PHASES: [(Phase, &str); 3] = [
    (Phase::Forward, "forward"),
    (Phase::Backward, "backward"),
    (Phase::Optimizer, "optimizer"),
];

fn rel_eq(a: f64, b: f64, tol: f64) {
    let scale = a.abs().max(b.abs()).max(1e-30);
    assert!((a - b).abs() <= tol * scale, "{a} vs {b} (rel tol {tol})");
}

#[test]
fn phase_durations_sum_to_step_total() {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let session = Session::standard(&spec);

    let profiles: Vec<_> = PHASES
        .iter()
        .map(|(phase, label)| {
            (*label, session.run(&ProfileRequest::new(trace.phase(*phase))).unwrap())
        })
        .collect();
    let timeline = StepTimeline::from_phases(&spec.name, profiles.iter().map(|(l, p)| (*l, p)));
    assert_eq!(timeline.phases.len(), PHASES.len());

    // Each phase slice is exactly the sum of its kernels' timed
    // durations, and those agree with the counter-derived phase time.
    let mut step = 0.0;
    for ((_, profile), slice) in profiles.iter().zip(&timeline.phases) {
        let kernel_sum: f64 = profile.kernels().map(|k| k.duration_s()).sum();
        rel_eq(slice.seconds, kernel_sum, 1e-12);
        rel_eq(slice.seconds, profile.total_seconds(), 1e-9);
        step += profile.total_seconds();
    }
    rel_eq(timeline.step_seconds(), step, 1e-9);
    assert!(timeline.step_seconds() > 0.0);
    // The idle (launch/drain ramp) component is part of the phase
    // times, never an extra addend on top of them.
    assert!(timeline.idle_seconds() > 0.0);
    assert!(timeline.idle_seconds() < timeline.step_seconds());
}

#[test]
fn bound_bucket_fractions_sum_to_one() {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::TensorFlow, Policy::O1, &spec);
    let session = Session::standard(&spec);

    let profiles: Vec<_> = PHASES
        .iter()
        .map(|(phase, label)| {
            (*label, session.run(&ProfileRequest::new(trace.phase(*phase))).unwrap())
        })
        .collect();
    let timeline = StepTimeline::from_phases(&spec.name, profiles.iter().map(|(l, p)| (*l, p)));

    // Every phase partitions into the three bound buckets...
    for slice in &timeline.phases {
        rel_eq(slice.compute_s + slice.memory_s + slice.overhead_s, slice.seconds, 1e-12);
    }
    // ...and so does the step: the bucket fractions sum to exactly 1.
    let step = timeline.step_seconds();
    assert!(step > 0.0);
    let (c, m, o) = timeline.bucket_seconds();
    rel_eq(c / step + m / step + o / step, 1.0, 1e-12);
    // A full training step exercises both compute- and memory-bound
    // kernels (tensor-core GEMMs vs streaming optimizer updates).
    assert!(c > 0.0, "compute-bound bucket empty");
    assert!(m > 0.0, "memory-bound bucket empty");
}

#[test]
fn timeline_deterministic_across_shared_and_standalone_sessions() {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::lite());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let session = Session::standard(&spec);
    let cache = SharedSimCache::new();

    for (phase, label) in PHASES {
        let kernels = trace.phase(phase);
        let standalone = session.run(&ProfileRequest::new(kernels)).unwrap();
        let shared = session.run(&ProfileRequest::new(kernels).shared_cache(&cache)).unwrap();
        // Bit-identical profiles, timing included...
        assert_eq!(standalone, shared, "{label}");
        // ...and therefore bit-identical timeline renderings.
        let mut t_standalone = StepTimeline::new(&spec.name);
        t_standalone.push_phase(label, &standalone);
        let mut t_shared = StepTimeline::new(&spec.name);
        t_shared.push_phase(label, &shared);
        assert_eq!(t_standalone, t_shared, "{label}");
        assert_eq!(
            hroofline::roofline::time::timeline_text(label, &t_standalone, &standalone),
            hroofline::roofline::time::timeline_text(label, &t_shared, &shared),
        );
    }
}

#[test]
fn v100_counter_outputs_byte_identical_with_and_without_timing() {
    // The acceptance bar for this PR: collecting durations must not
    // perturb a single byte of the counter-only artifact lanes.
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let all = trace.all();
    let session = Session::standard(&spec);

    let timed = session.run(&ProfileRequest::new(&all)).unwrap();
    let counters_only = session.run(&ProfileRequest::new(&all).counters_only()).unwrap();

    // Timing is the only difference between the two profiles.
    assert!(timed.kernels().all(|k| k.timing.is_some()));
    assert!(counters_only.kernels().all(|k| k.timing.is_none()));
    for (a, b) in timed.kernels().zip(counters_only.kernels()) {
        assert_eq!(a.name, b.name);
        assert_eq!(a.counters, b.counters, "{}", a.name);
        assert_eq!(a.invocations, b.invocations, "{}", a.name);
    }
    assert_eq!(timed.total_seconds(), counters_only.total_seconds());

    // The serialized counter lanes are byte-identical.
    assert_eq!(to_csv(&timed), to_csv(&counters_only));
    let svg = |p| RooflineChart::hierarchical(&RooflineModel::from_profile(&spec, p), "t").to_svg();
    assert_eq!(svg(&timed), svg(&counters_only));
}
