//! Scenario-matrix integration: the quick-mode sweep (the CI gate)
//! end to end — deterministic enumeration, golden catalog, artifact
//! layout, and cross-run reproducibility. Incremental replay, the
//! on-disk cell store, and `--shard`/`--merge` semantics live in
//! `rust/tests/incremental_matrix.rs`.

use hroofline::device::registry as devices;
use hroofline::dl::workloads;
use hroofline::scenario::{comparison_csv, comparison_table, Scenario, ScenarioMatrix};

/// The quick-mode catalog, pinned: workload-major, then framework,
/// phase, policy. A change here is an intentional matrix redefinition
/// and must update the CI artifact assertions too.
const QUICK_IDS: [&str; 32] = [
    "deepcam-paper-tf-forward-O0",
    "deepcam-paper-tf-forward-O1",
    "deepcam-paper-tf-backward-O0",
    "deepcam-paper-tf-backward-O1",
    "deepcam-paper-pt-forward-O0",
    "deepcam-paper-pt-forward-O1",
    "deepcam-paper-pt-backward-O0",
    "deepcam-paper-pt-backward-O1",
    "deepcam-lite-tf-forward-O0",
    "deepcam-lite-tf-forward-O1",
    "deepcam-lite-tf-backward-O0",
    "deepcam-lite-tf-backward-O1",
    "deepcam-lite-pt-forward-O0",
    "deepcam-lite-pt-forward-O1",
    "deepcam-lite-pt-backward-O0",
    "deepcam-lite-pt-backward-O1",
    "resnet-tf-forward-O0",
    "resnet-tf-forward-O1",
    "resnet-tf-backward-O0",
    "resnet-tf-backward-O1",
    "resnet-pt-forward-O0",
    "resnet-pt-forward-O1",
    "resnet-pt-backward-O0",
    "resnet-pt-backward-O1",
    "transformer-tf-forward-O0",
    "transformer-tf-forward-O1",
    "transformer-tf-backward-O0",
    "transformer-tf-backward-O1",
    "transformer-pt-forward-O0",
    "transformer-pt-forward-O1",
    "transformer-pt-backward-O0",
    "transformer-pt-backward-O1",
];

#[test]
fn quick_catalog_is_golden() {
    let ids: Vec<String> = ScenarioMatrix::quick().enumerate().iter().map(Scenario::id).collect();
    assert_eq!(ids, QUICK_IDS.to_vec());
    // The catalog table carries exactly one row per scenario and the
    // pinned header.
    let catalog = ScenarioMatrix::quick().catalog_table();
    assert_eq!(catalog.n_rows(), QUICK_IDS.len());
    let rendered = catalog.render();
    for col in ["scenario", "workload", "framework", "phase", "amp", "scale"] {
        assert!(rendered.contains(col), "missing column '{col}'");
    }
}

#[test]
fn quick_sweep_meets_the_acceptance_floor() {
    // ≥ 16 scenarios from ≥ 4 workloads × 2 frameworks × ≥ 2
    // phase/policy combos. Single-device (the registry default) so the
    // required CI gate's cost stays flat as devices are registered.
    let m = ScenarioMatrix::quick();
    assert!(m.workloads.len() >= 4);
    assert_eq!(m.frameworks.len(), 2);
    assert!(m.phases.len() * m.policies.len() >= 2);
    assert!(m.enumerate().len() >= 16);
    assert_eq!(workloads::registry().len(), m.workloads.len());
    assert_eq!(m.devices.len(), 1);
    assert_eq!(m.devices[0].name, devices::default_entry().name);
}

#[test]
fn quick_sweep_runs_and_compares_all_scenarios() {
    let run = ScenarioMatrix::quick().run();
    assert_eq!(run.results.len(), QUICK_IDS.len());

    // Results arrive in enumeration order, every scenario non-empty
    // (quick mode has no TF-optimizer cells), and every scenario
    // carries hierarchical Roofline data at all three levels.
    for (r, want) in run.results.iter().zip(QUICK_IDS) {
        assert_eq!(r.id(), want);
        assert!(!r.is_empty(), "{want}");
        let point = r.aggregate_point().unwrap_or_else(|| panic!("{want}: no point"));
        assert_eq!(point.ai.len(), 3, "{want}: L1/L2/HBM triplet");
        assert!(point.flops_per_sec > 0.0, "{want}");
    }

    // The shared cache deduped across scenarios.
    let (hits, sims) = run.sim_stats;
    assert!(sims > 0);
    assert!(hits > 0, "no cross-scenario kernel reuse ({hits} hits / {sims} sims)");

    // Cross-scenario comparison covers every row; the golden table is
    // structurally pinned (one row per scenario, stable id column).
    let table = comparison_table(&run.results);
    assert_eq!(table.n_rows(), run.results.len());
    let text = table.render();
    for id in QUICK_IDS {
        assert!(text.contains(id), "missing comparison row {id}");
    }

    // Framework contrast survives aggregation: the PyTorch forward
    // trace carries more distinct kernels than the TF one (Fig. 3 vs
    // Fig. 5 shape) for the conv workloads.
    let kernels_of = |id: &str| {
        run.results.iter().find(|r| r.id() == id).unwrap().profile.n_kernels()
    };
    assert!(
        kernels_of("deepcam-paper-pt-forward-O1") > kernels_of("deepcam-paper-tf-forward-O1")
    );
}

#[test]
fn sweep_is_reproducible_byte_for_byte() {
    // Same matrix, two runs (each internally parallel): identical
    // comparison CSV. This is the cross-run determinism the golden CI
    // artifact diffing relies on.
    let m1 = ScenarioMatrix::quick().with_workloads("resnet,transformer").unwrap();
    let m2 = ScenarioMatrix::quick().with_workloads("resnet,transformer").unwrap();
    let a = comparison_csv(&m1.run().results);
    let b = comparison_csv(&m2.run().results);
    assert_eq!(a, b);
    assert!(a.lines().count() == 1 + 16, "header + 16 rows: {}", a.lines().count());
}

#[test]
fn full_matrix_enumeration_is_superset_of_quick() {
    let full: Vec<String> = ScenarioMatrix::full().enumerate().iter().map(Scenario::id).collect();
    // The full matrix crosses every registered device: 4 workloads × 2
    // frameworks × 3 phases × 3 policies per device.
    assert_eq!(full.len(), 72 * devices::entries().len());
    // Quick uses quick scale, so ids coincide but builds differ; the id
    // space of quick (default-device, device-less ids) is contained in
    // full's.
    for id in QUICK_IDS {
        assert!(full.contains(&id.to_string()), "{id} missing from full matrix");
    }
    // Non-default devices appear with their short tag.
    for d in devices::entries().iter().skip(1) {
        let tagged = format!("deepcam-paper-tf-forward-O0@{}", d.short);
        assert!(full.contains(&tagged), "{tagged} missing from full matrix");
    }
}

#[test]
fn device_restricted_quick_sweep_is_device_tagged() {
    // A quick sweep pointed at a non-default device keeps the catalog
    // shape but tags every id — nothing collides with the golden
    // default-device catalog.
    let m = ScenarioMatrix::quick()
        .with_workloads("transformer")
        .unwrap()
        .with_devices("t4")
        .unwrap();
    let ids: Vec<String> = m.enumerate().iter().map(Scenario::id).collect();
    assert_eq!(ids.len(), 8);
    assert!(ids.iter().all(|id| id.ends_with("@t4")), "{ids:?}");
    let run = m.run();
    for r in &run.results {
        assert_eq!(r.scenario.device.name, "t4-pcie-16gb");
        assert!(!r.is_empty(), "{}", r.id());
    }
}
