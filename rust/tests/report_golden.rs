//! Report-level golden checks: every paper artifact generates, writes
//! its files, and carries the paper-shape invariants end to end.

use hroofline::report::{generate, ALL_IDS};

#[test]
fn all_artifacts_generate_and_write() {
    let dir = std::env::temp_dir().join(format!("hroofline-golden-{}", std::process::id()));
    for id in ALL_IDS {
        let a = generate(id).unwrap_or_else(|e| panic!("{id}: {e:#}"));
        assert_eq!(a.id, id);
        assert!(!a.text.is_empty(), "{id}: empty text");
        a.write_all(&dir).unwrap();
        assert!(dir.join(format!("{id}.txt")).exists());
        assert!(dir.join(format!("{id}.json")).exists());
        if a.svg.is_some() {
            let svg = std::fs::read_to_string(dir.join(format!("{id}.svg"))).unwrap();
            assert!(svg.starts_with("<svg"), "{id}: bad svg");
            assert!(svg.trim_end().ends_with("</svg>"), "{id}: unterminated svg");
        }
    }
    let _ = std::fs::remove_dir_all(dir);
}

#[test]
fn figures_have_svgs_tables_do_not() {
    for id in ALL_IDS {
        let a = generate(id).unwrap();
        if id.starts_with("fig") {
            assert!(a.svg.is_some(), "{id} should have a chart");
        } else {
            assert!(a.svg.is_none(), "{id} is a table");
        }
    }
}

#[test]
fn headline_shape_summary() {
    // The cross-figure story in one place (EXPERIMENTS.md §shape):
    // TF forward has a dominant TC kernel; PyTorch forward does not;
    // PyTorch's backward top kernel is the slow FP32 wgrad; the
    // optimizer is entirely memory-bound.
    let f3 = generate("fig3").unwrap().json;
    let f5 = generate("fig5").unwrap().json;
    let f6 = generate("fig6").unwrap().json;
    let share3 = f3.get("top_kernel_time_share").unwrap().as_f64().unwrap();
    let share5 = f5.get("top_kernel_time_share").unwrap().as_f64().unwrap();
    assert!(share3 > share5, "TF fwd more dominant than PT fwd");
    let top6 = &f6.get("kernels").unwrap().as_arr().unwrap()[0];
    assert!(!top6.get("tensor").unwrap().as_bool().unwrap());
}
