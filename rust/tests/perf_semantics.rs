//! Semantics guards for the profiling hot path: the dense counter
//! storage, the simulation memoizer, and the parallel session fan-out
//! are *optimizations only* — every observable output must be
//! bit-identical to the serial, unmemoized path (cf. PR 1's ERT-sweep
//! guarantee for `exec::parallel_map`).

use hroofline::device::{GpuSpec, Precision};
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework};
use hroofline::dl::Policy;
use hroofline::profiler::export::to_csv;
use hroofline::profiler::{ProfileRequest, Session, SessionConfig};
use hroofline::prop::check;
use hroofline::sim::kernel::{KernelDesc, KernelInvocation};

fn legacy_config() -> SessionConfig {
    // The pre-optimization behaviour: one simulation per trace entry,
    // strictly serial.
    SessionConfig { memoize: false, threads: Some(1), ..Default::default() }
}

#[test]
fn full_step_profile_bit_identical_across_optimizations() {
    // The acceptance check for this PR: a standard `Session::run`
    // over a full DeepCAM training step produces the same bits no
    // matter which of memoization / parallel fan-out is active.
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let all = trace.all();
    assert!(all.len() > 10, "paper-scale step should have many entries");

    let reference = Session::new(&spec, legacy_config()).run(&ProfileRequest::new(&all)).unwrap();
    let reference_csv = to_csv(&reference);

    let standard = Session::standard(&spec).run(&ProfileRequest::new(&all)).unwrap();
    assert_eq!(standard, reference, "standard (memoized, auto-threaded)");
    assert_eq!(to_csv(&standard), reference_csv, "serialized output");

    for (memoize, threads) in [(true, 1), (true, 8), (false, 8)] {
        let cfg = SessionConfig { memoize, threads: Some(threads), ..Default::default() };
        let p = Session::new(&spec, cfg).run(&ProfileRequest::new(&all)).unwrap();
        assert_eq!(p, reference, "memoize={memoize} threads={threads}");
        assert_eq!(to_csv(&p), reference_csv, "memoize={memoize} threads={threads}");
    }
}

#[test]
fn random_traces_profile_identically_memoized_and_parallel() {
    // Property: for arbitrary traces (duplicate descriptors, repeated
    // kernel names, mixed kernel families), the optimized session
    // equals the serial unmemoized one exactly.
    check("optimized profiling == legacy profiling", 20, |g| {
        let spec = GpuSpec::v100();
        // A small pool of distinct kernels; entries re-draw from it so
        // the memoizer sees genuine duplicates.
        let names = ["wgrad", "relu", "cast", "hmma", "adam"];
        let n_pool = g.usize_range(1, 6);
        let pool: Vec<KernelDesc> = (0..n_pool)
            .map(|i| {
                let name = names[i % names.len()];
                if g.bool() {
                    let m: u64 = 64 << g.usize_range(0, 3);
                    KernelDesc::gemm(name, m, m, m, Precision::Fp16, g.bool(), 64, &spec)
                } else {
                    let p = *g.pick(&Precision::ALL);
                    let n = 1u64 << g.usize_range(10, 18);
                    KernelDesc::streaming_elementwise(name, n, p, g.usize_range(0, 3) as u64)
                }
            })
            .collect();
        let n_entries = g.usize_range(1, 24);
        let trace: Vec<KernelInvocation> = (0..n_entries)
            .map(|_| KernelInvocation {
                kernel: g.pick(&pool).clone(),
                invocations: g.usize_range(1, 9) as u64,
                stream: g.usize_range(0, 3) as u32,
            })
            .collect();

        let reference =
            Session::new(&spec, legacy_config()).run(&ProfileRequest::new(&trace)).unwrap();
        let standard = Session::standard(&spec).run(&ProfileRequest::new(&trace)).unwrap();
        assert_eq!(standard, reference);
        let par = SessionConfig { threads: Some(3), ..Default::default() };
        let parallel = Session::new(&spec, par).run(&ProfileRequest::new(&trace)).unwrap();
        assert_eq!(parallel, reference);
        assert_eq!(to_csv(&parallel), to_csv(&reference));
    });
}

#[test]
fn one_metric_per_run_still_bit_identical_under_optimizations() {
    // The §III-B protocol (one metric per execution) exercises the
    // many-passes merge path; it must also be invariant.
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::lite());
    let trace = lower(&graph, Framework::TensorFlow, Policy::O1, &spec);
    let all = trace.all();

    let mut legacy = legacy_config();
    legacy.one_metric_per_run = true;
    let reference = Session::new(&spec, legacy).run(&ProfileRequest::new(&all)).unwrap();

    let fast =
        SessionConfig { one_metric_per_run: true, threads: Some(4), ..Default::default() };
    let optimized = Session::new(&spec, fast).run(&ProfileRequest::new(&all)).unwrap();
    assert_eq!(optimized, reference);
    assert_eq!(to_csv(&optimized), to_csv(&reference));
}
