//! Semantics guards for the streaming CSV ingest path (the bounded-
//! memory `profiler::ingest` pipeline behind `repro ingest`).
//!
//! The contract under test, in order of importance:
//!
//! 1. **Byte-identity**: `from_csv`/`from_csv_lenient` are thin wrappers
//!    over the streaming core, so streaming a file and parsing it
//!    in-memory must produce *identical* profiles — `Profile`'s exact
//!    `PartialEq` plus string equality of both serialized forms (CSV and
//!    JSON), for any chunk size.
//! 2. **Bounded memory**: the aggregator's high-water mark
//!    (`peak_resident_accumulators`) tracks unique kernels, never row
//!    count — the O(unique kernels) guarantee as an observable number.
//! 3. **Chunk-boundary robustness**: CRLF endings, quoted commas,
//!    device stamps and unterminated trailing lines survive every
//!    buffer-boundary placement, down to 1-byte chunks.
//! 4. **Dedup accounting**: `IngestStats::dedup_ratio` reflects the
//!    launch-to-kernel compression of the synthetic trace exactly.

use hroofline::device::{GpuSpec, Precision};
use hroofline::profiler::export::{from_csv, from_csv_lenient, profile_to_json, to_csv};
use hroofline::profiler::ingest::from_reader;
use hroofline::profiler::{IngestConfig, ProfileRequest, Session};
use hroofline::sim::kernel::{KernelDesc, KernelInvocation};

const HEADER: &str = "\"Kernel Name\",\"Metric Name\",\"Metric Value\",\"Invocations\"\n";

/// A realistic export: run a small mixed trace through a session and
/// serialize it, so the CSV carries quoted names, a device stamp, and
/// the full Table II metric set.
fn session_csv(spec: &GpuSpec) -> String {
    let trace = vec![
        KernelInvocation {
            kernel: KernelDesc::streaming_elementwise(
                "relu, \"fused\"",
                1 << 14,
                Precision::Fp32,
                1,
            ),
            invocations: 4,
            stream: 0,
        },
        KernelInvocation::once(KernelDesc::gemm(
            "volta_hmma_gemm", 256, 256, 256, Precision::Fp16, true, 64, spec,
        )),
    ];
    let profile = Session::standard(spec).run(&ProfileRequest::new(&trace)).unwrap();
    to_csv(&profile)
}

/// A synthetic many-launch export: `kernels` distinct kernels, each
/// emitting `metrics_per_kernel` rows repeated `repeats` times, so the
/// expected dedup ratio is `metrics_per_kernel * repeats`.
fn synthetic_csv(kernels: usize, metrics_per_kernel: usize, repeats: usize) -> String {
    let metric_names =
        ["sm__cycles_elapsed.avg", "dram__bytes.sum", "lts__t_bytes.sum", "l1tex__t_bytes.sum"];
    let mut csv = String::from(HEADER);
    for _ in 0..repeats {
        for k in 0..kernels {
            for m in 0..metrics_per_kernel {
                let metric = metric_names[m % metric_names.len()];
                // Same (kernel, metric) value on every repeat: repeated
                // launches in a real export re-state the aggregate.
                csv.push_str(&format!("\"kern_{k}\",\"{metric}\",{},{}\n", 100 * k + m, 1 + k % 3));
            }
        }
    }
    csv
}

#[test]
fn streaming_and_in_memory_paths_are_byte_identical() {
    let spec = GpuSpec::v100();
    let csv = session_csv(&spec);

    let in_memory = from_csv(&csv, &spec).unwrap();
    for chunk in [1usize, 7, 64, 4096, IngestConfig::DEFAULT_CHUNK_BYTES] {
        let out = from_reader(
            &mut csv.as_bytes(),
            &spec,
            &IngestConfig::new().chunk_bytes(chunk),
        )
        .unwrap();
        // Exact structural equality…
        assert_eq!(out.profile, in_memory, "chunk_bytes={chunk}");
        // …and string equality of both serialized forms — the literal
        // byte-identity acceptance check.
        assert_eq!(to_csv(&out.profile), to_csv(&in_memory), "csv bytes, chunk={chunk}");
        assert_eq!(
            profile_to_json(&out.profile).to_string_pretty(),
            profile_to_json(&in_memory).to_string_pretty(),
            "json bytes, chunk={chunk}"
        );
        assert!(out.diagnostics.is_empty());
    }
}

#[test]
fn dedup_ratio_matches_the_synthetic_trace() {
    let spec = GpuSpec::v100();
    let (kernels, metrics, repeats) = (20usize, 4usize, 25usize);
    let csv = synthetic_csv(kernels, metrics, repeats);
    let out = from_reader(&mut csv.as_bytes(), &spec, &IngestConfig::new()).unwrap();
    assert_eq!(out.stats.unique_kernels, kernels);
    assert_eq!(out.stats.rows, (kernels * metrics * repeats) as u64);
    let expected = (metrics * repeats) as f64;
    assert!(
        (out.stats.dedup_ratio() - expected).abs() < 1e-12,
        "dedup {} != {expected}",
        out.stats.dedup_ratio()
    );
    // Repeated launches fold, they don't multiply: the profile holds
    // each kernel once with its declared invocation count.
    assert_eq!(out.profile.n_kernels(), kernels);
    assert_eq!(out.profile.kernel("kern_5").unwrap().invocations, 1 + 5 % 3);
}

#[test]
fn chunk_boundaries_survive_crlf_and_trailing_partial_lines() {
    let spec = GpuSpec::v100();
    // CRLF line endings, a device stamp, a quoted comma in a kernel
    // name, and *no* trailing newline — the last row must be emitted
    // from the residual buffer at EOF.
    let csv = format!(
        "# device=TestBox\r\n{header}\"k, one\",\"dram__bytes.sum\",123,2\r\n\
         \"k2\",\"sm__cycles_elapsed.avg\",456,1",
        header = HEADER.trim_end_matches('\n').to_string() + "\r\n"
    );
    let reference = from_reader(&mut csv.as_bytes(), &spec, &IngestConfig::new()).unwrap();
    assert_eq!(reference.profile.device, "TestBox");
    assert_eq!(reference.profile.kernel("k, one").unwrap().invocations, 2);
    let k2 = reference.profile.kernel("k2").unwrap();
    assert_eq!(k2.counters.get("sm__cycles_elapsed.avg"), 456.0);
    // Every chunk size slices the CRLF pairs and the unterminated tail
    // differently; the output must not notice.
    for chunk in 1..=16usize {
        let out =
            from_reader(&mut csv.as_bytes(), &spec, &IngestConfig::new().chunk_bytes(chunk))
                .unwrap();
        assert_eq!(out.profile, reference.profile, "chunk_bytes={chunk}");
        assert_eq!(out.stats, reference.stats, "chunk_bytes={chunk}");
    }
    // In-memory wrapper agreement on the same pathological text.
    assert_eq!(from_csv(&csv, &spec).unwrap(), reference.profile);
}

#[test]
fn lenient_streaming_matches_from_csv_lenient() {
    let spec = GpuSpec::v100();
    let csv = format!(
        "{HEADER}\"k\",\"sm__cycles_elapsed.avg\",1000,1\n\
         \"k\",\"dram__bytes.sum\",notanumber,1\n\
         too,few\n\
         \"k\",\"lts__t_bytes.sum\",800,2\n\
         \"k2\",\"dram__bytes.sum\",50,1\n"
    );
    let (wrapper_profile, wrapper_diags) = from_csv_lenient(&csv, &spec).unwrap();
    for chunk in [1usize, 5, 64] {
        let out = from_reader(
            &mut csv.as_bytes(),
            &spec,
            &IngestConfig::new().lenient(true).chunk_bytes(chunk),
        )
        .unwrap();
        assert_eq!(out.profile, wrapper_profile, "chunk_bytes={chunk}");
        assert_eq!(out.diagnostics, wrapper_diags, "chunk_bytes={chunk}");
    }
    // The diagnostics carry the streamed line numbers: bad value at 3,
    // short row at 4, conflicting invocations at 5.
    let lines: Vec<usize> = wrapper_diags.rows.iter().map(|d| d.line).collect();
    assert_eq!(lines, [3, 4, 5]);
    // Rejected rows still count in stats (they were read and parsed).
    let out = from_reader(&mut csv.as_bytes(), &spec, &IngestConfig::new().lenient(true)).unwrap();
    assert_eq!(out.stats.rows, 5);
    assert_eq!(out.stats.unique_kernels, 2);
}

#[test]
fn resident_accumulators_track_unique_kernels_not_rows() {
    // The bounded-memory property: scale rows by 50x at constant kernel
    // count and the accumulator high-water mark must not move.
    let spec = GpuSpec::v100();
    let kernels = 16usize;
    let mut peaks = Vec::new();
    for repeats in [1usize, 10, 50] {
        let csv = synthetic_csv(kernels, 4, repeats);
        let out = from_reader(&mut csv.as_bytes(), &spec, &IngestConfig::new()).unwrap();
        assert_eq!(out.stats.rows, (kernels * 4 * repeats) as u64);
        assert_eq!(
            out.stats.peak_resident_accumulators, out.stats.unique_kernels,
            "aggregation never evicts, so peak == unique"
        );
        peaks.push(out.stats.peak_resident_accumulators);
    }
    assert!(peaks.iter().all(|&p| p == kernels), "peak is row-count-invariant: {peaks:?}");
}
