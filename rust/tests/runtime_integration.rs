//! Integration tests over the real AOT bridge: python/jax/pallas
//! artifacts loaded and executed through PJRT from Rust.
//!
//! These tests are skipped (not failed) when `make artifacts` has not
//! produced the artifact directory, so `cargo test` works on a fresh
//! checkout; CI and `make test` always build artifacts first.

use hroofline::runtime::engine::{literal_f32, to_vec_f32};
use hroofline::runtime::xla;
use hroofline::runtime::{ArtifactStore, Engine};

fn store_or_skip() -> Option<ArtifactStore> {
    match ArtifactStore::open_default() {
        Ok(s) => Some(s),
        Err(e) => {
            eprintln!("skipping runtime integration test (no artifacts): {e:#}");
            None
        }
    }
}

#[test]
fn gemm_artifact_matches_reference() {
    let Some(store) = store_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let module = engine.load(&store, "gemm_128").unwrap();
    let n = 128usize;
    // x = row index pattern, w = identity => y == x
    let mut x = vec![0f32; n * n];
    for (i, v) in x.iter_mut().enumerate() {
        *v = ((i % 7) as f32) - 3.0;
    }
    let mut w = vec![0f32; n * n];
    for i in 0..n {
        w[i * n + i] = 1.0;
    }
    let lx = literal_f32(&x, &[n, n]).unwrap();
    let lw = literal_f32(&w, &[n, n]).unwrap();
    let out = engine.run(&module, &[lx, lw]).unwrap();
    assert_eq!(out.len(), 1);
    let y = to_vec_f32(&out[0]).unwrap();
    assert_eq!(y.len(), n * n);
    for (a, b) in y.iter().zip(&x) {
        assert!((a - b).abs() < 1e-5, "{a} vs {b}");
    }
}

#[test]
fn ert_artifact_runs_and_converges_to_fixed_point() {
    let Some(store) = store_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let module = engine.load(&store, "ert_fma").unwrap();
    let dims: Vec<usize> = module.entry.inputs[0].dims.clone();
    let n: usize = dims.iter().product();
    let x = vec![1.0f32; n];
    let lx = literal_f32(&x, &dims).unwrap();
    let out = engine.run(&module, &[lx]).unwrap();
    let y = to_vec_f32(&out[0]).unwrap();
    // v <- alpha*v + beta with alpha=1.000001, beta=0.999999 from v=1:
    // each iteration adds ~1, so 64 iterations land at ~65.002.
    assert!(y.iter().all(|v| v.is_finite()));
    assert!((y[0] - 65.002).abs() < 0.1, "{}", y[0]);
}

#[test]
fn forward_artifact_produces_logits() {
    let Some(store) = store_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let module = engine.load(&store, "forward").unwrap();
    let inputs: Vec<xla::Literal> = module
        .entry
        .inputs
        .iter()
        .map(|spec| {
            let n: usize = spec.dims.iter().product();
            let data = vec![0.01f32; n.max(1)];
            literal_f32(&data, &spec.dims).unwrap()
        })
        .collect();
    let out = engine.run(&module, &inputs).unwrap();
    assert_eq!(out.len(), module.entry.outputs.len());
    let logits = to_vec_f32(&out[0]).unwrap();
    let expect: usize = module.entry.outputs[0].dims.iter().product();
    assert_eq!(logits.len(), expect);
    assert!(logits.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_decreases_loss_over_iterations() {
    let Some(store) = store_or_skip() else { return };
    let engine = Engine::cpu().unwrap();
    let module = engine.load(&store, "train_step").unwrap();
    let specs = module.entry.inputs.clone();
    let n_out = module.entry.outputs.len();
    let n_state = n_out - 1; // params + momentum; final output is loss

    // Initialize state from the manifest shapes. Params must match the
    // python init distribution loosely; small random values suffice for
    // a loss-decrease smoke check.
    let mut rng = hroofline::util::Rng::new(7);
    let mut state: Vec<xla::Literal> = Vec::new();
    for spec in &specs[..n_state] {
        let n: usize = spec.dims.iter().product::<usize>().max(1);
        let data: Vec<f32> = (0..n).map(|_| (rng.f64() as f32 - 0.5) * 0.2).collect();
        state.push(literal_f32(&data, &spec.dims).unwrap());
    }
    // Batch: x (f32) and labels (s32).
    let x_spec = &specs[n_state];
    let nx: usize = x_spec.dims.iter().product();
    let x: Vec<f32> = (0..nx).map(|_| (rng.f64() as f32 - 0.5)).collect();
    let lx = literal_f32(&x, &x_spec.dims).unwrap();
    let l_spec = &specs[n_state + 1];
    let nl: usize = l_spec.dims.iter().product();
    let labels: Vec<i32> = (0..nl).map(|_| (rng.below(3)) as i32).collect();
    let ll = {
        let lit = xla::Literal::vec1(&labels);
        let dims: Vec<i64> = l_spec.dims.iter().map(|&d| d as i64).collect();
        lit.reshape(&dims).unwrap()
    };

    let mut losses = Vec::new();
    for _ in 0..4 {
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(n_state + 2);
        for s in &state {
            inputs.push(s.clone());
        }
        inputs.push(lx.clone());
        inputs.push(ll.clone());
        let out = engine.run(&module, &inputs).unwrap();
        let loss = to_vec_f32(&out[n_out - 1]).unwrap()[0];
        assert!(loss.is_finite(), "loss diverged");
        losses.push(loss);
        state = out.into_iter().take(n_state).collect();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}
