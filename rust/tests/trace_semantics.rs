//! Run-telemetry integration: the observability layer's two contracts,
//! end to end.
//!
//! 1. **Well-formedness** — an armed run emits a single-root span tree
//!    that parses, validates (unique ids, parents exist, intervals
//!    nest), and carries one `cell` span per enumerated matrix cell
//!    with its outcome/attempt annotations.
//! 2. **Observational purity** — arming tracing and metrics must not
//!    change a single artifact byte, and the counters a run reports
//!    must agree with its `CacheStats` (one source of truth).
//!
//! Plus fixed-clock determinism: a serial session traced under the
//! fixed tick clock produces bit-identical JSONL across runs, which is
//! what lets tests pin trace bytes at all.

use std::path::{Path, PathBuf};

use hroofline::device::{GpuSpec, Precision};
use hroofline::obs::{MetricsRegistry, Trace, Tracer};
use hroofline::profiler::{ProfileRequest, Session, SessionConfig};
use hroofline::scenario::store::CellStore;
use hroofline::scenario::{
    comparison_artifact, CacheStats, MatrixRun, MatrixRunOptions, ScenarioMatrix,
};
use hroofline::sim::kernel::{KernelDesc, KernelInvocation};

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hroofline-trsem-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The 8-cell smoke matrix (transformer x 2 frameworks x 2 phases x 2
/// AMP policies on the default device).
fn small_matrix() -> ScenarioMatrix {
    ScenarioMatrix::quick().with_workloads("transformer").unwrap()
}

#[test]
fn armed_matrix_run_emits_one_well_formed_span_tree() {
    let tracer = Tracer::new();
    let sink = MetricsRegistry::new();
    let m = small_matrix();
    {
        let root = tracer.span("matrix");
        let options = MatrixRunOptions {
            span: Some(&root),
            metrics: Some(&sink),
            ..Default::default()
        };
        let run = m.run_with(&options);
        assert!(run.failures.is_empty());
    }
    let trace = Trace::parse_jsonl(&tracer.to_jsonl()).unwrap();
    trace.validate().expect("armed run must emit a valid span tree");
    assert_eq!(trace.roots().len(), 1, "exactly one root span");

    // One `cell` span per enumerated cell, each annotated and parented
    // by the root.
    let root_id = trace.roots()[0].id;
    let cells: Vec<_> = trace.spans.iter().filter(|s| s.name == "cell").collect();
    assert_eq!(cells.len(), 8, "one cell span per matrix cell");
    for c in &cells {
        assert_eq!(c.parent, Some(root_id));
        assert_eq!(c.field("outcome"), Some("ran"));
        assert_eq!(c.field("attempt"), Some("1"));
        let label = c.field("label").unwrap();
        assert!(label.contains(":transformer-"), "{label}");
    }

    // The session pipeline stages show up beneath the cells.
    for name in ["prepare", "profile", "dedup", "simulate", "kernel", "merge", "aggregate"] {
        assert!(trace.spans.iter().any(|s| s.name == name), "missing '{name}' span");
    }
    let cell_ids: std::collections::HashSet<u64> = cells.iter().map(|s| s.id).collect();
    for p in trace.spans.iter().filter(|s| s.name == "profile") {
        assert!(
            p.parent.is_some_and(|pid| cell_ids.contains(&pid)),
            "profile spans hang off cell spans"
        );
    }

    // The sink registry saw the run's counters.
    assert_eq!(sink.snapshot().counter("matrix.cells.ran"), 8);
    assert!(sink.snapshot().counter("sim.kernels.simulated") > 0);
}

#[test]
fn fixed_clock_serial_session_traces_are_bit_identical() {
    let spec = GpuSpec::v100();
    let config = SessionConfig { threads: Some(1), ..Default::default() };
    let session = Session::new(&spec, config);
    let trace: Vec<KernelInvocation> = ["relu", "bias", "relu"]
        .iter()
        .map(|name| {
            KernelInvocation::once(KernelDesc::streaming_elementwise(
                name,
                1 << 14,
                Precision::Fp32,
                1,
            ))
        })
        .collect();
    let run_once = || {
        let tracer = Tracer::fixed();
        {
            let root = tracer.span("run");
            session.run(&ProfileRequest::new(&trace).with_span(&root)).unwrap();
        }
        tracer.to_jsonl()
    };
    let a = run_once();
    let b = run_once();
    assert_eq!(a, b, "fixed-tick serial traces must be reproducible");
    // And the bytes round-trip through the strict parser.
    let parsed = Trace::parse_jsonl(&a).unwrap();
    parsed.validate().unwrap();
    assert_eq!(parsed.clock, "fixed-tick");
    // Two distinct kernels after dedup -> two `kernel` spans.
    assert_eq!(parsed.spans.iter().filter(|s| s.name == "kernel").count(), 2);
}

fn write_artifacts(run: &MatrixRun, dir: &Path) {
    for result in &run.results {
        result.to_artifact().write_all(&dir.join("scenarios")).unwrap();
    }
    comparison_artifact(run).write_all(dir).unwrap();
}

fn assert_trees_identical(a: &Path, b: &Path) {
    let mut names: Vec<_> =
        std::fs::read_dir(a).unwrap().map(|e| e.unwrap().file_name()).collect();
    names.sort();
    assert!(!names.is_empty(), "{} is empty", a.display());
    for name in names {
        let (pa, pb) = (a.join(&name), b.join(&name));
        if pa.is_dir() {
            assert_trees_identical(&pa, &pb);
        } else {
            assert_eq!(
                std::fs::read(&pa).unwrap(),
                std::fs::read(&pb).unwrap(),
                "{} differs between traced and untraced runs",
                pa.display()
            );
        }
    }
}

#[test]
fn arming_telemetry_changes_no_artifact_bytes() {
    let base = tmpdir("byte-identity");
    let m = small_matrix();

    let plain_dir = base.join("plain");
    let plain = m.run_with(&MatrixRunOptions::default());
    write_artifacts(&plain, &plain_dir);

    let traced_dir = base.join("traced");
    let tracer = Tracer::new();
    let sink = MetricsRegistry::new();
    let traced = {
        let root = tracer.span("matrix");
        let options = MatrixRunOptions {
            span: Some(&root),
            metrics: Some(&sink),
            ..Default::default()
        };
        m.run_with(&options)
    };
    write_artifacts(&traced, &traced_dir);

    // Telemetry actually collected something...
    assert!(!tracer.records().is_empty());
    assert!(!sink.snapshot().is_empty());
    // ...and perturbed nothing: every txt/json/svg/csv/timeline byte
    // matches the untraced run.
    assert_trees_identical(&plain_dir, &traced_dir);
    let _ = std::fs::remove_dir_all(&base);
}

#[test]
fn store_counters_agree_with_cache_stats_across_cold_and_warm_runs() {
    let dir = tmpdir("warm-metrics");
    let store = CellStore::open(&dir).unwrap();
    let m = small_matrix();
    let options = MatrixRunOptions {
        store: Some(&store),
        incremental: true,
        ..Default::default()
    };

    let cold = m.run_with(&options);
    assert_eq!(cold.cache_stats, CacheStats { hits: 0, misses: 8, evictions: 0 });
    assert_eq!(cold.metrics.counter("store.misses"), 8);
    assert_eq!(cold.metrics.counter("matrix.cells.ran"), 8);
    assert_eq!(cold.metrics.counter("matrix.cells.replayed"), 0);
    assert!(cold.metrics.counter("store.bytes_written") > 0);

    let warm = m.run_with(&options);
    assert_eq!(warm.cache_stats, CacheStats { hits: 8, misses: 0, evictions: 0 });
    assert_eq!(warm.metrics.counter("matrix.cells.replayed"), 8);
    assert_eq!(warm.metrics.counter("matrix.cells.ran"), 0);
    assert_eq!(warm.metrics.counter("store.bytes_written"), 0);

    // CacheStats is *derived* from the registry, so the two views can
    // never drift — the invariant this assertion pins.
    for run in [&cold, &warm] {
        assert_eq!(run.cache_stats.hits, run.metrics.counter("store.hits"));
        assert_eq!(run.cache_stats.misses, run.metrics.counter("store.misses"));
        assert_eq!(run.cache_stats.evictions, run.metrics.counter("store.evictions"));
    }
    let _ = std::fs::remove_dir_all(&dir);
}
