//! Fault-isolated execution, end to end: deterministic fault injection
//! into the scenario matrix, graceful degradation (survivors keep
//! profiling, failures land in the error manifest), rerun determinism
//! for a fixed `FaultPlan`, and the zero-perturbation guarantee — an
//! armed-but-idle supervised run is byte-identical to the default one.

use hroofline::exec::{FaultInjector, FaultPlan, RetryPolicy, SupervisePolicy};
use hroofline::scenario::{
    comparison_artifact, comparison_csv, errors_manifest, MatrixRunOptions, ScenarioMatrix,
};

/// The 8-cell quick transformer sweep: tf/pt × forward/backward × O0/O1
/// on the default device. Small enough for CI, big enough to leave
/// survivors around any injected fault.
fn matrix() -> ScenarioMatrix {
    ScenarioMatrix::quick().with_workloads("transformer").unwrap()
}

const TF_FWD_O0: &str = "transformer-tf-forward-O0";
const PT_BWD_O1: &str = "transformer-pt-backward-O1";

#[test]
fn injected_faults_fell_exactly_the_targeted_cells() {
    let plan = FaultPlan::new(7).panic_on(TF_FWD_O0).panic_on(PT_BWD_O1);
    let injector = FaultInjector::new(plan);
    let options = MatrixRunOptions { policy: SupervisePolicy::default(), fault: Some(&injector) };
    let run = matrix().run_with(&options);

    // k = 2 faults: n - k survivors, every cell accounted for.
    assert_eq!(run.n_cells(), 8);
    assert_eq!(run.results.len(), 6);
    assert_eq!(run.failures.len(), 2);
    let failed: Vec<String> = run.failures.iter().map(|f| f.id()).collect();
    assert_eq!(failed, [TF_FWD_O0, PT_BWD_O1]);
    // tf-forward-O0 enumerates first, pt-backward-O1 last.
    assert_eq!(run.failures[0].index, 0);
    assert_eq!(run.failures[1].index, 7);

    // Every surviving cell still renders its full artifact.
    for r in &run.results {
        assert!(!r.id().contains("tf-forward-O0") && !r.id().contains("pt-backward-O1"));
        let a = r.to_artifact();
        assert!(!a.text.is_empty(), "{}", r.id());
        assert!(a.svg.is_some(), "{}", r.id());
        assert!(a.csv.is_some(), "{}", r.id());
    }

    // The manifest lists exactly the k injected cells, as panics.
    let m = errors_manifest(&run);
    assert_eq!(m.get("schema").unwrap().as_str().unwrap(), "hroofline-matrix-errors-v1");
    assert_eq!(m.get("n_cells").unwrap().as_f64().unwrap(), 8.0);
    assert_eq!(m.get("n_ok").unwrap().as_f64().unwrap(), 6.0);
    assert_eq!(m.get("n_failed").unwrap().as_f64().unwrap(), 2.0);
    let entries = m.get("failures").unwrap().as_arr().unwrap();
    assert_eq!(entries.len(), 2);
    for (entry, want) in entries.iter().zip([TF_FWD_O0, PT_BWD_O1]) {
        assert_eq!(entry.get("cell").unwrap().as_str().unwrap(), want);
        assert_eq!(entry.get("kind").unwrap().as_str().unwrap(), "panicked");
        assert!(entry.get("error").unwrap().as_str().unwrap().contains("fault injected"));
    }

    // The comparison artifact carries the failure table and counts.
    let comparison = comparison_artifact(&run);
    assert!(comparison.text.contains("failed cells (2 of 8):"), "{}", comparison.text);
    assert!(comparison.text.contains(TF_FWD_O0), "{}", comparison.text);
    assert_eq!(comparison.json.get("n_failed").unwrap().as_f64().unwrap(), 2.0);
}

#[test]
fn fixed_fault_plan_reruns_identically() {
    // Chaos faults flip a per-label deterministic coin: two fresh
    // injectors built from the same plan must fell the same cells and
    // leave byte-identical survivor output. The plan mixes a guaranteed
    // panic (so there is always a failure to compare) with chaos scoped
    // to the four tf cells (so the four pt cells always survive).
    let sweep = || {
        let injector =
            FaultInjector::new(FaultPlan::new(42).panic_on(TF_FWD_O0).chaos("-tf-", 0.5));
        let options =
            MatrixRunOptions { policy: SupervisePolicy::default(), fault: Some(&injector) };
        matrix().run_with(&options)
    };
    let (a, b) = (sweep(), sweep());
    let ids = |run: &hroofline::scenario::MatrixRun| -> Vec<(usize, String, String)> {
        run.failures
            .iter()
            .map(|f| (f.index, f.id(), f.error.kind().to_string()))
            .collect()
    };
    assert_eq!(ids(&a), ids(&b));
    assert!(!a.failures.is_empty());
    assert!(a.results.len() >= 4, "the pt cells are outside the chaos blast radius");
    assert_eq!(comparison_csv(&a.results), comparison_csv(&b.results));
    // The manifests agree on everything except wall time.
    let (ma, mb) = (errors_manifest(&a), errors_manifest(&b));
    let ea = ma.get("failures").unwrap().as_arr().unwrap();
    let eb = mb.get("failures").unwrap().as_arr().unwrap();
    assert_eq!(ea.len(), eb.len());
    for (fa, fb) in ea.iter().zip(eb) {
        for key in ["cell", "index", "kind", "attempts", "error"] {
            assert_eq!(
                fa.get(key).unwrap().to_string_pretty(),
                fb.get(key).unwrap().to_string_pretty(),
                "{key}"
            );
        }
    }
}

#[test]
fn armed_but_idle_supervision_is_byte_identical_to_the_default_run() {
    // An injector whose plan matches nothing, plus an explicit policy,
    // must not perturb a single byte of the sweep's artifacts.
    let injector = FaultInjector::new(FaultPlan::new(7).panic_on("no-such-cell"));
    let options = MatrixRunOptions {
        policy: SupervisePolicy { retry: RetryPolicy::attempts(2), ..Default::default() },
        fault: Some(&injector),
    };
    let supervised = matrix().run_with(&options);
    let plain = matrix().run();
    assert!(supervised.failures.is_empty());
    assert_eq!(supervised.results.len(), plain.results.len());

    let (a, b) = (comparison_artifact(&supervised), comparison_artifact(&plain));
    assert_eq!(a.text, b.text);
    assert_eq!(a.json.to_string_pretty(), b.json.to_string_pretty());
    assert_eq!(a.svg, b.svg);
    assert_eq!(a.csv, b.csv);
    for (ra, rb) in supervised.results.iter().zip(&plain.results) {
        let (aa, ab) = (ra.to_artifact(), rb.to_artifact());
        assert_eq!(aa.text, ab.text, "{}", ra.id());
        assert_eq!(aa.csv, ab.csv, "{}", ra.id());
    }
}

#[test]
fn transient_kernel_faults_ride_the_retry_budget_cleanly() {
    // Kernel-grain FailFirst faults are transient; a retry budget of 2
    // absorbs them and the sweep completes as if nothing happened.
    let injector = FaultInjector::new(FaultPlan::new(7).fail_first("kernel:", 1));
    let options = MatrixRunOptions {
        policy: SupervisePolicy { retry: RetryPolicy::attempts(2), ..Default::default() },
        fault: Some(&injector),
    };
    let healed = matrix().run_with(&options);
    assert!(healed.failures.is_empty(), "retries should absorb every transient fault");
    assert_eq!(comparison_csv(&healed.results), comparison_csv(&matrix().run().results));
}

#[test]
fn fail_fast_still_accounts_for_every_cell() {
    let injector = FaultInjector::new(FaultPlan::new(7).panic_on(TF_FWD_O0));
    let options = MatrixRunOptions {
        policy: SupervisePolicy { stop_after_failures: Some(1), ..Default::default() },
        fault: Some(&injector),
    };
    let run = matrix().run_with(&options);
    // Every cell lands somewhere; the injected cell panicked, and any
    // cell the budget cut off is reported as skipped, not lost.
    assert_eq!(run.n_cells(), 8);
    assert_eq!(run.results.len() + run.failures.len(), 8);
    assert!(run.failures.iter().any(|f| f.error.kind() == "panicked" && f.id() == TF_FWD_O0));
    for f in &run.failures {
        assert!(
            matches!(f.error.kind(), "panicked" | "skipped"),
            "{}: {}",
            f.id(),
            f.error.kind()
        );
    }
    let manifest = errors_manifest(&run);
    assert_eq!(
        manifest.get("n_failed").unwrap().as_f64().unwrap(),
        run.failures.len() as f64
    );
}
