//! Cross-module integration: operator graph → framework lowering →
//! profiler session → roofline model → chart, end to end over the
//! simulated V100 — plus consistency checks between the Rust trace
//! generator and the AOT-compiled JAX twin.

use hroofline::device::{GpuSpec, MemLevel};
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework, Phase};
use hroofline::dl::Policy;
use hroofline::profiler::{ProfileRequest, Session};
use hroofline::roofline::chart::RooflineChart;
use hroofline::roofline::model::RooflineModel;

#[test]
fn full_pipeline_tf_forward() {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::TensorFlow, Policy::O1, &spec);
    let profile = Session::standard(&spec)
        .run(&ProfileRequest::new(trace.phase(Phase::Forward)))
        .unwrap();
    assert!(profile.n_kernels() > 5);
    assert!(profile.total_seconds() > 0.0);

    let model = RooflineModel::from_profile(&spec, &profile);
    model.validate_bounds().expect("roofline bound");
    assert!(!model.points.is_empty());

    let chart = RooflineChart::hierarchical(&model, "integration");
    let svg = chart.to_svg();
    assert!(svg.contains("</svg>"));
    // Every point renders its triplet.
    let circles = svg.matches("<circle").count();
    assert!(circles >= model.points.len() * 2);
}

#[test]
fn backward_pass_dominates_forward_in_time() {
    // Paper §IV-A: "the backward pass ... is generally more
    // time-consuming" — holds under both frameworks.
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    for fw in [Framework::TensorFlow, Framework::PyTorch] {
        let trace = lower(&graph, fw, Policy::O1, &spec);
        let fwd = Session::standard(&spec)
            .run(&ProfileRequest::new(trace.phase(Phase::Forward)))
            .unwrap()
            .total_seconds();
        let bwd = Session::standard(&spec)
            .run(&ProfileRequest::new(trace.phase(Phase::Backward)))
            .unwrap()
            .total_seconds();
        assert!(bwd > fwd, "{fw:?}: bwd {bwd} fwd {fwd}");
    }
}

#[test]
fn amp_o1_speeds_up_both_frameworks() {
    // §IV-C: AMP reduces run time materially on the compute phases.
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    for fw in [Framework::TensorFlow, Framework::PyTorch] {
        let o0 = lower(&graph, fw, Policy::O0, &spec);
        let o1 = lower(&graph, fw, Policy::O1, &spec);
        let time = |t: &hroofline::dl::lower::FrameworkTrace| {
            Session::standard(&spec)
                .run(&ProfileRequest::new(&t.all()))
                .unwrap()
                .total_seconds()
        };
        let (t0, t1) = (time(&o0), time(&o1));
        assert!(t1 < t0 * 0.85, "{fw:?}: O1 {t1} vs O0 {t0}");
    }
}

#[test]
fn optimizer_kernels_sit_near_bandwidth_ceiling() {
    // Memory-bound streaming kernels should attain a sizable fraction of
    // the HBM roofline at their AI — the "circles near the ceilings"
    // reading of Fig. 7.
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let profile = Session::standard(&spec)
        .run(&ProfileRequest::new(trace.phase(Phase::Optimizer)))
        .unwrap();
    let model = RooflineModel::from_profile(&spec, &profile);
    assert!(!model.points.is_empty());
    for p in &model.points {
        let (_, ai) = p.ai.iter().find(|(l, _)| *l == MemLevel::Hbm).unwrap();
        let bound = model.ceilings.bound(MemLevel::Hbm, *ai);
        assert!(
            p.flops_per_sec > 0.2 * bound,
            "{}: {:.2e} vs bound {:.2e}",
            p.name,
            p.flops_per_sec,
            bound
        );
    }
}

#[test]
fn lite_graph_flops_match_aot_manifest_when_present() {
    // The Rust lite config and the AOT-compiled JAX model are twins:
    // their *forward* FLOP counts must agree within a factor ~2.5 (XLA
    // counts transcendentals/padding/fusions differently).
    let Ok(store) = hroofline::runtime::ArtifactStore::open_default() else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let Ok(entry) = store.entry("forward") else {
        return;
    };
    let Some(xla_flops) = entry.flops_per_run else {
        eprintln!("skipping: no XLA cost analysis available");
        return;
    };
    let graph = deepcam(&DeepCamConfig::lite());
    let ours = graph.total_flops() as f64;
    let ratio = ours / xla_flops;
    assert!(
        (0.3..3.0).contains(&ratio),
        "graph {ours:.3e} vs XLA {xla_flops:.3e} (ratio {ratio:.2})"
    );
}

#[test]
fn profiler_overhead_scales_with_metric_passes() {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::lite());
    let trace = lower(&graph, Framework::PyTorch, Policy::O1, &spec);
    let kernels = trace.phase(Phase::Forward);

    let packed = Session::standard(&spec).run(&ProfileRequest::new(kernels)).unwrap();
    let cfg = hroofline::profiler::SessionConfig {
        one_metric_per_run: true,
        ..Default::default()
    };
    let separate = Session::new(&spec, cfg).run(&ProfileRequest::new(kernels)).unwrap();
    assert!(separate.profiling_overhead_s > 2.0 * packed.profiling_overhead_s);
    // Same derived results either way (determinism requirement, §II-B).
    assert!((separate.total_seconds() - packed.total_seconds()).abs() < 1e-9);
}

#[test]
fn alternate_devices_profile_consistently() {
    // The device axis end to end: the same graph, lowered and profiled
    // per registry device, is strictly faster on the A100, slower on
    // the T4, and keeps Roofline bounds everywhere.
    let v100 = GpuSpec::v100();
    let a100 = GpuSpec::a100();
    let t4 = GpuSpec::t4();
    let graph = deepcam(&DeepCamConfig::paper());
    let seconds = |spec: &GpuSpec| {
        let trace = lower(&graph, Framework::TensorFlow, Policy::O1, spec);
        let profile = Session::standard(spec)
            .run(&ProfileRequest::new(trace.phase(Phase::Forward)))
            .unwrap();
        RooflineModel::from_profile(spec, &profile).validate_bounds().unwrap();
        assert_eq!(profile.device, spec.name);
        profile.total_seconds()
    };
    let (t_v, t_a, t_t) = (seconds(&v100), seconds(&a100), seconds(&t4));
    assert!(t_a < t_v, "a100 {t_a} vs v100 {t_v}");
    assert!(t_t > t_v, "t4 {t_t} vs v100 {t_v}");
}
