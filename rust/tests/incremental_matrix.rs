//! Incremental scenario-matrix integration: the content-addressed cell
//! store end to end — cross-process key stability, cold-vs-warm byte
//! identity, dirty-cell invalidation, shard partitioning, corrupt-entry
//! repair, and the fault/store exclusion rule.
//!
//! These are the contracts the sharded CI topology rests on: `--shard
//! i/N` jobs fill disjoint stores, `--merge` unions them, and the
//! merged report must be byte-identical to an unsharded run.

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::process::Command;

use hroofline::scenario::store::{CellStore, Lookup};
use hroofline::scenario::{
    cache_manifest, comparison_artifact, CacheStats, MatrixRunOptions, ScenarioMatrix,
};

fn tmpdir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("hroofline-incr-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// `repro matrix --quick --print-keys [extra...]` → stdout lines.
fn print_keys(extra: &[&str]) -> Vec<String> {
    let mut args = vec!["matrix", "--quick", "--print-keys"];
    args.extend_from_slice(extra);
    let out = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args(&args)
        .output()
        .expect("spawning repro");
    assert!(out.status.success(), "print-keys failed: {out:?}");
    String::from_utf8(out.stdout).unwrap().lines().map(String::from).collect()
}

#[test]
fn cell_keys_are_stable_across_processes() {
    // Two separate processes and an in-process enumeration must agree
    // line for line — the property that lets a CI shard trust entries
    // written by a different job on a different runner.
    let a = print_keys(&[]);
    let b = print_keys(&[]);
    assert_eq!(a, b, "two processes disagree on cell keys");
    let in_proc: Vec<String> = ScenarioMatrix::quick()
        .cell_keys()
        .into_iter()
        .map(|(key, id)| format!("{} {id}", key.as_hex()))
        .collect();
    assert_eq!(a, in_proc, "CLI and library enumerations disagree");
    assert_eq!(in_proc.len(), 32, "quick catalog is 32 cells");

    // Keys are 32 lowercase hex chars, pairwise distinct.
    let mut seen = HashSet::new();
    for line in &in_proc {
        let hex = line.split_whitespace().next().unwrap();
        assert_eq!(hex.len(), 32, "{line}");
        assert!(
            hex.chars().all(|c| c.is_ascii_digit() || ('a'..='f').contains(&c)),
            "{hex}"
        );
        assert!(seen.insert(hex.to_string()), "duplicate key {hex}");
    }
}

#[test]
fn shard_key_partition_unions_to_the_full_enumeration() {
    let all = print_keys(&[]);
    assert_eq!(all.len(), 32);
    let shards: Vec<Vec<String>> = (0..3)
        .map(|i| print_keys(&["--shard", &format!("{i}/3")]))
        .collect();
    // 32 cells round-robin across 3 shards: 11 / 11 / 10.
    assert_eq!(
        shards.iter().map(Vec::len).collect::<Vec<_>>(),
        vec![11, 11, 10]
    );
    // Disjoint, complete, and in global enumeration order: cell i lives
    // at position i/3 of shard i%3.
    let rebuilt: Vec<String> = (0..all.len()).map(|i| shards[i % 3][i / 3].clone()).collect();
    assert_eq!(rebuilt, all, "shards must partition the enumeration round-robin");
}

#[test]
fn warm_cli_run_reproduces_every_artifact_byte_for_byte() {
    let base = tmpdir("cli-warm");
    let store = base.join("store");
    let run = |out: &Path| {
        let status = Command::new(env!("CARGO_BIN_EXE_repro"))
            .args([
                "matrix",
                "--quick",
                "--workloads",
                "transformer",
                "--incremental",
                "--store",
                store.to_str().unwrap(),
                "--out",
                out.to_str().unwrap(),
            ])
            .status()
            .expect("spawning repro");
        assert!(status.success());
    };
    let cold = base.join("cold");
    let warm = base.join("warm");
    run(&cold);
    run(&warm);
    // Everything — comparison report, per-scenario artifacts, timeline
    // lanes, SVGs — must match byte for byte; only matrix.cache.json
    // (where the volatile stats live) is allowed to differ.
    assert_trees_identical(&cold, &warm, "matrix.cache.json");
    let cache = std::fs::read_to_string(warm.join("matrix.cache.json")).unwrap();
    assert!(cache.contains("\"misses\": 0"), "{cache}");
    assert!(cache.contains("\"simulations\": 0"), "{cache}");
    let _ = std::fs::remove_dir_all(&base);
}

fn assert_trees_identical(a: &Path, b: &Path, skip: &str) {
    let mut names: Vec<_> =
        std::fs::read_dir(a).unwrap().map(|e| e.unwrap().file_name()).collect();
    names.sort();
    assert!(!names.is_empty(), "{} is empty", a.display());
    for name in names {
        let (pa, pb) = (a.join(&name), b.join(&name));
        if pa.is_dir() {
            assert_trees_identical(&pa, &pb, skip);
        } else if name.to_str() != Some(skip) {
            assert_eq!(
                std::fs::read(&pa).unwrap(),
                std::fs::read(&pb).unwrap(),
                "{} differs between runs",
                pa.display()
            );
        }
    }
}

#[test]
fn device_change_dirties_every_cell_key() {
    let v100 = ScenarioMatrix::quick().with_workloads("transformer").unwrap().cell_keys();
    let t4 = ScenarioMatrix::quick()
        .with_workloads("transformer")
        .unwrap()
        .with_devices("t4")
        .unwrap()
        .cell_keys();
    assert_eq!(v100.len(), t4.len());
    let v100_set: HashSet<&str> = v100.iter().map(|(k, _)| k.as_hex()).collect();
    for (k, id) in &t4 {
        assert!(!v100_set.contains(k.as_hex()), "{id}: key must move with the GpuSpec");
    }
}

#[test]
fn dirty_cells_re_run_while_clean_cells_stay_cached() {
    let dir = tmpdir("dirty");
    let store = CellStore::open(&dir).unwrap();
    let options = MatrixRunOptions {
        store: Some(&store),
        incremental: true,
        ..Default::default()
    };
    let m = ScenarioMatrix::quick().with_workloads("transformer").unwrap();
    let cold = m.run_with(&options);
    assert_eq!(cold.cache_stats, CacheStats { hits: 0, misses: 8, evictions: 0 });

    // The same catalog on another device is entirely dirty: the warm
    // store serves nothing, every cell re-runs (and is persisted under
    // its new key alongside the old entries).
    let other = ScenarioMatrix::quick()
        .with_workloads("transformer")
        .unwrap()
        .with_devices("t4")
        .unwrap();
    let t4_run = other.run_with(&options);
    assert_eq!(t4_run.cache_stats, CacheStats { hits: 0, misses: 8, evictions: 0 });
    assert_eq!(store.n_entries(), 16);

    // The original matrix still hits all 8 of its own entries.
    let warm = m.run_with(&options);
    assert_eq!(warm.cache_stats, CacheStats { hits: 8, misses: 0, evictions: 0 });
    assert_eq!(warm.sim_stats.1, 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_store_entry_is_re_run_and_repaired() {
    let dir = tmpdir("corrupt");
    let store = CellStore::open(&dir).unwrap();
    let m = ScenarioMatrix::quick().with_workloads("transformer").unwrap();
    let options = MatrixRunOptions {
        store: Some(&store),
        incremental: true,
        ..Default::default()
    };
    let cold = m.run_with(&options);

    // Truncate one committed entry mid-JSON — a crashed writer, a bad
    // artifact download, cosmic rays. The contract: a cache miss plus
    // an eviction, never a hard error.
    let keys = m.cell_keys();
    let (key, _) = &keys[0];
    let path = dir.join(format!("{}.json", key.as_hex()));
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, &text[..text.len() / 2]).unwrap();
    assert!(matches!(store.load(key), Lookup::Corrupt));

    let repaired = m.run_with(&options);
    assert_eq!(repaired.cache_stats, CacheStats { hits: 7, misses: 1, evictions: 1 });
    let manifest = cache_manifest(&repaired);
    assert_eq!(manifest.get("store").unwrap().get("evictions").unwrap().as_f64().unwrap(), 1.0);

    // The re-run overwrote the entry in place, and corruption never
    // leaked into the artifacts.
    let healthy = m.run_with(&options);
    assert_eq!(healthy.cache_stats, CacheStats { hits: 8, misses: 0, evictions: 0 });
    let a = comparison_artifact(&cold);
    let b = comparison_artifact(&repaired);
    assert_eq!(a.text, b.text);
    assert_eq!(a.csv, b.csv);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fault_armed_cli_run_never_touches_the_store() {
    let base = tmpdir("fault");
    let store = base.join("store");
    let out = base.join("out");
    let status = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "matrix",
            "--quick",
            "--workloads",
            "transformer",
            "--incremental",
            "--store",
            store.to_str().unwrap(),
            "--inject-fault",
            "panic:transformer-tf-forward-O0",
            "--out",
            out.to_str().unwrap(),
        ])
        .status()
        .expect("spawning repro");
    assert_eq!(status.code(), Some(3), "one failed cell exits 3");
    // Fault drills bypass the store entirely: nothing was persisted,
    // not even the surviving cells — a drill must never seed the cache.
    let n = std::fs::read_dir(&store).map(|rd| rd.count()).unwrap_or(0);
    assert_eq!(n, 0, "fault-armed runs must not write cell entries");
    let _ = std::fs::remove_dir_all(&base);
}
