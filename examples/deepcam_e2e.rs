//! End-to-end driver — proves all three layers compose.
//!
//! 1. **Real training**: loads `artifacts/train_step.hlo.txt` (the JAX
//!    DeepCAM-lite model whose convolutions are Pallas GEMM kernels,
//!    AOT-lowered by `make artifacts`) and trains it through PJRT from
//!    Rust for a few hundred steps on synthetic climate tiles, logging
//!    the loss curve — Python never runs here.
//! 2. **Empirical roofline placement**: runs the host-CPU ERT sweep and
//!    reports where the measured training throughput sits against this
//!    machine's own measured ceilings.
//! 3. **Simulated V100 characterization** of the same network: lowers
//!    the paper-twin operator graph under both frameworks and emits the
//!    hierarchical roofline SVGs.
//!
//! Run: `make artifacts && cargo run --release --example deepcam_e2e -- --steps 200`

use hroofline::cli::Cmd;
use hroofline::coordinator::train::{run_training, TrainConfig};
use hroofline::device::{GpuSpec, MemLevel};
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework, Phase};
use hroofline::dl::Policy;
use hroofline::ert::{empirical, sweep::SweepConfig};
use hroofline::profiler::{ProfileRequest, Session};
use hroofline::roofline::chart::RooflineChart;
use hroofline::roofline::model::RooflineModel;
use hroofline::util::error as anyhow;
use hroofline::util::fmt;

fn main() -> anyhow::Result<()> {
    let cmd = Cmd::new("deepcam_e2e", "end-to-end DeepCAM-lite driver")
        .flag("steps", "200", "training steps")
        .flag("artifacts", "artifacts", "artifact directory")
        .flag("out", "out/e2e", "output directory");
    let args: Vec<String> = std::env::args().skip(1).collect();
    let parsed = match cmd.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{}", e.0);
            std::process::exit(2);
        }
    };
    let steps: usize = parsed.get_as("steps").map_err(|e| anyhow::anyhow!(e.0))?;
    let out_dir = parsed.get("out").to_string();
    std::fs::create_dir_all(&out_dir)?;

    // ---- 1. real training through PJRT --------------------------------
    println!("== [1/3] training DeepCAM-lite for {steps} steps (PJRT, CPU) ==");
    let cfg = TrainConfig {
        steps,
        artifacts_dir: parsed.get("artifacts").to_string(),
        log_every: (steps / 10).max(1),
        seed: 7,
    };
    let result = run_training(&cfg, |step, loss, dt| {
        println!("  step {step:>5}  loss {loss:.5}  ({}/step)", fmt::duration(dt));
    })?;
    println!(
        "  loss: {:.5} -> {:.5} over {} steps; median step {}",
        result.losses[0],
        result.final_loss(),
        steps,
        fmt::duration(result.step_seconds.median),
    );
    anyhow::ensure!(
        result.final_loss() < result.losses[0],
        "training failed to reduce loss"
    );
    // Persist the loss curve for EXPERIMENTS.md.
    let curve: Vec<String> = result
        .losses
        .iter()
        .enumerate()
        .map(|(i, l)| format!("{i},{l}"))
        .collect();
    std::fs::write(
        format!("{out_dir}/loss_curve.csv"),
        format!("step,loss\n{}\n", curve.join("\n")),
    )?;

    // ---- 2. empirical host roofline placement -------------------------
    println!("\n== [2/3] empirical host-CPU ERT sweep ==");
    let sweeps = empirical::characterize(&SweepConfig::quick());
    let fp32 = sweeps.iter().find(|s| s.label == "FP32").unwrap();
    let peak = fp32.peak_gflops() * 1e9;
    println!(
        "  host FP32 ceiling {} | L1 {} | DRAM {}",
        fmt::si_flops(peak),
        fmt::si(fp32.peak_bandwidth(MemLevel::L1) * 1e9, "B/s"),
        fmt::si(fp32.peak_bandwidth(MemLevel::Hbm) * 1e9, "B/s"),
    );
    if let Some(attained) = result.attained_flops_per_sec() {
        println!(
            "  training attained {} = {:.1}% of the host's measured ceiling",
            fmt::si_flops(attained),
            attained / peak * 100.0
        );
    } else {
        println!("  (no XLA FLOP estimate in manifest — skipping placement)");
    }

    // ---- 3. simulated V100 characterization ----------------------------
    println!("\n== [3/3] hierarchical rooflines of the paper-scale twin ==");
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());
    for (fw, phase, label) in [
        (Framework::TensorFlow, Phase::Forward, "tf_forward"),
        (Framework::TensorFlow, Phase::Backward, "tf_backward"),
        (Framework::PyTorch, Phase::Forward, "pt_forward"),
        (Framework::PyTorch, Phase::Backward, "pt_backward"),
        (Framework::PyTorch, Phase::Optimizer, "pt_optimizer"),
    ] {
        let trace = lower(&graph, fw, Policy::O1, &spec);
        let profile = Session::standard(&spec).run(&ProfileRequest::new(trace.phase(phase)))?;
        let model = RooflineModel::from_profile(&spec, &profile);
        model.validate_bounds().expect("roofline bounds");
        let chart =
            RooflineChart::hierarchical(&model, &format!("DeepCAM {label} (V100, simulated)"));
        let path = format!("{out_dir}/{label}.svg");
        std::fs::write(&path, chart.to_svg())?;
        println!(
            "  {label:<13} {} GPU-time, {} kernels -> {path}",
            fmt::duration(profile.total_seconds()),
            profile.n_kernels()
        );
    }
    println!("\nE2E complete. Artifacts in {out_dir}/");
    Ok(())
}
