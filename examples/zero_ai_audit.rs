//! Zero-AI kernel audit (paper §IV-D, Table III) plus the what-if the
//! paper recommends: "avoid such 'implicit' zero-AI kernels as much as
//! possible by fusing them" — we quantify the launch-overhead and
//! bandwidth savings of eliminating them.
//!
//! Run: `cargo run --release --example zero_ai_audit`

use hroofline::device::GpuSpec;
use hroofline::dl::deepcam::{deepcam, DeepCamConfig};
use hroofline::dl::lower::{lower, Framework, Phase};
use hroofline::dl::Policy;
use hroofline::profiler::{ProfileRequest, Session};
use hroofline::util::error as anyhow;
use hroofline::util::{fmt, Table};

fn main() -> anyhow::Result<()> {
    let spec = GpuSpec::v100();
    let graph = deepcam(&DeepCamConfig::paper());

    println!("Zero-AI kernel audit — one DeepCAM training step\n");
    let mut table = Table::new(&["framework", "phase", "zero-AI", "total", "fraction"]);
    let mut summaries = Vec::new();
    for fw in [Framework::TensorFlow, Framework::PyTorch] {
        let trace = lower(&graph, fw, Policy::O1, &spec);
        for (phase, label) in [
            (Phase::Forward, "forward"),
            (Phase::Backward, "backward"),
            (Phase::Optimizer, "optimizer"),
        ] {
            let (zero, total) = trace.zero_ai_census(phase, &spec);
            if total == 0 {
                continue;
            }
            table.row(&[
                fw.name().to_string(),
                label.to_string(),
                zero.to_string(),
                total.to_string(),
                fmt::pct(zero as f64 / total as f64),
            ]);
        }
        summaries.push((fw, trace));
    }
    println!("{}", table.render());

    // What-if: drop every zero-AI kernel (perfect fusion) and compare.
    println!("what-if: perfect fusion of all zero-AI kernels\n");
    let mut wi = Table::new(&[
        "framework",
        "time (as-is)",
        "time (fused)",
        "saved",
        "launch overhead saved",
    ]);
    for (fw, trace) in &summaries {
        let all = trace.all();
        let profile = Session::standard(&spec).run(&ProfileRequest::new(&all))?;
        let fused: Vec<_> = all
            .iter()
            .filter(|i| !i.kernel.mix.is_zero_ai(&spec))
            .cloned()
            .collect();
        let profile_fused = Session::standard(&spec).run(&ProfileRequest::new(&fused))?;
        let t0 = profile.total_seconds();
        let t1 = profile_fused.total_seconds();
        let removed: u64 = all
            .iter()
            .filter(|i| i.kernel.mix.is_zero_ai(&spec))
            .map(|i| i.invocations)
            .sum();
        let launch_saved = removed as f64 * spec.launch_latency_s;
        wi.row(&[
            fw.name().to_string(),
            fmt::duration(t0),
            fmt::duration(t1),
            fmt::pct(1.0 - t1 / t0),
            fmt::duration(launch_saved),
        ]);
    }
    println!("{}", wi.render());
    println!(
        "(launch overhead at {} per launch; the paper's point: as FLOP rates\n\
         and bandwidth grow faster than launch latency shrinks, these\n\
         kernels become overhead-bound — fuse them or overlap them.)",
        fmt::duration(spec.launch_latency_s)
    );
    Ok(())
}
