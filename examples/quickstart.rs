//! Quickstart: the 60-second tour of hroofline.
//!
//! 1. build the V100 device model and extract its Roofline ceilings;
//! 2. describe three kernels (a TC GEMM, a streaming FMA, a zero-AI
//!    cast) and profile them with the Nsight-analog session;
//! 3. print the hierarchical-roofline kernel table and write an SVG.
//!
//! Run: `cargo run --release --example quickstart`

use hroofline::device::{GpuSpec, Precision};
use hroofline::profiler::{ProfileRequest, Session};
use hroofline::util::error as anyhow;
use hroofline::roofline::chart::RooflineChart;
use hroofline::roofline::model::RooflineModel;
use hroofline::sim::kernel::{KernelDesc, KernelInvocation};
use hroofline::util::fmt;

fn main() -> anyhow::Result<()> {
    // --- 1. machine characterization -----------------------------------
    let spec = GpuSpec::v100();
    println!("device: {}", spec.name);
    for p in Precision::ALL {
        println!(
            "  {:10} ceiling: {}",
            p.name(),
            fmt::si_flops(spec.achievable_flops(p))
        );
    }
    println!(
        "  TensorCore ceiling: {}",
        fmt::si_flops(spec.achievable_tensor_flops())
    );

    // --- 2. application characterization -------------------------------
    let trace = vec![
        KernelInvocation::once(KernelDesc::gemm(
            "volta_h884gemm_demo", 4096, 4096, 4096, Precision::Fp16, true, 128, &spec,
        )),
        KernelInvocation {
            kernel: KernelDesc::streaming_elementwise("saxpy_demo", 1 << 22, Precision::Fp32, 2),
            invocations: 16,
            stream: 0,
        },
        KernelInvocation {
            kernel: KernelDesc::streaming_elementwise("cast_f2h_demo", 1 << 22, Precision::Fp16, 0),
            invocations: 8,
            stream: 0,
        },
    ];
    let profile = Session::standard(&spec).run(&ProfileRequest::new(&trace))?;
    println!(
        "\nprofiled {} kernels / {} invocations, total GPU time {}",
        profile.n_kernels(),
        profile.total_invocations(),
        fmt::duration(profile.total_seconds())
    );
    let (zero, total) = profile.zero_ai_census();
    println!("zero-AI invocations: {zero}/{total}");

    // --- 3. the hierarchical roofline -----------------------------------
    let model = RooflineModel::from_profile(&spec, &profile);
    model.validate_bounds().expect("all kernels under the roofline");
    let chart = RooflineChart::hierarchical(&model, "Quickstart — three kernels on a V100");
    println!("\n{}", chart.to_table().render());
    std::fs::create_dir_all("out/quickstart")?;
    std::fs::write("out/quickstart/roofline.svg", chart.to_svg())?;
    println!("wrote out/quickstart/roofline.svg");
    Ok(())
}
